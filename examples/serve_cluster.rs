//! End-to-end **fleet** deployment — the repo's full-stack validation
//! driver: two real cloud VLA servers (TCP, batcher + worker thread) serve
//! cross-session batched chunk requests from a fleet of robot sessions,
//! each running the RAPID dispatcher against its own manipulator
//! simulator. The fleet scheduler coalesces cloud offloads from different
//! sessions into single wire frames and spreads batches across the
//! endpoints with a least-loaded router.
//!
//! All layers compose here: L1 Pallas kernels (inside the HLO, when the
//! `pjrt` feature + artifacts are present), L2 JAX model, L3 rust
//! dispatcher + fleet scheduler + batcher + router, real TCP.
//!
//! ```text
//! cargo run --release --example serve_cluster
//! ```

use rapid::config::presets::libero_preset;
use rapid::config::PolicyKind;
use rapid::experiments::Backends;
use rapid::net::{CloudClient, CloudServer};
use rapid::robot::TaskKind;
use rapid::serve::Fleet;
use rapid::vla::Backend;
use std::sync::atomic::Ordering;

fn start_endpoint(tag: u64, max_batch: usize) -> CloudServer {
    CloudServer::start("127.0.0.1:0", max_batch, move || match Backends::try_pjrt() {
        Ok(b) => {
            println!("[cloud {tag}] serving the AOT-compiled cloud variant via PJRT");
            b.cloud
        }
        Err(e) => {
            println!("[cloud {tag}] PJRT unavailable ({e}); serving analytic surrogate");
            Box::new(rapid::vla::AnalyticBackend::cloud(tag)) as Box<dyn Backend>
        }
    })
    .expect("server start")
}

fn main() {
    let mut sys = libero_preset();
    sys.fleet.n_sessions = 8;
    sys.fleet.max_batch = 4;
    sys.fleet.max_inflight = 16;
    sys.fleet.episodes_per_session = 2;

    // ---- cloud side: two endpoints, each with its own batcher/worker ----
    let servers: Vec<CloudServer> =
        (0..2).map(|i| start_endpoint(i as u64 + 1, sys.fleet.max_batch)).collect();
    let clients: Vec<CloudClient> = servers
        .iter()
        .map(|s| {
            let mut c = CloudClient::connect(&s.addr.to_string()).expect("connect");
            let ping = c.ping().expect("ping");
            println!("[edge] connected to {} (TCP ping {:?})", s.addr, ping);
            c
        })
        .collect();

    // ---- edge side: N concurrent RAPID sessions over the shared path ----
    let t0 = std::time::Instant::now();
    let res = Fleet::remote(&sys, TaskKind::PickPlace, PolicyKind::Rapid, clients).run();
    let wall = t0.elapsed().as_secs_f64();
    let summary = res.summary();

    for s in &res.sessions {
        let offloads: u64 = s.episodes.iter().map(|m| m.cloud_events).sum();
        let ok = s.episodes.iter().filter(|m| m.success).count();
        println!(
            "[edge] session {}: {} episodes, {} ok, {} offloads, seed {:#x}",
            s.session,
            s.episodes.len(),
            ok,
            offloads,
            s.seed0
        );
    }

    // ---- report ----
    let st = &res.stats;
    println!("\n=== fleet report ===");
    println!(
        "sessions              : {} × {} episodes",
        summary.sessions, sys.fleet.episodes_per_session
    );
    println!(
        "control steps         : {} in {wall:.2}s wall => {:.0} steps/s",
        summary.total_steps,
        summary.total_steps as f64 / wall.max(1e-9)
    );
    println!("cloud offloads (TCP)  : {}", summary.total_cloud_events);
    println!(
        "wire batches          : {} (multi-session {}, mean {:.2}, max {})",
        st.batches, st.multi_session_batches, res.mean_batch, st.max_batch_observed
    );
    println!(
        "flushes               : full {} / deadline {} / drain {}",
        st.full_flushes, st.deadline_flushes, st.drain_flushes
    );
    println!("endpoint spread       : {:?}", res.endpoint_dispatches);
    for (i, s) in servers.iter().enumerate() {
        println!(
            "server {i}              : {} requests in {} worker batches ({} batch frames)",
            s.stats().requests.load(Ordering::Relaxed),
            s.stats().batches.load(Ordering::Relaxed),
            s.stats().batch_frames.load(Ordering::Relaxed)
        );
    }
    println!(
        "fleet latency         : total {:.1}ms/chunk (cloud {:.1} + edge {:.1})",
        summary.fleet.total_lat_mean, summary.fleet.cloud_lat_ms, summary.fleet.edge_lat_ms
    );

    for s in servers {
        s.shutdown();
    }
    println!("[cloud] shut down cleanly");
}

//! End-to-end edge-cloud deployment — the repo's full-stack validation
//! driver (DESIGN.md "End-to-end validation"): a *real* cloud VLA server
//! (PJRT-compiled AOT artifact behind a TCP router/batcher) serves chunk
//! requests from an edge process running the RAPID dispatcher against the
//! manipulator simulator; we then report batched-request latency and
//! throughput over the wire.
//!
//! All layers compose here: L1 Pallas kernels (inside the HLO), L2 JAX
//! model (the artifact), L3 rust dispatcher + server + router, real TCP.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_cluster
//! ```

use rapid::config::presets::libero_preset;
use rapid::config::PolicyKind;
use rapid::experiments::Backends;
use rapid::net::{CloudClient, CloudServer};
use rapid::robot::tasks::ALL_TASKS;
use rapid::serve::run_episode;
use rapid::util::Summary;
use rapid::vla::Backend;
use std::sync::atomic::Ordering;

fn main() {
    let sys = libero_preset();

    // ---- cloud side: PJRT-backed server with a batcher ----
    let server = CloudServer::start("127.0.0.1:0", 8, || match Backends::try_pjrt() {
        Ok(b) => {
            println!("[cloud] serving the AOT-compiled cloud variant via PJRT");
            b.cloud
        }
        Err(e) => {
            println!("[cloud] PJRT unavailable ({e}); serving analytic surrogate");
            Box::new(rapid::vla::AnalyticBackend::cloud(1))
        }
    })
    .expect("server start");
    let addr = server.addr.to_string();
    println!("[cloud] listening on {addr}");

    // ---- edge side: RAPID episodes whose cloud calls go over TCP ----
    let mut edge_backend: Box<dyn Backend> = match Backends::try_pjrt() {
        Ok(b) => b.edge,
        Err(_) => Box::new(rapid::vla::AnalyticBackend::edge(2)),
    };
    let mut cloud_client = CloudClient::connect(&addr).expect("connect");
    let ping = cloud_client.ping().expect("ping");
    println!("[edge] connected; TCP ping {:?}", ping);

    let t0 = std::time::Instant::now();
    let mut total_steps = 0usize;
    let mut offloads = 0u64;
    let mut successes = 0usize;
    let mut episodes = 0usize;
    for (i, &task) in ALL_TASKS.iter().enumerate() {
        for ep in 0..2 {
            let strategy = rapid::policy::build(PolicyKind::Rapid, &sys);
            let out = run_episode(
                &sys,
                task,
                strategy,
                edge_backend.as_mut(),
                &mut cloud_client,
                1000 + (i * 10 + ep) as u64,
                false,
            );
            total_steps += out.metrics.steps;
            offloads += out.metrics.cloud_events;
            successes += out.metrics.success as usize;
            episodes += 1;
            println!(
                "[edge] {} ep{}: steps={} offloads={} success={}",
                task.name(),
                ep,
                out.metrics.steps,
                out.metrics.cloud_events,
                out.metrics.success
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---- report ----
    let rtts: Vec<f64> = cloud_client.rtts_us.iter().map(|&u| u as f64 / 1000.0).collect();
    let s = Summary::of(&rtts);
    println!("\n=== end-to-end report ===");
    println!("episodes              : {episodes} ({successes} successful)");
    println!("control steps         : {total_steps} in {wall:.2}s wall => {:.0} steps/s", total_steps as f64 / wall);
    println!("cloud offloads (TCP)  : {offloads}");
    println!("request RTT           : mean {:.2}ms p50 {:.2}ms p95 {:.2}ms max {:.2}ms", s.mean, s.p50, s.p95, s.max);
    println!("server requests       : {}", server.stats().requests.load(Ordering::Relaxed));
    println!("server batches        : {}", server.stats().batches.load(Ordering::Relaxed));
    println!(
        "throughput            : {:.1} req/s over the wire",
        offloads as f64 / wall
    );

    server.shutdown();
    println!("[cloud] shut down cleanly");
}

//! Quickstart: load the AOT-compiled VLA surrogate, run one RAPID episode
//! on the LIBERO preset, and print the latency/load summary.
//!
//! ```bash
//! make artifacts            # once: python AOT -> artifacts/*.hlo.txt
//! cargo run --release --example quickstart
//! ```

use rapid::config::presets::libero_preset;
use rapid::config::PolicyKind;
use rapid::experiments::Backends;
use rapid::robot::TaskKind;
use rapid::serve::run_episode;

fn main() {
    let sys = libero_preset();
    // Real path: PJRT + HLO artifacts (falls back to the analytic surrogate
    // with a warning if `make artifacts` hasn't been run).
    let mut backends = Backends::pjrt_or_analytic(42);

    println!("== RAPID quickstart: {} / {} ==", sys.name, TaskKind::PickPlace.name());
    let strategy = rapid::policy::build(PolicyKind::Rapid, &sys);
    let out = run_episode(
        &sys,
        TaskKind::PickPlace,
        strategy,
        backends.edge.as_mut(),
        backends.cloud.as_mut(),
        42,
        true,
    );

    let m = &out.metrics;
    let (cloud, edge, total) = m.latency_columns();
    println!("steps executed        : {}", m.steps);
    println!("edge refills          : {}", m.edge_events);
    println!("cloud offloads        : {} ({} preemptions)", m.cloud_events, m.preemptions);
    println!(
        "emulated latency      : cloud {cloud:.1}ms + edge {edge:.1}ms => total {total:.1}ms per event"
    );
    println!("parameter placement   : edge {:.1}GB / cloud {:.1}GB", m.edge_gb, m.cloud_gb);
    println!("trigger precision     : {:.2}", m.trigger_precision());
    println!("task success          : {} (rms tracking error {:.3} rad)", m.success, m.rms_error);

    if let Some(trace) = out.trace {
        println!("\ntimeline (sparklines over {} steps):", m.steps);
        println!("  saliency {}", trace.sparkline("saliency", 60));
        println!("  torque   {}", trace.sparkline("tau_norm", 60));
        println!("  offload  {}", trace.sparkline("offload", 60));
    }
}

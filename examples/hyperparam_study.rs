//! Hyper-parameter study (paper §VI-D.1): sweep the dual thresholds and
//! the cooldown, reporting the latency/offload trade-off curve.
//!
//! ```bash
//! cargo run --release --example hyperparam_study
//! ```

use rapid::config::presets::libero_preset;
use rapid::config::PolicyKind;
use rapid::experiments::{sweep, Backends};
use rapid::metrics::aggregate;
use rapid::robot::tasks::ALL_TASKS;
use rapid::serve::session::run_policy;

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(31);

    // threshold grid around the paper's optimum
    let (table, points) = sweep::run(&sys, &mut backends, &[0.35, 0.65, 1.0], &[0.2, 0.35, 0.6], 2);
    print!("{}", table.render());
    let best = points.iter().min_by(|a, b| a.total_lat.partial_cmp(&b.total_lat).unwrap()).unwrap();
    println!(
        "best: ({:.2}, {:.2}) @ {:.1}ms — paper reports (0.65, 0.35) as the balance point\n",
        best.theta_comp, best.theta_red, best.total_lat
    );

    // cooldown study (paper §V-B: C prevents network flooding)
    println!("cooldown C sweep (offloads/episode and latency):");
    for c in [0u32, 4, 12, 24] {
        let mut s = sys.clone();
        s.dispatcher.cooldown = c;
        let res = run_policy(
            &s,
            PolicyKind::Rapid,
            &ALL_TASKS,
            2,
            backends.edge.as_mut(),
            backends.cloud.as_mut(),
        );
        let row = aggregate(PolicyKind::Rapid, &res.episodes);
        let offl = res.episodes.iter().map(|m| m.cloud_events as f64).sum::<f64>()
            / res.episodes.len() as f64;
        println!(
            "  C={c:<3} offloads/ep {offl:>5.1}  total {:.1}ms  success {:.0}%",
            row.total_lat_mean,
            100.0 * row.success_rate
        );
    }
}

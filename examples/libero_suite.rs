//! LIBERO simulation suite (paper Table III workload): all four policies
//! over the three manipulation tasks, printed as the paper's comparison
//! table — this is the repo's main reproduction driver.
//!
//! ```bash
//! cargo run --release --example libero_suite [episodes]
//! ```

use rapid::config::presets::libero_preset;
use rapid::config::PolicyKind;
use rapid::experiments::{tab345, Backends};

fn main() {
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);

    let t0 = std::time::Instant::now();
    let (table, rows) = tab345::tab3(&sys, &mut backends, episodes);
    print!("{}", table.render());

    let rapid_row = rows.get(PolicyKind::Rapid);
    let vision_row = rows.get(PolicyKind::VisionBased);
    let edge_row = rows.get(PolicyKind::EdgeOnly);
    println!("\nheadline numbers:");
    println!(
        "  RAPID total latency    : {:.1} ± {:.1} ms",
        rapid_row.total_lat_mean, rapid_row.total_lat_std
    );
    println!("  speedup vs vision-based: {:.2}x", rows.speedup_vs_vision());
    println!(
        "  speedup vs edge-only   : {:.2}x",
        edge_row.total_lat_mean / rapid_row.total_lat_mean
    );
    println!(
        "  accuracy (success rate): RAPID {:.0}% vs vision {:.0}%",
        100.0 * rapid_row.success_rate,
        100.0 * vision_row.success_rate
    );
    println!(
        "  measured model time    : edge {:.0}µs / cloud {:.0}µs per call (real PJRT wall clock)",
        rapid_row.measured_edge_us,
        rapid_row.measured_cloud_us
    );
    println!("[suite wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

//! Compatibility study (paper Tab. I + Fig. 2): how the vision-based
//! baseline and RAPID respond to increasing visual disturbance. RAPID's
//! kinematic triggers are environment-agnostic, so its latency should stay
//! flat where the vision baseline degrades.
//!
//! ```bash
//! cargo run --release --example noise_sweep
//! ```

use rapid::config::presets::libero_preset;
use rapid::config::{NoiseLevel, PolicyKind};
use rapid::experiments::Backends;
use rapid::metrics::aggregate;
use rapid::robot::tasks::ALL_TASKS;
use rapid::serve::session::run_policy;
use rapid::util::tablefmt::{ms, Table};

fn main() {
    let mut backends = Backends::pjrt_or_analytic(7);
    let mut table = Table::new(
        "Noise compatibility: total latency (and cloud offloads/episode)",
        &["Noise", "Vision-Based", "RAPID", "Vision offloads/ep", "RAPID offloads/ep"],
    );
    let mut vision_lat = Vec::new();
    let mut rapid_lat = Vec::new();
    for noise in [NoiseLevel::Standard, NoiseLevel::VisualNoise, NoiseLevel::Distraction] {
        let mut sys = libero_preset();
        sys.scene.noise = noise;
        let mut lat = Vec::new();
        let mut offl = Vec::new();
        for kind in [PolicyKind::VisionBased, PolicyKind::Rapid] {
            let res = run_policy(
                &sys,
                kind,
                &ALL_TASKS,
                3,
                backends.edge.as_mut(),
                backends.cloud.as_mut(),
            );
            let row = aggregate(kind, &res.episodes);
            lat.push(row.total_lat_mean);
            let mean_offl = res.episodes.iter().map(|m| m.cloud_events as f64).sum::<f64>()
                / res.episodes.len() as f64;
            offl.push(mean_offl);
        }
        vision_lat.push(lat[0]);
        rapid_lat.push(lat[1]);
        table.row(&[
            noise.name().to_string(),
            ms(lat[0]),
            ms(lat[1]),
            format!("{:.1}", offl[0]),
            format!("{:.1}", offl[1]),
        ]);
    }
    print!("{}", table.render());
    let degradation = |v: &[f64]| (v[2] - v[0]) / v[0] * 100.0;
    println!(
        "\nlatency degradation Standard -> Distraction: vision {:+.0}%  RAPID {:+.0}%",
        degradation(&vision_lat),
        degradation(&rapid_lat)
    );
    println!(
        "RAPID is environment-agnostic: {}",
        degradation(&rapid_lat).abs() < degradation(&vision_lat).abs()
    );
}

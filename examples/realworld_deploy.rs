//! Real-world deployment scenario (paper Table IV): the physical-testbed
//! preset — slower edge SoC, lossier wireless link, noisier torque sensors
//! — comparing ISAR (vision-based) against RAPID, plus the end-to-end
//! 1.73x headline speedup check.
//!
//! ```bash
//! cargo run --release --example realworld_deploy [episodes]
//! ```

use rapid::config::presets::realworld_preset;
use rapid::config::PolicyKind;
use rapid::experiments::{tab345, Backends};

fn main() {
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let sys = realworld_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);

    println!("preset: {} — edge SoC {:.1}ms full model, link {:.0}Mbps rtt {:.0}ms\n",
        sys.name, sys.devices.edge_full_ms, sys.link.bw_mbps, sys.link.rtt_ms);

    let (table, rows) = tab345::tab4(&sys, &mut backends, episodes);
    print!("{}", table.render());

    let rapid = rows.get(PolicyKind::Rapid);
    println!(
        "\nRAPID end-to-end speedup vs ISAR: {:.2}x (paper: ~1.73x)",
        rows.speedup_vs_vision()
    );
    println!("RAPID edge footprint            : {:.1} GB (paper: 2.4 GB)", rapid.edge_gb);
    println!("RAPID latency stability (std)   : ±{:.1} ms", rapid.total_lat_std);
}

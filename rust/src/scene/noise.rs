//! Visual disturbance models producing a per-step scene clarity in (0, 1].

use crate::config::{NoiseLevel, SceneConfig};
use crate::util::Pcg32;

/// Stateful clarity process for one episode.
#[derive(Debug, Clone)]
pub struct NoiseModel {
    cfg: SceneConfig,
    rng: Pcg32,
    /// Remaining steps of an active distractor occlusion.
    occlusion_left: usize,
}

impl NoiseModel {
    pub fn new(cfg: &SceneConfig, seed: u64) -> Self {
        NoiseModel { cfg: cfg.clone(), rng: Pcg32::new(seed, 0x5CE_E), occlusion_left: 0 }
    }

    /// Scene clarity at a control step. `interacting` marks steps where the
    /// gripper itself partially occludes the target (a small, *physical*
    /// clarity dip present even in clean scenes).
    pub fn clarity(&mut self, interacting: bool) -> f64 {
        let base = match self.cfg.noise {
            NoiseLevel::Standard => 1.0,
            NoiseLevel::VisualNoise => {
                // flickering lighting/camera noise: clarity wanders around
                // the configured floor
                let c = self.cfg.visual_noise_clarity;
                (c + 0.18 * self.rng.normal()).clamp(0.15, 0.9)
            }
            NoiseLevel::Distraction => {
                if self.occlusion_left > 0 {
                    self.occlusion_left -= 1;
                    self.cfg.occlusion_clarity
                } else if self.rng.chance(self.cfg.occlusion_rate) {
                    self.occlusion_left = self.cfg.occlusion_len.saturating_sub(1);
                    self.cfg.occlusion_clarity
                } else {
                    1.0
                }
            }
        };
        let gripper = if interacting { 0.88 } else { 1.0 };
        (base * gripper).clamp(0.05, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(noise: NoiseLevel) -> SceneConfig {
        SceneConfig { noise, ..SceneConfig::default() }
    }

    #[test]
    fn standard_is_clean() {
        let mut nm = NoiseModel::new(&cfg(NoiseLevel::Standard), 1);
        for _ in 0..100 {
            assert_eq!(nm.clarity(false), 1.0);
        }
    }

    #[test]
    fn standard_interaction_dips_slightly() {
        let mut nm = NoiseModel::new(&cfg(NoiseLevel::Standard), 1);
        let c = nm.clarity(true);
        assert!(c < 1.0 && c > 0.8);
    }

    #[test]
    fn visual_noise_degrades_mean_clarity() {
        let mut nm = NoiseModel::new(&cfg(NoiseLevel::VisualNoise), 2);
        let mean: f64 = (0..500).map(|_| nm.clarity(false)).sum::<f64>() / 500.0;
        assert!(mean < 0.7, "mean clarity {mean}");
        assert!(mean > 0.2);
    }

    #[test]
    fn distraction_produces_occlusion_runs() {
        let mut nm = NoiseModel::new(&cfg(NoiseLevel::Distraction), 3);
        let cs: Vec<f64> = (0..400).map(|_| nm.clarity(false)).collect();
        let occluded = cs.iter().filter(|&&c| c < 0.5).count();
        assert!(occluded > 20, "occluded steps {occluded}");
        // occlusions come in runs of the configured length
        let mut run = 0;
        let mut max_run = 0;
        for &c in &cs {
            if c < 0.5 {
                run += 1;
                max_run = max_run.max(run);
            } else {
                run = 0;
            }
        }
        assert!(max_run >= 3);
    }

    #[test]
    fn deterministic() {
        let mut a = NoiseModel::new(&cfg(NoiseLevel::Distraction), 9);
        let mut b = NoiseModel::new(&cfg(NoiseLevel::Distraction), 9);
        for _ in 0..100 {
            assert_eq!(a.clarity(false), b.clarity(false));
        }
    }
}

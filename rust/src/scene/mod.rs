//! Synthetic scene: observation renderer + visual disturbance models.
//!
//! The renderer emits the 64-channel observation vector the VLA surrogate
//! was constructed against (layout documented in `python/compile/model.py`
//! and mirrored in `python/tests/obsgen.py`). Visual noise is modeled as
//! *signal attenuation + clutter* — occlusion and contrast loss scale every
//! channel down and replace texture with occluder texture — which provably
//! flattens the surrogate's action logits (the vision baseline's failure
//! mode in Tab. I / Fig. 2).

pub mod noise;
pub mod renderer;

pub use noise::NoiseModel;
pub use renderer::Renderer;

//! Observation renderer: simulator state -> 64-channel visual feature
//! vector, matching the layout the surrogate weights were constructed
//! against (python/compile/model.py):
//!
//! ```text
//! [0:7)   normalized joint error to the current waypoint
//! [7:15)  contact-saliency horizon over the next k steps
//! [15]    global interaction saliency
//! [16:64) texture channels (scene-hash pseudo-features, clarity-scaled)
//! ```

use super::noise::NoiseModel;
use crate::robot::RobotSim;
use crate::util::Pcg32;
use crate::{CHUNK, D_VIS, N_JOINTS};

#[derive(Debug, Clone)]
pub struct Renderer {
    noise: NoiseModel,
    rng: Pcg32,
    /// Persistent scene texture: the workspace's visual content is static
    /// across an episode. Its *energy* is what a confident VLA sees —
    /// occluders/flicker attenuate it (occluders are featureless blobs, so
    /// the replacement clutter is low-energy).
    scene_texture: [f32; D_VIS - 16],
    /// Last rendered clarity (exposed for trace/debug).
    pub last_clarity: f64,
}

/// Per-channel std of the persistent scene texture.
pub const SCENE_TEXTURE_STD: f64 = 0.45;
/// Per-channel std of occluder clutter (featureless => low energy).
pub const CLUTTER_STD: f64 = 0.10;

impl Renderer {
    pub fn new(noise: NoiseModel, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0x0B5);
        let mut scene_texture = [0f32; D_VIS - 16];
        for t in scene_texture.iter_mut() {
            *t = rng.normal_ms(0.0, SCENE_TEXTURE_STD) as f32;
        }
        Renderer { noise, rng, scene_texture, last_clarity: 1.0 }
    }

    /// Render the observation for the simulator's current step.
    pub fn render(&mut self, sim: &RobotSim) -> [f32; D_VIS] {
        let t = sim.step_index();
        let interacting = sim.traj.phase_at(t).is_critical();
        let clarity = self.noise.clarity(interacting);
        self.last_clarity = clarity;

        let mut obs = [0.0f32; D_VIS];
        let err = sim.joint_error();
        for j in 0..N_JOINTS {
            obs[j] = err[j].clamp(-1.5, 1.5) as f32;
        }
        let horizon = sim.traj.saliency_horizon(t, CHUNK);
        for (i, s) in horizon.iter().enumerate() {
            obs[7 + i] = *s as f32;
        }
        obs[15] = sim.traj.saliency_at(t) as f32;
        // texture: the persistent scene content + small sensor noise
        for (o, s) in obs.iter_mut().skip(16).zip(self.scene_texture.iter()) {
            *o = *s + self.rng.normal_ms(0.0, 0.05) as f32;
        }
        // attenuation: occlusion hides semantics AND texture...
        for o in obs.iter_mut() {
            *o *= clarity as f32;
        }
        // ...and low-energy occluder clutter replaces the texture signal
        // without restoring the semantic channels.
        for o in obs.iter_mut().take(D_VIS).skip(16) {
            *o += self.rng.normal_ms(0.0, CLUTTER_STD * (1.0 - clarity)) as f32;
        }
        obs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NoiseLevel, RobotConfig, SceneConfig};
    use crate::robot::TaskKind;

    fn renderer(noise: NoiseLevel, seed: u64) -> Renderer {
        let scfg = SceneConfig { noise, ..SceneConfig::default() };
        Renderer::new(NoiseModel::new(&scfg, seed), seed)
    }

    fn sim() -> RobotSim {
        RobotSim::new(TaskKind::PickPlace, &RobotConfig::default(), 3)
    }

    #[test]
    fn layout_semantics_clean_scene() {
        let s = sim();
        let mut r = renderer(NoiseLevel::Standard, 1);
        let obs = r.render(&s);
        // joint error channels match the sim
        let err = s.joint_error();
        for j in 0..N_JOINTS {
            assert!((obs[j] as f64 - err[j].clamp(-1.5, 1.5)).abs() < 1e-6);
        }
        // saliency channels in [0,1]
        for i in 7..16 {
            assert!((0.0..=1.0).contains(&(obs[i] as f64)));
        }
        assert_eq!(r.last_clarity, 1.0);
    }

    #[test]
    fn noise_attenuates_semantic_channels() {
        let s = sim();
        let mut clean = renderer(NoiseLevel::Standard, 1);
        let mut noisy = renderer(NoiseLevel::VisualNoise, 1);
        let o_clean = clean.render(&s);
        let o_noisy = noisy.render(&s);
        let sem = |o: &[f32; D_VIS]| -> f64 { o[..16].iter().map(|v| (*v as f64).abs()).sum() };
        assert!(sem(&o_noisy) < sem(&o_clean));
    }

    #[test]
    fn occlusion_suppresses_scene_texture_energy() {
        let s = sim();
        let mut clean_r = renderer(NoiseLevel::Standard, 5);
        let clean_tex: f64 =
            clean_r.render(&s)[16..].iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let mut noisy = renderer(NoiseLevel::Distraction, 5);
        let mut found = false;
        for _ in 0..200 {
            let o = noisy.render(&s);
            if noisy.last_clarity < 0.5 {
                let tex: f64 = o[16..].iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
                assert!(tex < 0.5 * clean_tex, "occluded {tex} vs clean {clean_tex}");
                found = true;
                break;
            }
        }
        assert!(found, "no occlusion in 200 frames");
    }

    #[test]
    fn scene_texture_is_persistent_across_frames() {
        let s = sim();
        let mut r = renderer(NoiseLevel::Standard, 6);
        let a = r.render(&s);
        let b = r.render(&s);
        // frame-to-frame texture correlation must be high (same scene)
        let dot: f64 =
            a[16..].iter().zip(b[16..].iter()).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        let na: f64 = a[16..].iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let nb: f64 = b[16..].iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        assert!(dot / (na * nb) > 0.9);
    }

    #[test]
    fn observations_finite() {
        let s = sim();
        let mut r = renderer(NoiseLevel::Distraction, 7);
        for _ in 0..50 {
            assert!(r.render(&s).iter().all(|v| v.is_finite()));
        }
    }
}

//! RAPID CLI — the leader entrypoint.
//!
//! ```text
//! rapid run   [--preset libero|realworld] [--policy rapid|...] [--task pick|drawer|peg]
//!             [--noise standard|noise|distraction] [--episodes N] [--seed S]
//!             [--analytic] [--trace out.csv] [--config file.toml]
//! rapid bench <tab1|tab2|tab3|tab4|tab5|fig2|fig3|fig5|sweep|overhead|reuse|serve|zoo
//!             |workload|pipeline|xpu|scale|obs|all> [--json BENCH_serve.json] [--budget-ms MS]
//!             (scale also takes --sessions N: the Poisson fleet ladder
//!              climbs to N in-process sessions, e.g. --sessions 100000)
//! rapid serve [--addr 127.0.0.1:7070] [--batch 4] [--analytic]
//! rapid fleet [--sessions N] [--policy K] [--task T] [--episodes E] [--batch B]
//!             [--inflight I] [--endpoints P] [--seed S] [--config file.toml]
//!             [--trace-out trace.json] [--metrics-json metrics.json]
//! rapid trace [--sessions N] [--config file.toml] [--out trace.json]
//! rapid zoo   [--sessions N] [--task T] [--seed S] [--config file.toml]
//! rapid workload [--sessions N] [--task T] [--seed S] [--config file.toml]
//!             [--arrivals fixed|poisson|bursty|trace] [--trace T] [--interarrival R]
//! rapid pipeline [--sessions N] [--task T] [--seed S] [--config file.toml]
//! rapid autoscale [--sessions N] [--task T] [--seed S] [--config file.toml]
//! rapid xpu   [--sessions N] [--task T] [--seed S] [--config file.toml]
//! rapid info
//! ```
//!
//! (Argument parsing is hand-rolled: no third-party CLI crates exist in
//! this offline environment.)

use rapid::config::{presets, NoiseLevel, PolicyKind, SystemConfig};
use rapid::experiments::{self, Backends};
use rapid::robot::TaskKind;
use rapid::util::tablefmt::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("fleet") => cmd_fleet(&args[1..]),
        Some("chaos") => cmd_chaos(&args[1..]),
        Some("zoo") => cmd_zoo(&args[1..]),
        Some("workload") => cmd_workload(&args[1..]),
        Some("pipeline") => cmd_pipeline(&args[1..]),
        Some("autoscale") => cmd_autoscale(&args[1..]),
        Some("xpu") => cmd_xpu(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("info") => cmd_info(),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown command: {other}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "RAPID — redundancy-aware edge-cloud partitioned inference for VLA models\n\n\
         USAGE:\n  rapid run   [--preset P] [--policy K] [--task T] [--noise N] [--episodes E]\n\
         \x20             [--seed S] [--analytic] [--trace FILE] [--config FILE]\n\
         \x20 rapid bench <tab1|tab2|tab3|tab4|tab5|fig2|fig3|fig5|sweep|overhead|reuse|serve\n\
         \x20             |zoo|workload|pipeline|autoscale|xpu|scale|obs|all>\n\
         \x20             [--config FILE] [--json FILE] [--budget-ms MS]\n\
         \x20             (serve: benchkit timings of the serve layer, written as\n\
         \x20              machine-readable JSON with --json, e.g. BENCH_serve.json;\n\
         \x20              reuse: cache-off vs cache-on fleet table;\n\
         \x20              scale: the scale-ceiling ladder — --sessions N climbs a\n\
         \x20              Poisson fleet to N in-process sessions, --json writes\n\
         \x20              BENCH_scale.json; not part of `bench all`;\n\
         \x20              obs: span-record/histogram hot paths plus the\n\
         \x20              traced-vs-untraced fleet overhead pair)\n\
         \x20 rapid serve [--addr A] [--batch B] [--analytic]\n\
         \x20 rapid fleet [--sessions N] [--policy K] [--task T] [--episodes E]\n\
         \x20             [--batch B] [--inflight I] [--endpoints P] [--seed S]\n\
         \x20             [--config FILE] [--trace-out FILE] [--metrics-json FILE]\n\
         \x20             (--trace-out/--metrics-json arm [trace] for the run —\n\
         \x20              zero draws, zero clock reads: the run itself is\n\
         \x20              bit-identical to an untraced one)\n\
         \x20 rapid chaos [--sessions N] [--task T] [--seed S] [--batch B]\n\
         \x20             [--episodes E] [--endpoints P] [--config FILE]\n\
         \x20             [--trace-out FILE] [--metrics-json FILE]\n\
         \x20             (defaults to configs/chaos.toml; compares RAPID vs\n\
         \x20              Edge-/Cloud-Only fleets under the fault schedule;\n\
         \x20              the obs flags trace one extra Cloud-Only arm)\n\
         \x20 rapid zoo   [--sessions N] [--task T] [--seed S] [--config FILE]\n\
         \x20             (heterogeneous model-zoo fleet: family catalog,\n\
         \x20              planner choices, per-family RAPID vs baselines)\n\
         \x20 rapid workload [--sessions N] [--task T] [--seed S] [--config FILE]\n\
         \x20             [--arrivals fixed|poisson|bursty|trace] [--trace T]\n\
         \x20             [--interarrival R]\n\
         \x20             (dynamic open-loop arrivals: prints the compiled\n\
         \x20              session plan, then the arrival-shape table)\n\
         \x20 rapid pipeline [--sessions N] [--task T] [--seed S] [--config FILE]\n\
         \x20             (pipelined + speculative execution: prints the active\n\
         \x20              [pipeline] knobs, then the four-arm off/on x spec\n\
         \x20              off/on table for RAPID vs Cloud-Only)\n\
         \x20 rapid autoscale [--sessions N] [--task T] [--seed S] [--config FILE]\n\
         \x20             (deterministic autoscaling control plane: composes the\n\
         \x20              chaos schedule with a Poisson workload and compares\n\
         \x20              static-min/static-max provisioning against the\n\
         \x20              [autoscale] loop, with and without admission shed)\n\
         \x20 rapid xpu   [--sessions N] [--task T] [--seed S] [--config FILE]\n\
         \x20             (device-heterogeneity zoo: class catalog, the\n\
         \x20              class x family partition matrix, then the uniform\n\
         \x20              cloudlet fleet vs a mixed lite/nx/agx fleet for\n\
         \x20              RAPID vs Cloud-Only under the chaos schedule)\n\
         \x20 rapid trace [--sessions N] [--config FILE] [--out trace.json]\n\
         \x20             (deterministic trace demo: two fleets composed to hit\n\
         \x20              every span stage; writes Perfetto-loadable Chrome\n\
         \x20              trace JSON plus a .jsonl sibling, prints per-stage\n\
         \x20              span counts, exits 1 if any stage kind is missing)\n\
         \x20 rapid info\n"
    );
}

/// Tiny flag parser: --key value / --flag.
struct Flags<'a>(&'a [String]);

impl<'a> Flags<'a> {
    fn get(&self, key: &str) -> Option<&str> {
        self.0.iter().position(|a| a == key).and_then(|i| self.0.get(i + 1)).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.0.iter().any(|a| a == key)
    }
}

fn load_sys(flags: &Flags) -> SystemConfig {
    let mut sys = flags
        .get("--preset")
        .and_then(presets::by_name)
        .unwrap_or_else(presets::libero_preset);
    if let Some(path) = flags.get("--config") {
        match std::fs::read_to_string(path) {
            Ok(src) => match rapid::config::parse::parse_toml(&src) {
                Ok(v) => sys.apply_value(&v),
                Err(e) => {
                    eprintln!("config parse error: {e}");
                    std::process::exit(2);
                }
            },
            Err(e) => {
                eprintln!("cannot read {path}: {e}");
                std::process::exit(2);
            }
        }
    }
    if let Some(n) = flags.get("--noise").and_then(NoiseLevel::parse) {
        sys.scene.noise = n;
    }
    if let Some(s) = flags.get("--seed").and_then(|s| s.parse().ok()) {
        sys.episode.seed = s;
    }
    if let Some(e) = flags.get("--episodes").and_then(|s| s.parse().ok()) {
        sys.episode.episodes = e;
    }
    // `from_toml` validates file loads; the overlay path (`apply_value` +
    // CLI flags) must reject bad knob combinations too — an unknown
    // device class must never fall through to a silent default
    if let Err(msg) = sys.validate() {
        eprintln!("config error: {msg}");
        std::process::exit(2);
    }
    sys
}

fn backends(flags: &Flags, seed: u64) -> Backends {
    if flags.has("--analytic") {
        Backends::analytic(seed)
    } else {
        Backends::pjrt_or_analytic(seed)
    }
}

fn cmd_run(rest: &[String]) -> i32 {
    let flags = Flags(rest);
    let sys = load_sys(&flags);
    let kind = flags.get("--policy").and_then(PolicyKind::parse).unwrap_or(PolicyKind::Rapid);
    let task = flags.get("--task").and_then(TaskKind::parse);
    let mut b = backends(&flags, sys.episode.seed);

    match task {
        Some(task) => {
            // single traced episode (with the per-session reuse tier when
            // the active config enables [cache])
            let strategy = rapid::policy::build(kind, &sys);
            let mut store = if sys.cache.enabled {
                Some(rapid::cache::ReuseStore::from_config(&sys.cache, sys.episode.seed))
            } else {
                None
            };
            let out = rapid::serve::run_episode_with_cache(
                &sys,
                task,
                strategy,
                b.edge.as_mut(),
                b.cloud.as_mut(),
                sys.episode.seed,
                true,
                store.as_mut(),
                0,
            );
            let m = &out.metrics;
            let (c, e, t) = m.latency_columns();
            println!(
                "task={} policy={} steps={} events(edge/cloud)={}|{} preempt={} success={}",
                task.name(),
                kind.name(),
                m.steps,
                m.edge_events,
                m.cloud_events,
                m.preemptions,
                m.success
            );
            println!("latency: cloud {c:.1}ms + edge {e:.1}ms (+overhead) = total {t:.1}ms/event");
            println!("loads: edge {:.1}GB cloud {:.1}GB", m.edge_gb, m.cloud_gb);
            if let Some(store) = &store {
                println!("{}", store.stats().report());
            }
            if let Some(path) = flags.get("--trace") {
                if let Some(tr) = out.trace {
                    if let Err(err) = tr.save_csv(path) {
                        eprintln!("trace save failed: {err}");
                        return 1;
                    }
                    println!("trace written to {path}");
                }
            }
        }
        None => {
            let episodes = sys.episode.episodes;
            let res = rapid::serve::session::run_policy(
                &sys,
                kind,
                &rapid::robot::tasks::ALL_TASKS,
                episodes,
                b.edge.as_mut(),
                b.cloud.as_mut(),
            );
            let mut t = Table::new(
                &format!("Suite: {} on preset {}", kind.name(), sys.name),
                &[
            "Method", "Cloud Lat.", "Cloud Load", "Edge Lat.", "Edge Load", "Total Lat.",
            "Total Load",
        ],
            );
            t.row(&res.row.table_cells(None));
            print!("{}", t.render());
            println!(
                "success {:.0}%  rms_err {:.3}  preemptions/ep {:.1}  trig-precision {:.2}",
                100.0 * res.row.success_rate,
                res.row.rms_error,
                res.row.preemptions,
                res.row.trigger_precision
            );
        }
    }
    0
}

fn cmd_bench(rest: &[String]) -> i32 {
    let flags = Flags(&rest[1.min(rest.len())..]);
    let which = rest.first().map(|s| s.as_str()).unwrap_or("all");
    let sys = load_sys(&flags);
    let mut b = backends(&flags, sys.episode.seed);
    let eps = sys.episode.episodes.min(6).max(2);

    let single = which != "all";
    let run_one = |name: &str, b: &mut Backends| match name {
        "tab1" => print!("{}", experiments::tab1::run(&sys, b, eps).0.render()),
        "tab2" => print!("{}", experiments::tab2::run(&sys, b, eps).0.render()),
        "tab3" => {
            let (t, rows) = experiments::tab345::tab3(&sys, b, eps);
            print!("{}", t.render());
            println!("speedup vs vision: {:.2}x", rows.speedup_vs_vision());
        }
        "tab4" => {
            let real = presets::realworld_preset();
            let (t, rows) = experiments::tab345::tab4(&real, b, eps);
            print!("{}", t.render());
            println!("speedup vs vision: {:.2}x", rows.speedup_vs_vision());
        }
        "tab5" => print!("{}", experiments::tab345::tab5(&sys, b, eps).0.render()),
        "fig2" => {
            let data = experiments::fig2::run(&sys, b);
            for (noise, e, c) in &data.entropy_traces {
                println!(
                    "{:<13} false-breach rate {:.1}%",
                    noise.name(),
                    100.0 * experiments::fig2::false_breach_rate(e, c, data.entropy_threshold)
                );
            }
        }
        "fig3" => {
            let data = experiments::fig3::run(&sys, b, eps);
            for (task, _, _, r, rho) in &data.series {
                println!("{:<16} pearson r = {r:.3}   spearman = {rho:.3}", task.name());
            }
            println!(
                "pooled: r = {:.3}, spearman = {:.3}",
                data.pooled_pearson, data.pooled_spearman
            );
        }
        "fig5" => {
            let data = experiments::fig5::run(&sys, b);
            print!("{}", experiments::fig5::render_ascii(&data, 72));
        }
        "sweep" => {
            let (t, _) = experiments::sweep::run(
                &sys,
                b,
                &[0.35, 0.65, 1.0, 1.5],
                &[0.2, 0.35, 0.6],
                (eps / 2).max(1),
            );
            print!("{}", t.render());
        }
        "overhead" => {
            let r = experiments::overhead::run(&sys, 0.06);
            println!(
                "dispatcher tick: {:.0}ns ({:.3}% of the {}Hz sensor budget); state {} bytes",
                r.tick_ns,
                100.0 * r.tick_budget_frac,
                sys.robot.sensor_hz,
                r.state_bytes
            );
        }
        "reuse" => {
            let (t, rows) = experiments::reuse::run(&sys, rapid::robot::TaskKind::PickPlace);
            print!("{}", t.render());
            let hits: u64 = rows.iter().map(|r| r.clean_cache.hits + r.chaos_cache.hits).sum();
            println!("fleet-shared cache hits across all arms: {hits}");
        }
        "serve" => bench_serve(&sys, &flags, single),
        "zoo" => bench_zoo(&sys, &flags, single),
        "workload" => bench_workload(&sys, &flags, single),
        "pipeline" => bench_pipeline(&sys, &flags, single),
        "autoscale" => bench_autoscale(&sys, &flags, single),
        "xpu" => bench_xpu(&sys, &flags, single),
        "scale" => bench_scale(&sys, &flags, single),
        "obs" => bench_obs(&sys, &flags, single),
        other => eprintln!("unknown bench {other}"),
    };

    if which == "all" {
        if flags.get("--json").is_some() {
            // serve and zoo would both write the same path, the second
            // silently clobbering the first — make the limitation explicit
            eprintln!("[bench] --json applies to single-bench runs; ignored for `bench all`");
        }
        // every `--json`-capable bench except `scale` (whose 10k-session
        // default ladder is a deliberate long run; see the help text)
        for name in [
            "tab1", "tab2", "tab3", "tab4", "tab5", "fig2", "fig3", "fig5", "sweep", "overhead",
            "reuse", "serve", "zoo", "workload", "pipeline", "autoscale", "xpu", "obs",
        ] {
            println!("\n### {name}");
            run_one(name, &mut b);
        }
    } else {
        run_one(which, &mut b);
    }
    0
}

/// `rapid bench serve`: benchkit timings of the serve layer (episode
/// driver, fleet scheduler, reuse-store probe), optionally written as
/// machine-readable JSON (`--json BENCH_serve.json`) so the perf
/// trajectory accumulates across commits. `--budget-ms` bounds each
/// case's measurement time (CI smoke uses a tiny budget).
fn bench_serve(sys: &SystemConfig, flags: &Flags, write_json: bool) {
    use rapid::robot::TaskKind;
    use rapid::vla::AnalyticBackend;

    let budget = flags.get("--budget-ms").and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let mut bench = rapid::benchkit::Bench::new().with_budget_ms(budget);
    rapid::benchkit::header("serve layer");

    let seed = sys.episode.seed;
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let name =
            format!("episode/{}", if kind == PolicyKind::Rapid { "rapid" } else { "cloud_only" });
        bench.run(&name, || {
            let strategy = rapid::policy::build(kind, sys);
            let mut edge = AnalyticBackend::edge(seed);
            let mut cloud = AnalyticBackend::cloud(seed);
            let out = rapid::serve::run_episode(
                sys,
                TaskKind::PickPlace,
                strategy,
                &mut edge,
                &mut cloud,
                seed,
                false,
            );
            std::hint::black_box(out.metrics.steps);
        });
    }

    let mut fleet_sys = sys.clone();
    fleet_sys.cache.enabled = false;
    let n = fleet_sys.fleet.n_sessions.max(1);
    bench.run(&format!("fleet/{n}s/rapid"), || {
        let res =
            rapid::serve::Fleet::local(&fleet_sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
        std::hint::black_box(res.total_steps());
    });
    let mut cached_sys = fleet_sys.clone();
    cached_sys.cache.enabled = true;
    bench.run(&format!("fleet/{n}s/cloud_only+cache"), || {
        let res =
            rapid::serve::Fleet::local(&cached_sys, TaskKind::PickPlace, PolicyKind::CloudOnly)
                .run();
        std::hint::black_box(res.cache.hits);
    });

    // reuse-store probe hot path: one warm entry, repeated hits
    {
        let cfg = rapid::config::CacheConfig { enabled: true, ..Default::default() };
        let mut store = rapid::cache::ReuseStore::from_config(&cfg, 1);
        let frame = rapid::robot::SensorFrame {
            step: 0,
            q: rapid::robot::Jv::splat(0.3),
            dq: rapid::robot::Jv::splat(0.1),
            tau: rapid::robot::Jv::ZERO,
        };
        let sig = rapid::cache::Signature::of(&cfg, 1, &frame, None, Default::default());
        let mut cloud = AnalyticBackend::cloud(1);
        let out =
            rapid::vla::Backend::infer(&mut cloud, &[0.1; rapid::D_VIS], &[0.0; rapid::D_PROP], 1);
        store.admit(sig, out, 0, 0);
        bench.run("cache/probe_hit", || {
            std::hint::black_box(matches!(
                store.probe(&sig, 1, 0),
                rapid::cache::ProbeOutcome::Hit(_)
            ));
        });
    }

    if let Some(path) = flags.get("--json").filter(|_| write_json) {
        match bench.save_json(path) {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// `rapid bench zoo`: benchkit timings of the heterogeneous serve path —
/// mixed-family fleets per policy and the planner hot loop — optionally
/// written as machine-readable JSON (`--json BENCH_zoo.json`).
fn bench_zoo(sys: &SystemConfig, flags: &Flags, write_json: bool) {
    use rapid::robot::TaskKind;
    use rapid::vla::{FamilyProfile, ModelFamily};

    let budget = flags.get("--budget-ms").and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let mut bench = rapid::benchkit::Bench::new().with_budget_ms(budget);
    rapid::benchkit::header("model zoo");

    let mut zoo_sys = sys.clone();
    zoo_sys.models.enabled = true;
    let n = zoo_sys.fleet.n_sessions.max(1);
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let name = format!(
            "zoo_fleet/{n}s/{}",
            if kind == PolicyKind::Rapid { "rapid" } else { "cloud_only" }
        );
        let s = zoo_sys.clone();
        bench.run(&name, || {
            let res = rapid::serve::Fleet::local(&s, TaskKind::PickPlace, kind).run();
            std::hint::black_box(res.stats.mixed_family_batches);
        });
    }
    // planner hot loop: one plan per family per call
    bench.run("planner/plan_all_families", || {
        for fam in ModelFamily::ALL {
            let p = rapid::policy::planner::plan(&FamilyProfile::of(fam), 1000.0, 8.0);
            std::hint::black_box(p.partition_idx);
        }
    });

    if let Some(path) = flags.get("--json").filter(|_| write_json) {
        match bench.save_json(path) {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// `rapid bench workload`: benchkit timings of the event-driven serve
/// path — the event-queue hot loop, workload-plan compilation, and full
/// dynamic-arrival fleets — optionally written as machine-readable JSON
/// (`--json BENCH_workload.json`).
fn bench_workload(sys: &SystemConfig, flags: &Flags, write_json: bool) {
    use rapid::robot::TaskKind;
    use rapid::serve::{EventKind, EventQueue};

    let budget = flags.get("--budget-ms").and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let mut bench = rapid::benchkit::Bench::new().with_budget_ms(budget);
    rapid::benchkit::header("workload engine");

    // event-queue hot loop: a round's worth of pushes and pops
    bench.run("events/push_pop_1k", || {
        let mut q = EventQueue::new();
        for t in 0..250u64 {
            q.push(t, EventKind::FaultEdge);
            q.push(t, EventKind::Ready((t % 16) as usize));
            q.push(t, EventKind::Ready((t % 7) as usize));
            q.push(t, EventKind::Deadline);
        }
        let mut n = 0u64;
        while let Some(ev) = q.pop() {
            n += ev.time;
        }
        std::hint::black_box(n);
    });

    // workload-plan compilation (poisson draws + family/episode draws)
    let mut plan_sys = sys.clone();
    plan_sys.workload.enabled = true;
    plan_sys.workload.arrivals = "poisson".into();
    plan_sys.workload.interarrival_rounds = 3.0;
    plan_sys.workload.n_sessions = 64;
    plan_sys.workload.episodes_min = 1;
    plan_sys.workload.episodes_max = 3;
    bench.run("workload/plan_poisson_64s", || {
        std::hint::black_box(rapid::serve::workload::plan(&plan_sys).n_sessions());
    });

    // full dynamic fleets per arrival shape
    for shape in ["poisson", "bursty"] {
        let mut s = sys.clone();
        s.cache.enabled = false;
        s.workload.enabled = true;
        s.workload.arrivals = shape.into();
        s.workload.interarrival_rounds = 5.0;
        let n = s.fleet.n_sessions.max(1);
        bench.run(&format!("workload_fleet/{n}s/{shape}/cloud_only"), || {
            let res = rapid::serve::Fleet::local(&s, TaskKind::PickPlace, PolicyKind::CloudOnly)
                .run();
            std::hint::black_box(res.total_steps());
        });
    }

    if let Some(path) = flags.get("--json").filter(|_| write_json) {
        match bench.save_json(path) {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// `rapid bench pipeline`: benchkit timings of the pipelined execution
/// path — the sequential scheduler vs the overlap+speculation fleet for
/// RAPID and Cloud-Only — optionally written as machine-readable JSON
/// (`--json BENCH_pipeline.json`). The `seq` cases double as a perf
/// guard: the disabled-pipeline fleet must not regress under the new
/// branches.
fn bench_pipeline(sys: &SystemConfig, flags: &Flags, write_json: bool) {
    use rapid::robot::TaskKind;

    let budget = flags.get("--budget-ms").and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let mut bench = rapid::benchkit::Bench::new().with_budget_ms(budget);
    rapid::benchkit::header("pipelined execution");

    let arms = rapid::experiments::pipeline::arms(sys);
    let n = sys.fleet.n_sessions.max(1);
    for (arm_idx, label) in [(0usize, "seq"), (3usize, "both")] {
        for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
            let name = format!(
                "pipeline_fleet/{n}s/{label}/{}",
                if kind == PolicyKind::Rapid { "rapid" } else { "cloud_only" }
            );
            let s = arms[arm_idx].clone();
            bench.run(&name, || {
                let res = rapid::serve::Fleet::local(&s, TaskKind::PickPlace, kind).run();
                std::hint::black_box(res.stats.spec_requests);
            });
        }
    }

    if let Some(path) = flags.get("--json").filter(|_| write_json) {
        match bench.save_json(path) {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// Arm the composed autoscale scenario on top of the active config:
/// deadline batching (a held partial batch is what the round-start
/// scaler tick reads as backlog), a Poisson open-loop workload, and —
/// when the config ships `[autoscale]` disabled — a demo control loop
/// (floor 1, ceiling 3, tight debounce) so the command always scales.
fn compose_autoscale(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    if s.fleet.batch_deadline_us == 0 {
        s.fleet.batch_deadline_us = 50_000;
    }
    s.fleet.max_batch = s.fleet.max_batch.max(s.fleet.n_sessions.max(1));
    s.fleet.max_inflight = s.fleet.max_inflight.max(2 * s.fleet.n_sessions.max(1));
    if !s.workload.enabled {
        s.workload.enabled = true;
        s.workload.arrivals = "poisson".into();
        s.workload.interarrival_rounds = 3.0;
    }
    if !s.autoscale.enabled {
        s.autoscale.enabled = true;
        s.autoscale.min_endpoints = 1;
        s.autoscale.max_endpoints = 3;
        s.autoscale.slo_queue = 2;
        s.autoscale.sustain_rounds = 1;
        s.autoscale.idle_rounds = 1;
        s.autoscale.cooldown_rounds = 0;
    }
    s
}

/// `rapid bench autoscale`: benchkit timings of the control-plane path —
/// the static-min scheduler vs the autoscaling fleet for RAPID and
/// Cloud-Only under the composed Poisson workload, plus the multi-factor
/// planner hot loop — optionally written as machine-readable JSON
/// (`--json BENCH_autoscale.json`). The `static` cases double as a perf
/// guard: the disabled-autoscale fleet must not regress under the new
/// branches.
fn bench_autoscale(sys: &SystemConfig, flags: &Flags, write_json: bool) {
    use rapid::policy::planner;
    use rapid::robot::TaskKind;
    use rapid::vla::{FamilyProfile, ModelFamily};

    let budget = flags.get("--budget-ms").and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let mut bench = rapid::benchkit::Bench::new().with_budget_ms(budget);
    rapid::benchkit::header("autoscaling control plane");

    let arms = rapid::experiments::autoscale::arms(&compose_autoscale(sys));
    let n = sys.fleet.n_sessions.max(1);
    for (arm_idx, label) in [(0usize, "static_min"), (2usize, "autoscale")] {
        for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
            let name = format!(
                "autoscale_fleet/{n}s/{label}/{}",
                if kind == PolicyKind::Rapid { "rapid" } else { "cloud_only" }
            );
            let s = arms[arm_idx].clone();
            bench.run(&name, || {
                let res = rapid::serve::Fleet::local(&s, TaskKind::PickPlace, kind).run();
                std::hint::black_box(res.stats.scale_up_events);
            });
        }
    }

    // multi-factor planner hot loop: one budget-filtered, endpoint-aware
    // plan per family per call (the replan path a loaded round pays)
    let budget_nx = planner::DeviceBudget::of("nx").expect("nx is a catalog class");
    bench.run("planner/plan_with_all_families", || {
        for (i, fam) in ModelFamily::ALL.into_iter().enumerate() {
            let load = planner::EndpointLoad {
                queue_depth: i as u64 * 3,
                capacity: 1.0,
                queue_weight: 0.2,
            };
            let p = planner::plan_with(&FamilyProfile::of(fam), 200.0, 20.0, budget_nx, load);
            std::hint::black_box(p.partition_idx);
        }
    });

    if let Some(path) = flags.get("--json").filter(|_| write_json) {
        match bench.save_json(path) {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// `rapid bench xpu`: benchkit timings of the device-zoo path — the
/// uniform (class-free) fleet vs the mixed lite/nx/agx fleet for RAPID
/// and Cloud-Only, plus the full (class × family) planner matrix —
/// optionally written as machine-readable JSON (`--json BENCH_xpu.json`).
/// The `uniform` cases double as a perf guard: the disabled-zoo fleet
/// must not regress under the new class branches.
fn bench_xpu(sys: &SystemConfig, flags: &Flags, write_json: bool) {
    use rapid::policy::planner;
    use rapid::robot::TaskKind;
    use rapid::runtime::DeviceClass;
    use rapid::vla::{FamilyProfile, ModelFamily};

    let budget = flags.get("--budget-ms").and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let mut bench = rapid::benchkit::Bench::new().with_budget_ms(budget);
    rapid::benchkit::header("device-heterogeneity zoo");

    let mut zoo_sys = sys.clone();
    zoo_sys.models.enabled = true;
    let arms = rapid::experiments::xpu::arms(&zoo_sys);
    let n = sys.fleet.n_sessions.max(1);
    for (arm_idx, label) in [(0usize, "uniform"), (1usize, "mixed")] {
        for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
            let name = format!(
                "xpu_fleet/{n}s/{label}/{}",
                if kind == PolicyKind::Rapid { "rapid" } else { "cloud_only" }
            );
            let s = arms[arm_idx].clone();
            bench.run(&name, || {
                let res = rapid::serve::Fleet::local(&s, TaskKind::PickPlace, kind).run();
                std::hint::black_box(res.total_steps());
            });
        }
    }

    // per-class planner hot loop: one budget-filtered, class-scaled plan
    // per (class, family) cell — the full matrix replan a mixed fleet
    // pays at every link edge
    bench.run("planner/plan_for_class_matrix", || {
        for class in DeviceClass::ALL {
            let budget = planner::DeviceBudget::for_class(class);
            for fam in ModelFamily::ALL {
                let prof = FamilyProfile::of(fam);
                let load = planner::EndpointLoad::NOMINAL;
                let p = planner::plan_for_class(&prof, class, 200.0, 20.0, budget, load);
                std::hint::black_box(p.partition_idx);
            }
        }
    });

    if let Some(path) = flags.get("--json").filter(|_| write_json) {
        match bench.save_json(path) {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// The `bench scale` Poisson ladder: rungs at 1%, 10%, and 100% of
/// `--sessions`, each clamped to >= 1 session (1% of anything below 100
/// truncates to zero otherwise and the fleet constructor has nothing to
/// run), with adjacent duplicate rungs collapsed so tiny ladders don't
/// re-time the same fleet.
fn scale_rungs(sessions: usize) -> Vec<usize> {
    let mut rungs: Vec<usize> =
        [sessions / 100, sessions / 10, sessions].into_iter().map(|n| n.max(1)).collect();
    rungs.dedup();
    rungs
}

/// `rapid bench scale`: the in-process scale ceiling. Micro benches of
/// the three layers the ceiling rests on — the virtual-time event queue,
/// the sharded reuse store under eviction pressure, and the reusable
/// frame-encode buffer — then a Poisson open-loop fleet ladder that
/// climbs to `--sessions N` (default 10 000; the tentpole target is
/// 100 000). Fleet rungs run one timed iteration each (no warm-up): the
/// measurement *is* the run. `--json BENCH_scale.json` writes the
/// machine-readable results; CI smokes a 2 000-session rung.
fn bench_scale(sys: &SystemConfig, flags: &Flags, write_json: bool) {
    use rapid::robot::TaskKind;
    use rapid::serve::{EventKind, EventQueue};
    use rapid::vla::AnalyticBackend;

    let sessions: usize =
        flags.get("--sessions").and_then(|s| s.parse().ok()).unwrap_or(10_000).max(1);
    let budget = flags.get("--budget-ms").and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let mut bench = rapid::benchkit::Bench::new().with_budget_ms(budget);
    rapid::benchkit::header("scale ceiling");

    // micro: event-queue throughput at fleet-arrival scale — 100k mixed
    // events through the pre-reserved heap
    bench.run("scale/events/push_pop_100k", || {
        let mut q = EventQueue::with_capacity(100_000);
        for t in 0..25_000u64 {
            q.push(t, EventKind::Arrival((t % 4096) as usize));
            q.push(t, EventKind::Ready((t % 4096) as usize));
            q.push(t, EventKind::Ready(((t * 7) % 4096) as usize));
            q.push(t, EventKind::Deadline);
        }
        let mut acc = 0u64;
        while let Some(ev) = q.pop() {
            acc += ev.time;
        }
        std::hint::black_box(acc);
    });

    // micro: sharded reuse store under sustained eviction pressure —
    // admissions and probes spread over 16 shards, far past capacity
    {
        let cfg = rapid::config::CacheConfig {
            enabled: true,
            capacity: 1024,
            shards: 16,
            ..Default::default()
        };
        let mut cloud = AnalyticBackend::cloud(7);
        let out = rapid::vla::Backend::infer(
            &mut cloud,
            &[0.1; rapid::D_VIS],
            &[0.0; rapid::D_PROP],
            1,
        );
        let sigs: Vec<rapid::cache::Signature> = (0..4096u64)
            .map(|i| {
                let frame = rapid::robot::SensorFrame {
                    step: 0,
                    q: rapid::robot::Jv::splat(0.05 * (i % 61) as f32),
                    dq: rapid::robot::Jv::splat(0.01 * (i % 13) as f32),
                    tau: rapid::robot::Jv::ZERO,
                };
                rapid::cache::Signature::of(
                    &cfg,
                    (i % 8) as usize,
                    &frame,
                    None,
                    Default::default(),
                )
            })
            .collect();
        let mut store = rapid::cache::ReuseStore::from_config(&cfg, 7);
        bench.run("scale/cache/sharded_admit_probe_4k", || {
            for (i, sig) in sigs.iter().enumerate() {
                store.admit(*sig, out.clone(), i as u64, 0);
                std::hint::black_box(matches!(
                    store.probe(sig, i as u64, 0),
                    rapid::cache::ProbeOutcome::Hit(_)
                ));
            }
        });
    }

    // micro: batch-frame encode through the reusable buffer — the
    // steady-state client dispatch path allocates nothing per flush
    {
        use rapid::net::proto::{self, InferRequest};
        let items: Vec<(u32, InferRequest)> = (0..64u32)
            .map(|i| {
                let mut obs = [0f32; rapid::D_VIS];
                obs[0] = 0.01 * i as f32;
                (i, InferRequest { instr: i, obs, proprio: [0.0; rapid::D_PROP] })
            })
            .collect();
        let mut buf: Vec<u8> = Vec::new();
        bench.run("scale/proto/encode_batch_64_into", || {
            proto::encode_batch_infer_into(&mut buf, &items);
            std::hint::black_box(buf.len());
        });
    }

    // fleet ladder: Poisson arrivals at 1%, 10%, 100% of --sessions,
    // one episode per session, fleet-shared sharded cache on. One timed
    // iteration per rung: a 100k-session run is its own measurement.
    let mut bench = bench.with_min_iters(1).with_warmup_iters(0);
    for n in scale_rungs(sessions) {
        let mut s = sys.clone();
        s.workload.enabled = true;
        s.workload.arrivals = "poisson".into();
        s.workload.interarrival_rounds = 2.0;
        s.workload.n_sessions = n;
        s.workload.episodes_min = 1;
        s.workload.episodes_max = 1;
        s.fleet.n_sessions = n;
        s.fleet.episodes_per_session = 1;
        s.cache.enabled = true;
        s.cache.shared = true;
        s.cache.capacity = 4096;
        s.cache.shards = 16;
        let t0 = std::time::Instant::now();
        let mut steps = 0u64;
        bench.run(&format!("scale/fleet/{n}s/poisson/cloud_only"), || {
            let res =
                rapid::serve::Fleet::local(&s, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
            steps += res.total_steps();
            std::hint::black_box(res.stats.rounds);
        });
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  rung {n}s: {steps} steps in {wall:.2}s ({:.0} steps/s)",
            steps as f64 / wall.max(1e-9)
        );
    }

    if let Some(path) = flags.get("--json").filter(|_| write_json) {
        match bench.save_json(path) {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// `rapid bench obs`: observability-layer timings — the span-record hot
/// path, histogram insert and shard merge, and a traced-vs-untraced fleet
/// pair whose delta is the end-to-end cost of an enabled `[trace]`
/// section — optionally written as machine-readable JSON
/// (`--json BENCH_obs.json`).
fn bench_obs(sys: &SystemConfig, flags: &Flags, write_json: bool) {
    use rapid::obs::{LogHistogram, Stage, Tracer};
    use rapid::robot::TaskKind;

    let budget = flags.get("--budget-ms").and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let mut bench = rapid::benchkit::Bench::new().with_budget_ms(budget);
    rapid::benchkit::header("observability");

    // span-record hot path: 4k stores into a preallocated tracer
    bench.run("obs/span_record_4k", || {
        let mut tr = Tracer::new(1 << 16, 50_000.0);
        for i in 0..4096u64 {
            let ts = tr.base_us(i / 8);
            tr.record(Stage::CloudQueue, ts, 125, (i % 64) as u32, (i % 4) as u8, 0, 0);
        }
        std::hint::black_box(tr.len());
    });

    // histogram hot paths: 4k inserts, then a 64-shard fold
    bench.run("obs/hist_insert_4k", || {
        let mut h = LogHistogram::new();
        for i in 0..4096u64 {
            h.insert((i.wrapping_mul(2_654_435_761) % 1_000_000) as f64);
        }
        std::hint::black_box(h.p99());
    });
    let shards: Vec<LogHistogram> = (0..64u64)
        .map(|s| {
            let mut h = LogHistogram::new();
            for i in 0..64u64 {
                h.insert(((s * 64 + i) * 37 % 500_000) as f64);
            }
            h
        })
        .collect();
    bench.run("obs/hist_merge_64_shards", || {
        let mut total = LogHistogram::new();
        for h in &shards {
            total.merge(h);
        }
        std::hint::black_box(total.count());
    });

    // traced vs untraced fleet: same seed and shape, [trace] the only
    // delta — this pair is the overhead headline the README quotes
    let mut off = sys.clone();
    off.cache.enabled = false;
    off.trace.enabled = false;
    let mut on = off.clone();
    on.trace.enabled = true;
    let n = off.fleet.n_sessions.max(1);
    bench.run(&format!("obs/fleet/{n}s/untraced"), || {
        let res =
            rapid::serve::Fleet::local(&off, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        std::hint::black_box(res.total_steps());
    });
    bench.run(&format!("obs/fleet/{n}s/traced"), || {
        let res =
            rapid::serve::Fleet::local(&on, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        std::hint::black_box(res.trace.as_ref().map_or(0, |t| t.len()));
    });

    if let Some(path) = flags.get("--json").filter(|_| write_json) {
        match bench.save_json(path) {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

fn cmd_serve(rest: &[String]) -> i32 {
    let flags = Flags(rest);
    let addr = flags.get("--addr").unwrap_or("127.0.0.1:7070").to_string();
    let batch = flags.get("--batch").and_then(|s| s.parse().ok()).unwrap_or(4);
    let analytic = flags.has("--analytic");
    let server = rapid::net::CloudServer::start(&addr, batch, move || {
        if analytic {
            Box::new(rapid::vla::AnalyticBackend::cloud(1)) as Box<dyn rapid::vla::Backend>
        } else {
            match Backends::try_pjrt() {
                Ok(b) => b.cloud,
                Err(e) => {
                    eprintln!("[serve] PJRT unavailable ({e}); serving analytic model");
                    Box::new(rapid::vla::AnalyticBackend::cloud(1))
                }
            }
        }
    });
    match server {
        Ok(s) => {
            println!("cloud VLA server listening on {} (batch<= {batch}); Ctrl-C to stop", s.addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

/// Write one observability artifact, reporting success/failure.
fn write_artifact(path: &str, contents: &str, what: &str) -> bool {
    match std::fs::write(path, contents) {
        Ok(()) => {
            println!("{what} written to {path}");
            true
        }
        Err(e) => {
            eprintln!("cannot write {path}: {e}");
            false
        }
    }
}

/// `trace.json` -> `trace.jsonl`; anything else gets `.jsonl` appended.
fn jsonl_sibling(path: &str) -> String {
    match path.strip_suffix(".json") {
        Some(base) => format!("{base}.jsonl"),
        None => format!("{path}.jsonl"),
    }
}

/// Shared `--trace-out` / `--metrics-json` handling for the fleet-running
/// commands: write the Chrome trace (plus its JSONL sibling) and the
/// registry dump a traced fleet produced. Returns false on a failed
/// write.
fn write_obs_artifacts(flags: &Flags, res: &rapid::serve::FleetResult) -> bool {
    let mut ok = true;
    if let Some(path) = flags.get("--trace-out") {
        match res.trace.as_ref() {
            Some(tr) => {
                ok &= write_artifact(path, &tr.to_chrome_json(), "chrome trace");
                ok &= write_artifact(&jsonl_sibling(path), &tr.to_jsonl(), "span JSONL");
            }
            None => {
                eprintln!("--trace-out given but the fleet ran without [trace]");
                ok = false;
            }
        }
    }
    if let Some(path) = flags.get("--metrics-json") {
        ok &= write_artifact(path, &res.registry().to_json(), "metrics JSON");
    }
    ok
}

/// Re-run one wedged fleet arm with the flight recorder armed and dump
/// the postmortem to stderr. Arming `[trace]` draws nothing from any PRNG
/// and never touches the clock, so the re-run reproduces the wedge
/// exactly; the reporting run itself stays untraced.
fn dump_flight(sys: &SystemConfig, task: TaskKind, kind: PolicyKind) {
    let mut traced = sys.clone();
    traced.trace.enabled = true;
    let res = rapid::serve::Fleet::local(&traced, task, kind).run();
    match res.flight {
        Some(f) => eprint!("{}", f.report()),
        None => eprintln!("flight recorder: unavailable (fleet built without [trace])"),
    }
}

fn cmd_fleet(rest: &[String]) -> i32 {
    let flags = Flags(rest);
    let mut sys = load_sys(&flags);
    if let Some(n) = flags.get("--sessions").and_then(|s| s.parse::<usize>().ok()) {
        sys.fleet.n_sessions = n.max(1);
    }
    if let Some(b) = flags.get("--batch").and_then(|s| s.parse().ok()) {
        sys.fleet.max_batch = b;
    }
    if let Some(i) = flags.get("--inflight").and_then(|s| s.parse().ok()) {
        sys.fleet.max_inflight = i;
    }
    if let Some(e) = flags.get("--episodes").and_then(|s| s.parse().ok()) {
        sys.fleet.episodes_per_session = e;
    }
    if let Some(p) = flags.get("--endpoints").and_then(|s| s.parse::<usize>().ok()) {
        sys.fleet.endpoints = p.max(1);
    }
    if flags.get("--trace-out").is_some() || flags.get("--metrics-json").is_some() {
        // arming [trace] draws nothing and never touches the clock: this
        // run is bit-identical to the same command without the flags
        sys.trace.enabled = true;
    }
    let kind = flags.get("--policy").and_then(PolicyKind::parse).unwrap_or(PolicyKind::Rapid);
    let task = flags
        .get("--task")
        .and_then(TaskKind::parse)
        .unwrap_or(rapid::robot::TaskKind::PickPlace);

    let t0 = std::time::Instant::now();
    let res = rapid::serve::Fleet::local(&sys, task, kind).run();
    let wall = t0.elapsed().as_secs_f64();
    let summary = res.summary();

    let mut t = Table::new(
        &format!(
            "Fleet: {} × {} session(s) of {} ({} episode(s) each)",
            kind.name(),
            summary.sessions,
            task.name(),
            sys.fleet.episodes_per_session.max(1)
        ),
        &[
            "Session", "Cloud Lat.", "Cloud Load", "Edge Lat.", "Edge Load", "Total Lat.",
            "Total Load",
        ],
    );
    for (i, row) in summary.per_session.iter().enumerate() {
        t.row(&row.table_cells(Some(&format!("session {i}"))));
    }
    t.row(&summary.fleet.table_cells(Some("fleet aggregate")));
    print!("{}", t.render());

    // one registry-driven rollup replaces the old ad-hoc counter lines
    // (batching stats, flush causes, fault counters, the cache report
    // line, per-family rollups) — zero-valued counters are elided, so a
    // plain fleet prints roughly what it used to
    let mut reg = res.registry();
    for (i, n) in res.endpoint_dispatches.iter().enumerate() {
        reg.set(&format!("endpoint/{i}/dispatches"), *n);
    }
    print!("{}", reg.render("fleet counters"));
    if sys.workload.enabled {
        println!(
            "workload: {} arrivals, last join @ round {}",
            sys.workload.arrivals,
            res.sessions.iter().map(|x| x.arrival_round).max().unwrap_or(0)
        );
    }
    // wall time is nondeterministic, so it stays out of the registry
    println!(
        "steps {}  cloud events {}  wall {:.2}s ({:.0} steps/s)",
        summary.total_steps,
        summary.total_cloud_events,
        wall,
        summary.total_steps as f64 / wall.max(1e-9)
    );

    if !write_obs_artifacts(&flags, &res) {
        return 1;
    }

    let expect = task.seq_len();
    let wedged: Vec<usize> = res
        .sessions
        .iter()
        .enumerate()
        .filter(|(_, s)| s.episodes.iter().any(|m| m.steps != expect))
        .map(|(i, _)| i)
        .collect();
    if !wedged.is_empty() {
        eprintln!("WEDGED session(s): {wedged:?}");
        match res.flight {
            Some(f) => eprint!("{}", f.report()),
            None => dump_flight(&sys, task, kind),
        }
        return 1;
    }
    0
}

fn cmd_chaos(rest: &[String]) -> i32 {
    let flags = Flags(rest);
    let mut sys = load_sys(&flags);
    // no explicit config: fall back to the shipped chaos schedule, then to
    // the built-in demo schedule, so the command always injects faults —
    // and always say which schedule actually ran
    let explicit_config = flags.get("--config").is_some();
    if !explicit_config {
        if let Ok(src) = std::fs::read_to_string("configs/chaos.toml") {
            match rapid::config::parse::parse_toml(&src) {
                Ok(v) => {
                    sys.apply_value(&v);
                    println!("schedule: configs/chaos.toml");
                }
                Err(e) => {
                    eprintln!("configs/chaos.toml parse error: {e}");
                    return 2;
                }
            }
        }
    }
    if !sys.faults.enabled {
        sys.faults = rapid::config::FaultsConfig::demo();
        if !explicit_config {
            // no config at all: pair the demo schedule with the fleet
            // shape chaos.toml ships; an explicit config keeps its own
            sys.fleet.n_sessions = 6;
            sys.fleet.endpoints = 3;
        }
        println!("schedule: built-in demo (active config enables no faults)");
    } else if explicit_config {
        println!("schedule: --config");
    }
    if let Some(n) = flags.get("--sessions").and_then(|s| s.parse::<usize>().ok()) {
        sys.fleet.n_sessions = n.max(1);
    }
    if let Some(b) = flags.get("--batch").and_then(|s| s.parse().ok()) {
        sys.fleet.max_batch = b;
    }
    if let Some(e) = flags.get("--episodes").and_then(|s| s.parse().ok()) {
        sys.fleet.episodes_per_session = e;
    }
    if let Some(p) = flags.get("--endpoints").and_then(|s| s.parse::<usize>().ok()) {
        sys.fleet.endpoints = p.max(1);
    }
    let task = flags
        .get("--task")
        .and_then(TaskKind::parse)
        .unwrap_or(rapid::robot::TaskKind::PickPlace);

    let f = &sys.faults;
    println!(
        "fault schedule: timeout {:.0}ms, retries {}, endpoints {}",
        f.offload_timeout_ms,
        f.max_retries,
        sys.fleet.endpoints.max(1)
    );
    if f.crash_end > f.crash_start {
        println!(
            "  crash    endpoint {} rounds [{}, {})",
            f.crash_endpoint, f.crash_start, f.crash_end
        );
    }
    if f.degrade_end > f.degrade_start {
        println!(
            "  degrade  rounds [{}, {}) -> {:.0} Mbps / {:.0}ms RTT",
            f.degrade_start, f.degrade_end, f.degrade_bw_mbps, f.degrade_rtt_ms
        );
    }
    if f.outage_end > f.outage_start {
        println!("  outage   rounds [{}, {})", f.outage_start, f.outage_end);
    }
    if f.drop_end > f.drop_start && f.drop_prob > 0.0 {
        println!("  drops    rounds [{}, {}) p={:.2}", f.drop_start, f.drop_end, f.drop_prob);
    }
    if f.delay_end > f.delay_start && f.delay_ms > 0.0 {
        println!("  delay    rounds [{}, {}) +{:.0}ms", f.delay_start, f.delay_end, f.delay_ms);
    }

    let t0 = std::time::Instant::now();
    let (table, rows) = rapid::experiments::degraded::run(&sys, task);
    print!("{}", table.render());

    if flags.get("--trace-out").is_some() || flags.get("--metrics-json").is_some() {
        // trace the arm most exposed to the schedule: the Cloud-Only
        // fleet under the configured faults
        let mut traced = sys.clone();
        traced.trace.enabled = true;
        let obs = rapid::serve::Fleet::local(&traced, task, PolicyKind::CloudOnly).run();
        if !write_obs_artifacts(&flags, &obs) {
            return 1;
        }
    }

    let wedged: Vec<&str> =
        rows.iter().filter(|r| !r.completed).map(|r| r.policy.name()).collect();
    if wedged.is_empty() {
        println!(
            "all policies completed every episode (zero wedged sessions); wall {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        0
    } else {
        eprintln!("WEDGED sessions under: {wedged:?}");
        if let Some(r) = rows.iter().find(|r| !r.completed) {
            dump_flight(&sys, task, r.policy);
        }
        1
    }
}

/// `rapid zoo`: the heterogeneous model-zoo demo — family catalog with
/// the planner's partition choice under the active link, then the
/// per-family RAPID vs Edge-/Cloud-Only mixed-fleet table.
fn cmd_zoo(rest: &[String]) -> i32 {
    use rapid::vla::FamilyProfile;

    let flags = Flags(rest);
    let mut sys = load_sys(&flags);
    sys.models.enabled = true;
    if let Some(n) = flags.get("--sessions").and_then(|s| s.parse::<usize>().ok()) {
        sys.fleet.n_sessions = n.max(1);
    }
    let task = flags
        .get("--task")
        .and_then(TaskKind::parse)
        .unwrap_or(rapid::robot::TaskKind::PickPlace);

    println!(
        "model zoo: families {:?} over {} session(s), link {:.0} Mbps / {:.0} ms RTT",
        sys.models.family_list().iter().map(|f| f.name()).collect::<Vec<_>>(),
        sys.fleet.n_sessions.max(1),
        sys.link.bw_mbps,
        sys.link.rtt_ms
    );
    for fam in sys.models.family_list() {
        let prof = FamilyProfile::of(fam);
        let plan = rapid::policy::planner::plan(&prof, sys.link.bw_mbps, sys.link.rtt_ms);
        println!(
            "  {:<14} chunk {}  edge x{:.2}  partitions {}  -> split #{}: edge {:.1} GB, \
             payload {:.0} KB, cloud {:.0} ms",
            fam.name(),
            prof.chunk_len,
            prof.edge_ms_scale,
            prof.partitions.len(),
            plan.partition_idx,
            plan.edge_gb,
            plan.payload_bytes / 1e3,
            plan.cloud_compute_ms
        );
    }

    let t0 = std::time::Instant::now();
    let (table, rows, arms) = rapid::experiments::hetero::run(&sys, task);
    print!("{}", table.render());
    let mixed: u64 = arms.iter().map(|a| a.mixed_family_batches).sum();
    let wedged: Vec<String> = rows
        .iter()
        .filter(|r| !r.completed)
        .map(|r| format!("{}/{}", r.policy.name(), r.family.name()))
        .collect();
    if mixed == 0 && wedged.is_empty() {
        println!(
            "zero mixed-family batches across {} arms; all sessions completed; wall {:.2}s",
            arms.len(),
            t0.elapsed().as_secs_f64()
        );
        0
    } else {
        eprintln!("mixed-family batches: {mixed}; wedged: {wedged:?}");
        if let Some(r) = rows.iter().find(|r| !r.completed) {
            dump_flight(&sys, task, r.policy);
        }
        1
    }
}

/// `rapid workload`: the dynamic-arrivals demo — compile the active
/// `[workload]` plan and print it (who joins when, with how many episodes
/// and which family), then run the arrival-shape comparison table.
fn cmd_workload(rest: &[String]) -> i32 {
    let flags = Flags(rest);
    let mut sys = load_sys(&flags);
    if let Some(n) = flags.get("--sessions").and_then(|s| s.parse::<usize>().ok()) {
        // pin both knobs: workload.n_sessions overrides even a trace's
        // implied fleet size, so --sessions always means what it says
        sys.fleet.n_sessions = n.max(1);
        sys.workload.n_sessions = n.max(1);
    }
    if let Some(a) = flags.get("--arrivals") {
        sys.workload.enabled = true;
        sys.workload.arrivals = a.to_string();
    }
    if let Some(t) = flags.get("--trace") {
        sys.workload.enabled = true;
        sys.workload.arrivals = "trace".into();
        sys.workload.trace = t.to_string();
    }
    if let Some(r) = flags.get("--interarrival").and_then(|s| s.parse::<f64>().ok()) {
        sys.workload.enabled = true;
        sys.workload.interarrival_rounds = r;
    }
    let task = flags
        .get("--task")
        .and_then(TaskKind::parse)
        .unwrap_or(rapid::robot::TaskKind::PickPlace);

    let plan = rapid::serve::workload::plan(&sys);
    println!(
        "workload: {} ({} arrivals over {} session(s), last join @ round {})",
        if sys.workload.enabled { "enabled" } else { "disabled -> lockstep plan" },
        plan.kind.name(),
        plan.n_sessions(),
        plan.last_arrival()
    );
    for (i, spec) in plan.specs.iter().enumerate() {
        println!(
            "  session {i:<3} joins @ round {:<6} episodes {}  family {}",
            spec.arrival_round,
            spec.episodes,
            spec.family.name()
        );
    }

    let t0 = std::time::Instant::now();
    let (table, rows) = rapid::experiments::arrivals::run(&sys, task);
    print!("{}", table.render());
    let wedged: Vec<String> = rows
        .iter()
        .filter(|r| !r.completed)
        .map(|r| format!("{}/{}", r.shape, r.policy.name()))
        .collect();
    if wedged.is_empty() {
        println!(
            "all arrival shapes completed every session; wall {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        0
    } else {
        eprintln!("WEDGED sessions under: {wedged:?}");
        if let Some(r) = rows.iter().find(|r| !r.completed) {
            dump_flight(&rapid::experiments::arrivals::shaped(&sys, r.shape), task, r.policy);
        }
        1
    }
}

/// `rapid pipeline`: the pipelined + speculative execution demo — print
/// the active `[pipeline]` knobs, then the four-arm table (pipeline
/// off/on x speculation off/on) for RAPID vs Cloud-Only. Exits non-zero
/// if any arm wedges or leaves a speculation unresolved.
fn cmd_pipeline(rest: &[String]) -> i32 {
    let flags = Flags(rest);
    let mut sys = load_sys(&flags);
    if let Some(n) = flags.get("--sessions").and_then(|s| s.parse::<usize>().ok()) {
        sys.fleet.n_sessions = n.max(1);
    }
    let task = flags
        .get("--task")
        .and_then(TaskKind::parse)
        .unwrap_or(rapid::robot::TaskKind::PickPlace);

    let p = &sys.pipeline;
    println!(
        "pipeline: {} (overlap {}, speculate {}) — spec_decode {} ms, rollback {} ms, \
         accept_eps {}, max_zscore {}",
        if p.enabled { "enabled" } else { "disabled (table arms enable it)" },
        p.overlap,
        p.speculate,
        p.spec_decode_ms,
        p.rollback_ms,
        p.accept_eps,
        p.max_zscore
    );

    let t0 = std::time::Instant::now();
    let (table, rows) = rapid::experiments::pipeline::run(&sys, task);
    print!("{}", table.render());
    let mut bad: Vec<String> = Vec::new();
    let mut first_bad: Option<(usize, PolicyKind)> = None;
    for r in &rows {
        for (arm_idx, label, a) in [
            (0usize, "seq", &r.seq),
            (1, "overlap", &r.overlap),
            (2, "spec", &r.spec),
            (3, "both", &r.both),
        ] {
            if !a.completed {
                bad.push(format!("{}/{label} wedged", r.policy.name()));
                first_bad.get_or_insert((arm_idx, r.policy));
            }
            if a.spec_confirms + a.spec_rollbacks != a.spec_dispatches {
                bad.push(format!("{}/{label} left a speculation unresolved", r.policy.name()));
                first_bad.get_or_insert((arm_idx, r.policy));
            }
        }
    }
    if bad.is_empty() {
        println!(
            "all arms completed; every speculation resolved; wall {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        0
    } else {
        eprintln!("FAILED arms: {bad:?}");
        if let Some((arm_idx, kind)) = first_bad {
            dump_flight(&rapid::experiments::pipeline::arms(&sys)[arm_idx], task, kind);
        }
        1
    }
}

/// `rapid autoscale`: the deterministic control-plane demo — compose the
/// chaos fault schedule with a Poisson open-loop workload, print the
/// active `[autoscale]` knobs, then run the four-arm provisioning table
/// (static-min / static-max / autoscale / autoscale+shed) for RAPID vs
/// Cloud-Only. Exits non-zero if any arm wedges a session.
fn cmd_autoscale(rest: &[String]) -> i32 {
    let flags = Flags(rest);
    let mut sys = load_sys(&flags);
    // no explicit config: fall back to the shipped chaos schedule, then
    // to the built-in demo schedule, so the scaler always faces faults —
    // and always say which schedule actually ran
    let explicit_config = flags.get("--config").is_some();
    if !explicit_config {
        if let Ok(src) = std::fs::read_to_string("configs/chaos.toml") {
            match rapid::config::parse::parse_toml(&src) {
                Ok(v) => {
                    sys.apply_value(&v);
                    println!("schedule: configs/chaos.toml");
                }
                Err(e) => {
                    eprintln!("configs/chaos.toml parse error: {e}");
                    return 2;
                }
            }
        }
    }
    if !sys.faults.enabled {
        sys.faults = rapid::config::FaultsConfig::demo();
        println!("schedule: built-in demo (active config enables no faults)");
    } else if explicit_config {
        println!("schedule: --config");
    }
    if let Some(n) = flags.get("--sessions").and_then(|s| s.parse::<usize>().ok()) {
        sys.fleet.n_sessions = n.max(1);
        sys.workload.n_sessions = n.max(1);
    }
    let task = flags
        .get("--task")
        .and_then(TaskKind::parse)
        .unwrap_or(rapid::robot::TaskKind::PickPlace);
    let sys = compose_autoscale(&sys);

    let a = &sys.autoscale;
    println!(
        "autoscale: endpoints {}..{}, slo_queue {}, sustain {}, idle {}, cooldown {}, \
         shed_queue {}, family_pools {}",
        a.min_endpoints,
        a.max_endpoints,
        a.slo_queue,
        a.sustain_rounds,
        a.idle_rounds,
        a.cooldown_rounds,
        a.shed_queue,
        a.family_pools
    );
    println!(
        "workload: {} arrivals over {} session(s), deadline {}us",
        sys.workload.arrivals,
        sys.fleet.n_sessions.max(1),
        sys.fleet.batch_deadline_us
    );

    let t0 = std::time::Instant::now();
    let (table, rows) = rapid::experiments::autoscale::run(&sys, task);
    print!("{}", table.render());
    let mut bad: Vec<String> = Vec::new();
    let mut first_bad: Option<(usize, PolicyKind)> = None;
    for r in &rows {
        for (arm_idx, label, a) in [
            (0usize, "static_min", &r.static_min),
            (1, "static_max", &r.static_max),
            (2, "autoscale", &r.auto),
            (3, "autoscale+shed", &r.auto_shed),
        ] {
            if !a.completed {
                bad.push(format!("{}/{label} wedged", r.policy.name()));
                first_bad.get_or_insert((arm_idx, r.policy));
            }
        }
    }
    if bad.is_empty() {
        let (up, down): (u64, u64) =
            rows.iter().fold((0, 0), |(u, d), r| (u + r.auto.scale_up, d + r.auto.scale_down));
        println!(
            "all arms completed (zero wedged sessions); {up} spawn(s) / {down} drain(s) across \
             the autoscale arms; wall {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        0
    } else {
        eprintln!("WEDGED arms: {bad:?}");
        if let Some((arm_idx, kind)) = first_bad {
            dump_flight(&rapid::experiments::autoscale::arms(&sys)[arm_idx], task, kind);
        }
        1
    }
}

/// `rapid xpu`: the device-heterogeneity zoo. Composes the chaos
/// schedule (same fallback chain as `rapid autoscale`) with the model
/// zoo — per-class partition choices only show once family plans are
/// installed — prints the class catalog and the (class × family)
/// partition matrix under the nominal link, then the uniform-vs-mixed
/// fleet table. Exits 1 (dumping the flight ring) when any arm wedges.
fn cmd_xpu(rest: &[String]) -> i32 {
    use rapid::policy::planner;
    use rapid::runtime::DeviceClass;

    let flags = Flags(rest);
    let mut sys = load_sys(&flags);
    let explicit_config = flags.get("--config").is_some();
    if !explicit_config {
        if let Ok(src) = std::fs::read_to_string("configs/chaos.toml") {
            match rapid::config::parse::parse_toml(&src) {
                Ok(v) => {
                    sys.apply_value(&v);
                    println!("schedule: configs/chaos.toml");
                }
                Err(e) => {
                    eprintln!("configs/chaos.toml parse error: {e}");
                    return 2;
                }
            }
        }
    }
    if !sys.faults.enabled {
        sys.faults = rapid::config::FaultsConfig::demo();
        println!("schedule: built-in demo (active config enables no faults)");
    } else if explicit_config {
        println!("schedule: --config");
    }
    if let Some(n) = flags.get("--sessions").and_then(|s| s.parse::<usize>().ok()) {
        sys.fleet.n_sessions = n.max(1);
        sys.workload.n_sessions = n.max(1);
    }
    let task = flags
        .get("--task")
        .and_then(TaskKind::parse)
        .unwrap_or(rapid::robot::TaskKind::PickPlace);
    sys.models.enabled = true;

    println!("device classes (edge x / obs x / action grid / budget GB / budget ms):");
    for &c in DeviceClass::ALL.iter() {
        let b = planner::DeviceBudget::for_class(c);
        println!(
            "  {:<8} x{:<4} x{:<4} {:<9} {:<6} {}",
            c.name(),
            c.edge_scale(),
            c.obs_scale(),
            if c.action_quant() > 0.0 { format!("{:.4}", c.action_quant()) } else { "-".into() },
            if b.mem_gb.is_finite() { format!("{}", b.mem_gb) } else { "inf".into() },
            if b.prefix_ms.is_finite() { format!("{}", b.prefix_ms) } else { "inf".into() },
        );
    }
    println!("partition matrix (class x family -> split idx, e = edge-only):");
    for cell in rapid::experiments::xpu::partition_matrix(&sys) {
        let pick =
            if cell.edge_only { "e".to_string() } else { format!("{}", cell.partition_idx) };
        println!("  {:<8} {:<10} {pick}", cell.class.name(), cell.family.name());
    }

    let t0 = std::time::Instant::now();
    let (table, rows) = rapid::experiments::xpu::run(&sys, task);
    print!("{}", table.render());
    let mut bad: Vec<String> = Vec::new();
    let mut first_bad: Option<(usize, PolicyKind)> = None;
    for r in &rows {
        for (arm_idx, label, a) in [(0usize, "uniform", &r.uniform), (1, "mixed", &r.mixed)] {
            if !a.completed {
                bad.push(format!("{}/{label} wedged", r.policy.name()));
                first_bad.get_or_insert((arm_idx, r.policy));
            }
        }
    }
    if bad.is_empty() {
        println!(
            "all arms completed (zero wedged sessions); wall {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        0
    } else {
        eprintln!("WEDGED arms: {bad:?}");
        if let Some((arm_idx, kind)) = first_bad {
            dump_flight(&rapid::experiments::xpu::arms(&sys)[arm_idx], task, kind);
        }
        1
    }
}

/// `rapid trace`: run the deterministic two-fleet trace demo
/// (`obs::demo`), write the merged Perfetto-loadable Chrome trace JSON
/// plus its compact JSONL sibling, print per-stage span counts and the
/// merged registry, and exit 1 if any stage kind failed to appear — the
/// trace-smoke CI step leans on that as a coverage gate.
fn cmd_trace(rest: &[String]) -> i32 {
    use rapid::obs::Stage;

    let flags = Flags(rest);
    let sys = load_sys(&flags);
    let sessions = flags.get("--sessions").and_then(|s| s.parse::<usize>().ok()).unwrap_or(6);
    let out = flags.get("--out").unwrap_or("trace.json");

    let demo = rapid::obs::demo::run_trace_demo(&sys, sessions);
    let total: u64 = demo.stage_counts.iter().sum();
    println!("trace demo: {total} spans across two fleets (pid 0 faults+cache, pid 1 zoo+spec)");
    for (stage, count) in Stage::ALL.iter().zip(demo.stage_counts.iter()) {
        println!("  {:<13} {count}", stage.name());
    }
    print!("{}", demo.registry.render("trace demo counters"));

    if !write_artifact(out, &demo.chrome_json, "chrome trace") {
        return 1;
    }
    if !write_artifact(&jsonl_sibling(out), &demo.jsonl, "span JSONL") {
        return 1;
    }
    if let Some(path) = flags.get("--metrics-json") {
        if !write_artifact(path, &demo.registry.to_json(), "metrics JSON") {
            return 1;
        }
    }

    let missing = rapid::obs::demo::missing_stages(&demo.stage_counts);
    if missing.is_empty() {
        println!("all {} stage kinds present", Stage::ALL.len());
        0
    } else {
        eprintln!("MISSING stage kinds: {missing:?}");
        1
    }
}

fn cmd_info() -> i32 {
    println!("RAPID reproduction — three-layer rust + JAX + Pallas stack");
    match rapid::runtime::ArtifactMeta::load(rapid::runtime::ArtifactMeta::default_dir()) {
        Ok(m) => {
            println!("artifacts: {:?} (seed {})", m.dir, m.seed);
            for v in &m.variants {
                println!("  {}: d={} layers={} params={}", v.name, v.d, v.layers, v.n_params);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    #[cfg(feature = "pjrt")]
    match rapid::runtime::RuntimeClient::cpu() {
        Ok(c) => println!("pjrt: {} ok", c.platform()),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt: disabled at build time (enable the `pjrt` feature)");
    0
}

#[cfg(test)]
mod tests {
    use super::scale_rungs;

    #[test]
    fn scale_rungs_clamp_small_fleets_to_one_session() {
        // the ISSUE-7 pin: 1% of 50 truncates to 0 without the clamp
        assert_eq!(scale_rungs(50), vec![1, 5, 50]);
        // below 10 sessions the two small rungs collapse onto one
        assert_eq!(scale_rungs(7), vec![1, 7]);
        assert_eq!(scale_rungs(1), vec![1]);
        // at and above 100 the ladder is the plain 1%/10%/100% split
        assert_eq!(scale_rungs(100), vec![1, 10, 100]);
        assert_eq!(scale_rungs(10_000), vec![100, 1_000, 10_000]);
        // every rung is runnable
        for s in [1usize, 2, 9, 10, 49, 99, 101, 12_345] {
            assert!(scale_rungs(s).iter().all(|&n| n >= 1), "sessions {s}");
        }
    }
}

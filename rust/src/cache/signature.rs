//! Quantized observation/kinematic signature — the reuse-cache key.
//!
//! Two dispatches may share a cached chunk only when they are *kinematic
//! near-duplicates*: same task instruction, same joint configuration and
//! speed up to a quantization step, and the same (coarsely binned)
//! windowed anomaly z-scores. Quantization is the divergence budget's
//! spatial half — the [`crate::cache::ReuseStore`] TTL is its temporal
//! half. Tighter `quant` means fewer but safer hits; the defaults absorb
//! sensor noise (σ ≈ 0.002 rad) without conflating distinct trajectory
//! points (bins of 0.1 rad / 0.1 rad/s).

use crate::config::CacheConfig;
use crate::dispatcher::ReuseEvidence;
use crate::robot::SensorFrame;
use crate::runtime::DeviceClass;
use crate::vla::profile::ModelFamily;
use crate::N_JOINTS;

/// Exact-match cache key: everything already quantized to integer bins.
/// Derived `Eq`/`Hash` make lookups allocation-free and deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Signature {
    /// Task instruction id — chunks never cross tasks.
    pub instr: usize,
    /// Model-family discriminant — chunks never cross model families: two
    /// sessions in the same kinematic state but served by different
    /// backends (zoo families, or any future edge/cloud variant split)
    /// must never share a cached answer.
    fam: u8,
    /// Device-class discriminant — chunks never cross device classes
    /// either: a Lite robot snaps its actions onto a coarse grid, so an
    /// Agx chunk in the same kinematic bin would replay an incompatible
    /// trajectory. 0 (Cloudlet) when the device zoo is off, keeping old
    /// keys bit-identical.
    dev: u8,
    /// Joint positions, binned at `cache.quant` rad.
    q: [i32; N_JOINTS],
    /// Velocity norm ‖q̇‖, binned at `cache.quant` rad/s.
    v: i32,
    /// Windowed anomaly z-scores (M̂_acc, M̂_τ), binned at `cache.z_quant`
    /// σ; 0 for strategies that expose no kinematic evidence.
    z_acc: i32,
    z_tau: i32,
}

/// Quantize to a bin index. Non-finite inputs and non-positive steps map
/// to a sentinel bin that never collides with a normal signature.
fn bin(x: f64, step: f64) -> i32 {
    if !x.is_finite() || step <= 0.0 {
        return i32::MAX;
    }
    (x / step).round().clamp(-1.0e9, 1.0e9) as i32
}

impl Signature {
    /// Build the signature of a dispatch from the last proprioceptive
    /// frame, the serving model family, and (when the strategy provides
    /// it) the dispatcher's normalized anomaly evidence.
    pub fn of(
        cfg: &CacheConfig,
        instr: usize,
        frame: &SensorFrame,
        ev: Option<&ReuseEvidence>,
        family: ModelFamily,
    ) -> Signature {
        Signature::of_class(cfg, instr, frame, ev, family, DeviceClass::default())
    }

    /// [`Signature::of`] with an explicit device-class discriminant. The
    /// default (Cloudlet) class produces exactly the keys `of` produces.
    pub fn of_class(
        cfg: &CacheConfig,
        instr: usize,
        frame: &SensorFrame,
        ev: Option<&ReuseEvidence>,
        family: ModelFamily,
        class: DeviceClass,
    ) -> Signature {
        let mut q = [0i32; N_JOINTS];
        for (i, b) in q.iter_mut().enumerate() {
            *b = bin(frame.q[i], cfg.quant);
        }
        let (z_acc, z_tau) = match ev {
            Some(e) => (bin(e.m_acc_hat, cfg.z_quant), bin(e.m_tau_hat, cfg.z_quant)),
            None => (0, 0),
        };
        let v = bin(frame.dq.norm(), cfg.quant);
        Signature { instr, fam: family.id(), dev: class.id(), q, v, z_acc, z_tau }
    }

    /// The family discriminant baked into this key.
    pub fn family_id(&self) -> u8 {
        self.fam
    }

    /// The device-class discriminant baked into this key.
    pub fn class_id(&self) -> u8 {
        self.dev
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::Jv;

    const FAM: ModelFamily = ModelFamily::Surrogate;

    fn frame(q: f64, dq: f64) -> SensorFrame {
        SensorFrame { step: 0, q: Jv::splat(q), dq: Jv::splat(dq), tau: Jv::ZERO }
    }

    fn cfg() -> CacheConfig {
        CacheConfig::default()
    }

    #[test]
    fn identical_states_share_a_signature() {
        let c = cfg();
        let a = Signature::of(&c, 1, &frame(0.31, 0.2), None, FAM);
        let b = Signature::of(&c, 1, &frame(0.31, 0.2), None, FAM);
        assert_eq!(a, b);
    }

    #[test]
    fn noise_below_the_quantization_step_is_absorbed() {
        let c = cfg();
        let a = Signature::of(&c, 1, &frame(0.30, 0.20), None, FAM);
        let b = Signature::of(&c, 1, &frame(0.302, 0.201), None, FAM);
        assert_eq!(a, b, "sub-quant jitter must not split the bin");
    }

    #[test]
    fn distinct_states_and_tasks_split() {
        let c = cfg();
        let a = Signature::of(&c, 1, &frame(0.3, 0.2), None, FAM);
        assert_ne!(a, Signature::of(&c, 2, &frame(0.3, 0.2), None, FAM), "task id");
        assert_ne!(a, Signature::of(&c, 1, &frame(0.9, 0.2), None, FAM), "joint state");
        assert_ne!(a, Signature::of(&c, 1, &frame(0.3, 1.9), None, FAM), "velocity");
    }

    #[test]
    fn model_family_is_a_hard_discriminant() {
        // regression (PR 4 satellite): before the discriminant, two
        // sessions in the same kinematic state served by *different model
        // variants* shared a signature, so a shared store could
        // cross-serve chunks between incompatible backends
        let c = cfg();
        let a = Signature::of(&c, 1, &frame(0.3, 0.2), None, ModelFamily::Surrogate);
        for fam in [ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            let b = Signature::of(&c, 1, &frame(0.3, 0.2), None, fam);
            assert_ne!(a, b, "{fam:?} must not share the surrogate's key");
            assert_eq!(b.family_id(), fam.id());
        }
        // same family still matches
        let c2 = Signature::of(&c, 1, &frame(0.3, 0.2), None, ModelFamily::OpenVlaAr);
        assert_eq!(c2, Signature::of(&c, 1, &frame(0.3, 0.2), None, ModelFamily::OpenVlaAr));
    }

    #[test]
    fn device_class_is_a_hard_discriminant() {
        // regression (PR 10): a Lite robot snaps actions onto a coarse
        // grid, so its chunks must never cross-serve an Agx session even
        // in an identical kinematic bin — and vice versa.
        let c = cfg();
        let base = Signature::of(&c, 1, &frame(0.3, 0.2), None, FAM);
        assert_eq!(base.class_id(), 0, "plain `of` keys carry the no-op class");
        for class in [DeviceClass::Agx, DeviceClass::Nx, DeviceClass::Lite] {
            let b = Signature::of_class(&c, 1, &frame(0.3, 0.2), None, FAM, class);
            assert_ne!(base, b, "{class:?} must not share the cloudlet's key");
            assert_eq!(b.class_id(), class.id());
        }
        // the default class is exactly the legacy key
        let d = Signature::of_class(&c, 1, &frame(0.3, 0.2), None, FAM, DeviceClass::default());
        assert_eq!(base, d);
    }

    #[test]
    fn evidence_bins_participate_in_the_key() {
        let c = cfg();
        let calm = ReuseEvidence { m_acc_hat: 0.2, m_tau_hat: 0.1, velocity: 0.2 };
        let wild = ReuseEvidence { m_acc_hat: 30.0, m_tau_hat: 0.1, velocity: 0.2 };
        let a = Signature::of(&c, 1, &frame(0.3, 0.2), Some(&calm), FAM);
        let b = Signature::of(&c, 1, &frame(0.3, 0.2), Some(&wild), FAM);
        assert_ne!(a, b);
        // calm evidence quantizes into the no-evidence bin (both ~0σ)
        assert_eq!(a, Signature::of(&c, 1, &frame(0.3, 0.2), None, FAM));
    }

    #[test]
    fn non_finite_inputs_never_match_normal_bins() {
        let c = cfg();
        let mut f = frame(0.3, 0.2);
        f.q[0] = f64::NAN;
        let bad = Signature::of(&c, 1, &f, None, FAM);
        assert_ne!(bad, Signature::of(&c, 1, &frame(0.3, 0.2), None, FAM));
        // but NaN signatures are still self-equal (no poisoned HashMap)
        assert_eq!(bad, Signature::of(&c, 1, &f, None, FAM));
    }
}

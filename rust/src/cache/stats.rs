//! Reuse-cache counters, reported per store (fleet aggregate) and — for
//! hits/misses/staleness — mirrored into `EpisodeMetrics` per session.

/// Lifetime counters of one [`crate::cache::ReuseStore`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Probes attempted (hits + misses).
    pub probes: u64,
    /// Probes served from the store within the divergence budget.
    pub hits: u64,
    /// Probes that found nothing usable (no entry, wrong owner, or stale).
    pub misses: u64,
    /// Subset of misses where an entry existed but exceeded its
    /// TTL-in-rounds (the entry is dropped on discovery).
    pub stale: u64,
    /// Entries offered to the store (inserts + refreshes).
    pub admissions: u64,
    /// Admissions that refreshed an existing signature in place.
    pub refreshed: u64,
    /// Entries displaced by seeded random replacement at capacity.
    pub evictions: u64,
}

impl CacheStats {
    /// Hit fraction over all probes (0 when nothing was probed).
    pub fn hit_rate(&self) -> f64 {
        if self.probes == 0 {
            0.0
        } else {
            self.hits as f64 / self.probes as f64
        }
    }

    /// True when every counter is zero (an untouched store).
    pub fn is_zero(&self) -> bool {
        *self == CacheStats::default()
    }

    /// One-line human report, shared by every CLI surface so `rapid run`
    /// and `rapid fleet` can never drift apart.
    pub fn report(&self) -> String {
        format!(
            "cache: probes {}  hits {} ({:.1}%)  misses {}  stale {}  admitted {}  refreshed {}  evicted {}",
            self.probes,
            self.hits,
            100.0 * self.hit_rate(),
            self.misses,
            self.stale,
            self.admissions,
            self.refreshed,
            self.evictions
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_safe_and_correct() {
        let mut s = CacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        assert!(s.is_zero());
        s.probes = 4;
        s.hits = 3;
        s.misses = 1;
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert!(!s.is_zero());
    }
}

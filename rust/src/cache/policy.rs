//! Probe policy: when is speculative reuse allowed at all?
//!
//! The signature (spatial budget) and TTL (temporal budget) bound *how
//! far* a reused chunk may diverge from a fresh cloud answer; the probe
//! gate bounds *when* reuse is attempted in the first place: a dispatch
//! whose windowed anomaly z-scores exceed `cache.max_zscore` is a
//! genuinely novel situation — exactly the critical-phase events RAPID
//! exists to send to the cloud — and must never be served from memory.
//! Routine (redundant-phase) dispatches, and strategies that expose no
//! kinematic evidence at all (Cloud-Only's timer-like refills), probe
//! freely.

use super::signature::Signature;
use crate::config::CacheConfig;
use crate::dispatcher::ReuseEvidence;
use crate::robot::SensorFrame;

/// The z-score gate shared by the reuse probe and the pipeline's
/// speculative decode (`[pipeline]`): a dispatch whose windowed anomaly
/// z-scores exceed `max_zscore` is a genuinely novel situation and must
/// neither be served from memory nor speculated on. No evidence (e.g.
/// Cloud-Only's timer-like refills) counts as routine. NaN scores
/// compare false and therefore refuse.
pub fn zscore_gate_allows(ev: Option<&ReuseEvidence>, max_zscore: f64) -> bool {
    match ev {
        None => true,
        Some(e) => e.m_acc_hat.max(e.m_tau_hat) <= max_zscore,
    }
}

/// Thin, allocation-free view over the `[cache]` knobs used at dispatch
/// time (construction is free; the driver builds one per offload).
pub struct ReusePolicy<'a> {
    cfg: &'a CacheConfig,
}

impl<'a> ReusePolicy<'a> {
    pub fn new(cfg: &'a CacheConfig) -> ReusePolicy<'a> {
        ReusePolicy { cfg }
    }

    /// The dispatch's cache key (family-discriminated: hits never cross
    /// model families).
    pub fn signature(
        &self,
        instr: usize,
        frame: &SensorFrame,
        ev: Option<&ReuseEvidence>,
        family: crate::vla::profile::ModelFamily,
    ) -> Signature {
        Signature::of(self.cfg, instr, frame, ev, family)
    }

    /// [`ReusePolicy::signature`] discriminated by device class as well:
    /// with the device zoo armed, a Lite robot's coarse-grid chunks must
    /// never cross-serve an Agx session. The default class reproduces
    /// `signature` exactly.
    pub fn signature_for(
        &self,
        instr: usize,
        frame: &SensorFrame,
        ev: Option<&ReuseEvidence>,
        family: crate::vla::profile::ModelFamily,
        class: crate::runtime::DeviceClass,
    ) -> Signature {
        Signature::of_class(self.cfg, instr, frame, ev, family, class)
    }

    /// True when this dispatch may be served from the store.
    pub fn probe_allowed(&self, ev: Option<&ReuseEvidence>) -> bool {
        zscore_gate_allows(ev, self.cfg.max_zscore)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(a: f64, t: f64) -> ReuseEvidence {
        ReuseEvidence { m_acc_hat: a, m_tau_hat: t, velocity: 0.3 }
    }

    #[test]
    fn gate_follows_max_zscore() {
        let cfg = CacheConfig::default();
        let p = ReusePolicy::new(&cfg);
        assert!(p.probe_allowed(None), "no evidence = routine dispatch");
        assert!(p.probe_allowed(Some(&ev(1.0, 2.0))));
        assert!(p.probe_allowed(Some(&ev(cfg.max_zscore, 0.0))), "boundary inclusive");
        assert!(!p.probe_allowed(Some(&ev(cfg.max_zscore + 0.1, 0.0))));
        assert!(!p.probe_allowed(Some(&ev(0.0, 1e9))));
        assert!(!p.probe_allowed(Some(&ev(f64::NAN, 0.0))), "NaN refuses reuse");
    }

    #[test]
    fn shared_gate_matches_probe_gate() {
        // one definition: the pipeline's speculation gate and the reuse
        // probe gate must agree on every evidence shape
        let cfg = CacheConfig::default();
        let p = ReusePolicy::new(&cfg);
        for e in [None, Some(ev(1.0, 2.0)), Some(ev(9.0, 0.0)), Some(ev(f64::NAN, 0.0))] {
            assert_eq!(p.probe_allowed(e.as_ref()), zscore_gate_allows(e.as_ref(), cfg.max_zscore));
        }
    }
}

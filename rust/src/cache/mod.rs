//! Redundancy-aware action-reuse cache — converts the step-wise
//! redundancy the dispatcher already measures into *skipped cloud round
//! trips*.
//!
//! Two tiers share one deterministic store:
//!
//! * **Per-session speculative reuse**: on a cloud dispatch in a redundant
//!   phase, the episode driver first probes a cache of recent cloud chunks
//!   keyed by a quantized observation/kinematic [`Signature`] (joint
//!   state, velocity, windowed anomaly z-scores, task id). A hit within
//!   the divergence budget serves the chunk at edge-probe latency instead
//!   of suspending the session on the cloud.
//! * **Fleet-shared result cache**: the fleet scheduler admits
//!   cross-session batch replies into one shared [`ReuseStore`], so
//!   session B reuses session A's answer for a matching signature —
//!   including through uplink-outage windows, when no fresh offload can
//!   leave the edge.
//!
//! Determinism contract (same discipline as `faults/`): with the cache
//! disabled no store is constructed and every serve path is **bit
//! identical** to a build without this module; with it enabled, eviction
//! is the only stochastic choice and draws from the store's own seeded
//! PRNG *only when an eviction actually happens*, so runs replay exactly
//! under a fixed seed.

pub mod policy;
pub mod signature;
pub mod stats;
pub mod store;

pub use policy::{zscore_gate_allows, ReusePolicy};
pub use signature::Signature;
pub use stats::CacheStats;
pub use store::{ProbeOutcome, ReuseStore};

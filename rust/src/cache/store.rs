//! The reuse store: a bounded, TTL'd, owner-tagged map from quantized
//! signatures to cloud-grade action chunks.
//!
//! Determinism: lookups and inserts never iterate the backing `HashMap`
//! (iteration order is the only non-deterministic thing about it), and
//! the store's PRNG is drawn **only** when an at-capacity admission must
//! evict — an under-capacity run consumes zero draws and replays exactly.

use super::signature::Signature;
use super::stats::CacheStats;
use crate::config::CacheConfig;
use crate::util::Pcg32;
use crate::vla::ModelOut;
use std::collections::HashMap;

/// Outcome of a probe.
pub enum ProbeOutcome {
    /// A fresh entry within the divergence budget: serve this chunk.
    Hit(ModelOut),
    /// An entry existed but aged past `ttl_rounds`; it has been dropped.
    Stale,
    /// No usable entry.
    Miss,
}

struct Entry {
    sig: Signature,
    out: ModelOut,
    /// Scheduler round (control step, single-session) of admission.
    round: u64,
    /// Session that produced the chunk (the per-session tier filters on
    /// this when the fleet-shared tier is disabled).
    owner: usize,
}

/// Bounded reuse cache with seeded-deterministic random replacement.
///
/// In shared mode every session reads and writes one namespace; with
/// `shared = false` the map is keyed by (owner, signature) so each
/// session keeps a private tier inside the same bounded store.
pub struct ReuseStore {
    capacity: usize,
    ttl_rounds: u64,
    shared: bool,
    rng: Pcg32,
    map: HashMap<(usize, Signature), usize>,
    entries: Vec<Entry>,
    stats: CacheStats,
    /// High-water mark: one past the latest admission round. Per-session
    /// callers whose round counter restarts (a fresh episode over a
    /// persistent store) resume from here so entry ages — and therefore
    /// the TTL budget — stay monotonic across episodes.
    next_round: u64,
}

impl ReuseStore {
    pub fn new(capacity: usize, ttl_rounds: u64, shared: bool, seed: u64) -> ReuseStore {
        let capacity = capacity.max(1);
        ReuseStore {
            capacity,
            ttl_rounds,
            shared,
            rng: Pcg32::new(seed, 0xCAC_4E),
            map: HashMap::with_capacity(capacity),
            entries: Vec::with_capacity(capacity),
            stats: CacheStats::default(),
            next_round: 0,
        }
    }

    /// Store described by a `[cache]` config section. `base_seed` seeds
    /// the eviction stream when the section doesn't pin its own seed.
    pub fn from_config(cfg: &CacheConfig, base_seed: u64) -> ReuseStore {
        let seed = if cfg.seed != 0 { cfg.seed } else { base_seed ^ 0x5EED_CACE };
        ReuseStore::new(cfg.capacity, cfg.ttl_rounds, cfg.shared, seed)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// One past the latest admission round: the round a fresh per-session
    /// episode should resume its clock from (see `run_episode_with_cache`).
    pub fn next_round(&self) -> u64 {
        self.next_round
    }

    /// Map key: the shared tier pools every session into one namespace,
    /// the unshared tier prefixes the owner.
    fn key(&self, sig: Signature, owner: usize) -> (usize, Signature) {
        (if self.shared { 0 } else { owner }, sig)
    }

    /// Look up a signature at scheduler round `round` on behalf of session
    /// `owner`. Stale entries are evicted on discovery so the store never
    /// serves a chunk older than its TTL.
    pub fn probe(&mut self, sig: &Signature, round: u64, owner: usize) -> ProbeOutcome {
        self.stats.probes += 1;
        let Some(&idx) = self.map.get(&self.key(*sig, owner)) else {
            self.stats.misses += 1;
            return ProbeOutcome::Miss;
        };
        if round.saturating_sub(self.entries[idx].round) > self.ttl_rounds {
            self.stats.misses += 1;
            self.stats.stale += 1;
            self.remove_at(idx);
            return ProbeOutcome::Stale;
        }
        self.stats.hits += 1;
        ProbeOutcome::Hit(self.entries[idx].out.clone())
    }

    /// Admit a cloud reply. An existing signature is refreshed in place;
    /// a new one at capacity displaces a seeded-random victim.
    pub fn admit(&mut self, sig: Signature, out: ModelOut, round: u64, owner: usize) {
        self.stats.admissions += 1;
        self.next_round = self.next_round.max(round.saturating_add(1));
        if let Some(&idx) = self.map.get(&self.key(sig, owner)) {
            self.stats.refreshed += 1;
            let e = &mut self.entries[idx];
            e.out = out;
            e.round = round;
            e.owner = owner;
            return;
        }
        if self.entries.len() >= self.capacity {
            // seeded random replacement: the only PRNG draw in the store
            let victim = self.rng.below(self.entries.len() as u32) as usize;
            self.stats.evictions += 1;
            let old = self.key(self.entries[victim].sig, self.entries[victim].owner);
            self.map.remove(&old);
            self.entries[victim] = Entry { sig, out, round, owner };
            self.map.insert(self.key(sig, owner), victim);
            return;
        }
        self.entries.push(Entry { sig, out, round, owner });
        self.map.insert(self.key(sig, owner), self.entries.len() - 1);
    }

    /// Remove the entry at `idx` (swap-remove; the moved tail entry's map
    /// slot is re-pointed).
    fn remove_at(&mut self, idx: usize) {
        let old = self.key(self.entries[idx].sig, self.entries[idx].owner);
        self.map.remove(&old);
        self.entries.swap_remove(idx);
        if idx < self.entries.len() {
            let moved = self.key(self.entries[idx].sig, self.entries[idx].owner);
            self.map.insert(moved, idx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::{Jv, SensorFrame};
    use crate::vla::Backend;

    fn sig(q: f64) -> Signature {
        sig_fam(q, crate::vla::profile::ModelFamily::Surrogate)
    }

    fn sig_fam(q: f64, fam: crate::vla::profile::ModelFamily) -> Signature {
        let f = SensorFrame { step: 0, q: Jv::splat(q), dq: Jv::ZERO, tau: Jv::ZERO };
        Signature::of(&CacheConfig::default(), 1, &f, None, fam)
    }

    fn out(seed: u64) -> ModelOut {
        crate::vla::AnalyticBackend::cloud(seed).infer(
            &[0.1; crate::D_VIS],
            &[0.0; crate::D_PROP],
            1,
        )
    }

    #[test]
    fn probe_hit_miss_and_stats() {
        let mut s = ReuseStore::new(8, 10, true, 1);
        assert!(matches!(s.probe(&sig(0.1), 0, 0), ProbeOutcome::Miss));
        s.admit(sig(0.1), out(1), 0, 0);
        assert!(matches!(s.probe(&sig(0.1), 3, 5), ProbeOutcome::Hit(_)), "shared tier crosses owners");
        assert!(matches!(s.probe(&sig(0.7), 3, 0), ProbeOutcome::Miss));
        assert_eq!(s.stats().probes, 3);
        assert_eq!(s.stats().hits, 1);
        assert_eq!(s.stats().misses, 2);
    }

    #[test]
    fn ttl_expires_and_drops_the_entry() {
        let mut s = ReuseStore::new(8, 10, true, 1);
        s.admit(sig(0.1), out(1), 0, 0);
        assert!(matches!(s.probe(&sig(0.1), 10, 0), ProbeOutcome::Hit(_)), "age == ttl still fresh");
        assert!(matches!(s.probe(&sig(0.1), 11, 0), ProbeOutcome::Stale));
        assert_eq!(s.len(), 0, "stale entry dropped on discovery");
        assert!(matches!(s.probe(&sig(0.1), 11, 0), ProbeOutcome::Miss));
        assert_eq!(s.stats().stale, 1);
    }

    #[test]
    fn unshared_store_is_per_session() {
        let mut s = ReuseStore::new(8, 100, false, 1);
        s.admit(sig(0.1), out(1), 0, 3);
        assert!(matches!(s.probe(&sig(0.1), 1, 4), ProbeOutcome::Miss), "other session blocked");
        assert!(matches!(s.probe(&sig(0.1), 1, 3), ProbeOutcome::Hit(_)), "owner still hits");
    }

    #[test]
    fn capacity_bound_holds_under_eviction() {
        let mut s = ReuseStore::new(4, 1000, true, 7);
        for i in 0..50 {
            s.admit(sig(i as f64), out(i), i, 0);
            assert!(s.len() <= 4, "len {} at admit {i}", s.len());
        }
        assert_eq!(s.stats().evictions, 46);
        assert_eq!(s.stats().admissions, 50);
        // the map stays consistent: every surviving entry is probeable
        let mut live = 0;
        for i in 0..50 {
            if matches!(s.probe(&sig(i as f64), 1000, 0), ProbeOutcome::Hit(_)) {
                live += 1;
            }
        }
        assert_eq!(live, 4);
    }

    #[test]
    fn refresh_updates_round_without_growing() {
        let mut s = ReuseStore::new(4, 5, true, 1);
        s.admit(sig(0.1), out(1), 0, 0);
        s.admit(sig(0.1), out(2), 9, 1);
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats().refreshed, 1);
        assert!(matches!(s.probe(&sig(0.1), 12, 0), ProbeOutcome::Hit(_)), "refreshed TTL");
    }

    #[test]
    fn family_tagged_entries_never_cross_serve() {
        use crate::vla::profile::ModelFamily;
        // regression (PR 4 satellite): a shared store holding a chunk
        // produced by one model family must miss for every other family in
        // the identical kinematic state
        let mut s = ReuseStore::new(8, 100, true, 1);
        s.admit(sig_fam(0.1, ModelFamily::OpenVlaAr), out(1), 0, 0);
        assert!(matches!(
            s.probe(&sig_fam(0.1, ModelFamily::OpenVlaAr), 1, 5),
            ProbeOutcome::Hit(_)
        ));
        for fam in [ModelFamily::Surrogate, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            assert!(
                matches!(s.probe(&sig_fam(0.1, fam), 1, 5), ProbeOutcome::Miss),
                "{fam:?} cross-served another family's chunk"
            );
        }
    }

    #[test]
    fn eviction_replays_under_a_fixed_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let mut s = ReuseStore::new(3, 1000, true, seed);
            for i in 0..30 {
                s.admit(sig(i as f64), out(i), i, 0);
            }
            (0..30).map(|i| matches!(s.probe(&sig(i as f64), 999, 0), ProbeOutcome::Hit(_))).collect()
        };
        assert_eq!(run(42), run(42), "same seed, same survivors");
        assert_ne!(run(42), run(43), "eviction stream is seed-driven");
    }
}

//! The reuse store: a bounded, TTL'd, owner-tagged map from quantized
//! signatures to cloud-grade action chunks.
//!
//! Scale: the backing is a fixed power-of-two array of shards, each with
//! its own bounded entry vector, exact-match index and seeded eviction
//! stream. Shard routing hashes the map key through a fixed-key FNV-1a
//! (the std hasher is randomly keyed per process and would break
//! replay). A single shard reproduces the historical single-map store
//! bit for bit.
//!
//! Determinism: lookups and inserts never iterate the backing `HashMap`
//! (iteration order is the only non-deterministic thing about it), and
//! a shard's PRNG is drawn **only** when an at-capacity admission must
//! evict — an under-capacity run consumes zero draws and replays exactly,
//! no matter how its traffic is spread across shards.

use super::signature::Signature;
use super::stats::CacheStats;
use crate::config::CacheConfig;
use crate::util::Pcg32;
use crate::vla::ModelOut;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

/// Outcome of a probe.
pub enum ProbeOutcome {
    /// A fresh entry within the divergence budget: serve this chunk.
    Hit(ModelOut),
    /// An entry existed but aged past `ttl_rounds`; it has been dropped.
    Stale,
    /// No usable entry.
    Miss,
}

struct Entry {
    sig: Signature,
    out: ModelOut,
    /// Scheduler round (control step, single-session) of admission.
    round: u64,
    /// Session that produced the chunk (the per-session tier filters on
    /// this when the fleet-shared tier is disabled).
    owner: usize,
}

/// Deterministic 64-bit FNV-1a. Shard routing must replay across runs
/// and processes, and `std`'s default hasher is randomly keyed per
/// process — so shard selection hashes through this fixed-key hasher.
struct Fnv1a(u64);

impl Hasher for Fnv1a {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01B3);
        }
    }
}

/// Map key of a stored entry (mirrors `ReuseStore::key`).
fn entry_key(shared: bool, e: &Entry) -> (usize, Signature) {
    (if shared { 0 } else { e.owner }, e.sig)
}

/// One shard: a bounded entry vector, its exact-match index, and a
/// private eviction stream drawn only on at-capacity admission.
struct Shard {
    rng: Pcg32,
    map: HashMap<(usize, Signature), usize>,
    entries: Vec<Entry>,
}

impl Shard {
    /// Remove the entry at `idx` (swap-remove; the moved tail entry's map
    /// slot is re-pointed).
    fn remove_at(&mut self, idx: usize, shared: bool) {
        let old = entry_key(shared, &self.entries[idx]);
        self.map.remove(&old);
        self.entries.swap_remove(idx);
        if idx < self.entries.len() {
            let moved = entry_key(shared, &self.entries[idx]);
            self.map.insert(moved, idx);
        }
    }
}

/// Bounded, sharded reuse cache with seeded-deterministic random
/// replacement.
///
/// In shared mode every session reads and writes one namespace; with
/// `shared = false` the map is keyed by (owner, signature) so each
/// session keeps a private tier inside the same bounded store.
pub struct ReuseStore {
    capacity: usize,
    /// Per-shard entry bound; `shard_cap * shards.len() <= capacity`.
    shard_cap: usize,
    /// `shards.len() - 1` (the shard count is a power of two).
    mask: usize,
    ttl_rounds: u64,
    shared: bool,
    shards: Vec<Shard>,
    stats: CacheStats,
    /// High-water mark: one past the latest admission round. Per-session
    /// callers whose round counter restarts (a fresh episode over a
    /// persistent store) resume from here so entry ages — and therefore
    /// the TTL budget — stay monotonic across episodes.
    next_round: u64,
}

impl ReuseStore {
    /// Single-shard store: exactly the historical (PR 5) layout — one
    /// map, one entry vector, one eviction stream on `0xCAC_4E`.
    pub fn new(capacity: usize, ttl_rounds: u64, shared: bool, seed: u64) -> ReuseStore {
        ReuseStore::with_shards(capacity, ttl_rounds, shared, seed, 1)
    }

    /// Sharded store. `n_shards` is rounded up to a power of two, then
    /// halved until every shard holds at least one entry, so the total
    /// bound `shard_capacity() * n_shards()` never exceeds `capacity`.
    /// Shard `i` evicts from stream `0xCAC_4E ^ (i << 20)`, so one shard
    /// reproduces [`ReuseStore::new`] bit for bit.
    pub fn with_shards(
        capacity: usize,
        ttl_rounds: u64,
        shared: bool,
        seed: u64,
        n_shards: usize,
    ) -> ReuseStore {
        let capacity = capacity.max(1);
        let mut n = n_shards.max(1).next_power_of_two();
        while n > 1 && capacity / n == 0 {
            n /= 2;
        }
        let shard_cap = capacity / n;
        let shards = (0..n)
            .map(|i| Shard {
                rng: Pcg32::new(seed, 0xCAC_4E ^ ((i as u64) << 20)),
                map: HashMap::with_capacity(shard_cap),
                entries: Vec::with_capacity(shard_cap),
            })
            .collect();
        ReuseStore {
            capacity,
            shard_cap,
            mask: n - 1,
            ttl_rounds,
            shared,
            shards,
            stats: CacheStats::default(),
            next_round: 0,
        }
    }

    /// Store described by a `[cache]` config section. `base_seed` seeds
    /// the eviction streams when the section doesn't pin its own seed.
    pub fn from_config(cfg: &CacheConfig, base_seed: u64) -> ReuseStore {
        let seed = if cfg.seed != 0 { cfg.seed } else { base_seed ^ 0x5EED_CACE };
        ReuseStore::with_shards(cfg.capacity, cfg.ttl_rounds, cfg.shared, seed, cfg.shards)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.entries.is_empty())
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of shards (a power of two; 1 is the historical store).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard entry bound. The effective total capacity is
    /// `n_shards() * shard_capacity()` (≤ `capacity()` after rounding).
    pub fn shard_capacity(&self) -> usize {
        self.shard_cap
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// One past the latest admission round: the round a fresh per-session
    /// episode should resume its clock from (see `run_episode_with_cache`).
    pub fn next_round(&self) -> u64 {
        self.next_round
    }

    /// Map key: the shared tier pools every session into one namespace,
    /// the unshared tier prefixes the owner.
    fn key(&self, sig: Signature, owner: usize) -> (usize, Signature) {
        (if self.shared { 0 } else { owner }, sig)
    }

    /// Shard routing: fixed-key FNV-1a over the map key, masked to the
    /// power-of-two shard count (the single-shard store skips the hash).
    fn shard_of(&self, key: &(usize, Signature)) -> usize {
        if self.mask == 0 {
            return 0;
        }
        let mut h = Fnv1a(0xCBF2_9CE4_8422_2325);
        key.hash(&mut h);
        (h.finish() as usize) & self.mask
    }

    /// Look up a signature at scheduler round `round` on behalf of session
    /// `owner`. Stale entries are evicted on discovery so the store never
    /// serves a chunk older than its TTL.
    pub fn probe(&mut self, sig: &Signature, round: u64, owner: usize) -> ProbeOutcome {
        self.stats.probes += 1;
        let key = self.key(*sig, owner);
        let si = self.shard_of(&key);
        let shared = self.shared;
        let ttl = self.ttl_rounds;
        let shard = &mut self.shards[si];
        let Some(&idx) = shard.map.get(&key) else {
            self.stats.misses += 1;
            return ProbeOutcome::Miss;
        };
        if round.saturating_sub(shard.entries[idx].round) > ttl {
            self.stats.misses += 1;
            self.stats.stale += 1;
            shard.remove_at(idx, shared);
            return ProbeOutcome::Stale;
        }
        self.stats.hits += 1;
        ProbeOutcome::Hit(shard.entries[idx].out.clone())
    }

    /// Admit a cloud reply. An existing signature is refreshed in place;
    /// a new one at shard capacity displaces a seeded-random victim from
    /// its own shard.
    pub fn admit(&mut self, sig: Signature, out: ModelOut, round: u64, owner: usize) {
        self.stats.admissions += 1;
        self.next_round = self.next_round.max(round.saturating_add(1));
        let key = self.key(sig, owner);
        let si = self.shard_of(&key);
        let shared = self.shared;
        let cap = self.shard_cap;
        let shard = &mut self.shards[si];
        if let Some(&idx) = shard.map.get(&key) {
            self.stats.refreshed += 1;
            let e = &mut shard.entries[idx];
            e.out = out;
            e.round = round;
            e.owner = owner;
            return;
        }
        if shard.entries.len() >= cap {
            // seeded random replacement: the only PRNG draw in the store
            let victim = shard.rng.below(shard.entries.len() as u32) as usize;
            self.stats.evictions += 1;
            let old = entry_key(shared, &shard.entries[victim]);
            shard.map.remove(&old);
            shard.entries[victim] = Entry { sig, out, round, owner };
            shard.map.insert(key, victim);
            return;
        }
        shard.entries.push(Entry { sig, out, round, owner });
        shard.map.insert(key, shard.entries.len() - 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::{Jv, SensorFrame};
    use crate::vla::Backend;

    fn sig(q: f64) -> Signature {
        sig_fam(q, crate::vla::profile::ModelFamily::Surrogate)
    }

    fn sig_fam(q: f64, fam: crate::vla::profile::ModelFamily) -> Signature {
        let f = SensorFrame { step: 0, q: Jv::splat(q), dq: Jv::ZERO, tau: Jv::ZERO };
        Signature::of(&CacheConfig::default(), 1, &f, None, fam)
    }

    fn out(seed: u64) -> ModelOut {
        crate::vla::AnalyticBackend::cloud(seed).infer(
            &[0.1; crate::D_VIS],
            &[0.0; crate::D_PROP],
            1,
        )
    }

    #[test]
    fn probe_hit_miss_and_stats() {
        let mut s = ReuseStore::new(8, 10, true, 1);
        assert!(matches!(s.probe(&sig(0.1), 0, 0), ProbeOutcome::Miss));
        s.admit(sig(0.1), out(1), 0, 0);
        assert!(
            matches!(s.probe(&sig(0.1), 3, 5), ProbeOutcome::Hit(_)),
            "shared tier crosses owners"
        );
        assert!(matches!(s.probe(&sig(0.7), 3, 0), ProbeOutcome::Miss));
        assert_eq!(s.stats().probes, 3);
        assert_eq!(s.stats().hits, 1);
        assert_eq!(s.stats().misses, 2);
    }

    #[test]
    fn ttl_expires_and_drops_the_entry() {
        let mut s = ReuseStore::new(8, 10, true, 1);
        s.admit(sig(0.1), out(1), 0, 0);
        assert!(
            matches!(s.probe(&sig(0.1), 10, 0), ProbeOutcome::Hit(_)),
            "age == ttl still fresh"
        );
        assert!(matches!(s.probe(&sig(0.1), 11, 0), ProbeOutcome::Stale));
        assert_eq!(s.len(), 0, "stale entry dropped on discovery");
        assert!(matches!(s.probe(&sig(0.1), 11, 0), ProbeOutcome::Miss));
        assert_eq!(s.stats().stale, 1);
    }

    #[test]
    fn unshared_store_is_per_session() {
        let mut s = ReuseStore::new(8, 100, false, 1);
        s.admit(sig(0.1), out(1), 0, 3);
        assert!(matches!(s.probe(&sig(0.1), 1, 4), ProbeOutcome::Miss), "other session blocked");
        assert!(matches!(s.probe(&sig(0.1), 1, 3), ProbeOutcome::Hit(_)), "owner still hits");
    }

    #[test]
    fn capacity_bound_holds_under_eviction() {
        let mut s = ReuseStore::new(4, 1000, true, 7);
        for i in 0..50 {
            s.admit(sig(i as f64), out(i), i, 0);
            assert!(s.len() <= 4, "len {} at admit {i}", s.len());
        }
        assert_eq!(s.stats().evictions, 46);
        assert_eq!(s.stats().admissions, 50);
        // the map stays consistent: every surviving entry is probeable
        let mut live = 0;
        for i in 0..50 {
            if matches!(s.probe(&sig(i as f64), 1000, 0), ProbeOutcome::Hit(_)) {
                live += 1;
            }
        }
        assert_eq!(live, 4);
    }

    #[test]
    fn refresh_updates_round_without_growing() {
        let mut s = ReuseStore::new(4, 5, true, 1);
        s.admit(sig(0.1), out(1), 0, 0);
        s.admit(sig(0.1), out(2), 9, 1);
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats().refreshed, 1);
        assert!(matches!(s.probe(&sig(0.1), 12, 0), ProbeOutcome::Hit(_)), "refreshed TTL");
    }

    #[test]
    fn family_tagged_entries_never_cross_serve() {
        use crate::vla::profile::ModelFamily;
        // regression (PR 4 satellite): a shared store holding a chunk
        // produced by one model family must miss for every other family in
        // the identical kinematic state
        let mut s = ReuseStore::new(8, 100, true, 1);
        s.admit(sig_fam(0.1, ModelFamily::OpenVlaAr), out(1), 0, 0);
        assert!(matches!(
            s.probe(&sig_fam(0.1, ModelFamily::OpenVlaAr), 1, 5),
            ProbeOutcome::Hit(_)
        ));
        for fam in [ModelFamily::Surrogate, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            assert!(
                matches!(s.probe(&sig_fam(0.1, fam), 1, 5), ProbeOutcome::Miss),
                "{fam:?} cross-served another family's chunk"
            );
        }
    }

    #[test]
    fn eviction_replays_under_a_fixed_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let mut s = ReuseStore::new(3, 1000, true, seed);
            for i in 0..30 {
                s.admit(sig(i as f64), out(i), i, 0);
            }
            (0..30)
                .map(|i| matches!(s.probe(&sig(i as f64), 999, 0), ProbeOutcome::Hit(_)))
                .collect()
        };
        assert_eq!(run(42), run(42), "same seed, same survivors");
        assert_ne!(run(42), run(43), "eviction stream is seed-driven");
    }

    #[test]
    fn one_shard_store_is_the_single_map_store() {
        // with_shards(.., 1) must replay new() exactly — same eviction
        // stream (shard 0 keeps 0xCAC_4E), same survivors, same counters
        let survivors = |s: &mut ReuseStore| -> Vec<bool> {
            for i in 0..30 {
                s.admit(sig(i as f64), out(i), i, 0);
            }
            (0..30)
                .map(|i| matches!(s.probe(&sig(i as f64), 999, 0), ProbeOutcome::Hit(_)))
                .collect()
        };
        let mut a = ReuseStore::new(3, 1000, true, 42);
        let mut b = ReuseStore::with_shards(3, 1000, true, 42, 1);
        assert_eq!(survivors(&mut a), survivors(&mut b));
        assert_eq!(a.stats(), b.stats());
        assert_eq!(b.n_shards(), 1);
        assert_eq!(b.shard_capacity(), 3);
    }

    #[test]
    fn shard_count_rounds_to_power_of_two_and_respects_capacity() {
        let s = ReuseStore::with_shards(64, 10, true, 1, 3);
        assert_eq!(s.n_shards(), 4);
        assert_eq!(s.shard_capacity(), 16);
        // more shards than capacity: halved until every shard holds one
        let t = ReuseStore::with_shards(4, 10, true, 1, 64);
        assert_eq!(t.n_shards(), 4);
        assert_eq!(t.shard_capacity(), 1);
        assert!(t.n_shards() * t.shard_capacity() <= t.capacity());
    }

    #[test]
    fn sharded_capacity_bound_holds_in_total() {
        let mut s = ReuseStore::with_shards(8, 1000, true, 7, 4);
        for i in 0..100 {
            s.admit(sig(i as f64), out(i), i, 0);
            assert!(s.len() <= 8, "len {} at admit {i}", s.len());
        }
        // counters reconcile: every admission inserted or refreshed, and
        // every insert is either resident or was displaced by an eviction
        let st = *s.stats();
        assert_eq!(st.admissions, 100);
        assert_eq!(st.admissions - st.refreshed - st.evictions, s.len() as u64);
        // the index stays consistent: every surviving entry is probeable
        let resident = s.len();
        let mut live = 0;
        for i in 0..100 {
            if matches!(s.probe(&sig(i as f64), 1000, 0), ProbeOutcome::Hit(_)) {
                live += 1;
            }
        }
        assert_eq!(live, resident);
    }

    #[test]
    fn under_capacity_sharded_store_matches_single_map_outcomes() {
        // no shard ever fills (shard_cap >= distinct keys) → no draws →
        // shard routing is unobservable: every probe outcome and every
        // counter matches the single-map store exactly
        for shards in [1usize, 2, 4, 8] {
            let mut a = ReuseStore::new(512, 50, true, 9);
            let mut b = ReuseStore::with_shards(512, 50, true, 9, shards);
            for i in 0..40 {
                a.admit(sig(i as f64), out(i), i, 0);
                b.admit(sig(i as f64), out(i), i, 0);
            }
            for i in 0..40 {
                let hit_a = matches!(a.probe(&sig(i as f64), 45, 1), ProbeOutcome::Hit(_));
                let hit_b = matches!(b.probe(&sig(i as f64), 45, 1), ProbeOutcome::Hit(_));
                assert_eq!(hit_a, hit_b, "key {i} diverged at {shards} shards");
            }
            assert_eq!(a.stats(), b.stats(), "{shards} shards");
            assert_eq!(a.len(), b.len());
            assert_eq!(b.stats().evictions, 0, "under-capacity run must not evict");
        }
    }
}

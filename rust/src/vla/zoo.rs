//! The model zoo: family-shaped deterministic backends.
//!
//! A [`ZooBackend`] wraps the analytic surrogate of the right grade and
//! pushes every output through its family's
//! [`FamilyProfile::shape`](crate::vla::profile::FamilyProfile::shape)
//! transform. The [`ModelFamily::Surrogate`] wrapper is constructed to be
//! **bit-identical** to the bare [`AnalyticBackend`] of the same seed
//! (same label, same PRNG streams, identity shape), which is what lets
//! the differential conformance suite pin `[models] enabled` with only
//! the surrogate family against a zoo-free fleet.
//!
//! Non-surrogate families salt the seed so distinct families answer with
//! distinct (but per-family reproducible) model weights.

use crate::vla::profile::{FamilyProfile, ModelFamily};
use crate::vla::{AnalyticBackend, Backend, ModelOut};
use crate::{D_PROP, D_VIS};

/// Seed salt per family (0 for the surrogate: exact PR 0–3 streams).
fn salt(family: ModelFamily) -> u64 {
    match family {
        ModelFamily::Surrogate => 0,
        other => 0x200_u64.wrapping_mul(other.id() as u64) ^ 0xFA_517,
    }
}

pub struct ZooBackend {
    inner: AnalyticBackend,
    profile: FamilyProfile,
}

impl ZooBackend {
    /// Edge-grade member of `family`.
    pub fn edge(family: ModelFamily, seed: u64) -> ZooBackend {
        let inner = match family {
            ModelFamily::Surrogate => AnalyticBackend::edge(seed),
            other => AnalyticBackend::new(
                &format!("edge-{}-analytic", other.name()),
                seed ^ salt(other),
            ),
        };
        ZooBackend { inner, profile: FamilyProfile::of(family) }
    }

    /// Cloud-grade member of `family`.
    pub fn cloud(family: ModelFamily, seed: u64) -> ZooBackend {
        let inner = match family {
            ModelFamily::Surrogate => AnalyticBackend::cloud(seed),
            other => AnalyticBackend::new(
                &format!("cloud-{}-analytic", other.name()),
                (seed ^ salt(other)) ^ 0xC10,
            ),
        };
        ZooBackend { inner, profile: FamilyProfile::of(family) }
    }

    pub fn family(&self) -> ModelFamily {
        self.profile.family
    }
}

impl Backend for ZooBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn infer(&mut self, obs: &[f32; D_VIS], proprio: &[f32; D_PROP], instr: usize) -> ModelOut {
        self.profile.shape(self.inner.infer(obs, proprio, instr))
    }
}

/// Balanced contiguous-block assignment of `n_sessions` over `families`
/// (session i gets `families[i * len / n]`). Blocks — not round-robin —
/// so lockstep same-family sessions stay adjacent in scheduler order and
/// family-keyed batches still coalesce across sessions.
pub fn assign_families(families: &[ModelFamily], n_sessions: usize, session: usize) -> ModelFamily {
    if families.is_empty() {
        return ModelFamily::Surrogate;
    }
    let n = n_sessions.max(1);
    let i = session.min(n - 1);
    families[(i * families.len()) / n]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surrogate_zoo_backend_matches_bare_analytic_exactly() {
        let mut zoo = ZooBackend::cloud(ModelFamily::Surrogate, 7);
        let mut bare = AnalyticBackend::cloud(7);
        let obs = [0.25f32; D_VIS];
        for i in 0..4 {
            let a = zoo.infer(&obs, &[0.0; D_PROP], i);
            let b = bare.infer(&obs, &[0.0; D_PROP], i);
            assert_eq!(a.actions, b.actions, "call {i}");
            assert_eq!(a.mass, b.mass);
        }
        assert_eq!(zoo.name(), bare.name());
    }

    #[test]
    fn families_answer_with_distinct_weights() {
        let obs = [0.3f32; D_VIS];
        let a = ZooBackend::cloud(ModelFamily::OpenVlaAr, 7).infer(&obs, &[0.0; D_PROP], 1);
        let b = ZooBackend::cloud(ModelFamily::Pi0Diffusion, 7).infer(&obs, &[0.0; D_PROP], 1);
        assert_ne!(a.actions[0], b.actions[0], "family salt must separate weights");
        assert_eq!(a.actions.len(), 4, "AR family emits short chunks");
        assert_eq!(b.actions.len(), crate::CHUNK);
    }

    #[test]
    fn zoo_backend_replays_under_a_fixed_seed() {
        let run = || {
            ZooBackend::cloud(ModelFamily::EdgeQuant, 11)
                .infer(&[0.2; D_VIS], &[0.0; D_PROP], 2)
                .actions
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn block_assignment_is_balanced_and_contiguous() {
        use ModelFamily::*;
        let fams = [OpenVlaAr, Pi0Diffusion, EdgeQuant];
        let got: Vec<ModelFamily> = (0..8).map(|i| assign_families(&fams, 8, i)).collect();
        // contiguous blocks in catalog order
        for w in got.windows(2) {
            assert!(w[0] <= w[1], "non-contiguous: {got:?}");
        }
        for f in fams {
            let n = got.iter().filter(|&&g| g == f).count();
            assert!((2..=3).contains(&n), "unbalanced {f:?}: {got:?}");
        }
        assert_eq!(assign_families(&[], 8, 3), Surrogate);
    }
}

//! VLA model interface on the Rust side: model outputs, entropy, the
//! backend abstraction (PJRT-backed or analytic), and observation assembly.

pub mod attention;
pub mod backend;
pub mod chunk;
pub mod entropy;
pub mod obs;

pub use backend::{AnalyticBackend, Backend};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use chunk::ModelOut;
pub use entropy::shannon_entropy;

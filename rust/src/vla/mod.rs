//! VLA model interface on the Rust side: model outputs, entropy, the
//! backend abstraction (PJRT-backed or analytic), observation assembly,
//! and the heterogeneous model zoo (family profiles + shaped backends).

pub mod attention;
pub mod backend;
pub mod chunk;
pub mod entropy;
pub mod obs;
pub mod profile;
pub mod zoo;

pub use backend::{AnalyticBackend, Backend};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use chunk::ModelOut;
pub use entropy::shannon_entropy;
pub use profile::{FamilyProfile, ModelFamily, PartitionPoint, N_FAMILIES};
pub use zoo::{assign_families, ZooBackend};

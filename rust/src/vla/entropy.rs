//! Shannon entropy of an action-token logit row (nats) — mirrors
//! `python/compile/model.py::entropy` bit-for-bit in structure.

/// Numerically stable softmax entropy.
pub fn shannon_entropy(logits: &[f32]) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    if !max.is_finite() {
        return 0.0;
    }
    let mut z = 0.0f64;
    let mut ez_sum = 0.0f64;
    for &l in logits {
        let e = ((l as f64) - max).exp();
        ez_sum += e;
        z += e * ((l as f64) - max);
    }
    // H = log(sum e^z) - E[z]
    ez_sum.max(1e-300).ln() - z / ez_sum.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_log_n() {
        let logits = vec![0.0f32; 64];
        assert!((shannon_entropy(&logits) - (64f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn peaked_is_near_zero() {
        let mut logits = vec![0.0f32; 64];
        logits[3] = 50.0;
        assert!(shannon_entropy(&logits) < 1e-6);
    }

    #[test]
    fn scaling_decreases_entropy() {
        let base: Vec<f32> = (0..64).map(|i| ((i * 37) % 13) as f32 * 0.1).collect();
        let hot: Vec<f32> = base.iter().map(|x| x * 10.0).collect();
        assert!(shannon_entropy(&hot) < shannon_entropy(&base));
    }

    #[test]
    fn shift_invariant() {
        let a: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let b: Vec<f32> = a.iter().map(|x| x + 100.0).collect();
        assert!((shannon_entropy(&a) - shannon_entropy(&b)).abs() < 1e-6);
    }

    #[test]
    fn large_values_stable() {
        let logits = vec![1e30f32, -1e30, 0.0];
        let h = shannon_entropy(&logits);
        assert!(h.is_finite() && h >= 0.0);
    }
}

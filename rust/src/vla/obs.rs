//! Observation assembly: sensor frame -> proprio input vector.

use crate::robot::SensorFrame;
use crate::{D_PROP, N_JOINTS};

/// Pack (q, q̇, τ) into the model's proprio input layout.
pub fn proprio_vec(f: &SensorFrame) -> [f32; D_PROP] {
    let mut out = [0f32; D_PROP];
    for j in 0..N_JOINTS {
        out[j] = f.q[j] as f32;
        out[N_JOINTS + j] = f.dq[j] as f32;
        out[2 * N_JOINTS + j] = f.tau[j] as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::Jv;

    #[test]
    fn layout() {
        let f = SensorFrame { step: 0, q: Jv::splat(1.0), dq: Jv::splat(2.0), tau: Jv::splat(3.0) };
        let p = proprio_vec(&f);
        assert_eq!(p[0], 1.0);
        assert_eq!(p[N_JOINTS], 2.0);
        assert_eq!(p[2 * N_JOINTS], 3.0);
        assert_eq!(p.len(), D_PROP);
    }
}

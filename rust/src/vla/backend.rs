//! Model backend abstraction.
//!
//! * [`PjrtBackend`] — the real path: AOT-compiled HLO executed via PJRT.
//! * [`AnalyticBackend`] — a pure-Rust mirror of the surrogate's
//!   *constructed semantics* (same observation contract, same qualitative
//!   behaviours) used by unit/property tests and fast sweeps where the
//!   numeric model is not the object under test.

use crate::robot::Jv;
use crate::util::Pcg32;
use crate::vla::chunk::ModelOut;
use crate::{CHUNK, D_PROP, D_VIS, VOCAB};

pub trait Backend {
    fn name(&self) -> &str;

    /// One forward pass: obs (clarity-attenuated visual features), proprio,
    /// instruction index -> action chunk + side channels.
    fn infer(&mut self, obs: &[f32; D_VIS], proprio: &[f32; D_PROP], instr: usize) -> ModelOut;

    /// Mean measured wall-clock per call (µs), if tracked.
    fn mean_us(&self) -> f64 {
        0.0
    }
}

/// PJRT-backed inference (the production path; `pjrt` feature).
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    pub exe: crate::runtime::PolicyExecutable,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(exe: crate::runtime::PolicyExecutable) -> Self {
        PjrtBackend { exe }
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.exe.variant
    }

    fn infer(&mut self, obs: &[f32; D_VIS], proprio: &[f32; D_PROP], instr: usize) -> ModelOut {
        self.exe.infer(obs, proprio, instr).expect("pjrt inference failed")
    }

    fn mean_us(&self) -> f64 {
        self.exe.mean_us()
    }
}

/// Analytic mirror of the constructed surrogate (model.py docstring §1–3):
/// actions track the joint-error channels, logit sharpness scales with
/// observation signal magnitude, attention mass follows the routed
/// saliency horizon.
pub struct AnalyticBackend {
    label: String,
    /// Fixed random logit directions (per vocab entry), seeded.
    logit_dirs: Vec<[f32; VOCAB]>,
    act_gain: f64,
    logit_gain: f64,
    mass_gain: f64,
    mass_shift: f64,
    noise: Pcg32,
    noise_scale: f64,
}

impl AnalyticBackend {
    pub fn new(label: &str, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0xAB);
        let mut dirs = Vec::with_capacity(CHUNK);
        for _ in 0..CHUNK {
            let mut row = [0f32; VOCAB];
            for r in row.iter_mut() {
                *r = rng.normal() as f32;
            }
            dirs.push(row);
        }
        let cloudish = label.contains("cloud");
        AnalyticBackend {
            label: label.to_string(),
            logit_dirs: dirs,
            act_gain: if cloudish { 1.2 } else { 0.9 },
            logit_gain: if cloudish { 3.4 } else { 2.8 },
            mass_gain: 9.0,
            mass_shift: 3.5,
            noise: rng.fork(7),
            noise_scale: if cloudish { 0.02 } else { 0.05 },
        }
    }

    pub fn edge(seed: u64) -> Self {
        Self::new("edge-analytic", seed)
    }

    pub fn cloud(seed: u64) -> Self {
        Self::new("cloud-analytic", seed ^ 0xC10)
    }
}

impl Backend for AnalyticBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn infer(&mut self, obs: &[f32; D_VIS], _proprio: &[f32; D_PROP], instr: usize) -> ModelOut {
        // visual confidence signal: semantic content + persistent scene
        // texture energy (normalized to its clean-scene expectation) —
        // mirrors what the constructed PJRT surrogate's attention routes
        // into the logit path
        let sem: f64 = obs[..16].iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let tex: f64 = obs[16..].iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let tex_clean = crate::scene::renderer::SCENE_TEXTURE_STD * ((D_VIS - 16) as f64).sqrt();
        let sig = 0.5 * sem + 1.0 * (tex / tex_clean).min(1.5);
        let mut actions = Vec::with_capacity(CHUNK);
        let mut logits = Vec::with_capacity(CHUNK);
        let mut mass = Vec::with_capacity(CHUNK);
        for i in 0..CHUNK {
            // actions: routed joint error + small model noise
            actions.push(Jv::from_fn(|j| {
                (self.act_gain * obs[j] as f64 + self.noise_scale * self.noise.normal()).tanh()
            }));
            // logits: fixed random direction scaled by signal magnitude
            let mut row = [0f32; VOCAB];
            let sharp = (self.logit_gain * sig) as f32;
            for (v, d) in row.iter_mut().zip(self.logit_dirs[i].iter()) {
                *v = sharp * d + 0.03 * (instr as f32 + 1.0) * d.signum();
            }
            logits.push(row);
            // mass: softplus of routed saliency-horizon slot (same mapping
            // the constructed PJRT surrogate realizes: softplus(g·sal − c))
            let sal = obs[7 + i] as f64;
            let x = self.mass_gain * sal - self.mass_shift;
            mass.push((1.0 + x.exp()).ln());
        }
        ModelOut { actions, logits, mass }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::N_JOINTS;

    fn obs_with(err: f64, sal: f64, clarity: f64) -> [f32; D_VIS] {
        let mut o = [0f32; D_VIS];
        for j in 0..N_JOINTS {
            o[j] = err as f32;
        }
        for i in 0..CHUNK {
            o[7 + i] = sal as f32;
        }
        o[15] = sal as f32;
        for v in o.iter_mut().skip(16) {
            *v = 0.3;
        }
        for v in o.iter_mut() {
            *v *= clarity as f32;
        }
        o
    }

    #[test]
    fn mirrors_entropy_behaviour() {
        let mut b = AnalyticBackend::cloud(1);
        let clean = b.infer(&obs_with(0.3, 0.1, 1.0), &[0.0; D_PROP], 1);
        let noisy = b.infer(&obs_with(0.3, 0.1, 0.2), &[0.0; D_PROP], 1);
        assert!(noisy.mean_entropy() > clean.mean_entropy() + 0.3);
    }

    #[test]
    fn mirrors_mass_behaviour() {
        let mut b = AnalyticBackend::cloud(2);
        let calm = b.infer(&obs_with(0.3, 0.05, 1.0), &[0.0; D_PROP], 1);
        let crit = b.infer(&obs_with(0.1, 0.9, 1.0), &[0.0; D_PROP], 1);
        let m = |o: &ModelOut| o.mass.iter().sum::<f64>() / CHUNK as f64;
        assert!(m(&crit) > 3.0 * m(&calm));
    }

    #[test]
    fn mirrors_action_tracking() {
        let mut b = AnalyticBackend::edge(3);
        let out = b.infer(&obs_with(0.4, 0.1, 1.0), &[0.0; D_PROP], 1);
        let mean_a: f64 = out.actions.iter().map(|a| a[0]).sum::<f64>() / CHUNK as f64;
        assert!(mean_a > 0.15, "mean action {mean_a}");
        let out_neg = b.infer(&obs_with(-0.4, 0.1, 1.0), &[0.0; D_PROP], 1);
        let mean_n: f64 = out_neg.actions.iter().map(|a| a[0]).sum::<f64>() / CHUNK as f64;
        assert!(mean_n < -0.15);
    }
}

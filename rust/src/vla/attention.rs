//! Episode-level attention-redundancy analysis (paper Table II / §III-B).
//!
//! Per-step attention masses are normalized over the episode; steps with
//! normalized weight below the uniform baseline 1/L are classified as
//! redundant, matching the paper's criterion.

/// Redundancy statistics for one episode-long attention-mass series.
#[derive(Debug, Clone, Copy)]
pub struct RedundancyStats {
    /// Sequence length L.
    pub len: usize,
    /// Uniform baseline 1/L.
    pub uniform: f64,
    /// Proportion of redundant actions (weight < 1/L).
    pub p_red: f64,
    /// Proportion of critical actions (weight ≥ 1/L).
    pub p_crit: f64,
    /// Mean normalized weight of redundant actions.
    pub w_red: f64,
    /// Mean normalized weight of critical actions.
    pub w_crit: f64,
}

/// Normalize a raw attention-mass series to sum 1 and compute Table II
/// statistics. Returns None for empty/degenerate input.
pub fn redundancy_stats(mass: &[f64]) -> Option<RedundancyStats> {
    let n = mass.len();
    if n == 0 {
        return None;
    }
    let total: f64 = mass.iter().sum();
    if !(total.is_finite()) || total <= 0.0 {
        return None;
    }
    let uniform = 1.0 / n as f64;
    let weights: Vec<f64> = mass.iter().map(|m| m / total).collect();
    let (mut red, mut crit) = (Vec::new(), Vec::new());
    for w in weights {
        if w < uniform {
            red.push(w);
        } else {
            crit.push(w);
        }
    }
    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    Some(RedundancyStats {
        len: n,
        uniform,
        p_red: red.len() as f64 / n as f64,
        p_crit: crit.len() as f64 / n as f64,
        w_red: mean(&red),
        w_crit: mean(&crit),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_series_all_critical() {
        // equal weights sit exactly at 1/L => classified critical (>=)
        let s = redundancy_stats(&vec![1.0; 10]).unwrap();
        assert_eq!(s.p_crit, 1.0);
        assert_eq!(s.p_red, 0.0);
    }

    #[test]
    fn peaked_series_mostly_redundant() {
        let mut mass = vec![0.01; 50];
        for m in mass.iter_mut().take(50).skip(41) {
            *m = 1.0;
        }
        let s = redundancy_stats(&mass).unwrap();
        assert!(s.p_red > 0.8, "p_red {}", s.p_red);
        assert!(s.w_crit > 5.0 * s.w_red, "w_crit {} w_red {}", s.w_crit, s.w_red);
        assert_eq!(s.len, 50);
        assert!((s.uniform - 0.02).abs() < 1e-12);
    }

    #[test]
    fn partitions_are_exhaustive() {
        let mass: Vec<f64> = (1..=37).map(|i| i as f64).collect();
        let s = redundancy_stats(&mass).unwrap();
        assert!((s.p_red + s.p_crit - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(redundancy_stats(&[]).is_none());
        assert!(redundancy_stats(&[0.0, 0.0]).is_none());
        assert!(redundancy_stats(&[f64::NAN, 1.0]).is_none());
    }
}

//! Model output container: an action chunk plus the per-token side
//! channels (logits for entropy, attention mass for redundancy).

use crate::robot::Jv;
use crate::vla::entropy::shannon_entropy;
use crate::{CHUNK, N_JOINTS, VOCAB};

#[derive(Debug, Clone)]
pub struct ModelOut {
    /// Action chunk: k normalized joint-velocity commands.
    pub actions: Vec<Jv>,
    /// Per-token action logits [k][V].
    pub logits: Vec<[f32; VOCAB]>,
    /// Per-token attention mass (redundancy instrumentation).
    pub mass: Vec<f64>,
}

impl ModelOut {
    /// Assemble from the flat buffers the PJRT tuple returns.
    pub fn from_flat(actions: &[f32], logits: &[f32], mass: &[f32]) -> ModelOut {
        Self::from_flat_k(CHUNK, actions, logits, mass)
    }

    /// [`ModelOut::from_flat`] for a chunk of `k` actions — model-zoo
    /// families emit chunks shorter than [`CHUNK`] (the zoo wire frames
    /// carry `k` explicitly).
    pub fn from_flat_k(k: usize, actions: &[f32], logits: &[f32], mass: &[f32]) -> ModelOut {
        assert!(k >= 1 && k <= CHUNK, "chunk length {k}");
        assert_eq!(actions.len(), k * N_JOINTS);
        assert_eq!(logits.len(), k * VOCAB);
        assert_eq!(mass.len(), k);
        let acts = (0..k)
            .map(|i| Jv::from_fn(|j| actions[i * N_JOINTS + j] as f64))
            .collect();
        let lgs = (0..k)
            .map(|i| {
                let mut row = [0f32; VOCAB];
                row.copy_from_slice(&logits[i * VOCAB..(i + 1) * VOCAB]);
                row
            })
            .collect();
        ModelOut { actions: acts, logits: lgs, mass: mass.iter().map(|&m| m as f64).collect() }
    }

    /// Actions in this chunk (= [`CHUNK`] for the default surrogate,
    /// shorter for short-chunk zoo families).
    pub fn chunk_len(&self) -> usize {
        self.actions.len()
    }

    /// Shannon entropy (nats) of action token i's distribution — the
    /// vision baseline's offloading signal.
    pub fn entropy(&self, i: usize) -> f64 {
        shannon_entropy(&self.logits[i.min(self.logits.len().saturating_sub(1))])
    }

    /// Mean entropy over the chunk.
    pub fn mean_entropy(&self) -> f64 {
        let k = self.logits.len().max(1);
        (0..k).map(|i| self.entropy(i)).sum::<f64>() / k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_flat_roundtrip() {
        let actions: Vec<f32> = (0..CHUNK * N_JOINTS).map(|i| i as f32 * 0.01).collect();
        let logits: Vec<f32> = (0..CHUNK * VOCAB).map(|i| (i % 7) as f32).collect();
        let mass: Vec<f32> = (0..CHUNK).map(|i| i as f32).collect();
        let out = ModelOut::from_flat(&actions, &logits, &mass);
        assert_eq!(out.actions.len(), CHUNK);
        assert!((out.actions[1][2] - (1 * N_JOINTS + 2) as f64 * 0.01).abs() < 1e-6);
        assert_eq!(out.mass[3], 3.0);
        assert!(out.entropy(0) > 0.0);
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        ModelOut::from_flat(&[0.0; 3], &[0.0; CHUNK * VOCAB], &[0.0; CHUNK]);
    }

    #[test]
    fn from_flat_k_builds_short_chunks() {
        let k = 4;
        let actions: Vec<f32> = (0..k * N_JOINTS).map(|i| i as f32 * 0.01).collect();
        let logits: Vec<f32> = (0..k * VOCAB).map(|i| (i % 5) as f32).collect();
        let mass: Vec<f32> = (0..k).map(|i| i as f32).collect();
        let out = ModelOut::from_flat_k(k, &actions, &logits, &mass);
        assert_eq!(out.chunk_len(), k);
        assert!(out.entropy(k + 3) > 0.0, "entropy index clamps to the short chunk");
        assert!(out.mean_entropy().is_finite());
    }

    #[test]
    #[should_panic]
    fn from_flat_k_rejects_oversize_chunks() {
        let k = CHUNK + 1;
        ModelOut::from_flat_k(
            k,
            &vec![0.0; k * N_JOINTS],
            &vec![0.0; k * VOCAB],
            &vec![0.0; k],
        );
    }
}

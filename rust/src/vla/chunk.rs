//! Model output container: an action chunk plus the per-token side
//! channels (logits for entropy, attention mass for redundancy).

use crate::robot::Jv;
use crate::vla::entropy::shannon_entropy;
use crate::{CHUNK, N_JOINTS, VOCAB};

#[derive(Debug, Clone)]
pub struct ModelOut {
    /// Action chunk: k normalized joint-velocity commands.
    pub actions: Vec<Jv>,
    /// Per-token action logits [k][V].
    pub logits: Vec<[f32; VOCAB]>,
    /// Per-token attention mass (redundancy instrumentation).
    pub mass: Vec<f64>,
}

impl ModelOut {
    /// Assemble from the flat buffers the PJRT tuple returns.
    pub fn from_flat(actions: &[f32], logits: &[f32], mass: &[f32]) -> ModelOut {
        assert_eq!(actions.len(), CHUNK * N_JOINTS);
        assert_eq!(logits.len(), CHUNK * VOCAB);
        assert_eq!(mass.len(), CHUNK);
        let acts = (0..CHUNK)
            .map(|i| Jv::from_fn(|j| actions[i * N_JOINTS + j] as f64))
            .collect();
        let lgs = (0..CHUNK)
            .map(|i| {
                let mut row = [0f32; VOCAB];
                row.copy_from_slice(&logits[i * VOCAB..(i + 1) * VOCAB]);
                row
            })
            .collect();
        ModelOut { actions: acts, logits: lgs, mass: mass.iter().map(|&m| m as f64).collect() }
    }

    /// Shannon entropy (nats) of action token i's distribution — the
    /// vision baseline's offloading signal.
    pub fn entropy(&self, i: usize) -> f64 {
        shannon_entropy(&self.logits[i.min(CHUNK - 1)])
    }

    /// Mean entropy over the chunk.
    pub fn mean_entropy(&self) -> f64 {
        (0..CHUNK).map(|i| self.entropy(i)).sum::<f64>() / CHUNK as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_flat_roundtrip() {
        let actions: Vec<f32> = (0..CHUNK * N_JOINTS).map(|i| i as f32 * 0.01).collect();
        let logits: Vec<f32> = (0..CHUNK * VOCAB).map(|i| (i % 7) as f32).collect();
        let mass: Vec<f32> = (0..CHUNK).map(|i| i as f32).collect();
        let out = ModelOut::from_flat(&actions, &logits, &mass);
        assert_eq!(out.actions.len(), CHUNK);
        assert!((out.actions[1][2] - (1 * N_JOINTS + 2) as f64 * 0.01).abs() < 1e-6);
        assert_eq!(out.mass[3], 3.0);
        assert!(out.entropy(0) > 0.0);
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        ModelOut::from_flat(&[0.0; 3], &[0.0; CHUNK * VOCAB], &[0.0; CHUNK]);
    }
}

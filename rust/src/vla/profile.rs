//! Heterogeneous VLA model-family profiles — the "diverse VLA models"
//! axis of the paper's title.
//!
//! A [`ModelFamily`] names an architecture class with its own inference
//! economics; a [`FamilyProfile`] is the deterministic catalog entry the
//! serve layer consumes: chunk shape, device-time scaling, an accuracy
//! transform, and a **partition-point catalog** — the split depths this
//! family supports, each with its edge-prefix compute cost, wire payload
//! and cloud compute time. The compatibility-aware planner
//! (`policy::planner`) picks one point per (family, link condition); the
//! fleet scheduler keys its cross-session batches on the family so no
//! wire batch ever mixes frame layouts.
//!
//! Everything here is a pure function of the family id — no PRNG, no
//! config — so edge and cloud (local backends and the remote TCP server)
//! agree on family semantics by construction.

use crate::robot::Jv;
use crate::vla::ModelOut;
use crate::CHUNK;

/// Number of families (wire ids 0..N_FAMILIES).
pub const N_FAMILIES: usize = 4;

/// An architecture class served by the zoo. Ids are stable wire tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelFamily {
    /// The original analytic surrogate (PR 0–3 behaviour); id 0. A fleet
    /// with `[models]` disabled is entirely this family.
    Surrogate,
    /// Autoregressive OpenVLA-style: short action chunks decoded token by
    /// token — cheap to ship, expensive per cloud call.
    OpenVlaAr,
    /// π0-style chunked diffusion: full-length chunks from an iterative
    /// denoiser — heavy activations, cloud time amortized over the chunk.
    Pi0Diffusion,
    /// Edge-compressed quantized variant: degraded action precision in
    /// exchange for a much cheaper edge-resident slice.
    EdgeQuant,
}

impl Default for ModelFamily {
    fn default() -> Self {
        ModelFamily::Surrogate
    }
}

impl ModelFamily {
    pub const ALL: [ModelFamily; N_FAMILIES] = [
        ModelFamily::Surrogate,
        ModelFamily::OpenVlaAr,
        ModelFamily::Pi0Diffusion,
        ModelFamily::EdgeQuant,
    ];

    /// Stable wire id (the family tag on zoo batch frames).
    pub fn id(&self) -> u8 {
        match self {
            ModelFamily::Surrogate => 0,
            ModelFamily::OpenVlaAr => 1,
            ModelFamily::Pi0Diffusion => 2,
            ModelFamily::EdgeQuant => 3,
        }
    }

    pub fn from_id(id: u8) -> Option<ModelFamily> {
        Self::ALL.get(id as usize).copied()
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelFamily::Surrogate => "surrogate",
            ModelFamily::OpenVlaAr => "openvla-ar",
            ModelFamily::Pi0Diffusion => "pi0-diffusion",
            ModelFamily::EdgeQuant => "edge-quant",
        }
    }

    pub fn parse(s: &str) -> Option<ModelFamily> {
        match s.trim().to_ascii_lowercase().as_str() {
            "surrogate" | "default" => Some(ModelFamily::Surrogate),
            "openvla" | "openvla-ar" | "openvla_ar" | "ar" => Some(ModelFamily::OpenVlaAr),
            "pi0" | "pi0-diffusion" | "pi0_diffusion" | "diffusion" => {
                Some(ModelFamily::Pi0Diffusion)
            }
            "edgequant" | "edge-quant" | "edge_quant" | "quant" => Some(ModelFamily::EdgeQuant),
            _ => None,
        }
    }
}

/// One supported split depth of a family: how much of the model the edge
/// runs before shipping, what crosses the wire, and what the cloud pays.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionPoint {
    /// Parameter GB resident on the edge at this split (reporting only —
    /// strategies keep their own load accounting).
    pub edge_gb: f64,
    /// Edge compute spent producing the split-point activations before an
    /// offload can leave the device (ms, device-nominal).
    pub edge_prefix_ms: f64,
    /// Offload payload at this split (bytes).
    pub payload_bytes: f64,
    /// Cloud compute per offload at this split (ms, device-nominal).
    pub cloud_compute_ms: f64,
}

/// Deterministic per-family serving profile.
#[derive(Debug, Clone, PartialEq)]
pub struct FamilyProfile {
    pub family: ModelFamily,
    /// Actions emitted per inference (≤ [`CHUNK`]); short chunks mean more
    /// frequent refills.
    pub chunk_len: usize,
    /// Multiplier on edge-slice inference time (the quantized family's
    /// whole reason to exist).
    pub edge_ms_scale: f64,
    /// Action quantization step (0 = none): the accuracy the compressed
    /// family trades away, applied identically on edge and cloud.
    pub action_quant: f64,
    /// Supported split depths, shallow (big payload, no prefix) to deep
    /// (small payload, edge prefix compute). Never empty.
    pub partitions: Vec<PartitionPoint>,
}

impl FamilyProfile {
    /// The catalog entry for a family. Values are calibrated against the
    /// default `[devices]`/`[link]` anchors (90 ms cloud compute, 1.5 MB
    /// observation payload) so the surrogate row is an exact no-op.
    pub fn of(family: ModelFamily) -> FamilyProfile {
        match family {
            ModelFamily::Surrogate => FamilyProfile {
                family,
                chunk_len: CHUNK,
                edge_ms_scale: 1.0,
                action_quant: 0.0,
                partitions: vec![PartitionPoint {
                    edge_gb: 2.4,
                    edge_prefix_ms: 0.0,
                    payload_bytes: 1.5e6,
                    cloud_compute_ms: 90.0,
                }],
            },
            ModelFamily::OpenVlaAr => FamilyProfile {
                family,
                chunk_len: 4,
                edge_ms_scale: 1.0,
                action_quant: 0.0,
                partitions: vec![
                    PartitionPoint {
                        edge_gb: 2.4,
                        edge_prefix_ms: 0.0,
                        payload_bytes: 1.5e6,
                        cloud_compute_ms: 190.0,
                    },
                    PartitionPoint {
                        edge_gb: 3.4,
                        edge_prefix_ms: 28.0,
                        payload_bytes: 0.5e6,
                        cloud_compute_ms: 175.0,
                    },
                    PartitionPoint {
                        edge_gb: 4.8,
                        edge_prefix_ms: 65.0,
                        payload_bytes: 0.15e6,
                        cloud_compute_ms: 160.0,
                    },
                ],
            },
            ModelFamily::Pi0Diffusion => FamilyProfile {
                family,
                chunk_len: CHUNK,
                edge_ms_scale: 1.1,
                action_quant: 0.0,
                partitions: vec![
                    PartitionPoint {
                        edge_gb: 2.4,
                        edge_prefix_ms: 0.0,
                        payload_bytes: 2.5e6,
                        cloud_compute_ms: 165.0,
                    },
                    PartitionPoint {
                        edge_gb: 4.0,
                        edge_prefix_ms: 40.0,
                        payload_bytes: 1.0e6,
                        cloud_compute_ms: 150.0,
                    },
                    PartitionPoint {
                        edge_gb: 5.6,
                        edge_prefix_ms: 85.0,
                        payload_bytes: 0.4e6,
                        cloud_compute_ms: 140.0,
                    },
                ],
            },
            ModelFamily::EdgeQuant => FamilyProfile {
                family,
                chunk_len: CHUNK,
                edge_ms_scale: 0.45,
                action_quant: 1.0 / 64.0,
                partitions: vec![
                    PartitionPoint {
                        edge_gb: 1.2,
                        edge_prefix_ms: 0.0,
                        payload_bytes: 0.8e6,
                        cloud_compute_ms: 115.0,
                    },
                    PartitionPoint {
                        edge_gb: 1.8,
                        edge_prefix_ms: 10.0,
                        payload_bytes: 0.3e6,
                        cloud_compute_ms: 112.0,
                    },
                    PartitionPoint {
                        edge_gb: 2.4,
                        edge_prefix_ms: 22.0,
                        payload_bytes: 0.1e6,
                        cloud_compute_ms: 102.0,
                    },
                ],
            },
        }
    }

    /// Shape a raw model output into this family's frame layout: truncate
    /// to the family chunk length and apply the quantization grid. Pure
    /// and deterministic — the TCP server applies the identical transform,
    /// so local and remote zoo fleets agree on semantics.
    pub fn shape(&self, mut out: ModelOut) -> ModelOut {
        let k = self.chunk_len.clamp(1, CHUNK);
        out.actions.truncate(k);
        out.logits.truncate(k);
        out.mass.truncate(k);
        if self.action_quant > 0.0 {
            let step = self.action_quant;
            for a in out.actions.iter_mut() {
                *a = Jv::from_fn(|j| (a[j] / step).round() * step);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vla::{AnalyticBackend, Backend};
    use crate::{D_PROP, D_VIS};

    #[test]
    fn ids_roundtrip_and_names_parse() {
        for fam in ModelFamily::ALL {
            assert_eq!(ModelFamily::from_id(fam.id()), Some(fam));
            assert_eq!(ModelFamily::parse(fam.name()), Some(fam));
        }
        assert_eq!(ModelFamily::from_id(200), None);
        assert_eq!(ModelFamily::parse("nope"), None);
        assert_eq!(ModelFamily::parse("openvla"), Some(ModelFamily::OpenVlaAr));
    }

    #[test]
    fn catalogs_are_well_formed() {
        for fam in ModelFamily::ALL {
            let p = FamilyProfile::of(fam);
            assert!(!p.partitions.is_empty(), "{fam:?}");
            assert!(p.chunk_len >= 1 && p.chunk_len <= CHUNK, "{fam:?}");
            assert!(p.edge_ms_scale > 0.0);
            // shallow -> deep: payload shrinks, prefix grows
            for w in p.partitions.windows(2) {
                assert!(w[1].payload_bytes < w[0].payload_bytes, "{fam:?}");
                assert!(w[1].edge_prefix_ms > w[0].edge_prefix_ms, "{fam:?}");
                assert!(w[1].edge_gb > w[0].edge_gb, "{fam:?}");
            }
        }
    }

    #[test]
    fn surrogate_shape_is_identity() {
        let mut b = AnalyticBackend::cloud(3);
        let out = b.infer(&[0.2; D_VIS], &[0.0; D_PROP], 1);
        let shaped = FamilyProfile::of(ModelFamily::Surrogate).shape(out.clone());
        assert_eq!(shaped.actions, out.actions);
        assert_eq!(shaped.mass, out.mass);
        assert_eq!(shaped.actions.len(), CHUNK);
    }

    #[test]
    fn ar_family_truncates_to_short_chunks() {
        let mut b = AnalyticBackend::cloud(3);
        let out = b.infer(&[0.2; D_VIS], &[0.0; D_PROP], 1);
        let shaped = FamilyProfile::of(ModelFamily::OpenVlaAr).shape(out);
        assert_eq!(shaped.actions.len(), 4);
        assert_eq!(shaped.logits.len(), 4);
        assert_eq!(shaped.mass.len(), 4);
    }

    #[test]
    fn quant_family_snaps_actions_to_the_grid() {
        let mut b = AnalyticBackend::cloud(3);
        let out = b.infer(&[0.2; D_VIS], &[0.0; D_PROP], 1);
        let p = FamilyProfile::of(ModelFamily::EdgeQuant);
        let shaped = p.shape(out.clone());
        for (a, raw) in shaped.actions.iter().zip(out.actions.iter()) {
            for j in 0..crate::N_JOINTS {
                let grid = a[j] / p.action_quant;
                assert!((grid - grid.round()).abs() < 1e-9, "off-grid action");
                assert!((a[j] - raw[j]).abs() <= p.action_quant / 2.0 + 1e-12);
            }
        }
    }
}

//! Per-episode accounting. The accounting identity (DESIGN.md §7.7):
//!
//! total latency contribution = cloud-side + edge-side + routing overhead,
//!
//! where the side columns are *amortized per consumed action chunk*
//! (steps / k). This is what makes wasted work visible: a policy that
//! floods the cloud with chunks it then discards (the vision baseline
//! under noise) pays for every generation but only consumes a few — its
//! per-chunk latency explodes, exactly the behaviour the paper's Tab. I
//! rows show (395 → 520 → 685 ms at constant load). Edge-Only/Cloud-Only
//! generate exactly one chunk per chunk consumed, so their columns equal
//! the per-inference service time, matching the paper's anchors.

use crate::config::PolicyKind;
use crate::robot::TaskKind;

#[derive(Debug, Clone)]
pub struct EpisodeMetrics {
    pub task: TaskKind,
    pub policy: PolicyKind,
    pub steps: usize,

    // --- emulated testbed time (ms) ---
    pub edge_busy_ms: f64,
    pub cloud_busy_ms: f64,
    /// Routing/communication overhead: vision preprocessing, split
    /// re-partitions, retransmission time, dispatcher CPU.
    pub overhead_ms: f64,

    // --- events ---
    pub edge_events: u64,
    pub cloud_events: u64,
    pub preemptions: u64,
    pub discarded_actions: u64,
    pub retransmissions: u64,
    pub repartitions: u64,
    /// Offloads the fleet scheduler refused under backpressure (the
    /// session fell back to its edge slice); always 0 single-session.
    pub deferred_offloads: u64,
    /// Offloads whose reply was lost (dropped/timed out/endpoint dead):
    /// the session timed out and re-served the step from its edge slice
    /// (`EpisodeState::fail_cloud`); always 0 without fault injection.
    pub failovers: u64,
    /// Cloud dispatches served from the reuse cache at probe latency
    /// instead of the wire; always 0 with the cache disabled.
    pub cache_hits: u64,
    /// Reuse probes that found no fresh matching entry (the dispatch went
    /// to the cloud as usual).
    pub cache_misses: u64,
    /// Subset of misses where a matching entry existed but had aged past
    /// `cache.ttl_rounds` (the staleness half of the divergence budget).
    pub cache_stale: u64,
    /// Offloads that dispatched speculatively (`[pipeline].speculate`):
    /// the edge kept stepping on a provisional chunk while the cloud
    /// round trip was in flight; always 0 with the pipeline disabled.
    pub spec_dispatches: u64,
    /// Speculative dispatches whose cloud reply confirmed the consumed
    /// provisional prefix within `pipeline.accept_eps` (free).
    pub spec_confirms: u64,
    /// Speculative dispatches the cloud reply corrected (`rollback_ms`
    /// re-charged to the session clock and overhead column).
    pub spec_rollbacks: u64,
    /// Offload triggers suppressed because a speculative cloud request
    /// was already in flight for this session.
    pub spec_suppressed: u64,

    // --- pipeline overlap (ms) ---
    /// Edge-prefix compute hidden under in-flight cloud round trips by
    /// `[pipeline].overlap` (already subtracted from `edge_busy_ms`);
    /// always 0 with the pipeline disabled.
    pub overlap_hidden_ms: f64,

    // --- loads (GB), time-averaged over the episode ---
    pub edge_gb: f64,
    pub cloud_gb: f64,

    // --- trigger quality vs ground-truth critical phases ---
    pub trig_tp: u64,
    pub trig_fp: u64,
    pub crit_steps: u64,

    // --- task outcome ---
    pub rms_error: f64,
    pub success: bool,

    // --- real measured wall clock (µs) for the §Perf record ---
    pub measured_edge_us: f64,
    pub measured_cloud_us: f64,
    pub dispatcher_cpu_ns: u64,
}

impl EpisodeMetrics {
    pub fn new(task: TaskKind, policy: PolicyKind) -> Self {
        EpisodeMetrics {
            task,
            policy,
            steps: 0,
            edge_busy_ms: 0.0,
            cloud_busy_ms: 0.0,
            overhead_ms: 0.0,
            edge_events: 0,
            cloud_events: 0,
            preemptions: 0,
            discarded_actions: 0,
            retransmissions: 0,
            repartitions: 0,
            deferred_offloads: 0,
            failovers: 0,
            cache_hits: 0,
            cache_misses: 0,
            cache_stale: 0,
            spec_dispatches: 0,
            spec_confirms: 0,
            spec_rollbacks: 0,
            spec_suppressed: 0,
            overlap_hidden_ms: 0.0,
            edge_gb: 0.0,
            cloud_gb: 0.0,
            trig_tp: 0,
            trig_fp: 0,
            crit_steps: 0,
            rms_error: 0.0,
            success: false,
            measured_edge_us: 0.0,
            measured_cloud_us: 0.0,
            dispatcher_cpu_ns: 0,
        }
    }

    pub fn events(&self) -> u64 {
        self.edge_events + self.cloud_events
    }

    /// Chunks actually consumed by the control loop.
    pub fn chunks_consumed(&self) -> u64 {
        ((self.steps + crate::CHUNK - 1) / crate::CHUNK).max(1) as u64
    }

    /// Amortized per-consumed-chunk latency columns (cloud, edge, total).
    pub fn latency_columns(&self) -> (f64, f64, f64) {
        let n = self.chunks_consumed() as f64;
        let cloud = self.cloud_busy_ms / n;
        let edge = self.edge_busy_ms / n;
        let total = cloud + edge + self.overhead_ms / n;
        (cloud, edge, total)
    }

    /// Trigger precision: TP / (TP + FP).
    pub fn trigger_precision(&self) -> f64 {
        let denom = self.trig_tp + self.trig_fp;
        if denom == 0 {
            return 1.0;
        }
        self.trig_tp as f64 / denom as f64
    }

    /// Accounting identity check (invariant #7).
    pub fn identity_holds(&self, total_gb: f64) -> bool {
        let (c, e, t) = self.latency_columns();
        let sums = (c + e + self.overhead_ms / self.chunks_consumed() as f64 - t).abs() < 1e-9;
        let loads = (self.edge_gb + self.cloud_gb - total_gb).abs() < 1e-6;
        sums && loads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> EpisodeMetrics {
        let mut m = EpisodeMetrics::new(TaskKind::PickPlace, PolicyKind::Rapid);
        m.steps = 48; // 6 consumed chunks at k = 8
        m.edge_busy_ms = 800.0;
        m.cloud_busy_ms = 400.0;
        m.overhead_ms = 60.0;
        m.edge_events = 4;
        m.cloud_events = 2;
        m.edge_gb = 2.4;
        m.cloud_gb = 11.8;
        m
    }

    #[test]
    fn columns_amortize_per_consumed_chunk() {
        let m = m();
        assert_eq!(m.chunks_consumed(), 6);
        let (c, e, t) = m.latency_columns();
        assert!((c - 400.0 / 6.0).abs() < 1e-9);
        assert!((e - 800.0 / 6.0).abs() < 1e-9);
        assert!((t - (c + e + 10.0)).abs() < 1e-9);
    }

    #[test]
    fn wasted_generations_inflate_per_chunk_latency() {
        // same busy time, fewer consumed chunks => higher per-chunk cost
        let mut flood = m();
        flood.steps = 16; // only 2 chunks consumed for the same work
        assert!(flood.latency_columns().2 > m().latency_columns().2);
    }

    #[test]
    fn identity() {
        assert!(m().identity_holds(14.2));
        let mut bad = m();
        bad.edge_gb = 5.0;
        assert!(!bad.identity_holds(14.2));
    }

    #[test]
    fn zero_events_safe() {
        let m = EpisodeMetrics::new(TaskKind::PegInsert, PolicyKind::EdgeOnly);
        let (c, e, t) = m.latency_columns();
        assert_eq!((c, e, t), (0.0, 0.0, 0.0));
        assert_eq!(m.trigger_precision(), 1.0);
    }
}

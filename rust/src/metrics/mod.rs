//! Latency / load / fluency accounting matching the paper's table columns.

pub mod recorder;
pub mod summary;

pub use recorder::EpisodeMetrics;
pub use summary::{aggregate, summarize_fleet, FleetSummary, PolicyRow};

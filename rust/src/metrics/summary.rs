//! Aggregation of per-episode metrics into paper-style table rows.

use super::recorder::EpisodeMetrics;
use crate::config::PolicyKind;
use crate::util::Summary;

/// One table row: a policy summarized over many episodes.
#[derive(Debug, Clone)]
pub struct PolicyRow {
    pub policy: PolicyKind,
    pub episodes: usize,
    pub cloud_lat_ms: f64,
    pub edge_lat_ms: f64,
    pub total_lat_mean: f64,
    pub total_lat_std: f64,
    pub overhead_ms: f64,
    pub edge_gb: f64,
    pub cloud_gb: f64,
    pub total_gb: f64,
    pub success_rate: f64,
    pub rms_error: f64,
    pub preemptions: f64,
    pub trigger_precision: f64,
    pub measured_edge_us: f64,
    pub measured_cloud_us: f64,
    pub dispatcher_ns_per_step: f64,
}

/// Aggregate episodes of a single policy.
pub fn aggregate(policy: PolicyKind, eps: &[EpisodeMetrics]) -> PolicyRow {
    assert!(!eps.is_empty(), "no episodes to aggregate");
    let totals: Vec<f64> = eps.iter().map(|m| m.latency_columns().2).collect();
    let s = Summary::of(&totals);
    let mean = |f: &dyn Fn(&EpisodeMetrics) -> f64| -> f64 {
        eps.iter().map(|m| f(m)).sum::<f64>() / eps.len() as f64
    };
    PolicyRow {
        policy,
        episodes: eps.len(),
        cloud_lat_ms: mean(&|m| m.latency_columns().0),
        edge_lat_ms: mean(&|m| m.latency_columns().1),
        total_lat_mean: s.mean,
        total_lat_std: s.std,
        overhead_ms: mean(&|m| m.overhead_ms / m.chunks_consumed() as f64),
        edge_gb: mean(&|m| m.edge_gb),
        cloud_gb: mean(&|m| m.cloud_gb),
        total_gb: mean(&|m| m.edge_gb + m.cloud_gb),
        success_rate: mean(&|m| if m.success { 1.0 } else { 0.0 }),
        rms_error: mean(&|m| m.rms_error),
        preemptions: mean(&|m| m.preemptions as f64),
        trigger_precision: mean(&|m| m.trigger_precision()),
        measured_edge_us: mean(&|m| m.measured_edge_us),
        measured_cloud_us: mean(&|m| m.measured_cloud_us),
        dispatcher_ns_per_step: mean(&|m| {
            if m.steps == 0 {
                0.0
            } else {
                m.dispatcher_cpu_ns as f64 / m.steps as f64
            }
        }),
    }
}

/// Fleet rollup: each session's own aggregate plus the fleet-wide
/// aggregate over every episode of every session.
#[derive(Debug, Clone)]
pub struct FleetSummary {
    pub sessions: usize,
    pub episodes: usize,
    pub per_session: Vec<PolicyRow>,
    pub fleet: PolicyRow,
    pub total_cloud_events: u64,
    pub total_steps: u64,
    pub total_deferred_offloads: u64,
    /// Reuse-cache rollups (all 0 with the cache disabled).
    pub total_cache_hits: u64,
    pub total_cache_misses: u64,
    pub total_cache_stale: u64,
}

/// Aggregate a fleet run: `per_session[i]` holds session i's episode
/// metrics in completion order. Every session must have completed at
/// least one episode.
pub fn summarize_fleet(policy: PolicyKind, per_session: &[Vec<EpisodeMetrics>]) -> FleetSummary {
    assert!(!per_session.is_empty(), "no sessions to summarize");
    assert!(per_session.iter().all(|s| !s.is_empty()), "a session completed no episodes");
    let all: Vec<EpisodeMetrics> = per_session.iter().flat_map(|s| s.iter().cloned()).collect();
    FleetSummary {
        sessions: per_session.len(),
        episodes: all.len(),
        per_session: per_session.iter().map(|s| aggregate(policy, s)).collect(),
        fleet: aggregate(policy, &all),
        total_cloud_events: all.iter().map(|m| m.cloud_events).sum(),
        total_steps: all.iter().map(|m| m.steps as u64).sum(),
        total_deferred_offloads: all.iter().map(|m| m.deferred_offloads).sum(),
        total_cache_hits: all.iter().map(|m| m.cache_hits).sum(),
        total_cache_misses: all.iter().map(|m| m.cache_misses).sum(),
        total_cache_stale: all.iter().map(|m| m.cache_stale).sum(),
    }
}

impl PolicyRow {
    /// Paper-style row cells: Method | Cloud Lat | Cloud Load | Edge Lat |
    /// Edge Load | Total Lat ± std | Total Load.
    pub fn table_cells(&self, name_override: Option<&str>) -> Vec<String> {
        use crate::util::tablefmt::{gb, ms, ms_pm};
        let dash = "-".to_string();
        let name = name_override.unwrap_or(self.policy.name()).to_string();
        let (cl, cg) = if self.cloud_gb <= 1e-9 && self.cloud_lat_ms <= 1e-9 {
            (dash.clone(), dash.clone())
        } else {
            (ms(self.cloud_lat_ms), gb(self.cloud_gb))
        };
        let (el, eg) = if self.edge_gb <= 1e-9 && self.edge_lat_ms <= 1e-9 {
            (dash.clone(), dash)
        } else {
            (ms(self.edge_lat_ms), gb(self.edge_gb))
        };
        vec![
            name,
            cl,
            cg,
            el,
            eg,
            ms_pm(self.total_lat_mean, self.total_lat_std),
            gb(self.total_gb),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::TaskKind;

    fn ep(cloud: f64, edge: f64, ov: f64, e_ev: u64, c_ev: u64) -> EpisodeMetrics {
        let mut m = EpisodeMetrics::new(TaskKind::PickPlace, PolicyKind::Rapid);
        m.cloud_busy_ms = cloud;
        m.edge_busy_ms = edge;
        m.overhead_ms = ov;
        m.edge_events = e_ev;
        m.cloud_events = c_ev;
        m.edge_gb = 2.4;
        m.cloud_gb = 11.8;
        m.steps = 50;
        m
    }

    #[test]
    fn aggregation_means() {
        let eps = vec![ep(400.0, 800.0, 60.0, 4, 2), ep(600.0, 600.0, 0.0, 3, 3)];
        let row = aggregate(PolicyKind::Rapid, &eps);
        assert_eq!(row.episodes, 2);
        // steps = 50 => ceil(50/8) = 7 consumed chunks per episode
        let t0 = (400.0 + 800.0 + 60.0) / 7.0;
        let t1 = 1200.0 / 7.0;
        assert!((row.total_lat_mean - (t0 + t1) / 2.0).abs() < 1e-9);
        assert!((row.total_gb - 14.2).abs() < 1e-9);
    }

    #[test]
    fn table_cells_format() {
        let row = aggregate(PolicyKind::Rapid, &[ep(400.0, 800.0, 0.0, 4, 2)]);
        let cells = row.table_cells(None);
        assert_eq!(cells.len(), 7);
        assert!(cells[1].ends_with("ms"));
        assert_eq!(cells[6], "14.2GB");
    }

    #[test]
    #[should_panic]
    fn empty_aggregation_panics() {
        aggregate(PolicyKind::Rapid, &[]);
    }

    #[test]
    fn fleet_summary_rolls_up_sessions() {
        let per_session = vec![
            vec![ep(400.0, 800.0, 60.0, 4, 2), ep(600.0, 600.0, 0.0, 3, 3)],
            vec![ep(500.0, 700.0, 30.0, 4, 2)],
        ];
        let s = summarize_fleet(PolicyKind::Rapid, &per_session);
        assert_eq!(s.sessions, 2);
        assert_eq!(s.episodes, 3);
        assert_eq!(s.per_session.len(), 2);
        assert_eq!(s.fleet.episodes, 3);
        assert_eq!(s.total_cloud_events, 7);
        assert_eq!(s.total_steps, 150);
        // the fleet aggregate equals the flat aggregate over all episodes
        let all: Vec<EpisodeMetrics> =
            per_session.iter().flat_map(|v| v.iter().cloned()).collect();
        let flat = aggregate(PolicyKind::Rapid, &all);
        assert_eq!(s.fleet.total_lat_mean, flat.total_lat_mean);
    }

    #[test]
    #[should_panic]
    fn fleet_summary_rejects_empty_session() {
        summarize_fleet(PolicyKind::Rapid, &[vec![], vec![ep(1.0, 1.0, 0.0, 1, 1)]]);
    }
}

//! Minimal benchmarking harness (criterion is unavailable offline):
//! warm-up, timed iterations, robust summary statistics, and a consistent
//! report format shared by all `benches/*.rs` (harness = false) binaries.

use crate::util::Summary;
use std::time::Instant;

/// One benchmark case result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time (ns).
    pub summary: Summary,
}

impl BenchResult {
    /// Machine-readable form (one JSON object) for the perf trajectory.
    pub fn to_json(&self) -> String {
        let s = &self.summary;
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{},\"std_ns\":{},\"min_ns\":{},\
             \"max_ns\":{},\"p50_ns\":{},\"p95_ns\":{},\"p99_ns\":{}}}",
            json_escape(&self.name),
            self.iters,
            num(s.mean),
            num(s.std),
            num(s.min),
            num(s.max),
            num(s.p50),
            num(s.p95),
            num(s.p99),
        )
    }

    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<42} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(s.mean),
            fmt_ns(s.p50),
            fmt_ns(s.p95),
            fmt_ns(s.min),
        )
    }
}

/// JSON-safe number: non-finite values (which valid runs never produce)
/// degrade to null instead of emitting unparseable tokens.
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Benchmark runner with warm-up and a time budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_ms: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            budget_ms: 2_000.0,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget_ms(mut self, ms: f64) -> Self {
        self.budget_ms = ms;
        self
    }

    /// Lower the iteration floor (clamped to 1). Heavyweight cases — the
    /// 100k-session fleet rungs of `rapid bench scale` — run once instead
    /// of ten times.
    pub fn with_min_iters(mut self, n: usize) -> Self {
        self.min_iters = n.max(1);
        self
    }

    /// Override the warm-up count (0 disables warm-up entirely; used for
    /// cases whose single iteration *is* the measurement).
    pub fn with_warmup_iters(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Time `f` repeatedly; returns per-iteration stats.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters
            || (times.len() < self.max_iters
                && start.elapsed().as_secs_f64() * 1e3 < self.budget_ms)
        {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: times.len(),
            summary: Summary::of(&times),
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// All results as one JSON document: `{"results": [...]}` — the
    /// schema behind `BENCH_serve.json` and friends.
    pub fn to_json(&self) -> String {
        let items: Vec<String> = self.results.iter().map(|r| r.to_json()).collect();
        format!("{{\"results\":[{}]}}\n", items.join(","))
    }

    /// Write [`Bench::to_json`] to `path`.
    pub fn save_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Standard header printed by every bench binary.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_summarizes() {
        let mut b = Bench {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 50,
            budget_ms: 50.0,
            results: vec![],
        };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(1);
            std::hint::black_box(acc);
        });
        assert!(r.iters >= 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn respects_budget() {
        let mut b = Bench {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 1_000_000,
            budget_ms: 30.0,
            results: vec![],
        };
        let r = b.run("sleepy", || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(r.iters < 20, "iters {}", r.iters);
    }

    #[test]
    fn json_roundtrips_through_the_in_tree_parser() {
        let mut b = Bench {
            warmup_iters: 0,
            min_iters: 3,
            max_iters: 10,
            budget_ms: 20.0,
            results: vec![],
        };
        b.run("serve/\"quoted\"\nname", || std::hint::black_box(1 + 1));
        b.run("fleet/8x1", || std::hint::black_box(2 + 2));
        let doc = b.to_json();
        let v = crate::config::json::parse_json(&doc).expect("benchkit JSON must parse");
        let results = v.get("results").and_then(|r| r.as_list()).expect("results array");
        assert_eq!(results.len(), 2);
        assert_eq!(results[1].str_or("name", ""), "fleet/8x1");
        assert!(results[0].f64_or("mean_ns", -1.0) >= 0.0);
        assert!(results[0].f64_or("iters", 0.0) >= 3.0);
        assert!(results[0].f64_or("p95_ns", -1.0) >= results[0].f64_or("min_ns", 1e18) - 1e-9);
    }

    #[test]
    fn builders_pin_single_iteration_runs() {
        // the scale-bench fleet rungs rely on exactly this configuration:
        // no warm-up, one timed iteration, tiny budget
        let mut b = Bench::new().with_min_iters(0).with_warmup_iters(0).with_budget_ms(0.0);
        assert_eq!(b.min_iters, 1, "min_iters clamps to 1");
        assert_eq!(b.warmup_iters, 0);
        let mut calls = 0u32;
        let r = b.run("once", || {
            calls += 1;
            std::hint::black_box(calls);
        });
        assert_eq!(r.iters, 1, "zero budget + min 1 => exactly one timed iteration");
        assert_eq!(calls, 1, "no warm-up calls");
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.20s");
    }
}

//! Minimal benchmarking harness (criterion is unavailable offline):
//! warm-up, timed iterations, robust summary statistics, and a consistent
//! report format shared by all `benches/*.rs` (harness = false) binaries.

use crate::util::Summary;
use std::time::Instant;

/// One benchmark case result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time (ns).
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<42} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(s.mean),
            fmt_ns(s.p50),
            fmt_ns(s.p95),
            fmt_ns(s.min),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Benchmark runner with warm-up and a time budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_ms: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, min_iters: 10, max_iters: 10_000, budget_ms: 2_000.0, results: Vec::new() }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget_ms(mut self, ms: f64) -> Self {
        self.budget_ms = ms;
        self
    }

    /// Time `f` repeatedly; returns per-iteration stats.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters
            || (times.len() < self.max_iters && start.elapsed().as_secs_f64() * 1e3 < self.budget_ms)
        {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult { name: name.to_string(), iters: times.len(), summary: Summary::of(&times) };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Standard header printed by every bench binary.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_summarizes() {
        let mut b = Bench { warmup_iters: 1, min_iters: 5, max_iters: 50, budget_ms: 50.0, results: vec![] };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(1);
            std::hint::black_box(acc);
        });
        assert!(r.iters >= 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn respects_budget() {
        let mut b = Bench { warmup_iters: 0, min_iters: 2, max_iters: 1_000_000, budget_ms: 30.0, results: vec![] };
        let r = b.run("sleepy", || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(r.iters < 20, "iters {}", r.iters);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.20s");
    }
}

//! `PolicyExecutable`: one compiled VLA variant + device-resident weights.
//!
//! Weights are uploaded ONCE per session as a `PjRtBuffer` and every
//! inference goes through `execute_b` with buffer arguments — re-uploading
//! the 2.3 M-parameter cloud weight blob per call would dominate the hot
//! path (see EXPERIMENTS.md §Perf for the measured before/after).

use super::artifact::{read_weights, VariantMeta};
use super::client::{RuntimeClient, RuntimeError};
use crate::vla::ModelOut;
use crate::{CHUNK, D_PROP, D_VIS, N_INSTR, N_JOINTS, VOCAB};
use std::rc::Rc;
use std::time::Instant;

pub struct PolicyExecutable {
    exe: Rc<xla::PjRtLoadedExecutable>,
    weights: xla::PjRtBuffer,
    pub variant: String,
    pub n_params: usize,
    /// Cumulative measured execution time (µs) and call count — the real
    /// wall-clock numbers recorded alongside the emulated testbed times.
    pub total_us: u64,
    pub calls: u64,
}

impl PolicyExecutable {
    pub fn new(
        client: &mut RuntimeClient,
        exe: Rc<xla::PjRtLoadedExecutable>,
        meta: &VariantMeta,
    ) -> Result<Self, RuntimeError> {
        let host = read_weights(&meta.weights_path)?;
        let weights = client.raw().buffer_from_host_buffer::<f32>(&host, &[host.len()], None)?;
        Ok(PolicyExecutable {
            exe,
            weights,
            variant: meta.name.clone(),
            n_params: meta.n_params,
            total_us: 0,
            calls: 0,
        })
    }

    /// Run one inference. `instr` is the instruction-embedding index.
    pub fn infer(
        &mut self,
        obs: &[f32; D_VIS],
        proprio: &[f32; D_PROP],
        instr: usize,
    ) -> Result<ModelOut, RuntimeError> {
        let t0 = Instant::now();
        let client = self.exe.client().clone();
        let obs_b = client.buffer_from_host_buffer::<f32>(obs, &[D_VIS], None)?;
        let prop_b = client.buffer_from_host_buffer::<f32>(proprio, &[D_PROP], None)?;
        let mut ins = [0f32; N_INSTR];
        ins[instr.min(N_INSTR - 1)] = 1.0;
        let ins_b = client.buffer_from_host_buffer::<f32>(&ins, &[N_INSTR], None)?;

        let result = self.exe.execute_b(&[&self.weights, &obs_b, &prop_b, &ins_b])?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (actions, logits, mass)
        let (a_l, l_l, m_l) = lit.to_tuple3()?;
        let actions = a_l.to_vec::<f32>()?;
        let logits = l_l.to_vec::<f32>()?;
        let mass = m_l.to_vec::<f32>()?;
        debug_assert_eq!(actions.len(), CHUNK * N_JOINTS);
        debug_assert_eq!(logits.len(), CHUNK * VOCAB);
        debug_assert_eq!(mass.len(), CHUNK);

        let us = t0.elapsed().as_micros() as u64;
        self.total_us += us;
        self.calls += 1;
        Ok(ModelOut::from_flat(&actions, &logits, &mass))
    }

    /// Mean measured execution time per call (µs).
    pub fn mean_us(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.total_us as f64 / self.calls as f64
        }
    }
}

//! Virtual device clock: converts events into *emulated testbed time*.
//!
//! The surrogate VLA is ~10⁻³ the size of OpenVLA, so raw wall clock on
//! this machine is meaningless for the paper's tables. `DeviceClock`
//! advances a virtual time using the calibrated service-time model of
//! `DeviceConfig` (DESIGN.md §5) with deterministic jitter; the *measured*
//! PJRT times are tracked separately by [`super::PolicyExecutable`].

use crate::config::{DeviceConfig, SystemConfig};
use crate::util::Pcg32;

#[derive(Debug, Clone)]
pub struct DeviceClock {
    cfg: DeviceConfig,
    rng: Pcg32,
    /// Virtual time elapsed (ms).
    pub now_ms: f64,
}

impl DeviceClock {
    pub fn new(cfg: &DeviceConfig, seed: u64) -> Self {
        DeviceClock { cfg: cfg.clone(), rng: Pcg32::new(seed, 0xDE_7), now_ms: 0.0 }
    }

    fn jittered(&mut self, base_ms: f64) -> f64 {
        (base_ms * (1.0 + self.cfg.jitter * self.rng.normal())).max(0.0)
    }

    /// Edge inference with `gb` parameters resident (linear scaling
    /// anchored at the Edge-Only full-model time).
    pub fn edge_infer(&mut self, sys: &SystemConfig, gb: f64) -> f64 {
        self.edge_infer_scaled(sys, gb, 1.0)
    }

    /// [`DeviceClock::edge_infer`] under a model-family time multiplier
    /// (zoo profiles). Scale 1.0 is bit-identical to the unscaled call —
    /// one jitter draw either way.
    pub fn edge_infer_scaled(&mut self, sys: &SystemConfig, gb: f64, scale: f64) -> f64 {
        let t = self.jittered(sys.edge_infer_ms(gb)) * scale;
        self.now_ms += t;
        t
    }

    /// Cloud-side compute for a full-model inference.
    pub fn cloud_compute(&mut self) -> f64 {
        self.cloud_compute_scaled(1.0)
    }

    /// [`DeviceClock::cloud_compute`] under a model-family time multiplier
    /// (zoo partition points). Scale 1.0 is bit-identical.
    pub fn cloud_compute_scaled(&mut self, scale: f64) -> f64 {
        let t = self.cloud_compute_sampled(scale);
        self.now_ms += t;
        t
    }

    /// Draw the cloud compute time *without* advancing the clock — the
    /// pipelined offload paths (`[pipeline]`) charge the round trip in
    /// restructured form but must consume exactly the same jitter draw as
    /// the sequential [`DeviceClock::cloud_compute_scaled`] path, so a
    /// degenerate pipeline stays bit-identical.
    pub fn cloud_compute_sampled(&mut self, scale: f64) -> f64 {
        self.jittered(self.cfg.cloud_compute_ms) * scale
    }

    /// Vision-based routing cost (preprocess + distribution extraction).
    pub fn vision_route(&mut self) -> f64 {
        let t = self.jittered(self.cfg.vision_route_ms);
        self.now_ms += t;
        t
    }

    pub fn preempt(&mut self) -> f64 {
        let t = self.jittered(self.cfg.preempt_ms);
        self.now_ms += t;
        t
    }

    pub fn obs_capture(&mut self) -> f64 {
        let t = self.jittered(self.cfg.obs_capture_ms);
        self.now_ms += t;
        t
    }

    /// Advance by an externally computed duration (e.g. link transfer).
    pub fn advance(&mut self, ms: f64) {
        self.now_ms += ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_infer_anchored() {
        let sys = SystemConfig::default();
        let mut c = DeviceClock::new(&sys.devices, 1);
        let xs: Vec<f64> = (0..200).map(|_| c.edge_infer(&sys, sys.total_model_gb)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 782.5).abs() < 25.0, "mean {mean}");
        assert!(c.now_ms > 0.0);
    }

    #[test]
    fn small_slice_proportionally_cheaper() {
        let sys = SystemConfig::default();
        let mut c = DeviceClock::new(&sys.devices, 2);
        let small: f64 = (0..100).map(|_| c.edge_infer(&sys, 2.4)).sum::<f64>() / 100.0;
        assert!(small < 200.0 && small > 90.0, "small {small}");
    }

    #[test]
    fn deterministic() {
        let sys = SystemConfig::default();
        let mut a = DeviceClock::new(&sys.devices, 3);
        let mut b = DeviceClock::new(&sys.devices, 3);
        for _ in 0..10 {
            assert_eq!(a.cloud_compute(), b.cloud_compute());
        }
    }

    #[test]
    fn sampled_draw_matches_scaled_draw() {
        // same seed, same draw stream: sampling then advancing by hand is
        // indistinguishable from the one-shot scaled call
        let sys = SystemConfig::default();
        let mut a = DeviceClock::new(&sys.devices, 5);
        let mut b = DeviceClock::new(&sys.devices, 5);
        for _ in 0..50 {
            let ta = a.cloud_compute_scaled(1.3);
            let tb = b.cloud_compute_sampled(1.3);
            b.advance(tb);
            assert_eq!(ta, tb);
            assert_eq!(a.now_ms, b.now_ms);
        }
    }

    #[test]
    fn times_nonnegative() {
        let sys = SystemConfig::default();
        let mut c = DeviceClock::new(&sys.devices, 4);
        for _ in 0..1000 {
            assert!(c.preempt() >= 0.0);
            assert!(c.obs_capture() >= 0.0);
        }
    }
}

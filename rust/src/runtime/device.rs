//! Virtual device clock: converts events into *emulated testbed time*.
//!
//! The surrogate VLA is ~10⁻³ the size of OpenVLA, so raw wall clock on
//! this machine is meaningless for the paper's tables. `DeviceClock`
//! advances a virtual time using the calibrated service-time model of
//! `DeviceConfig` (DESIGN.md §5) with deterministic jitter; the *measured*
//! PJRT times are tracked separately by [`super::PolicyExecutable`].

use crate::config::{DeviceConfig, SystemConfig};
use crate::util::Pcg32;

/// Number of device classes in the catalog ([`DeviceClass::ALL`]).
pub const N_CLASSES: usize = 4;

/// Edge-device class catalog (the XPU heterogeneity axis): what silicon a
/// fleet slot actually is. Each class carries the runtime factors the
/// planner and driver need — edge-compute scale, obs-capture cost, and
/// action-grid quantization — while its memory/prefix *budget* lives in
/// [`crate::policy::planner::DeviceBudget::for_class`]. The default
/// `Cloudlet` class is an exact no-op (every scale 1.0, grid off, budget
/// unlimited): a fleet of cloudlets is bit-identical to a class-free run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DeviceClass {
    /// Wall-powered edge server: the calibration anchor, exact no-op.
    #[default]
    Cloudlet,
    /// Embedded GPU module (Orin AGX style): near-anchor compute.
    Agx,
    /// Mid-tier embedded module (Orin NX style): slower prefix, coarse
    /// NPU action grid.
    Nx,
    /// Battery CPU-only robot: slowest compute, coarsest grid.
    Lite,
}

impl DeviceClass {
    /// Catalog order == `id()` order.
    pub const ALL: [DeviceClass; N_CLASSES] =
        [DeviceClass::Cloudlet, DeviceClass::Agx, DeviceClass::Nx, DeviceClass::Lite];

    /// Valid class names, for config-error messages.
    pub const NAMES: &'static str = "cloudlet, agx, nx, lite";

    /// Stable wire/signature discriminant (`Cloudlet == 0`, so legacy
    /// class-free signatures and reports read as cloudlet).
    pub fn id(self) -> u8 {
        match self {
            DeviceClass::Cloudlet => 0,
            DeviceClass::Agx => 1,
            DeviceClass::Nx => 2,
            DeviceClass::Lite => 3,
        }
    }

    pub fn from_id(id: u8) -> Option<DeviceClass> {
        DeviceClass::ALL.get(id as usize).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            DeviceClass::Cloudlet => "cloudlet",
            DeviceClass::Agx => "agx",
            DeviceClass::Nx => "nx",
            DeviceClass::Lite => "lite",
        }
    }

    /// Parse a config-file class name (trimmed, case-insensitive).
    pub fn parse(s: &str) -> Option<DeviceClass> {
        match s.trim().to_ascii_lowercase().as_str() {
            "cloudlet" | "default" => Some(DeviceClass::Cloudlet),
            "agx" => Some(DeviceClass::Agx),
            "nx" => Some(DeviceClass::Nx),
            "lite" => Some(DeviceClass::Lite),
            _ => None,
        }
    }

    /// Multiplier on edge-slice inference time (weaker silicon is slower
    /// at the same resident GB). `Cloudlet` is exactly 1.0 — the no-op.
    pub fn edge_scale(self) -> f64 {
        match self {
            DeviceClass::Cloudlet => 1.0,
            DeviceClass::Agx => 1.25,
            DeviceClass::Nx => 1.6,
            DeviceClass::Lite => 2.2,
        }
    }

    /// Multiplier on camera observation-capture latency (slower ISP /
    /// CPU-bound encode on weaker devices). `Cloudlet` is exactly 1.0.
    pub fn obs_scale(self) -> f64 {
        match self {
            DeviceClass::Cloudlet => 1.0,
            DeviceClass::Agx => 1.1,
            DeviceClass::Nx => 1.25,
            DeviceClass::Lite => 1.5,
        }
    }

    /// Action-grid quantization step (rad/s) the device's NPU/CPU
    /// inference path snaps served actions to; 0.0 = continuous output
    /// (no snapping — the no-op for `cloudlet`/`agx`).
    pub fn action_quant(self) -> f64 {
        match self {
            DeviceClass::Cloudlet | DeviceClass::Agx => 0.0,
            DeviceClass::Nx => 1.0 / 128.0,
            DeviceClass::Lite => 1.0 / 64.0,
        }
    }
}

/// Deterministic block assignment of device classes across a fleet
/// (mirrors `vla::zoo::assign_families`): session `i` of `n` gets
/// `classes[i * classes.len() / n]` — contiguous balanced blocks, zero
/// PRNG draws. An empty list yields the default class.
pub fn assign_classes(classes: &[DeviceClass], n_sessions: usize, session: usize) -> DeviceClass {
    if classes.is_empty() || n_sessions == 0 {
        return DeviceClass::default();
    }
    let i = session.min(n_sessions - 1);
    classes[(i * classes.len()) / n_sessions]
}

#[derive(Debug, Clone)]
pub struct DeviceClock {
    cfg: DeviceConfig,
    rng: Pcg32,
    /// Virtual time elapsed (ms).
    pub now_ms: f64,
}

impl DeviceClock {
    pub fn new(cfg: &DeviceConfig, seed: u64) -> Self {
        DeviceClock { cfg: cfg.clone(), rng: Pcg32::new(seed, 0xDE_7), now_ms: 0.0 }
    }

    fn jittered(&mut self, base_ms: f64) -> f64 {
        (base_ms * (1.0 + self.cfg.jitter * self.rng.normal())).max(0.0)
    }

    /// Edge inference with `gb` parameters resident (linear scaling
    /// anchored at the Edge-Only full-model time).
    pub fn edge_infer(&mut self, sys: &SystemConfig, gb: f64) -> f64 {
        self.edge_infer_scaled(sys, gb, 1.0)
    }

    /// [`DeviceClock::edge_infer`] under a model-family time multiplier
    /// (zoo profiles). Scale 1.0 is bit-identical to the unscaled call —
    /// one jitter draw either way.
    pub fn edge_infer_scaled(&mut self, sys: &SystemConfig, gb: f64, scale: f64) -> f64 {
        let t = self.jittered(sys.edge_infer_ms(gb)) * scale;
        self.now_ms += t;
        t
    }

    /// Cloud-side compute for a full-model inference.
    pub fn cloud_compute(&mut self) -> f64 {
        self.cloud_compute_scaled(1.0)
    }

    /// [`DeviceClock::cloud_compute`] under a model-family time multiplier
    /// (zoo partition points). Scale 1.0 is bit-identical.
    pub fn cloud_compute_scaled(&mut self, scale: f64) -> f64 {
        let t = self.cloud_compute_sampled(scale);
        self.now_ms += t;
        t
    }

    /// Draw the cloud compute time *without* advancing the clock — the
    /// pipelined offload paths (`[pipeline]`) charge the round trip in
    /// restructured form but must consume exactly the same jitter draw as
    /// the sequential [`DeviceClock::cloud_compute_scaled`] path, so a
    /// degenerate pipeline stays bit-identical.
    pub fn cloud_compute_sampled(&mut self, scale: f64) -> f64 {
        self.jittered(self.cfg.cloud_compute_ms) * scale
    }

    /// Vision-based routing cost (preprocess + distribution extraction).
    pub fn vision_route(&mut self) -> f64 {
        let t = self.jittered(self.cfg.vision_route_ms);
        self.now_ms += t;
        t
    }

    pub fn preempt(&mut self) -> f64 {
        let t = self.jittered(self.cfg.preempt_ms);
        self.now_ms += t;
        t
    }

    pub fn obs_capture(&mut self) -> f64 {
        self.obs_capture_scaled(1.0)
    }

    /// [`DeviceClock::obs_capture`] under a device-class time multiplier.
    /// Scale 1.0 is bit-identical to the unscaled call — one jitter draw
    /// either way (same pattern as [`DeviceClock::edge_infer_scaled`]).
    pub fn obs_capture_scaled(&mut self, scale: f64) -> f64 {
        let t = self.jittered(self.cfg.obs_capture_ms) * scale;
        self.now_ms += t;
        t
    }

    /// Advance by an externally computed duration (e.g. link transfer).
    pub fn advance(&mut self, ms: f64) {
        self.now_ms += ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_infer_anchored() {
        let sys = SystemConfig::default();
        let mut c = DeviceClock::new(&sys.devices, 1);
        let xs: Vec<f64> = (0..200).map(|_| c.edge_infer(&sys, sys.total_model_gb)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 782.5).abs() < 25.0, "mean {mean}");
        assert!(c.now_ms > 0.0);
    }

    #[test]
    fn small_slice_proportionally_cheaper() {
        let sys = SystemConfig::default();
        let mut c = DeviceClock::new(&sys.devices, 2);
        let small: f64 = (0..100).map(|_| c.edge_infer(&sys, 2.4)).sum::<f64>() / 100.0;
        assert!(small < 200.0 && small > 90.0, "small {small}");
    }

    #[test]
    fn deterministic() {
        let sys = SystemConfig::default();
        let mut a = DeviceClock::new(&sys.devices, 3);
        let mut b = DeviceClock::new(&sys.devices, 3);
        for _ in 0..10 {
            assert_eq!(a.cloud_compute(), b.cloud_compute());
        }
    }

    #[test]
    fn sampled_draw_matches_scaled_draw() {
        // same seed, same draw stream: sampling then advancing by hand is
        // indistinguishable from the one-shot scaled call
        let sys = SystemConfig::default();
        let mut a = DeviceClock::new(&sys.devices, 5);
        let mut b = DeviceClock::new(&sys.devices, 5);
        for _ in 0..50 {
            let ta = a.cloud_compute_scaled(1.3);
            let tb = b.cloud_compute_sampled(1.3);
            b.advance(tb);
            assert_eq!(ta, tb);
            assert_eq!(a.now_ms, b.now_ms);
        }
    }

    #[test]
    fn times_nonnegative() {
        let sys = SystemConfig::default();
        let mut c = DeviceClock::new(&sys.devices, 4);
        for _ in 0..1000 {
            assert!(c.preempt() >= 0.0);
            assert!(c.obs_capture() >= 0.0);
        }
    }

    #[test]
    fn class_catalog_roundtrips_and_defaults_to_the_noop() {
        assert_eq!(DeviceClass::default(), DeviceClass::Cloudlet);
        for (i, c) in DeviceClass::ALL.into_iter().enumerate() {
            assert_eq!(c.id() as usize, i, "ALL order must match id()");
            assert_eq!(DeviceClass::from_id(c.id()), Some(c));
            assert_eq!(DeviceClass::parse(c.name()), Some(c));
            assert_eq!(DeviceClass::parse(&format!("  {}  ", c.name().to_uppercase())), Some(c));
        }
        assert_eq!(DeviceClass::parse("default"), Some(DeviceClass::Cloudlet));
        assert_eq!(DeviceClass::parse("orin-typo"), None);
        assert_eq!(DeviceClass::from_id(99), None);
        // the default class is an exact no-op at every runtime factor
        assert_eq!(DeviceClass::Cloudlet.edge_scale(), 1.0);
        assert_eq!(DeviceClass::Cloudlet.obs_scale(), 1.0);
        assert_eq!(DeviceClass::Cloudlet.action_quant(), 0.0);
        // weaker silicon is monotonically slower
        assert!(DeviceClass::Agx.edge_scale() < DeviceClass::Nx.edge_scale());
        assert!(DeviceClass::Nx.edge_scale() < DeviceClass::Lite.edge_scale());
        assert!(DeviceClass::Nx.action_quant() < DeviceClass::Lite.action_quant());
    }

    #[test]
    fn obs_capture_scale_one_is_bit_identical() {
        let sys = SystemConfig::default();
        let mut a = DeviceClock::new(&sys.devices, 6);
        let mut b = DeviceClock::new(&sys.devices, 6);
        for _ in 0..100 {
            assert_eq!(a.obs_capture(), b.obs_capture_scaled(1.0));
            assert_eq!(a.now_ms, b.now_ms);
        }
        // a non-unit scale consumes exactly one draw too: streams stay
        // aligned across class boundaries
        let ta = a.obs_capture();
        let tb = b.obs_capture_scaled(1.5);
        assert_eq!(tb, ta * 1.5);
    }

    #[test]
    fn block_assignment_is_contiguous_and_covers_all_classes() {
        let list = [DeviceClass::Lite, DeviceClass::Nx, DeviceClass::Agx];
        let n = 9;
        let got: Vec<DeviceClass> = (0..n).map(|i| assign_classes(&list, n, i)).collect();
        assert_eq!(got[0], DeviceClass::Lite);
        assert_eq!(got[n - 1], DeviceClass::Agx);
        // contiguous: class index never decreases
        let ids: Vec<u8> =
            got.iter().map(|c| list.iter().position(|x| x == c).unwrap() as u8).collect();
        assert!(ids.windows(2).all(|w| w[0] <= w[1]), "{ids:?}");
        for c in list {
            assert!(got.contains(&c), "{c:?} missing from {got:?}");
        }
        assert_eq!(assign_classes(&[], 4, 2), DeviceClass::Cloudlet);
        assert_eq!(assign_classes(&list, 0, 0), DeviceClass::Cloudlet);
    }
}

//! PJRT CPU client wrapper with a compile cache.
//!
//! The underlying `xla::PjRtClient` is created once per process (PJRT CPU
//! clients are heavyweight); executables are cached by artifact path.

use super::artifact::{ArtifactMeta, VariantMeta};
use super::executor::PolicyExecutable;
use std::collections::HashMap;

#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    Artifact(super::artifact::ArtifactError),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(m) => write!(f, "xla: {m}"),
            RuntimeError::Artifact(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Artifact(e) => Some(e),
            RuntimeError::Xla(_) => None,
        }
    }
}

impl From<super::artifact::ArtifactError> for RuntimeError {
    fn from(e: super::artifact::ArtifactError) -> Self {
        RuntimeError::Artifact(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

pub struct RuntimeClient {
    client: xla::PjRtClient,
    cache: HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
}

impl RuntimeClient {
    pub fn cpu() -> Result<Self, RuntimeError> {
        Ok(RuntimeClient { client: xla::PjRtClient::cpu()?, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an HLO text artifact (cached by path).
    pub fn compile_hlo_text(
        &mut self,
        path: &std::path::Path,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>, RuntimeError> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.cache.insert(key, exe.clone());
        Ok(exe)
    }

    /// Build a [`PolicyExecutable`] for one model variant: compiles the HLO
    /// and uploads the weights to a device-resident buffer.
    pub fn load_variant(&mut self, meta: &VariantMeta) -> Result<PolicyExecutable, RuntimeError> {
        let exe = self.compile_hlo_text(&meta.hlo_path)?;
        PolicyExecutable::new(self, exe, meta)
    }

    /// Convenience: load both standard variants from an artifact dir.
    pub fn load_standard(
        &mut self,
        artifacts: &ArtifactMeta,
    ) -> Result<(PolicyExecutable, PolicyExecutable), RuntimeError> {
        let edge = artifacts
            .variant("edge")
            .ok_or_else(|| RuntimeError::Xla("no edge variant in meta.json".into()))?
            .clone();
        let cloud = artifacts
            .variant("cloud")
            .ok_or_else(|| RuntimeError::Xla("no cloud variant in meta.json".into()))?
            .clone();
        Ok((self.load_variant(&edge)?, self.load_variant(&cloud)?))
    }
}

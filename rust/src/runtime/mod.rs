//! PJRT runtime: loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python is never on this path — the artifacts are self-contained.

pub mod artifact;
pub mod client;
pub mod device;
pub mod executor;

pub use artifact::{ArtifactMeta, VariantMeta};
pub use client::RuntimeClient;
pub use device::DeviceClock;
pub use executor::PolicyExecutable;

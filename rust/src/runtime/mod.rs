//! PJRT runtime: loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python is never on this path — the artifacts are self-contained.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod device;
#[cfg(feature = "pjrt")]
pub mod executor;

pub use artifact::{ArtifactMeta, VariantMeta};
#[cfg(feature = "pjrt")]
pub use client::RuntimeClient;
pub use device::{assign_classes, DeviceClass, DeviceClock, N_CLASSES};
#[cfg(feature = "pjrt")]
pub use executor::PolicyExecutable;

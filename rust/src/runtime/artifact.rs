//! Artifact discovery: parse `artifacts/meta.json`, locate HLO text and
//! weight blobs, and validate weight checksums/sizes.

use crate::config::json::parse_json;
use crate::config::Value;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum ArtifactError {
    Missing(PathBuf),
    Io(std::io::Error),
    Meta(String),
    WeightsSize { variant: String, file_params: usize, meta_params: usize },
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Missing(p) => write!(f, "artifact dir not found: {}", p.display()),
            ArtifactError::Io(e) => write!(f, "io: {e}"),
            ArtifactError::Meta(m) => write!(f, "meta.json: {m}"),
            ArtifactError::WeightsSize { variant, file_params, meta_params } => write!(
                f,
                "weights size mismatch for {variant}: file has {file_params} f32, meta says {meta_params}"
            ),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ArtifactError {
    fn from(e: std::io::Error) -> Self {
        ArtifactError::Io(e)
    }
}

/// Per-variant artifact description.
#[derive(Debug, Clone)]
pub struct VariantMeta {
    pub name: String,
    pub d: usize,
    pub heads: usize,
    pub layers: usize,
    pub n_params: usize,
    pub hlo_path: PathBuf,
    pub weights_path: PathBuf,
}

/// Parsed `meta.json` + resolved paths.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub seed: u64,
    pub chunk: usize,
    pub n_joints: usize,
    pub vocab: usize,
    pub variants: Vec<VariantMeta>,
}

impl ArtifactMeta {
    /// Load and validate from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactMeta, ArtifactError> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        if !meta_path.exists() {
            return Err(ArtifactError::Missing(dir));
        }
        let text = std::fs::read_to_string(&meta_path)?;
        let v = parse_json(&text).map_err(|e| ArtifactError::Meta(e.to_string()))?;

        let dims = v.get("dims").ok_or_else(|| ArtifactError::Meta("missing dims".into()))?;
        let variants_tbl = v
            .get("variants")
            .and_then(Value::as_table)
            .ok_or_else(|| ArtifactError::Meta("missing variants".into()))?;

        let mut variants = Vec::new();
        for (name, vv) in variants_tbl {
            let hlo = vv.str_or("hlo", "");
            let weights = vv.str_or("weights", "");
            let vm = VariantMeta {
                name: name.clone(),
                d: vv.usize_or("d", 0),
                heads: vv.usize_or("heads", 0),
                layers: vv.usize_or("layers", 0),
                n_params: vv.usize_or("n_params", 0),
                hlo_path: dir.join(hlo),
                weights_path: dir.join(weights),
            };
            if !vm.hlo_path.exists() {
                return Err(ArtifactError::Meta(format!(
                    "{name}: hlo file missing: {:?}",
                    vm.hlo_path
                )));
            }
            let wsize = std::fs::metadata(&vm.weights_path)?.len() as usize;
            if wsize != 4 * vm.n_params {
                return Err(ArtifactError::WeightsSize {
                    variant: name.clone(),
                    file_params: wsize / 4,
                    meta_params: vm.n_params,
                });
            }
            variants.push(vm);
        }
        variants.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(ArtifactMeta {
            dir,
            seed: v.f64_or("seed", 0.0) as u64,
            chunk: dims.usize_or("chunk", crate::CHUNK),
            n_joints: dims.usize_or("n_joints", crate::N_JOINTS),
            vocab: dims.usize_or("vocab", crate::VOCAB),
            variants,
        })
    }

    pub fn variant(&self, name: &str) -> Option<&VariantMeta> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Default artifact directory: `$RAPID_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("RAPID_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

/// Read a little-endian f32 weight blob.
pub fn read_weights(path: impl AsRef<Path>) -> Result<Vec<f32>, ArtifactError> {
    let bytes = std::fs::read(path)?;
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for c in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        ArtifactMeta::default_dir().join("meta.json").exists()
    }

    #[test]
    fn loads_real_meta_when_present() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = ArtifactMeta::load(ArtifactMeta::default_dir()).unwrap();
        assert_eq!(m.chunk, crate::CHUNK);
        assert_eq!(m.n_joints, crate::N_JOINTS);
        assert!(m.variant("edge").is_some());
        assert!(m.variant("cloud").is_some());
        let edge = m.variant("edge").unwrap();
        let w = read_weights(&edge.weights_path).unwrap();
        assert_eq!(w.len(), edge.n_params);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(matches!(
            ArtifactMeta::load("/nonexistent-dir-xyz"),
            Err(ArtifactError::Missing(_))
        ));
    }
}

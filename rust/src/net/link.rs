//! Analytic link model: transfer time = serialization + bandwidth +
//! propagation, with jitter and clarity-dependent retransmissions
//! (degraded vision ⇒ bigger/re-sent frames — the communication-overhead
//! surge the paper's Table I attributes to noisy scenes).

use crate::config::LinkConfig;
use crate::util::Pcg32;

/// Result of one modeled transfer.
#[derive(Debug, Clone, Copy)]
pub struct Transfer {
    pub ms: f64,
    pub retransmissions: u32,
}

#[derive(Debug, Clone)]
pub struct Link {
    cfg: LinkConfig,
    rng: Pcg32,
    /// Totals for accounting.
    pub total_bytes: f64,
    pub total_retrans: u64,
}

impl Link {
    pub fn new(cfg: &LinkConfig, seed: u64) -> Self {
        Link { cfg: cfg.clone(), rng: Pcg32::new(seed, 0x11_4E), total_bytes: 0.0, total_retrans: 0 }
    }

    pub fn config(&self) -> &LinkConfig {
        &self.cfg
    }

    /// One-way transfer of `bytes` under scene clarity in (0, 1].
    pub fn transfer(&mut self, bytes: f64, clarity: f64) -> Transfer {
        let base = bytes * 8.0 / (self.cfg.bw_mbps * 1e6) * 1e3 + self.cfg.rtt_ms / 2.0;
        let mut ms = base * (1.0 + self.cfg.jitter * self.rng.normal()).max(0.2);
        // degraded frames are re-sent: each retransmission repeats the
        // payload time (geometric, clarity-gated)
        let p = (self.cfg.noise_retrans * (1.0 - clarity.clamp(0.0, 1.0))).clamp(0.0, 0.9);
        let mut retrans = 0u32;
        while retrans < 8 && self.rng.chance(p) {
            ms += base;
            retrans += 1;
        }
        self.total_bytes += bytes * (1.0 + retrans as f64);
        self.total_retrans += retrans as u64;
        Transfer { ms, retransmissions: retrans }
    }

    /// Full offload round trip: observation up, chunk down.
    pub fn offload_roundtrip(&mut self, obs_bytes: f64, chunk_bytes: f64, clarity: f64) -> Transfer {
        let up = self.transfer(obs_bytes, clarity);
        let down = self.transfer(chunk_bytes, 1.0); // the reply is tiny/clean
        Transfer { ms: up.ms + down.ms, retransmissions: up.retransmissions + down.retransmissions }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link(seed: u64) -> Link {
        Link::new(&LinkConfig::default(), seed)
    }

    #[test]
    fn clean_transfer_near_nominal() {
        let mut l = link(1);
        let bytes = 1.5e6;
        let nominal = bytes * 8.0 / (1000.0 * 1e6) * 1e3 + 4.0;
        let mean: f64 = (0..300).map(|_| l.transfer(bytes, 1.0).ms).sum::<f64>() / 300.0;
        assert!((mean - nominal).abs() < nominal * 0.15, "mean {mean} nominal {nominal}");
    }

    #[test]
    fn clean_scene_no_retransmissions() {
        let mut l = link(2);
        for _ in 0..200 {
            assert_eq!(l.transfer(1e6, 1.0).retransmissions, 0);
        }
    }

    #[test]
    fn occlusion_causes_retransmissions() {
        let mut l = link(3);
        let total: u32 = (0..300).map(|_| l.transfer(1e6, 0.2).retransmissions).sum();
        assert!(total > 20, "retrans {total}");
    }

    #[test]
    fn bigger_payloads_take_longer() {
        let mut l = link(4);
        let small: f64 = (0..100).map(|_| l.transfer(1e5, 1.0).ms).sum::<f64>();
        let big: f64 = (0..100).map(|_| l.transfer(6e6, 1.0).ms).sum::<f64>();
        assert!(big > small * 2.0);
    }

    #[test]
    fn accounting_accumulates() {
        let mut l = link(5);
        l.transfer(1e6, 0.1);
        l.transfer(1e6, 0.1);
        assert!(l.total_bytes >= 2e6);
    }
}

//! Analytic link model: transfer time = serialization + bandwidth +
//! propagation, with jitter and clarity-dependent retransmissions
//! (degraded vision ⇒ bigger/re-sent frames — the communication-overhead
//! surge the paper's Table I attributes to noisy scenes).

use crate::config::LinkConfig;
use crate::util::Pcg32;

/// Result of one modeled transfer.
#[derive(Debug, Clone, Copy)]
pub struct Transfer {
    pub ms: f64,
    pub retransmissions: u32,
}

/// A temporary override of the link's nominal bandwidth/RTT — the
/// time-varying condition a [`crate::faults::FaultPlan`] degrade window
/// puts the link under. `None` profile ⇒ the static `LinkConfig` values,
/// with identical PRNG consumption, so fault-free runs are bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkProfile {
    pub bw_mbps: f64,
    pub rtt_ms: f64,
}

#[derive(Debug, Clone)]
pub struct Link {
    cfg: LinkConfig,
    rng: Pcg32,
    /// Active degradation window, if any (see [`LinkProfile`]).
    profile: Option<LinkProfile>,
    /// Totals for accounting.
    pub total_bytes: f64,
    pub total_retrans: u64,
}

impl Link {
    pub fn new(cfg: &LinkConfig, seed: u64) -> Self {
        Link {
            cfg: cfg.clone(),
            rng: Pcg32::new(seed, 0x11_4E),
            profile: None,
            total_bytes: 0.0,
            total_retrans: 0,
        }
    }

    pub fn config(&self) -> &LinkConfig {
        &self.cfg
    }

    /// Install (or clear) a time-varying condition override. Affects only
    /// the bandwidth/RTT terms; jitter and retransmission draws consume
    /// the same PRNG stream either way.
    pub fn set_profile(&mut self, profile: Option<LinkProfile>) {
        self.profile = profile;
    }

    pub fn profile(&self) -> Option<LinkProfile> {
        self.profile
    }

    /// Bandwidth in force right now (profile override or nominal).
    pub fn effective_bw_mbps(&self) -> f64 {
        self.profile.map_or(self.cfg.bw_mbps, |p| p.bw_mbps)
    }

    /// RTT in force right now (profile override or nominal).
    pub fn effective_rtt_ms(&self) -> f64 {
        self.profile.map_or(self.cfg.rtt_ms, |p| p.rtt_ms)
    }

    /// One-way transfer of `bytes` under scene clarity in (0, 1].
    pub fn transfer(&mut self, bytes: f64, clarity: f64) -> Transfer {
        let base =
            bytes * 8.0 / (self.effective_bw_mbps() * 1e6) * 1e3 + self.effective_rtt_ms() / 2.0;
        let mut ms = base * (1.0 + self.cfg.jitter * self.rng.normal()).max(0.2);
        // degraded frames are re-sent: each retransmission repeats the
        // payload time (geometric, clarity-gated)
        let p = (self.cfg.noise_retrans * (1.0 - clarity.clamp(0.0, 1.0))).clamp(0.0, 0.9);
        let mut retrans = 0u32;
        while retrans < 8 && self.rng.chance(p) {
            ms += base;
            retrans += 1;
        }
        self.total_bytes += bytes * (1.0 + retrans as f64);
        self.total_retrans += retrans as u64;
        Transfer { ms, retransmissions: retrans }
    }

    /// Full offload round trip: observation up, chunk down.
    pub fn offload_roundtrip(
        &mut self,
        obs_bytes: f64,
        chunk_bytes: f64,
        clarity: f64,
    ) -> Transfer {
        let up = self.transfer(obs_bytes, clarity);
        let down = self.transfer(chunk_bytes, 1.0); // the reply is tiny/clean
        Transfer { ms: up.ms + down.ms, retransmissions: up.retransmissions + down.retransmissions }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link(seed: u64) -> Link {
        Link::new(&LinkConfig::default(), seed)
    }

    #[test]
    fn clean_transfer_near_nominal() {
        // deterministic: replay the link's own seeded jitter stream and
        // pin every transfer exactly (no statistical tolerance to deflake)
        let cfg = LinkConfig::default();
        let mut l = link(1);
        let mut replay = Pcg32::new(1, 0x11_4E);
        let bytes = 1.5e6;
        let base = bytes * 8.0 / (cfg.bw_mbps * 1e6) * 1e3 + cfg.rtt_ms / 2.0;
        for i in 0..300 {
            let want = base * (1.0 + cfg.jitter * replay.normal()).max(0.2);
            // the retransmission gate draws once even at clarity 1.0
            assert!(!replay.chance(0.0));
            let got = l.transfer(bytes, 1.0).ms;
            assert!((got - want).abs() < 1e-9, "transfer {i}: got {got} want {want}");
            // and the jittered value stays anchored near nominal
            assert!(got > 0.0 && got < base * 2.0, "transfer {i}: {got} vs base {base}");
        }
    }

    #[test]
    fn degraded_profile_slows_transfers_and_clears() {
        let mut nominal = link(7);
        let mut degraded = link(7); // same seed -> same jitter stream
        degraded.set_profile(Some(LinkProfile { bw_mbps: 50.0, rtt_ms: 80.0 }));
        for _ in 0..50 {
            let a = nominal.transfer(1.5e6, 1.0).ms;
            let b = degraded.transfer(1.5e6, 1.0).ms;
            assert!(b > a, "degraded {b} <= nominal {a}");
        }
        assert_eq!(degraded.effective_bw_mbps(), 50.0);
        degraded.set_profile(None);
        assert_eq!(degraded.effective_bw_mbps(), LinkConfig::default().bw_mbps);
        // identical PRNG consumption under a profile: clearing it re-syncs
        // the two links exactly
        let a = nominal.transfer(2e6, 1.0);
        let b = degraded.transfer(2e6, 1.0);
        assert_eq!(a.ms, b.ms);
        assert_eq!(a.retransmissions, b.retransmissions);
    }

    #[test]
    fn clean_scene_no_retransmissions() {
        let mut l = link(2);
        for _ in 0..200 {
            assert_eq!(l.transfer(1e6, 1.0).retransmissions, 0);
        }
    }

    #[test]
    fn occlusion_causes_retransmissions() {
        let mut l = link(3);
        let total: u32 = (0..300).map(|_| l.transfer(1e6, 0.2).retransmissions).sum();
        assert!(total > 20, "retrans {total}");
    }

    #[test]
    fn bigger_payloads_take_longer() {
        let mut l = link(4);
        let small: f64 = (0..100).map(|_| l.transfer(1e5, 1.0).ms).sum::<f64>();
        let big: f64 = (0..100).map(|_| l.transfer(6e6, 1.0).ms).sum::<f64>();
        assert!(big > small * 2.0);
    }

    #[test]
    fn accounting_accumulates() {
        let mut l = link(5);
        l.transfer(1e6, 0.1);
        l.transfer(1e6, 0.1);
        assert!(l.total_bytes >= 2e6);
    }
}

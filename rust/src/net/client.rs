//! Blocking TCP client used by the edge process to query the cloud server.

use super::proto::{self, Frame, InferRequest, ProtoError};
use crate::vla::ModelOut;
use crate::{D_PROP, D_VIS};
use std::net::TcpStream;
use std::time::{Duration, Instant};

pub struct CloudClient {
    stream: TcpStream,
    /// Measured request round-trip times (µs).
    pub rtts_us: Vec<u64>,
    /// Reusable encode buffer: batch frames are built here in place, so
    /// the steady-state dispatch path allocates nothing per flush.
    buf: Vec<u8>,
}

impl CloudClient {
    pub fn connect(addr: &str) -> std::io::Result<CloudClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(CloudClient { stream, rtts_us: Vec::new(), buf: Vec::new() })
    }

    /// Round-trip an inference request.
    pub fn infer(
        &mut self,
        obs: &[f32; D_VIS],
        proprio: &[f32; D_PROP],
        instr: usize,
    ) -> Result<ModelOut, ProtoError> {
        let t0 = Instant::now();
        let req = InferRequest { instr: instr as u32, obs: *obs, proprio: *proprio };
        proto::write_all(&mut self.stream, &proto::encode_infer(&req))?;
        match proto::read_frame(&mut self.stream)? {
            Frame::Result(out) => {
                self.rtts_us.push(t0.elapsed().as_micros() as u64);
                Ok(out)
            }
            other => Err(ProtoError::Malformed(format!("expected result, got {other:?}"))),
        }
    }

    /// Round-trip a *cross-session* batch: each item is (session id,
    /// request); the response echoes the ids in request order. One wire
    /// frame each way regardless of how many sessions are aboard.
    pub fn infer_batch(
        &mut self,
        items: &[(u32, InferRequest)],
    ) -> Result<Vec<(u32, ModelOut)>, ProtoError> {
        let t0 = Instant::now();
        proto::encode_batch_infer_into(&mut self.buf, items);
        proto::write_all(&mut self.stream, &self.buf)?;
        match proto::read_frame(&mut self.stream)? {
            Frame::BatchResult(outs) => {
                if outs.len() != items.len() {
                    return Err(ProtoError::Malformed(format!(
                        "batch result arity {} != {}",
                        outs.len(),
                        items.len()
                    )));
                }
                for ((got, _), (want, _)) in outs.iter().zip(items.iter()) {
                    if got != want {
                        return Err(ProtoError::Malformed(format!(
                            "batch result session {got} out of order (want {want})"
                        )));
                    }
                }
                self.rtts_us.push(t0.elapsed().as_micros() as u64);
                Ok(outs)
            }
            other => Err(ProtoError::Malformed(format!("expected batch result, got {other:?}"))),
        }
    }

    /// Round-trip a *family-tagged* cross-session batch (model-zoo path):
    /// the response must echo the family and the session ids in request
    /// order, so a chunk produced under the wrong frame layout can never
    /// be installed.
    pub fn infer_batch_zoo(
        &mut self,
        family: crate::vla::ModelFamily,
        items: &[(u32, InferRequest)],
    ) -> Result<Vec<(u32, ModelOut)>, ProtoError> {
        let t0 = Instant::now();
        proto::encode_zoo_batch_infer_into(&mut self.buf, family.id(), items);
        proto::write_all(&mut self.stream, &self.buf)?;
        match proto::read_frame(&mut self.stream)? {
            Frame::ZooBatchResult(fam, outs) => {
                if fam != family.id() {
                    return Err(ProtoError::Malformed(format!(
                        "zoo result family {fam} != {}",
                        family.id()
                    )));
                }
                if outs.len() != items.len() {
                    return Err(ProtoError::Malformed(format!(
                        "zoo batch result arity {} != {}",
                        outs.len(),
                        items.len()
                    )));
                }
                let want_k = crate::vla::FamilyProfile::of(family).chunk_len;
                for ((got, out), (want, _)) in outs.iter().zip(items.iter()) {
                    if got != want {
                        return Err(ProtoError::Malformed(format!(
                            "zoo batch result session {got} out of order (want {want})"
                        )));
                    }
                    // a non-conforming server must not install chunks of
                    // the wrong frame layout into a family's session
                    if out.chunk_len() != want_k {
                        return Err(ProtoError::Malformed(format!(
                            "zoo result chunk length {} != family {} chunk {want_k}",
                            out.chunk_len(),
                            family.name()
                        )));
                    }
                }
                self.rtts_us.push(t0.elapsed().as_micros() as u64);
                Ok(outs)
            }
            other => {
                Err(ProtoError::Malformed(format!("expected zoo batch result, got {other:?}")))
            }
        }
    }

    /// Liveness probe; returns measured RTT.
    pub fn ping(&mut self) -> Result<Duration, ProtoError> {
        let t0 = Instant::now();
        proto::write_all(&mut self.stream, &proto::encode_tag(proto::TAG_PING))?;
        match proto::read_frame(&mut self.stream)? {
            Frame::Pong => Ok(t0.elapsed()),
            other => Err(ProtoError::Malformed(format!("expected pong, got {other:?}"))),
        }
    }

    /// Ask the server to stop accepting connections.
    pub fn shutdown_server(&mut self) -> Result<(), ProtoError> {
        proto::write_all(&mut self.stream, &proto::encode_tag(proto::TAG_SHUTDOWN))
    }

    pub fn mean_rtt_us(&self) -> f64 {
        if self.rtts_us.is_empty() {
            0.0
        } else {
            self.rtts_us.iter().sum::<u64>() as f64 / self.rtts_us.len() as f64
        }
    }
}

/// A [`CloudClient`] is itself a model backend: inference over the wire.
/// This is what makes `examples/serve_cluster.rs` a *real* end-to-end
/// edge-cloud deployment — the episode driver's cloud calls leave the
/// process over TCP and hit the PJRT-backed server.
impl crate::vla::Backend for CloudClient {
    fn name(&self) -> &str {
        "cloud-tcp"
    }

    fn infer(
        &mut self,
        obs: &[f32; D_VIS],
        proprio: &[f32; D_PROP],
        instr: usize,
    ) -> crate::vla::ModelOut {
        CloudClient::infer(self, obs, proprio, instr).expect("cloud RPC failed")
    }

    fn mean_us(&self) -> f64 {
        self.mean_rtt_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::server::CloudServer;
    use crate::vla::AnalyticBackend;

    #[test]
    fn end_to_end_tcp_roundtrip() {
        let server =
            CloudServer::start("127.0.0.1:0", 4, || Box::new(AnalyticBackend::cloud(1))).unwrap();
        let addr = server.addr.to_string();
        let mut client = CloudClient::connect(&addr).unwrap();
        assert!(client.ping().is_ok());

        let mut obs = [0f32; D_VIS];
        obs[0] = 0.4;
        obs[7] = 0.9;
        let out = client.infer(&obs, &[0.0; D_PROP], 1).unwrap();
        assert_eq!(out.actions.len(), crate::CHUNK);
        assert!(out.mass.iter().all(|m| m.is_finite()));
        assert!(client.mean_rtt_us() > 0.0);
        server.shutdown();
    }

    #[test]
    fn batch_rpc_matches_sequential_singles_and_preserves_sessions() {
        // server A serves one cross-session batch; server B (identically
        // seeded backend) serves the same requests one at a time — the
        // pairwise-equal responses prove the batch path preserves request
        // order and never mixes sessions
        let a =
            CloudServer::start("127.0.0.1:0", 8, || Box::new(AnalyticBackend::cloud(42))).unwrap();
        let b =
            CloudServer::start("127.0.0.1:0", 8, || Box::new(AnalyticBackend::cloud(42))).unwrap();
        let mut ca = CloudClient::connect(&a.addr.to_string()).unwrap();
        let mut cb = CloudClient::connect(&b.addr.to_string()).unwrap();
        let items: Vec<(u32, InferRequest)> = (0..5u32)
            .map(|i| {
                let mut obs = [0f32; D_VIS];
                obs[0] = 0.1 * i as f32 + 0.1;
                obs[7] = 0.3;
                (100 + i, InferRequest { instr: i, obs, proprio: [0.0; D_PROP] })
            })
            .collect();
        let outs = ca.infer_batch(&items).unwrap();
        assert_eq!(outs.len(), items.len());
        for ((sid, out), (want_sid, req)) in outs.iter().zip(items.iter()) {
            assert_eq!(sid, want_sid);
            let solo = cb.infer(&req.obs, &req.proprio, req.instr as usize).unwrap();
            assert_eq!(out.mass, solo.mass);
            assert_eq!(out.actions, solo.actions);
        }
        assert_eq!(a.stats().batch_frames.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(a.stats().requests.load(std::sync::atomic::Ordering::Relaxed), 5);
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn zoo_batch_rpc_shapes_and_echoes_the_family() {
        use crate::vla::{FamilyProfile, ModelFamily};
        let server =
            CloudServer::start("127.0.0.1:0", 8, || Box::new(AnalyticBackend::cloud(42))).unwrap();
        let mut c = CloudClient::connect(&server.addr.to_string()).unwrap();
        let items: Vec<(u32, InferRequest)> = (0..3u32)
            .map(|i| {
                let mut obs = [0f32; D_VIS];
                obs[0] = 0.1 * i as f32 + 0.1;
                (i, InferRequest { instr: i, obs, proprio: [0.0; D_PROP] })
            })
            .collect();
        // AR family: the server must truncate every reply to 4 actions
        let outs = c.infer_batch_zoo(ModelFamily::OpenVlaAr, &items).unwrap();
        assert_eq!(outs.len(), 3);
        for (sid, out) in &outs {
            assert!(*sid < 3);
            assert_eq!(out.chunk_len(), FamilyProfile::of(ModelFamily::OpenVlaAr).chunk_len);
        }
        // surrogate family over the zoo path: full-length chunks
        let outs = c.infer_batch_zoo(ModelFamily::Surrogate, &items).unwrap();
        assert_eq!(outs[0].1.chunk_len(), crate::CHUNK);
        assert_eq!(server.stats().zoo_frames.load(std::sync::atomic::Ordering::Relaxed), 2);
        server.shutdown();
    }

    #[test]
    fn multiple_clients_served() {
        let server =
            CloudServer::start("127.0.0.1:0", 4, || Box::new(AnalyticBackend::cloud(2))).unwrap();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = CloudClient::connect(&addr).unwrap();
                    let mut obs = [0f32; D_VIS];
                    obs[0] = 0.1 * i as f32;
                    for _ in 0..5 {
                        let out = c.infer(&obs, &[0.0; D_PROP], i).unwrap();
                        assert_eq!(out.actions.len(), crate::CHUNK);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats().requests.load(std::sync::atomic::Ordering::Relaxed), 20);
        server.shutdown();
    }
}

//! Wire protocol for the real TCP edge↔cloud path.
//!
//! Length-prefixed binary frames (little-endian):
//!
//! ```text
//! request : [u32 len][u8 tag=1][u32 instr][f32 obs[64]][f32 proprio[21]]
//! response: [u32 len][u8 tag=2][f32 actions[8*7]][f32 logits[8*64]][f32 mass[8]]
//! ping    : [u32 len][u8 tag=3]            -> pong [u32 len][u8 tag=4]
//! shutdown: [u32 len][u8 tag=5]
//! batch   : [u32 len][u8 tag=6][u16 n] n × ([u32 session][request body])
//! batchres: [u32 len][u8 tag=7][u16 n] n × ([u32 session][response body])
//! zoobatch: [u32 len][u8 tag=8][u8 family][u16 n] n × ([u32 session][request body])
//! zoores  : [u32 len][u8 tag=9][u8 family][u16 n] n × ([u32 session][u16 k][k-sized response body])
//! ```
//!
//! Batch frames carry *cross-session* coalesced cloud offloads: the fleet
//! scheduler stamps every sub-request with its session id and the server
//! echoes the ids back, so responses can never migrate between sessions
//! even when many robots share one connection.
//!
//! Zoo batch frames additionally carry a **model-family tag** — one per
//! frame, not per item, because the fleet's family-keyed batching
//! guarantees a batch never mixes families — and their responses are
//! `k`-sized (a family's chunk length may be shorter than [`CHUNK`]). The
//! server echoes the family so an edge can never install a chunk produced
//! under the wrong frame layout.

use crate::vla::ModelOut;
use crate::{CHUNK, D_PROP, D_VIS, N_JOINTS, VOCAB};
use std::io::{Read, Write};

pub const TAG_INFER: u8 = 1;
pub const TAG_RESULT: u8 = 2;
pub const TAG_PING: u8 = 3;
pub const TAG_PONG: u8 = 4;
pub const TAG_SHUTDOWN: u8 = 5;
pub const TAG_BATCH_INFER: u8 = 6;
pub const TAG_BATCH_RESULT: u8 = 7;
pub const TAG_ZOO_BATCH_INFER: u8 = 8;
pub const TAG_ZOO_BATCH_RESULT: u8 = 9;

/// Hard cap on sub-requests per batch frame (well above any sane fleet).
pub const MAX_BATCH_ITEMS: usize = 4096;

#[derive(Debug)]
pub enum ProtoError {
    Io(std::io::Error),
    Malformed(String),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "io: {e}"),
            ProtoError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for ProtoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ProtoError::Io(e) => Some(e),
            ProtoError::Malformed(_) => None,
        }
    }
}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        ProtoError::Io(e)
    }
}

/// An inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    pub instr: u32,
    pub obs: [f32; D_VIS],
    pub proprio: [f32; D_PROP],
}

/// Any decoded frame.
#[derive(Debug)]
pub enum Frame {
    Infer(InferRequest),
    Result(ModelOut),
    Ping,
    Pong,
    Shutdown,
    /// Cross-session coalesced requests: (session id, request) pairs.
    BatchInfer(Vec<(u32, InferRequest)>),
    /// Per-session responses in request order: (session id, output) pairs.
    BatchResult(Vec<(u32, ModelOut)>),
    /// Family-tagged batch: every request serves the same model family.
    ZooBatchInfer(u8, Vec<(u32, InferRequest)>),
    /// Family-tagged responses (chunks may be shorter than [`CHUNK`]).
    ZooBatchResult(u8, Vec<(u32, ModelOut)>),
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(b: &[u8], n: usize) -> Result<(Vec<f32>, &[u8]), ProtoError> {
    if b.len() < 4 * n {
        return Err(ProtoError::Malformed(format!("need {} f32, have {} bytes", n, b.len())));
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(f32::from_le_bytes([b[4 * i], b[4 * i + 1], b[4 * i + 2], b[4 * i + 3]]));
    }
    Ok((out, &b[4 * n..]))
}

fn put_infer_body(body: &mut Vec<u8>, req: &InferRequest) {
    body.extend_from_slice(&req.instr.to_le_bytes());
    put_f32s(body, &req.obs);
    put_f32s(body, &req.proprio);
}

fn put_result_body(body: &mut Vec<u8>, out: &ModelOut) {
    for a in &out.actions {
        for j in 0..N_JOINTS {
            body.extend_from_slice(&(a[j] as f32).to_le_bytes());
        }
    }
    for row in &out.logits {
        put_f32s(body, row);
    }
    for m in &out.mass {
        body.extend_from_slice(&(*m as f32).to_le_bytes());
    }
}

/// Begin a frame in `buf`: clear it and reserve the 4-byte length slot.
/// Returns the slot offset for [`end_frame`]. Reusing one long-lived
/// buffer across calls keeps steady-state batch traffic allocation-free
/// (the buffer grows to the largest frame ever encoded and stays there).
fn begin_frame(buf: &mut Vec<u8>) -> usize {
    buf.clear();
    let at = buf.len();
    buf.extend_from_slice(&[0u8; 4]);
    at
}

/// Patch the length slot reserved by [`begin_frame`] with the number of
/// body bytes appended since.
fn end_frame(buf: &mut Vec<u8>, at: usize) {
    let len = (buf.len() - at - 4) as u32;
    buf[at..at + 4].copy_from_slice(&len.to_le_bytes());
}

pub fn encode_infer(req: &InferRequest) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_infer_into(&mut buf, req);
    buf
}

/// Encode an inference request into a reusable buffer (cleared first).
pub fn encode_infer_into(buf: &mut Vec<u8>, req: &InferRequest) {
    let at = begin_frame(buf);
    buf.push(TAG_INFER);
    put_infer_body(buf, req);
    end_frame(buf, at);
}

pub fn encode_result(out: &ModelOut) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_result_into(&mut buf, out);
    buf
}

/// Encode a response into a reusable buffer (cleared first).
pub fn encode_result_into(buf: &mut Vec<u8>, out: &ModelOut) {
    let at = begin_frame(buf);
    buf.push(TAG_RESULT);
    put_result_body(buf, out);
    end_frame(buf, at);
}

/// Body bytes of one encoded inference request (instr + obs + proprio).
pub const INFER_BODY_BYTES: usize = 4 + 4 * D_VIS + 4 * D_PROP;

/// Exact wire length in bytes of a batch-infer frame of `n` items —
/// computed from the layout, not by encoding, so the span tracer can tag
/// wire spans with payload sizes without touching a buffer (pinned
/// against the real encoder in the tests below).
pub fn batch_infer_frame_len(n: usize) -> usize {
    4 + 1 + 2 + n * (4 + INFER_BODY_BYTES)
}

/// Exact wire length in bytes of a zoo batch-infer frame of `n` items
/// (one extra family byte in the header).
pub fn zoo_batch_infer_frame_len(n: usize) -> usize {
    4 + 1 + 1 + 2 + n * (4 + INFER_BODY_BYTES)
}

/// Encode a cross-session request batch; items are (session id, request).
pub fn encode_batch_infer(items: &[(u32, InferRequest)]) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_batch_infer_into(&mut buf, items);
    buf
}

/// [`encode_batch_infer`] into a reusable buffer (cleared first) — the
/// client's batch hot path, one allocation-free frame per flush.
pub fn encode_batch_infer_into(buf: &mut Vec<u8>, items: &[(u32, InferRequest)]) {
    assert!(items.len() <= MAX_BATCH_ITEMS, "batch too large: {}", items.len());
    let at = begin_frame(buf);
    buf.push(TAG_BATCH_INFER);
    buf.extend_from_slice(&(items.len() as u16).to_le_bytes());
    for (session, req) in items {
        buf.extend_from_slice(&session.to_le_bytes());
        put_infer_body(buf, req);
    }
    end_frame(buf, at);
}

/// Encode a response batch; items are (session id, output) in request order.
pub fn encode_batch_result(items: &[(u32, ModelOut)]) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_batch_result_into(&mut buf, items);
    buf
}

/// [`encode_batch_result`] into a reusable buffer (cleared first) — the
/// server's reply hot path.
pub fn encode_batch_result_into(buf: &mut Vec<u8>, items: &[(u32, ModelOut)]) {
    assert!(items.len() <= MAX_BATCH_ITEMS, "batch too large: {}", items.len());
    let at = begin_frame(buf);
    buf.push(TAG_BATCH_RESULT);
    buf.extend_from_slice(&(items.len() as u16).to_le_bytes());
    for (session, out) in items {
        buf.extend_from_slice(&session.to_le_bytes());
        put_result_body(buf, out);
    }
    end_frame(buf, at);
}

/// Encode a family-tagged request batch (one family per frame — the
/// fleet's family-keyed batching never mixes them).
pub fn encode_zoo_batch_infer(family: u8, items: &[(u32, InferRequest)]) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_zoo_batch_infer_into(&mut buf, family, items);
    buf
}

/// [`encode_zoo_batch_infer`] into a reusable buffer (cleared first).
pub fn encode_zoo_batch_infer_into(buf: &mut Vec<u8>, family: u8, items: &[(u32, InferRequest)]) {
    assert!(items.len() <= MAX_BATCH_ITEMS, "batch too large: {}", items.len());
    let at = begin_frame(buf);
    buf.push(TAG_ZOO_BATCH_INFER);
    buf.push(family);
    buf.extend_from_slice(&(items.len() as u16).to_le_bytes());
    for (session, req) in items {
        buf.extend_from_slice(&session.to_le_bytes());
        put_infer_body(buf, req);
    }
    end_frame(buf, at);
}

/// Encode a family-tagged response batch; each item carries its explicit
/// chunk length `k` (zoo families may emit short chunks).
pub fn encode_zoo_batch_result(family: u8, items: &[(u32, ModelOut)]) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_zoo_batch_result_into(&mut buf, family, items);
    buf
}

/// [`encode_zoo_batch_result`] into a reusable buffer (cleared first).
pub fn encode_zoo_batch_result_into(buf: &mut Vec<u8>, family: u8, items: &[(u32, ModelOut)]) {
    assert!(items.len() <= MAX_BATCH_ITEMS, "batch too large: {}", items.len());
    let at = begin_frame(buf);
    buf.push(TAG_ZOO_BATCH_RESULT);
    buf.push(family);
    buf.extend_from_slice(&(items.len() as u16).to_le_bytes());
    for (session, out) in items {
        let k = out.actions.len();
        assert!(k >= 1 && k <= CHUNK, "chunk length {k}");
        assert_eq!(out.logits.len(), k, "ragged logits");
        assert_eq!(out.mass.len(), k, "ragged mass");
        buf.extend_from_slice(&session.to_le_bytes());
        buf.extend_from_slice(&(k as u16).to_le_bytes());
        put_result_body(buf, out);
    }
    end_frame(buf, at);
}

pub fn encode_tag(tag: u8) -> Vec<u8> {
    let mut buf = Vec::with_capacity(5);
    let at = begin_frame(&mut buf);
    buf.push(tag);
    end_frame(&mut buf, at);
    buf
}

/// Read one frame from a stream.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, ProtoError> {
    let mut len_b = [0u8; 4];
    r.read_exact(&mut len_b)?;
    let len = u32::from_le_bytes(len_b) as usize;
    if len == 0 || len > 16 * 1024 * 1024 {
        return Err(ProtoError::Malformed(format!("bad frame length {len}")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode(&body)
}

fn get_u32(b: &[u8]) -> Result<(u32, &[u8]), ProtoError> {
    if b.len() < 4 {
        return Err(ProtoError::Malformed("short u32".into()));
    }
    Ok((u32::from_le_bytes([b[0], b[1], b[2], b[3]]), &b[4..]))
}

fn get_infer_body(b: &[u8]) -> Result<(InferRequest, &[u8]), ProtoError> {
    let (instr, rest) = get_u32(b)?;
    let (obs_v, rest) = get_f32s(rest, D_VIS)?;
    let (prop_v, rest) = get_f32s(rest, D_PROP)?;
    let mut obs = [0f32; D_VIS];
    obs.copy_from_slice(&obs_v);
    let mut proprio = [0f32; D_PROP];
    proprio.copy_from_slice(&prop_v);
    Ok((InferRequest { instr, obs, proprio }, rest))
}

fn get_result_body(b: &[u8]) -> Result<(ModelOut, &[u8]), ProtoError> {
    get_result_body_k(CHUNK, b)
}

fn get_result_body_k(k: usize, b: &[u8]) -> Result<(ModelOut, &[u8]), ProtoError> {
    let (a, rest) = get_f32s(b, k * N_JOINTS)?;
    let (l, rest) = get_f32s(rest, k * VOCAB)?;
    let (m, rest) = get_f32s(rest, k)?;
    Ok((ModelOut::from_flat_k(k, &a, &l, &m), rest))
}

fn get_u16(b: &[u8]) -> Result<(usize, &[u8]), ProtoError> {
    if b.len() < 2 {
        return Err(ProtoError::Malformed("short u16".into()));
    }
    Ok((u16::from_le_bytes([b[0], b[1]]) as usize, &b[2..]))
}

fn get_batch_count(b: &[u8]) -> Result<(usize, &[u8]), ProtoError> {
    let (n, rest) = get_u16(b)?;
    if n == 0 || n > MAX_BATCH_ITEMS {
        return Err(ProtoError::Malformed(format!("bad batch count {n}")));
    }
    Ok((n, rest))
}

pub fn decode(body: &[u8]) -> Result<Frame, ProtoError> {
    match body.first() {
        Some(&TAG_INFER) => {
            let (req, rest) = get_infer_body(&body[1..])?;
            if !rest.is_empty() {
                return Err(ProtoError::Malformed("trailing bytes in infer".into()));
            }
            Ok(Frame::Infer(req))
        }
        Some(&TAG_RESULT) => {
            let (out, rest) = get_result_body(&body[1..])?;
            if !rest.is_empty() {
                return Err(ProtoError::Malformed("trailing bytes in result".into()));
            }
            Ok(Frame::Result(out))
        }
        Some(&TAG_PING) => Ok(Frame::Ping),
        Some(&TAG_PONG) => Ok(Frame::Pong),
        Some(&TAG_SHUTDOWN) => Ok(Frame::Shutdown),
        Some(&TAG_BATCH_INFER) => {
            let (n, mut rest) = get_batch_count(&body[1..])?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let (session, r) = get_u32(rest)?;
                let (req, r) = get_infer_body(r)?;
                items.push((session, req));
                rest = r;
            }
            if !rest.is_empty() {
                return Err(ProtoError::Malformed("trailing bytes in batch infer".into()));
            }
            Ok(Frame::BatchInfer(items))
        }
        Some(&TAG_BATCH_RESULT) => {
            let (n, mut rest) = get_batch_count(&body[1..])?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let (session, r) = get_u32(rest)?;
                let (out, r) = get_result_body(r)?;
                items.push((session, out));
                rest = r;
            }
            if !rest.is_empty() {
                return Err(ProtoError::Malformed("trailing bytes in batch result".into()));
            }
            Ok(Frame::BatchResult(items))
        }
        Some(&TAG_ZOO_BATCH_INFER) => {
            if body.len() < 2 {
                return Err(ProtoError::Malformed("short zoo batch".into()));
            }
            let family = body[1];
            let (n, mut rest) = get_batch_count(&body[2..])?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let (session, r) = get_u32(rest)?;
                let (req, r) = get_infer_body(r)?;
                items.push((session, req));
                rest = r;
            }
            if !rest.is_empty() {
                return Err(ProtoError::Malformed("trailing bytes in zoo batch".into()));
            }
            Ok(Frame::ZooBatchInfer(family, items))
        }
        Some(&TAG_ZOO_BATCH_RESULT) => {
            if body.len() < 2 {
                return Err(ProtoError::Malformed("short zoo result".into()));
            }
            let family = body[1];
            let (n, mut rest) = get_batch_count(&body[2..])?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let (session, r) = get_u32(rest)?;
                let (k, r) = get_u16(r)?;
                if k == 0 || k > CHUNK {
                    return Err(ProtoError::Malformed(format!("bad chunk length {k}")));
                }
                let (out, r) = get_result_body_k(k, r)?;
                items.push((session, out));
                rest = r;
            }
            if !rest.is_empty() {
                return Err(ProtoError::Malformed("trailing bytes in zoo result".into()));
            }
            Ok(Frame::ZooBatchResult(family, items))
        }
        other => Err(ProtoError::Malformed(format!("unknown tag {other:?}"))),
    }
}

pub fn write_all(w: &mut impl Write, bytes: &[u8]) -> Result<(), ProtoError> {
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_len_helpers_match_the_encoder() {
        for n in [0usize, 1, 4, 64] {
            let items: Vec<(u32, InferRequest)> = (0..n as u32)
                .map(|i| {
                    (i, InferRequest { instr: i, obs: [0.0; D_VIS], proprio: [0.0; D_PROP] })
                })
                .collect();
            assert_eq!(
                encode_batch_infer(&items).len(),
                batch_infer_frame_len(n),
                "batch n={n}"
            );
            assert_eq!(
                encode_zoo_batch_infer(2, &items).len(),
                zoo_batch_infer_frame_len(n),
                "zoo batch n={n}"
            );
        }
    }

    #[test]
    fn infer_roundtrip() {
        let req = InferRequest { instr: 3, obs: [0.5; D_VIS], proprio: [-0.25; D_PROP] };
        let bytes = encode_infer(&req);
        let mut cursor = std::io::Cursor::new(bytes);
        match read_frame(&mut cursor).unwrap() {
            Frame::Infer(got) => assert_eq!(got, req),
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn result_roundtrip() {
        let a: Vec<f32> = (0..CHUNK * N_JOINTS).map(|i| i as f32 * 0.1).collect();
        let l: Vec<f32> = (0..CHUNK * VOCAB).map(|i| (i % 13) as f32).collect();
        let m: Vec<f32> = (0..CHUNK).map(|i| i as f32).collect();
        let out = ModelOut::from_flat(&a, &l, &m);
        let bytes = encode_result(&out);
        let mut cursor = std::io::Cursor::new(bytes);
        match read_frame(&mut cursor).unwrap() {
            Frame::Result(got) => {
                assert_eq!(got.mass, out.mass);
                assert!((got.actions[2][3] - out.actions[2][3]).abs() < 1e-6);
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn ping_pong() {
        let mut c = std::io::Cursor::new(encode_tag(TAG_PING));
        assert!(matches!(read_frame(&mut c).unwrap(), Frame::Ping));
    }

    #[test]
    fn rejects_garbage() {
        let mut c = std::io::Cursor::new(vec![5, 0, 0, 0, 99, 0, 0, 0, 0]);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn rejects_absurd_length() {
        let mut bytes = (64 * 1024 * 1024u32).to_le_bytes().to_vec();
        bytes.push(1);
        let mut c = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn batch_infer_roundtrip_preserves_sessions_and_order() {
        let items: Vec<(u32, InferRequest)> = (0..5)
            .map(|i| {
                let mut obs = [0f32; D_VIS];
                obs[0] = i as f32 * 0.1;
                (10 + i, InferRequest { instr: i, obs, proprio: [i as f32; D_PROP] })
            })
            .collect();
        let bytes = encode_batch_infer(&items);
        let mut c = std::io::Cursor::new(bytes);
        match read_frame(&mut c).unwrap() {
            Frame::BatchInfer(got) => {
                assert_eq!(got.len(), items.len());
                for ((sid, req), (esid, ereq)) in got.iter().zip(items.iter()) {
                    assert_eq!(sid, esid);
                    assert_eq!(req, ereq);
                }
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn batch_result_roundtrip() {
        let mk = |v: f32| {
            let a: Vec<f32> = (0..CHUNK * N_JOINTS).map(|i| v + i as f32 * 0.01).collect();
            let l: Vec<f32> = (0..CHUNK * VOCAB).map(|i| (i % 5) as f32).collect();
            let m: Vec<f32> = (0..CHUNK).map(|i| v + i as f32).collect();
            ModelOut::from_flat(&a, &l, &m)
        };
        let items = vec![(3u32, mk(0.5)), (7u32, mk(2.0))];
        let bytes = encode_batch_result(&items);
        let mut c = std::io::Cursor::new(bytes);
        match read_frame(&mut c).unwrap() {
            Frame::BatchResult(got) => {
                assert_eq!(got.len(), 2);
                assert_eq!(got[0].0, 3);
                assert_eq!(got[1].0, 7);
                assert_eq!(got[0].1.mass, items[0].1.mass);
                assert_eq!(got[1].1.mass, items[1].1.mass);
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn zoo_batch_roundtrip_echoes_family_and_short_chunks() {
        let mk = |k: usize, v: f32| {
            let a: Vec<f32> = (0..k * N_JOINTS).map(|i| v + i as f32 * 0.01).collect();
            let l: Vec<f32> = (0..k * VOCAB).map(|i| (i % 5) as f32).collect();
            let m: Vec<f32> = (0..k).map(|i| v + i as f32).collect();
            ModelOut::from_flat_k(k, &a, &l, &m)
        };
        // request side
        let items: Vec<(u32, InferRequest)> = (0..3u32)
            .map(|i| (i, InferRequest { instr: i, obs: [0.1; D_VIS], proprio: [0.2; D_PROP] }))
            .collect();
        let bytes = encode_zoo_batch_infer(2, &items);
        let mut c = std::io::Cursor::new(bytes);
        match read_frame(&mut c).unwrap() {
            Frame::ZooBatchInfer(fam, got) => {
                assert_eq!(fam, 2);
                assert_eq!(got.len(), 3);
                assert_eq!(got[1].1, items[1].1);
            }
            other => panic!("wrong frame {other:?}"),
        }
        // response side: 4-action chunks survive the wire intact
        let outs = vec![(7u32, mk(4, 0.5)), (9u32, mk(4, 1.5))];
        let bytes = encode_zoo_batch_result(1, &outs);
        let mut c = std::io::Cursor::new(bytes);
        match read_frame(&mut c).unwrap() {
            Frame::ZooBatchResult(fam, got) => {
                assert_eq!(fam, 1);
                assert_eq!(got.len(), 2);
                assert_eq!(got[0].0, 7);
                assert_eq!(got[0].1.chunk_len(), 4);
                assert_eq!(got[1].1.mass, outs[1].1.mass);
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn encode_into_reuses_the_buffer_and_matches_fresh_encodes() {
        let items: Vec<(u32, InferRequest)> = (0..4)
            .map(|i| (i, InferRequest { instr: i, obs: [0.3; D_VIS], proprio: [0.7; D_PROP] }))
            .collect();
        let mut buf = Vec::new();
        encode_batch_infer_into(&mut buf, &items);
        assert_eq!(buf, encode_batch_infer(&items), "into-variant must be byte-identical");
        let grown = buf.capacity();
        // a smaller frame into the same buffer: same bytes as a fresh
        // encode, and the backing allocation is reused, not reallocated
        encode_batch_infer_into(&mut buf, &items[..1]);
        assert_eq!(buf, encode_batch_infer(&items[..1]));
        assert_eq!(buf.capacity(), grown, "steady-state reuse must not reallocate");
        // zoo framing through the same reusable buffer
        encode_zoo_batch_infer_into(&mut buf, 2, &items);
        assert_eq!(buf, encode_zoo_batch_infer(2, &items));
        let mut c = std::io::Cursor::new(buf.clone());
        assert!(matches!(read_frame(&mut c).unwrap(), Frame::ZooBatchInfer(2, _)));
    }

    #[test]
    fn zoo_result_rejects_bad_chunk_lengths() {
        // hand-build a zoo result frame claiming k = CHUNK + 1
        let mut body = vec![TAG_ZOO_BATCH_RESULT, 0];
        body.extend_from_slice(&1u16.to_le_bytes());
        body.extend_from_slice(&3u32.to_le_bytes());
        body.extend_from_slice(&((CHUNK + 1) as u16).to_le_bytes());
        let mut bytes = (body.len() as u32).to_le_bytes().to_vec();
        bytes.append(&mut body);
        let mut c = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn rejects_zero_count_batch() {
        let mut body = vec![TAG_BATCH_INFER, 0, 0];
        let mut bytes = (body.len() as u32).to_le_bytes().to_vec();
        bytes.append(&mut body);
        let mut c = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut c).is_err());
    }
}

//! Wire protocol for the real TCP edge↔cloud path.
//!
//! Length-prefixed binary frames (little-endian):
//!
//! ```text
//! request : [u32 len][u8 tag=1][u32 instr][f32 obs[64]][f32 proprio[21]]
//! response: [u32 len][u8 tag=2][f32 actions[8*7]][f32 logits[8*64]][f32 mass[8]]
//! ping    : [u32 len][u8 tag=3]            -> pong [u32 len][u8 tag=4]
//! shutdown: [u32 len][u8 tag=5]
//! ```

use crate::vla::ModelOut;
use crate::{CHUNK, D_PROP, D_VIS, N_JOINTS, VOCAB};
use std::io::{Read, Write};

pub const TAG_INFER: u8 = 1;
pub const TAG_RESULT: u8 = 2;
pub const TAG_PING: u8 = 3;
pub const TAG_PONG: u8 = 4;
pub const TAG_SHUTDOWN: u8 = 5;

#[derive(Debug, thiserror::Error)]
pub enum ProtoError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed frame: {0}")]
    Malformed(String),
}

/// An inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    pub instr: u32,
    pub obs: [f32; D_VIS],
    pub proprio: [f32; D_PROP],
}

/// Any decoded frame.
#[derive(Debug)]
pub enum Frame {
    Infer(InferRequest),
    Result(ModelOut),
    Ping,
    Pong,
    Shutdown,
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(b: &[u8], n: usize) -> Result<(Vec<f32>, &[u8]), ProtoError> {
    if b.len() < 4 * n {
        return Err(ProtoError::Malformed(format!("need {} f32, have {} bytes", n, b.len())));
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(f32::from_le_bytes([b[4 * i], b[4 * i + 1], b[4 * i + 2], b[4 * i + 3]]));
    }
    Ok((out, &b[4 * n..]))
}

pub fn encode_infer(req: &InferRequest) -> Vec<u8> {
    let mut body = vec![TAG_INFER];
    body.extend_from_slice(&req.instr.to_le_bytes());
    put_f32s(&mut body, &req.obs);
    put_f32s(&mut body, &req.proprio);
    frame(body)
}

pub fn encode_result(out: &ModelOut) -> Vec<u8> {
    let mut body = vec![TAG_RESULT];
    for a in &out.actions {
        for j in 0..N_JOINTS {
            body.extend_from_slice(&(a[j] as f32).to_le_bytes());
        }
    }
    for row in &out.logits {
        put_f32s(&mut body, row);
    }
    for m in &out.mass {
        body.extend_from_slice(&(*m as f32).to_le_bytes());
    }
    frame(body)
}

pub fn encode_tag(tag: u8) -> Vec<u8> {
    frame(vec![tag])
}

fn frame(body: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Read one frame from a stream.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, ProtoError> {
    let mut len_b = [0u8; 4];
    r.read_exact(&mut len_b)?;
    let len = u32::from_le_bytes(len_b) as usize;
    if len == 0 || len > 16 * 1024 * 1024 {
        return Err(ProtoError::Malformed(format!("bad frame length {len}")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode(&body)
}

pub fn decode(body: &[u8]) -> Result<Frame, ProtoError> {
    match body.first() {
        Some(&TAG_INFER) => {
            let b = &body[1..];
            if b.len() < 4 {
                return Err(ProtoError::Malformed("short infer".into()));
            }
            let instr = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            let (obs_v, rest) = get_f32s(&b[4..], D_VIS)?;
            let (prop_v, rest) = get_f32s(rest, D_PROP)?;
            if !rest.is_empty() {
                return Err(ProtoError::Malformed("trailing bytes in infer".into()));
            }
            let mut obs = [0f32; D_VIS];
            obs.copy_from_slice(&obs_v);
            let mut proprio = [0f32; D_PROP];
            proprio.copy_from_slice(&prop_v);
            Ok(Frame::Infer(InferRequest { instr, obs, proprio }))
        }
        Some(&TAG_RESULT) => {
            let b = &body[1..];
            let (a, rest) = get_f32s(b, CHUNK * N_JOINTS)?;
            let (l, rest) = get_f32s(rest, CHUNK * VOCAB)?;
            let (m, rest) = get_f32s(rest, CHUNK)?;
            if !rest.is_empty() {
                return Err(ProtoError::Malformed("trailing bytes in result".into()));
            }
            Ok(Frame::Result(ModelOut::from_flat(&a, &l, &m)))
        }
        Some(&TAG_PING) => Ok(Frame::Ping),
        Some(&TAG_PONG) => Ok(Frame::Pong),
        Some(&TAG_SHUTDOWN) => Ok(Frame::Shutdown),
        other => Err(ProtoError::Malformed(format!("unknown tag {other:?}"))),
    }
}

pub fn write_all(w: &mut impl Write, bytes: &[u8]) -> Result<(), ProtoError> {
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_roundtrip() {
        let req = InferRequest { instr: 3, obs: [0.5; D_VIS], proprio: [-0.25; D_PROP] };
        let bytes = encode_infer(&req);
        let mut cursor = std::io::Cursor::new(bytes);
        match read_frame(&mut cursor).unwrap() {
            Frame::Infer(got) => assert_eq!(got, req),
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn result_roundtrip() {
        let a: Vec<f32> = (0..CHUNK * N_JOINTS).map(|i| i as f32 * 0.1).collect();
        let l: Vec<f32> = (0..CHUNK * VOCAB).map(|i| (i % 13) as f32).collect();
        let m: Vec<f32> = (0..CHUNK).map(|i| i as f32).collect();
        let out = ModelOut::from_flat(&a, &l, &m);
        let bytes = encode_result(&out);
        let mut cursor = std::io::Cursor::new(bytes);
        match read_frame(&mut cursor).unwrap() {
            Frame::Result(got) => {
                assert_eq!(got.mass, out.mass);
                assert!((got.actions[2][3] - out.actions[2][3]).abs() < 1e-6);
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn ping_pong() {
        let mut c = std::io::Cursor::new(encode_tag(TAG_PING));
        assert!(matches!(read_frame(&mut c).unwrap(), Frame::Ping));
    }

    #[test]
    fn rejects_garbage() {
        let mut c = std::io::Cursor::new(vec![5, 0, 0, 0, 99, 0, 0, 0, 0]);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn rejects_absurd_length() {
        let mut bytes = (64 * 1024 * 1024u32).to_le_bytes().to_vec();
        bytes.push(1);
        let mut c = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut c).is_err());
    }
}

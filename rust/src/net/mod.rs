//! Edge↔cloud networking: an analytic link model for the virtual clock and
//! a *real* TCP RPC path (length-prefixed binary protocol, thread-pool
//! server) used by the end-to-end `serve_cluster` example.

pub mod client;
pub mod link;
pub mod proto;
pub mod server;

pub use client::CloudClient;
pub use link::{Link, Transfer};
pub use server::CloudServer;

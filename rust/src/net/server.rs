//! Real TCP cloud server: accepts edge connections, routes inference
//! requests to a model worker thread, returns action chunks.
//!
//! Architecture (vLLM-router-like, scaled to this repo): connection
//! handler threads parse frames and enqueue requests on an MPSC channel;
//! a single model-owner thread (PJRT executables are not `Send`) drains
//! the queue through the [`crate::serve::Batcher`] and answers via
//! per-request reply channels. Python is never involved: the worker loads
//! the AOT HLO artifact directly.

use super::proto::{self, Frame, InferRequest};
use crate::serve::batcher::Batcher;
use crate::vla::{Backend, ModelOut};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

/// A queued request with its reply channel.
pub struct Pending {
    pub req: InferRequest,
    pub reply: mpsc::Sender<ModelOut>,
}

/// Server statistics (shared, lock-free).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Cross-session batch frames received from fleet schedulers
    /// (family-tagged zoo frames included).
    pub batch_frames: AtomicU64,
    /// Subset of `batch_frames` that carried a model-family tag.
    pub zoo_frames: AtomicU64,
    pub errors: AtomicU64,
}

pub struct CloudServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    listener_handle: Option<thread::JoinHandle<()>>,
    worker_handle: Option<thread::JoinHandle<()>>,
}

impl CloudServer {
    /// Start serving on `addr` (use "127.0.0.1:0" for an ephemeral port).
    /// `make_backend` runs on the worker thread and constructs the model
    /// (PJRT load + weight upload happens there, once).
    pub fn start<F>(addr: &str, max_batch: usize, make_backend: F) -> std::io::Result<CloudServer>
    where
        F: FnOnce() -> Box<dyn Backend> + Send + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let (tx, rx) = mpsc::channel::<Pending>();

        // model worker: owns the backend, drains the queue in batches
        let wstats = stats.clone();
        let worker = thread::spawn(move || {
            let mut backend = make_backend();
            let mut batcher = Batcher::new(max_batch);
            loop {
                // block for the first request, then opportunistically drain
                let first = match rx.recv() {
                    Ok(p) => p,
                    Err(_) => break, // all senders dropped -> shutdown
                };
                batcher.push(first);
                while batcher.len() < batcher.max_batch() {
                    match rx.try_recv() {
                        Ok(p) => batcher.push(p),
                        Err(_) => break,
                    }
                }
                let batch = batcher.take();
                wstats.batches.fetch_add(1, Ordering::Relaxed);
                for p in batch {
                    let out = backend.infer(&p.req.obs, &p.req.proprio, p.req.instr as usize);
                    wstats.requests.fetch_add(1, Ordering::Relaxed);
                    let _ = p.reply.send(out);
                }
            }
        });

        // listener: one handler thread per connection
        let lstop = stop.clone();
        let lstats = stats.clone();
        let listener_handle = thread::spawn(move || {
            for conn in listener.incoming() {
                if lstop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let tx = tx.clone();
                        let hstats = lstats.clone();
                        let hstop = lstop.clone();
                        thread::spawn(move || handle_conn(stream, tx, hstats, hstop));
                    }
                    Err(_) => {
                        lstats.errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            drop(tx); // release the worker
        });

        Ok(CloudServer {
            addr: local,
            stop,
            stats,
            listener_handle: Some(listener_handle),
            worker_handle: Some(worker),
        })
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Stop the server and join its threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.listener_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.worker_handle.take() {
            let _ = h.join();
        }
    }
}

/// Serve one coalesced batch through the worker queue: fan the
/// sub-requests in, collect replies in request order, echo session ids.
/// With a family, every reply is pushed through the family's
/// deterministic shape transform and the response frame echoes the
/// family tag. The reply frame is encoded into `buf`, the connection's
/// long-lived scratch buffer, so steady-state batch traffic allocates no
/// frame per flush. `Err(())` means the connection must close.
fn serve_batch(
    stream: &mut TcpStream,
    tx: &mpsc::Sender<Pending>,
    buf: &mut Vec<u8>,
    items: Vec<(u32, InferRequest)>,
    family: Option<crate::vla::ModelFamily>,
) -> Result<(), ()> {
    let mut waits = Vec::with_capacity(items.len());
    for (session, req) in items {
        let (rtx, rrx) = mpsc::channel();
        if tx.send(Pending { req, reply: rtx }).is_err() {
            return Err(());
        }
        waits.push((session, rrx));
    }
    let profile = family.map(crate::vla::FamilyProfile::of);
    let mut outs = Vec::with_capacity(waits.len());
    for (session, rrx) in waits {
        match rrx.recv() {
            Ok(out) => {
                let out = match &profile {
                    Some(p) => p.shape(out),
                    None => out,
                };
                outs.push((session, out));
            }
            Err(_) => return Err(()),
        }
    }
    match family {
        Some(f) => proto::encode_zoo_batch_result_into(buf, f.id(), &outs),
        None => proto::encode_batch_result_into(buf, &outs),
    }
    proto::write_all(stream, buf).map_err(|_| ())
}

fn handle_conn(
    mut stream: TcpStream,
    tx: mpsc::Sender<Pending>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
) {
    // per-connection reusable reply-encode buffer (see `serve_batch`)
    let mut buf: Vec<u8> = Vec::new();
    let _ = stream.set_nodelay(true);
    // Bounded read timeout so handler threads notice `stop` and release
    // their queue sender (otherwise worker shutdown would deadlock on an
    // idle connection).
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match proto::read_frame(&mut stream) {
            Ok(Frame::Infer(req)) => {
                let (rtx, rrx) = mpsc::channel();
                if tx.send(Pending { req, reply: rtx }).is_err() {
                    break;
                }
                match rrx.recv() {
                    Ok(out) => {
                        if proto::write_all(&mut stream, &proto::encode_result(&out)).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
            Ok(Frame::BatchInfer(items)) => {
                // fan the sub-requests into the worker queue (they coalesce
                // in its batcher), then collect replies in request order and
                // echo the session ids so responses cannot cross sessions
                stats.batch_frames.fetch_add(1, Ordering::Relaxed);
                match serve_batch(&mut stream, &tx, &mut buf, items, None) {
                    Ok(()) => {}
                    Err(()) => break,
                }
            }
            Ok(Frame::ZooBatchInfer(fam_id, items)) => {
                // family-tagged batch: validate the family, serve the batch
                // through the shared worker, shape every reply with the
                // family's deterministic transform, echo the family tag
                let Some(family) = crate::vla::ModelFamily::from_id(fam_id) else {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    break;
                };
                stats.batch_frames.fetch_add(1, Ordering::Relaxed);
                stats.zoo_frames.fetch_add(1, Ordering::Relaxed);
                match serve_batch(&mut stream, &tx, &mut buf, items, Some(family)) {
                    Ok(()) => {}
                    Err(()) => break,
                }
            }
            Ok(Frame::Ping) => {
                if proto::write_all(&mut stream, &proto::encode_tag(proto::TAG_PONG)).is_err() {
                    break;
                }
            }
            Ok(Frame::Shutdown) => {
                stop.store(true, Ordering::SeqCst);
                break;
            }
            Ok(_) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                break;
            }
            Err(proto::ProtoError::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue; // idle poll tick: recheck the stop flag
            }
            Err(_) => break, // peer closed or malformed
        }
    }
}

//! Fixed-capacity ring buffer used by the sliding-window statistics and the
//! dispatcher's history buffers ("low-dimensional arrays consuming mere
//! kilobytes" — paper §VI-D.2). Allocation-free after construction.

#[derive(Debug, Clone)]
pub struct RingBuf<T> {
    buf: Vec<T>,
    cap: usize,
    head: usize, // next write position
    len: usize,
}

impl<T: Copy + Default> RingBuf<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "ring buffer capacity must be positive");
        RingBuf { buf: vec![T::default(); cap], cap, head: 0, len: 0 }
    }

    /// Push a value, returning the evicted element once full.
    pub fn push(&mut self, v: T) -> Option<T> {
        let evicted = if self.len == self.cap { Some(self.buf[self.head]) } else { None };
        self.buf[self.head] = v;
        self.head = (self.head + 1) % self.cap;
        if self.len < self.cap {
            self.len += 1;
        }
        evicted
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// i-th most recent element (0 = newest). None if out of range.
    pub fn recent(&self, i: usize) -> Option<T> {
        if i >= self.len {
            return None;
        }
        let idx = (self.head + self.cap - 1 - i) % self.cap;
        Some(self.buf[idx])
    }

    /// Iterate oldest -> newest.
    pub fn iter(&self) -> impl Iterator<Item = T> + '_ {
        (0..self.len).map(move |i| {
            let idx = (self.head + self.cap - self.len + i) % self.cap;
            self.buf[idx]
        })
    }

    pub fn clear(&mut self) {
        self.head = 0;
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_evicts_fifo() {
        let mut rb = RingBuf::new(3);
        assert_eq!(rb.push(1), None);
        assert_eq!(rb.push(2), None);
        assert_eq!(rb.push(3), None);
        assert!(rb.is_full());
        assert_eq!(rb.push(4), Some(1));
        assert_eq!(rb.push(5), Some(2));
        let v: Vec<i32> = rb.iter().collect();
        assert_eq!(v, vec![3, 4, 5]);
    }

    #[test]
    fn recent_indexing() {
        let mut rb = RingBuf::new(4);
        for i in 0..6 {
            rb.push(i);
        }
        assert_eq!(rb.recent(0), Some(5));
        assert_eq!(rb.recent(3), Some(2));
        assert_eq!(rb.recent(4), None);
    }

    #[test]
    fn iter_order_before_full() {
        let mut rb = RingBuf::new(5);
        rb.push(10);
        rb.push(20);
        let v: Vec<i32> = rb.iter().collect();
        assert_eq!(v, vec![10, 20]);
    }

    #[test]
    fn clear_resets() {
        let mut rb = RingBuf::new(2);
        rb.push(1);
        rb.push(2);
        rb.clear();
        assert!(rb.is_empty());
        assert_eq!(rb.recent(0), None);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _ = RingBuf::<f64>::new(0);
    }
}

//! ASCII table formatting for the paper-style experiment tables.

/// A simple left/right-aligned table builder printing paper-style rows.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub footnote: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            footnote: String::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn footnote(&mut self, s: &str) -> &mut Self {
        self.footnote = s.to_string();
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let sep: String =
            w.iter().map(|n| format!("+{}", "-".repeat(n + 2))).collect::<String>() + "+";
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("| {:width$} ", c, width = w[i]));
            }
            line.push('|');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.footnote.is_empty() {
            out.push_str(&format!("Note: {}\n", self.footnote));
        }
        out
    }

    /// Render as CSV (for downstream plotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",") + "\n";
        for r in &self.rows {
            out += &(r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",") + "\n");
        }
        out
    }
}

/// Format milliseconds as the paper does: "222.9ms" / "222.9 ± 11.4ms".
pub fn ms(v: f64) -> String {
    format!("{v:.1}ms")
}

pub fn ms_pm(mean: f64, std: f64) -> String {
    format!("{mean:.1} ± {std:.1}ms")
}

/// Format gigabytes: "14.2GB".
pub fn gb(v: f64) -> String {
    format!("{v:.1}GB")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", 100.0 * v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["Method", "Lat."]);
        t.row_strs(&["Edge-Only", "782.5ms"]);
        t.row_strs(&["RAPID", "222.9ms"]);
        let s = t.render();
        assert!(s.contains("| Edge-Only | 782.5ms |"));
        assert!(s.contains("| RAPID     | 222.9ms |"));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_strs(&["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(222.94), "222.9ms");
        assert_eq!(ms_pm(222.9, 11.4), "222.9 ± 11.4ms");
        assert_eq!(gb(14.2), "14.2GB");
        assert_eq!(pct(0.057), "5.7%");
    }
}

//! Deterministic PRNG: PCG32 (O'Neill) seeded via SplitMix64.
//!
//! Every stochastic component in the simulator draws from an explicitly
//! seeded `Pcg32`, giving the whole-system determinism invariant
//! (DESIGN.md §7.8): fixed seed ⇒ identical traces, triggers and tables.

/// PCG-XSH-RR 64/32 with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second normal variate from Box-Muller.
    spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let state0 = splitmix64(&mut sm);
        let mut sm2 = stream ^ 0xDA3E_39CB_94B9_5BDB;
        let inc = splitmix64(&mut sm2) | 1;
        let mut rng = Pcg32 { state: 0, inc, spare: None };
        rng.state = state0.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (stable: depends only on the
    /// parent's seed path, not on how many numbers were drawn).
    pub fn fork(&self, tag: u64) -> Self {
        Self::new(self.inc.rotate_left(17) ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let l = m as u32;
            if l >= n || l >= (u32::MAX - n + 1) % n {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg32::seeded(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg32::seeded(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_is_stable_and_independent() {
        let parent = Pcg32::seeded(5);
        let mut c1 = parent.fork(1);
        let mut c1b = parent.fork(1);
        let mut c2 = parent.fork(2);
        assert_eq!(c1.next_u64(), c1b.next_u64());
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}

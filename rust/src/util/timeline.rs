//! Named time-series recording (Fig. 2 / Fig. 5 trace dumps).

use std::collections::BTreeMap;
use std::io::Write;

/// Records multiple named series indexed by step, dumps aligned CSV.
#[derive(Debug, Default, Clone)]
pub struct Timeline {
    series: BTreeMap<String, Vec<(u64, f64)>>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, name: &str, step: u64, value: f64) {
        self.series.entry(name.to_string()).or_default().push((step, value));
    }

    pub fn names(&self) -> Vec<&str> {
        self.series.keys().map(|s| s.as_str()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&[(u64, f64)]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    /// Values of one series in step order (ignoring gaps).
    pub fn values(&self, name: &str) -> Vec<f64> {
        self.series.get(name).map(|v| v.iter().map(|&(_, x)| x).collect()).unwrap_or_default()
    }

    pub fn len(&self) -> usize {
        self.series.values().map(|v| v.len()).max().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Step-aligned CSV: one column per series, blank where missing.
    pub fn to_csv(&self) -> String {
        let mut steps: Vec<u64> = Vec::new();
        for v in self.series.values() {
            for &(s, _) in v {
                steps.push(s);
            }
        }
        steps.sort_unstable();
        steps.dedup();
        let maps: Vec<(&String, BTreeMap<u64, f64>)> = self
            .series
            .iter()
            .map(|(k, v)| (k, v.iter().cloned().collect()))
            .collect();
        let mut out = String::from("step");
        for (k, _) in &maps {
            out += &format!(",{k}");
        }
        out.push('\n');
        for s in steps {
            out += &s.to_string();
            for (_, m) in &maps {
                match m.get(&s) {
                    Some(v) => out += &format!(",{v}"),
                    None => out.push(','),
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    /// Poor-man's terminal sparkline of a series (for example binaries).
    pub fn sparkline(&self, name: &str, width: usize) -> String {
        let vals = self.values(name);
        if vals.is_empty() {
            return String::new();
        }
        let glyphs = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let (lo, hi) = vals
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &v| (a.min(v), b.max(v)));
        let span = (hi - lo).max(1e-12);
        let w = width.min(vals.len()).max(1);
        let mut out = String::new();
        for c in 0..w {
            // endpoint-inclusive sampling: the last cell shows the last value
            let idx = if w == 1 { 0 } else { c * (vals.len() - 1) / (w - 1) };
            let v = vals[idx];
            let g = (((v - lo) / span) * 7.0).round() as usize;
            out.push(glyphs[g.min(7)]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_values() {
        let mut t = Timeline::new();
        t.record("a", 0, 1.0);
        t.record("a", 1, 2.0);
        t.record("b", 1, 5.0);
        assert_eq!(t.values("a"), vec![1.0, 2.0]);
        assert_eq!(t.values("b"), vec![5.0]);
        assert_eq!(t.names(), vec!["a", "b"]);
    }

    #[test]
    fn csv_alignment() {
        let mut t = Timeline::new();
        t.record("x", 0, 1.0);
        t.record("y", 1, 2.0);
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,x,y");
        assert_eq!(lines[1], "0,1,");
        assert_eq!(lines[2], "1,,2");
    }

    #[test]
    fn sparkline_monotone() {
        let mut t = Timeline::new();
        for i in 0..64 {
            t.record("s", i, i as f64);
        }
        let sl = t.sparkline("s", 8);
        assert_eq!(sl.chars().count(), 8);
        assert!(sl.starts_with('▁'));
        assert!(sl.ends_with('█'));
    }
}

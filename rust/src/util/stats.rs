//! Rolling (sliding-window) statistics and summary statistics.
//!
//! `RollingStats` is the paper's "dynamic sliding window statistics":
//! it maintains mean μ and standard deviation σ of a score stream over a
//! window w, in O(1) per update, and is what normalizes the anomaly scores
//! M̂ = (M - μ) / (σ + ε) in Algorithm 1 step 3.

use super::ringbuf::RingBuf;

/// O(1) sliding-window mean/std via running sums with periodic exact
/// recomputation to bound floating-point drift.
#[derive(Debug, Clone)]
pub struct RollingStats {
    window: RingBuf<f64>,
    sum: f64,
    sumsq: f64,
    pushes: u64,
    /// Recompute exactly every this many pushes (drift control).
    refresh_every: u64,
}

impl RollingStats {
    pub fn new(window: usize) -> Self {
        RollingStats {
            window: RingBuf::new(window),
            sum: 0.0,
            sumsq: 0.0,
            pushes: 0,
            refresh_every: 4096,
        }
    }

    pub fn push(&mut self, v: f64) {
        if let Some(old) = self.window.push(v) {
            self.sum -= old;
            self.sumsq -= old * old;
        }
        self.sum += v;
        self.sumsq += v * v;
        self.pushes += 1;
        if self.pushes % self.refresh_every == 0 {
            self.recompute();
        }
    }

    fn recompute(&mut self) {
        self.sum = self.window.iter().sum();
        self.sumsq = self.window.iter().map(|x| x * x).sum();
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Mean over the current window (0 before any sample).
    pub fn mean(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        self.sum / self.window.len() as f64
    }

    /// Population standard deviation over the current window (>= 0).
    pub fn std(&self) -> f64 {
        let n = self.window.len();
        if n == 0 {
            return 0.0;
        }
        let mean = self.mean();
        let var = (self.sumsq / n as f64 - mean * mean).max(0.0);
        var.sqrt()
    }

    /// Normalized anomaly score (M - μ) / (σ + ε) — Algorithm 1 step 3.
    pub fn zscore(&self, v: f64, eps: f64) -> f64 {
        (v - self.mean()) / (self.std() + eps)
    }
}

/// Streaming mean/variance without a window (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Batch summary with order statistics (used by benchkit and the tables).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| -> f64 {
            let idx = (p * (n - 1) as f64).round() as usize;
            s[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
        }
    }
}

/// Pearson correlation coefficient (Fig. 3: torque vs redundancy).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n as f64;
    let my = y.iter().sum::<f64>() / n as f64;
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..n {
        let a = x[i] - mx;
        let b = y[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Spearman rank correlation (robust variant reported alongside Pearson).
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    fn ranks(v: &[f64]) -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
        let mut r = vec![0.0; v.len()];
        let mut i = 0;
        while i < idx.len() {
            let mut j = i;
            while j + 1 < idx.len() && v[idx[j + 1]] == v[idx[i]] {
                j += 1;
            }
            let avg = (i + j) as f64 / 2.0;
            for k in i..=j {
                r[idx[k]] = avg;
            }
            i = j + 1;
        }
        r
    }
    pearson(&ranks(x), &ranks(y))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rolling_matches_naive() {
        let mut rs = RollingStats::new(5);
        let data = [1.0, 4.0, 2.0, 8.0, 5.0, 7.0, 3.0, 6.0, 1.5, 9.0];
        for (i, &v) in data.iter().enumerate() {
            rs.push(v);
            let lo = i.saturating_sub(4);
            let win = &data[lo..=i];
            let mean = win.iter().sum::<f64>() / win.len() as f64;
            let var = win.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / win.len() as f64;
            assert!((rs.mean() - mean).abs() < 1e-9, "step {i}");
            assert!((rs.std() - var.sqrt()).abs() < 1e-9, "step {i}");
        }
    }

    #[test]
    fn rolling_std_nonnegative_on_constant() {
        let mut rs = RollingStats::new(8);
        for _ in 0..100 {
            rs.push(3.3333);
        }
        assert!(rs.std() >= 0.0);
        assert!(rs.std() < 1e-9);
    }

    #[test]
    fn zscore_of_mean_is_zero() {
        let mut rs = RollingStats::new(4);
        for v in [2.0, 4.0, 6.0, 8.0] {
            rs.push(v);
        }
        assert!((rs.zscore(5.0, 1e-6)).abs() < 1e-4);
    }

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 100.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-9);
        assert!((w.var() - var).abs() < 1e-6);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 95.0).abs() <= 1.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rolling_drift_refresh() {
        let mut rs = RollingStats::new(3);
        rs.refresh_every = 10;
        for i in 0..1000 {
            rs.push((i % 7) as f64 * 1e6);
        }
        // last window: 996%7=2, 997%7=3, 998%7=4 -> wait, 0..1000 ends at 999
        let w = [(997 % 7) as f64 * 1e6, (998 % 7) as f64 * 1e6, (999 % 7) as f64 * 1e6];
        let mean = w.iter().sum::<f64>() / 3.0;
        assert!((rs.mean() - mean).abs() < 1e-3);
    }
}

//! Self-contained utility substrates (no third-party crates are available
//! in this offline environment, so the PRNG, rolling statistics, ring
//! buffer, table formatting and CSV timeline are implemented here).

pub mod rng;
pub mod ringbuf;
pub mod stats;
pub mod tablefmt;
pub mod timeline;

pub use rng::Pcg32;
pub use ringbuf::RingBuf;
pub use stats::{RollingStats, Summary, Welford};

//! A TOML-subset parser sufficient for the repo's config files:
//! `[section.sub]` headers, `key = value` with strings, numbers, booleans
//! and flat arrays, `#` comments. No multi-line strings, no table arrays.

use super::value::Value;

#[derive(Debug)]
pub enum ParseError {
    At(usize, String),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ParseError::At(line, msg) = self;
        write!(f, "line {line}: {msg}")
    }
}

impl std::error::Error for ParseError {}

pub fn parse_toml(src: &str) -> Result<Value, ParseError> {
    let mut root = Value::table();
    let mut section = String::new();
    for (ln, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(ParseError::At(ln + 1, "unterminated section header".into()));
            }
            section = line[1..line.len() - 1].trim().to_string();
            if section.is_empty() {
                return Err(ParseError::At(ln + 1, "empty section name".into()));
            }
            // materialize the (possibly empty) section table
            root.set(&section, root.get(&section).cloned().unwrap_or_else(Value::table));
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| ParseError::At(ln + 1, format!("expected key = value, got {line:?}")))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ParseError::At(ln + 1, "empty key".into()));
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| ParseError::At(ln + 1, e))?;
        let path = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        root.set(&path, val);
    }
    Ok(root)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err("unterminated string".into());
        }
        return Ok(Value::Str(unescape(&s[1..s.len() - 1])?));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err("unterminated array".into());
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p)?);
            }
        }
        return Ok(Value::List(items));
    }
    s.parse::<f64>().map(Value::Num).map_err(|_| format!("cannot parse value {s:?}"))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape: \\{other:?}")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let v = parse_toml(
            r#"
            top = 1.5
            [dispatcher]
            theta_comp = 0.65   # paper optimum
            theta_red = 0.35
            enabled = true
            name = "rapid"
            [robot.arm]
            joints = 7
            "#,
        )
        .unwrap();
        assert_eq!(v.f64_or("top", 0.0), 1.5);
        assert_eq!(v.f64_or("dispatcher.theta_comp", 0.0), 0.65);
        assert!(v.bool_or("dispatcher.enabled", false));
        assert_eq!(v.str_or("dispatcher.name", ""), "rapid");
        assert_eq!(v.usize_or("robot.arm.joints", 0), 7);
    }

    #[test]
    fn parses_arrays() {
        let v = parse_toml("w = [1.0, 2.0, 3.5]\nnames = [\"a\", \"b\"]").unwrap();
        let w = v.get("w").unwrap().as_list().unwrap();
        assert_eq!(w.len(), 3);
        assert_eq!(w[2].as_f64(), Some(3.5));
        let n = v.get("names").unwrap().as_list().unwrap();
        assert_eq!(n[1].as_str(), Some("b"));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let v = parse_toml("s = \"a#b\" # real comment").unwrap();
        assert_eq!(v.str_or("s", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_toml("ok = 1\nbroken").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }

    #[test]
    fn escapes() {
        let v = parse_toml(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(v.str_or("s", ""), "a\nb\t\"c\"");
    }
}

//! Dynamic config value tree shared by the TOML and JSON parsers.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    List(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub enum ValueError {
    Missing(String),
    Type(String, &'static str),
}

impl fmt::Display for ValueError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ValueError::Missing(k) => write!(f, "key not found: {k}"),
            ValueError::Type(k, want) => write!(f, "type mismatch at {k}: expected {want}"),
        }
    }
}

impl std::error::Error for ValueError {}

impl Value {
    pub fn table() -> Value {
        Value::Table(BTreeMap::new())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    /// Dotted-path lookup: `get("dispatcher.theta_comp")`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }

    /// Dotted-path insert, creating intermediate tables.
    pub fn set(&mut self, path: &str, v: Value) {
        let parts: Vec<&str> = path.split('.').collect();
        let mut cur = self;
        for (i, part) in parts.iter().enumerate() {
            let t = match cur {
                Value::Table(t) => t,
                _ => {
                    *cur = Value::table();
                    match cur {
                        Value::Table(t) => t,
                        _ => unreachable!(),
                    }
                }
            };
            if i == parts.len() - 1 {
                t.insert(part.to_string(), v);
                return;
            }
            cur = t.entry(part.to_string()).or_insert_with(Value::table);
        }
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => write!(f, "{n}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::List(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::Table(t) => {
                write!(f, "{{")?;
                for (i, (k, v)) in t.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k} = {v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dotted_set_get() {
        let mut v = Value::table();
        v.set("a.b.c", Value::Num(3.0));
        assert_eq!(v.get("a.b.c").unwrap().as_f64(), Some(3.0));
        assert!(v.get("a.b.x").is_none());
    }

    #[test]
    fn typed_defaults() {
        let mut v = Value::table();
        v.set("x", Value::Num(2.0));
        assert_eq!(v.f64_or("x", 9.0), 2.0);
        assert_eq!(v.f64_or("y", 9.0), 9.0);
        assert_eq!(v.usize_or("x", 7), 2);
        assert!(v.bool_or("z", true));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Value::Num(2.5).as_usize(), None);
        assert_eq!(Value::Num(-1.0).as_usize(), None);
        assert_eq!(Value::Num(4.0).as_usize(), Some(4));
    }
}

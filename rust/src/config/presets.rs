//! Experiment presets matching the paper's two evaluation testbeds.

use super::schema::SystemConfig;

/// LIBERO simulation benchmark preset (Table III / V / Tab I / figures).
/// OpenVLA bookkeeping: 14.2 GB total; RAPID keeps 2.4 GB on the edge.
pub fn libero_preset() -> SystemConfig {
    SystemConfig::default()
}

/// Physical real-world deployment preset (Table IV): slightly larger
/// checkpoint (14.5 GB), a noisier/wider-latency wireless link, a slower
/// edge SoC, and rougher proprioceptive sensors.
pub fn realworld_preset() -> SystemConfig {
    let mut c = SystemConfig::default();
    c.name = "realworld".into();
    c.total_model_gb = 14.5;
    c.edge_model_gb = 2.4;
    c.vision_edge_gb = 4.3;
    c.devices.edge_full_ms = 812.6;
    c.devices.cloud_compute_ms = 92.0;
    c.devices.vision_route_ms = 55.0;
    c.devices.jitter = 0.09;
    c.link.rtt_ms = 14.0;
    c.link.bw_mbps = 600.0;
    c.link.jitter = 0.15;
    c.link.noise_retrans = 0.35;
    c.robot.sensor_noise = 0.004;
    c.episode.seed = 17;
    c
}

/// Named preset lookup used by the CLI.
pub fn by_name(name: &str) -> Option<SystemConfig> {
    match name.to_ascii_lowercase().as_str() {
        "libero" | "sim" => Some(libero_preset()),
        "realworld" | "real" | "real-world" => Some(realworld_preset()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_where_expected() {
        let sim = libero_preset();
        let real = realworld_preset();
        assert_eq!(sim.total_model_gb, 14.2);
        assert_eq!(real.total_model_gb, 14.5);
        assert!(real.devices.edge_full_ms > sim.devices.edge_full_ms);
        assert!(real.link.rtt_ms > sim.link.rtt_ms);
    }

    #[test]
    fn lookup() {
        assert!(by_name("libero").is_some());
        assert!(by_name("real").is_some());
        assert!(by_name("mars").is_none());
    }
}

//! Typed configuration schema. Every struct can be loaded from the TOML
//! [`Value`] tree (`from_value`) and has paper-calibrated defaults.
//!
//! Latency/load accounting model (DESIGN.md §5): emulated device service
//! times are explicit config (the surrogate is ~10⁻³ of OpenVLA, so wall
//! clock is recorded separately); edge compute scales linearly with the
//! parameter fraction resident on the edge.

use super::value::Value;

/// Visual disturbance level (paper Table I rows / §VI-A.2 environments).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NoiseLevel {
    /// Clean, noise-free workspace.
    Standard,
    /// Dynamic background lighting variation + camera noise.
    VisualNoise,
    /// Irrelevant moving objects / severe occlusions.
    Distraction,
}

impl NoiseLevel {
    pub fn name(&self) -> &'static str {
        match self {
            NoiseLevel::Standard => "Standard",
            NoiseLevel::VisualNoise => "Visual Noise",
            NoiseLevel::Distraction => "Distraction",
        }
    }

    pub fn parse(s: &str) -> Option<NoiseLevel> {
        match s.to_ascii_lowercase().as_str() {
            "standard" | "clean" => Some(NoiseLevel::Standard),
            "visual_noise" | "noise" | "visual" => Some(NoiseLevel::VisualNoise),
            "distraction" | "distract" => Some(NoiseLevel::Distraction),
            _ => None,
        }
    }
}

/// Partitioning strategy selector (paper baselines + ablations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Full RAPID dual-threshold dispatcher (ours).
    Rapid,
    /// Ablation: w/o θ_comp (acceleration trigger removed).
    RapidNoComp,
    /// Ablation: w/o θ_red (torque trigger removed).
    RapidNoRed,
    /// Ablation: static OR fusion instead of dynamic phase weights.
    RapidStaticFusion,
    /// Full model on the edge device.
    EdgeOnly,
    /// Full model in the cloud, edge does I/O only.
    CloudOnly,
    /// Vision-based dynamic partitioning via action-distribution entropy
    /// (SAFE on the LIBERO config, ISAR on the real-world config).
    VisionBased,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Rapid => "RAPID (Ours)",
            PolicyKind::RapidNoComp => "w/o theta_comp (Acc.)",
            PolicyKind::RapidNoRed => "w/o theta_red (Torque)",
            PolicyKind::RapidStaticFusion => "RAPID (static OR fusion)",
            PolicyKind::EdgeOnly => "Edge-Only",
            PolicyKind::CloudOnly => "Cloud-Only",
            PolicyKind::VisionBased => "Vision-Based",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "rapid" => Some(PolicyKind::Rapid),
            "rapid_no_comp" | "no_comp" => Some(PolicyKind::RapidNoComp),
            "rapid_no_red" | "no_red" => Some(PolicyKind::RapidNoRed),
            "rapid_static" | "static_fusion" => Some(PolicyKind::RapidStaticFusion),
            "edge" | "edge_only" => Some(PolicyKind::EdgeOnly),
            "cloud" | "cloud_only" => Some(PolicyKind::CloudOnly),
            "vision" | "vision_based" | "safe" | "isar" => Some(PolicyKind::VisionBased),
            _ => None,
        }
    }
}

/// Manipulator / physics parameters.
#[derive(Debug, Clone)]
pub struct RobotConfig {
    /// Control interval Δt in seconds (f_control = 20 Hz).
    pub dt: f64,
    /// Proprioceptive polling frequency f_sensor (Hz) — the dispatcher's
    /// high-rate loop (paper §V-A).
    pub sensor_hz: f64,
    /// Per-joint viscous damping.
    pub damping: f64,
    /// Gravity magnitude (m/s²).
    pub gravity: f64,
    /// Link masses (kg), proximal -> distal.
    pub link_mass: [f64; crate::N_JOINTS],
    /// Encoder / torque-sensor noise std.
    pub sensor_noise: f64,
    /// Actuator velocity tracking gain.
    pub track_gain: f64,
    /// Actuator acceleration (slew) limit in rad/s² — real drives ramp
    /// smoothly; without this, chunk-boundary action changes would produce
    /// free-space torque transients bigger than contact ones.
    pub max_accel: f64,
}

impl Default for RobotConfig {
    fn default() -> Self {
        RobotConfig {
            dt: 0.05,
            sensor_hz: 500.0,
            damping: 0.4,
            gravity: 9.81,
            link_mass: [4.0, 3.5, 3.0, 2.0, 1.5, 1.0, 0.5],
            sensor_noise: 0.002,
            track_gain: 0.85,
            max_accel: 6.0,
        }
    }
}

/// Scene / renderer parameters.
#[derive(Debug, Clone)]
pub struct SceneConfig {
    pub noise: NoiseLevel,
    /// Clarity floor under VisualNoise (1.0 = perfectly clean).
    pub visual_noise_clarity: f64,
    /// Probability per step of a distractor occlusion event.
    pub occlusion_rate: f64,
    /// Clarity during an occlusion event.
    pub occlusion_clarity: f64,
    /// Occlusion event duration in steps.
    pub occlusion_len: usize,
}

impl Default for SceneConfig {
    fn default() -> Self {
        SceneConfig {
            noise: NoiseLevel::Standard,
            visual_noise_clarity: 0.38,
            occlusion_rate: 0.18,
            occlusion_clarity: 0.15,
            occlusion_len: 8,
        }
    }
}

/// Network link between edge and cloud.
#[derive(Debug, Clone)]
pub struct LinkConfig {
    pub rtt_ms: f64,
    pub bw_mbps: f64,
    /// Serialized camera observation payload (bytes) for an offload.
    pub obs_bytes: f64,
    /// Returned action-chunk payload (bytes).
    pub chunk_bytes: f64,
    /// Intermediate-activation payload for split computing (vision-based
    /// baseline ships features from the split point, not raw pixels).
    pub activation_bytes: f64,
    /// Multiplicative latency jitter fraction.
    pub jitter: f64,
    /// Extra retransmission probability per transfer under degraded vision
    /// (distractor scenes saturate the uplink with re-sent frames).
    pub noise_retrans: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            rtt_ms: 8.0,
            bw_mbps: 1000.0,
            obs_bytes: 1.5e6,
            chunk_bytes: 4096.0,
            activation_bytes: 6.0e6,
            jitter: 0.08,
            noise_retrans: 0.55,
        }
    }
}

/// Emulated device service-time model (DESIGN.md §5).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    /// Full 14.2 GB model inference on the edge SoC (ms) — the paper's
    /// Edge-Only anchor.
    pub edge_full_ms: f64,
    /// Full model inference on the cloud A100 (ms, compute only).
    pub cloud_compute_ms: f64,
    /// Vision-based routing cost per decision: preprocess + forward pass to
    /// obtain the action distribution for entropy (paper §III-B.2 — "deep,
    /// implicit features that require a computationally expensive forward
    /// pass").
    pub vision_route_ms: f64,
    /// Chunk-preemption penalty (discard + state swap) on an offload.
    pub preempt_ms: f64,
    /// Camera observation capture latency.
    pub obs_capture_ms: f64,
    /// Service-time jitter fraction.
    pub jitter: f64,
    /// Device-heterogeneity zoo gate: comma-separated device-class names
    /// (`cloudlet` | `agx` | `nx` | `lite`) assigned across fleet
    /// sessions per `[workload] device_mix`. Empty (the default) disables
    /// the zoo — every session is the implicit `cloudlet` no-op class and
    /// serving is bit-identical to a class-free build. Unknown names are
    /// a config-load error (never a silent fallback).
    pub classes: String,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            edge_full_ms: 782.5,
            cloud_compute_ms: 90.0,
            vision_route_ms: 48.0,
            preempt_ms: 25.0,
            obs_capture_ms: 5.0,
            jitter: 0.05,
            classes: String::new(),
        }
    }
}

impl DeviceConfig {
    /// Is the device-heterogeneity zoo armed? (A non-empty class list.)
    pub fn classes_enabled(&self) -> bool {
        !self.classes.trim().is_empty()
    }

    /// Parse the class list. Validation at config load guarantees every
    /// name is known for loaded configs; a programmatically-set unknown
    /// name panics loudly here rather than silently degrading.
    pub fn class_list(&self) -> Vec<crate::runtime::DeviceClass> {
        use crate::runtime::DeviceClass;
        self.classes
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                DeviceClass::parse(s).unwrap_or_else(|| {
                    panic!("unknown device class {:?} (known: {})", s.trim(), DeviceClass::NAMES)
                })
            })
            .collect()
    }
}

/// RAPID dispatcher hyper-parameters (paper §IV / §VI-D.1).
#[derive(Debug, Clone)]
pub struct DispatcherConfig {
    /// Compatibility-optimal (acceleration) threshold θ_comp.
    pub theta_comp: f64,
    /// Redundancy-aware (torque) threshold θ_red.
    pub theta_red: f64,
    /// Sliding window w_a for acceleration statistics (sensor ticks).
    pub window_acc: usize,
    /// Running window for torque statistics (sensor ticks).
    pub window_tau: usize,
    /// Short moving-average window w_τ for the torque variation (Eq. 5).
    pub w_tau: usize,
    /// Velocity normalizer v_max (Eq. 6).
    pub v_max: f64,
    /// Cooldown step limit C (Eq. 8), in control steps.
    pub cooldown: u32,
    /// Normalization ε.
    pub eps: f64,
    /// Minimum normalized anomaly (in σ) for either side to count as an
    /// anomaly at all. The θ thresholds are *sensitivities* applied to the
    /// phase-weighted score; without this gate, sub-σ noise fluctuations
    /// would satisfy ω·M̂ > θ at θ < 1 on any calm stream.
    pub z_gate: f64,
    /// Physical floors: an anomaly must also be physically non-trivial.
    /// z-scores are scale-free, so a perfectly quiet sensor stream would
    /// otherwise normalize its own µ-scale noise into "anomalies".
    /// Units: M_acc in weighted rad/s², M_τ in weighted (N·m)².
    pub min_m_acc: f64,
    pub min_m_tau: f64,
    /// Joint weights W_a (acceleration) — end joints weighted higher.
    pub w_acc: [f64; crate::N_JOINTS],
    /// Joint weights W_τ (torque) — wrist joints most contact-sensitive.
    pub w_torque: [f64; crate::N_JOINTS],
    /// Ablation: disable the acceleration trigger (w/o θ_comp).
    pub disable_comp: bool,
    /// Ablation: disable the torque trigger (w/o θ_red).
    pub disable_red: bool,
    /// Ablation: static OR fusion (ω_a = ω_τ = 1) instead of Eq. 6.
    pub static_fusion: bool,
}

impl Default for DispatcherConfig {
    fn default() -> Self {
        DispatcherConfig {
            theta_comp: 0.65,
            theta_red: 0.35,
            window_acc: 64,
            window_tau: 256,
            w_tau: 8,
            v_max: 1.8,
            cooldown: 12,
            eps: 1e-6,
            z_gate: 2.5,
            min_m_acc: 0.5,
            min_m_tau: 0.05,
            w_acc: [0.5, 0.6, 0.7, 0.85, 1.0, 1.2, 1.4],
            w_torque: [0.3, 0.4, 0.5, 0.7, 1.0, 1.3, 1.6],
            disable_comp: false,
            disable_red: false,
            static_fusion: false,
        }
    }
}

/// Vision-based baseline (SAFE/ISAR) parameters.
#[derive(Debug, Clone)]
pub struct VisionPolicyConfig {
    /// Entropy offload threshold (nats).
    pub entropy_threshold: f64,
    /// Split-point adaptation rate: how aggressively the edge fraction
    /// shrinks as the running entropy rises (AVERY-style split computing).
    pub split_adapt: f64,
    /// Minimum edge-resident parameter fraction.
    pub min_edge_frac: f64,
    /// Entropy EWMA smoothing.
    pub ewma: f64,
}

impl Default for VisionPolicyConfig {
    fn default() -> Self {
        VisionPolicyConfig {
            entropy_threshold: 3.2,
            split_adapt: 1.2,
            min_edge_frac: 0.08,
            ewma: 0.35,
        }
    }
}

/// Fleet-scale serving knobs: the multi-session episode scheduler with
/// cross-session cloud batching (`serve::fleet`).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Concurrent robot sessions driven by the scheduler.
    pub n_sessions: usize,
    /// Max cloud offloads coalesced into one wire batch.
    pub max_batch: usize,
    /// How long a partial batch may wait for co-batching company, in µs of
    /// virtual control time (0 = flush at the end of every scheduler
    /// round). Longer deadlines trade chunk staleness for bigger batches.
    pub batch_deadline_us: u64,
    /// Backpressure bound: max cloud requests in flight fleet-wide. A
    /// session whose offload would exceed it degrades to its edge slice.
    pub max_inflight: usize,
    /// Cloud endpoints the router spreads batches across.
    pub endpoints: usize,
    /// Episodes each session runs back to back.
    pub episodes_per_session: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            n_sessions: 8,
            max_batch: 4,
            batch_deadline_us: 0,
            max_inflight: 16,
            endpoints: 1,
            episodes_per_session: 1,
        }
    }
}

/// Redundancy-aware reuse cache (`cache::ReuseStore`): speculative
/// per-session chunk reuse plus the fleet-shared result cache. With
/// `enabled = false` (the default) no store is constructed and the serve
/// layer is bit-identical to a cache-free build — the same zero-draws
/// contract as `[faults]`.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    pub enabled: bool,
    /// Max cached chunks; at capacity a seeded-random victim is evicted.
    pub capacity: usize,
    /// Entry lifetime in scheduler rounds (control steps single-session);
    /// the temporal half of the divergence budget.
    pub ttl_rounds: u64,
    /// Seed of the eviction stream; 0 derives from the episode seed.
    pub seed: u64,
    /// Quantization step for joint positions (rad) and the velocity norm
    /// (rad/s) — the spatial half of the divergence budget.
    pub quant: f64,
    /// Bin width (σ) for the windowed anomaly z-scores in the key.
    pub z_quant: f64,
    /// Probe gate: a dispatch whose anomaly z-score exceeds this is a
    /// novel situation and always goes to the real cloud.
    pub max_zscore: f64,
    /// Virtual time charged per served hit (edge-side probe + copy).
    pub probe_ms: f64,
    /// Fleet-shared tier: false restricts each session to its own entries
    /// (per-session speculative reuse only).
    pub shared: bool,
    /// Backing shards (rounded up to a power of two, capped so each shard
    /// holds at least one entry). 1 — the default — is the historical
    /// single-map store; larger values spread capacity and eviction
    /// streams across independently bounded shards for fleet-scale runs.
    pub shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            enabled: false,
            capacity: 256,
            ttl_rounds: 128,
            seed: 0,
            quant: 0.1,
            z_quant: 4.0,
            max_zscore: 8.0,
            probe_ms: 2.0,
            shared: true,
            shards: 1,
        }
    }
}

/// Pipelined + speculative partition execution (`serve::driver`). With
/// `enabled = false` (the default) — or enabled with both `overlap` and
/// `speculate` off — no pipelined code path runs and the scheduler is
/// bit-identical to the sequential offload model (the same
/// zero-perturbation contract as `[faults]`/`[cache]`/`[models]`/
/// `[workload]`).
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineConfig {
    pub enabled: bool,
    /// Overlap the next step's edge-prefix compute with the in-flight
    /// cloud round trip: an offload charges
    /// `max(edge_prefix, wire + cloud)` instead of the sum, with the
    /// hidden portion recorded in `overlap_hidden_ms`.
    pub overlap: bool,
    /// Speculative edge decoding: the edge slice emits a provisional
    /// chunk immediately and keeps stepping; the cloud reply confirms
    /// the consumed prefix (free) or corrects it (`rollback_ms`).
    /// Anomalous dispatches (z-score above `max_zscore`) never
    /// speculate and suspend sequentially.
    pub speculate: bool,
    /// Virtual time charged for the provisional edge decode (ms) — the
    /// quantized edge head re-used as a draft model, far cheaper than a
    /// full edge-slice inference.
    pub spec_decode_ms: f64,
    /// Penalty re-charged to the session clock and overhead column when
    /// the cloud reply corrects a speculated prefix (ms).
    pub rollback_ms: f64,
    /// Max per-joint |provisional - cloud| action divergence (rad/s)
    /// accepted as a free confirmation on the consumed prefix.
    pub accept_eps: f64,
    /// Speculation gate: a dispatch whose windowed anomaly z-score
    /// exceeds this is a critical phase and never speculates (same
    /// definition as the `cache.max_zscore` probe gate).
    pub max_zscore: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            enabled: false,
            overlap: false,
            speculate: false,
            spec_decode_ms: 15.0,
            rollback_ms: 40.0,
            accept_eps: 0.05,
            max_zscore: 8.0,
        }
    }
}

impl PipelineConfig {
    /// True when the overlap charge model may run.
    pub fn overlap_on(&self) -> bool {
        self.enabled && self.overlap
    }

    /// True when speculative edge decoding may run.
    pub fn speculate_on(&self) -> bool {
        self.enabled && self.speculate
    }
}

/// Observability layer (`obs`): deterministic span tracing + the wedge
/// flight recorder. With `enabled = false` (the default) the fleet
/// constructs no tracer and no recorder and serving is bit-identical to
/// a trace-free build — the same zero-perturbation contract as
/// `[faults]`/`[cache]`/`[models]`/`[workload]`/`[pipeline]`. Enabled,
/// recording consumes zero PRNG draws and never advances a clock, so the
/// traced run *still* replays bit-identically; only the exported trace
/// and the flight-recorder postmortem are new.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    pub enabled: bool,
    /// Hard cap on recorded spans per fleet; past it the tracer counts
    /// drops instead of growing (an enabled trace can never OOM a
    /// 100k-session run).
    pub max_spans: usize,
    /// Flight-recorder ring capacity per session (recent events kept for
    /// the wedge postmortem).
    pub flight_events: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, max_spans: 1 << 20, flight_events: 32 }
    }
}

/// Multi-factor placement (`policy::planner::plan_with`): fold device
/// budgets and endpoint state into the partition score. With
/// `enabled = false` (the default) the planner runs the single-factor
/// link-cost argmin (unlimited budget, nominal endpoint) and produces
/// bit-identical plans — the same zero-perturbation contract as every
/// other gate. Enabled, the device class filters partition points the
/// edge cannot host (filtered-to-empty degrades to edge-only serving,
/// never a wedge), and the least-loaded compatible endpoint's queue
/// depth and GPU capacity scale the cloud term so a contended endpoint
/// pushes the split deeper.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementConfig {
    pub enabled: bool,
    /// Edge device class (`cloudlet` | `agx` | `nx` | `lite`); selects a
    /// built-in [`crate::policy::planner::DeviceBudget`]. Unknown names
    /// are rejected at config load (a typo used to silently fall back to
    /// the unlimited `cloudlet` budget). With `[devices] classes` armed,
    /// each slot's own class supplies the budget instead and this knob
    /// only contributes its non-zero overrides.
    pub device_class: String,
    /// Override the class's edge memory budget (GB); 0 keeps the class
    /// value.
    pub max_edge_gb: f64,
    /// Override the class's per-offload edge-prefix budget (ms); 0 keeps
    /// the class value.
    pub prefix_ms_budget: f64,
    /// Cost weight per queued request on the target endpoint (0 ignores
    /// queue depth — capacity alone still applies).
    pub queue_weight: f64,
    /// Relative GPU capacity of cloud endpoints (1.0 = the nominal
    /// device the family catalogs were calibrated on).
    pub gpu_capacity: f64,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig {
            enabled: false,
            device_class: "cloudlet".into(),
            max_edge_gb: 0.0,
            prefix_ms_budget: 0.0,
            queue_weight: 0.0,
            gpu_capacity: 1.0,
        }
    }
}

impl PlacementConfig {
    /// Resolve the effective device budget: the class catalog entry with
    /// non-zero overrides applied on top. Validation at config load
    /// guarantees the class name is known for loaded configs; a
    /// programmatically-set unknown name panics loudly here rather than
    /// silently removing every budget (the historical UNLIMITED
    /// fallback).
    pub fn budget(&self) -> crate::policy::planner::DeviceBudget {
        use crate::runtime::DeviceClass;
        let mut b = crate::policy::planner::DeviceBudget::of(&self.device_class)
            .unwrap_or_else(|| {
                panic!(
                    "unknown device class {:?} (known: {})",
                    self.device_class,
                    DeviceClass::NAMES
                )
            });
        if self.max_edge_gb > 0.0 {
            b.mem_gb = self.max_edge_gb;
        }
        if self.prefix_ms_budget > 0.0 {
            b.prefix_ms = self.prefix_ms_budget;
        }
        b
    }

    /// [`PlacementConfig::budget`] for an explicit per-slot device class
    /// (the device zoo's path): the class catalog entry with this
    /// section's non-zero overrides applied on top.
    pub fn budget_for(
        &self,
        class: crate::runtime::DeviceClass,
    ) -> crate::policy::planner::DeviceBudget {
        let mut b = crate::policy::planner::DeviceBudget::for_class(class);
        if self.max_edge_gb > 0.0 {
            b.mem_gb = self.max_edge_gb;
        }
        if self.prefix_ms_budget > 0.0 {
            b.prefix_ms = self.prefix_ms_budget;
        }
        b
    }
}

/// Deterministic autoscaling control plane (`serve::fleet`): endpoint
/// slots spawn and drain in response to the workload engine's open-loop
/// arrivals, and admission control sheds offloads to edge-only serving
/// before queues wedge. With `enabled = false` (the default) the fleet
/// keeps its static endpoint list and is bit-identical to the
/// autoscale-free scheduler. Enabled, every decision is a pure function
/// of scheduler counters at round start — zero PRNG draws, zero clock
/// advances — so scaled runs still replay bit-identically under the
/// same seed.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    pub enabled: bool,
    /// Endpoints active at start and the drain floor (≥ 1).
    pub min_endpoints: usize,
    /// Spawn ceiling (remote mode clamps to the connected client count).
    pub max_endpoints: usize,
    /// SLO pressure signal: scale up when queued cloud requests exceed
    /// `slo_queue × active endpoints`.
    pub slo_queue: usize,
    /// Consecutive pressured rounds required before a spawn (debounce).
    pub sustain_rounds: u64,
    /// Consecutive idle rounds (zero queue, zero outstanding) before the
    /// newest spawned endpoint drains.
    pub idle_rounds: u64,
    /// Rounds after any scale event during which no further scaling
    /// happens (hysteresis).
    pub cooldown_rounds: u64,
    /// Admission shed: gate new offloads to edge-only while queued cloud
    /// requests ≥ this (0 = never shed).
    pub shed_queue: usize,
    /// With the model zoo on, spawned endpoints advertise only the
    /// family under pressure (per-family pools); off, they advertise
    /// every family.
    pub family_pools: bool,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            enabled: false,
            min_endpoints: 1,
            max_endpoints: 4,
            slo_queue: 4,
            sustain_rounds: 2,
            idle_rounds: 8,
            cooldown_rounds: 4,
            shed_queue: 0,
            family_pools: false,
        }
    }
}

/// Heterogeneous VLA model zoo (`vla::zoo` + `policy::planner`). With
/// `enabled = false` (the default) every session serves the original
/// surrogate family and the serve layer is bit-identical to a zoo-free
/// build — the same zero-perturbation contract as `[faults]`/`[cache]`.
/// Enabled, fleet sessions are assigned the listed families in balanced
/// contiguous blocks, each session runs its family's backends at its
/// planner-chosen partition point, and cross-session cloud batches are
/// keyed by family so no wire batch ever mixes frame layouts.
///
/// Note: family catalogs (`vla::profile::FamilyProfile`) carry *absolute*
/// per-family costs calibrated against the default `[devices]`/`[link]`
/// anchors — a zoo session's offload payload and cloud compute come from
/// its family's partition point, not from `link.obs_bytes` /
/// `devices.cloud_compute_ms` (only the jitter model and the surrogate
/// family keep following those knobs).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelsConfig {
    pub enabled: bool,
    /// Comma-separated family names (`surrogate`, `openvla`, `pi0`,
    /// `edgequant`), assigned across fleet sessions in catalog order.
    pub families: String,
}

impl Default for ModelsConfig {
    fn default() -> Self {
        ModelsConfig { enabled: false, families: "openvla,pi0,edgequant".into() }
    }
}

impl ModelsConfig {
    /// Parse the family list; unknown names are skipped with a warning on
    /// stderr (a typo must not silently change fleet composition). An
    /// empty result falls back to the surrogate family alone.
    pub fn family_list(&self) -> Vec<crate::vla::profile::ModelFamily> {
        let mut fams = Vec::new();
        for name in self.families.split(',') {
            match crate::vla::profile::ModelFamily::parse(name) {
                Some(f) => fams.push(f),
                None if name.trim().is_empty() => {}
                None => eprintln!(
                    "[models] unknown family {:?} skipped (known: surrogate, openvla, pi0, \
                     edgequant)",
                    name.trim()
                ),
            }
        }
        if fams.is_empty() {
            vec![crate::vla::profile::ModelFamily::Surrogate]
        } else {
            fams
        }
    }
}

/// Open-loop workload engine (`serve::workload`): seeded dynamic session
/// arrivals for the event-driven fleet scheduler. With `enabled = false`
/// (the default) the scheduler compiles the lockstep plan — every session
/// arrives at round 0 with the `[fleet]` episode count and block-assigned
/// family — and is bit-identical to the pre-workload round loop (the same
/// zero-perturbation contract as `[faults]`/`[cache]`/`[models]`).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    pub enabled: bool,
    /// Arrival process: `fixed`, `poisson`, `bursty`, or `trace`.
    pub arrivals: String,
    /// Sessions the workload spawns (0 = use `fleet.n_sessions`; a trace
    /// with no pinned count defines the fleet size itself).
    pub n_sessions: usize,
    /// Round the arrival process starts.
    pub start_round: u64,
    /// Fixed: exact gap between arrivals (rounds; 0 = everyone at the
    /// start round). Poisson: mean of the exponential inter-arrival gap.
    pub interarrival_rounds: f64,
    /// Bursty: back-to-back arrivals per on-window (one per round) ...
    pub burst_len: u64,
    /// ... followed by this many silent rounds.
    pub idle_len: u64,
    /// Trace replay: inline rounds (`"0,0,4,12"`) or `"@path"` to a file
    /// with one arrival round per line (`#` comments).
    pub trace: String,
    /// Seed of the engine's private draw stream; 0 derives from the
    /// episode seed.
    pub seed: u64,
    /// Per-session episode count drawn uniformly from
    /// `[episodes_min, episodes_max]`; 0/0 pins `fleet.episodes_per_session`.
    pub episodes_min: usize,
    pub episodes_max: usize,
    /// Family assignment: `blocks` (the lockstep contiguous-block rule) or
    /// `draw` (seeded uniform draw from the `[models]` family list).
    pub family_mix: String,
    /// Device-class assignment when `[devices] classes` is non-empty:
    /// `blocks` (contiguous balanced blocks, zero draws) or `draw`
    /// (seeded uniform draw from the class list). Any other value is a
    /// config-load error. Inert while the device zoo is disabled.
    pub device_mix: String,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            enabled: false,
            arrivals: "fixed".into(),
            n_sessions: 0,
            start_round: 0,
            interarrival_rounds: 0.0,
            burst_len: 4,
            idle_len: 12,
            trace: String::new(),
            seed: 0,
            episodes_min: 0,
            episodes_max: 0,
            family_mix: "blocks".into(),
            device_mix: "blocks".into(),
        }
    }
}

/// Deterministic fault-injection schedule (`faults::FaultPlan` is built
/// from this section; see `rust/src/faults/`). All windows are half-open
/// `[start, end)` ranges of scheduler rounds; an empty window (start >=
/// end) disables that fault. With `enabled = false` the whole section is
/// inert and the serve layer is bit-identical to a fault-free build.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultsConfig {
    pub enabled: bool,
    /// Seed of the drop-decision stream; 0 derives from the episode seed.
    pub seed: u64,
    /// Virtual time the edge waits for a reply before failing over (ms).
    pub offload_timeout_ms: f64,
    /// Re-dispatches on surviving endpoints before degrading to the edge.
    pub max_retries: usize,
    /// Endpoint crash/recover window.
    pub crash_endpoint: usize,
    pub crash_start: u64,
    pub crash_end: u64,
    /// Bandwidth/RTT collapse window and the degraded values.
    pub degrade_start: u64,
    pub degrade_end: u64,
    pub degrade_bw_mbps: f64,
    pub degrade_rtt_ms: f64,
    /// Full uplink outage window (no offload can leave the edge).
    pub outage_start: u64,
    pub outage_end: u64,
    /// Reply-drop window and per-dispatch drop probability.
    pub drop_prob: f64,
    pub drop_start: u64,
    pub drop_end: u64,
    /// Reply-delay window and the extra latency (ms); a delay beyond
    /// `offload_timeout_ms` is treated as a drop.
    pub delay_ms: f64,
    pub delay_start: u64,
    pub delay_end: u64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            enabled: false,
            seed: 0,
            offload_timeout_ms: 250.0,
            max_retries: 2,
            crash_endpoint: 0,
            crash_start: 0,
            crash_end: 0,
            degrade_start: 0,
            degrade_end: 0,
            degrade_bw_mbps: 50.0,
            degrade_rtt_ms: 80.0,
            outage_start: 0,
            outage_end: 0,
            drop_prob: 0.0,
            drop_start: 0,
            drop_end: 0,
            delay_ms: 0.0,
            delay_start: 0,
            delay_end: 0,
        }
    }
}

impl FaultsConfig {
    /// The representative chaos schedule `rapid chaos` falls back to when
    /// `configs/chaos.toml` is absent (every value explicit so the two
    /// cannot drift silently; `rapid chaos` prints which one it ran, and
    /// pairs this with the same 6-session / 3-endpoint fleet shape): a
    /// mid-run endpoint crash, a bandwidth/RTT collapse, a short full
    /// outage, seeded reply drops and a sub-timeout reply delay.
    pub fn demo() -> FaultsConfig {
        FaultsConfig {
            enabled: true,
            seed: 99,
            offload_timeout_ms: 250.0,
            max_retries: 2,
            crash_endpoint: 0,
            crash_start: 8,
            crash_end: 40,
            degrade_start: 16,
            degrade_end: 44,
            degrade_bw_mbps: 50.0,
            degrade_rtt_ms: 80.0,
            outage_start: 30,
            outage_end: 34,
            drop_prob: 0.3,
            drop_start: 24,
            drop_end: 48,
            delay_ms: 60.0,
            delay_start: 12,
            delay_end: 20,
        }
    }
}

/// Episode / workload parameters.
#[derive(Debug, Clone)]
pub struct EpisodeConfig {
    /// Episodes per task in a suite run.
    pub episodes: usize,
    /// Seed for the whole suite.
    pub seed: u64,
}

impl Default for EpisodeConfig {
    fn default() -> Self {
        EpisodeConfig { episodes: 12, seed: 7 }
    }
}

/// Top-level system configuration (one per experiment preset).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub name: String,
    /// Total VLA model size in GB (14.2 sim / 14.5 real-world).
    pub total_model_gb: f64,
    /// Parameter fraction resident on the edge for RAPID (2.4 / 14.2).
    pub edge_model_gb: f64,
    /// Edge fraction the vision baseline starts from (4.7 / 14.2).
    pub vision_edge_gb: f64,
    /// Edge slices for the ablated variants (paper Table V load columns):
    /// weakening a trigger degrades critical-phase detection, so the
    /// deployment compensates with a larger edge-resident slice to keep
    /// task success — 4.0 GB w/o θ_comp, 5.7 GB w/o θ_red.
    pub edge_gb_no_comp: f64,
    pub edge_gb_no_red: f64,
    pub robot: RobotConfig,
    pub scene: SceneConfig,
    pub link: LinkConfig,
    pub devices: DeviceConfig,
    pub dispatcher: DispatcherConfig,
    pub vision: VisionPolicyConfig,
    pub fleet: FleetConfig,
    pub workload: WorkloadConfig,
    pub faults: FaultsConfig,
    pub cache: CacheConfig,
    pub models: ModelsConfig,
    pub pipeline: PipelineConfig,
    pub trace: TraceConfig,
    pub placement: PlacementConfig,
    pub autoscale: AutoscaleConfig,
    pub episode: EpisodeConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            name: "libero".into(),
            total_model_gb: 14.2,
            edge_model_gb: 2.4,
            vision_edge_gb: 4.7,
            edge_gb_no_comp: 4.0,
            edge_gb_no_red: 5.7,
            robot: RobotConfig::default(),
            scene: SceneConfig::default(),
            link: LinkConfig::default(),
            devices: DeviceConfig::default(),
            dispatcher: DispatcherConfig::default(),
            vision: VisionPolicyConfig::default(),
            fleet: FleetConfig::default(),
            workload: WorkloadConfig::default(),
            faults: FaultsConfig::default(),
            cache: CacheConfig::default(),
            models: ModelsConfig::default(),
            pipeline: PipelineConfig::default(),
            trace: TraceConfig::default(),
            placement: PlacementConfig::default(),
            autoscale: AutoscaleConfig::default(),
            episode: EpisodeConfig::default(),
        }
    }
}

/// Reject a hostile bandwidth (NaN/∞/≤ 0) at config validation, keeping
/// the prior (default or previously-sanitized) value. A non-finite link
/// value used to poison every partition cost to NaN, and the planner's
/// strict-`<` argmin then silently picked index 0.
fn sanitize_bw(key: &str, val: f64, prior: f64) -> f64 {
    if val.is_finite() && val > 0.0 {
        val
    } else {
        eprintln!("[config] {key} = {val} is not a positive finite bandwidth; keeping {prior}");
        prior
    }
}

/// Reject a hostile RTT (NaN/∞/negative) at config validation, keeping
/// the prior value. Zero is a valid RTT.
fn sanitize_rtt(key: &str, val: f64, prior: f64) -> f64 {
    if val.is_finite() && val >= 0.0 {
        val
    } else {
        eprintln!("[config] {key} = {val} is not a finite non-negative RTT; keeping {prior}");
        prior
    }
}

impl SystemConfig {
    /// Overlay values from a parsed TOML tree onto this config.
    pub fn apply_value(&mut self, v: &Value) {
        self.name = v.str_or("name", &self.name).to_string();
        self.total_model_gb = v.f64_or("total_model_gb", self.total_model_gb);
        self.edge_model_gb = v.f64_or("edge_model_gb", self.edge_model_gb);
        self.edge_gb_no_comp = v.f64_or("edge_gb_no_comp", self.edge_gb_no_comp);
        self.edge_gb_no_red = v.f64_or("edge_gb_no_red", self.edge_gb_no_red);
        self.vision_edge_gb = v.f64_or("vision_edge_gb", self.vision_edge_gb);

        self.robot.dt = v.f64_or("robot.dt", self.robot.dt);
        self.robot.sensor_hz = v.f64_or("robot.sensor_hz", self.robot.sensor_hz);
        self.robot.damping = v.f64_or("robot.damping", self.robot.damping);
        self.robot.gravity = v.f64_or("robot.gravity", self.robot.gravity);
        self.robot.sensor_noise = v.f64_or("robot.sensor_noise", self.robot.sensor_noise);
        self.robot.track_gain = v.f64_or("robot.track_gain", self.robot.track_gain);
        self.robot.max_accel = v.f64_or("robot.max_accel", self.robot.max_accel);

        if let Some(n) = v.get("scene.noise").and_then(|x| x.as_str()).and_then(NoiseLevel::parse) {
            self.scene.noise = n;
        }
        self.scene.visual_noise_clarity =
            v.f64_or("scene.visual_noise_clarity", self.scene.visual_noise_clarity);
        self.scene.occlusion_rate = v.f64_or("scene.occlusion_rate", self.scene.occlusion_rate);
        self.scene.occlusion_clarity =
            v.f64_or("scene.occlusion_clarity", self.scene.occlusion_clarity);
        self.scene.occlusion_len = v.usize_or("scene.occlusion_len", self.scene.occlusion_len);

        self.link.rtt_ms =
            sanitize_rtt("link.rtt_ms", v.f64_or("link.rtt_ms", self.link.rtt_ms), self.link.rtt_ms);
        self.link.bw_mbps = sanitize_bw(
            "link.bw_mbps",
            v.f64_or("link.bw_mbps", self.link.bw_mbps),
            self.link.bw_mbps,
        );
        self.link.obs_bytes = v.f64_or("link.obs_bytes", self.link.obs_bytes);
        self.link.chunk_bytes = v.f64_or("link.chunk_bytes", self.link.chunk_bytes);
        self.link.activation_bytes = v.f64_or("link.activation_bytes", self.link.activation_bytes);
        self.link.jitter = v.f64_or("link.jitter", self.link.jitter);
        self.link.noise_retrans = v.f64_or("link.noise_retrans", self.link.noise_retrans);

        self.devices.edge_full_ms = v.f64_or("devices.edge_full_ms", self.devices.edge_full_ms);
        self.devices.cloud_compute_ms =
            v.f64_or("devices.cloud_compute_ms", self.devices.cloud_compute_ms);
        self.devices.vision_route_ms =
            v.f64_or("devices.vision_route_ms", self.devices.vision_route_ms);
        self.devices.preempt_ms = v.f64_or("devices.preempt_ms", self.devices.preempt_ms);
        self.devices.obs_capture_ms =
            v.f64_or("devices.obs_capture_ms", self.devices.obs_capture_ms);
        self.devices.jitter = v.f64_or("devices.jitter", self.devices.jitter);
        self.devices.classes = v.str_or("devices.classes", &self.devices.classes).to_string();

        self.dispatcher.theta_comp = v.f64_or("dispatcher.theta_comp", self.dispatcher.theta_comp);
        self.dispatcher.theta_red = v.f64_or("dispatcher.theta_red", self.dispatcher.theta_red);
        self.dispatcher.window_acc =
            v.usize_or("dispatcher.window_acc", self.dispatcher.window_acc);
        self.dispatcher.window_tau =
            v.usize_or("dispatcher.window_tau", self.dispatcher.window_tau);
        self.dispatcher.w_tau = v.usize_or("dispatcher.w_tau", self.dispatcher.w_tau);
        self.dispatcher.v_max = v.f64_or("dispatcher.v_max", self.dispatcher.v_max);
        self.dispatcher.z_gate = v.f64_or("dispatcher.z_gate", self.dispatcher.z_gate);
        self.dispatcher.min_m_acc = v.f64_or("dispatcher.min_m_acc", self.dispatcher.min_m_acc);
        self.dispatcher.min_m_tau = v.f64_or("dispatcher.min_m_tau", self.dispatcher.min_m_tau);
        self.dispatcher.cooldown =
            v.usize_or("dispatcher.cooldown", self.dispatcher.cooldown as usize) as u32;
        self.dispatcher.disable_comp =
            v.bool_or("dispatcher.disable_comp", self.dispatcher.disable_comp);
        self.dispatcher.disable_red =
            v.bool_or("dispatcher.disable_red", self.dispatcher.disable_red);
        self.dispatcher.static_fusion =
            v.bool_or("dispatcher.static_fusion", self.dispatcher.static_fusion);

        self.vision.entropy_threshold =
            v.f64_or("vision.entropy_threshold", self.vision.entropy_threshold);
        self.vision.split_adapt = v.f64_or("vision.split_adapt", self.vision.split_adapt);
        self.vision.min_edge_frac = v.f64_or("vision.min_edge_frac", self.vision.min_edge_frac);
        self.vision.ewma = v.f64_or("vision.ewma", self.vision.ewma);

        self.fleet.n_sessions = v.usize_or("fleet.n_sessions", self.fleet.n_sessions);
        self.fleet.max_batch = v.usize_or("fleet.max_batch", self.fleet.max_batch);
        self.fleet.batch_deadline_us =
            v.usize_or("fleet.batch_deadline_us", self.fleet.batch_deadline_us as usize) as u64;
        self.fleet.max_inflight = v.usize_or("fleet.max_inflight", self.fleet.max_inflight);
        self.fleet.endpoints = v.usize_or("fleet.endpoints", self.fleet.endpoints);
        self.fleet.episodes_per_session =
            v.usize_or("fleet.episodes_per_session", self.fleet.episodes_per_session);

        let w = &mut self.workload;
        w.enabled = v.bool_or("workload.enabled", w.enabled);
        w.arrivals = v.str_or("workload.arrivals", &w.arrivals).to_string();
        w.n_sessions = v.usize_or("workload.n_sessions", w.n_sessions);
        w.start_round = v.usize_or("workload.start_round", w.start_round as usize) as u64;
        w.interarrival_rounds = v.f64_or("workload.interarrival_rounds", w.interarrival_rounds);
        w.burst_len = v.usize_or("workload.burst_len", w.burst_len as usize) as u64;
        w.idle_len = v.usize_or("workload.idle_len", w.idle_len as usize) as u64;
        w.trace = v.str_or("workload.trace", &w.trace).to_string();
        w.seed = v.usize_or("workload.seed", w.seed as usize) as u64;
        w.episodes_min = v.usize_or("workload.episodes_min", w.episodes_min);
        w.episodes_max = v.usize_or("workload.episodes_max", w.episodes_max);
        w.family_mix = v.str_or("workload.family_mix", &w.family_mix).to_string();
        w.device_mix = v.str_or("workload.device_mix", &w.device_mix).to_string();

        let f = &mut self.faults;
        f.enabled = v.bool_or("faults.enabled", f.enabled);
        f.seed = v.usize_or("faults.seed", f.seed as usize) as u64;
        f.offload_timeout_ms = v.f64_or("faults.offload_timeout_ms", f.offload_timeout_ms);
        f.max_retries = v.usize_or("faults.max_retries", f.max_retries);
        f.crash_endpoint = v.usize_or("faults.crash_endpoint", f.crash_endpoint);
        f.crash_start = v.usize_or("faults.crash_start", f.crash_start as usize) as u64;
        f.crash_end = v.usize_or("faults.crash_end", f.crash_end as usize) as u64;
        f.degrade_start = v.usize_or("faults.degrade_start", f.degrade_start as usize) as u64;
        f.degrade_end = v.usize_or("faults.degrade_end", f.degrade_end as usize) as u64;
        f.degrade_bw_mbps = sanitize_bw(
            "faults.degrade_bw_mbps",
            v.f64_or("faults.degrade_bw_mbps", f.degrade_bw_mbps),
            f.degrade_bw_mbps,
        );
        f.degrade_rtt_ms = sanitize_rtt(
            "faults.degrade_rtt_ms",
            v.f64_or("faults.degrade_rtt_ms", f.degrade_rtt_ms),
            f.degrade_rtt_ms,
        );
        f.outage_start = v.usize_or("faults.outage_start", f.outage_start as usize) as u64;
        f.outage_end = v.usize_or("faults.outage_end", f.outage_end as usize) as u64;
        f.drop_prob = v.f64_or("faults.drop_prob", f.drop_prob);
        f.drop_start = v.usize_or("faults.drop_start", f.drop_start as usize) as u64;
        f.drop_end = v.usize_or("faults.drop_end", f.drop_end as usize) as u64;
        f.delay_ms = v.f64_or("faults.delay_ms", f.delay_ms);
        f.delay_start = v.usize_or("faults.delay_start", f.delay_start as usize) as u64;
        f.delay_end = v.usize_or("faults.delay_end", f.delay_end as usize) as u64;

        let c = &mut self.cache;
        c.enabled = v.bool_or("cache.enabled", c.enabled);
        c.capacity = v.usize_or("cache.capacity", c.capacity);
        c.ttl_rounds = v.usize_or("cache.ttl_rounds", c.ttl_rounds as usize) as u64;
        c.seed = v.usize_or("cache.seed", c.seed as usize) as u64;
        c.quant = v.f64_or("cache.quant", c.quant);
        c.z_quant = v.f64_or("cache.z_quant", c.z_quant);
        c.max_zscore = v.f64_or("cache.max_zscore", c.max_zscore);
        c.probe_ms = v.f64_or("cache.probe_ms", c.probe_ms);
        c.shared = v.bool_or("cache.shared", c.shared);
        c.shards = v.usize_or("cache.shards", c.shards);

        self.models.enabled = v.bool_or("models.enabled", self.models.enabled);
        self.models.families = v.str_or("models.families", &self.models.families).to_string();

        let p = &mut self.pipeline;
        p.enabled = v.bool_or("pipeline.enabled", p.enabled);
        p.overlap = v.bool_or("pipeline.overlap", p.overlap);
        p.speculate = v.bool_or("pipeline.speculate", p.speculate);
        p.spec_decode_ms = v.f64_or("pipeline.spec_decode_ms", p.spec_decode_ms);
        p.rollback_ms = v.f64_or("pipeline.rollback_ms", p.rollback_ms);
        p.accept_eps = v.f64_or("pipeline.accept_eps", p.accept_eps);
        p.max_zscore = v.f64_or("pipeline.max_zscore", p.max_zscore);

        let t = &mut self.trace;
        t.enabled = v.bool_or("trace.enabled", t.enabled);
        t.max_spans = v.usize_or("trace.max_spans", t.max_spans);
        t.flight_events = v.usize_or("trace.flight_events", t.flight_events);

        let pl = &mut self.placement;
        pl.enabled = v.bool_or("placement.enabled", pl.enabled);
        pl.device_class = v.str_or("placement.device_class", &pl.device_class).to_string();
        pl.max_edge_gb = v.f64_or("placement.max_edge_gb", pl.max_edge_gb);
        pl.prefix_ms_budget = v.f64_or("placement.prefix_ms_budget", pl.prefix_ms_budget);
        pl.queue_weight = v.f64_or("placement.queue_weight", pl.queue_weight);
        pl.gpu_capacity = v.f64_or("placement.gpu_capacity", pl.gpu_capacity);

        let a = &mut self.autoscale;
        a.enabled = v.bool_or("autoscale.enabled", a.enabled);
        a.min_endpoints = v.usize_or("autoscale.min_endpoints", a.min_endpoints).max(1);
        a.max_endpoints = v.usize_or("autoscale.max_endpoints", a.max_endpoints);
        a.slo_queue = v.usize_or("autoscale.slo_queue", a.slo_queue);
        a.sustain_rounds = v.usize_or("autoscale.sustain_rounds", a.sustain_rounds as usize) as u64;
        a.idle_rounds = v.usize_or("autoscale.idle_rounds", a.idle_rounds as usize) as u64;
        a.cooldown_rounds =
            v.usize_or("autoscale.cooldown_rounds", a.cooldown_rounds as usize) as u64;
        a.shed_queue = v.usize_or("autoscale.shed_queue", a.shed_queue);
        a.family_pools = v.bool_or("autoscale.family_pools", a.family_pools);

        self.episode.episodes = v.usize_or("episode.episodes", self.episode.episodes);
        self.episode.seed = v.f64_or("episode.seed", self.episode.seed as f64) as u64;
    }

    /// Fallible semantic checks an overlay cannot express (`apply_value`
    /// is infallible): device-class names and workload bounds that must
    /// be rejected at load instead of silently changing fleet
    /// composition. Returns the first problem as a human-readable error.
    pub fn validate(&self) -> Result<(), String> {
        use crate::runtime::DeviceClass;
        if DeviceClass::parse(&self.placement.device_class).is_none() {
            return Err(format!(
                "[placement] device_class = {:?} is not a known device class (known: {})",
                self.placement.device_class,
                DeviceClass::NAMES
            ));
        }
        for name in self.devices.classes.split(',').filter(|s| !s.trim().is_empty()) {
            if DeviceClass::parse(name).is_none() {
                return Err(format!(
                    "[devices] classes names unknown device class {:?} (known: {})",
                    name.trim(),
                    DeviceClass::NAMES
                ));
            }
        }
        let mix = self.workload.device_mix.trim();
        if !mix.eq_ignore_ascii_case("blocks") && !mix.eq_ignore_ascii_case("draw") {
            return Err(format!(
                "[workload] device_mix = {:?} is not a known assignment mode (known: blocks, \
                 draw; classes: {})",
                self.workload.device_mix,
                DeviceClass::NAMES
            ));
        }
        if self.workload.episodes_min > self.workload.episodes_max
            && self.workload.episodes_max != 0
        {
            return Err(format!(
                "[workload] episodes_min ({}) > episodes_max ({}): inverted episode bounds \
                 (0/0 pins fleet.episodes_per_session)",
                self.workload.episodes_min, self.workload.episodes_max
            ));
        }
        if self.workload.episodes_min > 0 && self.workload.episodes_max == 0 {
            return Err(format!(
                "[workload] episodes_min ({}) with episodes_max = 0: set both bounds \
                 (0/0 pins fleet.episodes_per_session)",
                self.workload.episodes_min
            ));
        }
        Ok(())
    }

    pub fn from_toml(src: &str) -> Result<SystemConfig, super::parse::ParseError> {
        let v = super::parse::parse_toml(src)?;
        let mut cfg = SystemConfig::default();
        cfg.apply_value(&v);
        cfg.validate().map_err(|msg| super::parse::ParseError::At(0, msg))?;
        Ok(cfg)
    }

    /// Cloud-resident parameter GB for a given edge-resident GB
    /// (load-conservation invariant: columns sum to the total).
    pub fn cloud_gb(&self, edge_gb: f64) -> f64 {
        (self.total_model_gb - edge_gb).max(0.0)
    }

    /// Emulated edge inference time for a model slice of `gb` parameters
    /// (linear in resident parameters, anchored at the Edge-Only number).
    pub fn edge_infer_ms(&self, gb: f64) -> f64 {
        self.devices.edge_full_ms * (gb / self.total_model_gb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_anchors() {
        let c = SystemConfig::default();
        assert_eq!(c.total_model_gb, 14.2);
        assert_eq!(c.dispatcher.theta_comp, 0.65);
        assert_eq!(c.dispatcher.theta_red, 0.35);
        assert_eq!(c.devices.edge_full_ms, 782.5);
    }

    #[test]
    fn toml_overlay() {
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[dispatcher]\ntheta_comp = 0.8\n[scene]\nnoise = \"distraction\"",
        )
        .unwrap();
        c.apply_value(&v);
        assert_eq!(c.dispatcher.theta_comp, 0.8);
        assert_eq!(c.scene.noise, NoiseLevel::Distraction);
        // untouched values keep defaults
        assert_eq!(c.dispatcher.theta_red, 0.35);
    }

    #[test]
    fn load_conservation() {
        let c = SystemConfig::default();
        assert!((c.cloud_gb(2.4) + 2.4 - c.total_model_gb).abs() < 1e-9);
    }

    #[test]
    fn edge_infer_scales_linearly() {
        let c = SystemConfig::default();
        let full = c.edge_infer_ms(c.total_model_gb);
        assert!((full - 782.5).abs() < 1e-9);
        assert!((c.edge_infer_ms(7.1) - 391.25).abs() < 1e-9);
    }

    #[test]
    fn fleet_defaults_and_overlay() {
        let c = SystemConfig::default();
        assert_eq!(c.fleet.n_sessions, 8);
        assert_eq!(c.fleet.max_batch, 4);
        assert_eq!(c.fleet.batch_deadline_us, 0);
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[fleet]\nn_sessions = 32\nmax_batch = 8\nbatch_deadline_us = 150000\nendpoints = 3",
        )
        .unwrap();
        c.apply_value(&v);
        assert_eq!(c.fleet.n_sessions, 32);
        assert_eq!(c.fleet.max_batch, 8);
        assert_eq!(c.fleet.batch_deadline_us, 150_000);
        assert_eq!(c.fleet.endpoints, 3);
        // untouched fleet keys keep defaults
        assert_eq!(c.fleet.max_inflight, 16);
    }

    #[test]
    fn faults_defaults_inert_and_overlay() {
        let c = SystemConfig::default();
        assert!(!c.faults.enabled);
        assert_eq!(c.faults.offload_timeout_ms, 250.0);
        assert_eq!(c.faults.max_retries, 2);
        assert_eq!(c.faults.crash_end, 0);
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[faults]\nenabled = true\nseed = 99\ncrash_endpoint = 1\ncrash_start = 8\n\
             crash_end = 40\ndrop_prob = 0.3\ndrop_start = 24\ndrop_end = 48\n\
             degrade_start = 16\ndegrade_end = 44\ndegrade_bw_mbps = 50.0",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.faults.enabled);
        assert_eq!(c.faults.seed, 99);
        assert_eq!(c.faults.crash_endpoint, 1);
        assert_eq!((c.faults.crash_start, c.faults.crash_end), (8, 40));
        assert_eq!(c.faults.drop_prob, 0.3);
        assert_eq!(c.faults.degrade_bw_mbps, 50.0);
        // untouched keys keep defaults
        assert_eq!(c.faults.offload_timeout_ms, 250.0);
        assert_eq!(c.faults.outage_end, 0);
    }

    #[test]
    fn faults_demo_schedule_is_enabled_and_windowed() {
        let f = FaultsConfig::demo();
        assert!(f.enabled);
        assert!(f.crash_end > f.crash_start);
        assert!(f.drop_prob > 0.0 && f.drop_end > f.drop_start);
        assert!(f.delay_ms < f.offload_timeout_ms, "demo delay must stay sub-timeout");
    }

    #[test]
    fn cache_defaults_inert_and_overlay() {
        let c = SystemConfig::default();
        assert!(!c.cache.enabled, "cache must default off (bit-identity)");
        assert_eq!(c.cache.capacity, 256);
        assert_eq!(c.cache.ttl_rounds, 128);
        assert!(c.cache.shared);
        assert_eq!(c.cache.shards, 1, "single-map store by default (bit-identity)");
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[cache]\nenabled = true\ncapacity = 64\nttl_rounds = 32\nseed = 9\n\
             quant = 0.05\nmax_zscore = 4.0\nshared = false\nshards = 8",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.cache.enabled);
        assert_eq!(c.cache.capacity, 64);
        assert_eq!(c.cache.ttl_rounds, 32);
        assert_eq!(c.cache.seed, 9);
        assert_eq!(c.cache.quant, 0.05);
        assert_eq!(c.cache.max_zscore, 4.0);
        assert!(!c.cache.shared);
        assert_eq!(c.cache.shards, 8);
        // untouched keys keep defaults
        assert_eq!(c.cache.probe_ms, 2.0);
        assert_eq!(c.cache.z_quant, 4.0);
    }

    #[test]
    fn models_defaults_inert_and_overlay() {
        use crate::vla::profile::ModelFamily;
        let c = SystemConfig::default();
        assert!(!c.models.enabled, "zoo must default off (bit-identity)");
        assert_eq!(
            c.models.family_list(),
            vec![ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant]
        );
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[models]\nenabled = true\nfamilies = \"pi0, edgequant\"",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.models.enabled);
        assert_eq!(
            c.models.family_list(),
            vec![ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant]
        );
        // unknown names are skipped; an all-unknown list falls back to the
        // surrogate so an enabled zoo can never have zero families
        c.models.families = "what, ever".into();
        assert_eq!(c.models.family_list(), vec![ModelFamily::Surrogate]);
    }

    #[test]
    fn workload_defaults_inert_and_overlay() {
        let c = SystemConfig::default();
        assert!(!c.workload.enabled, "workload must default off (bit-identity)");
        assert_eq!(c.workload.arrivals, "fixed");
        assert_eq!(c.workload.n_sessions, 0);
        assert_eq!(c.workload.interarrival_rounds, 0.0);
        assert_eq!(c.workload.family_mix, "blocks");
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[workload]\nenabled = true\narrivals = \"poisson\"\nn_sessions = 12\n\
             interarrival_rounds = 3.5\nseed = 41\nepisodes_min = 1\nepisodes_max = 3\n\
             family_mix = \"draw\"\ntrace = \"0,4,9\"",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.workload.enabled);
        assert_eq!(c.workload.arrivals, "poisson");
        assert_eq!(c.workload.n_sessions, 12);
        assert_eq!(c.workload.interarrival_rounds, 3.5);
        assert_eq!(c.workload.seed, 41);
        assert_eq!((c.workload.episodes_min, c.workload.episodes_max), (1, 3));
        assert_eq!(c.workload.family_mix, "draw");
        assert_eq!(c.workload.trace, "0,4,9");
        // untouched keys keep defaults
        assert_eq!(c.workload.burst_len, 4);
        assert_eq!(c.workload.idle_len, 12);
        assert_eq!(c.workload.start_round, 0);
    }

    #[test]
    fn pipeline_defaults_inert_and_overlay() {
        let c = SystemConfig::default();
        assert!(!c.pipeline.enabled, "pipeline must default off (bit-identity)");
        assert!(!c.pipeline.overlap);
        assert!(!c.pipeline.speculate);
        assert!(!c.pipeline.overlap_on() && !c.pipeline.speculate_on());
        assert_eq!(c.pipeline.spec_decode_ms, 15.0);
        assert_eq!(c.pipeline.rollback_ms, 40.0);
        assert_eq!(c.pipeline.max_zscore, 8.0);
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[pipeline]\nenabled = true\noverlap = true\nspeculate = true\n\
             spec_decode_ms = 9.0\nrollback_ms = 55.0\naccept_eps = 0.1\nmax_zscore = 4.0",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.pipeline.enabled && c.pipeline.overlap && c.pipeline.speculate);
        assert!(c.pipeline.overlap_on() && c.pipeline.speculate_on());
        assert_eq!(c.pipeline.spec_decode_ms, 9.0);
        assert_eq!(c.pipeline.rollback_ms, 55.0);
        assert_eq!(c.pipeline.accept_eps, 0.1);
        assert_eq!(c.pipeline.max_zscore, 4.0);
        // enabled alone — every sub-knob off — stays degenerate
        let mut d = SystemConfig::default();
        d.pipeline.enabled = true;
        assert!(!d.pipeline.overlap_on() && !d.pipeline.speculate_on());
    }

    #[test]
    fn trace_defaults_inert_and_overlay() {
        let c = SystemConfig::default();
        assert!(!c.trace.enabled, "trace must default off (bit-identity)");
        assert_eq!(c.trace.max_spans, 1 << 20);
        assert_eq!(c.trace.flight_events, 32);
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[trace]\nenabled = true\nmax_spans = 4096\nflight_events = 8",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.trace.enabled);
        assert_eq!(c.trace.max_spans, 4096);
        assert_eq!(c.trace.flight_events, 8);
        // partial overlay keeps the other knobs at their defaults
        let mut d = SystemConfig::default();
        let v = super::super::parse::parse_toml("[trace]\nenabled = true").unwrap();
        d.apply_value(&v);
        assert!(d.trace.enabled);
        assert_eq!(d.trace.max_spans, 1 << 20);
    }

    #[test]
    fn policy_kind_parse() {
        assert_eq!(PolicyKind::parse("safe"), Some(PolicyKind::VisionBased));
        assert_eq!(PolicyKind::parse("rapid"), Some(PolicyKind::Rapid));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn placement_defaults_inert_and_overlay() {
        let c = SystemConfig::default();
        assert!(!c.placement.enabled, "placement must default off (bit-identity)");
        assert_eq!(c.placement.device_class, "cloudlet");
        assert_eq!(c.placement.queue_weight, 0.0);
        assert_eq!(c.placement.gpu_capacity, 1.0);
        // default budget is unlimited (single-factor plan)
        assert_eq!(c.placement.budget(), crate::policy::planner::DeviceBudget::UNLIMITED);
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[placement]\nenabled = true\ndevice_class = \"nx\"\nmax_edge_gb = 2.5\n\
             prefix_ms_budget = 20.0\nqueue_weight = 0.05\ngpu_capacity = 0.5",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.placement.enabled);
        assert_eq!(c.placement.device_class, "nx");
        assert_eq!(c.placement.queue_weight, 0.05);
        assert_eq!(c.placement.gpu_capacity, 0.5);
        // overrides win over the class catalog entry
        let b = c.placement.budget();
        assert_eq!(b.mem_gb, 2.5);
        assert_eq!(b.prefix_ms, 20.0);
        // zero overrides keep the class values
        let mut d = SystemConfig::default();
        let v = super::super::parse::parse_toml("[placement]\ndevice_class = \"nx\"").unwrap();
        d.apply_value(&v);
        assert_eq!(d.placement.budget(), crate::policy::planner::DeviceBudget::of("nx").unwrap());
    }

    #[test]
    fn devices_classes_default_off_and_overlay() {
        use crate::runtime::DeviceClass;
        let c = SystemConfig::default();
        assert!(!c.devices.classes_enabled(), "device zoo must default off (bit-identity)");
        assert!(c.devices.class_list().is_empty());
        assert_eq!(c.workload.device_mix, "blocks");
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[devices]\nclasses = \"lite, nx, agx\"\n[workload]\ndevice_mix = \"draw\"",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.devices.classes_enabled());
        assert_eq!(
            c.devices.class_list(),
            vec![DeviceClass::Lite, DeviceClass::Nx, DeviceClass::Agx]
        );
        assert_eq!(c.workload.device_mix, "draw");
        assert!(c.validate().is_ok());
    }

    #[test]
    fn unknown_device_class_names_are_a_load_error() {
        // regression: a typo'd [placement] device_class used to fall back
        // to the UNLIMITED cloudlet budget silently; it is now rejected
        // at load with an error naming the valid classes
        let err = SystemConfig::from_toml("[placement]\ndevice_class = \"orin-typo\"")
            .expect_err("typo'd device_class must not load");
        let msg = err.to_string();
        assert!(msg.contains("orin-typo"), "{msg}");
        assert!(msg.contains("cloudlet, agx, nx, lite"), "{msg}");
        let err = SystemConfig::from_toml("[devices]\nclasses = \"lite, orin-typo\"")
            .expect_err("typo'd [devices] classes must not load");
        assert!(err.to_string().contains("cloudlet, agx, nx, lite"), "{err}");
        let err = SystemConfig::from_toml("[workload]\ndevice_mix = \"shuffled\"")
            .expect_err("unknown device_mix must not load");
        assert!(err.to_string().contains("blocks"), "{err}");
        // every valid name still loads
        for name in ["cloudlet", "agx", "nx", "lite"] {
            let src = format!("[placement]\ndevice_class = \"{name}\"");
            assert!(SystemConfig::from_toml(&src).is_ok(), "{name} must load");
        }
        assert!(SystemConfig::from_toml("[devices]\nclasses = \"cloudlet\"").is_ok());
    }

    #[test]
    fn inverted_episode_bounds_are_a_load_error() {
        // regression: workload.plan used to silently raise episodes_max
        // to episodes_min, pinning a count the config never asked for
        let err = SystemConfig::from_toml("[workload]\nepisodes_min = 5\nepisodes_max = 2")
            .expect_err("inverted bounds must not load");
        assert!(err.to_string().contains("episodes_min"), "{err}");
        let err = SystemConfig::from_toml("[workload]\nepisodes_min = 5\nepisodes_max = 0")
            .expect_err("half-set bounds must not load");
        assert!(err.to_string().contains("episodes_min"), "{err}");
        // the 0/0 sentinel and ordered bounds still load
        assert!(SystemConfig::from_toml("[workload]\nepisodes_min = 0\nepisodes_max = 0").is_ok());
        assert!(SystemConfig::from_toml("[workload]\nepisodes_min = 1\nepisodes_max = 3").is_ok());
        assert!(SystemConfig::from_toml("[workload]\nepisodes_min = 0\nepisodes_max = 3").is_ok());
    }

    #[test]
    fn autoscale_defaults_inert_and_overlay() {
        let c = SystemConfig::default();
        assert!(!c.autoscale.enabled, "autoscale must default off (bit-identity)");
        assert_eq!(c.autoscale.min_endpoints, 1);
        assert_eq!(c.autoscale.max_endpoints, 4);
        assert_eq!(c.autoscale.shed_queue, 0, "shed must default off");
        let mut c = SystemConfig::default();
        let v = super::super::parse::parse_toml(
            "[autoscale]\nenabled = true\nmin_endpoints = 2\nmax_endpoints = 6\nslo_queue = 3\n\
             sustain_rounds = 1\nidle_rounds = 4\ncooldown_rounds = 2\nshed_queue = 24\n\
             family_pools = true",
        )
        .unwrap();
        c.apply_value(&v);
        assert!(c.autoscale.enabled && c.autoscale.family_pools);
        assert_eq!(c.autoscale.min_endpoints, 2);
        assert_eq!(c.autoscale.max_endpoints, 6);
        assert_eq!(c.autoscale.slo_queue, 3);
        assert_eq!(c.autoscale.sustain_rounds, 1);
        assert_eq!(c.autoscale.idle_rounds, 4);
        assert_eq!(c.autoscale.cooldown_rounds, 2);
        assert_eq!(c.autoscale.shed_queue, 24);
        // min_endpoints is clamped to ≥ 1 (a zero floor would wedge)
        let mut d = SystemConfig::default();
        let v = super::super::parse::parse_toml("[autoscale]\nmin_endpoints = 0").unwrap();
        d.apply_value(&v);
        assert_eq!(d.autoscale.min_endpoints, 1);
    }

    #[test]
    fn hostile_link_values_are_sanitized() {
        // regression: a NaN/∞/≤0 link value made every partition cost NaN
        // and the planner silently picked index 0; validation now keeps
        // the prior value instead
        let mut c = SystemConfig::default();
        let nominal_bw = c.link.bw_mbps;
        let nominal_rtt = c.link.rtt_ms;
        let v = super::super::parse::parse_toml(
            "[link]\nbw_mbps = nan\nrtt_ms = -5.0\n[faults]\ndegrade_bw_mbps = 0.0\n\
             degrade_rtt_ms = inf",
        )
        .unwrap();
        c.apply_value(&v);
        assert_eq!(c.link.bw_mbps, nominal_bw);
        assert_eq!(c.link.rtt_ms, nominal_rtt);
        assert_eq!(c.faults.degrade_bw_mbps, FaultsConfig::default().degrade_bw_mbps);
        assert_eq!(c.faults.degrade_rtt_ms, FaultsConfig::default().degrade_rtt_ms);
        // sane values still pass through untouched
        let mut d = SystemConfig::default();
        let v = super::super::parse::parse_toml("[link]\nbw_mbps = 55.5\nrtt_ms = 0.0").unwrap();
        d.apply_value(&v);
        assert_eq!(d.link.bw_mbps, 55.5);
        assert_eq!(d.link.rtt_ms, 0.0);
    }
}

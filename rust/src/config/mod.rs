//! Configuration system: a self-contained TOML-subset parser, a JSON parser
//! (for `artifacts/meta.json`), typed schema structs, and the experiment
//! presets (LIBERO simulation / real-world deployment) used by the tables.

pub mod json;
pub mod parse;
pub mod presets;
pub mod schema;
pub mod value;

pub use presets::{libero_preset, realworld_preset};
pub use schema::*;
pub use value::Value;

//! Minimal JSON parser for `artifacts/meta.json` (and test fixtures).
//! Parses into the shared [`Value`] tree. Numbers become f64.

use super::value::Value;
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse_json(src: &str) -> Result<Value, JsonError> {
    let bytes = src.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Table(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Table(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::List(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::List(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_document() {
        let v = parse_json(
            r#"{"seed": 0, "variants": {"edge": {"d": 64, "n_params": 123716,
               "hlo": "edge_policy.hlo.txt"}}, "ok": true, "xs": [1, 2.5, -3e2]}"#,
        )
        .unwrap();
        assert_eq!(v.f64_or("variants.edge.d", 0.0), 64.0);
        assert_eq!(v.str_or("variants.edge.hlo", ""), "edge_policy.hlo.txt");
        let xs = v.get("xs").unwrap().as_list().unwrap();
        assert_eq!(xs[2].as_f64(), Some(-300.0));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse_json("{} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse_json(r#"{"s": "aA\n"}"#).unwrap();
        assert_eq!(v.str_or("s", ""), "aA\n");
    }

    #[test]
    fn nested_arrays() {
        let v = parse_json("[[1,2],[3]]").unwrap();
        let l = v.as_list().unwrap();
        assert_eq!(l[0].as_list().unwrap().len(), 2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse_json("{}").unwrap(), Value::table());
        assert_eq!(parse_json("[]").unwrap(), Value::List(vec![]));
    }
}

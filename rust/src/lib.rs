//! # RAPID — Redundancy-Aware and Compatibility-Optimal Edge-Cloud
//! # Partitioned Inference for Diverse VLA Models
//!
//! Production-quality reproduction of the RAPID paper (CS.DC 2026):
//! a three-layer Rust + JAX + Pallas serving stack where the Rust L3
//! coordinator implements the paper's contribution — a kinematic,
//! environment-agnostic dual-threshold dispatcher that partitions VLA
//! inference between an edge device and the cloud.
//!
//! Layer map (see DESIGN.md):
//! * [`dispatcher`] — the RAPID trigger (Algorithm 1): rolling kinematic
//!   statistics, normalized anomaly scores, dynamic phase weights,
//!   dual-threshold fusion, cooldown, chunk queue.
//! * [`policy`] — partitioning strategies: RAPID + the paper's baselines
//!   (Edge-Only, Cloud-Only, vision-entropy SAFE/ISAR).
//! * [`robot`], [`scene`] — the evaluation substrate: rigid-body N-DOF
//!   manipulator simulator and synthetic observation renderer.
//! * [`runtime`], [`vla`] — PJRT CPU client loading the AOT-compiled JAX/
//!   Pallas VLA surrogate (HLO text artifacts; python never at runtime;
//!   `pjrt` feature — offline builds use the analytic surrogates) — plus
//!   the **heterogeneous model zoo** (`vla::profile` / `vla::zoo`):
//!   deterministic model-family profiles (autoregressive short-chunk,
//!   diffusion long-chunk, quantized edge-compressed) over the same
//!   `Backend` trait, each with its own partition-point catalog, and the
//!   compatibility-aware planner (`policy::planner`) that picks the
//!   optimal split per (family, link condition). The fleet keys its
//!   cross-session batches on the family (never mixing frame layouts),
//!   endpoints advertise the families they serve, and with `[models]`
//!   disabled the whole zoo constructs nothing — bit-identical serving.
//! * [`net`] — analytic link model (with time-varying fault profiles) +
//!   the real TCP path: length-prefixed wire protocol with single and
//!   *cross-session batch* frames (batch paths encode into a reusable
//!   buffer — zero allocations per frame in steady state), blocking
//!   client, threaded cloud server (batcher in front of a model-owner
//!   worker).
//! * [`faults`] — deterministic fault injection: seeded, schedule-driven
//!   [`faults::FaultPlan`] (link outages, bandwidth/RTT collapse, endpoint
//!   crash/recover, reply drop/delay) compiled into a
//!   [`faults::FaultEngine`] the fleet scheduler queries per round; empty
//!   plans are bit-identical to no engine at all.
//! * [`cache`] — the redundancy-aware reuse cache: quantized kinematic
//!   [`cache::Signature`]s over a bounded, TTL'd [`cache::ReuseStore`]
//!   with seeded-deterministic eviction, backed by a power-of-two shard
//!   array (`cache.shards`; 1 — the default — reproduces the historical
//!   single-map store bit for bit, higher counts bound each shard
//!   independently for fleet-scale runs). Two tiers share the store:
//!   per-session speculative chunk reuse (the driver probes before every
//!   cloud dispatch in a redundant phase) and the fleet-shared result
//!   cache (cross-session batch replies admitted on flush, so one robot's
//!   answer serves the whole fleet — even through outage windows).
//!   Disabled, it constructs nothing and the serve layer is bit-identical
//!   to a cache-free build.
//! * [`serve`] — the serving stack, smallest to largest scope:
//!   [`serve::driver`] is the resumable per-session step machine
//!   (`EpisodeState`: poll → suspend on cloud → resume, with fleet
//!   arrival/departure hooks), [`serve::session`] the sequential suite
//!   runner behind the paper tables, [`serve::events`] the deterministic
//!   virtual-time event queue (binary heap, stable `(time, class, seq)`
//!   tie-break), [`serve::workload`] the seeded open-loop arrival engine
//!   (fixed / Poisson / bursty / trace-replay session plans from the
//!   `[workload]` config section), and [`serve::fleet`] the event-driven
//!   multi-session scheduler — sessions join and leave mid-run at their
//!   planned rounds (the lockstep all-at-t0 shape falls out as the
//!   degenerate case, bit-identical to the historical round loop), cloud
//!   offloads coalesced across sessions by [`serve::batcher`] (full /
//!   deadline / drain flushes), spread over endpoints by
//!   [`serve::router`], with fleet-wide backpressure
//!   (`fleet.max_inflight`) that degrades refused offloads to the edge
//!   slice — and failover under injected faults: crashed endpoints are
//!   routed around, lost replies retried on the least-loaded survivor,
//!   exhausted batches re-served from the edge
//!   (`EpisodeState::fail_cloud`), so no session ever wedges in suspend.
//!   Fleet bookkeeping is O(batch) per event — incremental
//!   active/finished counters, epoch-tagged lazy fault-edge adoption, a
//!   sorted arrival list for dead-air jumps — so `rapid bench scale`
//!   pushes 100k in-process sessions through one scheduler. The
//!   config-gated `[pipeline]` stage adds **pipelined + speculative
//!   partition execution** on top: *overlap* hides the step t+1
//!   edge-prefix compute under the in-flight round trip (an offload
//!   charges `max(prefix, wire + cloud)` instead of the sum), and
//!   *speculative edge decoding* serves a provisional edge chunk
//!   immediately — the session keeps stepping and the cloud reply
//!   confirms the consumed prefix for free or rolls it back for a
//!   configured penalty, with the `[cache]` z-score gate keeping
//!   anomalous phases sequential. Shipped disabled: the inert stage is
//!   bit-identical to the sequential scheduler, PRNG draws included.
//!   The `[placement]`/`[autoscale]` control plane extends both ends:
//!   **multi-factor placement** scores partition points over (device
//!   budget, family, link, endpoint state) — a device-class budget
//!   filters infeasible splits (an emptied catalog degrades to the
//!   edge-only sentinel plan, never a wedge) and the least-loaded
//!   endpoint's queue/capacity reweights the cloud term
//!   (`policy::planner::plan_with`, `serve::router::Router::load_for`)
//!   — while the **deterministic autoscaler** spawns and LIFO-drains
//!   pre-allocated endpoint slots from pure round-start counter reads
//!   (SLO pressure / idle streaks, with hysteresis) and an admission
//!   shed gates offloads to edge-only before queues can wedge. Both
//!   ship disabled and bit-identical off; enabled runs replay exactly.
//!   The config-gated `[devices] classes` **device-heterogeneity zoo**
//!   ([`runtime::DeviceClass`]) block- or draw-assigns a catalog of
//!   edge-silicon classes (cloudlet / agx / nx / lite) across fleet
//!   sessions: each slot plans over its own (class, family, link)
//!   triple — the class budget filters the split catalog, the compute
//!   scale shifts the argmin toward shallower splits on weak silicon,
//!   NPU classes snap served actions onto their grids, and reuse
//!   signatures carry the class as a hard discriminant so cache hits
//!   never cross a class boundary. Per-class rollups exactly partition
//!   fleet totals; disabled (or cloudlet-only), every factor is an
//!   exact no-op and serving is bit-identical to the class-free
//!   scheduler. Unknown class names fail at config load — never a
//!   silent unlimited budget.
//! * [`obs`] — the observability layer, config-gated behind `[trace]`:
//!   a deterministic virtual-time span tracer (Chrome trace-event JSON /
//!   JSONL export, zero PRNG draws, zero clock advances — traced runs
//!   replay bit-identically and same-seed traces are byte-identical), a
//!   metrics registry of named counters + log-bucketed latency
//!   histograms (p50/p95/p99/max over fixed power-of-two buckets with an
//!   exactly associative merge), and a per-session flight recorder whose
//!   ring-buffer postmortem every CLI wedge path dumps.
//! * [`experiments`] — one generator per paper table/figure.
//!
//! Python runs once at build time (`make artifacts`); the binary built from
//! this crate is self-contained afterwards.

pub mod util;
pub mod config;
pub mod robot;
pub mod scene;
pub mod kinematics;
pub mod dispatcher;
pub mod policy;
pub mod runtime;
pub mod vla;
pub mod net;
pub mod faults;
pub mod cache;
pub mod serve;
pub mod metrics;
pub mod obs;
pub mod benchkit;
pub mod experiments;

/// Degrees of freedom of the simulated manipulator (paper: 7-DOF arm).
pub const N_JOINTS: usize = 7;
/// Action-chunk length k (Eq. 1).
pub const CHUNK: usize = 8;
/// Action-token vocabulary for the entropy signal.
pub const VOCAB: usize = 64;
/// Visual feature channels produced by the renderer.
pub const D_VIS: usize = 64;
/// Proprioceptive input dim: q, q_dot, tau.
pub const D_PROP: usize = 3 * N_JOINTS;
/// Instruction one-hot size.
pub const N_INSTR: usize = 8;

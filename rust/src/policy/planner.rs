//! Compatibility-aware partition planner: pick the split point of a model
//! family's catalog that minimizes the per-offload critical path under
//! the link condition currently in force.
//!
//! Cost model (matches the driver's charging, device-nominal):
//!
//! ```text
//! cost(p) = p.edge_prefix_ms                    (split-point activations)
//!         + p.payload_bytes·8 / bw + rtt/2      (uplink transfer)
//!         + p.cloud_compute_ms                  (cloud slice)
//! ```
//!
//! Ties break toward the **larger payload** (shallower split): that makes
//! the chosen payload monotone non-decreasing in bandwidth — pinned by
//! `proptest_invariants` — so a degrading link always moves the split
//! deeper, never oscillates. The planner is a pure function: no PRNG, no
//! state, identical output for identical (family, link) inputs, which is
//! what lets the fleet replan per round under fault-injected link
//! profiles without perturbing determinism.

use crate::vla::profile::{FamilyProfile, ModelFamily, PartitionPoint};

/// The planner's verdict for one session: everything the episode driver
/// needs to serve a family at its chosen split.
#[derive(Debug, Clone, PartialEq)]
pub struct FamilyPlan {
    pub family: ModelFamily,
    /// Actions per inference (family chunk shape).
    pub chunk_len: usize,
    /// Multiplier on edge-slice inference time.
    pub edge_ms_scale: f64,
    /// Edge compute charged before each offload leaves the device (ms).
    pub edge_prefix_ms: f64,
    /// Offload payload at the chosen split (bytes).
    pub payload_bytes: f64,
    /// Cloud compute per offload at the chosen split (ms, nominal — the
    /// driver rescales its jittered draw by this / `devices.cloud_compute_ms`).
    pub cloud_compute_ms: f64,
    /// Cloud compute at the family's shallowest split (full cloud model,
    /// ms): the cost charged to strategies that take no zoo split —
    /// entropy baselines partition with their own split model, so they
    /// pay the family's full-model cloud price, never a deep-split
    /// discount whose edge prefix they skipped.
    pub full_cloud_ms: f64,
    /// Edge-resident GB at the chosen split (reporting).
    pub edge_gb: f64,
    /// Index into the family's partition catalog.
    pub partition_idx: usize,
}

/// Estimated per-offload critical path of one partition point (ms).
pub fn partition_cost(p: &PartitionPoint, bw_mbps: f64, rtt_ms: f64) -> f64 {
    let bw = bw_mbps.max(1e-3);
    p.edge_prefix_ms + p.payload_bytes * 8.0 / (bw * 1e6) * 1e3 + rtt_ms / 2.0 + p.cloud_compute_ms
}

/// Pick the compatibility-optimal partition of `profile` under the given
/// link condition (effective bandwidth/RTT — nominal config values, or a
/// fault window's degraded profile).
pub fn plan(profile: &FamilyProfile, bw_mbps: f64, rtt_ms: f64) -> FamilyPlan {
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    for (i, p) in profile.partitions.iter().enumerate() {
        let c = partition_cost(p, bw_mbps, rtt_ms);
        // strict '<' + shallow-to-deep catalog order = ties keep the
        // earlier (larger-payload) point: monotone in bandwidth
        if c < best_cost {
            best = i;
            best_cost = c;
        }
    }
    let p = profile.partitions[best];
    FamilyPlan {
        family: profile.family,
        chunk_len: profile.chunk_len,
        edge_ms_scale: profile.edge_ms_scale,
        edge_prefix_ms: p.edge_prefix_ms,
        payload_bytes: p.payload_bytes,
        cloud_compute_ms: p.cloud_compute_ms,
        full_cloud_ms: profile.partitions[0].cloud_compute_ms,
        edge_gb: p.edge_gb,
        partition_idx: best,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surrogate_plan_is_the_nominal_no_op() {
        let p = plan(&FamilyProfile::of(ModelFamily::Surrogate), 1000.0, 8.0);
        assert_eq!(p.partition_idx, 0);
        assert_eq!(p.payload_bytes, 1.5e6);
        assert_eq!(p.cloud_compute_ms, 90.0);
        assert_eq!(p.edge_prefix_ms, 0.0);
        assert_eq!(p.edge_ms_scale, 1.0);
        assert_eq!(p.chunk_len, crate::CHUNK);
    }

    #[test]
    fn fast_link_prefers_shallow_splits_slow_link_deep() {
        for fam in [ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            let prof = FamilyProfile::of(fam);
            let fast = plan(&prof, 1000.0, 8.0);
            let slow = plan(&prof, 5.0, 80.0);
            assert!(
                fast.payload_bytes >= slow.payload_bytes,
                "{fam:?}: fast {} < slow {}",
                fast.payload_bytes,
                slow.payload_bytes
            );
            assert_eq!(slow.partition_idx, prof.partitions.len() - 1, "{fam:?} at 5 Mbps");
            assert_eq!(fast.partition_idx, 0, "{fam:?} at 1 Gbps");
        }
    }

    #[test]
    fn planner_is_deterministic() {
        let prof = FamilyProfile::of(ModelFamily::Pi0Diffusion);
        assert_eq!(plan(&prof, 77.7, 13.0), plan(&prof, 77.7, 13.0));
    }

    #[test]
    fn cost_accounts_every_term() {
        let p = PartitionPoint {
            edge_gb: 2.0,
            edge_prefix_ms: 10.0,
            payload_bytes: 1e6,
            cloud_compute_ms: 100.0,
        };
        // 1e6 B = 8 Mbit at 100 Mbps = 80 ms; + rtt/2 = 5; + 10 + 100
        assert!((partition_cost(&p, 100.0, 10.0) - 195.0).abs() < 1e-9);
    }
}

//! Compatibility-aware partition planner: pick the split point of a model
//! family's catalog that minimizes the per-offload critical path under
//! the link condition currently in force.
//!
//! Cost model (matches the driver's charging, device-nominal):
//!
//! ```text
//! cost(p) = p.edge_prefix_ms                    (split-point activations)
//!         + p.payload_bytes·8 / bw + rtt/2      (uplink transfer)
//!         + p.cloud_compute_ms                  (cloud slice)
//! ```
//!
//! Ties break toward the **larger payload** (shallower split): that makes
//! the chosen payload monotone non-decreasing in bandwidth — pinned by
//! `proptest_invariants` — so a degrading link always moves the split
//! deeper, never oscillates. The planner is a pure function: no PRNG, no
//! state, identical output for identical (family, link) inputs, which is
//! what lets the fleet replan per round under fault-injected link
//! profiles without perturbing determinism.
//!
//! # Multi-factor placement (`[placement]`)
//!
//! Link cost alone contradicts two realities of edge-cloud VLA serving
//! (RoboECC direction): the edge device has finite memory and battery,
//! and the cloud endpoint the router will pick has finite GPU capacity
//! and a queue. [`plan_with`] extends the single-factor score:
//!
//! * a [`DeviceBudget`] (per device class) **filters** partition points
//!   the device cannot host — too many edge-resident GB, or a per-offload
//!   edge prefix the battery budget cannot sustain;
//! * an [`EndpointLoad`] (capacity + queue depth of the least-loaded
//!   compatible endpoint) **scales** the cloud term, so a contended or
//!   weak endpoint pushes the split deeper (more edge, less cloud) —
//!   the planner's split choice and the router's least-loaded choice
//!   stop contradicting each other.
//!
//! With the budget unlimited and the endpoint nominal the multi-factor
//! score reduces *bit-identically* to the single-factor plan (`x * 1.0`
//! and `min(x, ∞)` are exact in IEEE float) — pinned by proptest.
//!
//! A catalog filtered to empty degrades deterministically to the
//! [`edge_only_plan`] sentinel: the session serves every step from its
//! resident edge slice and never offloads (no wedge, no panic).

use crate::runtime::device::DeviceClass;
use crate::vla::profile::{FamilyProfile, ModelFamily, PartitionPoint};

/// `partition_idx` sentinel of the edge-only degrade plan: no catalog
/// entry was feasible (or the catalog was empty), so the session serves
/// from its edge slice and never offloads.
pub const EDGE_ONLY_SPLIT: usize = usize::MAX;

/// The planner's verdict for one session: everything the episode driver
/// needs to serve a family at its chosen split.
#[derive(Debug, Clone, PartialEq)]
pub struct FamilyPlan {
    pub family: ModelFamily,
    /// Actions per inference (family chunk shape).
    pub chunk_len: usize,
    /// Multiplier on edge-slice inference time.
    pub edge_ms_scale: f64,
    /// Edge compute charged before each offload leaves the device (ms).
    pub edge_prefix_ms: f64,
    /// Offload payload at the chosen split (bytes).
    pub payload_bytes: f64,
    /// Cloud compute per offload at the chosen split (ms, nominal — the
    /// driver rescales its jittered draw by this / `devices.cloud_compute_ms`).
    pub cloud_compute_ms: f64,
    /// Cloud compute at the family's shallowest split (full cloud model,
    /// ms): the cost charged to strategies that take no zoo split —
    /// entropy baselines partition with their own split model, so they
    /// pay the family's full-model cloud price, never a deep-split
    /// discount whose edge prefix they skipped.
    pub full_cloud_ms: f64,
    /// Edge-resident GB at the chosen split (reporting).
    pub edge_gb: f64,
    /// Index into the family's partition catalog ([`EDGE_ONLY_SPLIT`]
    /// when the budget filtered the catalog to empty).
    pub partition_idx: usize,
}

impl FamilyPlan {
    /// Did the planner degrade to the no-offload sentinel?
    pub fn is_edge_only(&self) -> bool {
        self.partition_idx == EDGE_ONLY_SPLIT
    }
}

/// Per-device-class placement budget: what the edge device can host.
/// Fields are upper bounds a partition point must satisfy to be feasible;
/// `INFINITY` disables that bound.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceBudget {
    /// Edge-resident parameter memory the device can hold (GB).
    pub mem_gb: f64,
    /// Battery-derived cap on per-offload edge prefix compute (ms): a
    /// power-constrained device cannot sustain heavy split-point
    /// activations on every offload.
    pub prefix_ms: f64,
}

impl DeviceBudget {
    /// No budget: every catalog point is feasible (single-factor plan).
    pub const UNLIMITED: DeviceBudget =
        DeviceBudget { mem_gb: f64::INFINITY, prefix_ms: f64::INFINITY };

    /// Built-in device-class catalog (RoboECC-style anchors), keyed by
    /// [`DeviceClass`]:
    ///
    /// * `cloudlet` — wall-powered edge server: no budget.
    /// * `agx`      — embedded GPU module: 5 GB / 70 ms (excludes only
    ///   the deepest diffusion split).
    /// * `nx`       — mid-tier module: 3.5 GB / 30 ms (shallow + mid
    ///   splits only).
    /// * `lite`     — battery CPU-only robot: 2 GB / 10 ms (only the
    ///   quantized family's shallow split fits; every other family
    ///   degrades to edge-only).
    pub fn for_class(class: DeviceClass) -> DeviceBudget {
        match class {
            DeviceClass::Cloudlet => DeviceBudget::UNLIMITED,
            DeviceClass::Agx => DeviceBudget { mem_gb: 5.0, prefix_ms: 70.0 },
            DeviceClass::Nx => DeviceBudget { mem_gb: 3.5, prefix_ms: 30.0 },
            DeviceClass::Lite => DeviceBudget { mem_gb: 2.0, prefix_ms: 10.0 },
        }
    }

    /// [`DeviceBudget::for_class`] from a config-file class name. Returns
    /// `None` for unknown names — callers must reject, not default. (The
    /// historical fallback to `UNLIMITED` meant a typo'd
    /// `[placement] device_class` silently removed every budget; config
    /// load now validates names against [`DeviceClass::NAMES`].)
    pub fn of(class: &str) -> Option<DeviceBudget> {
        DeviceClass::parse(class).map(DeviceBudget::for_class)
    }

    /// Is `p` inside this budget?
    pub fn admits(&self, p: &PartitionPoint) -> bool {
        p.edge_gb <= self.mem_gb && p.edge_prefix_ms <= self.prefix_ms
    }
}

/// Endpoint-state factor folded into the cloud term of the score: the
/// queue depth and GPU capacity of the least-loaded endpoint that could
/// serve this family (the one the router would pick).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EndpointLoad {
    /// Requests queued ahead of this offload on the best endpoint.
    pub queue_depth: u64,
    /// Relative GPU capacity of that endpoint (1.0 = the nominal device
    /// the catalog's `cloud_compute_ms` was calibrated on).
    pub capacity: f64,
    /// Cost weight per queued request (config `placement.queue_weight`;
    /// 0 ignores the queue).
    pub queue_weight: f64,
}

impl EndpointLoad {
    /// Idle nominal endpoint: multiplier exactly 1.0 (single-factor plan).
    pub const NOMINAL: EndpointLoad =
        EndpointLoad { queue_depth: 0, capacity: 1.0, queue_weight: 0.0 };

    /// Multiplier on the cloud term: queued work inflates it, a stronger
    /// GPU deflates it. Exactly 1.0 for [`EndpointLoad::NOMINAL`].
    pub fn multiplier(&self) -> f64 {
        (1.0 + self.queue_depth as f64 * self.queue_weight) / self.capacity.max(1e-6)
    }
}

/// Estimated per-offload critical path of one partition point (ms).
pub fn partition_cost(p: &PartitionPoint, bw_mbps: f64, rtt_ms: f64) -> f64 {
    let bw = bw_mbps.max(1e-3);
    p.edge_prefix_ms + p.payload_bytes * 8.0 / (bw * 1e6) * 1e3 + rtt_ms / 2.0 + p.cloud_compute_ms
}

/// Multi-factor score: [`partition_cost`] with the cloud term scaled by
/// the endpoint-load multiplier. With `load_mult == 1.0` this is
/// bit-identical to [`partition_cost`] (`x * 1.0 == x` in IEEE floats —
/// same terms, same summation order).
pub fn partition_score(p: &PartitionPoint, bw_mbps: f64, rtt_ms: f64, load_mult: f64) -> f64 {
    let bw = bw_mbps.max(1e-3);
    p.edge_prefix_ms
        + p.payload_bytes * 8.0 / (bw * 1e6) * 1e3
        + rtt_ms / 2.0
        + p.cloud_compute_ms * load_mult
}

/// The no-offload degrade sentinel: the session serves every step from
/// its resident edge slice. Offload-path fields are zero and
/// `partition_idx` is [`EDGE_ONLY_SPLIT`]; edge-side economics
/// (`chunk_len`, `edge_ms_scale`) keep the family's real values so the
/// edge slice still behaves like that family.
pub fn edge_only_plan(profile: &FamilyProfile) -> FamilyPlan {
    FamilyPlan {
        family: profile.family,
        chunk_len: profile.chunk_len,
        edge_ms_scale: profile.edge_ms_scale,
        edge_prefix_ms: 0.0,
        payload_bytes: 0.0,
        cloud_compute_ms: 0.0,
        full_cloud_ms: profile.partitions.first().map_or(0.0, |p| p.cloud_compute_ms),
        edge_gb: 0.0,
        partition_idx: EDGE_ONLY_SPLIT,
    }
}

/// Budget-filtered, endpoint-aware argmin over the catalog. Returns
/// `None` when no partition point survives the filter (empty catalog, or
/// every point over budget) — callers degrade to [`edge_only_plan`].
///
/// Non-finite scores are skipped rather than compared: a NaN cost can
/// never win the argmin silently (the historical strict-`<` bug made
/// index 0 win whenever every cost was NaN). Link values are additionally
/// sanitized at config validation, so finite inputs are the normal case.
pub fn try_plan_with(
    profile: &FamilyProfile,
    bw_mbps: f64,
    rtt_ms: f64,
    budget: DeviceBudget,
    load: EndpointLoad,
) -> Option<FamilyPlan> {
    let load_mult = load.multiplier();
    let mut best: Option<usize> = None;
    let mut best_cost = f64::INFINITY;
    for (i, p) in profile.partitions.iter().enumerate() {
        if !budget.admits(p) {
            continue;
        }
        let c = partition_score(p, bw_mbps, rtt_ms, load_mult);
        if !c.is_finite() {
            continue;
        }
        // strict '<' + shallow-to-deep catalog order = ties keep the
        // earlier (larger-payload) point: monotone in bandwidth
        if c < best_cost {
            best = Some(i);
            best_cost = c;
        }
    }
    let best = best?;
    let p = profile.partitions[best];
    Some(FamilyPlan {
        family: profile.family,
        chunk_len: profile.chunk_len,
        edge_ms_scale: profile.edge_ms_scale,
        edge_prefix_ms: p.edge_prefix_ms,
        payload_bytes: p.payload_bytes,
        cloud_compute_ms: p.cloud_compute_ms,
        full_cloud_ms: profile.partitions[0].cloud_compute_ms,
        edge_gb: p.edge_gb,
        partition_idx: best,
    })
}

/// [`try_plan_with`] that degrades to [`edge_only_plan`] instead of
/// returning `None` — the total function every scheduler path calls.
pub fn plan_with(
    profile: &FamilyProfile,
    bw_mbps: f64,
    rtt_ms: f64,
    budget: DeviceBudget,
    load: EndpointLoad,
) -> FamilyPlan {
    try_plan_with(profile, bw_mbps, rtt_ms, budget, load)
        .unwrap_or_else(|| edge_only_plan(profile))
}

/// Pick the compatibility-optimal partition of `profile` under the given
/// link condition (effective bandwidth/RTT — nominal config values, or a
/// fault window's degraded profile). Single-factor: unlimited budget,
/// nominal endpoint. An empty catalog degrades to [`edge_only_plan`]
/// instead of panicking on `partitions[best]`.
pub fn plan(profile: &FamilyProfile, bw_mbps: f64, rtt_ms: f64) -> FamilyPlan {
    plan_with(profile, bw_mbps, rtt_ms, DeviceBudget::UNLIMITED, EndpointLoad::NOMINAL)
}

/// [`partition_score`] with the edge-prefix term scaled by the device
/// class's compute factor: weaker silicon pays more for the same split
/// activations, so the argmin shifts toward shallower splits (or cloud
/// work) on weak devices. `prefix_scale == 1.0` is bit-identical to
/// [`partition_score`] (`x * 1.0 == x`, same summation order).
pub fn partition_score_for_class(
    p: &PartitionPoint,
    prefix_scale: f64,
    bw_mbps: f64,
    rtt_ms: f64,
    load_mult: f64,
) -> f64 {
    let bw = bw_mbps.max(1e-3);
    p.edge_prefix_ms * prefix_scale
        + p.payload_bytes * 8.0 / (bw * 1e6) * 1e3
        + rtt_ms / 2.0
        + p.cloud_compute_ms * load_mult
}

/// Plan over a (device class, family, link) triple: [`plan_with`]'s
/// budget-filtered, endpoint-aware argmin with the edge-prefix term
/// scaled by the class's compute factor, and the chosen plan's
/// `edge_prefix_ms` carrying that class-scaled cost (what the driver
/// actually charges per offload). The budget still filters on the
/// *unscaled* catalog values (memory is class-independent). For
/// [`DeviceClass::Cloudlet`] (scale exactly 1.0) this is bit-identical
/// to [`plan_with`]. A catalog filtered to empty degrades to
/// [`edge_only_plan`] — on a `lite` robot most families land here.
pub fn plan_for_class(
    profile: &FamilyProfile,
    class: DeviceClass,
    bw_mbps: f64,
    rtt_ms: f64,
    budget: DeviceBudget,
    load: EndpointLoad,
) -> FamilyPlan {
    let scale = class.edge_scale();
    let load_mult = load.multiplier();
    let mut best: Option<usize> = None;
    let mut best_cost = f64::INFINITY;
    for (i, p) in profile.partitions.iter().enumerate() {
        if !budget.admits(p) {
            continue;
        }
        let c = partition_score_for_class(p, scale, bw_mbps, rtt_ms, load_mult);
        if !c.is_finite() {
            continue;
        }
        // strict '<' + shallow-to-deep catalog order: ties keep the
        // earlier (larger-payload) point, as in `try_plan_with`
        if c < best_cost {
            best = Some(i);
            best_cost = c;
        }
    }
    let Some(best) = best else {
        return edge_only_plan(profile);
    };
    let p = profile.partitions[best];
    FamilyPlan {
        family: profile.family,
        chunk_len: profile.chunk_len,
        edge_ms_scale: profile.edge_ms_scale,
        edge_prefix_ms: p.edge_prefix_ms * scale,
        payload_bytes: p.payload_bytes,
        cloud_compute_ms: p.cloud_compute_ms,
        full_cloud_ms: profile.partitions[0].cloud_compute_ms,
        edge_gb: p.edge_gb,
        partition_idx: best,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surrogate_plan_is_the_nominal_no_op() {
        let p = plan(&FamilyProfile::of(ModelFamily::Surrogate), 1000.0, 8.0);
        assert_eq!(p.partition_idx, 0);
        assert_eq!(p.payload_bytes, 1.5e6);
        assert_eq!(p.cloud_compute_ms, 90.0);
        assert_eq!(p.edge_prefix_ms, 0.0);
        assert_eq!(p.edge_ms_scale, 1.0);
        assert_eq!(p.chunk_len, crate::CHUNK);
    }

    #[test]
    fn fast_link_prefers_shallow_splits_slow_link_deep() {
        for fam in [ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            let prof = FamilyProfile::of(fam);
            let fast = plan(&prof, 1000.0, 8.0);
            let slow = plan(&prof, 5.0, 80.0);
            assert!(
                fast.payload_bytes >= slow.payload_bytes,
                "{fam:?}: fast {} < slow {}",
                fast.payload_bytes,
                slow.payload_bytes
            );
            assert_eq!(slow.partition_idx, prof.partitions.len() - 1, "{fam:?} at 5 Mbps");
            assert_eq!(fast.partition_idx, 0, "{fam:?} at 1 Gbps");
        }
    }

    #[test]
    fn planner_is_deterministic() {
        let prof = FamilyProfile::of(ModelFamily::Pi0Diffusion);
        assert_eq!(plan(&prof, 77.7, 13.0), plan(&prof, 77.7, 13.0));
    }

    #[test]
    fn cost_accounts_every_term() {
        let p = PartitionPoint {
            edge_gb: 2.0,
            edge_prefix_ms: 10.0,
            payload_bytes: 1e6,
            cloud_compute_ms: 100.0,
        };
        // 1e6 B = 8 Mbit at 100 Mbps = 80 ms; + rtt/2 = 5; + 10 + 100
        assert!((partition_cost(&p, 100.0, 10.0) - 195.0).abs() < 1e-9);
    }

    #[test]
    fn empty_catalog_degrades_to_edge_only_instead_of_panicking() {
        // regression: plan() used to index partitions[best] unguarded —
        // with budget filtering an empty catalog is a reachable state and
        // must degrade deterministically, not panic
        let empty = FamilyProfile {
            family: ModelFamily::OpenVlaAr,
            chunk_len: 4,
            edge_ms_scale: 1.0,
            action_quant: 0.0,
            partitions: Vec::new(),
        };
        let p = plan(&empty, 100.0, 10.0);
        assert!(p.is_edge_only());
        assert_eq!(p.partition_idx, EDGE_ONLY_SPLIT);
        assert_eq!(p.payload_bytes, 0.0);
        assert_eq!(p.cloud_compute_ms, 0.0);
        // edge-side economics keep the family's real values
        assert_eq!(p.chunk_len, 4);
        assert_eq!(p.family, ModelFamily::OpenVlaAr);
        assert_eq!(plan(&empty, 100.0, 10.0), p, "degrade is deterministic");
    }

    #[test]
    fn over_budget_catalog_degrades_to_edge_only() {
        // the `lite` class (2 GB) cannot host any OpenVLA split (2.4 GB
        // shallowest): filtered-to-empty must yield the edge-only sentinel
        let prof = FamilyProfile::of(ModelFamily::OpenVlaAr);
        let lite = DeviceBudget::for_class(DeviceClass::Lite);
        let p = plan_with(&prof, 100.0, 10.0, lite, EndpointLoad::NOMINAL);
        assert!(p.is_edge_only());
        assert!(try_plan_with(&prof, 100.0, 10.0, lite, EndpointLoad::NOMINAL).is_none());
    }

    #[test]
    fn nan_link_never_wins_the_argmin_silently() {
        // regression: NaN bandwidth/RTT made every cost NaN, strict '<'
        // never updated, and index 0 won silently. Non-finite scores are
        // now skipped, so an all-NaN catalog degrades to edge-only.
        let prof = FamilyProfile::of(ModelFamily::Pi0Diffusion);
        let p = plan(&prof, f64::NAN, 10.0);
        assert!(p.is_edge_only(), "NaN link must not silently pick split 0: {p:?}");
        let p = plan(&prof, 100.0, f64::NAN);
        assert!(p.is_edge_only());
        // infinite rtt likewise cannot produce a finite score
        let p = plan(&prof, 100.0, f64::INFINITY);
        assert!(p.is_edge_only());
    }

    #[test]
    fn unlimited_budget_nominal_endpoint_reduces_to_single_factor() {
        for fam in ModelFamily::ALL {
            let prof = FamilyProfile::of(fam);
            for (bw, rtt) in [(1000.0, 8.0), (50.0, 40.0), (5.0, 80.0), (77.7, 13.0)] {
                let single = plan(&prof, bw, rtt);
                let multi =
                    plan_with(&prof, bw, rtt, DeviceBudget::UNLIMITED, EndpointLoad::NOMINAL);
                assert_eq!(single, multi, "{fam:?} at {bw} Mbps");
            }
        }
    }

    #[test]
    fn memory_budget_filters_deep_splits() {
        // nx class (3.5 GB / 30 ms): OpenVLA's deep split (4.8 GB / 65 ms)
        // is infeasible even on a 5 Mbps link that would otherwise pick it
        let prof = FamilyProfile::of(ModelFamily::OpenVlaAr);
        let free = plan(&prof, 5.0, 80.0);
        assert_eq!(free.partition_idx, 2);
        let nx = plan_with(
            &prof,
            5.0,
            80.0,
            DeviceBudget::for_class(DeviceClass::Nx),
            EndpointLoad::NOMINAL,
        );
        assert_eq!(nx.partition_idx, 1, "budget must stop at the mid split");
        assert!(nx.edge_gb <= 3.5 && nx.edge_prefix_ms <= 30.0);
    }

    #[test]
    fn endpoint_contention_pushes_the_split_deeper() {
        // a loaded endpoint inflates the cloud term: the planner sheds
        // cloud work by taking a deeper split than the idle-endpoint plan
        let prof = FamilyProfile::of(ModelFamily::OpenVlaAr);
        let idle = plan_with(&prof, 200.0, 20.0, DeviceBudget::UNLIMITED, EndpointLoad::NOMINAL);
        let loaded = EndpointLoad { queue_depth: 12, capacity: 1.0, queue_weight: 0.05 };
        let hot = plan_with(&prof, 200.0, 20.0, DeviceBudget::UNLIMITED, loaded);
        assert!(
            hot.partition_idx >= idle.partition_idx,
            "contention may never move the split shallower: {} vs {}",
            hot.partition_idx,
            idle.partition_idx
        );
        assert!(hot.partition_idx > 0, "12 queued at weight 0.05 must move a 200 Mbps plan");
        // a weak GPU (half capacity) acts the same way
        let weak = EndpointLoad { queue_depth: 0, capacity: 0.5, queue_weight: 0.0 };
        let w = plan_with(&prof, 200.0, 20.0, DeviceBudget::UNLIMITED, weak);
        assert!(w.partition_idx >= idle.partition_idx);
    }

    #[test]
    fn device_class_catalog_parses_and_rejects_unknown_names() {
        // regression (flipped pin): `of` used to fall back to UNLIMITED
        // for any unrecognized string, so a typo'd [placement]
        // device_class silently removed every budget. Unknown names are
        // now rejected — config load turns this None into a hard error.
        assert_eq!(DeviceBudget::of("unknown-typo"), None);
        assert_eq!(DeviceBudget::of(""), None);
        assert_eq!(DeviceBudget::of("cloudlet"), Some(DeviceBudget::UNLIMITED));
        let nx = DeviceBudget::of("nx").unwrap();
        assert!(nx.mem_gb < DeviceBudget::of("agx").unwrap().mem_gb);
        assert!(DeviceBudget::of("lite").unwrap().mem_gb < nx.mem_gb);
        for c in DeviceClass::ALL {
            assert_eq!(DeviceBudget::of(c.name()), Some(DeviceBudget::for_class(c)));
        }
        assert_eq!(EndpointLoad::NOMINAL.multiplier(), 1.0);
    }

    #[test]
    fn cloudlet_class_plan_is_bit_identical_to_plan_with() {
        for fam in ModelFamily::ALL {
            let prof = FamilyProfile::of(fam);
            for (bw, rtt) in [(1000.0, 8.0), (50.0, 40.0), (5.0, 80.0), (77.7, 13.0)] {
                let base =
                    plan_with(&prof, bw, rtt, DeviceBudget::UNLIMITED, EndpointLoad::NOMINAL);
                let cls = plan_for_class(
                    &prof,
                    DeviceClass::Cloudlet,
                    bw,
                    rtt,
                    DeviceBudget::UNLIMITED,
                    EndpointLoad::NOMINAL,
                );
                assert_eq!(base, cls, "{fam:?} at {bw} Mbps");
            }
        }
    }

    #[test]
    fn classes_pick_provably_different_partition_points() {
        // the device-zoo acceptance shape at the default 120 Mbps / 20 ms
        // link: cloudlet takes OpenVLA's deep split, nx is budget-stopped
        // at the mid split, lite can host no OpenVLA split at all
        let prof = FamilyProfile::of(ModelFamily::OpenVlaAr);
        let plan_of = |class: DeviceClass| {
            let budget = DeviceBudget::for_class(class);
            plan_for_class(&prof, class, 120.0, 20.0, budget, EndpointLoad::NOMINAL)
        };
        let cloudlet = plan_of(DeviceClass::Cloudlet);
        let nx = plan_of(DeviceClass::Nx);
        let lite = plan_of(DeviceClass::Lite);
        assert!(lite.is_edge_only(), "lite must degrade to edge-only: {lite:?}");
        assert!(!cloudlet.is_edge_only() && !nx.is_edge_only());
        assert!(
            nx.partition_idx < cloudlet.partition_idx,
            "nx must stop shallower than cloudlet: {} vs {}",
            nx.partition_idx,
            cloudlet.partition_idx
        );
        // the class-scaled prefix is what the plan carries
        let scaled = plan_for_class(
            &prof,
            DeviceClass::Nx,
            120.0,
            20.0,
            DeviceBudget::UNLIMITED,
            EndpointLoad::NOMINAL,
        );
        let raw = prof.partitions[scaled.partition_idx].edge_prefix_ms;
        assert_eq!(scaled.edge_prefix_ms, raw * DeviceClass::Nx.edge_scale());
    }
}

//! Cloud-Only baseline: the edge strictly handles sensor observation and
//! action I/O; every chunk comes from the cloud.

use super::{DecisionCtx, Route, Strategy};
use crate::config::{PolicyKind, SystemConfig};

#[derive(Debug, Default)]
pub struct CloudOnly;

impl CloudOnly {
    pub fn new() -> Self {
        CloudOnly
    }
}

impl Strategy for CloudOnly {
    fn kind(&self) -> PolicyKind {
        PolicyKind::CloudOnly
    }

    fn decide(&mut self, ctx: &DecisionCtx) -> Route {
        if ctx.queue_empty {
            Route::CloudOffload
        } else {
            Route::Cached
        }
    }

    fn edge_gb(&self, _sys: &SystemConfig) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refills_only_from_cloud() {
        let mut s = CloudOnly::new();
        let ctx = |step, queue_empty| DecisionCtx {
            step,
            queue_empty,
            entropy: None,
            family: Default::default(),
        };
        assert_eq!(s.decide(&ctx(0, true)), Route::CloudOffload);
        assert_eq!(s.decide(&ctx(1, false)), Route::Cached);
    }

    #[test]
    fn zero_edge_load() {
        let s = CloudOnly::new();
        assert_eq!(s.edge_gb(&SystemConfig::default()), 0.0);
    }
}

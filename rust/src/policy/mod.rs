//! Partitioning strategies: RAPID (+ ablations) and the paper's baselines.

pub mod cloud_only;
pub mod edge_only;
pub mod planner;
pub mod rapid_policy;
pub mod vision;

pub use cloud_only::CloudOnly;
pub use edge_only::EdgeOnly;
pub use planner::FamilyPlan;
pub use rapid_policy::RapidPolicy;
pub use vision::VisionPolicy;

use crate::config::{PolicyKind, SystemConfig};
use crate::dispatcher::ReuseEvidence;
use crate::robot::SensorFrame;
use crate::vla::profile::ModelFamily;

/// Where the next chunk (if any) comes from this control step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Keep executing the cached chunk.
    Cached,
    /// Refill the queue from the edge-resident model.
    EdgeRefill,
    /// Preempt and offload to the cloud model.
    CloudOffload,
}

/// Context available at a control-step decision.
#[derive(Debug, Clone, Copy)]
pub struct DecisionCtx {
    pub step: usize,
    pub queue_empty: bool,
    /// Entropy of the action about to execute (vision baseline signal);
    /// None when the strategy does not request it.
    pub entropy: Option<f64>,
    /// Model family the session serves ([`ModelFamily::Surrogate`] with
    /// `[models]` disabled). Strategies may specialize on it; the stock
    /// ones ignore it — the family's cost profile is applied by the
    /// driver from the planner's [`FamilyPlan`].
    pub family: ModelFamily,
}

/// A partitioning strategy: consumes the sensor stream, emits routes.
pub trait Strategy {
    fn kind(&self) -> PolicyKind;

    /// High-rate sensor tick (no-op for baselines that ignore kinematics).
    fn observe(&mut self, _frame: &SensorFrame) {}

    /// Control-rate routing decision.
    fn decide(&mut self, ctx: &DecisionCtx) -> Route;

    /// Whether the driver must supply per-step entropy (vision baseline).
    fn needs_entropy(&self) -> bool {
        false
    }

    /// Parameter GB currently resident on the edge.
    fn edge_gb(&self, sys: &SystemConfig) -> f64;

    /// Notification hooks for accounting (split re-partitions etc.).
    fn on_offload(&mut self, _step: usize) {}

    /// Number of split-point changes (vision baseline repartition cost).
    fn repartitions(&self) -> u64 {
        0
    }

    /// Measured decision CPU time in ns (RAPID reports its dispatcher cost
    /// — the 5–7% overhead claim is checked against this).
    fn decision_ns(&self) -> u64 {
        0
    }

    /// Kinematic redundancy evidence behind the latest decision, consumed
    /// by the reuse cache's signature and probe gate. None means the
    /// strategy measures nothing (its dispatches are treated as routine).
    fn reuse_evidence(&self) -> Option<ReuseEvidence> {
        None
    }
}

/// Factory: build the strategy for a [`PolicyKind`].
pub fn build(kind: PolicyKind, sys: &SystemConfig) -> Box<dyn Strategy> {
    match kind {
        PolicyKind::EdgeOnly => Box::new(EdgeOnly::new()),
        PolicyKind::CloudOnly => Box::new(CloudOnly::new()),
        PolicyKind::VisionBased => Box::new(VisionPolicy::new(&sys.vision, sys.vision_edge_gb)),
        PolicyKind::Rapid => Box::new(RapidPolicy::new(&sys.dispatcher, sys.robot.dt)),
        PolicyKind::RapidNoComp => {
            let mut d = sys.dispatcher.clone();
            d.disable_comp = true;
            Box::new(RapidPolicy::with_kind(&d, sys.robot.dt, PolicyKind::RapidNoComp))
        }
        PolicyKind::RapidNoRed => {
            let mut d = sys.dispatcher.clone();
            d.disable_red = true;
            Box::new(RapidPolicy::with_kind(&d, sys.robot.dt, PolicyKind::RapidNoRed))
        }
        PolicyKind::RapidStaticFusion => {
            let mut d = sys.dispatcher.clone();
            d.static_fusion = true;
            Box::new(RapidPolicy::with_kind(&d, sys.robot.dt, PolicyKind::RapidStaticFusion))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_kinds() {
        let sys = SystemConfig::default();
        for kind in [
            PolicyKind::Rapid,
            PolicyKind::RapidNoComp,
            PolicyKind::RapidNoRed,
            PolicyKind::RapidStaticFusion,
            PolicyKind::EdgeOnly,
            PolicyKind::CloudOnly,
            PolicyKind::VisionBased,
        ] {
            let s = build(kind, &sys);
            assert_eq!(s.kind(), kind);
        }
    }

    #[test]
    fn load_conservation_across_strategies() {
        let sys = SystemConfig::default();
        for kind in [
            PolicyKind::Rapid,
            PolicyKind::EdgeOnly,
            PolicyKind::CloudOnly,
            PolicyKind::VisionBased,
        ] {
            let s = build(kind, &sys);
            let edge = s.edge_gb(&sys);
            let cloud = sys.cloud_gb(edge);
            assert!((edge + cloud - sys.total_model_gb).abs() < 1e-9, "{kind:?}");
            assert!(edge >= 0.0 && edge <= sys.total_model_gb);
        }
    }
}

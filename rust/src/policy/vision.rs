//! Vision-based dynamic partitioning baseline (SAFE / ISAR / AVERY-style):
//! offloads to the cloud when the Shannon entropy of the action
//! distribution exceeds a threshold, and adapts its split point (the
//! parameter fraction resident on the edge) to the running entropy level —
//! higher sustained entropy pushes more of the model to the cloud
//! (the behaviour Table I measures under increasing noise).

use super::{DecisionCtx, Route, Strategy};
use crate::config::{PolicyKind, SystemConfig, VisionPolicyConfig};

pub struct VisionPolicy {
    cfg: VisionPolicyConfig,
    /// Baseline edge-resident GB in a clean scene.
    base_edge_gb: f64,
    /// EWMA of observed entropy.
    ewma_h: f64,
    initialized: bool,
    /// Current split fraction of the clean-scene edge residency in (0, 1].
    split_frac: f64,
    repartitions: u64,
}

impl VisionPolicy {
    pub fn new(cfg: &VisionPolicyConfig, base_edge_gb: f64) -> Self {
        VisionPolicy {
            cfg: cfg.clone(),
            base_edge_gb,
            ewma_h: 0.0,
            initialized: false,
            split_frac: 1.0,
            repartitions: 0,
        }
    }

    /// Update the adaptive split point from the running entropy. A change
    /// of more than 5% of residency is a re-partition event (model layers
    /// must be shipped — expensive, charged by the driver).
    fn adapt_split(&mut self) {
        // map entropy above threshold to a shrinking edge share
        let over = (self.ewma_h - self.cfg.entropy_threshold).max(0.0);
        let target = (1.0 - self.cfg.split_adapt * over)
            .max(self.cfg.min_edge_frac / (self.base_edge_gb / 14.2));
        let target = target.clamp(0.05, 1.0);
        if (target - self.split_frac).abs() > 0.05 {
            self.split_frac = target;
            self.repartitions += 1;
        }
    }

    pub fn ewma_entropy(&self) -> f64 {
        self.ewma_h
    }
}

impl Strategy for VisionPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::VisionBased
    }

    fn needs_entropy(&self) -> bool {
        true
    }

    fn decide(&mut self, ctx: &DecisionCtx) -> Route {
        if let Some(h) = ctx.entropy {
            if self.initialized {
                self.ewma_h = (1.0 - self.cfg.ewma) * self.ewma_h + self.cfg.ewma * h;
            } else {
                self.ewma_h = h;
                self.initialized = true;
            }
            self.adapt_split();
            // trigger on the smoothed signal: isolated single-step entropy
            // blips don't preempt, sustained uncertainty does
            if self.ewma_h > self.cfg.entropy_threshold {
                return Route::CloudOffload;
            }
        }
        if ctx.queue_empty {
            Route::EdgeRefill
        } else {
            Route::Cached
        }
    }

    fn edge_gb(&self, _sys: &SystemConfig) -> f64 {
        self.base_edge_gb * self.split_frac
    }

    fn repartitions(&self) -> u64 {
        self.repartitions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> VisionPolicy {
        VisionPolicy::new(&VisionPolicyConfig::default(), 4.7)
    }

    fn ctx(entropy: f64, queue_empty: bool) -> DecisionCtx {
        DecisionCtx {
            step: 0,
            queue_empty,
            entropy: Some(entropy),
            family: Default::default(),
        }
    }

    #[test]
    fn low_entropy_stays_on_edge() {
        let mut p = policy();
        for _ in 0..50 {
            assert_eq!(p.decide(&ctx(2.8, false)), Route::Cached);
        }
        let sys = SystemConfig::default();
        assert!((p.edge_gb(&sys) - 4.7).abs() < 1e-9);
    }

    #[test]
    fn high_entropy_offloads() {
        let mut p = policy();
        assert_eq!(p.decide(&ctx(4.0, false)), Route::CloudOffload);
    }

    #[test]
    fn sustained_noise_shrinks_edge_residency() {
        let mut p = policy();
        let sys = SystemConfig::default();
        let before = p.edge_gb(&sys);
        for _ in 0..100 {
            p.decide(&ctx(4.05, false));
        }
        let after = p.edge_gb(&sys);
        assert!(after < before * 0.8, "edge residency {before} -> {after}");
        assert!(p.repartitions() >= 1);
        assert!(after >= 0.0);
    }

    #[test]
    fn recovery_when_scene_clears() {
        let mut p = policy();
        let sys = SystemConfig::default();
        for _ in 0..100 {
            p.decide(&ctx(4.05, false));
        }
        let degraded = p.edge_gb(&sys);
        for _ in 0..200 {
            p.decide(&ctx(2.5, false));
        }
        assert!(p.edge_gb(&sys) > degraded);
    }

    #[test]
    fn empty_queue_refills_when_calm() {
        let mut p = policy();
        assert_eq!(p.decide(&ctx(2.5, true)), Route::EdgeRefill);
    }
}

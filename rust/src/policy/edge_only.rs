//! Edge-Only baseline: the full VLA runs on the edge device; the queue is
//! refilled locally every time it drains. No cloud, no triggers.

use super::{DecisionCtx, Route, Strategy};
use crate::config::{PolicyKind, SystemConfig};

#[derive(Debug, Default)]
pub struct EdgeOnly;

impl EdgeOnly {
    pub fn new() -> Self {
        EdgeOnly
    }
}

impl Strategy for EdgeOnly {
    fn kind(&self) -> PolicyKind {
        PolicyKind::EdgeOnly
    }

    fn decide(&mut self, ctx: &DecisionCtx) -> Route {
        if ctx.queue_empty {
            Route::EdgeRefill
        } else {
            Route::Cached
        }
    }

    fn edge_gb(&self, sys: &SystemConfig) -> f64 {
        sys.total_model_gb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_offloads() {
        let mut s = EdgeOnly::new();
        for step in 0..100 {
            let r = s.decide(&DecisionCtx {
                step,
                queue_empty: step % 8 == 0,
                entropy: None,
                family: Default::default(),
            });
            assert_ne!(r, Route::CloudOffload);
        }
    }

    #[test]
    fn refills_on_empty() {
        let mut s = EdgeOnly::new();
        let ctx = |step, queue_empty| DecisionCtx {
            step,
            queue_empty,
            entropy: None,
            family: Default::default(),
        };
        assert_eq!(s.decide(&ctx(0, true)), Route::EdgeRefill);
        assert_eq!(s.decide(&ctx(1, false)), Route::Cached);
    }
}

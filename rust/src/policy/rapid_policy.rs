//! RAPID strategy adapter: wraps [`RapidDispatcher`] behind the common
//! [`Strategy`] interface (ablation variants share the same adapter with
//! modified dispatcher flags).

use super::{DecisionCtx, Route, Strategy};
use crate::config::{DispatcherConfig, PolicyKind, SystemConfig};
use crate::dispatcher::{Decision, RapidDispatcher, ReuseEvidence, TriggerEval};
use crate::robot::SensorFrame;

pub struct RapidPolicy {
    dispatcher: RapidDispatcher,
    kind: PolicyKind,
    /// Cumulative decision CPU time (ns) — the *measured* routing overhead
    /// behind the paper's 5–7% claim.
    pub decision_ns: u64,
}

impl RapidPolicy {
    pub fn new(cfg: &DispatcherConfig, dt: f64) -> Self {
        Self::with_kind(cfg, dt, PolicyKind::Rapid)
    }

    pub fn with_kind(cfg: &DispatcherConfig, dt: f64, kind: PolicyKind) -> Self {
        RapidPolicy { dispatcher: RapidDispatcher::new(cfg, dt), kind, decision_ns: 0 }
    }

    pub fn last_eval(&self) -> Option<TriggerEval> {
        self.dispatcher.last_eval()
    }

    pub fn dispatcher(&self) -> &RapidDispatcher {
        &self.dispatcher
    }
}

impl Strategy for RapidPolicy {
    fn kind(&self) -> PolicyKind {
        self.kind
    }

    fn observe(&mut self, frame: &SensorFrame) {
        let t0 = std::time::Instant::now();
        self.dispatcher.observe(frame);
        self.decision_ns += t0.elapsed().as_nanos() as u64;
    }

    fn decide(&mut self, ctx: &DecisionCtx) -> Route {
        let t0 = std::time::Instant::now();
        let d = self.dispatcher.decide(ctx.queue_empty);
        self.decision_ns += t0.elapsed().as_nanos() as u64;
        match d {
            Decision::ExecuteCached => Route::Cached,
            Decision::RefillEdge => Route::EdgeRefill,
            Decision::OffloadCloud => Route::CloudOffload,
        }
    }

    fn edge_gb(&self, sys: &SystemConfig) -> f64 {
        // Ablated variants compensate for weaker triggers with a larger
        // edge slice (the paper's Table V load columns; see schema docs).
        match self.kind {
            PolicyKind::RapidNoComp => sys.edge_gb_no_comp,
            PolicyKind::RapidNoRed => sys.edge_gb_no_red,
            _ => sys.edge_model_gb,
        }
    }

    fn decision_ns(&self) -> u64 {
        self.decision_ns
    }

    fn reuse_evidence(&self) -> Option<ReuseEvidence> {
        self.dispatcher.reuse_evidence()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::Jv;

    fn frame(step: usize, dq: f64, tau: f64) -> SensorFrame {
        SensorFrame { step, q: Jv::ZERO, dq: Jv::splat(dq), tau: Jv::splat(tau) }
    }

    #[test]
    fn routes_follow_dispatcher() {
        let sys = SystemConfig::default();
        let mut p = RapidPolicy::new(&sys.dispatcher, sys.robot.dt);
        // calm warm-up
        for i in 0..60 {
            p.observe(&frame(i, 0.2, 1.0));
            let ctx = DecisionCtx {
                step: i,
                queue_empty: false,
                entropy: None,
                family: Default::default(),
            };
            assert_eq!(p.decide(&ctx), Route::Cached);
        }
        // contact spike at rest -> offload
        p.observe(&frame(60, 0.05, 9.0));
        assert_eq!(
            p.decide(&DecisionCtx {
                step: 60,
                queue_empty: false,
                entropy: None,
                family: Default::default(),
            }),
            Route::CloudOffload
        );
    }

    #[test]
    fn measures_decision_overhead() {
        let sys = SystemConfig::default();
        let mut p = RapidPolicy::new(&sys.dispatcher, sys.robot.dt);
        for i in 0..100 {
            p.observe(&frame(i, 0.2, 1.0));
            p.decide(&DecisionCtx {
                step: i,
                queue_empty: false,
                entropy: None,
                family: Default::default(),
            });
        }
        assert!(p.decision_ns > 0);
        // O(1) arithmetic: must stay well under 50µs per tick on any host
        assert!(p.decision_ns / 100 < 50_000, "per-tick {}ns", p.decision_ns / 100);
    }

    #[test]
    fn ablation_kinds_report_themselves() {
        let sys = SystemConfig::default();
        let mut d = sys.dispatcher.clone();
        d.disable_red = true;
        let p = RapidPolicy::with_kind(&d, sys.robot.dt, PolicyKind::RapidNoRed);
        assert_eq!(p.kind(), PolicyKind::RapidNoRed);
    }
}

//! The fleet scheduler's virtual-time event queue.
//!
//! A deterministic binary-heap priority queue over discrete virtual time
//! (scheduler rounds). The fleet's former lockstep round loop is now a
//! stream of typed events popped from this queue:
//!
//! * [`EventKind::FaultEdge`] — a round begins: fault-window edges are
//!   applied (time-varying link profiles, outage windows, zoo replans).
//! * [`EventKind::Arrival`] — a session joins the fleet (open-loop
//!   workload arrivals; the lockstep fleet arrives everyone at t = 0).
//! * [`EventKind::Ready`] — a session may advance one control step. A
//!   *reply-arrival* (a suspended session resumed by a batch flush)
//!   re-enters the schedule as the `Ready` event the flush pushes for it.
//!   A **speculative** dispatch (`[pipeline].speculate`) never suspends:
//!   the session pushes its own next `Ready` at dispatch time and the
//!   serving flush only resolves the speculation — it pushes no second
//!   `Ready` for that session, or the session would double-step.
//! * [`EventKind::Deadline`] — a round ends: batch-deadline / drain
//!   bookkeeping runs, and the next round is scheduled (or the run ends).
//!
//! # Ordering contract (the tie-break the whole serve layer leans on)
//!
//! Events pop in ascending `(time, class, seq, push order)`:
//!
//! 1. **time** — virtual scheduler round; the queue is time-monotone (a
//!    popped event's time never decreases, pinned by proptest #22).
//! 2. **class** — within a round, `FaultEdge < Arrival < Ready <
//!    Deadline`: fault edges apply before anyone steps, arrivals join
//!    before the round's polls, and deadline bookkeeping sees the whole
//!    round.
//! 3. **seq** — within a class, the session index. `Ready` events pop in
//!    ascending session order, which is exactly the lockstep `for i in
//!    0..n` iteration order — the invariant that makes the all-at-t0
//!    degenerate case **bit-identical** to the historical round loop.
//! 4. **push order** — a monotone counter breaks exact `(time, class,
//!    seq)` ties FIFO, so even adversarial duplicate pushes (the property
//!    suite generates them) pop in one deterministic order.
//!
//! The queue is pure data structure: it draws no randomness and never
//! inspects wall clocks, so a fleet run's event schedule replays exactly
//! under a shared seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What a scheduled event does when popped. See the module docs for the
/// within-round ordering semantics of each kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Round start: apply the fault schedule's edges for this round.
    FaultEdge,
    /// Session `i` joins the fleet.
    Arrival(usize),
    /// Session `i` may advance one control step (also the reply-arrival
    /// path: a flush resumes a suspended session by pushing its `Ready`).
    Ready(usize),
    /// Round end: batch-deadline / drain bookkeeping.
    Deadline,
}

impl EventKind {
    /// Within-round class rank (see module docs).
    pub fn class(&self) -> u8 {
        match self {
            EventKind::FaultEdge => 0,
            EventKind::Arrival(_) => 1,
            EventKind::Ready(_) => 2,
            EventKind::Deadline => 3,
        }
    }

    /// Within-class rank: the session index for session-bound events.
    pub fn seq(&self) -> u64 {
        match self {
            EventKind::FaultEdge | EventKind::Deadline => 0,
            EventKind::Arrival(i) | EventKind::Ready(i) => *i as u64,
        }
    }
}

/// One scheduled event, stamped with its virtual time.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: u64,
    pub kind: EventKind,
    /// FIFO tie-break among exact `(time, class, seq)` duplicates.
    order: u64,
}

impl Event {
    /// The full ordering key (exposed so property tests can check the
    /// contract without re-deriving it).
    pub fn key(&self) -> (u64, u8, u64, u64) {
        (self.time, self.kind.class(), self.kind.seq(), self.order)
    }
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse so the smallest key pops first.
        other.key().cmp(&self.key())
    }
}

/// Deterministic virtual-time event queue (min-queue on [`Event::key`]).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    pushed: u64,
    /// Largest time popped so far (debug guard for time-monotonicity).
    last_time: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Queue with pre-reserved heap storage. Fleet runs seed one arrival
    /// per session up front, so reserving once avoids repeated heap
    /// regrowth at 100k+ sessions.
    pub fn with_capacity(n: usize) -> EventQueue {
        EventQueue { heap: BinaryHeap::with_capacity(n), ..EventQueue::default() }
    }

    /// Schedule `kind` at virtual time `time`.
    pub fn push(&mut self, time: u64, kind: EventKind) {
        let order = self.pushed;
        self.pushed += 1;
        self.heap.push(Event { time, kind, order });
    }

    /// Pop the earliest event under the `(time, class, seq, push order)`
    /// contract.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.last_time, "event queue went back in time");
        self.last_time = ev.time;
        Some(ev)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5, EventKind::Ready(0));
        q.push(1, EventKind::Ready(1));
        q.push(3, EventKind::FaultEdge);
        let times: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(times, vec![1, 3, 5]);
    }

    #[test]
    fn class_orders_within_a_round() {
        let mut q = EventQueue::new();
        q.push(2, EventKind::Deadline);
        q.push(2, EventKind::Ready(0));
        q.push(2, EventKind::FaultEdge);
        q.push(2, EventKind::Arrival(0));
        let kinds: Vec<EventKind> = std::iter::from_fn(|| q.pop()).map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::FaultEdge,
                EventKind::Arrival(0),
                EventKind::Ready(0),
                EventKind::Deadline
            ]
        );
    }

    #[test]
    fn ready_events_pop_in_session_order() {
        // push out of order; pops must follow the lockstep iteration order
        let mut q = EventQueue::new();
        for i in [4usize, 1, 3, 0, 2] {
            q.push(7, EventKind::Ready(i));
        }
        let sessions: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EventKind::Ready(i) => i,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(sessions, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(8);
        q.push(1, EventKind::Deadline);
        q.push(0, EventKind::FaultEdge);
        assert_eq!(q.pop().unwrap().time, 0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn exact_duplicates_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(1, EventKind::Ready(2));
        q.push(1, EventKind::Ready(2));
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        assert!(a.key() < b.key(), "duplicate keys must break ties by push order");
    }

    #[test]
    fn mixed_schedule_is_fully_deterministic() {
        let build = || {
            let mut q = EventQueue::new();
            for (t, k) in [
                (3, EventKind::Ready(1)),
                (0, EventKind::FaultEdge),
                (3, EventKind::Deadline),
                (0, EventKind::Arrival(0)),
                (3, EventKind::Ready(0)),
                (1, EventKind::Deadline),
            ] {
                q.push(t, k);
            }
            std::iter::from_fn(move || q.pop()).map(|e| (e.time, e.kind)).collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
        assert_eq!(build().first(), Some(&(0, EventKind::FaultEdge)));
    }
}

//! The workload engine: seeded, open-loop session arrivals for the fleet.
//!
//! A real fleet is never lockstep: robots come online at arbitrary times,
//! run different numbers of episodes, and serve different model families.
//! This module turns the `[workload]` config section into a deterministic
//! per-run [`WorkloadPlan`] — one [`SessionSpec`] per session, fixing its
//! arrival round, episode count and model family *before* the run starts
//! (open loop: arrivals don't react to fleet state) — which the
//! event-driven scheduler (`serve::fleet` over `serve::events`) executes.
//!
//! # Arrival processes
//!
//! * **fixed** — session i arrives at `start_round + i·interarrival`
//!   (interarrival 0 ⇒ everyone at `start_round`: the lockstep shape).
//! * **poisson** — exponential inter-arrival gaps with mean
//!   `interarrival_rounds`, drawn from the engine's own seeded PRNG.
//! * **bursty** — an on-off process: `burst_len` back-to-back arrivals
//!   (one per round), then `idle_len` silent rounds, repeating.
//! * **trace** — replay explicit arrival rounds from the tiny in-repo
//!   trace format (see [`parse_trace`]): inline `"0,0,4,12"`, or
//!   `"@path"` to load a file of one round per line (`#` comments).
//!
//! # Determinism contract
//!
//! The engine owns a private PRNG (`[workload] seed`, or derived from the
//! episode seed) and draws in a fixed documented order: arrival gaps
//! first (Poisson only), then per-session episode counts, then families,
//! then device classes (the device zoo's `device_mix`, appended last so
//! pre-class draw streams never shift). Draw-free shapes (fixed / bursty
//! / trace, pinned episode counts, block family assignment, block class
//! assignment) consume nothing, so a `[workload]` section configured to
//! the lockstep degenerate shape — everyone at t = 0, fleet episode
//! count, block families — produces a plan whose execution is
//! **bit-identical** to the disabled-workload scheduler (the same
//! contract `[faults]`/`[cache]`/`[models]`/`[devices]` honour; pinned by
//! `rust/tests/workload_arrivals.rs` and `rust/tests/device_zoo.rs`).

use crate::config::SystemConfig;
use crate::runtime::{assign_classes, DeviceClass};
use crate::util::Pcg32;
use crate::vla::assign_families;
use crate::vla::profile::ModelFamily;

/// Arrival process selector (the `[workload] arrivals` string).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    Fixed,
    Poisson,
    Bursty,
    Trace,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> Option<ArrivalKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fixed" | "lockstep" => Some(ArrivalKind::Fixed),
            "poisson" | "open" => Some(ArrivalKind::Poisson),
            "bursty" | "onoff" | "on-off" => Some(ArrivalKind::Bursty),
            "trace" | "replay" => Some(ArrivalKind::Trace),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Fixed => "fixed",
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Trace => "trace",
        }
    }
}

/// Everything the scheduler needs to know about one session before the
/// run starts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionSpec {
    /// Scheduler round the session joins the fleet.
    pub arrival_round: u64,
    /// Episodes the session runs back to back before departing.
    pub episodes: usize,
    /// Model family the session serves for its whole run.
    pub family: ModelFamily,
    /// Edge device class the session runs on ([`DeviceClass::Cloudlet`]
    /// — the exact no-op — whenever `[devices] classes` is empty).
    pub class: DeviceClass,
}

/// The compiled plan: one spec per session, session index = vec index.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadPlan {
    pub specs: Vec<SessionSpec>,
    /// Shape the plan was generated from (fixed for the disabled path).
    pub kind: ArrivalKind,
}

impl WorkloadPlan {
    pub fn n_sessions(&self) -> usize {
        self.specs.len()
    }

    /// Latest arrival round in the plan (0 for lockstep shapes).
    pub fn last_arrival(&self) -> u64 {
        self.specs.iter().map(|s| s.arrival_round).max().unwrap_or(0)
    }

    /// True when every session arrives at round 0 (the lockstep shape).
    pub fn is_lockstep(&self) -> bool {
        self.specs.iter().all(|s| s.arrival_round == 0)
    }
}

/// Parse the tiny trace format: either an inline list of arrival rounds
/// separated by commas/whitespace (`"0, 0, 4 12"`), or `"@path"` to read
/// a file with one arrival round per line (blank lines and `#` comments
/// skipped). Unparseable tokens are skipped with a warning on stderr — a
/// typo must not silently change fleet composition.
pub fn parse_trace(trace: &str) -> Vec<u64> {
    let body;
    let src = if let Some(path) = trace.strip_prefix('@') {
        match std::fs::read_to_string(path.trim()) {
            Ok(s) => {
                body = s;
                body.as_str()
            }
            Err(e) => {
                eprintln!("[workload] cannot read trace {path:?}: {e}; using empty trace");
                ""
            }
        }
    } else {
        trace
    };
    let mut rounds = Vec::new();
    for line in src.lines() {
        let line = line.split('#').next().unwrap_or("");
        for tok in line.split(|c: char| c == ',' || c.is_whitespace()) {
            if tok.is_empty() {
                continue;
            }
            match tok.parse::<u64>() {
                Ok(r) => rounds.push(r),
                Err(_) => eprintln!("[workload] bad trace token {tok:?} skipped"),
            }
        }
    }
    rounds
}

/// Compile the active config into a [`WorkloadPlan`].
///
/// With `[workload]` disabled this is the **lockstep plan**: every fleet
/// session arrives at round 0, runs `fleet.episodes_per_session`
/// episodes, and serves its block-assigned family — exactly the shape the
/// pre-workload scheduler hard-coded, so the disabled path perturbs
/// nothing.
pub fn plan(sys: &SystemConfig) -> WorkloadPlan {
    let w = &sys.workload;
    if !w.enabled {
        return lockstep_plan(sys, sys.fleet.n_sessions.max(1));
    }
    let kind = match ArrivalKind::parse(&w.arrivals) {
        Some(k) => k,
        None => {
            eprintln!(
                "[workload] unknown arrivals {:?}; known: fixed, poisson, bursty, trace — \
                 falling back to fixed",
                w.arrivals
            );
            ArrivalKind::Fixed
        }
    };
    let trace = if kind == ArrivalKind::Trace { parse_trace(&w.trace) } else { Vec::new() };
    let n = if w.n_sessions > 0 {
        w.n_sessions
    } else if kind == ArrivalKind::Trace && !trace.is_empty() {
        // the trace defines the fleet size unless the config pins one
        trace.len()
    } else {
        sys.fleet.n_sessions.max(1)
    };

    let seed = if w.seed != 0 { w.seed } else { sys.episode.seed ^ 0x57_0AD0 };
    let mut rng = Pcg32::new(seed, 0x57D);

    // 1) arrival rounds (only Poisson draws)
    let arrivals: Vec<u64> = match kind {
        ArrivalKind::Fixed => {
            let gap = w.interarrival_rounds.max(0.0);
            (0..n).map(|i| w.start_round + (i as f64 * gap) as u64).collect()
        }
        ArrivalKind::Poisson => {
            let mean = w.interarrival_rounds.max(0.0);
            let mut t = 0.0f64;
            (0..n)
                .map(|_| {
                    let u = rng.f64();
                    t += -mean * (1.0 - u).ln();
                    w.start_round + t as u64
                })
                .collect()
        }
        ArrivalKind::Bursty => {
            let on = w.burst_len.max(1);
            let off = w.idle_len;
            (0..n as u64).map(|i| w.start_round + (i / on) * (on + off) + (i % on)).collect()
        }
        ArrivalKind::Trace => (0..n)
            .map(|i| {
                // fewer trace entries than sessions: the tail repeats the
                // last arrival (an empty trace degrades to all-at-start)
                trace.get(i).or(trace.last()).copied().unwrap_or(0) + w.start_round
            })
            .collect(),
    };

    // 2) episode counts (0/0 pins the fleet knob; min == max draws
    // nothing). Inverted bounds are rejected at config load
    // (`SystemConfig::validate`); the `.max(lo)` clamp below only guards
    // programmatically-built configs that skipped validation.
    let fleet_eps = sys.fleet.episodes_per_session.max(1);
    let (lo, hi) = if w.episodes_min == 0 && w.episodes_max == 0 {
        (fleet_eps, fleet_eps)
    } else {
        let lo = w.episodes_min.max(1);
        (lo, w.episodes_max.max(lo))
    };
    // the draw span is clamped into u32 range explicitly — a pathological
    // [1, usize::MAX] config must not truncate silently in the cast
    let span = (hi - lo + 1).min(u32::MAX as usize) as u32;
    let episodes: Vec<usize> =
        (0..n).map(|_| if lo == hi { lo } else { lo + rng.below(span) as usize }).collect();

    // 3) families ("blocks" is draw-free and equals the lockstep
    // assignment; sessions serve the surrogate whenever the zoo is off)
    let fams = if sys.models.enabled { sys.models.family_list() } else { Vec::new() };
    let draw_fams = w.family_mix.trim().eq_ignore_ascii_case("draw");
    let families: Vec<ModelFamily> = (0..n)
        .map(|i| {
            if fams.is_empty() {
                ModelFamily::Surrogate
            } else if draw_fams {
                fams[rng.below(fams.len() as u32) as usize]
            } else {
                assign_families(&fams, n, i)
            }
        })
        .collect();

    // 4) device classes — appended AFTER every pre-existing stage so the
    // arrival/episode/family draw streams never shift ([devices] off, or
    // the draw-free "blocks" mix, consumes nothing)
    let classes = session_classes(sys, &mut rng, n);

    let specs = (0..n)
        .map(|i| SessionSpec {
            arrival_round: arrivals[i],
            episodes: episodes[i],
            family: families[i],
            class: classes[i],
        })
        .collect();
    WorkloadPlan { specs, kind }
}

/// Per-session device classes for stage 4 of [`plan`]: the implicit
/// no-op `cloudlet` when the device zoo is off, block assignment
/// (draw-free, mirrors the family rule) or seeded uniform draws per
/// `[workload] device_mix` when it is on.
fn session_classes(sys: &SystemConfig, rng: &mut Pcg32, n: usize) -> Vec<DeviceClass> {
    if !sys.devices.classes_enabled() {
        return vec![DeviceClass::default(); n];
    }
    let list = sys.devices.class_list();
    let draw = sys.workload.device_mix.trim().eq_ignore_ascii_case("draw");
    (0..n)
        .map(|i| {
            if list.is_empty() {
                DeviceClass::default()
            } else if draw {
                list[rng.below(list.len() as u32) as usize]
            } else {
                assign_classes(&list, n, i)
            }
        })
        .collect()
}

/// The degenerate all-at-t0 plan the disabled path compiles to. Device
/// classes use the draw-free block assignment (there is no PRNG on this
/// path at all), so an armed `[devices]` section still mixes silicon
/// under a lockstep workload.
fn lockstep_plan(sys: &SystemConfig, n: usize) -> WorkloadPlan {
    let fams = if sys.models.enabled { sys.models.family_list() } else { Vec::new() };
    let classes =
        if sys.devices.classes_enabled() { sys.devices.class_list() } else { Vec::new() };
    let episodes = sys.fleet.episodes_per_session.max(1);
    let specs = (0..n)
        .map(|i| SessionSpec {
            arrival_round: 0,
            episodes,
            family: assign_families(&fams, n, i),
            class: assign_classes(&classes, n, i),
        })
        .collect();
    WorkloadPlan { specs, kind: ArrivalKind::Fixed }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wsys() -> SystemConfig {
        let mut sys = SystemConfig::default();
        sys.workload.enabled = true;
        sys
    }

    #[test]
    fn disabled_plan_is_the_lockstep_shape() {
        let sys = SystemConfig::default();
        let p = plan(&sys);
        assert_eq!(p.n_sessions(), sys.fleet.n_sessions);
        assert!(p.is_lockstep());
        for s in &p.specs {
            assert_eq!(s.episodes, 1);
            assert_eq!(s.family, ModelFamily::Surrogate);
        }
    }

    #[test]
    fn degenerate_enabled_plan_equals_the_disabled_plan() {
        // [workload] enabled but configured to the lockstep shape must
        // compile to the identical plan (the differential suite's anchor)
        let base = plan(&SystemConfig::default());
        let mut sys = wsys();
        sys.workload.arrivals = "fixed".into();
        sys.workload.interarrival_rounds = 0.0;
        assert_eq!(plan(&sys), base);
    }

    #[test]
    fn fixed_staggers_by_the_interarrival_gap() {
        let mut sys = wsys();
        sys.workload.arrivals = "fixed".into();
        sys.workload.interarrival_rounds = 3.0;
        sys.workload.start_round = 2;
        sys.workload.n_sessions = 4;
        let p = plan(&sys);
        let a: Vec<u64> = p.specs.iter().map(|s| s.arrival_round).collect();
        assert_eq!(a, vec![2, 5, 8, 11]);
    }

    #[test]
    fn poisson_replays_under_a_shared_seed_and_spreads() {
        let mut sys = wsys();
        sys.workload.arrivals = "poisson".into();
        sys.workload.interarrival_rounds = 4.0;
        sys.workload.seed = 9;
        sys.workload.n_sessions = 16;
        let a = plan(&sys);
        let b = plan(&sys);
        assert_eq!(a, b, "seeded plans must replay exactly");
        assert!(!a.is_lockstep(), "a 4-round mean gap must stagger someone");
        let mut sorted = a.specs.clone();
        sorted.sort_by_key(|s| s.arrival_round);
        assert_eq!(sorted, a.specs, "poisson arrivals are cumulative, hence sorted");
    }

    #[test]
    fn bursty_alternates_on_off_windows() {
        let mut sys = wsys();
        sys.workload.arrivals = "bursty".into();
        sys.workload.burst_len = 2;
        sys.workload.idle_len = 5;
        sys.workload.n_sessions = 5;
        let p = plan(&sys);
        let a: Vec<u64> = p.specs.iter().map(|s| s.arrival_round).collect();
        assert_eq!(a, vec![0, 1, 7, 8, 14]);
    }

    #[test]
    fn trace_parses_inline_and_sets_fleet_size() {
        let mut sys = wsys();
        sys.workload.arrivals = "trace".into();
        sys.workload.trace = "0, 0, 4 12".into();
        let p = plan(&sys);
        assert_eq!(p.n_sessions(), 4, "the trace defines the fleet size");
        let a: Vec<u64> = p.specs.iter().map(|s| s.arrival_round).collect();
        assert_eq!(a, vec![0, 0, 4, 12]);
        // pinned n_sessions beyond the trace: the tail repeats the last
        sys.workload.n_sessions = 6;
        let p = plan(&sys);
        let a: Vec<u64> = p.specs.iter().map(|s| s.arrival_round).collect();
        assert_eq!(a, vec![0, 0, 4, 12, 12, 12]);
    }

    #[test]
    fn episode_draws_stay_in_bounds_and_replay() {
        let mut sys = wsys();
        sys.workload.n_sessions = 32;
        sys.workload.episodes_min = 1;
        sys.workload.episodes_max = 3;
        sys.workload.seed = 4;
        let p = plan(&sys);
        assert!(p.specs.iter().all(|s| (1..=3).contains(&s.episodes)));
        assert!(p.specs.iter().any(|s| s.episodes != p.specs[0].episodes), "must vary");
        assert_eq!(plan(&sys), p);
    }

    #[test]
    fn family_draws_cover_the_zoo_and_blocks_match_lockstep() {
        let mut sys = wsys();
        sys.models.enabled = true;
        sys.workload.n_sessions = 24;
        sys.workload.family_mix = "draw".into();
        sys.workload.seed = 7;
        let p = plan(&sys);
        let fams = sys.models.family_list();
        assert!(p.specs.iter().all(|s| fams.contains(&s.family)));
        // block mix equals the lockstep assignment function exactly
        sys.workload.family_mix = "blocks".into();
        let p = plan(&sys);
        for (i, s) in p.specs.iter().enumerate() {
            assert_eq!(s.family, assign_families(&fams, 24, i));
        }
    }

    #[test]
    fn device_classes_default_to_the_noop_and_blocks_draw_nothing() {
        // [devices] off: every spec carries the implicit cloudlet no-op
        let p = plan(&SystemConfig::default());
        assert!(p.specs.iter().all(|s| s.class == DeviceClass::Cloudlet));

        // the class stage is appended last: arming [devices] with the
        // draw-free "blocks" mix must not shift any pre-class field
        let mut sys = wsys();
        sys.workload.arrivals = "poisson".into();
        sys.workload.interarrival_rounds = 3.0;
        sys.workload.n_sessions = 12;
        sys.workload.episodes_min = 1;
        sys.workload.episodes_max = 3;
        sys.workload.seed = 11;
        let base = plan(&sys);
        sys.devices.classes = "lite,nx,agx".into();
        let mixed = plan(&sys);
        for (a, b) in base.specs.iter().zip(mixed.specs.iter()) {
            assert_eq!(a.arrival_round, b.arrival_round, "blocks mix must be draw-free");
            assert_eq!(a.episodes, b.episodes);
            assert_eq!(a.family, b.family);
        }
        // block assignment equals the lockstep assignment function
        let list = sys.devices.class_list();
        for (i, s) in mixed.specs.iter().enumerate() {
            assert_eq!(s.class, crate::runtime::assign_classes(&list, 12, i));
        }
    }

    #[test]
    fn device_class_draws_cover_the_list_and_replay() {
        let mut sys = wsys();
        sys.workload.n_sessions = 24;
        sys.workload.seed = 13;
        sys.devices.classes = "lite,nx,agx".into();
        sys.workload.device_mix = "draw".into();
        let p = plan(&sys);
        let list = sys.devices.class_list();
        assert!(p.specs.iter().all(|s| list.contains(&s.class)));
        assert!(p.specs.iter().any(|s| s.class != p.specs[0].class), "24 draws must mix");
        assert_eq!(plan(&sys), p, "seeded class draws must replay exactly");
    }

    #[test]
    fn lockstep_plan_assigns_classes_in_blocks() {
        let mut sys = SystemConfig::default();
        sys.devices.classes = "lite,agx".into();
        sys.fleet.n_sessions = 8;
        let p = plan(&sys);
        assert!(p.is_lockstep());
        let list = sys.devices.class_list();
        for (i, s) in p.specs.iter().enumerate() {
            assert_eq!(s.class, crate::runtime::assign_classes(&list, 8, i));
        }
        assert_eq!(p.specs[0].class, DeviceClass::Lite);
        assert_eq!(p.specs[7].class, DeviceClass::Agx);
    }

    #[test]
    fn trace_file_loads_with_comments() {
        let dir = std::env::temp_dir().join("rapid_workload_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("arrivals.trace");
        std::fs::write(&path, "# demo trace\n0\n3\n\n7 # third robot\n").unwrap();
        let rounds = parse_trace(&format!("@{}", path.display()));
        assert_eq!(rounds, vec![0, 3, 7]);
    }
}

//! The episode driver: one task episode under one partitioning strategy.
//!
//! Per control step (f_control): ingest the proprioceptive frame (the
//! f_sensor evaluation collapses to control rate in simulation — the real
//! 500 Hz loop is exercised by `examples/serve_cluster.rs` and the
//! dispatcher perf bench), route via the strategy, execute chunk
//! generations on the *real* AOT-compiled models, advance the virtual
//! testbed clock per DESIGN.md §5, and step the simulator.
//!
//! The step machine is factored into a resumable [`EpisodeState`] so the
//! fleet scheduler (`serve::fleet`) can *suspend* a session at the moment
//! it needs the cloud — [`EpisodeState::poll`] returns
//! [`StepEvent::NeedCloud`] with the prepared request, the scheduler
//! coalesces requests from many sessions into one wire batch, and
//! [`EpisodeState::complete_cloud`] resumes the step with the response.
//! [`run_episode`] is the single-session driver: it services every
//! `NeedCloud` immediately, which reproduces the classic synchronous loop
//! operation for operation (same PRNG streams, same metrics).
//!
//! Backend selection rule: chunk content comes from the *cloud-grade*
//! model whenever the generating slice holds the majority of parameters
//! (Edge-Only runs the full 14.2 GB model locally — slow but full quality);
//! otherwise from the edge-grade model.

use crate::cache::{ProbeOutcome, ReusePolicy, ReuseStore, Signature};
use crate::config::SystemConfig;
use crate::dispatcher::{ChunkQueue, ChunkSource};
use crate::metrics::EpisodeMetrics;
use crate::net::link::LinkProfile;
use crate::net::Link;
use crate::obs::{Stage, Tracer, NO_ENDPOINT};
use crate::policy::{DecisionCtx, FamilyPlan, Route, Strategy};
use crate::robot::{RobotSim, SensorFrame, TaskKind};
use crate::runtime::{DeviceClass, DeviceClock};
use crate::scene::{NoiseModel, Renderer};
use crate::util::timeline::Timeline;
use crate::vla::profile::ModelFamily;
use crate::vla::{obs::proprio_vec, Backend, ModelOut};
use crate::{D_PROP, D_VIS};
use std::collections::VecDeque;

/// Extra routing cost charged per retransmission (reassembly + re-route).
const RETRANS_PENALTY_MS: f64 = 40.0;
/// Cost of moving the split point (vision baseline re-partition: model
/// layers must be shipped and re-warmed).
const REPARTITION_MS: f64 = 150.0;

pub struct EpisodeOutput {
    pub metrics: EpisodeMetrics,
    pub trace: Option<Timeline>,
}

/// A cloud offload prepared by [`EpisodeState::poll`]: everything the
/// cloud model needs, ready to be coalesced into a cross-session batch.
#[derive(Debug, Clone)]
pub struct CloudRequest {
    pub obs: [f32; D_VIS],
    pub proprio: [f32; D_PROP],
    pub instr: usize,
    /// Reuse-cache signature of the dispatch (Some only when a store was
    /// attached to the poll); rides the request so the reply can be
    /// admitted into the store on completion.
    pub sig: Option<Signature>,
    /// Model family of the session ([`ModelFamily::Surrogate`] without a
    /// zoo plan). The fleet scheduler keys its cross-session batches on
    /// this so no wire batch ever mixes frame layouts.
    pub family: ModelFamily,
    /// True for a speculative dispatch (`[pipeline].speculate`): the
    /// session did **not** suspend — it keeps stepping on a provisional
    /// edge chunk — so the reply must be delivered via
    /// [`EpisodeState::resolve_speculation`] (or
    /// [`EpisodeState::abort_speculation`] when lost), never
    /// `complete_cloud`/`fail_cloud`.
    pub speculative: bool,
}

/// In-step span cursor (`[trace]`): the spans of one polled step are laid
/// out sequentially from the round's base timestamp, each stage advancing
/// the cursor by exactly the virtual time it charged — so a Perfetto lane
/// shows capture → prefix → wire → compute end to end. Pure bookkeeping
/// over already-computed values; never samples, never advances a clock.
struct SpanCursor<'a> {
    tr: &'a mut Tracer,
    ts: u64,
    session: u32,
    family: u8,
}

impl SpanCursor<'_> {
    fn emit(&mut self, stage: Stage, ms: f64, tag: u32) {
        let dur = (ms * 1000.0) as u64;
        self.tr.record(stage, self.ts, dur, self.session, self.family, NO_ENDPOINT, tag);
        self.ts += dur;
    }
}

/// In-flight speculative offload (`[pipeline].speculate`): what the
/// session dispatched provisionally, kept until the cloud reply confirms
/// or corrects it.
struct SpecState {
    /// Control-step index at dispatch; the consumed provisional prefix at
    /// resolution time is `step_index - t0`.
    t0: usize,
    /// The provisional edge-decoded actions the session is executing.
    provisional: Vec<crate::robot::Jv>,
}

/// What happened when the session was polled.
pub enum StepEvent {
    /// One control step fully executed (cached action or edge refill).
    Stepped,
    /// The step is suspended awaiting a cloud response; deliver it via
    /// [`EpisodeState::complete_cloud`].
    NeedCloud(CloudRequest),
    /// The episode is over; call [`EpisodeState::finish`].
    Done,
}

/// Resumable per-session episode state. Drives exactly the same operation
/// sequence as the historical monolithic loop; the only new degree of
/// freedom is *when* the caller services a suspended cloud request.
pub struct EpisodeState {
    strategy: Box<dyn Strategy>,
    sim: RobotSim,
    renderer: Renderer,
    clock: DeviceClock,
    link: Link,
    queue: ChunkQueue,
    /// Side channels (entropy, mass) parallel to the action queue.
    side: VecDeque<(f64, f64)>,
    metrics: EpisodeMetrics,
    trace: Option<Timeline>,
    task: TaskKind,
    last_frame: SensorFrame,
    edge_gb_accum: f64,
    prev_repartitions: u64,
    prev_tau: crate::robot::Jv,
    /// Set between a `NeedCloud` return and its `complete_cloud` call.
    awaiting: bool,
    /// Outstanding speculative offload (`[pipeline].speculate`); always
    /// `None` with the pipeline disabled.
    spec: Option<SpecState>,
    /// Model-zoo serving plan (None without `[models]`: every path below
    /// is then bit-identical to a plan-free build).
    family_plan: Option<FamilyPlan>,
    /// Device class of the robot running this session (`[devices]`
    /// classes). The default (Cloudlet) class is an exact no-op: unit
    /// compute/capture scales and a zero action grid, so every path below
    /// is bit-identical to a class-free build.
    device_class: DeviceClass,
}

impl EpisodeState {
    pub fn new(
        sys: &SystemConfig,
        task: TaskKind,
        strategy: Box<dyn Strategy>,
        seed: u64,
        want_trace: bool,
    ) -> EpisodeState {
        let kind = strategy.kind();
        let sim = RobotSim::new(task, &sys.robot, seed);
        let last_frame = SensorFrame {
            step: 0,
            q: sim.q(),
            dq: crate::robot::Jv::ZERO,
            tau: crate::robot::Jv::ZERO,
        };
        EpisodeState {
            strategy,
            renderer: Renderer::new(NoiseModel::new(&sys.scene, seed ^ 0x9e37), seed ^ 0x517),
            clock: DeviceClock::new(&sys.devices, seed ^ 0xDC),
            link: Link::new(&sys.link, seed ^ 0x71),
            queue: ChunkQueue::new(),
            side: VecDeque::new(),
            metrics: EpisodeMetrics::new(task, kind),
            trace: if want_trace { Some(Timeline::new()) } else { None },
            task,
            sim,
            last_frame,
            edge_gb_accum: 0.0,
            prev_repartitions: 0,
            prev_tau: crate::robot::Jv::ZERO,
            awaiting: false,
            spec: None,
            family_plan: None,
            device_class: DeviceClass::default(),
        }
    }

    /// Install (or clear) the model-zoo serving plan. A `None` plan leaves
    /// the step machine bit-identical to a run that never called this —
    /// the same contract as [`EpisodeState::set_link_profile`].
    pub fn set_family_plan(&mut self, plan: Option<FamilyPlan>) {
        self.family_plan = plan;
    }

    /// Model family this session serves.
    pub fn family(&self) -> ModelFamily {
        self.family_plan.as_ref().map_or(ModelFamily::Surrogate, |p| p.family)
    }

    /// The installed model-zoo serving plan (`None` without `[models]`).
    pub fn family_plan(&self) -> Option<&FamilyPlan> {
        self.family_plan.as_ref()
    }

    /// Install the robot's device class (`[devices]` classes). Setting the
    /// default class leaves the step machine bit-identical to a run that
    /// never called this — the same contract as
    /// [`EpisodeState::set_family_plan`].
    pub fn set_device_class(&mut self, class: DeviceClass) {
        self.device_class = class;
    }

    /// Device class of the robot running this session.
    pub fn device_class(&self) -> DeviceClass {
        self.device_class
    }

    /// True while a `NeedCloud` request is outstanding.
    pub fn is_awaiting_cloud(&self) -> bool {
        self.awaiting
    }

    /// True while a *speculative* cloud request is outstanding (the
    /// session keeps stepping; resolution happens at the next flush).
    pub fn has_speculation(&self) -> bool {
        self.spec.is_some()
    }

    /// Install (or clear) a time-varying link condition (fault-injection
    /// degrade windows). A `None` profile leaves the step machine
    /// bit-identical to a run that never called this.
    pub fn set_link_profile(&mut self, profile: Option<LinkProfile>) {
        self.link.set_profile(profile);
    }

    /// Fleet **arrival hook**: a session joining the fleet mid-run (an
    /// open-loop workload arrival, or an episode rollover inside a fault
    /// window) adopts the link condition — and, for zoo sessions, the
    /// partition plan — in force at its arrival round. A fresh
    /// `EpisodeState` defaults to the nominal link and the nominal-link
    /// plan, which would be wrong inside a degrade window. `None`/`None`
    /// leaves the state bit-identical to a run that never called this
    /// (a `None` plan keeps the plan installed at construction).
    pub fn on_fleet_arrival(&mut self, profile: Option<LinkProfile>, plan: Option<FamilyPlan>) {
        self.link.set_profile(profile);
        if plan.is_some() {
            self.family_plan = plan;
        }
    }

    /// Fleet **departure hook**: seal and return the final episode's
    /// metrics as the session leaves the fleet for good. Equivalent to
    /// [`EpisodeState::seal_metrics`] plus releasing the session's link
    /// override (the departed session no longer tracks fault windows).
    pub fn on_fleet_departure(&mut self, sys: &SystemConfig) -> EpisodeMetrics {
        let metrics = self.seal_metrics(sys);
        self.link.set_profile(None);
        metrics
    }

    /// True once every control step of the episode has executed — and
    /// every cloud dispatch (suspended *or* speculative) is resolved, so
    /// an episode never departs with an unresolved request in the batcher.
    pub fn is_done(&self) -> bool {
        !self.awaiting && self.spec.is_none() && self.sim.done()
    }

    pub fn metrics(&self) -> &EpisodeMetrics {
        &self.metrics
    }

    /// Advance the session by (at most) one control step.
    ///
    /// `admit_cloud` is the scheduler's backpressure gate: when false, a
    /// step that wants a cloud offload is *deferred* — the trigger is
    /// dropped for this step (its cooldown still arms, as a real dropped
    /// dispatch would) and the session falls back to its cached chunk or
    /// an edge refill. Single-session callers pass `true`.
    pub fn poll(
        &mut self,
        sys: &SystemConfig,
        edge: &mut dyn Backend,
        cloud: &mut dyn Backend,
        admit_cloud: bool,
    ) -> StepEvent {
        self.poll_with_cache(sys, edge, cloud, admit_cloud, None, 0, 0)
    }

    /// [`EpisodeState::poll`] with a reuse cache attached: a step that
    /// routes to the cloud first probes `cache` (at scheduler round
    /// `round`, as session `owner`) and, on a fresh in-budget hit, serves
    /// the cached chunk at `cache.probe_ms` latency instead of suspending
    /// — no wire frame, no in-flight slot, and it keeps working through
    /// outage windows because the probe runs *before* the backpressure
    /// gate. With `cache = None` this is exactly [`EpisodeState::poll`].
    #[allow(clippy::too_many_arguments)]
    pub fn poll_with_cache(
        &mut self,
        sys: &SystemConfig,
        edge: &mut dyn Backend,
        cloud: &mut dyn Backend,
        admit_cloud: bool,
        cache: Option<&mut ReuseStore>,
        round: u64,
        owner: usize,
    ) -> StepEvent {
        self.poll_traced(sys, edge, cloud, admit_cloud, cache, round, owner, None)
    }

    /// [`EpisodeState::poll_with_cache`] with a span tracer attached
    /// (`[trace]`): every stage this step charges virtual time for —
    /// capture, edge prefix, wire, cloud compute, reuse probe/hit,
    /// speculative dispatch — is recorded as a [`Stage`] span at its
    /// position inside the fleet round. Recording reads values the step
    /// computes anyway: zero PRNG draws, zero clock advances, so
    /// `tracer = None` and `tracer = Some(_)` run bit-identical steps
    /// (pinned by `rust/tests/obs_trace.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn poll_traced(
        &mut self,
        sys: &SystemConfig,
        edge: &mut dyn Backend,
        cloud: &mut dyn Backend,
        admit_cloud: bool,
        mut cache: Option<&mut ReuseStore>,
        round: u64,
        owner: usize,
        tracer: Option<&mut Tracer>,
    ) -> StepEvent {
        assert!(!self.awaiting, "poll() while awaiting a cloud response");
        if self.sim.done() {
            return StepEvent::Done;
        }
        let span_family = self.family().id();
        let mut span = tracer.map(|tr| SpanCursor {
            ts: tr.base_us(round),
            session: owner as u32,
            family: span_family,
            tr,
        });
        let t = self.sim.step_index();
        self.strategy.observe(&self.last_frame);

        // entropy of the action about to execute (vision baseline signal)
        let next_entropy = self.side.front().map(|&(h, _)| h);
        let ctx = DecisionCtx {
            step: t,
            queue_empty: self.queue.is_empty(),
            entropy: if self.strategy.needs_entropy() { next_entropy } else { None },
            family: self.family(),
        };
        let route = self.strategy.decide(&ctx);
        // Invariant #1: an empty queue must force a refill.
        let mut route =
            if self.queue.is_empty() && route == Route::Cached { Route::EdgeRefill } else { route };

        // A second offload while a speculative request is in flight would
        // double-book the session in the batcher; degrade it exactly like
        // a backpressured dispatch. Dead code with the pipeline disabled
        // (`spec` is then always `None`).
        if route == Route::CloudOffload && self.spec.is_some() {
            self.metrics.spec_suppressed += 1;
            route = if self.queue.is_empty() { Route::EdgeRefill } else { Route::Cached };
        }

        // Speculative chunk reuse: probe the store before paying for the
        // wire. The signature is pure proprio/kinematics, so a hit skips
        // the whole observation pipeline; a miss leaves every PRNG stream
        // untouched and the step proceeds exactly as without a cache.
        let mut sig: Option<Signature> = None;
        if route == Route::CloudOffload {
            if let Some(store) = cache.as_deref_mut() {
                let pol = ReusePolicy::new(&sys.cache);
                let ev = self.strategy.reuse_evidence();
                // a dispatch the gate refuses carries no signature at all:
                // its reply must not be admitted either, or the store fills
                // with entries no future (equally-gated) probe can ever hit
                if pol.probe_allowed(ev.as_ref()) {
                    let s = pol.signature_for(
                        self.task.instr_id(),
                        &self.last_frame,
                        ev.as_ref(),
                        self.family(),
                        self.device_class,
                    );
                    match store.probe(&s, round, owner) {
                        ProbeOutcome::Hit(out) => {
                            if let Some(c) = span.as_mut() {
                                c.emit(Stage::ReuseProbe, 0.0, 2);
                                c.emit(Stage::ReuseHit, sys.cache.probe_ms, 2);
                            }
                            if !self.queue.is_empty() {
                                self.metrics.preemptions += 1;
                                self.metrics.overhead_ms += self.clock.preempt();
                            }
                            // served at edge-probe latency: no capture, no
                            // transfer, no cloud compute
                            self.clock.advance(sys.cache.probe_ms);
                            self.metrics.overhead_ms += sys.cache.probe_ms;
                            self.metrics.cache_hits += 1;
                            self.strategy.on_offload(t);
                            // trigger quality is scored exactly as a real
                            // offload: the dispatcher fired either way
                            self.score_trigger(t);
                            self.refill_queue(&out, ChunkSource::Cloud, t);
                            self.charge_repartitions();
                            self.finish_step(sys, Route::CloudOffload);
                            return StepEvent::Stepped;
                        }
                        ProbeOutcome::Stale => {
                            if let Some(c) = span.as_mut() {
                                c.emit(Stage::ReuseProbe, 0.0, 1);
                            }
                            self.metrics.cache_stale += 1;
                            self.metrics.cache_misses += 1;
                        }
                        ProbeOutcome::Miss => {
                            if let Some(c) = span.as_mut() {
                                c.emit(Stage::ReuseProbe, 0.0, 0);
                            }
                            self.metrics.cache_misses += 1;
                        }
                    }
                    sig = Some(s);
                }
            }
        }

        // Fleet backpressure: a disallowed offload degrades to the edge path.
        if route == Route::CloudOffload && !admit_cloud {
            self.metrics.deferred_offloads += 1;
            route = if self.queue.is_empty() { Route::EdgeRefill } else { Route::Cached };
        }

        match route {
            Route::Cached => {}
            Route::EdgeRefill | Route::CloudOffload => {
                let obs = self.renderer.render(&self.sim);
                let clarity = self.renderer.last_clarity;
                let proprio = proprio_vec(&self.last_frame);
                let instr = self.task.instr_id();

                if route == Route::CloudOffload {
                    if !self.queue.is_empty() {
                        self.metrics.preemptions += 1;
                        self.metrics.overhead_ms += self.clock.preempt();
                    }
                    let t_cap = self.clock.obs_capture_scaled(self.device_class.obs_scale());
                    if let Some(c) = span.as_mut() {
                        c.emit(Stage::Capture, t_cap, 0);
                    }
                    // entropy (split-computing) baselines partition with
                    // their own split model — they keep their activation
                    // payload and take no zoo split (charging a zoo prefix
                    // on top would mix two incompatible split models); all
                    // other strategies serve the planner's partition point:
                    // edge prefix compute, then the chosen payload
                    let zoo_split = if self.strategy.needs_entropy() {
                        None
                    } else {
                        self.family_plan.as_ref()
                    };
                    let t_prefix = zoo_split.map_or(0.0, |p| p.edge_prefix_ms);
                    let payload = if self.strategy.needs_entropy() {
                        sys.link.activation_bytes
                    } else {
                        zoo_split.map_or(sys.link.obs_bytes, |p| p.payload_bytes)
                    };
                    let xfer = self.link.offload_roundtrip(payload, sys.link.chunk_bytes, clarity);
                    // the jittered draw happens either way (identical PRNG
                    // stream); a plan rescales it to its family's cloud cost
                    let t_compute = self.clock.cloud_compute_sampled(self.cloud_ms_scale(sys));
                    // speculative edge decoding: routine dispatches (the
                    // shared z-score gate — critical phases never
                    // speculate) emit a provisional edge chunk and keep
                    // stepping instead of suspending
                    let speculative = sys.pipeline.speculate_on()
                        && crate::cache::zscore_gate_allows(
                            self.strategy.reuse_evidence().as_ref(),
                            sys.pipeline.max_zscore,
                        );
                    // [pipeline] overlap: the split-point prefix of the
                    // *next* dispatch computes while this round trip is in
                    // flight, so only the exposed remainder is charged —
                    // max(prefix, wire + cloud) instead of the sum. (A
                    // speculative dispatch hides the whole round trip
                    // instead; nothing is left to overlap.)
                    let hidden = if sys.pipeline.overlap_on() && !speculative {
                        t_prefix.min(xfer.ms + t_compute)
                    } else {
                        0.0
                    };
                    if t_prefix > 0.0 {
                        self.clock.advance(t_prefix - hidden);
                        self.metrics.edge_busy_ms += t_prefix - hidden;
                        self.metrics.overlap_hidden_ms += hidden;
                        if let Some(c) = span.as_mut() {
                            // dur = the exposed remainder actually charged;
                            // tag = the µs the overlap hid
                            c.emit(Stage::EdgePrefix, t_prefix - hidden, (hidden * 1000.0) as u32);
                        }
                    }
                    self.metrics.cloud_events += 1;
                    self.metrics.retransmissions += xfer.retransmissions as u64;
                    self.metrics.overhead_ms += xfer.retransmissions as f64 * RETRANS_PENALTY_MS;
                    self.strategy.on_offload(t);
                    self.score_trigger(t);
                    let family = self.family();

                    if speculative {
                        // the wire and cloud compute are fully hidden
                        // behind continued edge stepping: drawn above (so
                        // PRNG streams stay aligned with the sequential
                        // path) but never charged. The session pays the
                        // capture plus a cheap provisional decode and
                        // moves on; the flush resolves the request.
                        self.metrics.cloud_busy_ms += t_cap;
                        self.clock.advance(sys.pipeline.spec_decode_ms);
                        self.metrics.edge_busy_ms += sys.pipeline.spec_decode_ms;
                        self.metrics.spec_dispatches += 1;
                        if let Some(c) = span.as_mut() {
                            c.emit(Stage::SpecDispatch, sys.pipeline.spec_decode_ms, 0);
                        }
                        let t0 = std::time::Instant::now();
                        let out = edge.infer(&obs, &proprio, instr);
                        self.metrics.measured_edge_us += t0.elapsed().as_micros() as f64;
                        self.refill_queue(&out, ChunkSource::Edge, t);
                        self.charge_repartitions();
                        self.spec = Some(SpecState { t0: t, provisional: out.actions.clone() });
                        self.finish_step(sys, Route::CloudOffload);
                        return StepEvent::NeedCloud(CloudRequest {
                            obs,
                            proprio,
                            instr,
                            sig,
                            family,
                            speculative: true,
                        });
                    }

                    self.clock.advance(xfer.ms);
                    self.clock.advance(t_compute);
                    if let Some(c) = span.as_mut() {
                        // tag = payload bytes on the wire (saturating)
                        c.emit(Stage::Wire, xfer.ms, payload.min(u32::MAX as f64) as u32);
                        c.emit(Stage::CloudCompute, t_compute, 0);
                    }
                    self.metrics.cloud_busy_ms += t_cap + xfer.ms + t_compute;
                    self.awaiting = true;
                    return StepEvent::NeedCloud(CloudRequest {
                        obs,
                        proprio,
                        instr,
                        sig,
                        family,
                        speculative: false,
                    });
                }

                // routine edge refill
                self.edge_refill(sys, &obs, &proprio, instr, edge, cloud);
            }
        }

        self.finish_step(sys, route);
        StepEvent::Stepped
    }

    /// Resume a step suspended by [`StepEvent::NeedCloud`] with the cloud
    /// model's response. `measured_us` is the real wall-clock the caller
    /// spent on the inference (per request when amortized over a batch).
    pub fn complete_cloud(&mut self, sys: &SystemConfig, out: ModelOut, measured_us: f64) {
        assert!(self.awaiting, "complete_cloud() without a pending request");
        self.awaiting = false;
        self.metrics.measured_cloud_us += measured_us;
        let t = self.sim.step_index();
        self.refill_queue(&out, ChunkSource::Cloud, t);
        self.charge_repartitions();
        self.finish_step(sys, Route::CloudOffload);
    }

    /// Account a delayed cloud reply: the session stalls `ms` of virtual
    /// time still suspended (call before [`EpisodeState::complete_cloud`]).
    /// Speculative requests never stall and must not be charged here.
    pub fn charge_delay(&mut self, ms: f64) {
        assert!(self.awaiting, "charge_delay() without a pending request");
        self.clock.advance(ms);
        self.metrics.overhead_ms += ms;
    }

    /// Resolve an outstanding speculative offload with the cloud's reply
    /// (`[pipeline].speculate`): the provisional actions consumed since
    /// dispatch are *confirmed* — free — when every one stayed within
    /// `pipeline.accept_eps` of the cloud's answer, otherwise the
    /// `rollback_ms` penalty is re-charged to the session clock and the
    /// overhead column. Either way the cloud chunk's unconsumed suffix
    /// replaces the provisional remainder, so the session converges back
    /// onto cloud-grade actions from the next step on. Returns `true` on a
    /// confirm, `false` on a rollback (the span tracer tags the
    /// `SpecResolve` span with the outcome).
    pub fn resolve_speculation(
        &mut self,
        sys: &SystemConfig,
        out: ModelOut,
        measured_us: f64,
    ) -> bool {
        let spec = self.spec.take().expect("resolve_speculation() without a speculative offload");
        self.metrics.measured_cloud_us += measured_us;
        let consumed = (self.sim.step_index() - spec.t0)
            .min(spec.provisional.len())
            .min(out.actions.len());
        let confirmed = (0..consumed)
            .all(|i| (spec.provisional[i] - out.actions[i]).abs_max() <= sys.pipeline.accept_eps);
        if confirmed {
            self.metrics.spec_confirms += 1;
        } else {
            self.metrics.spec_rollbacks += 1;
            self.clock.advance(sys.pipeline.rollback_ms);
            self.metrics.overhead_ms += sys.pipeline.rollback_ms;
        }
        // adopt the cloud-grade suffix for the steps not yet consumed
        // (skipped only when the whole chunk is already in the past)
        if consumed < out.actions.len() {
            self.side.clear();
            for i in consumed..out.actions.len() {
                self.side.push_back((out.entropy(i), out.mass[i]));
            }
            let step = self.sim.step_index();
            self.overwrite_snapped(&out.actions[consumed..], ChunkSource::Cloud, step);
            self.metrics.discarded_actions = self.queue.discarded;
        }
        self.charge_repartitions();
        confirmed
    }

    /// A speculative offload whose reply was lost (dropped frame, crashed
    /// endpoint, exhausted retries): the provisional chunk simply stands —
    /// the session never stalled on the reply — and the lost dispatch is
    /// recorded as a failover.
    pub fn abort_speculation(&mut self) {
        assert!(self.spec.take().is_some(), "abort_speculation() without a speculative offload");
        self.metrics.failovers += 1;
    }

    /// Resolve a suspended offload whose reply was lost (dropped frame,
    /// crashed endpoint, timeout): the edge waits out `timeout_ms`, gives
    /// up on the reply, and re-serves the suspended step from its local
    /// slice — the failover that guarantees the session always resumes.
    /// Backend selection follows the routine edge-refill rule.
    pub fn fail_cloud(
        &mut self,
        sys: &SystemConfig,
        req: &CloudRequest,
        edge: &mut dyn Backend,
        cloud: &mut dyn Backend,
        timeout_ms: f64,
    ) {
        assert!(self.awaiting, "fail_cloud() without a pending request");
        self.awaiting = false;
        self.metrics.failovers += 1;
        // the reply never arrives: the remaining wait is pure overhead
        // (the fleet passes 0 here when failed dispatch attempts already
        // charged their timeouts via `charge_delay`)
        self.clock.advance(timeout_ms);
        self.metrics.overhead_ms += timeout_ms;
        // degraded service from the edge-resident slice
        self.edge_refill(sys, &req.obs, &req.proprio, req.instr, edge, cloud);
        self.finish_step(sys, Route::EdgeRefill);
    }

    /// Ground truth for trigger quality: was this dispatch near a critical
    /// phase? One definition for wire offloads and cache hits alike, so
    /// trigger precision stays comparable between cached and uncached runs.
    fn score_trigger(&mut self, t: usize) {
        let near_crit = (0..3).any(|d| self.sim.traj.phase_at(t + d).is_critical())
            || (t > 0 && self.sim.traj.phase_at(t - 1).is_critical());
        if near_crit {
            self.metrics.trig_tp += 1;
        } else {
            self.metrics.trig_fp += 1;
        }
    }

    /// Multiplier on the cloud compute draw: the active zoo plan's family
    /// cost relative to the configured nominal (1.0 without a plan).
    /// Strategies that take no zoo split (entropy baselines partition with
    /// their own split model) pay the family's *full-model* cloud cost —
    /// never a deep-split discount whose edge prefix they skipped.
    fn cloud_ms_scale(&self, sys: &SystemConfig) -> f64 {
        match &self.family_plan {
            Some(p) if sys.devices.cloud_compute_ms > 0.0 => {
                let ms = if self.strategy.needs_entropy() {
                    p.full_cloud_ms
                } else {
                    p.cloud_compute_ms
                };
                ms / sys.devices.cloud_compute_ms
            }
            _ => 1.0,
        }
    }

    /// Routine edge-slice refill, shared by the normal edge path and the
    /// failover path so both charge identically: slice-proportional
    /// inference time, the vision routing cost for entropy-needing
    /// strategies, the grade-selection rule, and the queue refill.
    fn edge_refill(
        &mut self,
        sys: &SystemConfig,
        obs: &[f32; D_VIS],
        proprio: &[f32; D_PROP],
        instr: usize,
        edge: &mut dyn Backend,
        cloud: &mut dyn Backend,
    ) {
        let gb = self.strategy.edge_gb(sys);
        let fam_scale = self.family_plan.as_ref().map_or(1.0, |p| p.edge_ms_scale);
        // weaker edge silicon multiplies on top of the family's slice
        // economics (class scale 1.0 — the default — is an exact no-op)
        let scale = fam_scale * self.device_class.edge_scale();
        let t_infer = self.clock.edge_infer_scaled(sys, gb, scale);
        self.metrics.edge_busy_ms += t_infer;
        self.metrics.edge_events += 1;
        if self.strategy.needs_entropy() {
            // vision preprocessing / distribution extraction
            self.metrics.overhead_ms += self.clock.vision_route();
        }
        let full_grade = gb >= 0.5 * sys.total_model_gb;
        let t0 = std::time::Instant::now();
        let out = if full_grade {
            cloud.infer(obs, proprio, instr)
        } else {
            edge.infer(obs, proprio, instr)
        };
        self.metrics.measured_edge_us += t0.elapsed().as_micros() as f64;
        let t = self.sim.step_index();
        self.refill_queue(&out, ChunkSource::Edge, t);
        self.charge_repartitions();
    }

    fn refill_queue(&mut self, out: &ModelOut, source: ChunkSource, t: usize) {
        self.side.clear();
        for i in 0..out.actions.len() {
            self.side.push_back((out.entropy(i), out.mass[i]));
        }
        self.overwrite_snapped(&out.actions, source, t);
        self.metrics.discarded_actions = self.queue.discarded;
    }

    /// Queue-overwrite funnel with the class action grid applied: every
    /// chunk a session executes — edge refills, cloud replies, cache hits,
    /// speculative suffixes — passes through here, so Lite/Nx grid
    /// snapping can never be bypassed. A zero grid step (the default
    /// class, and every run with the device zoo off) takes the untouched
    /// branch: not a single float op on the actions.
    fn overwrite_snapped(&mut self, actions: &[crate::robot::Jv], source: ChunkSource, t: usize) {
        let step = self.device_class.action_quant();
        if step > 0.0 {
            let snapped: Vec<crate::robot::Jv> = actions
                .iter()
                .map(|a| crate::robot::Jv::from_fn(|j| (a[j] / step).round() * step))
                .collect();
            self.queue.overwrite(&snapped, source, t);
        } else {
            self.queue.overwrite(actions, source, t);
        }
    }

    /// Split re-partitions (vision baseline): charge each change.
    fn charge_repartitions(&mut self) {
        let rp = self.strategy.repartitions();
        if rp > self.prev_repartitions {
            self.metrics.overhead_ms += (rp - self.prev_repartitions) as f64 * REPARTITION_MS;
            self.metrics.repartitions += rp - self.prev_repartitions;
            self.prev_repartitions = rp;
        }
    }

    /// Common step tail: dispatch the next cached action, record the
    /// trace, step the simulator and advance the virtual clock.
    fn finish_step(&mut self, sys: &SystemConfig, route: Route) {
        let t = self.sim.step_index();
        // Invariant #1 (hard): never dispatch from an empty queue.
        let action = self.queue.pop().expect("queue must be non-empty after routing");
        let (h, mass) = self.side.pop_front().unwrap_or((0.0, 0.0));

        if let Some(tl) = self.trace.as_mut() {
            let ts = t as u64;
            tl.record("entropy", ts, h);
            tl.record("mass", ts, mass);
            tl.record("clarity", ts, self.renderer.last_clarity);
            tl.record("offload", ts, if route == Route::CloudOffload { 1.0 } else { 0.0 });
            tl.record("refill", ts, if route == Route::EdgeRefill { 1.0 } else { 0.0 });
            let crit = if self.sim.traj.phase_at(t).is_critical() { 1.0 } else { 0.0 };
            tl.record("critical", ts, crit);
            tl.record(
                "phase",
                ts,
                match self.sim.traj.phase_at(t) {
                    crate::robot::Phase::Approach => 0.0,
                    crate::robot::Phase::Interact => 1.0,
                    crate::robot::Phase::Retract => 2.0,
                },
            );
            tl.record("saliency", ts, self.sim.traj.saliency_at(t));
            tl.record("velocity", ts, self.last_frame.dq.norm());
            tl.record("tau_norm", ts, self.last_frame.tau.norm());
            // Eq. 5's signal: wrist-weighted torque variation |W_τ Δτ|
            tl.record(
                "dtau_w",
                ts,
                (self.last_frame.tau - self.prev_tau).weighted_norm(&sys.dispatcher.w_torque),
            );
        }
        self.prev_tau = self.last_frame.tau;

        if self.sim.traj.phase_at(t).is_critical() {
            self.metrics.crit_steps += 1;
        }
        self.edge_gb_accum += self.strategy.edge_gb(sys);

        self.last_frame = self.sim.apply(action);
        self.clock.advance(sys.robot.dt * 1e3);
        self.metrics.steps += 1;
    }

    /// Fill the episode-final accounting fields and return a snapshot of
    /// the metrics. Idempotent; the fleet scheduler uses this to harvest a
    /// finished episode without consuming the slot.
    pub fn seal_metrics(&mut self, sys: &SystemConfig) -> EpisodeMetrics {
        assert!(!self.awaiting, "seal_metrics() while awaiting a cloud response");
        assert!(self.spec.is_none(), "seal_metrics() with an unresolved speculative offload");
        self.metrics.edge_gb = self.edge_gb_accum / self.metrics.steps.max(1) as f64;
        self.metrics.cloud_gb = sys.cloud_gb(self.metrics.edge_gb);
        self.metrics.rms_error = self.sim.rms_error();
        self.metrics.success = self.sim.success();
        // measured dispatcher CPU time (RAPID strategies report it; 0 otherwise)
        self.metrics.dispatcher_cpu_ns = self.strategy.decision_ns();
        self.metrics.clone()
    }

    /// Seal the episode accounting and return the output.
    pub fn finish(mut self, sys: &SystemConfig) -> EpisodeOutput {
        let metrics = self.seal_metrics(sys);
        EpisodeOutput { metrics, trace: self.trace }
    }
}

/// Run one episode synchronously. `edge`/`cloud` are the two model grades
/// (see module docs for the selection rule).
pub fn run_episode(
    sys: &SystemConfig,
    task: TaskKind,
    strategy: Box<dyn Strategy>,
    edge: &mut dyn Backend,
    cloud: &mut dyn Backend,
    seed: u64,
    want_trace: bool,
) -> EpisodeOutput {
    run_episode_with_cache(sys, task, strategy, edge, cloud, seed, want_trace, None, 0)
}

/// [`run_episode`] with a reuse store attached: the per-session
/// speculative-reuse tier. Cloud replies are admitted into `store` as
/// they arrive, and every subsequent redundant-phase dispatch probes it
/// first. Rounds count control steps. With `store = None` this is exactly
/// [`run_episode`], operation for operation.
#[allow(clippy::too_many_arguments)]
pub fn run_episode_with_cache(
    sys: &SystemConfig,
    task: TaskKind,
    strategy: Box<dyn Strategy>,
    edge: &mut dyn Backend,
    cloud: &mut dyn Backend,
    seed: u64,
    want_trace: bool,
    mut store: Option<&mut ReuseStore>,
    owner: usize,
) -> EpisodeOutput {
    let mut state = EpisodeState::new(sys, task, strategy, seed, want_trace);
    // resume the round clock past the store's newest entry: a persistent
    // store across episodes keeps entry ages (the TTL budget) monotonic
    // instead of resetting to "fresh" with the new episode's counter
    let mut round: u64 = store.as_deref().map_or(0, |s| s.next_round());
    loop {
        match state.poll_with_cache(sys, edge, cloud, true, store.as_deref_mut(), round, owner) {
            StepEvent::Stepped => {}
            StepEvent::Done => break,
            StepEvent::NeedCloud(req) => {
                let t0 = std::time::Instant::now();
                let out = cloud.infer(&req.obs, &req.proprio, req.instr);
                if let (Some(st), Some(sig)) = (store.as_deref_mut(), req.sig) {
                    st.admit(sig, out.clone(), round, owner);
                }
                let us = t0.elapsed().as_micros() as f64;
                if req.speculative {
                    // single-session serving resolves immediately: exactly
                    // one provisional action was consumed (the dispatch
                    // step itself)
                    state.resolve_speculation(sys, out, us);
                } else {
                    state.complete_cloud(sys, out, us);
                }
            }
        }
        round += 1;
    }
    state.finish(sys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::vla::AnalyticBackend;

    fn run(kind: PolicyKind, task: TaskKind, seed: u64) -> EpisodeMetrics {
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(kind, &sys);
        let mut edge = AnalyticBackend::edge(seed);
        let mut cloud = AnalyticBackend::cloud(seed);
        run_episode(&sys, task, strategy, &mut edge, &mut cloud, seed, false).metrics
    }

    #[test]
    fn all_policies_complete_episodes() {
        for kind in [
            PolicyKind::Rapid,
            PolicyKind::EdgeOnly,
            PolicyKind::CloudOnly,
            PolicyKind::VisionBased,
            PolicyKind::RapidNoComp,
            PolicyKind::RapidNoRed,
        ] {
            let m = run(kind, TaskKind::PickPlace, 3);
            assert_eq!(m.steps, TaskKind::PickPlace.seq_len(), "{kind:?}");
            assert!(m.events() > 0, "{kind:?}");
            assert!(m.identity_holds(14.2), "{kind:?}");
        }
    }

    #[test]
    fn edge_only_never_uses_cloud() {
        let m = run(PolicyKind::EdgeOnly, TaskKind::DrawerOpen, 4);
        assert_eq!(m.cloud_events, 0);
        assert_eq!(m.cloud_busy_ms, 0.0);
        assert!((m.edge_gb - 14.2).abs() < 1e-9);
    }

    #[test]
    fn cloud_only_never_uses_edge() {
        let m = run(PolicyKind::CloudOnly, TaskKind::DrawerOpen, 4);
        assert_eq!(m.edge_events, 0);
        assert_eq!(m.edge_gb, 0.0);
        assert!(m.cloud_events > 0);
    }

    #[test]
    fn rapid_splits_between_edge_and_cloud() {
        let m = run(PolicyKind::Rapid, TaskKind::PickPlace, 5);
        assert!(m.edge_events > 0, "edge events {}", m.edge_events);
        assert!(m.cloud_events > 0, "cloud events {}", m.cloud_events);
        assert!((m.edge_gb - 2.4).abs() < 1e-9);
    }

    #[test]
    fn rapid_total_latency_beats_edge_only() {
        let sys = SystemConfig::default();
        let mut rapid_tot = 0.0;
        let mut edge_tot = 0.0;
        for seed in 0..4 {
            rapid_tot += run(PolicyKind::Rapid, TaskKind::PickPlace, seed).latency_columns().2;
            edge_tot += run(PolicyKind::EdgeOnly, TaskKind::PickPlace, seed).latency_columns().2;
        }
        assert!(rapid_tot < edge_tot, "rapid {rapid_tot} vs edge {edge_tot}");
        let _ = sys;
    }

    #[test]
    fn deterministic_metrics() {
        let a = run(PolicyKind::Rapid, TaskKind::PegInsert, 11);
        let b = run(PolicyKind::Rapid, TaskKind::PegInsert, 11);
        assert_eq!(a.latency_columns().2, b.latency_columns().2);
        assert_eq!(a.cloud_events, b.cloud_events);
    }

    #[test]
    fn trace_contains_expected_series() {
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(1);
        let mut cloud = AnalyticBackend::cloud(1);
        let out = run_episode(&sys, TaskKind::PickPlace, strategy, &mut edge, &mut cloud, 1, true);
        let tl = out.trace.unwrap();
        for name in ["entropy", "mass", "clarity", "offload", "critical", "saliency"] {
            assert_eq!(tl.values(name).len(), TaskKind::PickPlace.seq_len(), "{name}");
        }
    }

    #[test]
    fn rapid_offloads_cluster_near_critical_phases() {
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(2);
        let mut cloud = AnalyticBackend::cloud(2);
        let out = run_episode(&sys, TaskKind::PickPlace, strategy, &mut edge, &mut cloud, 2, true);
        let precision = out.metrics.trigger_precision();
        assert!(precision > 0.5, "precision {precision}");
    }

    #[test]
    fn deferred_offload_degrades_to_edge_and_completes() {
        // admit_cloud = false everywhere: even CloudOnly must fall back to
        // the edge path and still serve every control step
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(PolicyKind::CloudOnly, &sys);
        let mut edge = AnalyticBackend::edge(8);
        let mut cloud = AnalyticBackend::cloud(8);
        let mut st = EpisodeState::new(&sys, TaskKind::PickPlace, strategy, 8, false);
        loop {
            match st.poll(&sys, &mut edge, &mut cloud, false) {
                StepEvent::Stepped => {}
                StepEvent::Done => break,
                StepEvent::NeedCloud(_) => panic!("offload admitted despite backpressure"),
            }
        }
        let out = st.finish(&sys);
        assert_eq!(out.metrics.steps, TaskKind::PickPlace.seq_len());
        assert_eq!(out.metrics.cloud_events, 0);
        assert!(out.metrics.deferred_offloads > 0);
        assert!(out.metrics.edge_events > 0);
    }

    #[test]
    fn delayed_resume_matches_uninterrupted_run() {
        // an episode driven through suspend-on-cloud with *delayed*
        // resumes — an unrelated second session advances many steps while
        // each request is parked — must produce the same trajectory
        // metrics as the uninterrupted run of the same seed
        let sys = SystemConfig::default();
        let solo = run(PolicyKind::Rapid, TaskKind::PegInsert, 33);

        let mut a = EpisodeState::new(
            &sys,
            TaskKind::PegInsert,
            crate::policy::build(PolicyKind::Rapid, &sys),
            33,
            false,
        );
        let mut a_edge = AnalyticBackend::edge(33);
        let mut a_cloud = AnalyticBackend::cloud(33);
        let mut b = EpisodeState::new(
            &sys,
            TaskKind::PickPlace,
            crate::policy::build(PolicyKind::Rapid, &sys),
            77,
            false,
        );
        let mut b_edge = AnalyticBackend::edge(77);
        let mut b_cloud = AnalyticBackend::cloud(77);

        loop {
            match a.poll(&sys, &mut a_edge, &mut a_cloud, true) {
                StepEvent::Stepped => {}
                StepEvent::Done => break,
                StepEvent::NeedCloud(req) => {
                    // hold the request: drive the other session meanwhile
                    for _ in 0..5 {
                        match b.poll(&sys, &mut b_edge, &mut b_cloud, true) {
                            StepEvent::Stepped => {}
                            StepEvent::Done => break,
                            StepEvent::NeedCloud(r2) => {
                                let out = b_cloud.infer(&r2.obs, &r2.proprio, r2.instr);
                                b.complete_cloud(&sys, out, 0.0);
                            }
                        }
                    }
                    let out = a_cloud.infer(&req.obs, &req.proprio, req.instr);
                    a.complete_cloud(&sys, out, 0.0);
                }
            }
        }
        let delayed = a.finish(&sys).metrics;
        assert_eq!(delayed.steps, solo.steps);
        assert_eq!(delayed.latency_columns(), solo.latency_columns());
        assert_eq!(delayed.cloud_events, solo.cloud_events);
        assert_eq!(delayed.edge_events, solo.edge_events);
        assert_eq!(delayed.preemptions, solo.preemptions);
        assert_eq!(delayed.rms_error, solo.rms_error);
        assert_eq!(delayed.success, solo.success);
    }

    #[test]
    fn fail_cloud_degrades_to_edge_and_always_resumes() {
        // every offload's reply is "lost": fail_cloud must resume the
        // session from the edge slice every time, to episode completion
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(PolicyKind::CloudOnly, &sys);
        let mut edge = AnalyticBackend::edge(9);
        let mut cloud = AnalyticBackend::cloud(9);
        let mut st = EpisodeState::new(&sys, TaskKind::PickPlace, strategy, 9, false);
        let mut failed = 0u64;
        loop {
            match st.poll(&sys, &mut edge, &mut cloud, true) {
                StepEvent::Stepped => {}
                StepEvent::Done => break,
                StepEvent::NeedCloud(req) => {
                    st.fail_cloud(&sys, &req, &mut edge, &mut cloud, 250.0);
                    failed += 1;
                    assert!(!st.is_awaiting_cloud());
                }
            }
        }
        let m = st.finish(&sys).metrics;
        assert!(failed > 0);
        assert_eq!(m.steps, TaskKind::PickPlace.seq_len());
        assert_eq!(m.failovers, failed);
        assert_eq!(m.edge_events, failed);
        // the timeout is charged as routing overhead on every failover
        assert!(m.overhead_ms >= 250.0 * failed as f64);
    }

    #[test]
    fn cold_cache_probes_are_bit_identical_to_no_cache() {
        // an attached-but-empty store misses on every probe; the episode
        // metrics must equal the cache-free run exactly (the probe costs
        // nothing and perturbs no PRNG stream)
        let sys = {
            let mut s = SystemConfig::default();
            s.cache.enabled = true;
            s
        };
        let base = run(PolicyKind::CloudOnly, TaskKind::PickPlace, 5);
        let mut store = crate::cache::ReuseStore::from_config(&sys.cache, 5);
        let strategy = crate::policy::build(PolicyKind::CloudOnly, &sys);
        let mut edge = AnalyticBackend::edge(5);
        let mut cloud = AnalyticBackend::cloud(5);
        // store attached but never admitted to: drive poll_with_cache with
        // probes only (no admission) by discarding req.sig
        let mut st = EpisodeState::new(&sys, TaskKind::PickPlace, strategy, 5, false);
        let mut round = 0u64;
        loop {
            let ev =
                st.poll_with_cache(&sys, &mut edge, &mut cloud, true, Some(&mut store), round, 0);
            match ev {
                StepEvent::Stepped => {}
                StepEvent::Done => break,
                StepEvent::NeedCloud(req) => {
                    let out = cloud.infer(&req.obs, &req.proprio, req.instr);
                    st.complete_cloud(&sys, out, 0.0);
                }
            }
            round += 1;
        }
        let m = st.finish(&sys).metrics;
        assert_eq!(m.latency_columns(), base.latency_columns());
        assert_eq!(m.cloud_events, base.cloud_events);
        assert_eq!(m.rms_error, base.rms_error);
        assert_eq!(m.cache_hits, 0);
        assert!(m.cache_misses > 0, "every offload probed and missed");
    }

    #[test]
    fn warm_cache_replays_the_episode_without_the_cloud() {
        // episode 2 of the same seed revisits exactly the states episode 1
        // cached: every offload hits, the cloud is never consulted, and
        // the trajectory (actions come from identical chunks) is unchanged
        // while latency strictly drops
        let mut sys = SystemConfig::default();
        sys.cache.enabled = true;
        let mut store = crate::cache::ReuseStore::from_config(&sys.cache, 5);

        let run_cached = |store: &mut crate::cache::ReuseStore, sys: &SystemConfig| {
            let strategy = crate::policy::build(PolicyKind::CloudOnly, sys);
            let mut edge = AnalyticBackend::edge(5);
            let mut cloud = AnalyticBackend::cloud(5);
            run_episode_with_cache(
                sys,
                TaskKind::PickPlace,
                strategy,
                &mut edge,
                &mut cloud,
                5,
                false,
                Some(store),
                0,
            )
            .metrics
        };
        let e1 = run_cached(&mut store, &sys);
        assert_eq!(e1.cache_hits, 0, "first episode has nothing to reuse");
        assert!(e1.cloud_events > 0);

        let e2 = run_cached(&mut store, &sys);
        assert_eq!(e2.cache_hits, e1.cloud_events, "every offload reuses episode 1's chunk");
        assert_eq!(e2.cloud_events, 0);
        assert_eq!(e2.rms_error, e1.rms_error, "identical chunks, identical trajectory");
        assert_eq!(e2.success, e1.success);
        assert!(
            e2.latency_columns().2 < e1.latency_columns().2,
            "hits must be strictly cheaper: {} vs {}",
            e2.latency_columns().2,
            e1.latency_columns().2
        );
    }

    #[test]
    fn zoo_plan_prices_the_family_economics() {
        use crate::vla::profile::{FamilyProfile, ModelFamily};
        use crate::vla::ZooBackend;
        let sys = SystemConfig::default();

        // Short-chunk AR family: CloudOnly refills every 4 steps instead
        // of every 8 — roughly twice the cloud events of the surrogate —
        // and each call costs more cloud compute.
        let run_fam = |fam: ModelFamily, kind: PolicyKind| {
            let plan = crate::policy::planner::plan(
                &FamilyProfile::of(fam),
                sys.link.bw_mbps,
                sys.link.rtt_ms,
            );
            let mut edge = ZooBackend::edge(fam, 6);
            let mut cloud = ZooBackend::cloud(fam, 6);
            let strategy = crate::policy::build(kind, &sys);
            let mut st = EpisodeState::new(&sys, TaskKind::PickPlace, strategy, 6, false);
            st.set_family_plan(Some(plan));
            assert_eq!(st.family(), fam);
            loop {
                match st.poll(&sys, &mut edge, &mut cloud, true) {
                    StepEvent::Stepped => {}
                    StepEvent::Done => break,
                    StepEvent::NeedCloud(req) => {
                        assert_eq!(req.family, fam, "request must carry its family");
                        let out = cloud.infer(&req.obs, &req.proprio, req.instr);
                        st.complete_cloud(&sys, out, 0.0);
                    }
                }
            }
            st.finish(&sys).metrics
        };

        let surrogate = run(PolicyKind::CloudOnly, TaskKind::PickPlace, 6);
        let ar = run_fam(ModelFamily::OpenVlaAr, PolicyKind::CloudOnly);
        assert_eq!(ar.steps, TaskKind::PickPlace.seq_len());
        assert!(
            ar.cloud_events > surrogate.cloud_events,
            "short chunks refill more often: {} vs {}",
            ar.cloud_events,
            surrogate.cloud_events
        );
        assert!(
            ar.cloud_busy_ms > surrogate.cloud_busy_ms,
            "AR cloud time must exceed the surrogate's"
        );

        // Quantized edge family: Edge-Only inference gets strictly cheaper.
        let plain_edge = run(PolicyKind::EdgeOnly, TaskKind::PickPlace, 6);
        let quant_edge = run_fam(ModelFamily::EdgeQuant, PolicyKind::EdgeOnly);
        assert_eq!(quant_edge.steps, TaskKind::PickPlace.seq_len());
        assert!(
            quant_edge.edge_busy_ms < plain_edge.edge_busy_ms,
            "quantized slice must be cheaper: {} vs {}",
            quant_edge.edge_busy_ms,
            plain_edge.edge_busy_ms
        );
    }

    #[test]
    fn surrogate_plan_with_default_knobs_is_bit_identical() {
        use crate::vla::profile::{FamilyProfile, ModelFamily};
        // the surrogate family's catalog equals the default [devices]/
        // [link] anchors, so installing its plan must not move a single
        // metric relative to the plan-free run of the same seed
        let sys = SystemConfig::default();
        let base = run(PolicyKind::Rapid, TaskKind::PickPlace, 12);
        let plan = crate::policy::planner::plan(
            &FamilyProfile::of(ModelFamily::Surrogate),
            sys.link.bw_mbps,
            sys.link.rtt_ms,
        );
        let mut edge = AnalyticBackend::edge(12);
        let mut cloud = AnalyticBackend::cloud(12);
        let mut st = EpisodeState::new(
            &sys,
            TaskKind::PickPlace,
            crate::policy::build(PolicyKind::Rapid, &sys),
            12,
            false,
        );
        st.set_family_plan(Some(plan));
        loop {
            match st.poll(&sys, &mut edge, &mut cloud, true) {
                StepEvent::Stepped => {}
                StepEvent::Done => break,
                StepEvent::NeedCloud(req) => {
                    let out = cloud.infer(&req.obs, &req.proprio, req.instr);
                    st.complete_cloud(&sys, out, 0.0);
                }
            }
        }
        let m = st.finish(&sys).metrics;
        assert_eq!(m.latency_columns(), base.latency_columns());
        assert_eq!(m.cloud_events, base.cloud_events);
        assert_eq!(m.rms_error, base.rms_error);
    }

    #[test]
    fn default_device_class_is_bit_identical() {
        // installing the Cloudlet class explicitly must not move a single
        // metric relative to a run that never called set_device_class
        use crate::runtime::DeviceClass;
        let base = run(PolicyKind::Rapid, TaskKind::PickPlace, 18);
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(18);
        let mut cloud = AnalyticBackend::cloud(18);
        let mut st = EpisodeState::new(&sys, TaskKind::PickPlace, strategy, 18, false);
        st.set_device_class(DeviceClass::default());
        assert_eq!(st.device_class(), DeviceClass::Cloudlet);
        loop {
            match st.poll(&sys, &mut edge, &mut cloud, true) {
                StepEvent::Stepped => {}
                StepEvent::Done => break,
                StepEvent::NeedCloud(req) => {
                    let out = cloud.infer(&req.obs, &req.proprio, req.instr);
                    st.complete_cloud(&sys, out, 0.0);
                }
            }
        }
        let m = st.finish(&sys).metrics;
        assert_eq!(m.latency_columns(), base.latency_columns());
        assert_eq!(m.cloud_events, base.cloud_events);
        assert_eq!(m.rms_error, base.rms_error);
        assert_eq!(m.success, base.success);
    }

    #[test]
    fn lite_class_pays_for_its_weaker_silicon() {
        // a Lite robot's episode still completes, but edge compute and
        // capture run slower (2.2× / 1.5×) and its actions execute on the
        // coarse grid, so the trajectory genuinely differs
        use crate::runtime::DeviceClass;
        let base = run(PolicyKind::Rapid, TaskKind::PickPlace, 19);
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(19);
        let mut cloud = AnalyticBackend::cloud(19);
        let mut st = EpisodeState::new(&sys, TaskKind::PickPlace, strategy, 19, false);
        st.set_device_class(DeviceClass::Lite);
        loop {
            match st.poll(&sys, &mut edge, &mut cloud, true) {
                StepEvent::Stepped => {}
                StepEvent::Done => break,
                StepEvent::NeedCloud(req) => {
                    let out = cloud.infer(&req.obs, &req.proprio, req.instr);
                    st.complete_cloud(&sys, out, 0.0);
                }
            }
        }
        let m = st.finish(&sys).metrics;
        assert_eq!(m.steps, TaskKind::PickPlace.seq_len(), "lite episodes still complete");
        assert!(
            m.latency_columns().2 > base.latency_columns().2,
            "weaker silicon must cost time: {} vs {}",
            m.latency_columns().2,
            base.latency_columns().2
        );
        assert_ne!(m.rms_error, base.rms_error, "grid-snapped actions move the trajectory");
    }

    #[test]
    fn suspended_step_resumes_identically() {
        // driving poll/complete_cloud by hand must equal run_episode exactly
        let sys = SystemConfig::default();
        let solo = run(PolicyKind::Rapid, TaskKind::PegInsert, 21);

        let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(21);
        let mut cloud = AnalyticBackend::cloud(21);
        let mut st = EpisodeState::new(&sys, TaskKind::PegInsert, strategy, 21, false);
        loop {
            match st.poll(&sys, &mut edge, &mut cloud, true) {
                StepEvent::Stepped => {}
                StepEvent::Done => break,
                StepEvent::NeedCloud(req) => {
                    let out = cloud.infer(&req.obs, &req.proprio, req.instr);
                    st.complete_cloud(&sys, out, 0.0);
                }
            }
        }
        let manual = st.finish(&sys).metrics;
        assert_eq!(manual.latency_columns(), solo.latency_columns());
        assert_eq!(manual.cloud_events, solo.cloud_events);
        assert_eq!(manual.edge_events, solo.edge_events);
        assert_eq!(manual.rms_error, solo.rms_error);
    }

    #[test]
    fn degenerate_pipeline_is_bit_identical() {
        // [pipeline] enabled with both modes off — and overlap armed with
        // no zoo plan (prefix 0, nothing to hide) — must not move a single
        // metric relative to the plain run of the same seed
        let base = run(PolicyKind::Rapid, TaskKind::PickPlace, 14);
        for (overlap, speculate) in [(false, false), (true, false)] {
            let mut sys = SystemConfig::default();
            sys.pipeline.enabled = true;
            sys.pipeline.overlap = overlap;
            sys.pipeline.speculate = speculate;
            let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
            let mut edge = AnalyticBackend::edge(14);
            let mut cloud = AnalyticBackend::cloud(14);
            let m =
                run_episode(&sys, TaskKind::PickPlace, strategy, &mut edge, &mut cloud, 14, false)
                    .metrics;
            assert_eq!(m.latency_columns(), base.latency_columns(), "overlap={overlap}");
            assert_eq!(m.cloud_events, base.cloud_events);
            assert_eq!(m.rms_error, base.rms_error);
            assert_eq!(m.spec_dispatches, 0);
            assert_eq!(m.overlap_hidden_ms, 0.0);
        }
    }

    #[test]
    fn speculative_episode_completes_and_is_cheaper() {
        // Cloud-Only exposes no kinematic evidence, so the z-gate allows
        // every dispatch: each offload hides its full round trip behind a
        // provisional decode and pays at most decode + rollback
        let base = run(PolicyKind::CloudOnly, TaskKind::PickPlace, 15);
        let mut sys = SystemConfig::default();
        sys.pipeline.enabled = true;
        sys.pipeline.speculate = true;
        let strategy = crate::policy::build(PolicyKind::CloudOnly, &sys);
        let mut edge = AnalyticBackend::edge(15);
        let mut cloud = AnalyticBackend::cloud(15);
        let m = run_episode(&sys, TaskKind::PickPlace, strategy, &mut edge, &mut cloud, 15, false)
            .metrics;
        assert_eq!(m.steps, TaskKind::PickPlace.seq_len());
        assert!(m.spec_dispatches > 0);
        assert_eq!(m.spec_confirms + m.spec_rollbacks, m.spec_dispatches, "every spec resolves");
        assert!(
            m.latency_columns().2 < base.latency_columns().2,
            "speculation must be cheaper: {} vs {}",
            m.latency_columns().2,
            base.latency_columns().2
        );
    }

    #[test]
    fn overlap_hides_prefix_under_the_round_trip() {
        use crate::vla::profile::{FamilyProfile, ModelFamily};
        use crate::vla::ZooBackend;
        // a deep split (planned under a slow link) has real prefix compute
        // to hide; overlap must shave exactly that time off the columns
        // while leaving draws — and therefore the trajectory — untouched
        let run_planned = |sys: &SystemConfig| {
            let plan = crate::policy::planner::plan(
                &FamilyProfile::of(ModelFamily::OpenVlaAr),
                20.0,
                40.0,
            );
            assert!(plan.edge_prefix_ms > 0.0, "slow link must pick a deep split");
            let mut edge = ZooBackend::edge(ModelFamily::OpenVlaAr, 16);
            let mut cloud = ZooBackend::cloud(ModelFamily::OpenVlaAr, 16);
            let strategy = crate::policy::build(PolicyKind::CloudOnly, sys);
            let mut st = EpisodeState::new(sys, TaskKind::PickPlace, strategy, 16, false);
            st.set_family_plan(Some(plan));
            loop {
                match st.poll(sys, &mut edge, &mut cloud, true) {
                    StepEvent::Stepped => {}
                    StepEvent::Done => break,
                    StepEvent::NeedCloud(req) => {
                        assert!(!req.speculative);
                        let out = cloud.infer(&req.obs, &req.proprio, req.instr);
                        st.complete_cloud(sys, out, 0.0);
                    }
                }
            }
            st.finish(sys).metrics
        };
        let mut sys = SystemConfig::default();
        sys.pipeline.enabled = true;
        sys.pipeline.overlap = true;
        let on = run_planned(&sys);
        sys.pipeline.overlap = false;
        let off = run_planned(&sys);
        assert!(on.overlap_hidden_ms > 0.0);
        assert_eq!(off.overlap_hidden_ms, 0.0);
        assert!(
            on.latency_columns().2 < off.latency_columns().2,
            "overlap must be cheaper: {} vs {}",
            on.latency_columns().2,
            off.latency_columns().2
        );
        // overlap restructures charges only: identical draws, trajectory
        assert_eq!(on.rms_error, off.rms_error);
        assert_eq!(on.cloud_events, off.cloud_events);
        assert!((on.edge_busy_ms + on.overlap_hidden_ms - off.edge_busy_ms).abs() < 1e-6);
    }
}

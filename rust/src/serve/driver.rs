//! The episode driver: one task episode under one partitioning strategy.
//!
//! Per control step (f_control): ingest the proprioceptive frame (the
//! f_sensor evaluation collapses to control rate in simulation — the real
//! 500 Hz loop is exercised by `examples/serve_cluster.rs` and the
//! dispatcher perf bench), route via the strategy, execute chunk
//! generations on the *real* AOT-compiled models, advance the virtual
//! testbed clock per DESIGN.md §5, and step the simulator.
//!
//! Backend selection rule: chunk content comes from the *cloud-grade*
//! model whenever the generating slice holds the majority of parameters
//! (Edge-Only runs the full 14.2 GB model locally — slow but full quality);
//! otherwise from the edge-grade model.

use crate::config::SystemConfig;
use crate::dispatcher::{ChunkQueue, ChunkSource};
use crate::metrics::EpisodeMetrics;
use crate::net::Link;
use crate::policy::{DecisionCtx, Route, Strategy};
use crate::robot::{RobotSim, TaskKind};
use crate::runtime::DeviceClock;
use crate::scene::{NoiseModel, Renderer};
use crate::util::timeline::Timeline;
use crate::vla::{obs::proprio_vec, Backend};
use std::collections::VecDeque;

/// Extra routing cost charged per retransmission (reassembly + re-route).
const RETRANS_PENALTY_MS: f64 = 40.0;
/// Cost of moving the split point (vision baseline re-partition: model
/// layers must be shipped and re-warmed).
const REPARTITION_MS: f64 = 150.0;

pub struct EpisodeOutput {
    pub metrics: EpisodeMetrics,
    pub trace: Option<Timeline>,
}

/// Run one episode. `edge`/`cloud` are the two model grades (see module
/// docs for the selection rule).
pub fn run_episode(
    sys: &SystemConfig,
    task: TaskKind,
    mut strategy: Box<dyn Strategy>,
    edge: &mut dyn Backend,
    cloud: &mut dyn Backend,
    seed: u64,
    want_trace: bool,
) -> EpisodeOutput {
    let kind = strategy.kind();
    let mut sim = RobotSim::new(task, &sys.robot, seed);
    let mut renderer = Renderer::new(NoiseModel::new(&sys.scene, seed ^ 0x9e37), seed ^ 0x517);
    let mut clock = DeviceClock::new(&sys.devices, seed ^ 0xDC);
    let mut link = Link::new(&sys.link, seed ^ 0x71);
    let mut queue = ChunkQueue::new();
    // side channels (entropy, mass) parallel to the action queue
    let mut side: VecDeque<(f64, f64)> = VecDeque::new();
    let mut metrics = EpisodeMetrics::new(task, kind);
    let mut trace = if want_trace { Some(Timeline::new()) } else { None };

    let mut last_frame = crate::robot::SensorFrame {
        step: 0,
        q: sim.q(),
        dq: crate::robot::Jv::ZERO,
        tau: crate::robot::Jv::ZERO,
    };
    let mut edge_gb_accum = 0.0f64;
    let mut prev_repartitions = 0u64;
    let mut prev_tau = crate::robot::Jv::ZERO;

    while !sim.done() {
        let t = sim.step_index();
        strategy.observe(&last_frame);

        // entropy of the action about to execute (vision baseline signal)
        let next_entropy = side.front().map(|&(h, _)| h);
        let ctx = DecisionCtx {
            step: t,
            queue_empty: queue.is_empty(),
            entropy: if strategy.needs_entropy() { next_entropy } else { None },
        };
        let route = strategy.decide(&ctx);
        // Invariant #1: an empty queue must force a refill.
        let route = if queue.is_empty() && route == Route::Cached { Route::EdgeRefill } else { route };

        match route {
            Route::Cached => {}
            Route::EdgeRefill | Route::CloudOffload => {
                let obs = renderer.render(&sim);
                let clarity = renderer.last_clarity;
                let proprio = proprio_vec(&last_frame);
                let instr = task.instr_id();

                if route == Route::CloudOffload {
                    if !queue.is_empty() {
                        metrics.preemptions += 1;
                        metrics.overhead_ms += clock.preempt();
                    }
                    let t_cap = clock.obs_capture();
                    // split-computing baselines ship intermediate activations
                    // from the split point; RAPID ships the raw observation
                    let payload = if strategy.needs_entropy() { sys.link.activation_bytes } else { sys.link.obs_bytes };
                    let xfer = link.offload_roundtrip(payload, sys.link.chunk_bytes, clarity);
                    clock.advance(xfer.ms);
                    let t_compute = clock.cloud_compute();
                    metrics.cloud_busy_ms += t_cap + xfer.ms + t_compute;
                    metrics.cloud_events += 1;
                    metrics.retransmissions += xfer.retransmissions as u64;
                    metrics.overhead_ms += xfer.retransmissions as f64 * RETRANS_PENALTY_MS;
                    strategy.on_offload(t);

                    let t0 = std::time::Instant::now();
                    let out = cloud.infer(&obs, &proprio, instr);
                    metrics.measured_cloud_us += t0.elapsed().as_micros() as f64;

                    // ground truth: was this offload near a critical phase?
                    let near_crit = (0..3).any(|d| sim.traj.phase_at(t + d).is_critical())
                        || (t > 0 && sim.traj.phase_at(t - 1).is_critical());
                    if near_crit {
                        metrics.trig_tp += 1;
                    } else {
                        metrics.trig_fp += 1;
                    }

                    side.clear();
                    for i in 0..out.actions.len() {
                        side.push_back((out.entropy(i), out.mass[i]));
                    }
                    queue.overwrite(&out.actions, ChunkSource::Cloud, t);
                    metrics.discarded_actions = queue.discarded;
                } else {
                    // routine edge refill
                    let gb = strategy.edge_gb(sys);
                    let t_infer = clock.edge_infer(sys, gb);
                    metrics.edge_busy_ms += t_infer;
                    metrics.edge_events += 1;
                    if strategy.needs_entropy() {
                        // vision preprocessing / distribution extraction
                        metrics.overhead_ms += clock.vision_route();
                    }
                    let full_grade = gb >= 0.5 * sys.total_model_gb;
                    let t0 = std::time::Instant::now();
                    let out = if full_grade { cloud.infer(&obs, &proprio, instr) } else { edge.infer(&obs, &proprio, instr) };
                    metrics.measured_edge_us += t0.elapsed().as_micros() as f64;
                    side.clear();
                    for i in 0..out.actions.len() {
                        side.push_back((out.entropy(i), out.mass[i]));
                    }
                    queue.overwrite(&out.actions, ChunkSource::Edge, t);
                    metrics.discarded_actions = queue.discarded;
                }

                // split re-partitions (vision baseline): charge each change
                let rp = strategy.repartitions();
                if rp > prev_repartitions {
                    metrics.overhead_ms += (rp - prev_repartitions) as f64 * REPARTITION_MS;
                    metrics.repartitions += rp - prev_repartitions;
                    prev_repartitions = rp;
                }
            }
        }

        // Invariant #1 (hard): never dispatch from an empty queue.
        let action = queue.pop().expect("queue must be non-empty after routing");
        let (h, mass) = side.pop_front().unwrap_or((0.0, 0.0));

        if let Some(tl) = trace.as_mut() {
            let ts = t as u64;
            tl.record("entropy", ts, h);
            tl.record("mass", ts, mass);
            tl.record("clarity", ts, renderer.last_clarity);
            tl.record("offload", ts, if route == Route::CloudOffload { 1.0 } else { 0.0 });
            tl.record("refill", ts, if route == Route::EdgeRefill { 1.0 } else { 0.0 });
            tl.record("critical", ts, if sim.traj.phase_at(t).is_critical() { 1.0 } else { 0.0 });
            tl.record(
                "phase",
                ts,
                match sim.traj.phase_at(t) {
                    crate::robot::Phase::Approach => 0.0,
                    crate::robot::Phase::Interact => 1.0,
                    crate::robot::Phase::Retract => 2.0,
                },
            );
            tl.record("saliency", ts, sim.traj.saliency_at(t));
            tl.record("velocity", ts, last_frame.dq.norm());
            tl.record("tau_norm", ts, last_frame.tau.norm());
            // Eq. 5's signal: wrist-weighted torque variation |W_τ Δτ|
            tl.record("dtau_w", ts, (last_frame.tau - prev_tau).weighted_norm(&sys.dispatcher.w_torque));
        }
        prev_tau = last_frame.tau;

        if sim.traj.phase_at(t).is_critical() {
            metrics.crit_steps += 1;
        }
        edge_gb_accum += strategy.edge_gb(sys);

        last_frame = sim.apply(action);
        clock.advance(sys.robot.dt * 1e3);
        metrics.steps += 1;
    }

    metrics.edge_gb = edge_gb_accum / metrics.steps.max(1) as f64;
    metrics.cloud_gb = sys.cloud_gb(metrics.edge_gb);
    metrics.rms_error = sim.rms_error();
    metrics.success = sim.success();
    // measured dispatcher CPU time (RAPID strategies report it; 0 otherwise)
    metrics.dispatcher_cpu_ns = strategy.decision_ns();

    EpisodeOutput { metrics, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::vla::AnalyticBackend;

    fn run(kind: PolicyKind, task: TaskKind, seed: u64) -> EpisodeMetrics {
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(kind, &sys);
        let mut edge = AnalyticBackend::edge(seed);
        let mut cloud = AnalyticBackend::cloud(seed);
        run_episode(&sys, task, strategy, &mut edge, &mut cloud, seed, false).metrics
    }

    #[test]
    fn all_policies_complete_episodes() {
        for kind in [
            PolicyKind::Rapid,
            PolicyKind::EdgeOnly,
            PolicyKind::CloudOnly,
            PolicyKind::VisionBased,
            PolicyKind::RapidNoComp,
            PolicyKind::RapidNoRed,
        ] {
            let m = run(kind, TaskKind::PickPlace, 3);
            assert_eq!(m.steps, TaskKind::PickPlace.seq_len(), "{kind:?}");
            assert!(m.events() > 0, "{kind:?}");
            assert!(m.identity_holds(14.2), "{kind:?}");
        }
    }

    #[test]
    fn edge_only_never_uses_cloud() {
        let m = run(PolicyKind::EdgeOnly, TaskKind::DrawerOpen, 4);
        assert_eq!(m.cloud_events, 0);
        assert_eq!(m.cloud_busy_ms, 0.0);
        assert!((m.edge_gb - 14.2).abs() < 1e-9);
    }

    #[test]
    fn cloud_only_never_uses_edge() {
        let m = run(PolicyKind::CloudOnly, TaskKind::DrawerOpen, 4);
        assert_eq!(m.edge_events, 0);
        assert_eq!(m.edge_gb, 0.0);
        assert!(m.cloud_events > 0);
    }

    #[test]
    fn rapid_splits_between_edge_and_cloud() {
        let m = run(PolicyKind::Rapid, TaskKind::PickPlace, 5);
        assert!(m.edge_events > 0, "edge events {}", m.edge_events);
        assert!(m.cloud_events > 0, "cloud events {}", m.cloud_events);
        assert!((m.edge_gb - 2.4).abs() < 1e-9);
    }

    #[test]
    fn rapid_total_latency_beats_edge_only() {
        let sys = SystemConfig::default();
        let mut rapid_tot = 0.0;
        let mut edge_tot = 0.0;
        for seed in 0..4 {
            rapid_tot += run(PolicyKind::Rapid, TaskKind::PickPlace, seed).latency_columns().2;
            edge_tot += run(PolicyKind::EdgeOnly, TaskKind::PickPlace, seed).latency_columns().2;
        }
        assert!(rapid_tot < edge_tot, "rapid {rapid_tot} vs edge {edge_tot}");
        let _ = sys;
    }

    #[test]
    fn deterministic_metrics() {
        let a = run(PolicyKind::Rapid, TaskKind::PegInsert, 11);
        let b = run(PolicyKind::Rapid, TaskKind::PegInsert, 11);
        assert_eq!(a.latency_columns().2, b.latency_columns().2);
        assert_eq!(a.cloud_events, b.cloud_events);
    }

    #[test]
    fn trace_contains_expected_series() {
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(1);
        let mut cloud = AnalyticBackend::cloud(1);
        let out = run_episode(&sys, TaskKind::PickPlace, strategy, &mut edge, &mut cloud, 1, true);
        let tl = out.trace.unwrap();
        for name in ["entropy", "mass", "clarity", "offload", "critical", "saliency"] {
            assert_eq!(tl.values(name).len(), TaskKind::PickPlace.seq_len(), "{name}");
        }
    }

    #[test]
    fn rapid_offloads_cluster_near_critical_phases() {
        let sys = SystemConfig::default();
        let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(2);
        let mut cloud = AnalyticBackend::cloud(2);
        let out = run_episode(&sys, TaskKind::PickPlace, strategy, &mut edge, &mut cloud, 2, true);
        assert!(out.metrics.trigger_precision() > 0.5, "precision {}", out.metrics.trigger_precision());
    }
}

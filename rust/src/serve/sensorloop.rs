//! Asynchronous multi-rate processing (paper §V-A).
//!
//! The low-level proprioceptive polling runs as an independent thread at
//! f_sensor (e.g. 500 Hz); the dual-threshold evaluation lives entirely in
//! that loop and, on a breach, raises an **interrupt flag** that the
//! f_control loop consumes without blocking the robot's kinematics. The
//! rolling statistics are therefore updated with many more samples than
//! the control rate would provide ("statistical robustness without
//! stealing compute cycles from the main control thread").
//!
//! The episode *simulator* collapses this to control rate (virtual time);
//! this module is the real-time implementation used by the deployment
//! example and the overhead benchmarks.

use crate::config::DispatcherConfig;
use crate::dispatcher::RapidDispatcher;
use crate::robot::SensorFrame;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Lock-free state shared between the sensor thread and the control loop.
#[derive(Debug, Default)]
pub struct TriggerFlag {
    /// The interrupt: set by the sensor loop, consumed by the control loop.
    dispatch: AtomicBool,
    /// Diagnostics.
    pub ticks: AtomicU64,
    pub triggers: AtomicU64,
    /// Last importance score (f64 bits) for telemetry.
    importance_bits: AtomicU64,
}

impl TriggerFlag {
    /// Consume the interrupt (returns true at most once per raise).
    pub fn take(&self) -> bool {
        self.dispatch.swap(false, Ordering::AcqRel)
    }

    pub fn raise(&self) {
        self.dispatch.store(true, Ordering::Release);
    }

    /// Observe the interrupt without consuming it (telemetry/scheduling
    /// probes that must not race the control loop's `take`).
    pub fn pending(&self) -> bool {
        self.dispatch.load(Ordering::Acquire)
    }

    pub fn importance(&self) -> f64 {
        f64::from_bits(self.importance_bits.load(Ordering::Relaxed))
    }
}

/// Handle to a running high-rate sensor loop.
pub struct SensorLoop {
    stop: Arc<AtomicBool>,
    pub flag: Arc<TriggerFlag>,
    handle: Option<thread::JoinHandle<SensorLoopStats>>,
}

/// Loop statistics returned on shutdown.
#[derive(Debug, Clone, Copy)]
pub struct SensorLoopStats {
    pub ticks: u64,
    pub achieved_hz: f64,
    pub mean_tick_ns: f64,
}

impl SensorLoop {
    /// Spawn the f_sensor thread. `source` is polled once per tick for the
    /// latest proprioceptive frame (it must be cheap and non-blocking —
    /// encoder/F-T registers in a real deployment).
    pub fn spawn<S>(cfg: &DispatcherConfig, sensor_hz: f64, mut source: S) -> SensorLoop
    where
        S: FnMut(u64) -> SensorFrame + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::new(TriggerFlag::default());
        let cfg = cfg.clone();
        let t_stop = stop.clone();
        let t_flag = flag.clone();
        let handle = thread::spawn(move || {
            // Eq. 2 finite differences use the *sensor* interval here.
            let dt = 1.0 / sensor_hz;
            let mut dispatcher = RapidDispatcher::new(&cfg, dt);
            let period = Duration::from_secs_f64(dt);
            let start = Instant::now();
            let mut busy_ns = 0u64;
            let mut tick: u64 = 0;
            let mut next = Instant::now();
            while !t_stop.load(Ordering::Relaxed) {
                let t0 = Instant::now();
                let frame = source(tick);
                let eval = dispatcher.observe(&frame);
                if eval.dispatch {
                    t_flag.raise();
                    t_flag.triggers.fetch_add(1, Ordering::Relaxed);
                }
                t_flag
                    .importance_bits
                    .store(eval.outcome.importance.to_bits(), Ordering::Relaxed);
                t_flag.ticks.fetch_add(1, Ordering::Relaxed);
                busy_ns += t0.elapsed().as_nanos() as u64;
                tick += 1;
                // fixed-rate scheduling with drift correction
                next += period;
                let now = Instant::now();
                if next > now {
                    thread::sleep(next - now);
                } else {
                    next = now; // overrun: resynchronize
                }
            }
            let elapsed = start.elapsed().as_secs_f64();
            SensorLoopStats {
                ticks: tick,
                achieved_hz: tick as f64 / elapsed.max(1e-9),
                mean_tick_ns: busy_ns as f64 / tick.max(1) as f64,
            }
        });
        SensorLoop { stop, flag, handle: Some(handle) }
    }

    /// Stop the loop and return its statistics.
    pub fn stop(mut self) -> SensorLoopStats {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.take().expect("already stopped").join().expect("sensor loop panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::Jv;

    /// Gaussian sensor noise at drive-filtered magnitudes (a deterministic
    /// cyclic pattern would make its own outliers genuinely anomalous under
    /// z-normalization). Velocity noise is ~1e-5 rad/s: servo drives ship
    /// *filtered* velocity estimates — raw finite differences at 1 kHz
    /// would amplify encoder noise by 1/dt and are not what q̇ registers
    /// contain on real hardware.
    fn calm_source() -> impl FnMut(u64) -> SensorFrame + Send + 'static {
        let mut rng = crate::util::Pcg32::seeded(0x5E45);
        move |step| SensorFrame {
            step: step as usize,
            q: Jv::ZERO,
            dq: Jv::from_fn(|_| 0.2 + 1e-5 * rng.normal()),
            tau: Jv::from_fn(|_| 1.0 + 2e-3 * rng.normal()),
        }
    }

    #[test]
    fn runs_near_target_rate_and_stops_cleanly() {
        let cfg = DispatcherConfig::default();
        let lp = SensorLoop::spawn(&cfg, 500.0, calm_source());
        thread::sleep(Duration::from_millis(300));
        let stats = lp.stop();
        assert!(stats.ticks > 100, "ticks {}", stats.ticks);
        assert!(
            (stats.achieved_hz - 500.0).abs() < 100.0,
            "achieved {} Hz",
            stats.achieved_hz
        );
        // the paper's overhead envelope: tick cost must be a tiny share of
        // the 2 ms budget
        assert!(stats.mean_tick_ns < 100_000.0, "tick {}ns", stats.mean_tick_ns);
    }

    #[test]
    fn calm_stream_false_trigger_rate_is_tiny() {
        // pure sensor noise: rare >z_gate excursions are statistically
        // expected (that's what the cooldown absorbs); the *rate* must be
        // far below anything that would cause measurable cloud traffic
        let cfg = DispatcherConfig::default();
        let lp = SensorLoop::spawn(&cfg, 1000.0, calm_source());
        thread::sleep(Duration::from_millis(300));
        let triggers = lp.flag.triggers.load(Ordering::Relaxed);
        let stats = lp.stop();
        let rate = triggers as f64 / stats.ticks.max(1) as f64;
        assert!(rate < 0.02, "false-trigger rate {rate} ({triggers}/{} ticks)", stats.ticks);
    }

    #[test]
    fn contact_spike_raises_interrupt_once_until_consumed() {
        let cfg = DispatcherConfig::default();
        // a shared switch flips the source into "contact" mode mid-run
        let contact = Arc::new(AtomicBool::new(false));
        let c2 = contact.clone();
        let mut calm = calm_source();
        let lp = SensorLoop::spawn(&cfg, 1000.0, move |step| {
            if c2.load(Ordering::Relaxed) {
                SensorFrame {
                    step: step as usize,
                    q: Jv::ZERO,
                    dq: Jv::splat(0.05),
                    tau: Jv::splat(9.0),
                }
            } else {
                calm(step)
            }
        });
        thread::sleep(Duration::from_millis(150)); // warm the windows
        contact.store(true, Ordering::Relaxed);
        // the interrupt must arrive within a few sensor periods
        let deadline = Instant::now() + Duration::from_millis(100);
        let mut raised = false;
        while Instant::now() < deadline {
            // pending() observes without consuming: once it reads true,
            // take() (the only consumer here) must succeed
            if lp.flag.pending() {
                assert!(lp.flag.take());
                raised = true;
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        assert!(raised, "no interrupt within 100ms of contact");
        lp.stop();
    }

    #[test]
    fn importance_telemetry_updates() {
        let cfg = DispatcherConfig::default();
        let lp = SensorLoop::spawn(&cfg, 2000.0, calm_source());
        thread::sleep(Duration::from_millis(100));
        let imp = lp.flag.importance();
        assert!(imp.is_finite());
        lp.stop();
    }
}

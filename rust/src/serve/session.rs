//! Multi-episode suite runner: tasks × episodes × policies, aggregated to
//! paper-style rows.
//!
//! The suite runner executes episodes *sequentially* and exists to
//! reproduce the paper's tables. For concurrent multi-robot serving (N
//! sessions sharing a batched cloud path) use [`super::fleet::Fleet`],
//! which interleaves sessions step-by-step instead of episode-by-episode.

use super::driver::run_episode;
use crate::config::{PolicyKind, SystemConfig};
use crate::metrics::{aggregate, EpisodeMetrics, PolicyRow};
use crate::robot::tasks::ALL_TASKS;
use crate::robot::TaskKind;
use crate::vla::Backend;

/// Results of a suite run for one policy.
pub struct SuiteResult {
    pub policy: PolicyKind,
    pub episodes: Vec<EpisodeMetrics>,
    pub row: PolicyRow,
}

/// Run `episodes` per task for one policy.
pub fn run_policy(
    sys: &SystemConfig,
    kind: PolicyKind,
    tasks: &[TaskKind],
    episodes: usize,
    edge: &mut dyn Backend,
    cloud: &mut dyn Backend,
) -> SuiteResult {
    let mut all = Vec::new();
    for (ti, &task) in tasks.iter().enumerate() {
        for ep in 0..episodes {
            let seed = sys.episode.seed ^ ((ti as u64) << 32) ^ (ep as u64) ^ ((kind as u64) << 16);
            let strategy = crate::policy::build(kind, sys);
            let out = run_episode(sys, task, strategy, edge, cloud, seed, false);
            all.push(out.metrics);
        }
    }
    let row = aggregate(kind, &all);
    SuiteResult { policy: kind, episodes: all, row }
}

/// Run the full suite over several policies.
pub fn run_suite(
    sys: &SystemConfig,
    kinds: &[PolicyKind],
    episodes: usize,
    edge: &mut dyn Backend,
    cloud: &mut dyn Backend,
) -> Vec<SuiteResult> {
    kinds
        .iter()
        .map(|&k| run_policy(sys, k, &ALL_TASKS, episodes, edge, cloud))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vla::AnalyticBackend;

    #[test]
    fn suite_orders_policies_as_the_paper() {
        let mut sys = SystemConfig::default();
        sys.episode.seed = 21;
        let mut edge = AnalyticBackend::edge(1);
        let mut cloud = AnalyticBackend::cloud(1);
        let results = run_suite(
            &sys,
            &[
                PolicyKind::EdgeOnly,
                PolicyKind::CloudOnly,
                PolicyKind::VisionBased,
                PolicyKind::Rapid,
            ],
            2,
            &mut edge,
            &mut cloud,
        );
        let total = |k: PolicyKind| {
            results.iter().find(|r| r.policy == k).unwrap().row.total_lat_mean
        };
        let edge_t = total(PolicyKind::EdgeOnly);
        let cloud_t = total(PolicyKind::CloudOnly);
        let vision_t = total(PolicyKind::VisionBased);
        let rapid_t = total(PolicyKind::Rapid);
        // paper ordering: Cloud < RAPID < Vision < Edge
        assert!(cloud_t < rapid_t, "cloud {cloud_t} rapid {rapid_t}");
        assert!(rapid_t < vision_t, "rapid {rapid_t} vision {vision_t}");
        assert!(vision_t < edge_t, "vision {vision_t} edge {edge_t}");
    }

    #[test]
    fn per_episode_counts() {
        let sys = SystemConfig::default();
        let mut edge = AnalyticBackend::edge(2);
        let mut cloud = AnalyticBackend::cloud(2);
        let r = run_policy(&sys, PolicyKind::Rapid, &ALL_TASKS, 2, &mut edge, &mut cloud);
        assert_eq!(r.episodes.len(), 6);
        assert_eq!(r.row.episodes, 6);
    }
}

//! Request router over multiple cloud workers: least-outstanding with
//! round-robin tie-break (the standard serving-router policy, scaled to
//! this repo's single-host deployment).

/// Tracks outstanding work per worker and picks targets.
#[derive(Debug, Clone)]
pub struct Router {
    outstanding: Vec<u64>,
    totals: Vec<u64>,
    rr: usize,
    pub dispatched: u64,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router { outstanding: vec![0; workers], totals: vec![0; workers], rr: 0, dispatched: 0 }
    }

    pub fn workers(&self) -> usize {
        self.outstanding.len()
    }

    /// Pick the worker with the fewest outstanding requests (round-robin
    /// over ties) and account the dispatch.
    pub fn pick(&mut self) -> usize {
        let n = self.outstanding.len();
        let min = *self.outstanding.iter().min().unwrap();
        // rotate the starting index so ties spread evenly
        let mut chosen = self.rr % n;
        for off in 0..n {
            let i = (self.rr + off) % n;
            if self.outstanding[i] == min {
                chosen = i;
                break;
            }
        }
        self.rr = (chosen + 1) % n;
        self.outstanding[chosen] += 1;
        self.totals[chosen] += 1;
        self.dispatched += 1;
        chosen
    }

    /// Lifetime dispatches per worker (fleet endpoint-spread reporting).
    pub fn totals(&self) -> &[u64] {
        &self.totals
    }

    /// Mark a request complete on a worker.
    pub fn complete(&mut self, worker: usize) {
        assert!(worker < self.outstanding.len());
        assert!(self.outstanding[worker] > 0, "completing idle worker");
        self.outstanding[worker] -= 1;
    }

    pub fn outstanding(&self, worker: usize) -> u64 {
        self.outstanding[worker]
    }

    /// Max load imbalance across workers.
    pub fn imbalance(&self) -> u64 {
        let max = *self.outstanding.iter().max().unwrap();
        let min = *self.outstanding.iter().min().unwrap();
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spreads_round_robin_when_idle() {
        let mut r = Router::new(3);
        let picks: Vec<usize> = (0..6).map(|_| r.pick()).collect();
        // each worker picked twice
        for w in 0..3 {
            assert_eq!(picks.iter().filter(|&&p| p == w).count(), 2);
        }
        assert_eq!(r.totals(), &[2, 2, 2]);
        assert_eq!(r.dispatched, 6);
    }

    #[test]
    fn prefers_least_loaded() {
        let mut r = Router::new(2);
        let a = r.pick();
        let b = r.pick();
        assert_ne!(a, b);
        r.complete(a);
        // a is now idle, b busy -> next pick must be a
        assert_eq!(r.pick(), a);
    }

    #[test]
    fn imbalance_bounded_under_completion() {
        let mut r = Router::new(4);
        let mut picks = Vec::new();
        for i in 0..100 {
            picks.push(r.pick());
            if i % 2 == 1 {
                let w = picks.remove(0);
                r.complete(w);
            }
        }
        assert!(r.imbalance() <= 1, "imbalance {}", r.imbalance());
    }

    #[test]
    #[should_panic]
    fn completing_idle_worker_panics() {
        let mut r = Router::new(2);
        r.complete(0);
    }
}

//! Request router over multiple cloud workers: least-outstanding with
//! round-robin tie-break (the standard serving-router policy, scaled to
//! this repo's single-host deployment).
//!
//! Compatibility-aware: every worker advertises the model families it
//! serves (all of them by default — a zoo-free fleet never notices).
//! [`Router::pick_compatible`] is [`Router::pick_alive`] restricted to
//! the advertisers of a batch's family; a family no live worker serves
//! yields `None` and the fleet degrades the batch to the edge slice.

use crate::vla::profile::{ModelFamily, N_FAMILIES};

/// Tracks outstanding work per worker and picks targets.
#[derive(Debug, Clone)]
pub struct Router {
    outstanding: Vec<u64>,
    totals: Vec<u64>,
    /// Advertised family support per worker (default: everything).
    supported: Vec<[bool; N_FAMILIES]>,
    /// Relative GPU capacity per worker (default 1.0 = the nominal
    /// device the family catalogs are calibrated on). Reporting input to
    /// the multi-factor planner; never changes pick order.
    capacity: Vec<f64>,
    rr: usize,
    pub dispatched: u64,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router {
            outstanding: vec![0; workers],
            totals: vec![0; workers],
            supported: vec![[true; N_FAMILIES]; workers],
            capacity: vec![1.0; workers],
            rr: 0,
            dispatched: 0,
        }
    }

    pub fn workers(&self) -> usize {
        self.outstanding.len()
    }

    /// Restrict a worker's advertisement to exactly `families`.
    pub fn advertise(&mut self, worker: usize, families: &[ModelFamily]) {
        assert!(worker < self.supported.len());
        let mut mask = [false; N_FAMILIES];
        for f in families {
            mask[f.id() as usize] = true;
        }
        self.supported[worker] = mask;
    }

    /// Does `worker` advertise `family`?
    pub fn supports(&self, worker: usize, family: ModelFamily) -> bool {
        self.supported[worker][family.id() as usize]
    }

    /// [`Router::pick_alive`] among workers that also advertise `family`.
    pub fn pick_compatible(&mut self, alive: &[bool], family: ModelFamily) -> Option<usize> {
        let mask: Vec<bool> = alive
            .iter()
            .enumerate()
            .map(|(w, &a)| a && self.supported[w][family.id() as usize])
            .collect();
        self.pick_alive(&mask)
    }

    /// Pick the worker with the fewest outstanding requests (round-robin
    /// over ties) and account the dispatch.
    pub fn pick(&mut self) -> usize {
        let all = vec![true; self.outstanding.len()];
        self.pick_alive(&all).expect("pick() with no workers")
    }

    /// Failover-aware pick: least-outstanding among workers whose `alive`
    /// flag is set (round-robin over ties, same tie-break order as
    /// [`Router::pick`] — with every flag true the two are identical).
    /// Returns `None` when no worker survives.
    pub fn pick_alive(&mut self, alive: &[bool]) -> Option<usize> {
        let n = self.outstanding.len();
        assert_eq!(alive.len(), n, "alive mask arity");
        let min = self
            .outstanding
            .iter()
            .zip(alive.iter())
            .filter(|(_, &a)| a)
            .map(|(&o, _)| o)
            .min()?;
        // rotate the starting index so ties spread evenly
        let mut chosen = None;
        for off in 0..n {
            let i = (self.rr + off) % n;
            if alive[i] && self.outstanding[i] == min {
                chosen = Some(i);
                break;
            }
        }
        let chosen = chosen?;
        self.rr = (chosen + 1) % n;
        self.outstanding[chosen] += 1;
        self.totals[chosen] += 1;
        self.dispatched += 1;
        Some(chosen)
    }

    /// Lifetime dispatches per worker (fleet endpoint-spread reporting).
    pub fn totals(&self) -> &[u64] {
        &self.totals
    }

    /// Mark a request complete on a worker.
    pub fn complete(&mut self, worker: usize) {
        assert!(worker < self.outstanding.len());
        assert!(self.outstanding[worker] > 0, "completing idle worker");
        self.outstanding[worker] -= 1;
    }

    pub fn outstanding(&self, worker: usize) -> u64 {
        self.outstanding[worker]
    }

    /// Set a worker's relative GPU capacity (multi-factor planner input).
    pub fn set_capacity(&mut self, worker: usize, capacity: f64) {
        assert!(worker < self.capacity.len());
        self.capacity[worker] = capacity.max(1e-6);
    }

    pub fn capacity(&self, worker: usize) -> f64 {
        self.capacity[worker]
    }

    /// Endpoint-state snapshot for the multi-factor planner: the queue
    /// depth and capacity of the least-loaded `alive` worker advertising
    /// `family` — the worker [`Router::pick_compatible`] would target.
    /// Read-only (no accounting, no rr rotation, no mutation): calling
    /// it never perturbs subsequent picks. `None` when the family is
    /// currently unroutable.
    pub fn load_for(&self, alive: &[bool], family: ModelFamily) -> Option<(u64, f64)> {
        assert_eq!(alive.len(), self.outstanding.len(), "alive mask arity");
        let fid = family.id() as usize;
        (0..self.outstanding.len())
            .filter(|&w| alive[w] && self.supported[w][fid])
            .map(|w| (self.outstanding[w], self.capacity[w]))
            .min_by(|a, b| a.0.cmp(&b.0))
    }

    /// Max load imbalance across workers.
    pub fn imbalance(&self) -> u64 {
        let max = *self.outstanding.iter().max().unwrap();
        let min = *self.outstanding.iter().min().unwrap();
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spreads_round_robin_when_idle() {
        let mut r = Router::new(3);
        let picks: Vec<usize> = (0..6).map(|_| r.pick()).collect();
        // each worker picked twice
        for w in 0..3 {
            assert_eq!(picks.iter().filter(|&&p| p == w).count(), 2);
        }
        assert_eq!(r.totals(), &[2, 2, 2]);
        assert_eq!(r.dispatched, 6);
    }

    #[test]
    fn prefers_least_loaded() {
        let mut r = Router::new(2);
        let a = r.pick();
        let b = r.pick();
        assert_ne!(a, b);
        r.complete(a);
        // a is now idle, b busy -> next pick must be a
        assert_eq!(r.pick(), a);
    }

    #[test]
    fn imbalance_bounded_under_completion() {
        let mut r = Router::new(4);
        let mut picks = Vec::new();
        for i in 0..100 {
            picks.push(r.pick());
            if i % 2 == 1 {
                let w = picks.remove(0);
                r.complete(w);
            }
        }
        assert!(r.imbalance() <= 1, "imbalance {}", r.imbalance());
    }

    #[test]
    #[should_panic]
    fn completing_idle_worker_panics() {
        let mut r = Router::new(2);
        r.complete(0);
    }

    #[test]
    fn pick_alive_routes_around_dead_workers() {
        let mut r = Router::new(3);
        let dead_zero = [false, true, true];
        for _ in 0..6 {
            let w = r.pick_alive(&dead_zero).unwrap();
            assert_ne!(w, 0);
        }
        assert_eq!(r.totals()[0], 0);
        assert_eq!(r.totals()[1], 3);
        assert_eq!(r.totals()[2], 3);
        assert!(r.pick_alive(&[false, false, false]).is_none());
    }

    #[test]
    fn pick_alive_all_true_matches_pick_exactly() {
        let mut a = Router::new(4);
        let mut b = Router::new(4);
        let alive = [true; 4];
        for i in 0..50 {
            let wa = a.pick();
            let wb = b.pick_alive(&alive).unwrap();
            assert_eq!(wa, wb, "pick {i}");
            if i % 3 == 0 {
                a.complete(wa);
                b.complete(wb);
            }
        }
    }

    #[test]
    fn pick_compatible_honours_family_advertisements() {
        let mut r = Router::new(3);
        // worker 0 serves only the AR family; 1 and 2 serve everything
        r.advertise(0, &[ModelFamily::OpenVlaAr]);
        assert!(r.supports(0, ModelFamily::OpenVlaAr));
        assert!(!r.supports(0, ModelFamily::Pi0Diffusion));
        assert!(r.supports(1, ModelFamily::Pi0Diffusion));
        let alive = [true, true, true];
        for _ in 0..6 {
            let w = r.pick_compatible(&alive, ModelFamily::Pi0Diffusion).unwrap();
            assert_ne!(w, 0, "non-advertiser picked");
        }
        // AR batches may land anywhere (0 advertises it too)
        assert!(r.pick_compatible(&alive, ModelFamily::OpenVlaAr).is_some());
        // a family only a dead worker serves is unroutable
        let mut r2 = Router::new(2);
        r2.advertise(0, &[ModelFamily::EdgeQuant]);
        r2.advertise(1, &[ModelFamily::Surrogate]);
        assert_eq!(r2.pick_compatible(&[false, true], ModelFamily::EdgeQuant), None);
    }

    #[test]
    fn default_advertisement_makes_pick_compatible_equal_pick_alive() {
        let mut a = Router::new(3);
        let mut b = Router::new(3);
        let alive = [true, false, true];
        for _ in 0..10 {
            assert_eq!(
                a.pick_compatible(&alive, ModelFamily::Pi0Diffusion),
                b.pick_alive(&alive)
            );
        }
    }

    #[test]
    fn load_for_reports_the_least_loaded_advertiser_without_mutating() {
        let mut r = Router::new(3);
        r.advertise(0, &[ModelFamily::OpenVlaAr]);
        r.set_capacity(2, 0.5);
        // load worker 1 twice, worker 2 once
        assert!(r.pick_alive(&[false, true, false]).is_some());
        assert!(r.pick_alive(&[false, true, false]).is_some());
        assert!(r.pick_alive(&[false, false, true]).is_some());
        let alive = [true, true, true];
        // Pi0 advertisers are 1 (depth 2) and 2 (depth 1, cap 0.5)
        assert_eq!(r.load_for(&alive, ModelFamily::Pi0Diffusion), Some((1, 0.5)));
        // AR can also land on idle worker 0 (depth 0, nominal cap)
        assert_eq!(r.load_for(&alive, ModelFamily::OpenVlaAr), Some((0, 1.0)));
        // unroutable family -> None
        let mut r2 = Router::new(2);
        r2.advertise(0, &[ModelFamily::EdgeQuant]);
        r2.advertise(1, &[ModelFamily::EdgeQuant]);
        assert_eq!(r2.load_for(&[true, true], ModelFamily::Surrogate), None);
        // read-only: querying never changed pick state
        let before = r.totals().to_vec();
        let _ = r.load_for(&alive, ModelFamily::Surrogate);
        assert_eq!(r.totals(), &before[..]);
        assert_eq!(r.dispatched, 3);
    }

    #[test]
    fn pick_alive_prefers_least_loaded_survivor() {
        let mut r = Router::new(3);
        // load worker 1 twice, worker 2 once; worker 0 is dead
        assert!(r.pick_alive(&[false, true, false]).is_some());
        assert!(r.pick_alive(&[false, true, false]).is_some());
        assert!(r.pick_alive(&[false, false, true]).is_some());
        // least-loaded survivor is 2 (1 outstanding vs 2)
        assert_eq!(r.pick_alive(&[false, true, true]), Some(2));
    }
}

//! Serving layer: the episode driver (closed control loop over sim +
//! renderer + strategy + models + link + virtual clock), the multi-episode
//! session runner, and the cloud-side batcher/router.

pub mod batcher;
pub mod driver;
pub mod router;
pub mod sensorloop;
pub mod session;

pub use batcher::Batcher;
pub use driver::{run_episode, EpisodeOutput};
pub use sensorloop::{SensorLoop, TriggerFlag};
pub use session::{run_suite, SuiteResult};

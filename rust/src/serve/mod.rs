//! Serving layer: the resumable episode driver (closed control loop over
//! sim + renderer + strategy + models + link + virtual clock), the
//! multi-episode session runner, the cloud-side batcher/router, and the
//! fleet scheduler that multiplexes N robot sessions over a shared cloud
//! path with cross-session request batching.

pub mod batcher;
pub mod driver;
pub mod events;
pub mod fleet;
pub mod router;
pub mod sensorloop;
pub mod session;
pub mod workload;

pub use batcher::Batcher;
pub use driver::{
    run_episode, run_episode_with_cache, CloudRequest, EpisodeOutput, EpisodeState, StepEvent,
};
pub use events::{Event, EventKind, EventQueue};
pub use fleet::{fleet_seed, CloudMode, Fleet, FleetResult, FleetStats};
pub use router::Router;
pub use sensorloop::{SensorLoop, TriggerFlag};
pub use session::{run_suite, SuiteResult};
pub use workload::{ArrivalKind, SessionSpec, WorkloadPlan};

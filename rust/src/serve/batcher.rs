//! Request batcher: accumulates pending requests up to a batch bound,
//! preserving FIFO order. Generic over the request type so the same
//! coalescing/accounting logic serves both sides of the wire:
//!
//! * the cloud server batches [`crate::net::server::Pending`] connection
//!   requests in front of its model-owner thread, and
//! * the fleet scheduler batches `fleet::FleetRequest`s from *different
//!   robot sessions* into one cross-session wire frame.
//!
//! The surrogate executes B=1 per call, so a batch is drained
//! sequentially; batching still amortizes queue wake-ups and wire frames,
//! and gives both the server and the fleet their backpressure boundary.
//!
//! # Family-keyed batching (model zoo)
//!
//! The batcher itself is family-agnostic; the *fleet scheduler* keys its
//! batches on the model family: when a request of a different
//! [`crate::vla::ModelFamily`] arrives, the pending batch is sealed and
//! flushed first (`FleetStats::family_flushes`), so a flushed batch is
//! family-uniform **by construction** — different families have different
//! frame layouts (chunk lengths, payload shapes) and must never share a
//! wire batch. Sessions are assigned families in contiguous blocks
//! precisely so that lockstep same-family offloads stay adjacent in
//! scheduler order and still coalesce across sessions under this rule.
//! Family-uniform batches then ride family-tagged zoo frames
//! (`net::proto::TAG_ZOO_BATCH_INFER`) whose single family byte covers
//! the whole batch; the surrogate family keeps the original untagged
//! frames so a zoo-free fleet's wire traffic is bit-identical to PR 3.
//!
//! # Speculative requests (`[pipeline].speculate`)
//!
//! Speculative dispatches ride the batcher exactly like suspended ones:
//! they occupy an in-flight slot (counting toward `fleet.max_inflight`
//! backpressure), obey the family seal above — a speculative request of a
//! new family still flushes the pending batch, so batches stay
//! family-pure regardless of speculation — and are served by the same
//! family-uniform wire frames. The only difference is downstream of the
//! flush: a speculative request's session never suspended, so the flush
//! resolves the speculation in place instead of resuming the session.

pub struct Batcher<T> {
    buf: Vec<T>,
    max_batch: usize,
    /// Lifetime statistics.
    pub total_batches: u64,
    pub total_requests: u64,
    pub max_observed: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize) -> Self {
        Batcher {
            buf: Vec::new(),
            max_batch: max_batch.max(1),
            total_batches: 0,
            total_requests: 0,
            max_observed: 0,
        }
    }

    pub fn push(&mut self, p: T) {
        self.buf.push(p);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The coalescing bound: `take()` should be called once `len()`
    /// reaches this (the batcher itself never drops requests).
    pub fn is_full(&self) -> bool {
        self.buf.len() >= self.max_batch
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Peek at the pending requests in FIFO order.
    pub fn pending(&self) -> &[T] {
        &self.buf
    }

    /// Take the current batch (FIFO order preserved).
    pub fn take(&mut self) -> Vec<T> {
        self.total_batches += 1;
        self.total_requests += self.buf.len() as u64;
        self.max_observed = self.max_observed.max(self.buf.len());
        std::mem::take(&mut self.buf)
    }

    /// Mean requests per batch so far.
    pub fn mean_batch(&self) -> f64 {
        if self.total_batches == 0 {
            0.0
        } else {
            self.total_requests as f64 / self.total_batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::proto::InferRequest;
    use crate::net::server::Pending;
    use std::sync::mpsc;

    fn pending(instr: u32) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            req: InferRequest { instr, obs: [0.0; crate::D_VIS], proprio: [0.0; crate::D_PROP] },
            reply: tx,
        }
    }

    #[test]
    fn fifo_preserved() {
        let mut b = Batcher::new(8);
        for i in 0..5 {
            b.push(pending(i));
        }
        let batch = b.take();
        let ids: Vec<u32> = batch.iter().map(|p| p.req.instr).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn stats_accumulate() {
        let mut b = Batcher::new(4);
        b.push(pending(0));
        b.push(pending(1));
        b.take();
        b.push(pending(2));
        b.take();
        assert_eq!(b.total_batches, 2);
        assert_eq!(b.total_requests, 3);
        assert_eq!(b.max_observed, 2);
        assert!((b.mean_batch() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn min_batch_is_one() {
        let b: Batcher<Pending> = Batcher::new(0);
        assert_eq!(b.max_batch(), 1);
    }

    #[test]
    fn is_full_tracks_bound() {
        let mut b = Batcher::new(2);
        assert!(!b.is_full());
        b.push(pending(0));
        assert!(!b.is_full());
        b.push(pending(1));
        assert!(b.is_full());
        b.take();
        assert!(!b.is_full());
    }

    #[test]
    fn generic_over_plain_values() {
        let mut b: Batcher<u32> = Batcher::new(3);
        b.push(7);
        b.push(9);
        assert_eq!(b.pending(), &[7, 9]);
        assert_eq!(b.take(), vec![7, 9]);
    }
}

//! Fleet-scale serving: a deterministic **event-driven virtual-time**
//! multi-session scheduler with cross-session cloud batching.
//!
//! The scheduler drives N robot sessions — each with its own partitioning
//! strategy (its own `RapidDispatcher` state), simulator, renderer, link
//! model and virtual clock — over a discrete virtual-time axis of
//! scheduler *rounds*, processed as typed events popped from the
//! [`EventQueue`](super::events::EventQueue) (see `serve::events` for the
//! `(time, class, seq)` ordering contract):
//!
//! * **fault edge** — a round begins: the fault schedule's link windows,
//!   outage edges and zoo replans apply;
//! * **arrival** — a session joins the fleet (the `[workload]` engine's
//!   open-loop arrival plan; the lockstep fleet arrives everyone at 0);
//! * **session ready** — a session advances one control step. A step that
//!   needs the cloud suspends ([`StepEvent::NeedCloud`]) and its prepared
//!   request lands in a shared [`Batcher`]; the scheduler coalesces
//!   offloads from *different* sessions into one wire batch, dispatches
//!   to a cloud endpoint picked by the least-loaded [`Router`], and a
//!   flush resumes each suspended session by scheduling its
//!   *reply-arrival* ready event;
//! * **batch deadline** — a round ends: deadline/drain flush bookkeeping
//!   runs and the next round is scheduled (or the run terminates once
//!   every arrived session departed and no arrival is pending).
//!
//! Flush policy (in priority order):
//! 1. **full** — the batch reached `fleet.max_batch`;
//! 2. **drain** — no session advanced this round (everyone alive is
//!    suspended), so waiting longer cannot grow the batch;
//! 3. **deadline** — the oldest pending request has waited
//!    `fleet.batch_deadline_us` of virtual control time.
//!
//! Backpressure: a session whose offload would push the in-flight count
//! past `fleet.max_inflight` has the dispatch *deferred* — it falls back
//! to its cached chunk / edge slice for that step (the per-session chunk
//! queue keeps the robot fed; see `EpisodeState::poll`).
//!
//! Scale: per-event cost is independent of fleet size. The drain check
//! reads an incrementally maintained departure counter, fault-edge
//! context (link profile + zoo plans) is recorded once per round and
//! adopted lazily per slot via an epoch tag (`sync_slot_context`), and
//! the dead-air jump indexes a sorted arrival list — so event processing
//! is O(batch), not O(n_sessions) (exercised by `rapid bench scale`).
//!
//! # Lockstep degeneracy (the load-bearing invariant)
//!
//! With `[workload]` disabled — or enabled in the all-at-t0 fixed shape —
//! every session's ready event sits at every round, ready events pop in
//! session-index order, and the event schedule replays the historical
//! lockstep `for i in 0..n` round loop **bit-identically**: same PRNG
//! streams, same per-episode trajectories, same flush causes, same fault
//! draws (pinned by `rust/tests/workload_arrivals.rs`). Dynamic arrivals
//! are strictly additive: sessions join at their planned round and leave
//! when their episode budget is spent, while everyone already present
//! keeps stepping.
//!
//! Everything is driven by seeded PRNGs and the deterministic event
//! order, so a fleet run is exactly reproducible — and, because every
//! session owns its model backends and PRNG streams, a fleet session's
//! episode metrics are *identical* to a single-session `run_episode` of
//! the same seed.
//!
//! # Observability (`[trace]`)
//!
//! With `[trace]` enabled the scheduler carries a [`Tracer`] and a
//! [`FlightRecorder`], threaded through the event classes above:
//!
//! * **fault edge** opens one fleet-wide `Outage` span per outage round
//!   (on the scheduler lane, tid = one past the last session);
//! * **session ready** hands the tracer into
//!   [`EpisodeState::poll_traced`](super::driver::EpisodeState), which
//!   lays the in-step stages (`Capture` → `EdgePrefix` → `Wire` →
//!   `CloudCompute`, plus `ReuseProbe`/`ReuseHit` and `SpecDispatch`)
//!   sequentially from the round's base timestamp; an enqueued request
//!   records a flight `Enqueue` event stamped with the queue depth;
//! * **flush** closes each request's `CloudQueue` span (enqueue round →
//!   flush round, tagged with the flush cause), then records `Failover`
//!   spans per failed dispatch attempt, `Reply` spans for in-timeout
//!   delays, and `SpecResolve` spans as speculations confirm, roll back,
//!   or abort — mirrored as flight events so a wedge postmortem replays
//!   the same story;
//! * **batch deadline** records nothing: bookkeeping charges no time.
//!
//! Recording reads values the scheduler computes anyway — zero PRNG
//! draws, zero clock advances — so a traced run is bit-identical to an
//! untraced one and two same-seed traces are byte-identical (pinned by
//! `rust/tests/obs_trace.rs`).

use super::batcher::Batcher;
use super::driver::{CloudRequest, EpisodeState, StepEvent};
use super::events::{EventKind, EventQueue};
use super::router::Router;
use super::workload::{self, WorkloadPlan};
use crate::cache::{CacheStats, ReuseStore};
use crate::config::{FleetConfig, PolicyKind, SystemConfig};
use crate::faults::FaultEngine;
use crate::metrics::{summarize_fleet, EpisodeMetrics, FleetSummary};
use crate::net::link::LinkProfile;
use crate::obs::{FlightKind, FlightRecorder, MetricsRegistry, Stage, Tracer, NO_ENDPOINT};
use crate::net::proto::InferRequest;
use crate::net::CloudClient;
use crate::policy::{planner, FamilyPlan};
use crate::robot::TaskKind;
use crate::runtime::{DeviceClass, N_CLASSES};
use crate::vla::profile::{FamilyProfile, ModelFamily, N_FAMILIES};
use crate::vla::{AnalyticBackend, Backend, ZooBackend};
use std::time::Instant;

/// Stable per-(session, episode) seed derivation. Session 0 / episode 0
/// reproduces the base seed, so fleet session 0 equals the corresponding
/// single-session run byte for byte.
pub fn fleet_seed(base: u64, session: usize, episode: usize) -> u64 {
    base ^ (session as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ((episode as u64) << 20)
}

/// One suspended cloud offload, stamped with its session of origin.
pub struct FleetRequest {
    pub session: usize,
    pub req: CloudRequest,
    /// Scheduler round the request entered the batcher — the base of its
    /// `CloudQueue` span (queue wait = flush round − this).
    pub enqueued_round: u64,
}

/// Where coalesced batches execute.
pub enum CloudMode {
    /// In-process: each request runs on its own session's cloud-grade
    /// backend (the deterministic testbed; used by tests and sweeps).
    Local,
    /// Over TCP: batch frames to one or more `net::CloudServer` endpoints
    /// (the real deployment path of `examples/serve_cluster.rs`).
    Remote(Vec<CloudClient>),
}

/// Scheduler-level statistics for one fleet run.
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetStats {
    pub rounds: u64,
    pub batches: u64,
    pub batched_requests: u64,
    /// Batches containing requests from more than one session.
    pub multi_session_batches: u64,
    pub max_batch_observed: usize,
    /// High-water mark of simultaneously in-flight cloud requests.
    pub max_inflight_observed: usize,
    /// Offloads refused by backpressure (sessions fell back to the edge).
    pub deferred_offloads: u64,
    pub full_flushes: u64,
    pub deadline_flushes: u64,
    pub drain_flushes: u64,
    // --- fault injection / failover (all 0 on the zero-fault path) ---
    /// Dispatches whose reply was lost to an injected drop or a
    /// beyond-timeout delay.
    pub dropped_replies: u64,
    /// Remote RPC failures (crashed/unreachable endpoints; circuit-broken
    /// for the rest of the run).
    pub endpoint_errors: u64,
    /// Batches re-dispatched to another surviving endpoint after a failed
    /// attempt.
    pub failover_redispatches: u64,
    /// Requests that exhausted every endpoint and were served from the
    /// edge slice via `EpisodeState::fail_cloud`.
    pub degraded_requests: u64,
    /// Rounds spent under a full uplink outage (offloads deferred).
    pub outage_rounds: u64,
    // --- model zoo (all 0 with [models] disabled) ---
    /// Partial batches sealed early because a request of a *different*
    /// model family arrived (family-keyed batching).
    pub family_flushes: u64,
    /// Batches observed carrying more than one model family. Must be 0 by
    /// construction; counted (not asserted) so the property suite can pin
    /// it across random interleavings.
    pub mixed_family_batches: u64,
    // --- pipelined execution (all 0 with [pipeline] disabled) ---
    /// Speculative requests that entered the batcher: their sessions kept
    /// stepping on provisional edge chunks instead of suspending, and the
    /// serving flush resolved (or aborted) each one.
    pub spec_requests: u64,
    // --- workload engine (lockstep values with [workload] disabled) ---
    /// Sessions that joined the fleet (one arrival event each).
    pub arrivals: u64,
    /// High-water mark of simultaneously active (arrived, not yet
    /// departed) sessions — n_sessions for lockstep shapes, lower under
    /// staggered arrivals.
    pub max_active_sessions: usize,
    // --- autoscaling control plane (all 0 with [autoscale] disabled) ---
    /// Endpoint slots spawned by the autoscaler under sustained SLO
    /// pressure.
    pub scale_up_events: u64,
    /// Endpoint slots drained by the autoscaler after sustained idleness.
    pub scale_down_events: u64,
    /// Ready polls admission-gated to edge-only serving by the shed
    /// threshold (`autoscale.shed_queue`).
    pub shed_polls: u64,
    /// High-water mark of simultaneously active endpoints (the static
    /// endpoint count with `[autoscale]` disabled).
    pub max_endpoints_observed: usize,
}

/// Per-session outcome: every episode's metrics, in order.
pub struct SessionReport {
    pub session: usize,
    /// Seed of the session's first episode (see [`fleet_seed`]).
    pub seed0: u64,
    /// Model family this session served for its whole run
    /// ([`ModelFamily::Surrogate`] with `[models]` disabled).
    pub family: ModelFamily,
    /// Device class of the robot for its whole run (the implicit
    /// [`DeviceClass::Cloudlet`] no-op with the device zoo disabled).
    pub class: DeviceClass,
    /// Scheduler round the session joined the fleet (0 in lockstep runs).
    pub arrival_round: u64,
    /// Scheduler round the session departed (sealed its last episode).
    pub departure_round: u64,
    pub episodes: Vec<EpisodeMetrics>,
}

/// Fleet totals for one model family. Summed over every family present,
/// these exactly partition the fleet-wide totals — pinned by the
/// differential conformance suite.
#[derive(Debug, Clone, Copy)]
pub struct FamilyTotals {
    pub family: ModelFamily,
    pub sessions: usize,
    pub steps: u64,
    pub cloud_events: u64,
    pub cache_hits: u64,
    pub batches: u64,
    pub batched_requests: u64,
}

/// Fleet totals for one device class — the device-axis mirror of
/// [`FamilyTotals`]. Summed over every class present, these exactly
/// partition the fleet-wide totals (each session belongs to exactly one
/// class), pinned by the device-zoo differential suite.
#[derive(Debug, Clone, Copy)]
pub struct ClassTotals {
    pub class: DeviceClass,
    pub sessions: usize,
    pub steps: u64,
    pub cloud_events: u64,
    pub cache_hits: u64,
}

pub struct FleetResult {
    pub policy: PolicyKind,
    pub task: TaskKind,
    pub sessions: Vec<SessionReport>,
    pub stats: FleetStats,
    /// Batches dispatched per cloud endpoint (router spread).
    pub endpoint_dispatches: Vec<u64>,
    /// Dispatch attempts per (endpoint, family id) — the observable the
    /// compatibility-aware router is pinned on (a non-advertiser's row
    /// stays 0 for that family).
    pub endpoint_family_dispatches: Vec<[u64; N_FAMILIES]>,
    pub mean_batch: f64,
    /// Fleet-shared reuse-store counters (all zero with `[cache]` off).
    pub cache: CacheStats,
    /// Per-family rollup (a single surrogate row with `[models]` off).
    pub families: Vec<FamilyTotals>,
    /// Per-device-class rollup (a single cloudlet row with the device
    /// zoo off).
    pub classes: Vec<ClassTotals>,
    /// Span tracer of the run (`Some` only with `[trace]` enabled).
    pub trace: Option<Tracer>,
    /// Flight recorder of the run (`Some` only with `[trace]` enabled).
    pub flight: Option<FlightRecorder>,
}

impl FleetResult {
    /// Per-session + fleet-aggregate metric rollup.
    pub fn summary(&self) -> FleetSummary {
        let per: Vec<Vec<EpisodeMetrics>> =
            self.sessions.iter().map(|s| s.episodes.clone()).collect();
        summarize_fleet(self.policy, &per)
    }

    pub fn total_cloud_events(&self) -> u64 {
        self.sessions.iter().flat_map(|s| s.episodes.iter()).map(|m| m.cloud_events).sum()
    }

    pub fn total_steps(&self) -> u64 {
        self.sessions.iter().flat_map(|s| s.episodes.iter()).map(|m| m.steps as u64).sum()
    }

    /// Fold the run into a [`MetricsRegistry`]: one counter per
    /// [`FleetStats`] field plus the cache and per-family rollups, and —
    /// when the run was traced — a per-stage latency histogram (µs of
    /// charged virtual time) with a family-keyed variant for mixed-zoo
    /// fleets. This is the single renderer every CLI surface prints
    /// through, so `rapid fleet` / `rapid chaos` / `rapid zoo` can never
    /// drift apart.
    pub fn registry(&self) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        let s = &self.stats;
        r.set("rounds", s.rounds);
        r.set("arrivals", s.arrivals);
        r.set("max_active_sessions", s.max_active_sessions as u64);
        r.set("batches", s.batches);
        r.set("batched_requests", s.batched_requests);
        r.set("multi_session_batches", s.multi_session_batches);
        r.set("max_batch_observed", s.max_batch_observed as u64);
        r.set("max_inflight_observed", s.max_inflight_observed as u64);
        r.set("mean_batch_x1000", (self.mean_batch * 1000.0) as u64);
        r.set("deferred_offloads", s.deferred_offloads);
        r.set("flushes/full", s.full_flushes);
        r.set("flushes/deadline", s.deadline_flushes);
        r.set("flushes/drain", s.drain_flushes);
        r.set("flushes/family", s.family_flushes);
        r.set("mixed_family_batches", s.mixed_family_batches);
        r.set("faults/dropped_replies", s.dropped_replies);
        r.set("faults/endpoint_errors", s.endpoint_errors);
        r.set("faults/failover_redispatches", s.failover_redispatches);
        r.set("faults/degraded_requests", s.degraded_requests);
        r.set("faults/outage_rounds", s.outage_rounds);
        r.set("spec_requests", s.spec_requests);
        r.set("autoscale/scale_up", s.scale_up_events);
        r.set("autoscale/scale_down", s.scale_down_events);
        r.set("autoscale/shed_polls", s.shed_polls);
        r.set("autoscale/max_endpoints", s.max_endpoints_observed as u64);
        r.set("cache/probes", self.cache.probes);
        r.set("cache/hits", self.cache.hits);
        r.set("cache/misses", self.cache.misses);
        r.set("cache/stale", self.cache.stale);
        r.set("cache/admissions", self.cache.admissions);
        r.set("cache/refreshed", self.cache.refreshed);
        r.set("cache/evictions", self.cache.evictions);
        for t in &self.families {
            let f = t.family.name();
            r.set(&format!("family/{f}/sessions"), t.sessions as u64);
            r.set(&format!("family/{f}/steps"), t.steps);
            r.set(&format!("family/{f}/cloud_events"), t.cloud_events);
            r.set(&format!("family/{f}/cache_hits"), t.cache_hits);
            r.set(&format!("family/{f}/batches"), t.batches);
        }
        for t in &self.classes {
            let c = t.class.name();
            r.set(&format!("class/{c}/sessions"), t.sessions as u64);
            r.set(&format!("class/{c}/steps"), t.steps);
            r.set(&format!("class/{c}/cloud_events"), t.cloud_events);
            r.set(&format!("class/{c}/cache_hits"), t.cache_hits);
        }
        if let Some(tr) = &self.trace {
            let multi = self.families.len() > 1;
            for sp in tr.spans() {
                let stage = sp.stage.name();
                r.observe(stage, sp.dur_us as f64);
                if multi {
                    if let Some(fam) = ModelFamily::ALL.get(sp.family as usize) {
                        r.observe(&format!("{stage}/{}", fam.name()), sp.dur_us as f64);
                    }
                }
            }
            r.set("trace/spans", tr.len() as u64);
            r.set("trace/dropped_spans", tr.dropped());
        }
        r
    }
}

enum FlushCause {
    Full,
    Deadline,
    Drain,
    /// A request of a different model family arrived: seal the pending
    /// batch so no wire batch ever mixes frame layouts.
    Family,
}

impl FlushCause {
    /// Stable cause code stamped into flight events and `CloudQueue` span
    /// tags — indexes [`crate::obs::flight::CAUSE_NAMES`].
    fn code(&self) -> u32 {
        match self {
            FlushCause::Full => 0,
            FlushCause::Deadline => 1,
            FlushCause::Drain => 2,
            FlushCause::Family => 3,
        }
    }
}

struct SessionSlot {
    state: EpisodeState,
    edge: Box<dyn Backend>,
    cloud: Box<dyn Backend>,
    /// Zoo family (fixed for the session's whole run).
    family: ModelFamily,
    /// Device class (fixed for the session's whole run; the implicit
    /// cloudlet no-op with the device zoo off).
    class: DeviceClass,
    /// Scheduler round the session joins the fleet.
    arrival: u64,
    /// Set once the arrival event has been processed.
    arrived: bool,
    /// Episodes this session runs before departing (the workload plan's
    /// per-session draw; `fleet.episodes_per_session` in lockstep runs).
    episodes_target: usize,
    /// Round the session sealed its last episode.
    departure: u64,
    episode_idx: usize,
    completed: Vec<EpisodeMetrics>,
    finished: bool,
}

/// The multi-session scheduler. Build with [`Fleet::local`] /
/// [`Fleet::remote`], then [`Fleet::run`].
pub struct Fleet {
    sys: SystemConfig,
    cfg: FleetConfig,
    task: TaskKind,
    kind: PolicyKind,
    base_seed: u64,
    slots: Vec<SessionSlot>,
    batcher: Batcher<FleetRequest>,
    router: Router,
    mode: CloudMode,
    stats: FleetStats,
    /// Scheduler rounds the oldest pending request has waited.
    pending_age: u64,
    /// `batch_deadline_us` converted to whole scheduler rounds.
    deadline_rounds: u64,
    /// Fault-injection engine (disarmed/empty on the zero-fault path).
    engine: FaultEngine,
    /// Fleet-shared reuse cache (None with `[cache]` disabled — the
    /// scheduler is then bit-identical to a cache-free build). Serves both
    /// tiers: sessions probe it before offloading, and cross-session batch
    /// replies are admitted on every flush.
    store: Option<ReuseStore>,
    /// Remote endpoints that errored at the RPC layer: circuit-broken for
    /// the rest of the run (a fresh run reconnects).
    io_dead: Vec<bool>,
    /// Current scheduler round index (0-based), the fault schedule's
    /// time base.
    cur_round: u64,
    /// Model zoo active (`[models] enabled`). Off, every zoo path below is
    /// skipped and the scheduler is bit-identical to the PR 3 scheduler.
    zoo_enabled: bool,
    /// Device-heterogeneity zoo active (`[devices] classes` non-empty).
    /// Off, every class path collapses to the implicit cloudlet no-op and
    /// the scheduler is bit-identical to the class-free build.
    classes_on: bool,
    /// Family of the requests currently pending in the batcher (only
    /// meaningful while it is non-empty).
    pending_family: ModelFamily,
    /// Link condition the current zoo plans were computed under; replans
    /// only happen when it actually changes (the planner is pure, so a
    /// stable link means stable plans).
    planned_link: Option<(f64, f64)>,
    // --- multi-factor placement (`[placement]`; off, the planner runs the
    // single-factor path and every field below is inert) ---
    /// Multi-factor placement active (`[placement] enabled`).
    placement_on: bool,
    /// Effective device budget (class catalog entry + overrides).
    budget: planner::DeviceBudget,
    /// Per-family endpoint-load snapshots the current zoo plans were
    /// computed under (replan key alongside `planned_link`; empty with
    /// placement off).
    planned_loads: Vec<planner::EndpointLoad>,
    // --- autoscaling control plane (`[autoscale]`; off, `ep_active` is
    // all-true and every decision path below is inert) ---
    /// Autoscaler active (`[autoscale] enabled`).
    autoscale_on: bool,
    /// Endpoint slot liveness: the router is pre-allocated at the scale
    /// ceiling and slots toggle here (all true with autoscale off).
    ep_active: Vec<bool>,
    /// Drain floor / spawn ceiling (config values clamped to the router
    /// size).
    as_min: usize,
    as_max: usize,
    /// Consecutive rounds the SLO pressure signal has held.
    pressure_streak: u64,
    /// Consecutive rounds with zero queued and zero outstanding work.
    idle_streak: u64,
    /// No scale decision before this round (cooldown hysteresis).
    cooldown_until: u64,
    family_batches: [u64; N_FAMILIES],
    family_requests: [u64; N_FAMILIES],
    endpoint_family_dispatches: Vec<[u64; N_FAMILIES]>,
    // --- event-loop round state ---
    /// Did any session step (or suspend on the cloud) this round? Reset at
    /// every fault-edge event; read by the round's deadline event (the
    /// drain-flush condition).
    progressed: bool,
    /// Uplink outage in force this round (blocks offload admission and
    /// pending-batch dispatch).
    round_outage: bool,
    /// Arrival events not yet processed (termination guard: the run may
    /// not end while a session is still due).
    pending_arrivals: usize,
    /// Currently active (arrived, not departed) sessions.
    active_sessions: usize,
    /// Departed sessions. The drain check compares this against the slot
    /// count instead of rescanning every slot per deadline event.
    finished_sessions: usize,
    /// Every planned arrival round, sorted ascending. Arrival events pop
    /// in time order, so the first `n - pending_arrivals` entries are
    /// exactly the processed ones and the next entry is the earliest
    /// arrival still due — the dead-air jump reads it in O(1).
    arrival_times: Vec<u64>,
    /// Link-context epoch: bumped at every fault-edge while a fault
    /// schedule is armed. Arrived slots adopt `cur_profile`/`cur_plans`
    /// lazily on their next touch (`sync_slot_context`), making the round
    /// start O(1) instead of O(active sessions).
    link_epoch: u64,
    /// Last `link_epoch` each slot adopted.
    slot_epoch: Vec<u64>,
    /// Link profile in force this round (fault schedule armed only).
    cur_profile: Option<LinkProfile>,
    /// Per-family partition plans under `planned_link`, indexed by family
    /// id (zoo runs under an armed fault schedule only).
    cur_plans: Vec<FamilyPlan>,
    // --- observability (`[trace]`; both None disabled — the scheduler is
    // then bit-identical to a trace-free build) ---
    /// Span tracer: virtual-time spans for every pipeline stage, recorded
    /// from values the scheduler computes anyway (zero PRNG draws, zero
    /// clock advances).
    tracer: Option<Tracer>,
    /// Wedge flight recorder: bounded per-session ring of recent
    /// scheduler events, dumped by the CLI's exit-1 paths.
    flight: Option<FlightRecorder>,
    /// Virtual µs per scheduler round (span time base).
    round_us: f64,
}

impl Fleet {
    /// Fleet over in-process per-session backends (deterministic testbed).
    pub fn local(sys: &SystemConfig, task: TaskKind, kind: PolicyKind) -> Fleet {
        Fleet::build(sys, task, kind, CloudMode::Local)
    }

    /// Fleet whose cloud batches go over TCP to real endpoints.
    pub fn remote(
        sys: &SystemConfig,
        task: TaskKind,
        kind: PolicyKind,
        clients: Vec<CloudClient>,
    ) -> Fleet {
        assert!(!clients.is_empty(), "remote fleet needs at least one endpoint");
        Fleet::build(sys, task, kind, CloudMode::Remote(clients))
    }

    /// Local fleet with an explicit fault engine (tests and chaos runs
    /// that build a [`crate::faults::FaultPlan`] programmatically instead
    /// of through the `[faults]` config section).
    pub fn local_with_faults(
        sys: &SystemConfig,
        task: TaskKind,
        kind: PolicyKind,
        engine: FaultEngine,
    ) -> Fleet {
        let mut f = Fleet::build(sys, task, kind, CloudMode::Local);
        f.engine = engine;
        f
    }

    /// Remote fleet with an explicit fault engine.
    pub fn remote_with_faults(
        sys: &SystemConfig,
        task: TaskKind,
        kind: PolicyKind,
        clients: Vec<CloudClient>,
        engine: FaultEngine,
    ) -> Fleet {
        let mut f = Fleet::remote(sys, task, kind, clients);
        f.engine = engine;
        f
    }

    fn build(sys: &SystemConfig, task: TaskKind, kind: PolicyKind, mode: CloudMode) -> Fleet {
        let cfg = sys.fleet.clone();
        let base_seed = sys.episode.seed;
        let autoscale_on = sys.autoscale.enabled;
        // with autoscale on the router (and every per-endpoint vector) is
        // pre-allocated at the scale ceiling; slots toggle `ep_active`
        // instead of resizing anything mid-run. Remote mode can only
        // scale over endpoints that actually connected.
        let endpoints = match &mode {
            CloudMode::Local if autoscale_on => {
                sys.autoscale.max_endpoints.max(sys.autoscale.min_endpoints).max(1)
            }
            CloudMode::Local => cfg.endpoints.max(1),
            CloudMode::Remote(clients) => clients.len(),
        };
        let as_max = if autoscale_on { sys.autoscale.max_endpoints.clamp(1, endpoints) } else { endpoints };
        let as_min = if autoscale_on { sys.autoscale.min_endpoints.clamp(1, as_max) } else { endpoints };
        let ep_active: Vec<bool> =
            (0..endpoints).map(|e| !autoscale_on || e < as_min).collect();
        let initial_active = ep_active.iter().filter(|&&b| b).count();
        let zoo_enabled = sys.models.enabled;
        // the workload engine compiles the session plan: arrivals, episode
        // counts and families. Disabled, it returns the lockstep plan
        // (everyone at round 0, `[fleet]` episode count, block families) —
        // exactly the shape the pre-workload scheduler hard-coded.
        let plan: WorkloadPlan = workload::plan(sys);
        let n = plan.n_sessions();
        let slots: Vec<SessionSlot> = plan
            .specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let seed = fleet_seed(base_seed, i, 0);
                Fleet::make_slot(sys, task, kind, zoo_enabled, seed, 0, spec)
            })
            .collect();
        let arrival_times = {
            let mut v: Vec<u64> = plan.specs.iter().map(|s| s.arrival_round).collect();
            v.sort_unstable();
            v
        };
        // round duration in µs of virtual control time
        let round_us = (sys.robot.dt * 1e6).max(1.0);
        let mut router = Router::new(endpoints);
        if sys.placement.enabled {
            for e in 0..endpoints {
                router.set_capacity(e, sys.placement.gpu_capacity);
            }
        }
        Fleet {
            sys: sys.clone(),
            task,
            kind,
            base_seed,
            slots,
            batcher: Batcher::new(cfg.max_batch),
            router,
            mode,
            stats: FleetStats { max_endpoints_observed: initial_active, ..Default::default() },
            pending_age: 0,
            deadline_rounds: (cfg.batch_deadline_us as f64 / round_us).ceil() as u64,
            engine: FaultEngine::from_config(&sys.faults, base_seed),
            store: if sys.cache.enabled {
                Some(ReuseStore::from_config(&sys.cache, base_seed))
            } else {
                None
            },
            io_dead: vec![false; endpoints],
            cur_round: 0,
            zoo_enabled,
            classes_on: sys.devices.classes_enabled(),
            pending_family: ModelFamily::Surrogate,
            planned_link: None,
            placement_on: sys.placement.enabled,
            budget: sys.placement.budget(),
            planned_loads: Vec::new(),
            autoscale_on,
            ep_active,
            as_min,
            as_max,
            pressure_streak: 0,
            idle_streak: 0,
            cooldown_until: 0,
            family_batches: [0; N_FAMILIES],
            family_requests: [0; N_FAMILIES],
            endpoint_family_dispatches: vec![[0; N_FAMILIES]; endpoints],
            progressed: false,
            round_outage: false,
            pending_arrivals: n,
            active_sessions: 0,
            finished_sessions: 0,
            arrival_times,
            link_epoch: 0,
            slot_epoch: vec![0; n],
            cur_profile: None,
            cur_plans: Vec::new(),
            tracer: if sys.trace.enabled {
                Some(Tracer::new(sys.trace.max_spans, round_us))
            } else {
                None
            },
            flight: if sys.trace.enabled {
                Some(FlightRecorder::new(n, sys.trace.flight_events))
            } else {
                None
            },
            round_us,
            cfg,
        }
    }

    /// Build one session from its workload spec: its episode state (with
    /// the planner's partition choice installed under the nominal link
    /// when the zoo is on) and its family backends. With the zoo off this
    /// is exactly the PR 3 slot.
    fn make_slot(
        sys: &SystemConfig,
        task: TaskKind,
        kind: PolicyKind,
        zoo: bool,
        seed: u64,
        episode_idx: usize,
        spec: &workload::SessionSpec,
    ) -> SessionSlot {
        let family = spec.family;
        let class = spec.class;
        let mut state = EpisodeState::new(sys, task, crate::policy::build(kind, sys), seed, false);
        // installing the default class is an exact no-op (the driver is
        // born with it), so this never perturbs a zoo-off run
        state.set_device_class(class);
        let (edge, cloud): (Box<dyn Backend>, Box<dyn Backend>) = if zoo {
            state.set_family_plan(Some(Fleet::initial_plan(sys, family, class)));
            (Box::new(ZooBackend::edge(family, seed)), Box::new(ZooBackend::cloud(family, seed)))
        } else {
            (Box::new(AnalyticBackend::edge(seed)), Box::new(AnalyticBackend::cloud(seed)))
        };
        SessionSlot {
            state,
            edge,
            cloud,
            family,
            class,
            arrival: spec.arrival_round,
            arrived: false,
            episodes_target: spec.episodes.max(1),
            departure: 0,
            episode_idx,
            completed: Vec::new(),
            finished: false,
        }
    }

    /// Restrict what `endpoint` advertises (compatibility-aware routing).
    /// Default: every endpoint serves every family.
    pub fn restrict_endpoint(&mut self, endpoint: usize, families: &[ModelFamily]) {
        self.router.advertise(endpoint, families);
    }

    /// Build-time partition plan for a session's (family, class) under
    /// the nominal link: single-factor with both `[placement]` and the
    /// device zoo off (bit-identical to the historical plan); with the
    /// device zoo armed the class supplies the budget and the edge-prefix
    /// compute scale, so a Lite robot provably picks a shallower split.
    fn initial_plan(sys: &SystemConfig, family: ModelFamily, class: DeviceClass) -> FamilyPlan {
        let prof = FamilyProfile::of(family);
        let (bw, rtt) = (sys.link.bw_mbps, sys.link.rtt_ms);
        let classes_on = sys.devices.classes_enabled();
        if !classes_on && !sys.placement.enabled {
            return planner::plan(&prof, bw, rtt);
        }
        let load = if sys.placement.enabled {
            planner::EndpointLoad {
                queue_depth: 0,
                capacity: sys.placement.gpu_capacity,
                queue_weight: sys.placement.queue_weight,
            }
        } else {
            planner::EndpointLoad::NOMINAL
        };
        if classes_on {
            let budget = if sys.placement.enabled {
                sys.placement.budget_for(class)
            } else {
                planner::DeviceBudget::for_class(class)
            };
            return planner::plan_for_class(&prof, class, bw, rtt, budget, load);
        }
        planner::plan_with(&prof, bw, rtt, sys.placement.budget(), load)
    }

    /// Endpoint-state factor for `family` right now: queue depth =
    /// requests pending in the batcher for this family plus the
    /// outstanding count of the least-loaded live advertiser (the
    /// endpoint the router would pick), capacity = that endpoint's.
    /// Falls back to the configured capacity when the family is
    /// currently unroutable (the plan still filters by budget).
    fn endpoint_load(&self, family: ModelFamily) -> planner::EndpointLoad {
        let queue_weight = self.sys.placement.queue_weight;
        let pending = if !self.batcher.is_empty() && self.pending_family == family {
            self.batcher.len() as u64
        } else {
            0
        };
        let alive: Vec<bool> = (0..self.router.workers())
            .map(|e| {
                self.ep_active[e] && !self.io_dead[e] && self.engine.endpoint_up(e, self.cur_round)
            })
            .collect();
        match self.router.load_for(&alive, family) {
            Some((depth, capacity)) => {
                planner::EndpointLoad { queue_depth: depth + pending, capacity, queue_weight }
            }
            None => planner::EndpointLoad {
                queue_depth: pending,
                capacity: self.sys.placement.gpu_capacity,
                queue_weight,
            },
        }
    }

    /// Partition plan for `(family, class)` under the given link — the
    /// one planner entry point every scheduler replan path goes through.
    /// Single-factor with `[placement]` and the device zoo off;
    /// budget-filtered and endpoint-aware with placement on; per-class
    /// (class budget + edge-prefix scale) with the device zoo armed.
    fn plan_family(&self, family: ModelFamily, class: DeviceClass, bw: f64, rtt: f64) -> FamilyPlan {
        let prof = FamilyProfile::of(family);
        if self.classes_on {
            let budget = if self.placement_on {
                self.sys.placement.budget_for(class)
            } else {
                planner::DeviceBudget::for_class(class)
            };
            let load = if self.placement_on {
                self.endpoint_load(family)
            } else {
                planner::EndpointLoad::NOMINAL
            };
            return planner::plan_for_class(&prof, class, bw, rtt, budget, load);
        }
        if !self.placement_on {
            return planner::plan(&prof, bw, rtt);
        }
        planner::plan_with(&prof, bw, rtt, self.budget, self.endpoint_load(family))
    }

    /// Rows in the `cur_plans` table: one per device class with the
    /// device zoo armed, the single historical row otherwise.
    fn plan_rows(&self) -> usize {
        if self.classes_on {
            N_CLASSES
        } else {
            1
        }
    }

    /// Index of `(class, family)` in the `cur_plans` table. With the
    /// device zoo off this ignores the class and reproduces the
    /// historical family-indexed layout exactly.
    fn plan_idx(&self, class: DeviceClass, family: ModelFamily) -> usize {
        if self.classes_on {
            class.id() as usize * N_FAMILIES + family.id() as usize
        } else {
            family.id() as usize
        }
    }

    /// Is per-round session context (link profile + zoo plans) being
    /// maintained? True under an armed fault schedule (historical
    /// behavior) and under endpoint-aware placement, whose plans follow
    /// the queue state round to round.
    fn ctx_armed(&self) -> bool {
        !self.engine.is_empty() || (self.placement_on && self.zoo_enabled)
    }

    /// One deterministic autoscale decision per round, at round start. A
    /// pure function of scheduler counters — queued cloud requests,
    /// router outstanding, active endpoint count — with zero PRNG draws
    /// and zero clock advances, so a scaled run replays bit-identically
    /// under the same seed.
    ///
    /// * **scale up** when the backlog has exceeded `slo_queue × active`
    ///   for `sustain_rounds` consecutive rounds: activate the
    ///   lowest-index inactive slot (with `family_pools` on in a zoo
    ///   fleet, it advertises only the family whose backlog tripped the
    ///   signal);
    /// * **scale down** when queue and outstanding have been zero for
    ///   `idle_rounds` consecutive rounds: drain the highest-index active
    ///   slot above the `min_endpoints` floor (LIFO), and only one with
    ///   no outstanding work;
    /// * after either decision, `cooldown_rounds` of hysteresis freeze
    ///   the streak counters so scale events cannot oscillate.
    fn autoscale_tick(&mut self, round: u64) {
        if !self.autoscale_on {
            return;
        }
        if round < self.cooldown_until {
            return;
        }
        let active = self.ep_active.iter().filter(|&&b| b).count();
        let backlog = self.batcher.len();
        let outstanding: u64 =
            (0..self.router.workers()).map(|e| self.router.outstanding(e)).sum();
        let a = &self.sys.autoscale;
        let (slo_queue, sustain, idle_need) = (a.slo_queue, a.sustain_rounds, a.idle_rounds);
        let cooldown = a.cooldown_rounds;
        let family_pools = a.family_pools;
        let pressured = backlog > slo_queue * active;
        if pressured {
            self.pressure_streak += 1;
            self.idle_streak = 0;
        } else if backlog == 0 && outstanding == 0 {
            self.idle_streak += 1;
            self.pressure_streak = 0;
        } else {
            self.pressure_streak = 0;
            self.idle_streak = 0;
        }
        if pressured && self.pressure_streak >= sustain.max(1) && active < self.as_max {
            let Some(e) = self.ep_active.iter().position(|&b| !b) else { return };
            self.ep_active[e] = true;
            if family_pools && self.zoo_enabled {
                // per-family pool: the spawned endpoint serves only the
                // family whose backlog tripped the SLO signal (pressure
                // implies a non-empty batcher, so `pending_family` is the
                // backlog's family)
                self.router.advertise(e, &[self.pending_family]);
            }
            self.stats.scale_up_events += 1;
            self.stats.max_endpoints_observed =
                self.stats.max_endpoints_observed.max(active + 1);
            if let Some(fl) = self.flight.as_mut() {
                fl.record_fleet(round, FlightKind::ScaleUp, e as u32, (active + 1) as u32);
            }
            self.pressure_streak = 0;
            self.cooldown_until = round + cooldown;
        } else if self.idle_streak >= idle_need.max(1) && active > self.as_min {
            // LIFO drain: the newest spawned slot goes first, and only
            // with zero outstanding work (an idle streak implies that,
            // but the guard keeps the invariant local)
            let Some(e) = (0..self.ep_active.len()).rev().find(|&e| self.ep_active[e]) else {
                return;
            };
            if self.router.outstanding(e) > 0 {
                return;
            }
            self.ep_active[e] = false;
            self.stats.scale_down_events += 1;
            if let Some(fl) = self.flight.as_mut() {
                fl.record_fleet(round, FlightKind::ScaleDown, e as u32, (active - 1) as u32);
            }
            self.idle_streak = 0;
            self.cooldown_until = round + cooldown;
        }
    }

    /// Effective link condition at the current round (a fault window's
    /// degraded profile, or the nominal config).
    fn effective_link(&self) -> (f64, f64) {
        if !self.engine.is_empty() {
            if let Some(p) = self.engine.link_profile(self.cur_round) {
                return (p.bw_mbps, p.rtt_ms);
            }
        }
        (self.sys.link.bw_mbps, self.sys.link.rtt_ms)
    }

    /// The context a session must adopt when it joins the fleet mid-run —
    /// or rolls an episode over — under an active fault schedule: the
    /// link profile in force this round and, for zoo sessions, the
    /// partition plan under the effective link. One definition for both
    /// call sites so the arrival and rollover paths can never drift.
    fn arrival_context(
        &self,
        family: ModelFamily,
        class: DeviceClass,
    ) -> (Option<LinkProfile>, Option<FamilyPlan>) {
        let plan = if self.zoo_enabled {
            let (bw, rtt) = self.effective_link();
            Some(self.plan_family(family, class, bw, rtt))
        } else {
            None
        };
        (self.engine.link_profile(self.cur_round), plan)
    }

    /// Seal the just-finished episode of slot `i`; start its next episode
    /// if any remain. Returns true when a fresh episode started; false
    /// when the session departed the fleet.
    fn advance_episode(&mut self, i: usize) -> bool {
        let next = self.slots[i].episode_idx + 1;
        if let Some(fl) = self.flight.as_mut() {
            let remaining = self.slots[i].episodes_target.saturating_sub(next) as u32;
            fl.record(i, self.cur_round, FlightKind::EpisodeDone, remaining, 0);
        }
        if next >= self.slots[i].episodes_target {
            // departure hook: seal the final episode and leave the fleet
            let metrics = self.slots[i].state.on_fleet_departure(&self.sys);
            self.stats.deferred_offloads += metrics.deferred_offloads;
            self.slots[i].completed.push(metrics);
            self.slots[i].finished = true;
            self.slots[i].departure = self.cur_round;
            self.active_sessions -= 1;
            self.finished_sessions += 1;
            return false;
        }
        let metrics = self.slots[i].state.seal_metrics(&self.sys);
        self.stats.deferred_offloads += metrics.deferred_offloads;
        self.slots[i].completed.push(metrics);
        let seed = fleet_seed(self.base_seed, i, next);
        let family = self.slots[i].family;
        let class = self.slots[i].class;
        let spec = workload::SessionSpec {
            arrival_round: self.slots[i].arrival,
            episodes: self.slots[i].episodes_target,
            family,
            class,
        };
        let fresh =
            Fleet::make_slot(&self.sys, self.task, self.kind, self.zoo_enabled, seed, next, &spec);
        let SessionSlot { mut state, edge, cloud, .. } = fresh;
        // the fresh episode starts mid-round: the arrival hook adopts the
        // link condition in force this round (a new EpisodeState defaults
        // to no profile and a zoo session's plan defaults to the nominal
        // link)
        if self.ctx_armed() {
            let (profile, plan) = self.arrival_context(family, class);
            state.on_fleet_arrival(profile, plan);
        }
        // the rollover hook installed this round's context
        self.slot_epoch[i] = self.link_epoch;
        let slot = &mut self.slots[i];
        slot.episode_idx = next;
        slot.state = state;
        slot.edge = edge;
        slot.cloud = cloud;
        true
    }

    /// Run every session to completion; consumes the scheduler.
    ///
    /// Seeds the event queue with one arrival per session plus the first
    /// fault-edge, then processes events until the batch-deadline event
    /// observes a drained fleet (no active session, no pending arrival,
    /// no pending batch).
    pub fn run(mut self) -> FleetResult {
        // one arrival per session seeds the heap; reserve a bit of slack
        // for the in-flight ready/deadline events on top
        let mut queue = EventQueue::with_capacity(self.slots.len() + 16);
        for (i, slot) in self.slots.iter().enumerate() {
            queue.push(slot.arrival, EventKind::Arrival(i));
        }
        queue.push(0, EventKind::FaultEdge);
        while let Some(ev) = queue.pop() {
            match ev.kind {
                EventKind::FaultEdge => self.on_fault_edge(ev.time, &mut queue),
                EventKind::Arrival(i) => self.on_session_arrival(i, ev.time, &mut queue),
                EventKind::Ready(i) => self.on_session_ready(i, ev.time, &mut queue),
                EventKind::Deadline => {
                    if !self.on_batch_deadline(ev.time, &mut queue) {
                        break;
                    }
                }
            }
        }
        self.harvest()
    }

    /// Round start: apply the fault schedule's edges for this round
    /// (time-varying link conditions apply to every arrived session —
    /// they share the physical network; an uplink outage blocks offload
    /// admission entirely), then schedule the round's deadline event.
    fn on_fault_edge(&mut self, t: u64, queue: &mut EventQueue) {
        self.cur_round = t;
        self.stats.rounds += 1;
        self.progressed = false;
        self.round_outage = false;
        // scale decisions happen at round start, before context capture,
        // so this round's plans already see the new endpoint set
        self.autoscale_tick(t);
        if self.ctx_armed() {
            // O(1) round start: record this round's context and bump the
            // epoch; arrived slots adopt it lazily on their next touch
            // (`sync_slot_context`) instead of an O(active) sweep here.
            // Departed sessions released their link override on the
            // departure hook and are never synced again, so it cannot be
            // re-armed.
            self.cur_profile = self.engine.link_profile(self.cur_round);
            // the planner is a pure function of (family, link, budget,
            // endpoint load), so replans are deterministic and only needed
            // when an input actually changes: a degrade window moves every
            // zoo session to a deeper split, endpoint pressure (placement
            // on) does the same, and the next round under the same
            // conditions reuses the recorded plans
            if self.zoo_enabled {
                let (bw, rtt) = self.effective_link();
                let loads: Vec<planner::EndpointLoad> = if self.placement_on {
                    ModelFamily::ALL.iter().map(|&f| self.endpoint_load(f)).collect()
                } else {
                    Vec::new()
                };
                if self.planned_link != Some((bw, rtt)) || loads != self.planned_loads {
                    self.planned_link = Some((bw, rtt));
                    // (class × family) table with the device zoo armed,
                    // the single historical family row otherwise
                    let mut plans = Vec::with_capacity(self.plan_rows() * N_FAMILIES);
                    for c in 0..self.plan_rows() {
                        let class = DeviceClass::from_id(c as u8).unwrap_or_default();
                        for &f in ModelFamily::ALL.iter() {
                            plans.push(self.plan_family(f, class, bw, rtt));
                        }
                    }
                    self.cur_plans = plans;
                    self.planned_loads = loads;
                }
            }
            self.link_epoch += 1;
            self.round_outage = self.engine.link_out(self.cur_round);
            if self.round_outage {
                self.stats.outage_rounds += 1;
                if let Some(tr) = self.tracer.as_mut() {
                    // one fleet-wide span per outage round on the scheduler
                    // lane (tid = one past the last session), tagged with
                    // the schedule window's length in rounds so a timeline
                    // shows the whole blackout
                    let tag = self
                        .engine
                        .outage_window_at(self.cur_round)
                        .map_or(0, |(s, e)| (e - s).min(u32::MAX as u64) as u32);
                    let lane = self.slots.len() as u32;
                    let ts = tr.base_us(self.cur_round);
                    tr.record(Stage::Outage, ts, self.round_us as u64, lane, 0, NO_ENDPOINT, tag);
                }
            }
        }
        queue.push(t, EventKind::Deadline);
    }

    /// Lazily adopt the current round's link context on slot `i`: the
    /// profile (and zoo plan) recorded at the last fault edge. The
    /// installs are pure, idempotent setters, so deferring them from the
    /// round start to the slot's next touch is observably identical to
    /// the historical eager per-round sweep — every path that reads a
    /// session's link or plan (poll, batch resume, episode seal) syncs
    /// first. No-op while no fault schedule is armed (`link_epoch` then
    /// stays 0 forever).
    fn sync_slot_context(&mut self, i: usize) {
        if self.slot_epoch[i] == self.link_epoch {
            return;
        }
        self.slot_epoch[i] = self.link_epoch;
        let idx = self.plan_idx(self.slots[i].class, self.slots[i].family);
        let slot = &mut self.slots[i];
        slot.state.set_link_profile(self.cur_profile);
        if self.zoo_enabled && !self.cur_plans.is_empty() {
            let plan = self.cur_plans[idx].clone();
            slot.state.set_family_plan(Some(plan));
        }
    }

    /// A session joins the fleet: adopt the link condition in force at
    /// its arrival round and schedule its first ready event (same round;
    /// ready events order by session index behind any earlier arrival).
    fn on_session_arrival(&mut self, i: usize, t: u64, queue: &mut EventQueue) {
        self.slots[i].arrived = true;
        self.pending_arrivals -= 1;
        self.stats.arrivals += 1;
        self.active_sessions += 1;
        self.stats.max_active_sessions = self.stats.max_active_sessions.max(self.active_sessions);
        if self.ctx_armed() {
            let (profile, plan) = self.arrival_context(self.slots[i].family, self.slots[i].class);
            self.slots[i].state.on_fleet_arrival(profile, plan);
        }
        // the arrival hook installed this round's context
        self.slot_epoch[i] = self.link_epoch;
        if let Some(fl) = self.flight.as_mut() {
            fl.record(i, t, FlightKind::Arrival, 0, 0);
        }
        queue.push(t, EventKind::Ready(i));
    }

    /// A session advances one control step (the body of the historical
    /// lockstep `for i in 0..n` iteration, one event per session).
    fn on_session_ready(&mut self, i: usize, t: u64, queue: &mut EventQueue) {
        if self.slots[i].finished || self.slots[i].state.is_awaiting_cloud() {
            return;
        }
        self.sync_slot_context(i);
        if self.slots[i].state.is_done() && !self.advance_episode(i) {
            return;
        }
        // an edge-only plan (placement budget filtered the whole catalog)
        // never offloads: its session serves every step from the resident
        // edge slice via the deferred-offload machinery — a degrade, not
        // a wedge
        let edge_only =
            self.slots[i].state.family_plan().map_or(false, |p| p.is_edge_only());
        // admission shed: past the configured backlog the control plane
        // stops admitting offloads before the queue can wedge (sessions
        // fall back to the edge exactly like backpressure deferrals)
        let shed = self.autoscale_on
            && self.sys.autoscale.shed_queue > 0
            && self.batcher.len() >= self.sys.autoscale.shed_queue;
        if shed {
            self.stats.shed_polls += 1;
            if let Some(fl) = self.flight.as_mut() {
                let qlen = self.batcher.len() as u32;
                fl.record_fleet(self.cur_round, FlightKind::Shed, qlen, i as u32);
            }
        }
        let admit = !self.round_outage
            && !edge_only
            && !shed
            && self.batcher.len() < self.cfg.max_inflight.max(1);
        let round = self.cur_round;
        // the probe runs inside poll, before the admit gate: cache hits
        // keep serving through outage/backpressure windows
        let store = self.store.as_mut();
        let tracer = self.tracer.as_mut();
        let slot = &mut self.slots[i];
        let ev = slot.state.poll_traced(
            &self.sys,
            slot.edge.as_mut(),
            slot.cloud.as_mut(),
            admit,
            store,
            round,
            i,
            tracer,
        );
        match ev {
            StepEvent::Stepped => {
                self.progressed = true;
                queue.push(t + 1, EventKind::Ready(i));
            }
            StepEvent::Done => {
                // episode boundary observed mid-poll: the next ready event
                // advances the episode (or departs the session)
                queue.push(t + 1, EventKind::Ready(i));
            }
            StepEvent::NeedCloud(req) => {
                self.progressed = true;
                let speculative = req.speculative;
                if speculative {
                    self.stats.spec_requests += 1;
                }
                // family-keyed batching: a request of a different family
                // seals the pending batch first, so no wire batch ever
                // mixes frame layouts
                if !self.batcher.is_empty() && self.pending_family != req.family {
                    self.flush(FlushCause::Family, queue, Some(i));
                }
                self.pending_family = req.family;
                self.batcher.push(FleetRequest { session: i, req, enqueued_round: round });
                self.stats.max_inflight_observed =
                    self.stats.max_inflight_observed.max(self.batcher.len());
                if let Some(fl) = self.flight.as_mut() {
                    let qlen = self.batcher.len() as u32;
                    fl.record(i, round, FlightKind::Enqueue, qlen, speculative as u32);
                }
                if self.batcher.is_full() {
                    self.flush(FlushCause::Full, queue, Some(i));
                }
                if speculative {
                    // the session did not suspend — it already executed its
                    // step on the provisional chunk, so it schedules its own
                    // next ready event; the flush that serves the request
                    // only resolves the speculation (and must not push a
                    // second ready for it)
                    queue.push(t + 1, EventKind::Ready(i));
                }
                // non-speculative requests get no self-reschedule: the
                // flush that serves them pushes the reply-arrival ready
            }
        }
    }

    /// Round end: batch-deadline/drain bookkeeping, then either schedule
    /// the next round or terminate (returns false) once the fleet is
    /// drained — no pending batch, no pending arrival, everyone departed.
    fn on_batch_deadline(&mut self, t: u64, queue: &mut EventQueue) -> bool {
        if self.batcher.is_empty() {
            // O(1) drain check: `finished_sessions` is maintained on the
            // departure hook, so no per-event slot rescan is needed
            if self.pending_arrivals == 0 && self.finished_sessions == self.slots.len() {
                return false;
            }
        } else {
            self.pending_age += 1;
            if !self.progressed {
                // everyone alive is suspended: waiting cannot grow the batch
                self.flush(FlushCause::Drain, queue, None);
            } else if self.pending_age > self.deadline_rounds {
                self.flush(FlushCause::Deadline, queue, None);
            }
        }
        // dead air — nobody active, nothing pending, stragglers still due:
        // jump the clock straight to the next arrival instead of ticking
        // empty rounds (a fat-fingered trace round must not become an
        // unbounded spin). Arrival events pop in time order, so indexing
        // the sorted arrival list by the processed count yields the
        // earliest arrival still due in O(1). Un-arrived slots always sit
        // strictly in the future here (their arrival event would have
        // popped before this deadline otherwise), so the jump never goes
        // backwards.
        let next = if self.active_sessions == 0 && self.batcher.is_empty() {
            let done = self.arrival_times.len() - self.pending_arrivals;
            self.arrival_times.get(done).copied().unwrap_or(t + 1).max(t + 1)
        } else {
            t + 1
        };
        queue.push(next, EventKind::FaultEdge);
        true
    }

    /// Final rollup once the event loop terminates.
    fn harvest(self) -> FleetResult {
        let mean_batch = self.batcher.mean_batch();
        let endpoint_dispatches = self.router.totals().to_vec();
        let endpoint_family_dispatches = self.endpoint_family_dispatches.clone();
        let stats = self.stats;
        let cache = self.store.as_ref().map(|s| *s.stats()).unwrap_or_default();
        let family_batches = self.family_batches;
        let family_requests = self.family_requests;
        let base_seed = self.base_seed;
        let trace = self.tracer;
        let flight = self.flight;
        let sessions: Vec<SessionReport> = self
            .slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| SessionReport {
                session: i,
                seed0: fleet_seed(base_seed, i, 0),
                family: s.family,
                class: s.class,
                arrival_round: s.arrival,
                departure_round: s.departure,
                episodes: s.completed,
            })
            .collect();
        // per-family rollup: sums over these rows exactly partition the
        // fleet totals (each session belongs to exactly one family, each
        // batch carries exactly one). Accumulated in one pass over the
        // session reports — indexed by family id, which matches the
        // family's position in `ModelFamily::ALL` — instead of one sweep
        // per family.
        let mut totals: Vec<FamilyTotals> = ModelFamily::ALL
            .iter()
            .map(|&fam| {
                let idx = fam.id() as usize;
                FamilyTotals {
                    family: fam,
                    sessions: 0,
                    steps: 0,
                    cloud_events: 0,
                    cache_hits: 0,
                    batches: family_batches[idx],
                    batched_requests: family_requests[idx],
                }
            })
            .collect();
        for s in &sessions {
            let t = &mut totals[s.family.id() as usize];
            t.sessions += 1;
            for m in &s.episodes {
                t.steps += m.steps as u64;
                t.cloud_events += m.cloud_events;
                t.cache_hits += m.cache_hits;
            }
        }
        let families: Vec<FamilyTotals> =
            totals.into_iter().filter(|t| t.sessions > 0 || t.batches > 0).collect();
        // per-class rollup, same contract on the device axis: sums over
        // these rows exactly partition the fleet totals (each session
        // belongs to exactly one class). A zoo-off fleet yields the
        // single implicit cloudlet row.
        let mut ctotals: Vec<ClassTotals> = DeviceClass::ALL
            .iter()
            .map(|&class| ClassTotals {
                class,
                sessions: 0,
                steps: 0,
                cloud_events: 0,
                cache_hits: 0,
            })
            .collect();
        for s in &sessions {
            let t = &mut ctotals[s.class.id() as usize];
            t.sessions += 1;
            for m in &s.episodes {
                t.steps += m.steps as u64;
                t.cloud_events += m.cloud_events;
                t.cache_hits += m.cache_hits;
            }
        }
        let classes: Vec<ClassTotals> = ctotals.into_iter().filter(|t| t.sessions > 0).collect();
        FleetResult {
            policy: self.kind,
            task: self.task,
            sessions,
            stats,
            endpoint_dispatches,
            endpoint_family_dispatches,
            mean_batch,
            cache,
            families,
            classes,
            trace,
            flight,
        }
    }

    /// Record the `SpecResolve` span + flight event for one resolved (or
    /// aborted) speculation. `outcome`: 1 confirmed (dur 0 — the hidden
    /// round trip was free), 0 rolled back (dur = `rollback_ms`), 2
    /// aborted by a failed offload (dur 0; `endpoint` = [`NO_ENDPOINT`]).
    fn record_spec_resolve(
        &mut self,
        session: usize,
        round: u64,
        fam: ModelFamily,
        endpoint: u32,
        outcome: u32,
    ) {
        if let Some(tr) = self.tracer.as_mut() {
            let ts = tr.base_us(round);
            let dur = if outcome == 0 {
                (self.sys.pipeline.rollback_ms * 1000.0) as u64
            } else {
                0
            };
            tr.record(Stage::SpecResolve, ts, dur, session as u32, fam.id(), endpoint, outcome);
        }
        if let Some(fl) = self.flight.as_mut() {
            fl.record(session, round, FlightKind::SpecResolve, outcome, 0);
        }
    }

    /// Dispatch the pending batch to an endpoint and resume its sessions.
    ///
    /// `after` carries the session index whose ready event triggered a
    /// mid-round flush (full / family seal): a resumed session with a
    /// *larger* index re-enters the current round's schedule (its ready
    /// event at the current time pops behind the in-flight one — exactly
    /// the lockstep `for` loop continuing past `after`), while indices at
    /// or below it wait for the next round. Round-end flushes
    /// (deadline/drain, `after = None`) resume everyone next round.
    fn flush(&mut self, cause: FlushCause, queue: &mut EventQueue, after: Option<usize>) {
        if self.batcher.is_empty() {
            return;
        }
        let batch = self.batcher.take();
        self.pending_age = 0;
        let cause_code = cause.code();
        // resumed sessions read their link profile (transfer timing) and
        // plan below — adopt this round's context first (O(batch); a
        // session suspended across fault edges would otherwise resume
        // under the profile of the round it suspended in)
        for fr in &batch {
            self.sync_slot_context(fr.session);
        }

        let mut ids: Vec<usize> = batch.iter().map(|r| r.session).collect();
        ids.sort_unstable();
        ids.dedup();
        self.stats.batches += 1;
        self.stats.batched_requests += batch.len() as u64;
        self.stats.max_batch_observed = self.stats.max_batch_observed.max(batch.len());
        if ids.len() > 1 {
            self.stats.multi_session_batches += 1;
        }
        match cause {
            FlushCause::Full => self.stats.full_flushes += 1,
            FlushCause::Deadline => self.stats.deadline_flushes += 1,
            FlushCause::Drain => self.stats.drain_flushes += 1,
            FlushCause::Family => self.stats.family_flushes += 1,
        }
        // family accounting: every batch carries exactly one family (the
        // push path seals on change; `mixed_family_batches` counts — not
        // asserts — violations so the property suite can pin them at 0)
        let fam = batch[0].req.family;
        if batch.iter().any(|fr| fr.req.family != fam) {
            self.stats.mixed_family_batches += 1;
        }
        self.family_batches[fam.id() as usize] += 1;
        self.family_requests[fam.id() as usize] += batch.len() as u64;

        // Dispatch with failover: pick the least-loaded surviving endpoint;
        // a lost reply (injected drop, beyond-timeout delay, or a real RPC
        // error) charges the suspended sessions the offload timeout — the
        // edge only learns the reply is lost by waiting it out — excludes
        // that endpoint and re-dispatches; when every endpoint is
        // exhausted (or the uplink is out) the whole batch degrades to the
        // edge slice — so every suspended session resumes, no matter what.
        let round = self.cur_round;
        if let Some(tr) = self.tracer.as_mut() {
            // queue-wait span per request: enqueue round → this flush,
            // tagged with the flush cause
            for fr in &batch {
                let ts = tr.base_us(fr.enqueued_round);
                let dur = tr.base_us(round).saturating_sub(ts);
                let sid = fr.session as u32;
                tr.record(Stage::CloudQueue, ts, dur, sid, fam.id(), NO_ENDPOINT, cause_code);
            }
        }
        if let Some(fl) = self.flight.as_mut() {
            for fr in &batch {
                fl.record(fr.session, round, FlightKind::Flush, cause_code, batch.len() as u32);
            }
        }
        let n_eps = self.router.workers();
        let mut excluded = vec![false; n_eps];
        let max_tries = 1 + self.engine.max_retries;
        let timeout = self.engine.timeout_ms;
        // during a full uplink outage no pending batch may dispatch either
        let outage = !self.engine.is_empty() && self.engine.link_out(round);
        let mut served = false;
        let mut tries = 0;
        let mut timeouts_charged = 0u32;
        while !outage && tries < max_tries && !served {
            let alive: Vec<bool> = (0..n_eps)
                .map(|e| {
                    self.ep_active[e]
                        && !excluded[e]
                        && !self.io_dead[e]
                        && self.engine.endpoint_up(e, round)
                })
                .collect();
            let Some(endpoint) = self.router.pick_compatible(&alive, fam) else { break };
            self.endpoint_family_dispatches[endpoint][fam.id() as usize] += 1;
            tries += 1;
            if tries > 1 {
                self.stats.failover_redispatches += 1;
                if let Some(fl) = self.flight.as_mut() {
                    for fr in &batch {
                        let retry = (tries - 1) as u32;
                        fl.record(fr.session, round, FlightKind::Failover, retry, endpoint as u32);
                    }
                }
            }
            // injected wire faults apply to both transports
            let delay = self.engine.reply_delay_ms(round);
            if self.engine.reply_dropped(round) || delay > self.engine.timeout_ms {
                self.stats.dropped_replies += 1;
                if let Some(tr) = self.tracer.as_mut() {
                    // every suspended session waits out the timeout on the
                    // endpoint that lost the reply (tag = attempt number)
                    let ts = tr.base_us(round);
                    let dur = (timeout * 1000.0) as u64;
                    for fr in &batch {
                        let (sid, ep) = (fr.session as u32, endpoint as u32);
                        tr.record(Stage::Failover, ts, dur, sid, fam.id(), ep, tries as u32);
                    }
                }
                if let Some(fl) = self.flight.as_mut() {
                    for fr in &batch {
                        let ep = endpoint as u32;
                        fl.record(fr.session, round, FlightKind::DropReply, ep, tries as u32);
                    }
                }
                for fr in &batch {
                    // speculative sessions never stalled on this reply
                    if !fr.req.speculative {
                        self.slots[fr.session].state.charge_delay(timeout);
                    }
                }
                timeouts_charged += 1;
                self.router.complete(endpoint);
                excluded[endpoint] = true;
                continue;
            }
            match &mut self.mode {
                CloudMode::Local => {
                    // per-session cloud backends: responses cannot cross
                    // sessions by construction, and each session's model PRNG
                    // stream matches its single-session run exactly
                    for fr in &batch {
                        let t0 = Instant::now();
                        let slot = &mut self.slots[fr.session];
                        let out = slot.cloud.infer(&fr.req.obs, &fr.req.proprio, fr.req.instr);
                        let us = t0.elapsed().as_micros() as f64;
                        // admission on batch flush: the reply enters the
                        // fleet-shared store before any session resumes
                        if let (Some(store), Some(sig)) = (self.store.as_mut(), fr.req.sig) {
                            store.admit(sig, out.clone(), round, fr.session);
                        }
                        if fr.req.speculative {
                            // the session kept stepping: an in-timeout delay
                            // is invisible to it, the reply just resolves the
                            // provisional prefix now
                            let ok = slot.state.resolve_speculation(&self.sys, out, us);
                            let ep = endpoint as u32;
                            self.record_spec_resolve(fr.session, round, fam, ep, ok as u32);
                        } else {
                            if delay > 0.0 {
                                slot.state.charge_delay(delay);
                                if let Some(tr) = self.tracer.as_mut() {
                                    let ts = tr.base_us(round);
                                    let dur = (delay * 1000.0) as u64;
                                    let (sid, ep) = (fr.session as u32, endpoint as u32);
                                    tr.record(Stage::Reply, ts, dur, sid, fam.id(), ep, 0);
                                }
                            }
                            slot.state.complete_cloud(&self.sys, out, us);
                        }
                    }
                    self.router.complete(endpoint);
                    served = true;
                }
                CloudMode::Remote(clients) => {
                    let items: Vec<(u32, InferRequest)> = batch
                        .iter()
                        .map(|fr| {
                            (
                                fr.session as u32,
                                InferRequest {
                                    instr: fr.req.instr as u32,
                                    obs: fr.req.obs,
                                    proprio: fr.req.proprio,
                                },
                            )
                        })
                        .collect();
                    if let Some(tr) = self.tracer.as_mut() {
                        // batch-level wire span on the scheduler lane: the
                        // per-session virtual wire time is traced in the
                        // driver; this marks the RPC itself with the frame
                        // bytes actually sent (dur 0 — wall time would
                        // break byte-identical replay)
                        let bytes = if fam == ModelFamily::Surrogate {
                            crate::net::proto::batch_infer_frame_len(items.len())
                        } else {
                            crate::net::proto::zoo_batch_infer_frame_len(items.len())
                        };
                        let lane = self.slots.len() as u32;
                        let (ts, tag) = (tr.base_us(round), bytes.min(u32::MAX as usize) as u32);
                        tr.record(Stage::Wire, ts, 0, lane, fam.id(), endpoint as u32, tag);
                    }
                    let t0 = Instant::now();
                    // the surrogate family keeps the original batch frames
                    // (bit-identical wire traffic with [models] off); zoo
                    // families ride the family-tagged frames
                    let rpc = if fam == ModelFamily::Surrogate {
                        clients[endpoint].infer_batch(&items)
                    } else {
                        clients[endpoint].infer_batch_zoo(fam, &items)
                    };
                    match rpc {
                        Ok(outs) => {
                            let per_us =
                                t0.elapsed().as_micros() as f64 / items.len().max(1) as f64;
                            // responses are routed back strictly by the
                            // echoed session id
                            for (sid, out) in outs {
                                // the echoed session id identifies the
                                // request uniquely (a session has at most
                                // one outstanding request)
                                let fr = batch.iter().find(|fr| fr.session == sid as usize);
                                // admission on batch flush
                                if let Some(store) = self.store.as_mut() {
                                    if let Some(sig) = fr.and_then(|fr| fr.req.sig) {
                                        store.admit(sig, out.clone(), round, sid as usize);
                                    }
                                }
                                let speculative = fr.map_or(false, |fr| fr.req.speculative);
                                let slot = &mut self.slots[sid as usize];
                                if speculative {
                                    let ok =
                                        slot.state.resolve_speculation(&self.sys, out, per_us);
                                    let (s, ep) = (sid as usize, endpoint as u32);
                                    self.record_spec_resolve(s, round, fam, ep, ok as u32);
                                } else {
                                    if delay > 0.0 {
                                        slot.state.charge_delay(delay);
                                        if let Some(tr) = self.tracer.as_mut() {
                                            let ts = tr.base_us(round);
                                            let dur = (delay * 1000.0) as u64;
                                            let ep = endpoint as u32;
                                            tr.record(Stage::Reply, ts, dur, sid, fam.id(), ep, 0);
                                        }
                                    }
                                    slot.state.complete_cloud(&self.sys, out, per_us);
                                }
                            }
                            self.router.complete(endpoint);
                            served = true;
                        }
                        Err(e) => {
                            // crashed/unreachable endpoint: surface the real
                            // error (misconfiguration must stay debuggable),
                            // wait out the timeout, circuit-break it and
                            // fail over to a survivor
                            eprintln!(
                                "[fleet] endpoint {endpoint} RPC failed ({e}); \
                                 circuit-breaking it for the rest of the run"
                            );
                            self.stats.endpoint_errors += 1;
                            for fr in &batch {
                                if !fr.req.speculative {
                                    self.slots[fr.session].state.charge_delay(timeout);
                                }
                            }
                            timeouts_charged += 1;
                            self.io_dead[endpoint] = true;
                            self.router.complete(endpoint);
                        }
                    }
                }
            }
        }
        if !served {
            self.stats.degraded_requests += batch.len() as u64;
            // every failed attempt above already charged its timeout; if no
            // dispatch was even possible (outage / no live endpoint) the
            // edge still waits one timeout before giving up on the reply
            let final_wait = if timeouts_charged == 0 { timeout } else { 0.0 };
            if let Some(tr) = self.tracer.as_mut() {
                // endpoint-less failover span: the final degraded wait
                // before every session re-serves from its edge slice
                let ts = tr.base_us(round);
                let dur = (final_wait * 1000.0) as u64;
                for fr in &batch {
                    let sid = fr.session as u32;
                    tr.record(Stage::Failover, ts, dur, sid, fam.id(), NO_ENDPOINT, tries as u32);
                }
            }
            if let Some(fl) = self.flight.as_mut() {
                for fr in &batch {
                    if outage {
                        fl.record(fr.session, round, FlightKind::Outage, 0, 0);
                    }
                    let sz = batch.len() as u32;
                    fl.record(fr.session, round, FlightKind::Degraded, cause_code, sz);
                }
            }
            for fr in &batch {
                let slot = &mut self.slots[fr.session];
                if fr.req.speculative {
                    // nothing to re-serve: the provisional chunk already
                    // covered the step, the lost reply just counts
                    slot.state.abort_speculation();
                    self.record_spec_resolve(fr.session, round, fam, NO_ENDPOINT, 2);
                } else {
                    slot.state.fail_cloud(
                        &self.sys,
                        &fr.req,
                        slot.edge.as_mut(),
                        slot.cloud.as_mut(),
                        final_wait,
                    );
                }
            }
        }
        // reply-arrival: every suspended session in the batch resumed
        // above (served or degraded) — schedule its next ready event per
        // the `after` rule so the event order replays the lockstep
        // iteration exactly. Speculative sessions already scheduled their
        // own cadence at dispatch; a second ready here would double-step
        // them.
        for fr in &batch {
            if fr.req.speculative {
                continue;
            }
            let at = match after {
                Some(j) if fr.session > j => self.cur_round,
                _ => self.cur_round + 1,
            };
            queue.push(at, EventKind::Ready(fr.session));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys_with(n: usize, max_batch: usize, max_inflight: usize) -> SystemConfig {
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = n;
        sys.fleet.max_batch = max_batch;
        sys.fleet.max_inflight = max_inflight;
        sys
    }

    #[test]
    fn fleet_seed_anchors_session_zero() {
        assert_eq!(fleet_seed(7, 0, 0), 7);
        assert_ne!(fleet_seed(7, 1, 0), fleet_seed(7, 2, 0));
        assert_ne!(fleet_seed(7, 1, 0), fleet_seed(7, 1, 1));
    }

    #[test]
    fn small_local_fleet_completes() {
        let sys = sys_with(3, 4, 16);
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
        assert_eq!(res.sessions.len(), 3);
        for s in &res.sessions {
            assert_eq!(s.episodes.len(), 1);
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
            assert_eq!(s.arrival_round, 0, "lockstep sessions arrive at t = 0");
            assert!(s.departure_round > 0);
        }
        assert!(res.stats.rounds >= TaskKind::PickPlace.seq_len() as u64);
        assert_eq!(res.stats.arrivals, 3);
        assert_eq!(res.stats.max_active_sessions, 3);
    }

    #[test]
    fn multi_episode_sessions_roll_over() {
        let mut sys = sys_with(2, 4, 16);
        sys.fleet.episodes_per_session = 3;
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::EdgeOnly).run();
        for s in &res.sessions {
            assert_eq!(s.episodes.len(), 3);
            for m in &s.episodes {
                assert_eq!(m.steps, TaskKind::PickPlace.seq_len());
            }
        }
        // edge-only never offloads: no batches at all
        assert_eq!(res.stats.batches, 0);
        assert_eq!(res.total_cloud_events(), 0);
    }

    #[test]
    fn fleet_shared_cache_serves_cross_session_hits() {
        // lockstep CloudOnly: all 8 sessions want the cloud at round 0 with
        // *identical* initial kinematic signatures; the first full batch of
        // 4 flushes (admitting its replies) before sessions 4..8 poll, so
        // they must hit the shared store in that same round
        let mut sys = sys_with(8, 4, 16);
        sys.cache.enabled = true;
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert!(res.cache.hits >= 4, "round-0 cross-session hits: {:?}", res.cache);
        // every offload decision is served exactly once: wire or cache
        let per_session = (TaskKind::PickPlace.seq_len() + crate::CHUNK - 1) / crate::CHUNK;
        let hits: u64 =
            res.sessions.iter().flat_map(|s| s.episodes.iter()).map(|m| m.cache_hits).sum();
        assert_eq!(hits, res.cache.hits, "per-episode and store hit counts agree");
        assert_eq!(
            res.total_cloud_events() + hits,
            (8 * per_session) as u64,
            "wire + cache partition the offload schedule"
        );
        assert_eq!(res.stats.batched_requests, res.total_cloud_events());
        for s in &res.sessions {
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        }
        // the cache-off run of the same fleet pays the wire for everything
        let mut off = sys.clone();
        off.cache.enabled = false;
        let base = Fleet::local(&off, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert_eq!(base.total_cloud_events(), (8 * per_session) as u64);
        assert!(base.cache.is_zero());
    }

    #[test]
    fn disabled_cache_builds_no_store() {
        let sys = sys_with(3, 4, 16);
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
        assert!(res.cache.is_zero());
        let hits: u64 =
            res.sessions.iter().flat_map(|s| s.episodes.iter()).map(|m| m.cache_hits).sum();
        assert_eq!(hits, 0);
    }

    #[test]
    fn zoo_disabled_reports_a_single_surrogate_row() {
        let sys = sys_with(3, 4, 16);
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert_eq!(res.families.len(), 1);
        let t = &res.families[0];
        assert_eq!(t.family, ModelFamily::Surrogate);
        assert_eq!(t.sessions, 3);
        assert_eq!(t.steps, res.total_steps());
        assert_eq!(t.cloud_events, res.total_cloud_events());
        assert_eq!(t.batches, res.stats.batches);
        assert_eq!(res.stats.family_flushes, 0);
        assert_eq!(res.stats.mixed_family_batches, 0);
        for s in &res.sessions {
            assert_eq!(s.family, ModelFamily::Surrogate);
        }
    }

    #[test]
    fn device_zoo_off_reports_a_single_cloudlet_row() {
        let sys = sys_with(3, 4, 16);
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert_eq!(res.classes.len(), 1);
        let t = &res.classes[0];
        assert_eq!(t.class, DeviceClass::Cloudlet);
        assert_eq!(t.sessions, 3);
        assert_eq!(t.steps, res.total_steps());
        assert_eq!(t.cloud_events, res.total_cloud_events());
        for s in &res.sessions {
            assert_eq!(s.class, DeviceClass::Cloudlet);
        }
    }

    #[test]
    fn mixed_class_fleet_rolls_up_by_class_and_partitions_totals() {
        let mut sys = sys_with(6, 4, 16);
        sys.devices.classes = "lite,nx,agx".into();
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
        // blocks assignment: 6 sessions over 3 classes = 2 each
        assert_eq!(res.classes.len(), 3);
        for t in &res.classes {
            assert_eq!(t.sessions, 2, "{:?}", t.class);
        }
        // rollup rows exactly partition the fleet totals
        assert_eq!(res.classes.iter().map(|t| t.steps).sum::<u64>(), res.total_steps());
        assert_eq!(
            res.classes.iter().map(|t| t.cloud_events).sum::<u64>(),
            res.total_cloud_events()
        );
        // every session completed its full episode despite weaker silicon
        for s in &res.sessions {
            assert_eq!(s.episodes.len(), 1);
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        }
    }

    #[test]
    fn zoo_fleet_keys_batches_by_family_and_partitions_totals() {
        let mut sys = sys_with(8, 4, 16);
        sys.models.enabled = true; // default families: openvla, pi0, edgequant
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert_eq!(res.stats.mixed_family_batches, 0, "a batch mixed families");
        assert!(res.families.len() >= 3, "mixed fleet must report every family");
        // same-family session blocks still coalesce across sessions
        assert!(res.stats.multi_session_batches > 0, "{:?}", res.stats);
        // lockstep offload rounds interleave families: the family seal fires
        assert!(res.stats.family_flushes > 0, "{:?}", res.stats);
        // per-family rows exactly partition the fleet totals
        let steps: u64 = res.families.iter().map(|t| t.steps).sum();
        let cloud: u64 = res.families.iter().map(|t| t.cloud_events).sum();
        let batches: u64 = res.families.iter().map(|t| t.batches).sum();
        let reqs: u64 = res.families.iter().map(|t| t.batched_requests).sum();
        assert_eq!(steps, res.total_steps());
        assert_eq!(cloud, res.total_cloud_events());
        assert_eq!(batches, res.stats.batches);
        assert_eq!(reqs, res.stats.batched_requests);
        // every session completed under its own family economics
        for s in &res.sessions {
            assert_eq!(s.episodes.len(), 1);
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        }
    }

    #[test]
    fn incompatible_endpoint_degrades_batches_without_wedging() {
        // single endpoint that advertises only the surrogate: every zoo
        // offload is unroutable and must degrade to the edge slice — no
        // session may wedge in suspend
        let mut sys = sys_with(4, 4, 16);
        sys.models.enabled = true;
        let mut fleet = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly);
        fleet.restrict_endpoint(0, &[ModelFamily::Surrogate]);
        let res = fleet.run();
        assert!(res.stats.degraded_requests > 0);
        assert_eq!(
            res.stats.degraded_requests, res.stats.batched_requests,
            "every batched request must degrade — nothing can dispatch"
        );
        assert_eq!(res.endpoint_dispatches.iter().sum::<u64>(), 0, "router never picked");
        for s in &res.sessions {
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
            assert!(s.episodes[0].failovers > 0);
        }
        // the router never dispatched a zoo family to the non-advertiser
        for fam in [ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            assert_eq!(res.endpoint_family_dispatches[0][fam.id() as usize], 0);
        }
    }

    #[test]
    fn cloud_only_lockstep_coalesces_across_sessions() {
        let sys = sys_with(6, 4, 16);
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        // every session offloads at the same rounds (steps 0, 8, 16, ...):
        // the batcher must see cross-session company
        assert!(res.stats.multi_session_batches > 0, "{:?}", res.stats);
        assert!(res.stats.max_batch_observed >= 2);
        assert!(res.stats.max_batch_observed <= 4);
        let per_session = (TaskKind::PickPlace.seq_len() + crate::CHUNK - 1) / crate::CHUNK;
        assert_eq!(res.total_cloud_events(), (6 * per_session) as u64);
        assert_eq!(res.stats.batched_requests, (6 * per_session) as u64);
    }

    #[test]
    fn staggered_arrivals_join_mid_run_and_complete() {
        // 4 sessions, one joining every 10 rounds: the fleet is genuinely
        // dynamic (max concurrency hit only once the last one joined), and
        // everyone still completes its full episode
        let mut sys = sys_with(4, 4, 16);
        sys.workload.enabled = true;
        sys.workload.arrivals = "fixed".into();
        sys.workload.interarrival_rounds = 10.0;
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert_eq!(res.stats.arrivals, 4);
        for (i, s) in res.sessions.iter().enumerate() {
            assert_eq!(s.arrival_round, (i as u64) * 10);
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
            assert!(s.departure_round >= s.arrival_round);
        }
        // later arrivals depart later (same per-session work, offset start)
        assert!(res.sessions[3].departure_round > res.sessions[0].departure_round);
        // the run must outlive the last arrival by at least one episode
        assert!(res.stats.rounds > 30 + TaskKind::PickPlace.seq_len() as u64 / 2);
    }

    #[test]
    fn dead_air_fast_forwards_to_the_next_arrival() {
        // one session now, one 10_000 rounds later: the scheduler must
        // jump the gap via the sorted arrival list instead of ticking
        // thousands of empty rounds
        let mut sys = sys_with(2, 4, 16);
        sys.workload.enabled = true;
        sys.workload.arrivals = "fixed".into();
        sys.workload.interarrival_rounds = 10_000.0;
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::EdgeOnly).run();
        assert_eq!(res.stats.arrivals, 2);
        assert!(res.stats.rounds < 500, "dead air must be skipped: {}", res.stats.rounds);
        assert_eq!(res.sessions[1].arrival_round, 10_000);
        for s in &res.sessions {
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        }
    }

    #[test]
    fn speculative_fleet_resolves_every_request() {
        // pipeline + speculation on: sessions keep stepping on provisional
        // chunks, every request still flows through the batcher and every
        // speculation is resolved by its serving flush
        let mut sys = sys_with(4, 4, 16);
        sys.pipeline.enabled = true;
        sys.pipeline.speculate = true;
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert!(res.stats.spec_requests > 0);
        let (mut disp, mut conf, mut roll, mut fails) = (0u64, 0u64, 0u64, 0u64);
        for m in res.sessions.iter().flat_map(|s| s.episodes.iter()) {
            assert_eq!(m.steps, TaskKind::PickPlace.seq_len());
            disp += m.spec_dispatches;
            conf += m.spec_confirms;
            roll += m.spec_rollbacks;
            fails += m.failovers;
        }
        assert_eq!(disp, res.stats.spec_requests);
        assert_eq!(conf + roll, disp, "no faults: every speculation resolves via a reply");
        assert_eq!(fails, 0);
        // hiding the round trip must beat the sequential fleet on latency
        let mut base_sys = sys.clone();
        base_sys.pipeline.enabled = false;
        let base = Fleet::local(&base_sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert!(
            res.summary().fleet.total_lat_mean < base.summary().fleet.total_lat_mean,
            "speculative fleet must be cheaper"
        );
    }

    #[test]
    fn dropped_speculative_replies_abort_without_stalling() {
        use crate::faults::FaultPlan;
        // every reply dropped, no retries: each speculation aborts as a
        // failover — and, because the session never waited on the reply,
        // the fleet still beats the sequential fleet that stalls out the
        // timeout on every drop
        let spec_run = |speculate: bool| {
            let mut sys = sys_with(2, 4, 16);
            sys.pipeline.enabled = speculate;
            sys.pipeline.speculate = speculate;
            let plan = FaultPlan::none().drop_replies(0, u64::MAX, 1.0);
            let engine = FaultEngine::new(plan, 3, 250.0, 0);
            Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, engine)
                .run()
        };
        let res = spec_run(true);
        let (mut disp, mut conf, mut roll, mut fails) = (0u64, 0u64, 0u64, 0u64);
        for m in res.sessions.iter().flat_map(|s| s.episodes.iter()) {
            assert_eq!(m.steps, TaskKind::PickPlace.seq_len());
            disp += m.spec_dispatches;
            conf += m.spec_confirms;
            roll += m.spec_rollbacks;
            fails += m.failovers;
        }
        assert!(disp > 0);
        assert_eq!(conf + roll, 0, "every reply dropped: nothing resolves via the wire");
        assert_eq!(fails, disp, "every speculation aborts as a failover");
        let base = spec_run(false);
        assert!(
            res.summary().fleet.total_lat_mean < base.summary().fleet.total_lat_mean,
            "aborted speculation must not pay the reply timeout"
        );
    }

    #[test]
    fn arrival_and_rollover_inside_fault_window_adopt_the_degraded_plan() {
        use crate::faults::FaultPlan;
        // regression: a fault edge that lands between a session's arrival
        // event and its first ready (same-round ordering FaultEdge <
        // Arrival < Ready) must hand the arriving — and any rolling-over —
        // session the window's degraded-link plan, never the nominal one
        let mut sys = sys_with(2, 4, 16);
        sys.models.enabled = true;
        sys.fleet.episodes_per_session = 2;
        let plan = FaultPlan::none().degrade(5, 10_000, 5.0, 80.0);
        let engine = FaultEngine::new(plan, 1, 250.0, 1);
        let mut fleet =
            Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, engine);
        let mut queue = EventQueue::with_capacity(8);

        // round 7 sits inside the degrade window
        fleet.on_fault_edge(7, &mut queue);
        assert!(fleet.link_epoch > 0);
        let deep = planner::plan(&FamilyProfile::of(fleet.slots[0].family), 5.0, 80.0);
        assert!(deep.partition_idx > 0, "the degraded link must move the split deeper");

        // mid-window arrival: the slot must carry the degraded plan at once
        fleet.on_session_arrival(0, 7, &mut queue);
        assert_eq!(fleet.slot_epoch[0], fleet.link_epoch);
        assert_eq!(fleet.slots[0].state.family_plan(), Some(&deep));

        // mid-window episode rollover: the fresh state must as well
        assert!(fleet.advance_episode(0), "episode 2 must start, not depart");
        assert_eq!(fleet.slot_epoch[0], fleet.link_epoch);
        assert_eq!(fleet.slots[0].state.family_plan(), Some(&deep));
    }

    #[test]
    fn autoscale_and_placement_disabled_with_hostile_knobs_are_inert() {
        // the gate contract: enabled = false must be bit-identical no
        // matter how hostile the other knobs are
        let base_sys = sys_with(4, 4, 16);
        let base = Fleet::local(&base_sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
        let mut hostile = sys_with(4, 4, 16);
        hostile.autoscale.enabled = false;
        hostile.autoscale.min_endpoints = 7;
        hostile.autoscale.max_endpoints = 1;
        hostile.autoscale.slo_queue = 0;
        hostile.autoscale.sustain_rounds = 0;
        hostile.autoscale.idle_rounds = 0;
        hostile.autoscale.cooldown_rounds = 0;
        hostile.autoscale.shed_queue = 1;
        hostile.autoscale.family_pools = true;
        hostile.placement.enabled = false;
        hostile.placement.device_class = "lite".into();
        hostile.placement.queue_weight = 99.0;
        hostile.placement.gpu_capacity = 0.01;
        let h = Fleet::local(&hostile, TaskKind::PickPlace, PolicyKind::Rapid).run();
        assert_eq!(format!("{:?}", base.stats), format!("{:?}", h.stats));
        assert_eq!(base.endpoint_dispatches, h.endpoint_dispatches);
        assert_eq!(
            base.summary().fleet.total_lat_mean.to_bits(),
            h.summary().fleet.total_lat_mean.to_bits()
        );
        assert_eq!(base.stats.scale_up_events, 0);
        assert_eq!(h.stats.shed_polls, 0);
    }

    #[test]
    fn autoscaler_scales_up_under_pressure_and_drains_idle_slots() {
        let mut sys = sys_with(8, 16, 32);
        // a deadline window lets a partial batch survive to the next
        // round start, where the scaler reads it as backlog (with an
        // immediate flush every round the queue is empty at every tick)
        sys.fleet.batch_deadline_us = 50_000;
        sys.autoscale.enabled = true;
        sys.autoscale.min_endpoints = 1;
        sys.autoscale.max_endpoints = 3;
        sys.autoscale.slo_queue = 2;
        sys.autoscale.sustain_rounds = 1;
        sys.autoscale.idle_rounds = 1;
        sys.autoscale.cooldown_rounds = 0;
        let run = || Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        let res = run();
        // lockstep offload waves alternate backlog-8 and backlog-0 round
        // starts: the loaded ticks (8 queued > 2 × active) must trip
        // scale-up and the empty ticks between waves must drain
        assert!(res.stats.scale_up_events > 0, "{:?}", res.stats);
        assert!(res.stats.scale_down_events > 0, "{:?}", res.stats);
        assert!(res.stats.max_endpoints_observed > 1);
        assert!(res.stats.max_endpoints_observed <= 3);
        // zero wedges: every session completes its episode in full
        for s in &res.sessions {
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        }
        // spawned endpoints actually served traffic
        assert!(res.endpoint_dispatches.iter().filter(|&&d| d > 0).count() > 1);
        // exact seeded replay: the control plane draws no PRNG and reads
        // only deterministic counters
        let again = run();
        assert_eq!(format!("{:?}", res.stats), format!("{:?}", again.stats));
        assert_eq!(res.endpoint_dispatches, again.endpoint_dispatches);
        assert_eq!(
            res.summary().fleet.total_lat_mean.to_bits(),
            again.summary().fleet.total_lat_mean.to_bits()
        );
    }

    #[test]
    fn shed_gate_defers_offloads_past_the_backlog_threshold() {
        let mut sys = sys_with(8, 16, 32);
        sys.autoscale.enabled = true;
        sys.autoscale.min_endpoints = 2;
        sys.autoscale.max_endpoints = 2;
        sys.autoscale.shed_queue = 2;
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        // with 8 lockstep sessions wanting the cloud and only 2 admitted
        // per wave, the rest must shed to edge-only serving — and still
        // complete
        assert!(res.stats.shed_polls > 0, "{:?}", res.stats);
        assert!(res.stats.deferred_offloads > 0, "{:?}", res.stats);
        for s in &res.sessions {
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        }
        // shed kept the backlog at the threshold: no batch ever exceeded it
        assert!(res.stats.max_inflight_observed <= 2, "{:?}", res.stats);
    }

    #[test]
    fn placement_budget_degrades_over_budget_families_to_edge_only() {
        // the `lite` device class (2 GB) hosts no OpenVLA or Pi0 split:
        // those sessions must degrade to edge-only serving (no offloads,
        // no wedge) while EdgeQuant sessions keep offloading normally
        let mut sys = sys_with(6, 4, 16);
        sys.models.enabled = true;
        sys.placement.enabled = true;
        sys.placement.device_class = "lite".into();
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        for s in &res.sessions {
            assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len(), "session wedged");
        }
        for t in &res.families {
            match t.family {
                ModelFamily::EdgeQuant => {
                    assert!(t.cloud_events > 0, "in-budget family must offload: {t:?}")
                }
                ModelFamily::OpenVlaAr | ModelFamily::Pi0Diffusion => {
                    assert_eq!(t.cloud_events, 0, "over-budget family offloaded: {t:?}")
                }
                ModelFamily::Surrogate => {}
            }
        }
        // deterministic replay of the degrade
        let again = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert_eq!(format!("{:?}", res.stats), format!("{:?}", again.stats));
    }

    #[test]
    fn per_session_episode_draws_govern_departures() {
        let mut sys = sys_with(3, 4, 16);
        sys.workload.enabled = true;
        sys.workload.episodes_min = 1;
        sys.workload.episodes_max = 3;
        sys.workload.seed = 11;
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::EdgeOnly).run();
        let counts: Vec<usize> = res.sessions.iter().map(|s| s.episodes.len()).collect();
        assert!(counts.iter().all(|&c| (1..=3).contains(&c)), "{counts:?}");
        // the plan replays: same seed, same episode counts
        let again = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::EdgeOnly).run();
        let counts2: Vec<usize> = again.sessions.iter().map(|s| s.episodes.len()).collect();
        assert_eq!(counts, counts2);
    }
}

//! Raw kinematic scores from the proprioceptive stream.
//!
//! * Instantaneous joint acceleration via finite difference (Eq. 2) and the
//!   weighted acceleration magnitude score M_acc (Eq. 4).
//! * High-frequency torque variation Δτ and the windowed redundancy state
//!   score M_τ (Eq. 5).
//! * Instantaneous joint velocity norm v_t for the dynamic phase weights.
//!
//! All O(1) per sensor tick, allocation-free (paper §VI-D.2).

use crate::robot::{Jv, SensorFrame};
use crate::util::RingBuf;
use crate::N_JOINTS;

/// Raw per-tick features.
#[derive(Debug, Clone, Copy, Default)]
pub struct KinFeatures {
    /// Weighted acceleration magnitude score M_acc (Eq. 4).
    pub m_acc: f64,
    /// Windowed torque-variation score M_τ (Eq. 5).
    pub m_tau: f64,
    /// Velocity norm v_t = ‖q̇‖₂.
    pub v: f64,
}

/// Stateful extractor: previous frame + the short w_τ window of Eq. 5.
#[derive(Debug, Clone)]
pub struct KinState {
    prev: Option<SensorFrame>,
    dt: f64,
    w_acc: [f64; N_JOINTS],
    w_tau: [f64; N_JOINTS],
    /// |W_τ Δτ|² history over the short moving-average window w_τ.
    tau_var_win: RingBuf<f64>,
}

impl KinState {
    pub fn new(dt: f64, w_acc: [f64; N_JOINTS], w_tau: [f64; N_JOINTS], w_tau_len: usize) -> Self {
        KinState { prev: None, dt, w_acc, w_tau, tau_var_win: RingBuf::new(w_tau_len.max(1)) }
    }

    /// Ingest the next sensor frame; returns features (zero for the first
    /// frame, before a finite difference exists).
    pub fn update(&mut self, f: &SensorFrame) -> KinFeatures {
        let out = match &self.prev {
            None => KinFeatures { m_acc: 0.0, m_tau: 0.0, v: f.dq.norm() },
            Some(p) => {
                // Eq. 2 / Eq. 4
                let ddq = (f.dq - p.dq) * (1.0 / self.dt);
                let m_acc = ddq.weighted_norm(&self.w_acc);
                // Eq. 5: moving average of |W_τ Δτ|²
                let dtau = f.tau - p.tau;
                let wdt = Jv::from_fn(|i| self.w_tau[i] * dtau[i]);
                let mag2 = wdt.dot(&wdt);
                self.tau_var_win.push(mag2);
                let m_tau = self.tau_var_win.iter().sum::<f64>() / self.tau_var_win.len() as f64;
                KinFeatures { m_acc, m_tau, v: f.dq.norm() }
            }
        };
        self.prev = Some(*f);
        out
    }

    pub fn reset(&mut self) {
        self.prev = None;
        self.tau_var_win.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DispatcherConfig;

    fn state() -> KinState {
        let d = DispatcherConfig::default();
        KinState::new(0.05, d.w_acc, d.w_torque, d.w_tau)
    }

    fn frame(step: usize, dq: f64, tau: f64) -> SensorFrame {
        SensorFrame { step, q: Jv::ZERO, dq: Jv::splat(dq), tau: Jv::splat(tau) }
    }

    #[test]
    fn first_frame_zero_scores() {
        let mut s = state();
        let f = s.update(&frame(0, 0.5, 1.0));
        assert_eq!(f.m_acc, 0.0);
        assert_eq!(f.m_tau, 0.0);
        assert!(f.v > 0.0);
    }

    #[test]
    fn constant_motion_zero_accel() {
        let mut s = state();
        s.update(&frame(0, 0.5, 1.0));
        let f = s.update(&frame(1, 0.5, 1.0));
        assert!(f.m_acc < 1e-12);
        assert!(f.m_tau < 1e-12);
    }

    #[test]
    fn velocity_jump_spikes_m_acc() {
        let mut s = state();
        s.update(&frame(0, 0.0, 1.0));
        let f = s.update(&frame(1, 1.0, 1.0));
        // ddq = 1.0/0.05 = 20 rad/s² on every joint
        let expect = Jv::splat(20.0).weighted_norm(&DispatcherConfig::default().w_acc);
        assert!((f.m_acc - expect).abs() < 1e-9);
    }

    #[test]
    fn torque_jump_raises_m_tau_then_decays() {
        let mut s = state();
        s.update(&frame(0, 0.0, 1.0));
        let f_spike = s.update(&frame(1, 0.0, 4.0));
        assert!(f_spike.m_tau > 0.0);
        // hold torque constant: window average decays as the spike ages out
        let mut last = f_spike.m_tau;
        for i in 2..12 {
            let f = s.update(&frame(i, 0.0, 4.0));
            assert!(f.m_tau <= last + 1e-12);
            last = f.m_tau;
        }
        assert!(last < f_spike.m_tau / 2.0);
    }

    #[test]
    fn m_tau_matches_eq5_by_hand() {
        let d = DispatcherConfig::default();
        let mut s = KinState::new(0.05, d.w_acc, d.w_torque, 2);
        s.update(&frame(0, 0.0, 0.0));
        s.update(&frame(1, 0.0, 1.0)); // Δτ = 1 on all joints
        let f = s.update(&frame(2, 0.0, 3.0)); // Δτ = 2
        let e1: f64 = d.w_torque.iter().map(|w| (w * 1.0f64).powi(2)).sum();
        let e2: f64 = d.w_torque.iter().map(|w| (w * 2.0f64).powi(2)).sum();
        assert!((f.m_tau - (e1 + e2) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn reset_clears_history() {
        let mut s = state();
        s.update(&frame(0, 0.0, 0.0));
        s.update(&frame(1, 1.0, 5.0));
        s.reset();
        let f = s.update(&frame(2, 9.0, 9.0));
        assert_eq!(f.m_acc, 0.0);
        assert_eq!(f.m_tau, 0.0);
    }
}

//! Kinematic feature extraction (paper §IV-A.1, §IV-B.1): the
//! environment-agnostic signals RAPID partitions on.

pub mod features;
pub mod window;

pub use features::{KinFeatures, KinState};

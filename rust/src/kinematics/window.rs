//! Paired rolling-statistics windows for the two anomaly scores — a thin,
//! purpose-named wrapper over [`crate::util::RollingStats`] matching the
//! paper's (μ_acc, σ_acc) / (μ_τ, σ_τ) bookkeeping in Algorithm 1 step 2.

use crate::util::RollingStats;

/// Rolling normalization state for one score stream.
#[derive(Debug, Clone)]
pub struct ScoreWindow {
    stats: RollingStats,
    eps: f64,
    /// Minimum samples before z-scores are considered calibrated; before
    /// that the window reports 0 (no trigger during warm-up).
    warmup: usize,
}

impl ScoreWindow {
    pub fn new(window: usize, eps: f64, warmup: usize) -> Self {
        ScoreWindow { stats: RollingStats::new(window), eps, warmup }
    }

    /// Push the raw score and return the normalized anomaly score
    /// M̂ = (M - μ)/(σ + ε), or 0 during warm-up.
    pub fn normalize(&mut self, raw: f64) -> f64 {
        let z =
            if self.stats.len() >= self.warmup { self.stats.zscore(raw, self.eps) } else { 0.0 };
        self.stats.push(raw);
        z
    }

    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    pub fn std(&self) -> f64 {
        self.stats.std()
    }

    pub fn samples(&self) -> usize {
        self.stats.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_suppresses_triggers() {
        let mut w = ScoreWindow::new(16, 1e-6, 4);
        for _ in 0..3 {
            assert_eq!(w.normalize(100.0), 0.0);
        }
        // after warm-up and once the warm-up spikes age out of the window,
        // a fresh spike normalizes high
        for _ in 0..20 {
            w.normalize(1.0);
        }
        assert!(w.normalize(50.0) > 3.0);
    }

    #[test]
    fn steady_stream_z_near_zero() {
        let mut w = ScoreWindow::new(32, 1e-6, 4);
        let mut z_last = f64::NAN;
        for i in 0..100 {
            z_last = w.normalize(2.0 + 0.001 * (i % 3) as f64);
        }
        assert!(z_last.abs() < 2.0);
    }

    #[test]
    fn spike_scales_with_sigma() {
        // the same absolute spike is a bigger anomaly on a quieter stream
        let mut quiet = ScoreWindow::new(64, 1e-6, 4);
        let mut loud = ScoreWindow::new(64, 1e-6, 4);
        let mut r = crate::util::Pcg32::seeded(3);
        for _ in 0..64 {
            quiet.normalize(1.0 + 0.01 * r.normal());
            loud.normalize(1.0 + 0.5 * r.normal());
        }
        assert!(quiet.normalize(3.0) > loud.normalize(3.0));
    }
}

//! Joint-space vector type for the N-DOF manipulator.

use crate::N_JOINTS;
use std::ops::{Add, AddAssign, Index, IndexMut, Mul, Sub};

/// A joint-space vector (positions, velocities, torques, ...).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Jv(pub [f64; N_JOINTS]);

impl Jv {
    pub const ZERO: Jv = Jv([0.0; N_JOINTS]);

    pub fn splat(v: f64) -> Jv {
        Jv([v; N_JOINTS])
    }

    pub fn from_fn(mut f: impl FnMut(usize) -> f64) -> Jv {
        let mut out = [0.0; N_JOINTS];
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        Jv(out)
    }

    pub fn norm(&self) -> f64 {
        self.0.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn dot(&self, other: &Jv) -> f64 {
        self.0.iter().zip(other.0.iter()).map(|(a, b)| a * b).sum()
    }

    /// Element-wise product (used for joint weighting W_a, W_τ).
    pub fn hadamard(&self, other: &Jv) -> Jv {
        Jv::from_fn(|i| self.0[i] * other.0[i])
    }

    /// Weighted L2 norm ‖W x‖₂ with diagonal weights (paper Eq. 4).
    pub fn weighted_norm(&self, w: &[f64; N_JOINTS]) -> f64 {
        self.0
            .iter()
            .zip(w.iter())
            .map(|(x, wi)| {
                let v = wi * x;
                v * v
            })
            .sum::<f64>()
            .sqrt()
    }

    pub fn scale(&self, s: f64) -> Jv {
        Jv::from_fn(|i| self.0[i] * s)
    }

    pub fn clamp(&self, lo: f64, hi: f64) -> Jv {
        Jv::from_fn(|i| self.0[i].clamp(lo, hi))
    }

    pub fn abs_max(&self) -> f64 {
        self.0.iter().fold(0.0f64, |m, x| m.max(x.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.0.iter().all(|x| x.is_finite())
    }

    pub fn as_slice(&self) -> &[f64; N_JOINTS] {
        &self.0
    }
}

impl Add for Jv {
    type Output = Jv;
    fn add(self, rhs: Jv) -> Jv {
        Jv::from_fn(|i| self.0[i] + rhs.0[i])
    }
}

impl AddAssign for Jv {
    fn add_assign(&mut self, rhs: Jv) {
        for i in 0..N_JOINTS {
            self.0[i] += rhs.0[i];
        }
    }
}

impl Sub for Jv {
    type Output = Jv;
    fn sub(self, rhs: Jv) -> Jv {
        Jv::from_fn(|i| self.0[i] - rhs.0[i])
    }
}

impl Mul<f64> for Jv {
    type Output = Jv;
    fn mul(self, s: f64) -> Jv {
        self.scale(s)
    }
}

impl Index<usize> for Jv {
    type Output = f64;
    fn index(&self, i: usize) -> &f64 {
        &self.0[i]
    }
}

impl IndexMut<usize> for Jv {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.0[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Jv::splat(2.0);
        let b = Jv::from_fn(|i| i as f64);
        let c = a + b;
        assert_eq!(c[3], 5.0);
        let d = c - a;
        assert_eq!(d[3], 3.0);
        assert_eq!((a * 0.5)[0], 1.0);
    }

    #[test]
    fn norms() {
        let v = Jv([3.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert!((v.norm() - 5.0).abs() < 1e-12);
        let w = [2.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        assert!((v.weighted_norm(&w) - (36.0f64 + 16.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn weighted_norm_end_joint_sensitivity() {
        // The same disturbance on an end joint must score higher than on a
        // base joint under the paper's W_a weighting.
        let w = crate::config::DispatcherConfig::default().w_acc;
        let mut base = Jv::ZERO;
        base[0] = 1.0;
        let mut end = Jv::ZERO;
        end[6] = 1.0;
        assert!(end.weighted_norm(&w) > base.weighted_norm(&w));
    }

    #[test]
    fn clamp_and_absmax() {
        let v = Jv([-3.0, 0.5, 9.0, 0.0, 0.0, 0.0, 0.0]);
        let c = v.clamp(-1.0, 1.0);
        assert_eq!(c[0], -1.0);
        assert_eq!(c[2], 1.0);
        assert_eq!(v.abs_max(), 9.0);
    }
}

//! The manipulator simulator: integrates commanded actions, runs the
//! rigid-body dynamics, and emits proprioceptive sensor frames — the
//! environment-agnostic signal stream RAPID partitions on.

use super::contact::ContactModel;
use super::dynamics::Dynamics;
use super::tasks::TaskKind;
use super::trajectory::RefTrajectory;
use super::types::Jv;
use crate::config::RobotConfig;
use crate::util::Pcg32;

/// One proprioceptive sample (what the f_sensor loop reads).
#[derive(Debug, Clone, Copy)]
pub struct SensorFrame {
    /// Control step index.
    pub step: usize,
    /// Joint positions (rad).
    pub q: Jv,
    /// Joint velocities (rad/s).
    pub dq: Jv,
    /// Joint torques (N·m) from the joint torque sensors.
    pub tau: Jv,
}

/// Simulated N-DOF manipulator executing one task episode.
#[derive(Debug, Clone)]
pub struct RobotSim {
    pub traj: RefTrajectory,
    dynamics: Dynamics,
    contact: ContactModel,
    cfg: RobotConfig,
    rng: Pcg32,
    q: Jv,
    dq: Jv,
    step: usize,
    /// Cumulative squared tracking error (success metric).
    err_accum: f64,
}

impl RobotSim {
    pub fn new(task: TaskKind, cfg: &RobotConfig, seed: u64) -> Self {
        let start = Jv::ZERO;
        RobotSim {
            traj: RefTrajectory::build(task, start),
            dynamics: Dynamics::new(cfg),
            contact: ContactModel::new(seed ^ 0xC0_11_7A),
            cfg: cfg.clone(),
            rng: Pcg32::new(seed, 0x51_3),
            q: start,
            dq: Jv::ZERO,
            step: 0,
            err_accum: 0.0,
        }
    }

    pub fn step_index(&self) -> usize {
        self.step
    }

    pub fn done(&self) -> bool {
        self.step >= self.traj.len()
    }

    pub fn q(&self) -> Jv {
        self.q
    }

    /// Joint error to the *lookahead* reference target (what the renderer
    /// puts in obs[0:7), before clarity attenuation). The policy plans an
    /// action chunk ahead, so its visual target is ~half a chunk out; this
    /// also gives the tracking loop the gain it needs at reference speed.
    pub fn joint_error(&self) -> Jv {
        self.traj.target(self.step + crate::CHUNK) - self.q
    }

    /// Execute one control step with a commanded action (normalized joint
    /// velocity command in [-1, 1] per joint) and return the sensor frame.
    pub fn apply(&mut self, action: Jv) -> SensorFrame {
        let dt = self.cfg.dt;
        // first-order actuator with slew-rate limiting: track the
        // commanded velocity but never exceed max_accel
        let v_cmd = action.clamp(-1.0, 1.0) * 2.0; // rad/s scale
        let max_dv = self.cfg.max_accel * dt;
        let dq_new = Jv::from_fn(|i| {
            let dv = ((v_cmd[i] - self.dq[i]) * self.cfg.track_gain).clamp(-max_dv, max_dv);
            self.dq[i] + dv
        });
        let ddq = (dq_new - self.dq) * (1.0 / dt);
        self.dq = dq_new;
        self.q += self.dq * dt;

        let tau_ext = self.contact.tau_ext(&self.traj, self.step);
        let tau = self.dynamics.torque(&self.q, &self.dq, &ddq, &tau_ext);
        // torque sensor noise
        let tau_meas = Jv::from_fn(|i| tau[i] + self.rng.normal_ms(0.0, self.cfg.sensor_noise));
        let q_meas =
            Jv::from_fn(|i| self.q[i] + self.rng.normal_ms(0.0, self.cfg.sensor_noise * 0.2));
        let dq_meas = Jv::from_fn(|i| self.dq[i] + self.rng.normal_ms(0.0, self.cfg.sensor_noise));

        let err = self.joint_error().norm();
        self.err_accum += err * err;

        let frame = SensorFrame { step: self.step, q: q_meas, dq: dq_meas, tau: tau_meas };
        self.step += 1;
        frame
    }

    /// RMS tracking error over the episode so far (accuracy proxy).
    pub fn rms_error(&self) -> f64 {
        if self.step == 0 {
            return 0.0;
        }
        (self.err_accum / self.step as f64).sqrt()
    }

    /// Episode "success": final configuration close to the last waypoint
    /// and bounded RMS error (tracking-quality proxy for task success).
    pub fn success(&self) -> bool {
        let final_err = (self.traj.q_ref[self.traj.q_ref.len() - 1] - self.q).norm();
        final_err < 0.3 && self.rms_error() < 0.6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::tasks::ALL_TASKS;

    fn run_tracking(task: TaskKind, seed: u64) -> RobotSim {
        let cfg = RobotConfig::default();
        let mut sim = RobotSim::new(task, &cfg, seed);
        while !sim.done() {
            // ideal tracking controller: act on the joint error directly
            let err = sim.joint_error();
            let a = Jv::from_fn(|i| (err[i] * 2.5).clamp(-1.0, 1.0));
            sim.apply(a);
        }
        sim
    }

    #[test]
    fn ideal_controller_completes_all_tasks() {
        for t in ALL_TASKS {
            let sim = run_tracking(t, 4);
            assert!(sim.success(), "{}: rms {}", t.name(), sim.rms_error());
        }
    }

    #[test]
    fn zero_action_fails_task() {
        let cfg = RobotConfig::default();
        let mut sim = RobotSim::new(TaskKind::PickPlace, &cfg, 5);
        while !sim.done() {
            sim.apply(Jv::ZERO);
        }
        assert!(!sim.success());
    }

    #[test]
    fn torque_spikes_in_interact_phase() {
        let sim_run = |seed| -> (f64, f64) {
            let cfg = RobotConfig::default();
            let mut sim = RobotSim::new(TaskKind::PickPlace, &cfg, seed);
            let mut crit = Vec::new();
            let mut red = Vec::new();
            let mut prev_tau = Jv::ZERO;
            while !sim.done() {
                let step = sim.step_index();
                let err = sim.joint_error();
                let a = Jv::from_fn(|i| (err[i] * 2.5).clamp(-1.0, 1.0));
                let f = sim.apply(a);
                let dtau = (f.tau - prev_tau).norm();
                prev_tau = f.tau;
                if step > 0 {
                    if sim.traj.phase_at(step).is_critical() {
                        crit.push(dtau);
                    } else {
                        red.push(dtau);
                    }
                }
            }
            let m = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            (m(&crit), m(&red))
        };
        let (crit, red) = sim_run(6);
        assert!(crit > 2.0 * red, "Δτ critical {crit} vs redundant {red}");
    }

    #[test]
    fn sensor_frames_finite_and_ordered() {
        let cfg = RobotConfig::default();
        let mut sim = RobotSim::new(TaskKind::DrawerOpen, &cfg, 7);
        let mut last = None;
        while !sim.done() {
            let f = sim.apply(Jv::splat(0.1));
            assert!(f.q.is_finite() && f.dq.is_finite() && f.tau.is_finite());
            if let Some(l) = last {
                assert_eq!(f.step, l + 1);
            }
            last = Some(f.step);
        }
    }

    #[test]
    fn deterministic_episodes() {
        let a = run_tracking(TaskKind::PegInsert, 11);
        let b = run_tracking(TaskKind::PegInsert, 11);
        assert_eq!(a.q().0, b.q().0);
        assert_eq!(a.rms_error(), b.rms_error());
    }
}

//! Task library: the paper's three representative manipulation tasks
//! (§VI-A.2) with the sequence lengths of Table II.
//!
//! Each task is a sequence of waypoint segments annotated with a motion
//! phase. Phases drive (a) the contact model (torque transients only during
//! `Interact`), (b) the renderer's saliency channels, and (c) the ground
//! truth used to score trigger precision.

use super::types::Jv;
use crate::N_JOINTS;

/// Motion phase of a trajectory segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Free-space transit toward the interaction site (high redundancy).
    Approach,
    /// Critical physical interaction: grasp / pull / insert (low redundancy).
    Interact,
    /// Post-interaction transit (high redundancy).
    Retract,
}

impl Phase {
    pub fn is_critical(&self) -> bool {
        matches!(self, Phase::Interact)
    }
}

/// One waypoint segment: move to `target` over `steps` control steps.
#[derive(Debug, Clone)]
pub struct Segment {
    pub target: Jv,
    pub steps: usize,
    pub phase: Phase,
    /// Contact intensity while in this segment (0 in free space).
    pub contact: f64,
}

/// The three paper tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    PickPlace,
    DrawerOpen,
    PegInsert,
}

pub const ALL_TASKS: [TaskKind; 3] =
    [TaskKind::PickPlace, TaskKind::DrawerOpen, TaskKind::PegInsert];

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::PickPlace => "Pick & Place",
            TaskKind::DrawerOpen => "Drawer Opening",
            TaskKind::PegInsert => "Peg Insertion",
        }
    }

    pub fn parse(s: &str) -> Option<TaskKind> {
        match s.to_ascii_lowercase().as_str() {
            "pick" | "pickplace" | "pick_place" => Some(TaskKind::PickPlace),
            "drawer" | "drawer_open" => Some(TaskKind::DrawerOpen),
            "peg" | "peg_insert" => Some(TaskKind::PegInsert),
            _ => None,
        }
    }

    /// Instruction-embedding index fed to the VLA model.
    pub fn instr_id(&self) -> usize {
        match self {
            TaskKind::PickPlace => 1,
            TaskKind::DrawerOpen => 2,
            TaskKind::PegInsert => 3,
        }
    }

    /// Episode length L in control steps (Table II).
    pub fn seq_len(&self) -> usize {
        self.segments().iter().map(|s| s.steps).sum()
    }

    /// Waypoint plan. Targets are joint configurations (radians); the
    /// segment structure produces Table II's critical-action ratios
    /// (~13–19% of steps in `Interact` phases).
    pub fn segments(&self) -> Vec<Segment> {
        // Amplitudes scaled so the reference stays within the actuator
        // authority of an open-loop-chunked policy (tabletop-scale motions).
        let j = |v: [f64; N_JOINTS]| Jv(v) * 0.6;
        let seg = |t: [f64; N_JOINTS], steps: usize, phase: Phase, contact: f64| Segment {
            target: j(t),
            steps,
            phase,
            contact,
        };
        match self {
            // L = 50: approach 20, grasp 5, transfer 14, place 4, retract 7
            TaskKind::PickPlace => vec![
                seg([0.8, 0.5, -0.4, 0.9, 0.2, 0.6, 0.3], 20, Phase::Approach, 0.0),
                seg([0.85, 0.55, -0.42, 0.95, 0.25, 0.7, 0.45], 5, Phase::Interact, 1.0),
                seg([-0.3, 0.3, 0.2, 0.5, -0.2, 0.4, 0.45], 14, Phase::Approach, 0.15),
                seg([-0.35, 0.25, 0.25, 0.45, -0.25, 0.35, 0.1], 4, Phase::Interact, 0.9),
                seg([0.0, 0.0, 0.0, 0.3, 0.0, 0.2, 0.0], 7, Phase::Retract, 0.0),
            ],
            // L = 80: long approach 30, handle grasp 5, pull 6, release 20 + 19
            TaskKind::DrawerOpen => vec![
                seg([0.6, 0.7, -0.5, 1.1, 0.1, 0.8, 0.2], 30, Phase::Approach, 0.0),
                seg([0.62, 0.75, -0.52, 1.15, 0.12, 0.85, 0.4], 5, Phase::Interact, 1.0),
                seg([0.45, 0.6, -0.45, 0.95, 0.1, 0.7, 0.4], 6, Phase::Interact, 0.8),
                seg([0.2, 0.3, -0.2, 0.6, 0.0, 0.4, 0.1], 20, Phase::Retract, 0.0),
                seg([0.0, 0.0, 0.0, 0.3, 0.0, 0.2, 0.0], 19, Phase::Retract, 0.0),
            ],
            // L = 60: approach 22, align 6, insert 5, seat 2, retract 25
            TaskKind::PegInsert => vec![
                seg([0.5, 0.4, -0.3, 0.8, 0.3, 0.5, 0.25], 22, Phase::Approach, 0.0),
                seg([0.52, 0.45, -0.32, 0.85, 0.32, 0.55, 0.3], 6, Phase::Interact, 0.6),
                seg([0.52, 0.5, -0.33, 0.9, 0.33, 0.6, 0.3], 5, Phase::Interact, 1.0),
                seg([0.52, 0.52, -0.33, 0.92, 0.33, 0.62, 0.3], 2, Phase::Interact, 1.2),
                seg([0.0, 0.0, 0.0, 0.3, 0.0, 0.2, 0.0], 25, Phase::Retract, 0.0),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_lengths_match_table_ii() {
        assert_eq!(TaskKind::PickPlace.seq_len(), 50);
        assert_eq!(TaskKind::DrawerOpen.seq_len(), 80);
        assert_eq!(TaskKind::PegInsert.seq_len(), 60);
    }

    #[test]
    fn critical_ratio_in_paper_band() {
        // Table II: critical actions are 13.6% – 18.8% of steps.
        for t in ALL_TASKS {
            let total = t.seq_len() as f64;
            let crit: usize = t
                .segments()
                .iter()
                .filter(|s| s.phase.is_critical())
                .map(|s| s.steps)
                .sum();
            let ratio = crit as f64 / total;
            assert!((0.10..=0.22).contains(&ratio), "{}: {ratio}", t.name());
        }
    }

    #[test]
    fn contact_only_in_interact_phases_mostly() {
        for t in ALL_TASKS {
            for s in t.segments() {
                if s.phase == Phase::Interact {
                    assert!(s.contact > 0.0);
                }
                if s.contact >= 0.5 {
                    assert!(s.phase.is_critical());
                }
            }
        }
    }

    #[test]
    fn instr_ids_distinct() {
        let ids: Vec<usize> = ALL_TASKS.iter().map(|t| t.instr_id()).collect();
        assert_eq!(ids.len(), 3);
        assert!(ids.iter().all(|&i| i < crate::N_INSTR));
        let mut d = ids.clone();
        d.dedup();
        assert_eq!(d.len(), 3);
    }
}

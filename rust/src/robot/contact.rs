//! Contact / external-torque model.
//!
//! During `Interact` segments the end effector experiences external torques:
//! an impact transient at contact onset, sustained interaction force with
//! high-frequency variation (sliding friction, micro-slips), concentrated on
//! the wrist joints. This is the physical signal behind the paper's
//! redundancy-aware trigger (Δτ spikes at low redundancy phases, Fig. 3).

use super::trajectory::RefTrajectory;
use super::types::Jv;
use crate::util::Pcg32;
use crate::N_JOINTS;

/// Distribution of contact load over joints: wrist-dominated.
const CONTACT_DIST: [f64; N_JOINTS] = [0.05, 0.08, 0.12, 0.25, 0.5, 0.85, 1.0];

#[derive(Debug, Clone)]
pub struct ContactModel {
    rng: Pcg32,
    /// Steps since contact onset (None = no contact).
    onset: Option<usize>,
    /// Base torque magnitude (N·m) at contact intensity 1.
    pub magnitude: f64,
}

impl ContactModel {
    pub fn new(seed: u64) -> Self {
        ContactModel { rng: Pcg32::new(seed, 0xC0), onset: None, magnitude: 5.5 }
    }

    /// External torque at step t of the reference trajectory.
    pub fn tau_ext(&mut self, traj: &RefTrajectory, t: usize) -> Jv {
        let intensity = traj.contact_at(t);
        if intensity <= 0.0 {
            self.onset = None;
            return Jv::ZERO;
        }
        let since = match self.onset {
            Some(s0) => t.saturating_sub(s0),
            None => {
                self.onset = Some(t);
                0
            }
        };
        // Impact transient: sharp spike in the first contact steps decaying
        // into the sustained level.
        let impact = if since == 0 { 2.2 } else { 1.0 + 1.2 * (-(since as f64) / 1.5).exp() };
        let sustained = self.magnitude * intensity;
        let mut out = Jv::ZERO;
        for j in 0..N_JOINTS {
            // high-frequency variation: micro-slips and friction chatter
            let chatter = self.rng.normal_ms(0.0, 0.35 * sustained * CONTACT_DIST[j]);
            out[j] = sustained * CONTACT_DIST[j] * impact + chatter;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::tasks::TaskKind;

    fn traj() -> RefTrajectory {
        RefTrajectory::build(TaskKind::PickPlace, Jv::ZERO)
    }

    #[test]
    fn zero_in_free_space() {
        let tr = traj();
        let mut cm = ContactModel::new(1);
        // step 0 is deep in the approach phase
        assert_eq!(cm.tau_ext(&tr, 0).norm(), 0.0);
    }

    #[test]
    fn spike_at_contact_onset() {
        let tr = traj();
        let mut cm = ContactModel::new(2);
        let first_crit = (0..tr.len()).find(|&i| tr.phase[i].is_critical()).unwrap();
        let onset = cm.tau_ext(&tr, first_crit).norm();
        let later = cm.tau_ext(&tr, first_crit + 3).norm();
        assert!(onset > later, "impact {onset} vs sustained {later}");
        assert!(onset > 0.0);
    }

    #[test]
    fn wrist_dominated() {
        let tr = traj();
        let mut cm = ContactModel::new(3);
        let first_crit = (0..tr.len()).find(|&i| tr.phase[i].is_critical()).unwrap();
        let tau = cm.tau_ext(&tr, first_crit);
        assert!(tau[6].abs() > tau[0].abs());
    }

    #[test]
    fn deterministic_for_seed() {
        let tr = traj();
        let mut a = ContactModel::new(9);
        let mut b = ContactModel::new(9);
        let first_crit = (0..tr.len()).find(|&i| tr.phase[i].is_critical()).unwrap();
        for t in first_crit..first_crit + 4 {
            assert_eq!(a.tau_ext(&tr, t).0, b.tau_ext(&tr, t).0);
        }
    }
}

//! Rigid-body N-DOF manipulator simulation substrate.
//!
//! The paper evaluates RAPID on a physical 7-DOF arm and the LIBERO
//! benchmark; this module is the substitute substrate (DESIGN.md §3): a
//! manipulator with simplified rigid-body dynamics
//! `τ = M(q)q̈ + C(q,q̇)q̇ + G(q) + τ_ext` (paper Eq. 3), phase-structured
//! task trajectories (approach → interact → retract) and a contact model
//! producing the torque transients the redundancy-aware trigger keys on.

pub mod contact;
pub mod dynamics;
pub mod sim;
pub mod tasks;
pub mod trajectory;
pub mod types;

pub use sim::{RobotSim, SensorFrame};
pub use tasks::{Phase, TaskKind};
pub use trajectory::RefTrajectory;
pub use types::Jv;

//! Reference trajectory generation: min-jerk interpolation through the task
//! waypoints, plus the ground-truth phase / contact / saliency schedules the
//! renderer and contact model consume.

use super::tasks::{Phase, Segment, TaskKind};
use super::types::Jv;

/// Precomputed reference for one episode.
#[derive(Debug, Clone)]
pub struct RefTrajectory {
    /// Reference joint positions per control step (len = L + 1).
    pub q_ref: Vec<Jv>,
    /// Phase per step (len = L).
    pub phase: Vec<Phase>,
    /// Contact intensity per step (len = L).
    pub contact: Vec<f64>,
    /// Interaction saliency per step in [0, 1] — geometric
    /// proximity-to-contact profile (len = L).
    pub saliency: Vec<f64>,
    pub task: TaskKind,
}

/// Min-jerk scalar profile s(u) with s(0)=0, s(1)=1, zero vel/acc at ends.
pub fn min_jerk(u: f64) -> f64 {
    let u = u.clamp(0.0, 1.0);
    u * u * u * (10.0 - 15.0 * u + 6.0 * u * u)
}

impl RefTrajectory {
    pub fn build(task: TaskKind, start: Jv) -> RefTrajectory {
        let segments = task.segments();
        let total: usize = segments.iter().map(|s| s.steps).sum();
        let mut q_ref = Vec::with_capacity(total + 1);
        let mut phase = Vec::with_capacity(total);
        let mut contact = Vec::with_capacity(total);
        q_ref.push(start);
        let mut from = start;
        for seg in &segments {
            for s in 1..=seg.steps {
                let u = min_jerk(s as f64 / seg.steps as f64);
                q_ref.push(from + (seg.target - from) * u);
                phase.push(seg.phase);
                contact.push(seg.contact);
            }
            from = seg.target;
        }
        let saliency = Self::saliency_profile(&segments);
        RefTrajectory { q_ref, phase, contact, saliency, task }
    }

    /// Saliency ramps up approaching an `Interact` segment (the policy
    /// anticipates contact from the scene geometry), saturates during the
    /// interaction, and decays afterwards.
    fn saliency_profile(segments: &[Segment]) -> Vec<f64> {
        let total: usize = segments.iter().map(|s| s.steps).sum();
        // per-step base: contact intensity of the segment (clamped to 1)
        let mut base = Vec::with_capacity(total);
        for seg in segments {
            for _ in 0..seg.steps {
                base.push(if seg.phase.is_critical() {
                    seg.contact.clamp(0.6, 1.0)
                } else {
                    0.0f64
                });
            }
        }
        // anticipation ramp: look ahead up to `ramp` steps (kept short so
        // the redundancy statistics match Table II's ~80/20 split)
        let ramp = 3usize;
        let mut sal = vec![0.0f64; total];
        for t in 0..total {
            let mut v: f64 = base[t];
            for d in 1..=ramp {
                if t + d < total && base[t + d] > 0.0 {
                    v = v.max(base[t + d] * (1.0 - d as f64 / (ramp + 1) as f64));
                }
            }
            // residual decay after contact
            if v == 0.0 && t > 0 {
                v = (sal[t - 1] - 0.4).max(0.04);
            }
            sal[t] = v.clamp(0.0, 1.0).max(0.04);
        }
        sal
    }

    pub fn len(&self) -> usize {
        self.phase.len()
    }

    pub fn is_empty(&self) -> bool {
        self.phase.is_empty()
    }

    /// Reference target at step t (clamped to the end).
    pub fn target(&self, t: usize) -> Jv {
        self.q_ref[(t + 1).min(self.q_ref.len() - 1)]
    }

    /// Saliency at step t (clamped).
    pub fn saliency_at(&self, t: usize) -> f64 {
        self.saliency[t.min(self.saliency.len() - 1)]
    }

    /// Saliency horizon for the next `k` steps starting at t (obs channel
    /// [7:15) — what the model's attention-mass head is routed from).
    pub fn saliency_horizon(&self, t: usize, k: usize) -> Vec<f64> {
        (0..k).map(|i| self.saliency_at(t + i)).collect()
    }

    pub fn phase_at(&self, t: usize) -> Phase {
        self.phase[t.min(self.phase.len() - 1)]
    }

    pub fn contact_at(&self, t: usize) -> f64 {
        self.contact[t.min(self.contact.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::tasks::ALL_TASKS;

    #[test]
    fn min_jerk_boundary() {
        assert_eq!(min_jerk(0.0), 0.0);
        assert!((min_jerk(1.0) - 1.0).abs() < 1e-12);
        assert!(min_jerk(0.5) > 0.4 && min_jerk(0.5) < 0.6);
        // monotone
        let mut prev = 0.0;
        for i in 1..=100 {
            let v = min_jerk(i as f64 / 100.0);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn trajectory_lengths_consistent() {
        for t in ALL_TASKS {
            let tr = RefTrajectory::build(t, Jv::ZERO);
            assert_eq!(tr.len(), t.seq_len());
            assert_eq!(tr.q_ref.len(), t.seq_len() + 1);
            assert_eq!(tr.saliency.len(), t.seq_len());
        }
    }

    #[test]
    fn trajectory_reaches_waypoints() {
        let t = TaskKind::PickPlace;
        let tr = RefTrajectory::build(t, Jv::ZERO);
        let segs = t.segments();
        let mut idx = 0;
        for seg in &segs {
            idx += seg.steps;
            assert!((tr.q_ref[idx] - seg.target).norm() < 1e-9);
        }
    }

    #[test]
    fn saliency_peaks_in_critical_phases() {
        for t in ALL_TASKS {
            let tr = RefTrajectory::build(t, Jv::ZERO);
            let crit_mean: f64 = {
                let xs: Vec<f64> = (0..tr.len())
                    .filter(|&i| tr.phase[i].is_critical())
                    .map(|i| tr.saliency[i])
                    .collect();
                xs.iter().sum::<f64>() / xs.len() as f64
            };
            let red_mean: f64 = {
                let xs: Vec<f64> = (0..tr.len())
                    .filter(|&i| !tr.phase[i].is_critical())
                    .map(|i| tr.saliency[i])
                    .collect();
                xs.iter().sum::<f64>() / xs.len() as f64
            };
            assert!(crit_mean > 2.0 * red_mean, "{}: crit {crit_mean} red {red_mean}", t.name());
        }
    }

    #[test]
    fn saliency_anticipates_contact() {
        let t = TaskKind::PickPlace;
        let tr = RefTrajectory::build(t, Jv::ZERO);
        // the step just before the first Interact segment should already
        // have elevated saliency
        let first_crit = (0..tr.len()).find(|&i| tr.phase[i].is_critical()).unwrap();
        assert!(tr.saliency[first_crit - 1] > 0.3);
        assert!(tr.saliency[first_crit.saturating_sub(12)] < 0.3);
    }

    #[test]
    fn horizon_clamps_at_end() {
        let tr = RefTrajectory::build(TaskKind::PegInsert, Jv::ZERO);
        let h = tr.saliency_horizon(tr.len() - 2, 8);
        assert_eq!(h.len(), 8);
        assert!(h.iter().all(|v| v.is_finite()));
    }
}

//! Simplified rigid-body manipulator dynamics (paper Eq. 3):
//!
//! τ = M(q)·q̈ + C(q,q̇)·q̇ + G(q) + τ_ext
//!
//! The structure (configuration-dependent inertia, velocity-product
//! Coriolis terms, gravity loading decreasing toward distal joints) is what
//! matters for RAPID — the torque signal must have a realistic composition
//! so that isolating the interaction component via Δτ (paper §IV-B.1)
//! is a meaningful operation.

use super::types::Jv;
use crate::config::RobotConfig;
use crate::N_JOINTS;

/// Manipulator dynamics parameterized by link masses / damping / gravity.
#[derive(Debug, Clone)]
pub struct Dynamics {
    cfg: RobotConfig,
    /// Effective link lengths (m).
    link_len: [f64; N_JOINTS],
}

impl Dynamics {
    pub fn new(cfg: &RobotConfig) -> Self {
        Dynamics { cfg: cfg.clone(), link_len: [0.30, 0.28, 0.25, 0.22, 0.15, 0.10, 0.08] }
    }

    /// Diagonal of the mass/inertia matrix M(q): distal mass seen by joint
    /// i, modulated by configuration (folded arm has lower inertia).
    pub fn mass_diag(&self, q: &Jv) -> Jv {
        Jv::from_fn(|i| {
            // inertia of everything distal of joint i
            let distal: f64 = (i..N_JOINTS)
                .map(|j| self.cfg.link_mass[j] * self.link_len[j] * self.link_len[j])
                .sum();
            // configuration dependence: elbow-like modulation
            let mod_cfg = 1.0 + 0.35 * (q[i.min(N_JOINTS - 2)]).cos().abs();
            (0.02 + distal) * mod_cfg
        })
    }

    /// M(q)·a including weak nearest-neighbour inertial coupling.
    pub fn mass_mul(&self, q: &Jv, a: &Jv) -> Jv {
        let diag = self.mass_diag(q);
        Jv::from_fn(|i| {
            let mut v = diag[i] * a[i];
            if i > 0 {
                v += 0.15 * diag[i] * a[i - 1];
            }
            if i + 1 < N_JOINTS {
                v += 0.15 * diag[i + 1] * a[i + 1];
            }
            v
        })
    }

    /// C(q, q̇)·q̇ — Coriolis/centrifugal velocity products + viscous
    /// damping folded in (quadratic in joint speed, sign-following).
    pub fn coriolis(&self, q: &Jv, dq: &Jv) -> Jv {
        let diag = self.mass_diag(q);
        Jv::from_fn(|i| {
            let neighbor = if i + 1 < N_JOINTS { dq[i + 1] } else { 0.0 };
            0.12 * diag[i] * dq[i] * dq[i].abs() + 0.05 * diag[i] * dq[i] * neighbor
                + self.cfg.damping * dq[i]
        })
    }

    /// Gravity torque G(q): joints support all distal links; shoulder-like
    /// joints see the largest moments, wrist joints almost none.
    pub fn gravity(&self, q: &Jv) -> Jv {
        let g = self.cfg.gravity;
        Jv::from_fn(|i| {
            let moment: f64 = (i..N_JOINTS)
                .map(|j| self.cfg.link_mass[j] * self.link_len[j] * 0.5)
                .sum();
            g * moment * q[i].cos() * if i % 2 == 0 { 1.0 } else { 0.4 }
        })
    }

    /// Inverse dynamics: required torque for (q, q̇, q̈) plus external τ.
    pub fn torque(&self, q: &Jv, dq: &Jv, ddq: &Jv, tau_ext: &Jv) -> Jv {
        self.mass_mul(q, ddq) + self.coriolis(q, dq) + self.gravity(q) + *tau_ext
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dyn_default() -> Dynamics {
        Dynamics::new(&RobotConfig::default())
    }

    #[test]
    fn mass_diag_positive_and_decreasing_outward() {
        let d = dyn_default();
        let m = d.mass_diag(&Jv::ZERO);
        for i in 0..N_JOINTS {
            assert!(m[i] > 0.0);
        }
        // proximal joints see more distal inertia
        assert!(m[0] > m[5]);
    }

    #[test]
    fn gravity_loads_proximal_joints_most() {
        let d = dyn_default();
        let g = d.gravity(&Jv::ZERO);
        assert!(g[0].abs() > g[6].abs());
    }

    #[test]
    fn zero_motion_zero_coriolis() {
        let d = dyn_default();
        let c = d.coriolis(&Jv::splat(0.3), &Jv::ZERO);
        assert!(c.norm() < 1e-12);
    }

    #[test]
    fn torque_composition_additive_in_ext() {
        let d = dyn_default();
        let q = Jv::splat(0.2);
        let dq = Jv::splat(0.1);
        let ddq = Jv::splat(0.5);
        let t0 = d.torque(&q, &dq, &ddq, &Jv::ZERO);
        let ext = Jv::splat(2.0);
        let t1 = d.torque(&q, &dq, &ddq, &ext);
        assert!(((t1 - t0) - ext).norm() < 1e-12);
    }

    #[test]
    fn acceleration_raises_torque() {
        let d = dyn_default();
        let q = Jv::splat(0.1);
        let t_slow = d.torque(&q, &Jv::ZERO, &Jv::splat(0.1), &Jv::ZERO);
        let t_fast = d.torque(&q, &Jv::ZERO, &Jv::splat(2.0), &Jv::ZERO);
        assert!((t_fast - d.gravity(&q)).norm() > (t_slow - d.gravity(&q)).norm());
    }

    #[test]
    fn torque_finite_for_extreme_state() {
        let d = dyn_default();
        let t = d.torque(&Jv::splat(3.1), &Jv::splat(10.0), &Jv::splat(50.0), &Jv::splat(5.0));
        assert!(t.is_finite());
    }
}

//! Observability layer: deterministic span tracing, a metrics registry
//! with log-bucketed latency histograms, and a wedge flight recorder.
//!
//! Everything here is config-gated behind `[trace]` (shipped disabled in
//! all four presets) and obeys the repo's zero-perturbation contract:
//! recording consumes **zero PRNG draws** and **never advances a
//! clock** — a traced fleet replays bit-identically to an untraced one
//! (`rust/tests/obs_trace.rs`), and two same-seed traced runs emit
//! byte-identical Chrome-trace JSON and JSONL, so traces are diffable
//! artifacts, not just pictures.
//!
//! - [`tracer`] — virtual-time [`Span`]s per pipeline stage ([`Stage`]),
//!   exported as Chrome trace-event JSON (Perfetto-loadable) or JSONL.
//! - [`hist`] — fixed power-of-two [`LogHistogram`] (p50/p95/p99/max)
//!   with an exactly associative merge.
//! - [`registry`] — insertion-ordered counters + histograms behind one
//!   renderer (`rapid fleet`'s rollup, `--metrics-json`).
//! - [`flight`] — per-session ring of recent events dumped by every
//!   exit-1 wedge path ([`FlightRecorder::report`]).
//! - [`demo`] — the deterministic `rapid trace` scenario that exercises
//!   every stage kind.

pub mod demo;
pub mod flight;
pub mod hist;
pub mod registry;
pub mod tracer;

pub use flight::{FlightEvent, FlightKind, FlightRecorder};
pub use hist::LogHistogram;
pub use registry::MetricsRegistry;
pub use tracer::{chrome_trace_json, Span, Stage, Tracer, NO_ENDPOINT};

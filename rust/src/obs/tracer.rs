//! Deterministic virtual-time span tracer.
//!
//! A [`Span`] is one stage of one pipeline step — capture, edge prefix,
//! wire transfer, cloud queue wait, cloud compute, delayed reply, reuse
//! probe/hit, speculation dispatch/resolve, failover redispatch, or a
//! link-outage window — pinned to the *virtual* clock: `ts_us` is the
//! session's position inside its fleet round (`round * round_us` plus the
//! stage durations already charged this step) and `dur_us` is exactly the
//! virtual time the scheduler charged for that stage. Wall time never
//! enters a span, tracing draws nothing from any PRNG, and recording
//! never advances a clock — so a traced run replays bit-identically and
//! two same-seed traces are byte-identical artifacts (pinned by
//! `rust/tests/obs_trace.rs`).
//!
//! Export formats: Chrome trace-event JSON (`{"traceEvents": [...]}`,
//! complete `ph:"X"` events — load the file in Perfetto or
//! `chrome://tracing`) and a compact one-object-per-line JSONL for
//! in-tree diffing. `pid` is the fleet (0 unless merging several fleets,
//! as `rapid trace` does), `tid` is the session.

/// Pipeline stage kinds — one per place the scheduler charges virtual
/// time (or marks a zero-cost decision worth seeing on a timeline).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Sensor-frame capture before an offload (`clock.obs_capture()`).
    Capture,
    /// Edge prefix compute for a zoo split, net of any overlap-hidden
    /// portion (dur 0 when the pipeline hides all of it).
    EdgePrefix,
    /// Wire round trip of the offload payload (`link.offload_roundtrip`).
    Wire,
    /// Rounds a request waited in the cross-session batcher between
    /// dispatch and flush.
    CloudQueue,
    /// Cloud-side batch compute.
    CloudCompute,
    /// Fault-injected reply delay charged on top of the round trip.
    Reply,
    /// Reuse-cache probe (tag: 0 miss, 1 stale, 2 hit).
    ReuseProbe,
    /// Reuse-cache hit serving a step for `probe_ms` instead of a round
    /// trip.
    ReuseHit,
    /// Speculative edge decode emitted while the offload is in flight.
    SpecDispatch,
    /// Speculation resolution (tag: 1 confirmed free, 0 rolled back for
    /// `rollback_ms`, 2 aborted by a failed offload).
    SpecResolve,
    /// Failover redispatch after an endpoint was crossed off (tag: retry
    /// number; dur: the timeout charged when the reply was lost).
    Failover,
    /// Link-outage round (one span per outage round the fleet observed).
    Outage,
}

impl Stage {
    /// Every stage kind, in timeline order (index == `id`).
    pub const ALL: [Stage; 12] = [
        Stage::Capture,
        Stage::EdgePrefix,
        Stage::Wire,
        Stage::CloudQueue,
        Stage::CloudCompute,
        Stage::Reply,
        Stage::ReuseProbe,
        Stage::ReuseHit,
        Stage::SpecDispatch,
        Stage::SpecResolve,
        Stage::Failover,
        Stage::Outage,
    ];

    pub fn id(self) -> usize {
        match self {
            Stage::Capture => 0,
            Stage::EdgePrefix => 1,
            Stage::Wire => 2,
            Stage::CloudQueue => 3,
            Stage::CloudCompute => 4,
            Stage::Reply => 5,
            Stage::ReuseProbe => 6,
            Stage::ReuseHit => 7,
            Stage::SpecDispatch => 8,
            Stage::SpecResolve => 9,
            Stage::Failover => 10,
            Stage::Outage => 11,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Capture => "capture",
            Stage::EdgePrefix => "edge_prefix",
            Stage::Wire => "wire",
            Stage::CloudQueue => "cloud_queue",
            Stage::CloudCompute => "cloud_compute",
            Stage::Reply => "reply",
            Stage::ReuseProbe => "reuse_probe",
            Stage::ReuseHit => "reuse_hit",
            Stage::SpecDispatch => "spec_dispatch",
            Stage::SpecResolve => "spec_resolve",
            Stage::Failover => "failover",
            Stage::Outage => "outage",
        }
    }
}

/// Sentinel endpoint for spans not tied to a cloud endpoint.
pub const NO_ENDPOINT: u32 = u32::MAX;

/// One recorded stage instance. Plain `Copy` data — recording a span is
/// a bounds check and a 40-byte store, nothing more.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub stage: Stage,
    /// Virtual timestamp (µs since fleet start).
    pub ts_us: u64,
    /// Virtual duration (µs) — exactly what the scheduler charged.
    pub dur_us: u64,
    pub session: u32,
    /// `ModelFamily::id()` of the owning session.
    pub family: u8,
    /// Cloud endpoint serving the stage, or [`NO_ENDPOINT`].
    pub endpoint: u32,
    /// Stage-specific detail (probe outcome, retry number, payload bytes,
    /// confirm/rollback flag, outage length…). See [`Stage`] docs.
    pub tag: u32,
}

/// Bounded span sink for one fleet. `Vec`-backed (insertion order *is*
/// the deterministic order — no hash-map iteration anywhere) with a hard
/// cap: past `max_spans` the tracer counts drops instead of growing, so
/// an enabled trace can never OOM a 100k-session run.
#[derive(Debug, Clone)]
pub struct Tracer {
    spans: Vec<Span>,
    max_spans: usize,
    dropped: u64,
    /// Virtual µs per fleet round — the scale spans' round offsets use.
    round_us: f64,
}

impl Tracer {
    pub fn new(max_spans: usize, round_us: f64) -> Self {
        // reserve modestly; the cap may be far larger than any real run
        let cap = max_spans.min(4096);
        Tracer { spans: Vec::with_capacity(cap), max_spans, dropped: 0, round_us }
    }

    /// Virtual µs at the start of `round` — the base every in-round span
    /// cursor starts from.
    pub fn base_us(&self, round: u64) -> u64 {
        (round as f64 * self.round_us) as u64
    }

    pub fn round_us(&self) -> f64 {
        self.round_us
    }

    /// Record one span (40-byte store; drops past the cap).
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        stage: Stage,
        ts_us: u64,
        dur_us: u64,
        session: u32,
        family: u8,
        endpoint: u32,
        tag: u32,
    ) {
        if self.spans.len() >= self.max_spans {
            self.dropped += 1;
            return;
        }
        self.spans.push(Span { stage, ts_us, dur_us, session, family, endpoint, tag });
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Spans dropped past the `max_spans` cap.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Count of recorded spans of one stage kind.
    pub fn count_stage(&self, stage: Stage) -> u64 {
        self.spans.iter().filter(|s| s.stage == stage).count() as u64
    }

    /// Per-stage span counts indexed by [`Stage::id`].
    pub fn stage_counts(&self) -> [u64; Stage::ALL.len()] {
        let mut counts = [0u64; Stage::ALL.len()];
        for s in &self.spans {
            counts[s.stage.id()] += 1;
        }
        counts
    }

    /// Chrome trace-event JSON for this tracer alone (`pid` 0).
    pub fn to_chrome_json(&self) -> String {
        chrome_trace_json(&[(self, 0)])
    }

    /// Compact JSONL: one span object per line, insertion order.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::with_capacity(self.spans.len() * 96);
        for s in &self.spans {
            out.push_str(&format!(
                "{{\"stage\":\"{}\",\"ts\":{},\"dur\":{},\"session\":{},\"family\":{},\
                 \"endpoint\":{},\"tag\":{}}}\n",
                s.stage.name(),
                s.ts_us,
                s.dur_us,
                s.session,
                s.family,
                endpoint_json(s.endpoint),
                s.tag
            ));
        }
        out
    }
}

fn endpoint_json(ep: u32) -> i64 {
    if ep == NO_ENDPOINT {
        -1
    } else {
        ep as i64
    }
}

/// Merge one or more tracers into a single Chrome trace-event document,
/// each under its own `pid` (`rapid trace` merges its two demo fleets as
/// pid 0 and 1). All numbers are integers and the span order is the
/// tracers' insertion order, so same-seed runs emit byte-identical JSON.
pub fn chrome_trace_json(parts: &[(&Tracer, u32)]) -> String {
    let total: usize = parts.iter().map(|(t, _)| t.spans.len()).sum();
    let mut out = String::with_capacity(total * 140 + 64);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for (tracer, pid) in parts {
        for s in &tracer.spans {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{},\"tid\":{},\
                 \"cat\":\"fleet\",\"args\":{{\"family\":{},\"endpoint\":{},\"tag\":{}}}}}",
                s.stage.name(),
                s.ts_us,
                s.dur_us,
                pid,
                s.session,
                s.family,
                endpoint_json(s.endpoint),
                s.tag
            ));
        }
    }
    out.push_str("]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_ids_match_all_order() {
        for (i, st) in Stage::ALL.iter().enumerate() {
            assert_eq!(st.id(), i, "{}", st.name());
        }
        let mut names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.dedup();
        assert_eq!(names.len(), Stage::ALL.len(), "stage names must be unique");
    }

    #[test]
    fn cap_drops_instead_of_growing() {
        let mut t = Tracer::new(2, 1000.0);
        for i in 0..5 {
            t.record(Stage::Wire, i * 10, 5, 0, 0, 0, 0);
        }
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 3);
        assert_eq!(t.count_stage(Stage::Wire), 2);
    }

    #[test]
    fn base_us_scales_rounds() {
        let t = Tracer::new(16, 50_000.0);
        assert_eq!(t.base_us(0), 0);
        assert_eq!(t.base_us(3), 150_000);
    }

    #[test]
    fn chrome_json_is_valid_and_merges_pids() {
        let mut a = Tracer::new(16, 1000.0);
        a.record(Stage::Capture, 0, 12, 0, 1, NO_ENDPOINT, 0);
        let mut b = Tracer::new(16, 1000.0);
        b.record(Stage::Wire, 7, 90, 2, 0, 1, 4096);
        let doc = chrome_trace_json(&[(&a, 0), (&b, 1)]);
        let v = crate::config::json::parse_json(&doc).expect("chrome trace JSON must parse");
        let events = v.get("traceEvents").and_then(|e| e.as_list()).expect("traceEvents");
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].str_or("name", ""), "capture");
        assert_eq!(events[0].f64_or("pid", -1.0), 0.0);
        assert_eq!(events[1].str_or("name", ""), "wire");
        assert_eq!(events[1].f64_or("pid", -1.0), 1.0);
        assert_eq!(events[1].f64_or("dur", -1.0), 90.0);
        // no-endpoint sentinel serializes as -1, never as u32::MAX
        assert!(doc.contains("\"endpoint\":-1"));
        assert!(!doc.contains("4294967295"));
    }

    #[test]
    fn jsonl_one_line_per_span() {
        let mut t = Tracer::new(16, 1000.0);
        t.record(Stage::ReuseHit, 5, 300, 1, 2, NO_ENDPOINT, 2);
        t.record(Stage::Outage, 9, 1000, 0, 0, NO_ENDPOINT, 4);
        let doc = t.to_jsonl();
        let lines: Vec<&str> = doc.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            crate::config::json::parse_json(line).expect("every JSONL line parses");
        }
        assert!(doc.starts_with("{\"stage\":\"reuse_hit\""));
    }

    #[test]
    fn same_spans_same_bytes() {
        let mk = || {
            let mut t = Tracer::new(64, 1000.0);
            for i in 0..10u64 {
                t.record(Stage::ALL[(i % 12) as usize], i * 100, i, i as u32, 0, 0, 0);
            }
            t
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.to_chrome_json(), b.to_chrome_json());
        assert_eq!(a.to_jsonl(), b.to_jsonl());
    }
}

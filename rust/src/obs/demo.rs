//! Deterministic trace demo backing `rapid trace`: two small fleets,
//! composed so that **every** [`Stage`] kind is guaranteed to appear —
//! pinned by `rust/tests/obs_trace.rs` and validated per run by the
//! trace-smoke CI step.
//!
//! * **Fleet A** (pid 0) — lockstep Cloud-Only surrogate fleet with the
//!   shared reuse cache on and a programmatic fault schedule: an early
//!   reply-delay window (`Reply` spans), a mid-run uplink outage
//!   (`Outage`), and a permanent reply-drop tail that exhausts both
//!   endpoints (`Failover` + degraded flight events). Cross-session
//!   round-0 hits cover `ReuseProbe`/`ReuseHit`; the batcher covers
//!   `Capture`/`Wire`/`CloudQueue`/`CloudCompute`.
//! * **Fleet B** (pid 1) — model-zoo fleet under a slow link (deep splits
//!   give every dispatch real prefix compute: `EdgePrefix`) with the
//!   pipeline's overlap + speculation on (`SpecDispatch`/`SpecResolve`).
//!
//! Both fleets are seeded from the caller's config, so two same-seed
//! demos emit byte-identical artifacts.

use super::{chrome_trace_json, MetricsRegistry, Stage};
use crate::config::{PolicyKind, SystemConfig};
use crate::faults::{FaultEngine, FaultPlan};
use crate::robot::TaskKind;
use crate::serve::Fleet;

/// Everything `rapid trace` writes or checks.
pub struct TraceDemo {
    /// Merged Chrome trace-event JSON (fleet A = pid 0, fleet B = pid 1).
    pub chrome_json: String,
    /// Merged compact JSONL (fleet A's spans, then fleet B's).
    pub jsonl: String,
    /// Combined per-stage span counts, indexed by [`Stage::id`].
    pub stage_counts: [u64; Stage::ALL.len()],
    /// Combined metrics registry of both fleets.
    pub registry: MetricsRegistry,
}

/// Stage kinds a demo run failed to produce (empty on a healthy build —
/// `rapid trace` exits 1 otherwise, which is what CI pins).
pub fn missing_stages(counts: &[u64; Stage::ALL.len()]) -> Vec<&'static str> {
    Stage::ALL.iter().filter(|s| counts[s.id()] == 0).map(|s| s.name()).collect()
}

/// Run the two demo fleets (at least 6 sessions each — the batch size
/// plus cache-hit stragglers fleet A's coverage relies on) and merge
/// their artifacts.
pub fn run_trace_demo(sys: &SystemConfig, sessions: usize) -> TraceDemo {
    let n = sessions.max(6);

    // Fleet A: faults + cache under lockstep Cloud-Only. The delay window
    // covers the round-0 full flush (Reply), the outage covers rounds the
    // fleet is mid-episode (Outage), and the drop tail turns every late
    // dispatch into retry-then-degrade (Failover).
    let mut sys_a = sys.clone();
    sys_a.trace.enabled = true;
    sys_a.workload.enabled = false;
    sys_a.models.enabled = false;
    sys_a.pipeline.enabled = false;
    sys_a.cache.enabled = true;
    sys_a.fleet.n_sessions = n;
    sys_a.fleet.max_batch = 4;
    sys_a.fleet.max_inflight = 16;
    sys_a.fleet.episodes_per_session = 1;
    sys_a.fleet.endpoints = 2;
    let plan = FaultPlan::none()
        .delay_replies(0, 6, 60.0)
        .outage(6, 8)
        .drop_replies(10, u64::MAX, 1.0);
    let engine = FaultEngine::new(plan, sys_a.episode.seed, 250.0, 1);
    let a = Fleet::local_with_faults(&sys_a, TaskKind::PickPlace, PolicyKind::CloudOnly, engine)
        .run();

    // Fleet B: zoo splits under a slow link (the planner picks deep
    // splits with real edge-prefix compute) plus pipelined execution —
    // overlap and speculation both on. Cloud-Only exposes no kinematic
    // evidence, so the z-gate speculates on every dispatch.
    let mut sys_b = sys.clone();
    sys_b.trace.enabled = true;
    sys_b.workload.enabled = false;
    sys_b.cache.enabled = false;
    sys_b.models.enabled = true;
    sys_b.link.bw_mbps = 20.0;
    sys_b.link.rtt_ms = 40.0;
    sys_b.pipeline.enabled = true;
    sys_b.pipeline.overlap = true;
    sys_b.pipeline.speculate = true;
    sys_b.fleet.n_sessions = n;
    sys_b.fleet.max_batch = 4;
    sys_b.fleet.max_inflight = 16;
    sys_b.fleet.episodes_per_session = 1;
    let b = Fleet::local(&sys_b, TaskKind::PickPlace, PolicyKind::CloudOnly).run();

    let ta = a.trace.as_ref().expect("fleet A ran with [trace] enabled");
    let tb = b.trace.as_ref().expect("fleet B ran with [trace] enabled");
    let chrome_json = chrome_trace_json(&[(ta, 0), (tb, 1)]);
    let mut jsonl = ta.to_jsonl();
    jsonl.push_str(&tb.to_jsonl());
    let mut stage_counts = ta.stage_counts();
    for (i, c) in tb.stage_counts().iter().enumerate() {
        stage_counts[i] += c;
    }
    let mut registry = a.registry();
    registry.merge(&b.registry());
    TraceDemo { chrome_json, jsonl, stage_counts, registry }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_covers_every_stage_kind() {
        let demo = run_trace_demo(&SystemConfig::default(), 6);
        assert!(
            missing_stages(&demo.stage_counts).is_empty(),
            "missing stages: {:?}",
            missing_stages(&demo.stage_counts)
        );
        assert!(demo.chrome_json.contains("\"traceEvents\""));
        assert!(demo.registry.counter("trace/spans").unwrap_or(0) > 0);
    }

    #[test]
    fn same_seed_demos_are_byte_identical() {
        let x = run_trace_demo(&SystemConfig::default(), 6);
        let y = run_trace_demo(&SystemConfig::default(), 6);
        assert_eq!(x.chrome_json, y.chrome_json);
        assert_eq!(x.jsonl, y.jsonl);
        assert_eq!(x.registry.to_json(), y.registry.to_json());
    }
}

//! Metrics registry: named counters plus log-bucketed latency histograms
//! behind one renderer, replacing the ad-hoc `println!` rollups that
//! `rapid fleet` / `rapid chaos` / `rapid zoo` each used to hand-format.
//!
//! Storage is insertion-ordered `Vec`s (linear probe on a few dozen
//! names — no hashing, no iteration-order nondeterminism), so the render
//! and the `--metrics-json` dump are byte-stable across same-seed runs
//! and registries merge deterministically (histogram merge is exactly
//! associative; see [`super::hist`]).

use super::hist::LogHistogram;
use crate::util::tablefmt::Table;

/// Insertion-ordered counters and histograms.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: Vec<(String, u64)>,
    hists: Vec<(String, LogHistogram)>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to counter `name`, creating it at 0 first.
    pub fn inc(&mut self, name: &str, by: u64) {
        match self.counters.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => *v += by,
            None => self.counters.push((name.to_string(), by)),
        }
    }

    /// Set counter `name` (used for gauges like `max_batch_observed`
    /// where merge semantics are max, handled by the caller).
    pub fn set(&mut self, name: &str, v: u64) {
        match self.counters.iter_mut().find(|(n, _)| n == name) {
            Some((_, slot)) => *slot = v,
            None => self.counters.push((name.to_string(), v)),
        }
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// Record one latency sample (µs) into histogram `name`.
    pub fn observe(&mut self, name: &str, v: f64) {
        match self.hists.iter_mut().find(|(n, _)| n == name) {
            Some((_, h)) => h.insert(v),
            None => {
                let mut h = LogHistogram::new();
                h.insert(v);
                self.hists.push((name.to_string(), h));
            }
        }
    }

    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Fold a whole histogram into `name` (how the fleet imports its
    /// tracer's per-stage timings).
    pub fn merge_histogram(&mut self, name: &str, other: &LogHistogram) {
        match self.hists.iter_mut().find(|(n, _)| n == name) {
            Some((_, h)) => h.merge(other),
            None => self.hists.push((name.to_string(), other.clone())),
        }
    }

    /// Merge another registry: counters add, histograms merge. Names the
    /// other registry introduces keep its insertion order, appended.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (n, v) in &other.counters {
            self.inc(n, *v);
        }
        for (n, h) in &other.hists {
            self.merge_histogram(n, h);
        }
    }

    pub fn counters(&self) -> &[(String, u64)] {
        &self.counters
    }

    pub fn histograms(&self) -> &[(String, LogHistogram)] {
        &self.hists
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.hists.is_empty()
    }

    /// Render counters (zero-valued ones elided to keep the rollup the
    /// size of the old ad-hoc lines) and latency histograms as tables.
    pub fn render(&self, title: &str) -> String {
        let mut out = String::new();
        let live: Vec<&(String, u64)> = self.counters.iter().filter(|(_, v)| *v > 0).collect();
        if !live.is_empty() {
            let mut t = Table::new(title, &["Counter", "Value"]);
            for (n, v) in live {
                t.row(&[n.clone(), v.to_string()]);
            }
            out.push_str(&t.render());
        }
        if !self.hists.is_empty() {
            let mut t = Table::new(
                &format!("{title} — latency histograms (µs)"),
                &["Stage", "Count", "p50", "p95", "p99", "Max"],
            );
            for (n, h) in &self.hists {
                t.row(&[
                    n.clone(),
                    h.count().to_string(),
                    format!("{:.0}", h.p50()),
                    format!("{:.0}", h.p95()),
                    format!("{:.0}", h.p99()),
                    format!("{:.0}", h.max()),
                ]);
            }
            out.push_str(&t.render());
        }
        out
    }

    /// Machine-readable dump (`--metrics-json`): every counter (including
    /// zeros) and every histogram's quantiles + raw bucket array.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"counters\":{");
        for (i, (n, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{n}\":{v}"));
        }
        out.push_str("},\"histograms\":{");
        for (i, (n, h)) in self.hists.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let buckets: Vec<String> =
                h.buckets().iter().map(|b| b.to_string()).collect();
            out.push_str(&format!(
                "\"{n}\":{{\"count\":{},\"p50\":{:.0},\"p95\":{:.0},\"p99\":{:.0},\
                 \"max\":{:.0},\"buckets\":[{}]}}",
                h.count(),
                h.p50(),
                h.p95(),
                h.p99(),
                h.max(),
                buckets.join(",")
            ));
        }
        out.push_str("}}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_in_insertion_order() {
        let mut r = MetricsRegistry::new();
        r.inc("batches", 3);
        r.inc("rounds", 10);
        r.inc("batches", 2);
        assert_eq!(r.counter("batches"), Some(5));
        assert_eq!(r.counter("rounds"), Some(10));
        assert_eq!(r.counter("missing"), None);
        let names: Vec<&str> = r.counters().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["batches", "rounds"], "insertion order is stable");
    }

    #[test]
    fn merge_adds_counters_and_folds_histograms() {
        let mut a = MetricsRegistry::new();
        a.inc("hits", 2);
        a.observe("lat/wire", 100.0);
        let mut b = MetricsRegistry::new();
        b.inc("hits", 3);
        b.inc("misses", 1);
        b.observe("lat/wire", 900.0);
        a.merge(&b);
        assert_eq!(a.counter("hits"), Some(5));
        assert_eq!(a.counter("misses"), Some(1));
        let h = a.histogram("lat/wire").unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), 900.0);
    }

    #[test]
    fn render_elides_zero_counters_but_json_keeps_them() {
        let mut r = MetricsRegistry::new();
        r.inc("active", 4);
        r.set("dropped", 0);
        r.observe("lat/reply", 60_000.0);
        let rendered = r.render("fleet");
        assert!(rendered.contains("active"));
        assert!(!rendered.contains("dropped"), "zero counters are elided:\n{rendered}");
        assert!(rendered.contains("lat/reply"));
        let json = r.to_json();
        assert!(json.contains("\"dropped\":0"));
        let v = crate::config::json::parse_json(&json).expect("metrics JSON must parse");
        assert!(v.get("counters").is_some() && v.get("histograms").is_some());
    }

    #[test]
    fn json_is_byte_stable() {
        let mk = || {
            let mut r = MetricsRegistry::new();
            r.inc("a", 1);
            for i in 0..32 {
                r.observe("lat/x", (i * 17) as f64);
            }
            r.to_json()
        };
        assert_eq!(mk(), mk());
    }
}

//! Wedge flight recorder: a bounded per-session ring of recent scheduler
//! events, dumped by the CLI's exit-1 paths so "session never resumed"
//! comes with a postmortem instead of a bare exit code.
//!
//! Events are `Copy` fixed-size records (round + kind + two payload
//! words) stored in a [`RingBuf`] per session — recording is a store into
//! a preallocated ring, no allocation, no clock reads, no PRNG draws.
//! The recorder also remembers the most recent *degraded* dispatch (a
//! batch that exhausted every endpoint and fell back to edge-only): that
//! session is the prime wedge suspect, and [`FlightRecorder::report`]
//! leads with it, its last-N event tail, and the pending batch's flush
//! cause.

use crate::util::ringbuf::RingBuf;

/// What happened (payload words `a`/`b` per kind are documented inline).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FlightKind {
    /// Padding for unwritten ring slots — never recorded explicitly.
    #[default]
    None,
    /// Session joined the fleet.
    Arrival,
    /// Session enqueued a cloud request (`a` = queue length after push).
    Enqueue,
    /// Session's request left in a batch flush (`a` = flush cause code,
    /// `b` = batch size).
    Flush,
    /// Reply dropped or timed out by the fault engine (`a` = endpoint).
    DropReply,
    /// Redispatch to another endpoint (`a` = retry number).
    Failover,
    /// Batch exhausted every endpoint; session resumed degraded from the
    /// edge (`a` = flush cause code, `b` = batch size).
    Degraded,
    /// Link outage round observed while the session was active.
    Outage,
    /// Speculative dispatch resolved (`a` = 1 confirmed / 0 rolled back /
    /// 2 aborted).
    SpecResolve,
    /// Session finished an episode (`a` = episodes remaining).
    EpisodeDone,
    /// Autoscaler spawned an endpoint (fleet-level; `a` = endpoint id,
    /// `b` = active endpoints after the spawn).
    ScaleUp,
    /// Autoscaler drained an endpoint (fleet-level; `a` = endpoint id,
    /// `b` = active endpoints after the drain).
    ScaleDown,
    /// Admission control shed an offload to edge-only serving (`a` =
    /// queued cloud requests at the gate).
    Shed,
}

impl FlightKind {
    pub fn name(self) -> &'static str {
        match self {
            FlightKind::None => "none",
            FlightKind::Arrival => "arrival",
            FlightKind::Enqueue => "enqueue",
            FlightKind::Flush => "flush",
            FlightKind::DropReply => "drop_reply",
            FlightKind::Failover => "failover",
            FlightKind::Degraded => "degraded",
            FlightKind::Outage => "outage",
            FlightKind::SpecResolve => "spec_resolve",
            FlightKind::EpisodeDone => "episode_done",
            FlightKind::ScaleUp => "scale_up",
            FlightKind::ScaleDown => "scale_down",
            FlightKind::Shed => "shed",
        }
    }
}

/// Flush-cause names, indexed by the cause code the fleet stamps into
/// `Flush`/`Degraded` events (`serve::fleet::FlushCause` order).
pub const CAUSE_NAMES: [&str; 4] = ["full", "deadline", "drain", "family"];

/// One fixed-size flight event.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FlightEvent {
    pub round: u64,
    pub kind: FlightKind,
    pub a: u32,
    pub b: u32,
}

/// Per-session bounded event rings plus the latest degraded-dispatch
/// pointer the wedge report leads with.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    rings: Vec<RingBuf<FlightEvent>>,
    /// Fleet-level ring: control-plane events (autoscale spawns/drains,
    /// admission sheds) that belong to the scheduler, not any session.
    fleet_ring: RingBuf<FlightEvent>,
    /// (session, round, cause code, batch size) of the newest `Degraded`.
    last_degraded: Option<(usize, u64, u32, u32)>,
}

impl FlightRecorder {
    pub fn new(n_sessions: usize, events_per_session: usize) -> Self {
        let cap = events_per_session.max(1);
        FlightRecorder {
            rings: (0..n_sessions.max(1)).map(|_| RingBuf::new(cap)).collect(),
            fleet_ring: RingBuf::new(cap),
            last_degraded: None,
        }
    }

    pub fn sessions(&self) -> usize {
        self.rings.len()
    }

    /// Record one event (a ring store; out-of-range sessions are ignored
    /// rather than panicking a live postmortem tool).
    pub fn record(&mut self, session: usize, round: u64, kind: FlightKind, a: u32, b: u32) {
        let Some(ring) = self.rings.get_mut(session) else { return };
        ring.push(FlightEvent { round, kind, a, b });
        if kind == FlightKind::Degraded {
            self.last_degraded = Some((session, round, a, b));
        }
    }

    /// Session named first in the wedge report: the one with the newest
    /// degraded dispatch, else the session with the newest event at all.
    pub fn suspect(&self) -> Option<usize> {
        if let Some((s, _, _, _)) = self.last_degraded {
            return Some(s);
        }
        self.rings
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.recent(0).map(|e| (e.round, i)))
            .max()
            .map(|(_, i)| i)
    }

    /// Record one fleet-level control-plane event (autoscale/shed).
    pub fn record_fleet(&mut self, round: u64, kind: FlightKind, a: u32, b: u32) {
        self.fleet_ring.push(FlightEvent { round, kind, a, b });
    }

    /// Event tail (oldest → newest) for one session.
    pub fn tail(&self, session: usize) -> Vec<FlightEvent> {
        self.rings.get(session).map(|r| r.iter().collect()).unwrap_or_default()
    }

    /// Fleet-level control-plane event tail (oldest → newest).
    pub fn fleet_tail(&self) -> Vec<FlightEvent> {
        self.fleet_ring.iter().collect()
    }

    /// Human-readable postmortem: the suspect session, its last-N events,
    /// and — when a degraded dispatch was seen — the pending batch's
    /// flush cause and size.
    pub fn report(&self) -> String {
        let Some(suspect) = self.suspect() else {
            return "flight recorder: no events recorded".to_string();
        };
        let mut out = String::new();
        match self.last_degraded {
            Some((s, round, cause, batch)) => {
                let cause = CAUSE_NAMES.get(cause as usize).unwrap_or(&"?");
                out.push_str(&format!(
                    "flight recorder: session {s} stuck — degraded dispatch @ round {round} \
                     (pending batch: cause {cause}, {batch} request(s), all endpoints \
                     exhausted)\n"
                ));
            }
            None => {
                out.push_str(&format!(
                    "flight recorder: session {suspect} has the newest activity (no degraded \
                     dispatch recorded)\n"
                ));
            }
        }
        let tail = self.tail(suspect);
        out.push_str(&format!("last {} event(s) for session {suspect}:\n", tail.len()));
        for e in &tail {
            out.push_str(&format!(
                "  round {:<6} {:<13} a={} b={}\n",
                e.round,
                e.kind.name(),
                e.a,
                e.b
            ));
        }
        let fleet = self.fleet_tail();
        if !fleet.is_empty() {
            out.push_str(&format!("last {} control-plane event(s):\n", fleet.len()));
            for e in &fleet {
                out.push_str(&format!(
                    "  round {:<6} {:<13} a={} b={}\n",
                    e.round,
                    e.kind.name(),
                    e.a,
                    e.b
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rings_bound_per_session_history() {
        let mut fr = FlightRecorder::new(2, 3);
        for round in 0..10 {
            fr.record(0, round, FlightKind::Enqueue, 1, 0);
        }
        let tail = fr.tail(0);
        assert_eq!(tail.len(), 3, "ring keeps only the last N");
        assert_eq!(tail[0].round, 7);
        assert_eq!(tail[2].round, 9);
        assert!(fr.tail(1).is_empty());
        // out-of-range sessions are ignored, not a panic
        fr.record(99, 0, FlightKind::Arrival, 0, 0);
    }

    #[test]
    fn degraded_dispatch_names_the_suspect_and_cause() {
        let mut fr = FlightRecorder::new(4, 8);
        fr.record(1, 3, FlightKind::Enqueue, 1, 0);
        fr.record(2, 5, FlightKind::Flush, 0, 4);
        fr.record(2, 5, FlightKind::Degraded, 1, 4); // cause 1 = deadline
        let rep = fr.report();
        assert_eq!(fr.suspect(), Some(2));
        assert!(rep.contains("session 2 stuck"), "{rep}");
        assert!(rep.contains("cause deadline"), "{rep}");
        assert!(rep.contains("4 request(s)"), "{rep}");
        assert!(rep.contains("degraded"), "{rep}");
    }

    #[test]
    fn without_degraded_the_newest_event_wins() {
        let mut fr = FlightRecorder::new(3, 4);
        fr.record(0, 2, FlightKind::Enqueue, 0, 0);
        fr.record(1, 9, FlightKind::Flush, 0, 2);
        assert_eq!(fr.suspect(), Some(1));
        assert!(fr.report().contains("session 1"));
    }

    #[test]
    fn empty_recorder_reports_gracefully() {
        let fr = FlightRecorder::new(2, 4);
        assert_eq!(fr.suspect(), None);
        assert!(fr.report().contains("no events"));
    }

    #[test]
    fn fleet_ring_captures_control_plane_events() {
        let mut fr = FlightRecorder::new(2, 4);
        fr.record(0, 3, FlightKind::Enqueue, 1, 0);
        fr.record_fleet(5, FlightKind::ScaleUp, 2, 3);
        fr.record_fleet(9, FlightKind::Shed, 17, 0);
        fr.record_fleet(20, FlightKind::ScaleDown, 2, 2);
        let tail = fr.fleet_tail();
        assert_eq!(tail.len(), 3);
        assert_eq!(tail[0].kind, FlightKind::ScaleUp);
        assert_eq!(tail[2].kind, FlightKind::ScaleDown);
        // fleet events never shift the per-session suspect
        assert_eq!(fr.suspect(), Some(0));
        let rep = fr.report();
        assert!(rep.contains("control-plane"), "{rep}");
        assert!(rep.contains("scale_up"), "{rep}");
        assert!(rep.contains("shed"), "{rep}");
        // the ring is bounded like session rings
        for r in 0..10 {
            fr.record_fleet(100 + r, FlightKind::ScaleUp, 0, 0);
        }
        assert_eq!(fr.fleet_tail().len(), 4);
    }
}

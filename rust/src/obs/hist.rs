//! Log-bucketed latency histogram with a deterministic merge.
//!
//! Sixty-four fixed power-of-two buckets: a sample `v` (any non-negative
//! magnitude — the serve layer feeds microseconds) lands in bucket
//! `64 - leading_zeros(v as u64)`, i.e. bucket 0 holds `[0, 1)`, bucket
//! `i >= 1` holds `[2^(i-1), 2^i)`. Quantiles walk the cumulative counts
//! and report the bucket's upper bound clamped to the observed maximum,
//! so p50/p95/p99 are conservative (never under-report) and every value
//! the histogram emits is reproducible from the bucket array alone.
//!
//! There is deliberately no running `sum` field: floating-point addition
//! is not associative, and the merge below must be *exactly* associative
//! so that per-shard histograms folded in any order produce bit-identical
//! registries (proptest invariant #29). Bucket counts are `u64` adds and
//! the max is an `f64::max` — both associative and commutative.

/// Number of power-of-two buckets (covers the full `u64` magnitude range).
pub const N_BUCKETS: usize = 64;

/// Fixed-bucket log histogram. `Default` is the empty histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    buckets: [u64; N_BUCKETS],
    count: u64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { buckets: [0; N_BUCKETS], count: 0, max: 0.0 }
    }
}

/// Bucket index for a sample: 0 for `[0, 1)`, else `1 + floor(log2 v)`,
/// clamped into the table.
pub fn bucket_index(v: f64) -> usize {
    let u = v.max(0.0) as u64;
    ((64 - u.leading_zeros()) as usize).min(N_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` (the value a quantile reports
/// when the walk stops there, before the max clamp).
fn bucket_upper(i: usize) -> f64 {
    if i == 0 {
        1.0
    } else {
        ((1u128 << i) - 1) as f64
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample. Negative samples clamp to bucket 0.
    pub fn insert(&mut self, v: f64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Largest sample observed (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn buckets(&self) -> &[u64; N_BUCKETS] {
        &self.buckets
    }

    /// Fold `other` into `self`: bucket-wise `u64` add plus an `f64` max.
    /// Exactly associative and commutative, so shard merge order never
    /// changes the result.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Quantile `p` in `[0, 1]`: the upper bound of the first bucket whose
    /// cumulative count reaches `ceil(p * count)`, clamped to the observed
    /// max. Returns 0.0 on an empty histogram. Monotone in `p` (proptest
    /// invariant #28).
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 1.0);
        let target = ((p * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return bucket_upper(i).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-3.5), 0, "negatives clamp to bucket 0");
        assert_eq!(bucket_index(0.99), 0);
        assert_eq!(bucket_index(1.0), 1);
        assert_eq!(bucket_index(2.0), 2);
        assert_eq!(bucket_index(3.0), 2);
        assert_eq!(bucket_index(4.0), 3);
        assert_eq!(bucket_index(1023.0), 10);
        assert_eq!(bucket_index(1024.0), 11);
        assert_eq!(bucket_index(f64::MAX), N_BUCKETS - 1, "huge values clamp");
    }

    #[test]
    fn quantiles_walk_and_clamp_to_max() {
        let mut h = LogHistogram::new();
        for _ in 0..99 {
            h.insert(10.0); // bucket 4, upper bound 15
        }
        h.insert(1000.0); // bucket 10
        assert_eq!(h.count(), 100);
        assert_eq!(h.p50(), 15.0);
        assert_eq!(h.p95(), 15.0);
        // p99 lands on the 99th sample — still a 10.0
        assert_eq!(h.p99(), 15.0);
        // p100 reaches the outlier bucket; upper bound 1023 clamps to max
        assert_eq!(h.quantile(1.0), 1000.0);
        assert_eq!(h.max(), 1000.0);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn merge_is_bucketwise_and_order_free() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for i in 0..50 {
            a.insert(i as f64);
            b.insert((i * 100) as f64);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge must commute exactly");
        assert_eq!(ab.count(), 100);
        assert_eq!(ab.max(), 4900.0);
        assert!(ab.p50() <= ab.p95() && ab.p95() <= ab.p99());
    }

    #[test]
    fn single_sample_quantiles_equal_the_sample_clamp() {
        let mut h = LogHistogram::new();
        h.insert(700.0);
        // bucket upper bound is 1023 but the clamp pins every quantile to
        // the only value ever seen
        for p in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(h.quantile(p), 700.0, "p={p}");
        }
    }
}

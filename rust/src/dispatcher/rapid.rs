//! `RapidDispatcher` — the stateful, low-overhead edge dispatcher of
//! Algorithm 1. All sensory extraction and statistical updates are local
//! scalar arithmetic: O(1) per tick, allocation-free after construction.

use super::cooldown::Cooldown;
use super::fusion::{self, FusionOutcome};
use crate::config::DispatcherConfig;
use crate::kinematics::features::KinState;
use crate::kinematics::window::ScoreWindow;
use crate::robot::SensorFrame;

/// Per-tick trigger evaluation (Algorithm 1 steps 1–5).
#[derive(Debug, Clone, Copy)]
pub struct TriggerEval {
    pub m_acc_raw: f64,
    pub m_tau_raw: f64,
    pub m_acc_hat: f64,
    pub m_tau_hat: f64,
    pub velocity: f64,
    pub outcome: FusionOutcome,
    /// I_dispatch = I_trigger ∧ (c == 0)  (Eq. 8)
    pub dispatch: bool,
}

/// Redundancy evidence exported to the reuse cache (`cache::Signature`):
/// the dispatcher's normalized anomaly z-scores and the velocity that
/// drives the phase weights, as of the last sensor tick. This is the
/// dispatcher's own measurement of *how redundant* the current instant is
/// — high scores mean a novel/critical situation where reusing a cached
/// chunk would be unsafe.
#[derive(Debug, Clone, Copy)]
pub struct ReuseEvidence {
    /// Normalized acceleration anomaly M̂_acc (σ).
    pub m_acc_hat: f64,
    /// Normalized torque-variation anomaly M̂_τ (σ).
    pub m_tau_hat: f64,
    /// Velocity norm v_t (rad/s).
    pub velocity: f64,
}

/// Control-rate decision (Algorithm 1 line 6, under the edge/cloud split
/// interpretation documented in the module root).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Execute the next cached action.
    ExecuteCached,
    /// Queue empty in a redundant phase: refill from the edge model.
    RefillEdge,
    /// Critical phase detected: preempt and offload to the cloud.
    OffloadCloud,
}

#[derive(Debug, Clone)]
pub struct RapidDispatcher {
    cfg: DispatcherConfig,
    kin: KinState,
    acc_win: ScoreWindow,
    tau_win: ScoreWindow,
    cooldown: Cooldown,
    last_eval: Option<TriggerEval>,
    /// Counters for overhead/ablation reporting.
    pub n_ticks: u64,
    pub n_triggers: u64,
    pub n_dispatches: u64,
}

impl RapidDispatcher {
    pub fn new(cfg: &DispatcherConfig, dt: f64) -> Self {
        // Warm-up: a quarter of the window, at least 16 samples (σ estimates
        // below that are unstable enough to produce spurious >z_gate scores).
        let warm = (cfg.window_acc / 8).max(8);
        RapidDispatcher {
            kin: KinState::new(dt, cfg.w_acc, cfg.w_torque, cfg.w_tau),
            acc_win: ScoreWindow::new(cfg.window_acc, cfg.eps, warm),
            tau_win: ScoreWindow::new(cfg.window_tau, cfg.eps, warm),
            cooldown: Cooldown::new(cfg.cooldown),
            cfg: cfg.clone(),
            last_eval: None,
            n_ticks: 0,
            n_triggers: 0,
            n_dispatches: 0,
        }
    }

    /// High-rate sensor tick (f_sensor loop, §V-A): ingest a frame, update
    /// rolling statistics, evaluate the dual threshold. O(1).
    pub fn observe(&mut self, frame: &SensorFrame) -> TriggerEval {
        let feats = self.kin.update(frame);
        let m_acc_hat = self.acc_win.normalize(feats.m_acc);
        let m_tau_hat = self.tau_win.normalize(feats.m_tau);
        let outcome =
            fusion::evaluate_full(
                m_acc_hat,
                m_tau_hat,
                feats.m_acc,
                feats.m_tau,
                feats.v,
                &self.cfg,
            );
        let dispatch = outcome.triggered && self.cooldown.ready();
        let eval = TriggerEval {
            m_acc_raw: feats.m_acc,
            m_tau_raw: feats.m_tau,
            m_acc_hat,
            m_tau_hat,
            velocity: feats.v,
            outcome,
            dispatch,
        };
        self.n_ticks += 1;
        if outcome.triggered {
            self.n_triggers += 1;
        }
        self.last_eval = Some(eval);
        eval
    }

    /// Control-rate decision (Algorithm 1 line 6): consumes the latest
    /// sensor evaluation (the f_sensor loop's interrupt flag).
    pub fn decide(&mut self, queue_empty: bool) -> Decision {
        let dispatch = self.last_eval.map(|e| e.dispatch).unwrap_or(false);
        let d = if dispatch {
            self.cooldown.arm();
            self.n_dispatches += 1;
            Decision::OffloadCloud
        } else if queue_empty {
            Decision::RefillEdge
        } else {
            Decision::ExecuteCached
        };
        self.cooldown.tick();
        d
    }

    pub fn last_eval(&self) -> Option<TriggerEval> {
        self.last_eval
    }

    /// Redundancy evidence of the last tick (None before the first
    /// observation), for the reuse-cache signature.
    pub fn reuse_evidence(&self) -> Option<ReuseEvidence> {
        self.last_eval.map(|e| ReuseEvidence {
            m_acc_hat: e.m_acc_hat,
            m_tau_hat: e.m_tau_hat,
            velocity: e.velocity,
        })
    }

    pub fn cooldown_remaining(&self) -> u32 {
        self.cooldown.remaining()
    }

    pub fn config(&self) -> &DispatcherConfig {
        &self.cfg
    }

    pub fn reset(&mut self) {
        self.kin.reset();
        self.acc_win =
            ScoreWindow::new(self.cfg.window_acc, self.cfg.eps, (self.cfg.window_acc / 8).max(8));
        self.tau_win =
            ScoreWindow::new(self.cfg.window_tau, self.cfg.eps, (self.cfg.window_acc / 8).max(8));
        self.cooldown = Cooldown::new(self.cfg.cooldown);
        self.last_eval = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robot::Jv;

    fn frame(step: usize, dq: f64, tau: f64) -> SensorFrame {
        SensorFrame { step, q: Jv::ZERO, dq: Jv::splat(dq), tau: Jv::splat(tau) }
    }

    fn dispatcher() -> RapidDispatcher {
        RapidDispatcher::new(&DispatcherConfig::default(), 0.05)
    }

    /// Feed a calm stream to pass warm-up.
    fn warm(d: &mut RapidDispatcher, n: usize) {
        let mut t = 0.0f64;
        for i in 0..n {
            t += 0.001;
            d.observe(&frame(i, 0.2 + 0.001 * (i % 3) as f64, 1.0 + t.sin() * 0.01));
            d.decide(false);
        }
    }

    #[test]
    fn calm_stream_never_offloads() {
        let mut d = dispatcher();
        for i in 0..200 {
            d.observe(&frame(i, 0.2, 1.0));
            assert_ne!(d.decide(false), Decision::OffloadCloud);
        }
    }

    #[test]
    fn empty_queue_forces_edge_refill() {
        let mut d = dispatcher();
        d.observe(&frame(0, 0.2, 1.0));
        assert_eq!(d.decide(true), Decision::RefillEdge);
    }

    #[test]
    fn torque_spike_at_low_speed_offloads() {
        let mut d = dispatcher();
        warm(&mut d, 60);
        // sudden contact: big Δτ, near-zero velocity
        d.observe(&frame(60, 0.05, 8.0));
        assert_eq!(d.decide(false), Decision::OffloadCloud);
    }

    #[test]
    fn accel_spike_at_high_speed_offloads() {
        let mut d = dispatcher();
        let mut i = 0;
        // cruise at high speed
        for _ in 0..60 {
            d.observe(&frame(i, 1.7, 1.0));
            d.decide(false);
            i += 1;
        }
        // sudden stop: huge acceleration magnitude, velocity still high at
        // the differencing instant
        d.observe(&frame(i, 0.9, 1.0));
        assert_eq!(d.decide(false), Decision::OffloadCloud);
    }

    #[test]
    fn cooldown_masks_consecutive_triggers() {
        let mut d = dispatcher();
        warm(&mut d, 60);
        d.observe(&frame(60, 0.05, 8.0));
        assert_eq!(d.decide(false), Decision::OffloadCloud);
        // sustained contact keeps the raw trigger high, but dispatch is
        // masked for C steps
        let cd = d.config().cooldown as usize;
        for j in 0..cd - 1 {
            d.observe(&frame(61 + j, 0.05, if j % 2 == 0 { 1.0 } else { 8.0 }));
            assert_ne!(d.decide(false), Decision::OffloadCloud, "step {j}");
        }
    }

    #[test]
    fn queue_empty_during_cooldown_still_refills() {
        let mut d = dispatcher();
        warm(&mut d, 60);
        d.observe(&frame(60, 0.05, 8.0));
        assert_eq!(d.decide(false), Decision::OffloadCloud);
        d.observe(&frame(61, 0.05, 1.0));
        assert_eq!(d.decide(true), Decision::RefillEdge);
    }

    #[test]
    fn warmup_never_triggers() {
        let mut d = dispatcher();
        // even wild inputs during the first ticks must not dispatch
        for i in 0..3 {
            d.observe(&frame(i, 5.0 * (i as f64), 50.0 * (i as f64)));
            assert_ne!(d.decide(false), Decision::OffloadCloud);
        }
    }

    #[test]
    fn counters_track_activity() {
        let mut d = dispatcher();
        warm(&mut d, 60);
        d.observe(&frame(60, 0.05, 8.0));
        d.decide(false);
        assert!(d.n_ticks >= 61);
        assert!(d.n_triggers >= 1);
        assert_eq!(d.n_dispatches, 1);
    }

    #[test]
    fn reset_restores_warmup_behaviour() {
        let mut d = dispatcher();
        warm(&mut d, 60);
        d.reset();
        d.observe(&frame(0, 5.0, 50.0));
        assert_ne!(d.decide(false), Decision::OffloadCloud);
    }
}

//! Mechanism fusion (paper §IV-C): dynamic phase weights + dual threshold.

use crate::config::DispatcherConfig;

/// Velocity-driven modality weights (Eq. 6): ω_a + ω_τ = 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseWeights {
    pub w_a: f64,
    pub w_tau: f64,
}

/// ω_a = clip(v / v_max, 0, 1), ω_τ = 1 − ω_a. NaN-safe: a non-finite
/// velocity falls back to the torque-dominated regime (v = 0).
pub fn phase_weights(v: f64, v_max: f64) -> PhaseWeights {
    let ratio = if v.is_finite() && v_max > 0.0 { (v / v_max).clamp(0.0, 1.0) } else { 0.0 };
    PhaseWeights { w_a: ratio, w_tau: 1.0 - ratio }
}

/// Result of one dual-threshold evaluation (Eq. 7).
#[derive(Debug, Clone, Copy)]
pub struct FusionOutcome {
    pub triggered: bool,
    /// Which side fired (for trace/ablation analysis).
    pub by_comp: bool,
    pub by_red: bool,
    /// Continuous Action Importance Score S_imp.
    pub importance: f64,
    pub weights: PhaseWeights,
}

/// Evaluate the dynamic dual-threshold trigger (Eq. 7) with ablation flags.
/// `m_acc_raw` / `m_tau_raw` are the unnormalized scores (Eqs. 4–5) used
/// by the physical floors.
pub fn evaluate_full(
    m_acc_hat: f64,
    m_tau_hat: f64,
    m_acc_raw: f64,
    m_tau_raw: f64,
    v: f64,
    cfg: &DispatcherConfig,
) -> FusionOutcome {
    let weights = if cfg.static_fusion {
        // ablation: treat all anomalies equally (logical OR of raw scores)
        PhaseWeights { w_a: 1.0, w_tau: 1.0 }
    } else {
        phase_weights(v, cfg.v_max)
    };
    let comp_term = weights.w_a * m_acc_hat;
    let red_term = weights.w_tau * m_tau_hat;
    // An anomaly must be (a) statistically significant — z above z_gate —
    // and (b) physically non-trivial — raw score above the floor
    // (z-scores are scale-free: a perfectly quiet stream would otherwise
    // normalize its own µ-scale noise into anomalies). θ then sets the
    // phase-weighted sensitivity on genuine anomalies (Eq. 7).
    let by_comp = !cfg.disable_comp
        && m_acc_hat > cfg.z_gate
        && m_acc_raw > cfg.min_m_acc
        && comp_term > cfg.theta_comp;
    let by_red = !cfg.disable_red
        && m_tau_hat > cfg.z_gate
        && m_tau_raw > cfg.min_m_tau
        && red_term > cfg.theta_red;
    FusionOutcome {
        triggered: by_comp || by_red,
        by_comp,
        by_red,
        importance: comp_term + red_term,
        weights,
    }
}

/// Convenience wrapper with the physical floors trivially satisfied
/// (threshold-logic unit tests and callers without raw scores).
pub fn evaluate(m_acc_hat: f64, m_tau_hat: f64, v: f64, cfg: &DispatcherConfig) -> FusionOutcome {
    evaluate_full(m_acc_hat, m_tau_hat, f64::MAX, f64::MAX, v, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DispatcherConfig {
        DispatcherConfig::default()
    }

    #[test]
    fn weights_form_simplex() {
        for v in [-1.0, 0.0, 0.5, 1.8, 5.0, f64::NAN, f64::INFINITY] {
            let w = phase_weights(v, 1.8);
            assert!((w.w_a + w.w_tau - 1.0).abs() < 1e-12);
            assert!((0.0..=1.0).contains(&w.w_a));
            assert!((0.0..=1.0).contains(&w.w_tau));
        }
    }

    #[test]
    fn high_speed_acc_dominated() {
        let w = phase_weights(1.8, 1.8);
        assert_eq!(w.w_a, 1.0);
        assert_eq!(w.w_tau, 0.0);
    }

    #[test]
    fn low_speed_torque_dominated() {
        let w = phase_weights(0.0, 1.8);
        assert_eq!(w.w_a, 0.0);
        assert_eq!(w.w_tau, 1.0);
    }

    #[test]
    fn trigger_fires_on_either_side() {
        let c = cfg();
        // fast regime: acceleration spike
        let fast = evaluate(4.5, 0.0, 2.0, &c);
        assert!(fast.triggered && fast.by_comp && !fast.by_red);
        // slow regime: torque spike
        let slow = evaluate(0.0, 4.5, 0.0, &c);
        assert!(slow.triggered && slow.by_red && !slow.by_comp);
        // calm: nothing
        let calm = evaluate(0.1, 0.1, 0.9, &c);
        assert!(!calm.triggered);
    }

    #[test]
    fn phase_weighting_suppresses_off_phase_modality() {
        let c = cfg();
        // a big torque anomaly during *fast transit* is down-weighted
        let fast_torque = evaluate(0.0, 4.5, 1.8, &c);
        assert!(!fast_torque.triggered);
        // the same anomaly at rest triggers
        let slow_torque = evaluate(0.0, 4.5, 0.0, &c);
        assert!(slow_torque.triggered);
    }

    #[test]
    fn ablation_flags() {
        let mut c = cfg();
        c.disable_comp = true;
        assert!(!evaluate(10.0, 0.0, 2.0, &c).triggered);
        c.disable_comp = false;
        c.disable_red = true;
        assert!(!evaluate(0.0, 10.0, 0.0, &c).triggered);
    }

    #[test]
    fn static_fusion_ignores_velocity() {
        let mut c = cfg();
        c.static_fusion = true;
        // torque anomaly triggers even at max speed under static fusion
        let o = evaluate(0.0, 4.5, 5.0, &c);
        assert!(o.triggered && o.by_red);
    }

    #[test]
    fn threshold_monotonicity() {
        // raising θ never turns a non-trigger into a trigger
        let mut lo = cfg();
        lo.theta_comp = 0.3;
        let mut hi = cfg();
        hi.theta_comp = 0.9;
        for z in [0.0, 0.2, 0.5, 0.8, 1.2, 3.0] {
            let t_lo = evaluate(z, 0.0, 2.0, &lo).triggered;
            let t_hi = evaluate(z, 0.0, 2.0, &hi).triggered;
            assert!(t_lo || !t_hi, "z={z}");
        }
    }

    #[test]
    fn importance_is_weighted_sum() {
        let c = cfg();
        let o = evaluate(1.0, 2.0, 0.9, &c);
        let w = phase_weights(0.9, c.v_max);
        assert!((o.importance - (w.w_a * 1.0 + w.w_tau * 2.0)).abs() < 1e-12);
    }
}

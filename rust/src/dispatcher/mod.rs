//! The RAPID dispatcher — the paper's L3 contribution.
//!
//! A stateful, O(1)-per-tick edge dispatcher (Algorithm 1) that fuses two
//! kinematic anomaly monitors through velocity-driven dynamic phase weights
//! and a dual threshold:
//!
//! * compatibility-optimal trigger: weighted joint-acceleration anomaly
//!   M̂_acc vs θ_comp (catches non-linear kinematic mutations),
//! * redundancy-aware trigger: windowed torque-variation anomaly M̂_τ vs
//!   θ_red (catches low-redundancy physical interaction).
//!
//! Interpretation note (DESIGN.md §6): Algorithm 1 writes both the
//! trigger-refill and the empty-queue refill as cloud queries, but the
//! paper's load accounting (Tables III–V: a 2.4 GB edge-resident slice
//! doing 139 ms of work per cycle) implies routine, *redundant-phase* chunk
//! generation runs on the edge model while *critical-phase* preemptions go
//! to the cloud — which is also the framework's stated design ("processing
//! redundant phases on the edge device and critical interactions in the
//! cloud", §I). We implement that reading: `Decision::RefillEdge` for an
//! empty queue, `Decision::OffloadCloud` for a dual-threshold trigger.

pub mod cooldown;
pub mod fusion;
pub mod queue;
pub mod rapid;

pub use cooldown::Cooldown;
pub use fusion::{phase_weights, FusionOutcome, PhaseWeights};
pub use queue::{ChunkQueue, ChunkSource, QueueStats};
pub use rapid::{Decision, RapidDispatcher, ReuseEvidence, TriggerEval};

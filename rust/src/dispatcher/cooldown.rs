//! Temporal cooldown (paper §V-B, Eq. 8): after an offload the trigger is
//! masked for C control steps so the fresh chunk can resolve the
//! interaction before the cloud is queried again (prevents network
//! flooding during sustained contact).

#[derive(Debug, Clone, Copy)]
pub struct Cooldown {
    limit: u32,
    c: u32,
}

impl Cooldown {
    pub fn new(limit: u32) -> Self {
        Cooldown { limit, c: 0 }
    }

    /// I_dispatch = I_trigger ∧ (c == 0)   (Eq. 8)
    pub fn ready(&self) -> bool {
        self.c == 0
    }

    /// Arm after an offload: c = C.
    pub fn arm(&mut self) {
        self.c = self.limit;
    }

    /// Per-control-step decay: c = max(c − 1, 0).
    pub fn tick(&mut self) {
        self.c = self.c.saturating_sub(1);
    }

    pub fn remaining(&self) -> u32 {
        self.c
    }

    pub fn limit(&self) -> u32 {
        self.limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_for_exactly_c_steps() {
        let mut cd = Cooldown::new(3);
        assert!(cd.ready());
        cd.arm();
        assert!(!cd.ready());
        cd.tick();
        assert!(!cd.ready());
        cd.tick();
        assert!(!cd.ready());
        cd.tick();
        assert!(cd.ready());
    }

    #[test]
    fn tick_saturates_at_zero() {
        let mut cd = Cooldown::new(2);
        cd.tick();
        cd.tick();
        assert!(cd.ready());
        assert_eq!(cd.remaining(), 0);
    }

    #[test]
    fn zero_limit_never_masks() {
        let mut cd = Cooldown::new(0);
        cd.arm();
        assert!(cd.ready());
    }

    #[test]
    fn rearm_resets() {
        let mut cd = Cooldown::new(4);
        cd.arm();
        cd.tick();
        cd.tick();
        cd.arm();
        assert_eq!(cd.remaining(), 4);
    }
}

//! Cached action-chunk queue Q (Algorithm 1 state).

use crate::robot::Jv;
use crate::CHUNK;
use std::collections::VecDeque;

/// Who generated the currently cached chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkSource {
    Edge,
    Cloud,
}

/// Lifetime queue statistics (fleet per-session summaries aggregate
/// these across sessions sharing one scheduler).
#[derive(Debug, Clone, Copy, Default)]
pub struct QueueStats {
    /// Chunk refills (overwrites) served into the queue.
    pub overwrites: u64,
    /// Actions dispatched to the robot.
    pub popped: u64,
    /// High-water mark of the queue length.
    pub max_len: usize,
}

/// FIFO of pending actions with provenance metadata. Capacity is the
/// chunk length k: an overwrite replaces, never extends, the cache, so
/// `len() <= capacity()` is a hard invariant.
#[derive(Debug, Clone)]
pub struct ChunkQueue {
    q: VecDeque<Jv>,
    source: Option<ChunkSource>,
    /// Control step at which the current chunk was issued (staleness).
    issued_at: usize,
    /// Total actions discarded by preemptions (paper's "action
    /// interruptions" accounting).
    pub discarded: u64,
    stats: QueueStats,
}

impl ChunkQueue {
    pub fn new() -> Self {
        ChunkQueue {
            q: VecDeque::with_capacity(CHUNK),
            source: None,
            issued_at: 0,
            discarded: 0,
            stats: QueueStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Maximum actions the cache can hold (one chunk).
    pub fn capacity(&self) -> usize {
        CHUNK
    }

    pub fn source(&self) -> Option<ChunkSource> {
        self.source
    }

    pub fn issued_at(&self) -> usize {
        self.issued_at
    }

    pub fn stats(&self) -> QueueStats {
        self.stats
    }

    /// Overwrite Q with a fresh chunk (Algorithm 1 line 7): any remaining
    /// actions are now-stale predictions and are discarded. At most one
    /// chunk (k actions) is cached; longer slices are truncated so the
    /// capacity invariant holds unconditionally.
    pub fn overwrite(&mut self, actions: &[Jv], source: ChunkSource, step: usize) {
        debug_assert!(actions.len() <= CHUNK, "chunk longer than k: {}", actions.len());
        self.discarded += self.q.len() as u64;
        self.q.clear();
        self.q.extend(actions.iter().take(CHUNK).copied());
        self.source = Some(source);
        self.issued_at = step;
        self.stats.overwrites += 1;
        self.stats.max_len = self.stats.max_len.max(self.q.len());
    }

    /// Pop the next action (Algorithm 1 line 9).
    pub fn pop(&mut self) -> Option<Jv> {
        let a = self.q.pop_front();
        if a.is_some() {
            self.stats.popped += 1;
        }
        a
    }

    /// Staleness of the cached chunk in control steps.
    pub fn staleness(&self, now: usize) -> usize {
        now.saturating_sub(self.issued_at)
    }
}

impl Default for ChunkQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(v: f64) -> Vec<Jv> {
        vec![Jv::splat(v); CHUNK]
    }

    #[test]
    fn fifo_order() {
        let mut q = ChunkQueue::new();
        q.overwrite(&[Jv::splat(1.0), Jv::splat(2.0)], ChunkSource::Edge, 0);
        assert_eq!(q.pop().unwrap()[0], 1.0);
        assert_eq!(q.pop().unwrap()[0], 2.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn overwrite_counts_discarded() {
        let mut q = ChunkQueue::new();
        q.overwrite(&chunk(1.0), ChunkSource::Edge, 0);
        q.pop();
        q.pop();
        q.overwrite(&chunk(2.0), ChunkSource::Cloud, 5);
        assert_eq!(q.discarded, (CHUNK - 2) as u64);
        assert_eq!(q.source(), Some(ChunkSource::Cloud));
        assert_eq!(q.len(), CHUNK);
    }

    #[test]
    fn staleness_tracks_issue_step() {
        let mut q = ChunkQueue::new();
        q.overwrite(&chunk(0.5), ChunkSource::Cloud, 10);
        assert_eq!(q.staleness(13), 3);
        assert_eq!(q.staleness(9), 0); // saturating
    }

    #[test]
    fn stats_track_traffic_and_high_water() {
        let mut q = ChunkQueue::new();
        q.overwrite(&chunk(1.0), ChunkSource::Edge, 0);
        q.pop();
        q.pop();
        q.overwrite(&chunk(2.0), ChunkSource::Cloud, 2);
        q.pop();
        let s = q.stats();
        assert_eq!(s.overwrites, 2);
        assert_eq!(s.popped, 3);
        assert_eq!(s.max_len, CHUNK);
        assert!(q.len() <= q.capacity());
    }
}

//! Cached action-chunk queue Q (Algorithm 1 state).

use crate::robot::Jv;
use crate::CHUNK;
use std::collections::VecDeque;

/// Who generated the currently cached chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkSource {
    Edge,
    Cloud,
}

/// FIFO of pending actions with provenance metadata.
#[derive(Debug, Clone)]
pub struct ChunkQueue {
    q: VecDeque<Jv>,
    source: Option<ChunkSource>,
    /// Control step at which the current chunk was issued (staleness).
    issued_at: usize,
    /// Total actions discarded by preemptions (paper's "action
    /// interruptions" accounting).
    pub discarded: u64,
}

impl ChunkQueue {
    pub fn new() -> Self {
        ChunkQueue { q: VecDeque::with_capacity(CHUNK), source: None, issued_at: 0, discarded: 0 }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn source(&self) -> Option<ChunkSource> {
        self.source
    }

    pub fn issued_at(&self) -> usize {
        self.issued_at
    }

    /// Overwrite Q with a fresh chunk (Algorithm 1 line 7): any remaining
    /// actions are now-stale predictions and are discarded.
    pub fn overwrite(&mut self, actions: &[Jv], source: ChunkSource, step: usize) {
        self.discarded += self.q.len() as u64;
        self.q.clear();
        self.q.extend(actions.iter().copied());
        self.source = Some(source);
        self.issued_at = step;
    }

    /// Pop the next action (Algorithm 1 line 9).
    pub fn pop(&mut self) -> Option<Jv> {
        self.q.pop_front()
    }

    /// Staleness of the cached chunk in control steps.
    pub fn staleness(&self, now: usize) -> usize {
        now.saturating_sub(self.issued_at)
    }
}

impl Default for ChunkQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(v: f64) -> Vec<Jv> {
        vec![Jv::splat(v); CHUNK]
    }

    #[test]
    fn fifo_order() {
        let mut q = ChunkQueue::new();
        q.overwrite(&[Jv::splat(1.0), Jv::splat(2.0)], ChunkSource::Edge, 0);
        assert_eq!(q.pop().unwrap()[0], 1.0);
        assert_eq!(q.pop().unwrap()[0], 2.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn overwrite_counts_discarded() {
        let mut q = ChunkQueue::new();
        q.overwrite(&chunk(1.0), ChunkSource::Edge, 0);
        q.pop();
        q.pop();
        q.overwrite(&chunk(2.0), ChunkSource::Cloud, 5);
        assert_eq!(q.discarded, (CHUNK - 2) as u64);
        assert_eq!(q.source(), Some(ChunkSource::Cloud));
        assert_eq!(q.len(), CHUNK);
    }

    #[test]
    fn staleness_tracks_issue_step() {
        let mut q = ChunkQueue::new();
        q.overwrite(&chunk(0.5), ChunkSource::Cloud, 10);
        assert_eq!(q.staleness(13), 3);
        assert_eq!(q.staleness(9), 0); // saturating
    }
}

//! Figure 3: correlation between joint torque (variation) and step-wise
//! redundancy (attention mass) — the empirical basis of the
//! redundancy-aware trigger.

use super::Backends;
use crate::config::{PolicyKind, SystemConfig};
use crate::robot::tasks::ALL_TASKS;
use crate::robot::TaskKind;
use crate::serve::run_episode;
use crate::util::stats::{pearson, spearman};

pub struct Fig3Data {
    /// Per task: (torque-variation series, attention-mass series, r, ρ).
    pub series: Vec<(TaskKind, Vec<f64>, Vec<f64>, f64, f64)>,
    /// Pooled correlations.
    pub pooled_pearson: f64,
    pub pooled_spearman: f64,
}

pub fn run(sys: &SystemConfig, backends: &mut Backends, episodes: usize) -> Fig3Data {
    let mut series = Vec::new();
    let mut all_dtau = Vec::new();
    let mut all_mass = Vec::new();
    for &task in &ALL_TASKS {
        let mut dtau_s = Vec::new();
        let mut mass_s = Vec::new();
        for ep in 0..episodes {
            let strategy = crate::policy::build(PolicyKind::CloudOnly, sys);
            let out = run_episode(
                sys,
                task,
                strategy,
                backends.edge.as_mut(),
                backends.cloud.as_mut(),
                sys.episode.seed ^ 0xF3 ^ (ep as u64) << 4 ^ task.instr_id() as u64,
                true,
            );
            let tl = out.trace.unwrap();
            // Eq. 5's signal: wrist-weighted torque variation |W_τ Δτ|
            let dtau = tl.values("dtau_w");
            let mass = tl.values("mass");
            for i in 1..dtau.len() {
                dtau_s.push(dtau[i]);
                mass_s.push(mass[i]);
            }
        }
        let r = pearson(&dtau_s, &mass_s);
        let rho = spearman(&dtau_s, &mass_s);
        all_dtau.extend_from_slice(&dtau_s);
        all_mass.extend_from_slice(&mass_s);
        series.push((task, dtau_s, mass_s, r, rho));
    }
    Fig3Data {
        pooled_pearson: pearson(&all_dtau, &all_mass),
        pooled_spearman: spearman(&all_dtau, &all_mass),
        series,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torque_correlates_with_redundancy_signal() {
        let sys = SystemConfig::default();
        let mut b = Backends::analytic(19);
        let data = run(&sys, &mut b, 2);
        // paper claims a "high correlation"; on the simulator we demand a
        // clearly positive pooled correlation
        assert!(data.pooled_pearson > 0.35, "pearson {}", data.pooled_pearson);
        assert!(data.pooled_spearman > 0.35, "spearman {}", data.pooled_spearman);
        for (task, _, _, r, _) in &data.series {
            assert!(*r > 0.2, "{}: r={r}", task.name());
        }
    }
}

//! Table II: attention distribution & step-wise action redundancy per task
//! (the full cloud model instrumented over whole episodes).

use super::Backends;
use crate::config::{PolicyKind, SystemConfig};
use crate::robot::tasks::ALL_TASKS;
use crate::robot::TaskKind;
use crate::serve::run_episode;
use crate::util::tablefmt::{pct, Table};
use crate::vla::attention::{redundancy_stats, RedundancyStats};

pub struct Tab2Row {
    pub task: TaskKind,
    pub stats: RedundancyStats,
}

/// Run instrumented episodes (Cloud-Only, so every step's attention mass
/// comes from the full model, as the paper's analysis does) and compute
/// redundancy statistics over the episode-long mass series.
pub fn run(sys: &SystemConfig, backends: &mut Backends, episodes: usize) -> (Table, Vec<Tab2Row>) {
    let mut rows = Vec::new();
    for &task in &ALL_TASKS {
        // concatenate normalized per-episode stats by averaging
        let mut agg: Option<RedundancyStats> = None;
        for ep in 0..episodes {
            let strategy = crate::policy::build(PolicyKind::CloudOnly, sys);
            let out = run_episode(
                sys,
                task,
                strategy,
                backends.edge.as_mut(),
                backends.cloud.as_mut(),
                sys.episode.seed ^ (ep as u64) << 8 ^ task.instr_id() as u64,
                true,
            );
            let mass = out.trace.unwrap().values("mass");
            if let Some(s) = redundancy_stats(&mass) {
                agg = Some(match agg {
                    None => s,
                    Some(a) => RedundancyStats {
                        len: s.len,
                        uniform: s.uniform,
                        p_red: 0.5 * (a.p_red + s.p_red),
                        p_crit: 0.5 * (a.p_crit + s.p_crit),
                        w_red: 0.5 * (a.w_red + s.w_red),
                        w_crit: 0.5 * (a.w_crit + s.w_crit),
                    },
                });
            }
        }
        rows.push(Tab2Row { task, stats: agg.expect("no mass data") });
    }
    let mut t = Table::new(
        "TABLE II — Attention distribution and action redundancy",
        &["Task Domain", "L", "1/L", "P_red", "P_crit", "W_red", "W_crit"],
    );
    for r in &rows {
        let s = &r.stats;
        t.row(&[
            r.task.name().to_string(),
            s.len.to_string(),
            format!("{:.3}", s.uniform),
            pct(s.p_red),
            pct(s.p_crit),
            format!("{:.4}", s.w_red),
            format!("{:.4}", s.w_crit),
        ]);
    }
    t.footnote(
        "P_red/P_crit: share of steps with normalized attention below/above the uniform \
         baseline 1/L.",
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn redundancy_dominates_all_tasks() {
        let sys = SystemConfig::default();
        let mut backends = Backends::analytic(5);
        let (_, rows) = run(&sys, &mut backends, 2);
        for r in &rows {
            // paper: redundant actions > 80%; we accept the 70%+ band
            assert!(r.stats.p_red > 0.7, "{}: p_red {}", r.task.name(), r.stats.p_red);
            // critical attention much heavier than redundant
            assert!(
                r.stats.w_crit > 3.0 * r.stats.w_red,
                "{}: w_crit {} w_red {}",
                r.task.name(),
                r.stats.w_crit,
                r.stats.w_red
            );
        }
        // sequence lengths match Table II
        assert_eq!(rows[0].stats.len, 50);
    }
}

//! Table I: performance of the vision-based dynamic strategy under
//! increasing noise (Standard / Visual Noise / Distraction) — latency up,
//! edge residency down, total parameter load constant.

use super::Backends;
use crate::config::{NoiseLevel, PolicyKind, SystemConfig};
use crate::metrics::aggregate;
use crate::robot::tasks::ALL_TASKS;
use crate::serve::session::run_policy;
use crate::util::tablefmt::{gb, ms, Table};

pub struct Tab1Row {
    pub noise: NoiseLevel,
    pub cloud_lat: f64,
    pub cloud_gb: f64,
    pub edge_lat: f64,
    pub edge_gb: f64,
    pub total_lat: f64,
    pub total_gb: f64,
}

pub fn run(
    sys_base: &SystemConfig,
    backends: &mut Backends,
    episodes: usize,
) -> (Table, Vec<Tab1Row>) {
    let mut rows = Vec::new();
    for noise in [NoiseLevel::Standard, NoiseLevel::VisualNoise, NoiseLevel::Distraction] {
        let mut sys = sys_base.clone();
        sys.scene.noise = noise;
        let res = run_policy(
            &sys,
            PolicyKind::VisionBased,
            &ALL_TASKS,
            episodes,
            backends.edge.as_mut(),
            backends.cloud.as_mut(),
        );
        let row = aggregate(PolicyKind::VisionBased, &res.episodes);
        rows.push(Tab1Row {
            noise,
            cloud_lat: row.cloud_lat_ms,
            cloud_gb: row.cloud_gb,
            edge_lat: row.edge_lat_ms,
            edge_gb: row.edge_gb,
            total_lat: row.total_lat_mean,
            total_gb: row.total_gb,
        });
    }
    let mut t = Table::new(
        "TABLE I — Vision-based dynamic strategy under noise",
        &[
            "Noise", "Cloud Lat.", "Cloud Load", "Edge Lat.", "Edge Load", "Total Lat.",
            "Total Load",
        ],
    );
    for r in &rows {
        t.row(&[
            r.noise.name().to_string(),
            ms(r.cloud_lat),
            gb(r.cloud_gb),
            ms(r.edge_lat),
            gb(r.edge_gb),
            ms(r.total_lat),
            gb(r.total_gb),
        ]);
    }
    t.footnote(
        "Lat. includes computation, transmission and dynamic routing overhead; Load = \
         parameters resident (GB).",
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_degrades_vision_baseline_with_constant_load() {
        let sys = SystemConfig::default();
        let mut backends = Backends::analytic(3);
        let (_, rows) = run(&sys, &mut backends, 2);
        assert_eq!(rows.len(), 3);
        // total latency increases monotonically with noise
        assert!(
            rows[0].total_lat < rows[1].total_lat,
            "std {} vs noise {}",
            rows[0].total_lat,
            rows[1].total_lat
        );
        assert!(
            rows[1].total_lat < rows[2].total_lat,
            "noise {} vs distract {}",
            rows[1].total_lat,
            rows[2].total_lat
        );
        // edge residency shrinks (split point moves cloudward)
        assert!(rows[2].edge_gb < rows[0].edge_gb);
        // total load is conserved in every row
        for r in &rows {
            assert!((r.total_gb - sys.total_model_gb).abs() < 1e-6);
        }
    }
}

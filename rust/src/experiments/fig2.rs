//! Figure 2: (a) the vision-based entropy trace breaches its threshold
//! under noise during routine motion but stays flat (below threshold) in
//! clean scenes; (b) kinematic scores peak only at critical interactions.

use super::Backends;
use crate::config::{NoiseLevel, PolicyKind, SystemConfig};
use crate::robot::TaskKind;
use crate::serve::run_episode;
use crate::util::timeline::Timeline;

pub struct Fig2Data {
    /// (noise level, entropy trace, phase trace (0=approach,1=interact,2=retract))
    pub entropy_traces: Vec<(NoiseLevel, Vec<f64>, Vec<f64>)>,
    /// kinematic trace from a clean RAPID run.
    pub kinematic: Timeline,
    pub entropy_threshold: f64,
}

pub fn run(sys_base: &SystemConfig, backends: &mut Backends) -> Fig2Data {
    let mut entropy_traces = Vec::new();
    for noise in [NoiseLevel::Standard, NoiseLevel::VisualNoise, NoiseLevel::Distraction] {
        let mut sys = sys_base.clone();
        sys.scene.noise = noise;
        // concatenate a few episodes so occlusion events are well sampled
        let mut entropy = Vec::new();
        let mut phase = Vec::new();
        for ep in 0..3u64 {
            let strategy = crate::policy::build(PolicyKind::VisionBased, &sys);
            let out = run_episode(
                &sys,
                TaskKind::PickPlace,
                strategy,
                backends.edge.as_mut(),
                backends.cloud.as_mut(),
                sys.episode.seed ^ 0xF2 ^ (ep << 8),
                true,
            );
            let tl = out.trace.unwrap();
            entropy.extend(tl.values("entropy"));
            phase.extend(tl.values("phase"));
        }
        entropy_traces.push((noise, entropy, phase));
    }
    // kinematic panel from a clean RAPID episode
    let sys = sys_base.clone();
    let strategy = crate::policy::build(PolicyKind::Rapid, &sys);
    let out = run_episode(
        &sys,
        TaskKind::PickPlace,
        strategy,
        backends.edge.as_mut(),
        backends.cloud.as_mut(),
        sys.episode.seed ^ 0xF2,
        true,
    );
    Fig2Data {
        entropy_traces,
        kinematic: out.trace.unwrap(),
        entropy_threshold: sys_base.vision.entropy_threshold,
    }
}

/// Fraction of *approach-phase* steps whose entropy breaches the threshold
/// — the paper's panel (a) focus: "the entropy frequently breaches the
/// offloading threshold during routine movements (e.g., the Approach
/// Phase)" under noise, and stays below it in clean scenes.
pub fn false_breach_rate(entropy: &[f64], phase: &[f64], threshold: f64) -> f64 {
    let routine: Vec<usize> = (0..entropy.len()).filter(|&i| phase[i] < 0.5).collect();
    if routine.is_empty() {
        return 0.0;
    }
    routine.iter().filter(|&&i| entropy[i] > threshold).count() as f64 / routine.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_breaches_grow_with_noise() {
        let sys = SystemConfig::default();
        let mut b = Backends::analytic(13);
        let data = run(&sys, &mut b);
        let rates: Vec<f64> = data
            .entropy_traces
            .iter()
            .map(|(_, e, c)| false_breach_rate(e, c, data.entropy_threshold))
            .collect();
        // clean scene: rarely/never breaches during routine motion
        assert!(rates[0] < 0.1, "standard false-breach {}", rates[0]);
        // both disturbance conditions breach substantially more than clean
        // (visual noise degrades every frame; distraction is episodic, so
        // its per-step rate is lower but still well above clean)
        assert!(rates[1] > rates[0] + 0.1, "rates {rates:?}");
        assert!(rates[2] > rates[0] + 0.05, "rates {rates:?}");
    }

    #[test]
    fn kinematic_scores_peak_in_critical_phases() {
        let sys = SystemConfig::default();
        let mut b = Backends::analytic(17);
        let data = run(&sys, &mut b);
        // Eq. 5's wrist-weighted torque variation, not the raw torque norm:
        // free-space torque changes live on the heavy proximal joints and
        // are suppressed by W_τ.
        let dtau = data.kinematic.values("dtau_w");
        let crit = data.kinematic.values("critical");
        let mean = |sel: bool| {
            let xs: Vec<f64> =
                (1..dtau.len()).filter(|&i| (crit[i] > 0.5) == sel).map(|i| dtau[i]).collect();
            xs.iter().sum::<f64>() / xs.len().max(1) as f64
        };
        assert!(mean(true) > 1.5 * mean(false), "crit {} vs routine {}", mean(true), mean(false));
    }
}

//! Reuse-cache experiment: the same fleet with the redundancy-aware
//! reuse cache off vs on, over clean and chaos links.
//!
//! The point the table makes: the cache converts the step-wise redundancy
//! the dispatcher already measures into *skipped cloud round trips* —
//! Cloud-Only's lockstep refills collapse onto shared answers, RAPID's
//! redundant-phase dispatches reuse the fleet's recent chunks while its
//! critical-phase triggers (gated by `cache.max_zscore`) still pay for a
//! fresh inference, and Edge-Only is untouched (no offloads, no probes —
//! its rows are bit-identical by construction). Under chaos, a warm cache
//! keeps serving cloud-grade chunks through outage/drop windows that
//! force the cache-off fleet into timeouts and edge degradation.

use crate::cache::CacheStats;
use crate::config::{FaultsConfig, PolicyKind, SystemConfig};
use crate::robot::TaskKind;
use crate::serve::Fleet;
use crate::util::tablefmt::{ms, pct, Table};

/// Policies compared by the reuse table.
pub const POLICIES: [PolicyKind; 3] =
    [PolicyKind::Rapid, PolicyKind::EdgeOnly, PolicyKind::CloudOnly];

pub struct ReuseRow {
    pub policy: PolicyKind,
    /// Fleet-aggregate total latency, clean link, cache off / on.
    pub clean_off_lat: f64,
    pub clean_on_lat: f64,
    /// Task success, clean link, cache off / on.
    pub clean_off_success: f64,
    pub clean_on_success: f64,
    /// Store counters of the clean cache-on arm.
    pub clean_cache: CacheStats,
    /// Cloud events (wire inferences) of the clean arms.
    pub clean_off_cloud: u64,
    pub clean_on_cloud: u64,
    /// The same fleet under the fault schedule, cache off / on.
    pub chaos_off_lat: f64,
    pub chaos_on_lat: f64,
    pub chaos_cache: CacheStats,
    /// Requests degraded to the edge after exhausting every endpoint
    /// (chaos arms) — a warm cache shrinks this.
    pub chaos_off_degraded: u64,
    pub chaos_on_degraded: u64,
    /// Every episode of every session completed in all four arms.
    pub completed: bool,
}

fn arm(
    sys: &SystemConfig,
    task: TaskKind,
    kind: PolicyKind,
) -> (f64, f64, u64, CacheStats, u64, bool) {
    let res = Fleet::local(sys, task, kind).run();
    let summary = res.summary();
    let expect = task.seq_len();
    let completed =
        res.sessions.iter().all(|s| s.episodes.iter().all(|m| m.steps == expect));
    (
        summary.fleet.total_lat_mean,
        summary.fleet.success_rate,
        summary.total_cloud_events,
        res.cache,
        res.stats.degraded_requests,
        completed,
    )
}

/// Run the four-arm comparison. Clean arms disable `sys.faults`; chaos
/// arms use `sys.faults` when enabled, else the built-in demo schedule.
/// The cache-on arms force `cache.enabled = true` with the `[cache]`
/// knobs carried by `sys`, the cache-off arms force it off.
pub fn run(sys: &SystemConfig, task: TaskKind) -> (Table, Vec<ReuseRow>) {
    let mut variants = Vec::new();
    for (faults_on, cache_on) in [(false, false), (false, true), (true, false), (true, true)] {
        let mut s = sys.clone();
        s.cache.enabled = cache_on;
        if faults_on {
            if !s.faults.enabled {
                s.faults = FaultsConfig::demo();
            }
        } else {
            s.faults.enabled = false;
        }
        variants.push(s);
    }

    let mut rows = Vec::new();
    for kind in POLICIES {
        let (clean_off_lat, clean_off_success, clean_off_cloud, _, _, c1) =
            arm(&variants[0], task, kind);
        let (clean_on_lat, clean_on_success, clean_on_cloud, clean_cache, _, c2) =
            arm(&variants[1], task, kind);
        let (chaos_off_lat, _, _, _, chaos_off_degraded, c3) = arm(&variants[2], task, kind);
        let (chaos_on_lat, _, _, chaos_cache, chaos_on_degraded, c4) =
            arm(&variants[3], task, kind);
        rows.push(ReuseRow {
            policy: kind,
            clean_off_lat,
            clean_on_lat,
            clean_off_success,
            clean_on_success,
            clean_cache,
            clean_off_cloud,
            clean_on_cloud,
            chaos_off_lat,
            chaos_on_lat,
            chaos_cache,
            chaos_off_degraded,
            chaos_on_degraded,
            completed: c1 && c2 && c3 && c4,
        });
    }

    let mut t = Table::new(
        &format!(
            "Reuse cache ({} × {} session(s), capacity {}, ttl {} rounds)",
            task.name(),
            sys.fleet.n_sessions.max(1),
            sys.cache.capacity,
            sys.cache.ttl_rounds
        ),
        &[
            "Method",
            "Clean Lat.",
            "+Cache",
            "Hit Rate",
            "Cloud Ev. (off->on)",
            "Success (off->on)",
            "Chaos Lat.",
            "+Cache",
            "Chaos Hits",
        ],
    );
    for r in &rows {
        t.row(&[
            r.policy.name().to_string(),
            ms(r.clean_off_lat),
            ms(r.clean_on_lat),
            pct(r.clean_cache.hit_rate()),
            format!("{} -> {}", r.clean_off_cloud, r.clean_on_cloud),
            format!("{} -> {}", pct(r.clean_off_success), pct(r.clean_on_success)),
            ms(r.chaos_off_lat),
            ms(r.chaos_on_lat),
            r.chaos_cache.hits.to_string(),
        ]);
    }
    t.footnote(
        "+Cache = the identical fleet with [cache] enabled. Hit Rate is the fleet-shared \
         store's hits/probes; every hit is an offload served at probe latency instead of a \
         wire round trip. Chaos arms run the [faults] schedule (demo when none configured).",
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemConfig {
        let mut s = SystemConfig::default();
        s.fleet.n_sessions = 8;
        s.fleet.max_batch = 4;
        s
    }

    #[test]
    fn cloud_only_cache_arm_hits_and_strictly_wins() {
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        let r = rows.iter().find(|r| r.policy == PolicyKind::CloudOnly).unwrap();
        assert!(r.completed);
        assert!(r.clean_cache.hits > 0, "lockstep fleet must share answers: {:?}", r.clean_cache);
        assert!(
            r.clean_on_lat < r.clean_off_lat,
            "hits must strictly cut latency: {} vs {}",
            r.clean_on_lat,
            r.clean_off_lat
        );
        // reused chunks come from another session's backend/obs stream, so
        // trajectories genuinely differ; the claim pinned here is that reuse
        // within the divergence budget never *costs* success (the strict
        // equality acceptance pin lives in rust/tests/reuse_cache.rs)
        assert!(
            r.clean_on_success >= r.clean_off_success,
            "reuse must not cost task success: {} vs {}",
            r.clean_on_success,
            r.clean_off_success
        );
        assert!(r.clean_on_cloud < r.clean_off_cloud, "hits replace wire inferences");
    }

    #[test]
    fn edge_only_rows_are_bit_identical() {
        // no offloads => no probes => the cache-on fleet is the cache-off
        // fleet, to the last bit
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        let r = rows.iter().find(|r| r.policy == PolicyKind::EdgeOnly).unwrap();
        assert_eq!(r.clean_on_lat, r.clean_off_lat);
        assert_eq!(r.chaos_on_lat, r.chaos_off_lat);
        assert_eq!(r.clean_cache.probes, 0);
        assert!(r.completed);
    }

    #[test]
    fn table_renders_all_policies() {
        let mut s = sys();
        s.fleet.n_sessions = 4;
        let (t, rows) = run(&s, TaskKind::PickPlace);
        assert_eq!(rows.len(), POLICIES.len());
        let rendered = t.render();
        for r in &rows {
            assert!(rendered.contains(r.policy.name().split(' ').next().unwrap()));
            assert!(r.completed, "{:?} wedged", r.policy);
        }
    }
}

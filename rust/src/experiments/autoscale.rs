//! Autoscaling control-plane experiment: static provisioning vs the
//! deterministic `[autoscale]` loop, under the composed chaos + open-loop
//! workload scenario the CLI's `rapid autoscale` runs.
//!
//! Four arms share one seed, fault schedule, and arrival process; only
//! the provisioning policy differs:
//!
//! * **static-min** — `[autoscale]` disabled, `fleet.endpoints` pinned to
//!   the scale floor. The under-provisioned baseline: every burst piles
//!   onto the same endpoints and queues absorb the overload.
//! * **static-max** — disabled, endpoints pinned to the scale ceiling.
//!   The over-provisioned oracle: latency is as good as capacity can
//!   make it, but every idle round pays for the full fleet.
//! * **autoscale** — the control loop spawns endpoint slots under
//!   sustained SLO pressure and drains them after sustained idleness.
//! * **autoscale+shed** — the loop plus the admission gate: past the
//!   shed threshold new offloads degrade to the edge slice instead of
//!   joining a backlog that would wedge the batcher.
//!
//! The point the table makes: the autoscale arm tracks static-max
//! latency while holding mean active endpoints near static-min, and the
//! shed arm bounds the observed in-flight high-water mark at the cost of
//! a few deferred offloads. Because the scaler is a pure function of
//! scheduler counters (no clocks, no PRNG), every arm replays exactly.

use crate::config::{PolicyKind, SystemConfig};
use crate::robot::TaskKind;
use crate::serve::Fleet;
use crate::util::tablefmt::{ms, pct, Table};

/// Policies compared by the autoscale table (the paper's contrast pair:
/// partitioned RAPID against the offload-everything baseline, which
/// generates the most cloud pressure and therefore the most scaling).
pub const POLICIES: [PolicyKind; 2] = [PolicyKind::Rapid, PolicyKind::CloudOnly];

/// Aggregate of one (policy, provisioning-arm) fleet run.
#[derive(Debug, Clone, Copy)]
pub struct ArmStats {
    /// Fleet-aggregate mean total latency per episode.
    pub lat: f64,
    /// Fleet task-success rate.
    pub success: f64,
    /// Cloud events (wire inferences).
    pub cloud_events: u64,
    /// Offloads degraded to the edge slice (backpressure + shed gate).
    pub deferred: u64,
    /// Autoscaler spawn / drain events (0 on the static arms).
    pub scale_up: u64,
    pub scale_down: u64,
    /// Ready polls refused cloud admission by the shed gate.
    pub shed_polls: u64,
    /// High-water mark of simultaneously active endpoints.
    pub max_endpoints: usize,
    /// Endpoints that served at least one dispatch.
    pub endpoints_used: usize,
    /// Every episode of every session ran to its full step count.
    pub completed: bool,
}

pub struct AutoscaleRow {
    pub policy: PolicyKind,
    /// `[autoscale]` disabled, endpoints pinned at the scale floor.
    pub static_min: ArmStats,
    /// Disabled, endpoints pinned at the scale ceiling.
    pub static_max: ArmStats,
    /// The control loop, admission shed off.
    pub auto: ArmStats,
    /// The control loop plus the shed gate.
    pub auto_shed: ArmStats,
}

fn arm(sys: &SystemConfig, task: TaskKind, kind: PolicyKind) -> ArmStats {
    let res = Fleet::local(sys, task, kind).run();
    let summary = res.summary();
    let expect = task.seq_len();
    let completed = res
        .sessions
        .iter()
        .flat_map(|s| s.episodes.iter())
        .all(|m| m.steps == expect);
    ArmStats {
        lat: summary.fleet.total_lat_mean,
        success: summary.fleet.success_rate,
        cloud_events: summary.total_cloud_events,
        deferred: res.stats.deferred_offloads,
        scale_up: res.stats.scale_up_events,
        scale_down: res.stats.scale_down_events,
        shed_polls: res.stats.shed_polls,
        max_endpoints: res.stats.max_endpoints_observed,
        endpoints_used: res.endpoint_dispatches.iter().filter(|&&d| d > 0).count(),
        completed,
    }
}

/// Build the four provisioning arms from a base system config. The base
/// config's `[autoscale]` section supplies the floor/ceiling and loop
/// knobs; the static arms clear `enabled` so they are the unmodified
/// scheduler verbatim at a fixed endpoint count. The shed arm keeps the
/// base `shed_queue` when set and otherwise derives one from `slo_queue`
/// so the gate actually engages.
pub fn arms(sys: &SystemConfig) -> [SystemConfig; 4] {
    let floor = sys.autoscale.min_endpoints.max(1);
    let ceiling = sys.autoscale.max_endpoints.max(floor);
    let shed = if sys.autoscale.shed_queue > 0 {
        sys.autoscale.shed_queue
    } else {
        sys.autoscale.slo_queue.max(1) * 2
    };
    let mk_static = |endpoints: usize| {
        let mut s = sys.clone();
        s.autoscale.enabled = false;
        s.fleet.endpoints = endpoints;
        s
    };
    let mk_auto = |shed_queue: usize| {
        let mut s = sys.clone();
        s.autoscale.enabled = true;
        s.autoscale.min_endpoints = floor;
        s.autoscale.max_endpoints = ceiling;
        s.autoscale.shed_queue = shed_queue;
        s
    };
    [mk_static(floor), mk_static(ceiling), mk_auto(0), mk_auto(shed)]
}

/// Run the four-arm provisioning comparison for each policy in
/// [`POLICIES`]. All arms share the caller's seed, fault schedule, and
/// workload; only provisioning differs.
pub fn run(sys: &SystemConfig, task: TaskKind) -> (Table, Vec<AutoscaleRow>) {
    let variants = arms(sys);
    let floor = sys.autoscale.min_endpoints.max(1);
    let ceiling = sys.autoscale.max_endpoints.max(floor);
    let mut rows = Vec::new();
    for kind in POLICIES {
        rows.push(AutoscaleRow {
            policy: kind,
            static_min: arm(&variants[0], task, kind),
            static_max: arm(&variants[1], task, kind),
            auto: arm(&variants[2], task, kind),
            auto_shed: arm(&variants[3], task, kind),
        });
    }

    let mut t = Table::new(
        &format!(
            "Autoscaling control plane ({} × {} session(s), endpoints {}..{}, slo_queue {}, \
             sustain {}, idle {}, cooldown {})",
            task.name(),
            sys.fleet.n_sessions.max(1),
            floor,
            ceiling,
            sys.autoscale.slo_queue,
            sys.autoscale.sustain_rounds,
            sys.autoscale.idle_rounds,
            sys.autoscale.cooldown_rounds,
        ),
        &[
            "Method",
            "Static-min",
            "Static-max",
            "Autoscale",
            "+Shed",
            "Scale (up/down)",
            "Peak eps",
            "Shed/Defer",
            "Success (min->auto)",
        ],
    );
    for r in &rows {
        t.row(&[
            r.policy.name().to_string(),
            ms(r.static_min.lat),
            ms(r.static_max.lat),
            ms(r.auto.lat),
            ms(r.auto_shed.lat),
            format!("{}/{}", r.auto.scale_up, r.auto.scale_down),
            format!("{}", r.auto.max_endpoints),
            format!("{}/{}", r.auto_shed.shed_polls, r.auto_shed.deferred),
            format!("{} -> {}", pct(r.static_min.success), pct(r.auto.success)),
        ]);
    }
    t.footnote(
        "Static arms run [autoscale] disabled (the unmodified scheduler) at the floor/ceiling \
         endpoint count. Autoscale spawns a pre-allocated endpoint slot after sustain_rounds of \
         queue > slo_queue x active and drains the highest idle slot after idle_rounds of \
         silence; the scaler reads only scheduler counters, so seeded replays are exact. +Shed \
         additionally degrades new offloads to the edge slice while the queue sits at or above \
         shed_queue, bounding the in-flight high-water mark.",
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemConfig {
        let mut s = SystemConfig::default();
        s.fleet.n_sessions = 8;
        s.fleet.max_batch = 16;
        s.fleet.max_inflight = 32;
        // one round of deadline batching: a held partial batch is what
        // the round-start scaler tick reads as backlog
        s.fleet.batch_deadline_us = 50_000;
        s.autoscale.min_endpoints = 1;
        s.autoscale.max_endpoints = 3;
        s.autoscale.slo_queue = 2;
        s.autoscale.sustain_rounds = 1;
        s.autoscale.idle_rounds = 1;
        s.autoscale.cooldown_rounds = 0;
        s
    }

    #[test]
    fn static_min_arm_is_the_unmodified_scheduler() {
        // arm 0 must be bit-identical to a plain run of the same config
        // with [autoscale] left at its shipped default (disabled) and the
        // endpoint count pinned at the floor — the full differential
        // acceptance pin lives in rust/tests/autoscale_plane.rs
        let base = sys();
        let (_, rows) = run(&base, TaskKind::PickPlace);
        let mut plain_cfg = base.clone();
        plain_cfg.autoscale = Default::default();
        plain_cfg.fleet.endpoints = 1;
        for kind in POLICIES {
            let plain = arm(&plain_cfg, TaskKind::PickPlace, kind);
            let r = rows.iter().find(|r| r.policy == kind).unwrap();
            assert_eq!(r.static_min.lat, plain.lat, "{:?}", kind);
            assert_eq!(r.static_min.success, plain.success, "{:?}", kind);
            assert_eq!(r.static_min.cloud_events, plain.cloud_events, "{:?}", kind);
            assert_eq!(r.static_min.scale_up, 0, "{:?}", kind);
            assert_eq!(r.static_min.scale_down, 0, "{:?}", kind);
        }
    }

    #[test]
    fn autoscale_arm_scales_and_completes() {
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        let r = rows.iter().find(|r| r.policy == PolicyKind::CloudOnly).unwrap();
        assert!(r.auto.completed, "autoscale arm wedged");
        assert!(r.auto_shed.completed, "shed arm wedged");
        assert!(r.auto.scale_up > 0, "pressure never spawned an endpoint");
        assert!(r.auto.scale_down > 0, "idle drain never fired");
        assert!(r.auto.max_endpoints > 1 && r.auto.max_endpoints <= 3);
        // the scaler never changes what work is done, only where it runs
        assert_eq!(r.auto.cloud_events, r.static_min.cloud_events);
    }

    #[test]
    fn runs_replay_exactly() {
        let base = sys();
        let (_, a) = run(&base, TaskKind::PickPlace);
        let (_, b) = run(&base, TaskKind::PickPlace);
        for (ra, rb) in a.iter().zip(b.iter()) {
            assert_eq!(ra.auto.lat.to_bits(), rb.auto.lat.to_bits());
            assert_eq!(ra.auto.scale_up, rb.auto.scale_up);
            assert_eq!(ra.auto.scale_down, rb.auto.scale_down);
            assert_eq!(ra.auto_shed.shed_polls, rb.auto_shed.shed_polls);
        }
    }

    #[test]
    fn table_renders_all_policies() {
        let (t, rows) = run(&sys(), TaskKind::PickPlace);
        assert_eq!(rows.len(), POLICIES.len());
        let rendered = t.render();
        for r in &rows {
            assert!(rendered.contains(r.policy.name().split(' ').next().unwrap()));
        }
    }
}

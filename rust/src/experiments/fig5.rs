//! Figure 5 case study: a pick-and-place episode timeline showing where
//! RAPID triggers cloud offloads relative to the task's physical phases
//! ("pick up the banana and put it into the blue bowl").

use super::Backends;
use crate::config::{PolicyKind, SystemConfig};
use crate::robot::TaskKind;
use crate::serve::run_episode;
use crate::util::timeline::Timeline;

pub struct Fig5Data {
    pub trace: Timeline,
    pub offload_steps: Vec<usize>,
    pub critical_windows: Vec<(usize, usize)>,
}

pub fn run(sys: &SystemConfig, backends: &mut Backends) -> Fig5Data {
    let strategy = crate::policy::build(PolicyKind::Rapid, sys);
    let out = run_episode(
        sys,
        TaskKind::PickPlace,
        strategy,
        backends.edge.as_mut(),
        backends.cloud.as_mut(),
        sys.episode.seed ^ 0xF5,
        true,
    );
    let trace = out.trace.unwrap();
    let offload = trace.values("offload");
    let critical = trace.values("critical");
    let offload_steps: Vec<usize> =
        offload.iter().enumerate().filter(|(_, &v)| v > 0.5).map(|(i, _)| i).collect();
    let mut windows = Vec::new();
    let mut start = None;
    for (i, &c) in critical.iter().enumerate() {
        match (start, c > 0.5) {
            (None, true) => start = Some(i),
            (Some(s), false) => {
                windows.push((s, i - 1));
                start = None;
            }
            _ => {}
        }
    }
    if let Some(s) = start {
        windows.push((s, critical.len() - 1));
    }
    Fig5Data { trace, offload_steps, critical_windows: windows }
}

/// Render a terminal timeline (used by the bench and the example).
pub fn render_ascii(data: &Fig5Data, width: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("saliency : {}\n", data.trace.sparkline("saliency", width)));
    out.push_str(&format!("tau      : {}\n", data.trace.sparkline("tau_norm", width)));
    out.push_str(&format!("mass     : {}\n", data.trace.sparkline("mass", width)));
    let n = data.trace.values("offload").len();
    let mut marks = vec!['·'; width.min(n)];
    for &s in &data.offload_steps {
        let pos = s * marks.len() / n.max(1);
        if pos < marks.len() {
            marks[pos] = '▲';
        }
    }
    out.push_str(&format!("offloads : {}\n", marks.iter().collect::<String>()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offloads_land_near_critical_windows() {
        let sys = SystemConfig::default();
        let mut b = Backends::analytic(23);
        let data = run(&sys, &mut b);
        assert!(!data.offload_steps.is_empty(), "no offloads in case study");
        assert!(!data.critical_windows.is_empty());
        // at least half of the offloads are within 3 steps of a critical window
        let near = data
            .offload_steps
            .iter()
            .filter(|&&s| {
                data.critical_windows
                    .iter()
                    .any(|&(a, b_)| s + 3 >= a && s <= b_ + 3)
            })
            .count();
        assert!(
            near * 2 >= data.offload_steps.len(),
            "near {near} of {}",
            data.offload_steps.len()
        );
    }

    #[test]
    fn ascii_render_nonempty() {
        let sys = SystemConfig::default();
        let mut b = Backends::analytic(29);
        let data = run(&sys, &mut b);
        let s = render_ascii(&data, 50);
        assert!(s.contains("offloads"));
        assert!(s.lines().count() >= 4);
    }
}

//! Experiment generators: one per table/figure in the paper's evaluation
//! (DESIGN.md §6 maps each to its bench binary). All generators are pure
//! functions of (config, backends, seed) and return render-ready tables
//! plus raw data, so benches, examples and the CLI share one code path.

pub mod arrivals;
pub mod autoscale;
pub mod degraded;
pub mod fig2;
pub mod fig3;
pub mod fig5;
pub mod hetero;
pub mod overhead;
pub mod pipeline;
pub mod reuse;
pub mod sweep;
pub mod tab1;
pub mod tab2;
pub mod tab345;
pub mod xpu;

use crate::vla::{AnalyticBackend, Backend};

/// Backend pair used by every experiment.
pub struct Backends {
    pub edge: Box<dyn Backend>,
    pub cloud: Box<dyn Backend>,
}

impl Backends {
    /// Fast analytic surrogates (unit tests, smoke runs, sweeps).
    pub fn analytic(seed: u64) -> Backends {
        Backends {
            edge: Box::new(AnalyticBackend::edge(seed)),
            cloud: Box::new(AnalyticBackend::cloud(seed)),
        }
    }

    /// Real AOT-compiled models via PJRT; falls back to analytic (with a
    /// warning) when artifacts are missing so every binary stays runnable.
    pub fn pjrt_or_analytic(seed: u64) -> Backends {
        match Self::try_pjrt() {
            Ok(b) => b,
            Err(e) => {
                eprintln!("[backends] PJRT unavailable ({e}); using analytic surrogates");
                Self::analytic(seed)
            }
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn try_pjrt() -> Result<Backends, String> {
        use crate::runtime::{ArtifactMeta, RuntimeClient};
        let meta = ArtifactMeta::load(ArtifactMeta::default_dir()).map_err(|e| e.to_string())?;
        let mut client = RuntimeClient::cpu().map_err(|e| e.to_string())?;
        let (edge, cloud) = client.load_standard(&meta).map_err(|e| e.to_string())?;
        Ok(Backends {
            edge: Box::new(crate::vla::PjrtBackend::new(edge)),
            cloud: Box::new(crate::vla::PjrtBackend::new(cloud)),
        })
    }

    /// Offline builds ship without the `pjrt` feature (the `xla` crate is
    /// not vendorable here); every caller falls back to the analytic pair.
    #[cfg(not(feature = "pjrt"))]
    pub fn try_pjrt() -> Result<Backends, String> {
        Err("built without the `pjrt` feature; using analytic surrogates".into())
    }
}

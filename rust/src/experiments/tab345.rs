//! Tables III, IV, V: the main comparisons.
//!
//! * Table III — LIBERO simulation: Edge-Only / Cloud-Only / SAFE / RAPID.
//! * Table IV — real-world preset:  Edge-Only / Cloud-Only / ISAR / RAPID.
//! * Table V  — ablation: w/o θ_comp, w/o θ_red, full RAPID.

use super::Backends;
use crate::config::{PolicyKind, SystemConfig};
use crate::metrics::PolicyRow;
use crate::serve::session::run_suite;
use crate::util::tablefmt::Table;

pub struct MainRows {
    pub rows: Vec<PolicyRow>,
}

impl MainRows {
    pub fn get(&self, k: PolicyKind) -> &PolicyRow {
        self.rows.iter().find(|r| r.policy == k).expect("missing policy row")
    }

    /// End-to-end speedup of RAPID over the vision baseline (the paper's
    /// 1.73× headline).
    pub fn speedup_vs_vision(&self) -> f64 {
        self.get(PolicyKind::VisionBased).total_lat_mean
            / self.get(PolicyKind::Rapid).total_lat_mean
    }
}

fn comparison(
    sys: &SystemConfig,
    backends: &mut Backends,
    kinds: &[PolicyKind],
    episodes: usize,
) -> MainRows {
    let results = run_suite(sys, kinds, episodes, backends.edge.as_mut(), backends.cloud.as_mut());
    MainRows { rows: results.into_iter().map(|r| r.row).collect() }
}

fn render(title: &str, rows: &MainRows, names: &[(PolicyKind, &str)]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Method", "Cloud Lat.", "Cloud Load", "Edge Lat.", "Edge Load", "Total Lat.",
            "Total Load",
        ],
    );
    for (k, name) in names {
        t.row(&rows.get(*k).table_cells(Some(name)));
    }
    t
}

/// Table III (LIBERO preset expected in `sys`).
pub fn tab3(sys: &SystemConfig, backends: &mut Backends, episodes: usize) -> (Table, MainRows) {
    let kinds =
        [PolicyKind::EdgeOnly, PolicyKind::CloudOnly, PolicyKind::VisionBased, PolicyKind::Rapid];
    let rows = comparison(sys, backends, &kinds, episodes);
    let t = render(
        "TABLE III — Edge-cloud collaborative inference on simulation benchmarks (LIBERO)",
        &rows,
        &[
            (PolicyKind::EdgeOnly, "Edge-Only"),
            (PolicyKind::CloudOnly, "Cloud-Only"),
            (PolicyKind::VisionBased, "SAFE (Vision-Based)"),
            (PolicyKind::Rapid, "RAPID (Ours)"),
        ],
    );
    (t, rows)
}

/// Table IV (real-world preset expected in `sys`).
pub fn tab4(sys: &SystemConfig, backends: &mut Backends, episodes: usize) -> (Table, MainRows) {
    let kinds =
        [PolicyKind::EdgeOnly, PolicyKind::CloudOnly, PolicyKind::VisionBased, PolicyKind::Rapid];
    let rows = comparison(sys, backends, &kinds, episodes);
    let t = render(
        "TABLE IV — Edge-cloud collaborative inference on real-world environments",
        &rows,
        &[
            (PolicyKind::EdgeOnly, "Edge-Only"),
            (PolicyKind::CloudOnly, "Cloud-Only"),
            (PolicyKind::VisionBased, "ISAR (Vision-Based)"),
            (PolicyKind::Rapid, "RAPID (Ours)"),
        ],
    );
    (t, rows)
}

/// Table V — dual-threshold ablation on the LIBERO preset.
pub fn tab5(sys: &SystemConfig, backends: &mut Backends, episodes: usize) -> (Table, MainRows) {
    let kinds = [PolicyKind::RapidNoComp, PolicyKind::RapidNoRed, PolicyKind::Rapid];
    let rows = comparison(sys, backends, &kinds, episodes);
    let t = render(
        "TABLE V — Ablation of dual-threshold partitioning (LIBERO)",
        &rows,
        &[
            (PolicyKind::RapidNoComp, "w/o theta_comp (Acc.)"),
            (PolicyKind::RapidNoRed, "w/o theta_red (Torque)"),
            (PolicyKind::Rapid, "RAPID (Ours)"),
        ],
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{libero_preset, realworld_preset};

    #[test]
    fn tab3_shape_matches_paper() {
        let sys = libero_preset();
        let mut b = Backends::analytic(7);
        let (_, rows) = tab3(&sys, &mut b, 2);
        let e = rows.get(PolicyKind::EdgeOnly).total_lat_mean;
        let c = rows.get(PolicyKind::CloudOnly).total_lat_mean;
        let v = rows.get(PolicyKind::VisionBased).total_lat_mean;
        let r = rows.get(PolicyKind::Rapid).total_lat_mean;
        // ordering: cloud < rapid < vision < edge
        assert!(c < r && r < v && v < e, "c={c:.0} r={r:.0} v={v:.0} e={e:.0}");
        // RAPID keeps a small edge footprint
        assert!((rows.get(PolicyKind::Rapid).edge_gb - 2.4).abs() < 1e-6);
        // speedup over vision in the paper's ballpark (>1.2x)
        assert!(rows.speedup_vs_vision() > 1.2, "speedup {}", rows.speedup_vs_vision());
    }

    #[test]
    fn tab5_ablation_ordering() {
        let sys = libero_preset();
        let mut b = Backends::analytic(9);
        let (_, rows) = tab5(&sys, &mut b, 2);
        let full = rows.get(PolicyKind::Rapid).total_lat_mean;
        let no_comp = rows.get(PolicyKind::RapidNoComp).total_lat_mean;
        let no_red = rows.get(PolicyKind::RapidNoRed).total_lat_mean;
        // paper: full < w/o comp < w/o red
        assert!(full < no_comp, "full {full} no_comp {no_comp}");
        assert!(no_comp < no_red, "no_comp {no_comp} no_red {no_red}");
    }

    #[test]
    fn tab4_realworld_slower_than_sim() {
        let mut b = Backends::analytic(11);
        let (_, sim_rows) = tab3(&libero_preset(), &mut b, 2);
        let (_, real_rows) = tab4(&realworld_preset(), &mut b, 2);
        assert!(
            real_rows.get(PolicyKind::Rapid).total_lat_mean
                > sim_rows.get(PolicyKind::Rapid).total_lat_mean * 0.9
        );
        assert!((real_rows.get(PolicyKind::Rapid).total_gb - 14.5).abs() < 1e-6);
    }
}

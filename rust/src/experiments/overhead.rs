//! Overhead analysis (paper §VI-D.2): RAPID's dispatching must stay a
//! marginal 5–7% of the system budget. Two views:
//!
//! * temporal — measured dispatcher CPU time per sensor tick vs the
//!   f_sensor tick budget (500 Hz ⇒ 2 ms/tick);
//! * spatial — history buffers + chunk queue footprint in KB.

use crate::config::SystemConfig;
use crate::dispatcher::RapidDispatcher;
use crate::robot::{Jv, SensorFrame};
use std::time::Instant;

pub struct OverheadReport {
    /// Mean dispatcher cost per sensor tick (ns), measured.
    pub tick_ns: f64,
    /// Share of the f_sensor tick budget consumed.
    pub tick_budget_frac: f64,
    /// Emulated end-to-end overhead share (overhead_ms / total latency)
    /// from a RAPID suite run — the paper's 5–7% claim.
    pub system_overhead_frac: f64,
    /// Dispatcher state footprint (bytes, analytic).
    pub state_bytes: usize,
}

/// Measure the raw dispatcher tick cost over `n` synthetic frames.
pub fn measure_tick_ns(sys: &SystemConfig, n: usize) -> f64 {
    let mut d = RapidDispatcher::new(&sys.dispatcher, 1.0 / sys.robot.sensor_hz);
    let mut frame = SensorFrame { step: 0, q: Jv::ZERO, dq: Jv::splat(0.2), tau: Jv::splat(1.0) };
    // warm
    for i in 0..256 {
        frame.step = i;
        d.observe(&frame);
    }
    let t0 = Instant::now();
    for i in 0..n {
        frame.step = i;
        frame.dq = Jv::splat(0.2 + 0.001 * (i % 7) as f64);
        frame.tau = Jv::splat(1.0 + 0.01 * (i % 5) as f64);
        d.observe(&frame);
        std::hint::black_box(d.last_eval());
    }
    t0.elapsed().as_nanos() as f64 / n as f64
}

/// Analytic dispatcher state footprint.
pub fn state_bytes(sys: &SystemConfig) -> usize {
    let d = &sys.dispatcher;
    // two rolling windows of f64 + the short torque window + queue of k
    // actions + constants
    8 * (d.window_acc + d.window_tau + d.w_tau) + crate::CHUNK * crate::N_JOINTS * 8 + 256
}

pub fn run(sys: &SystemConfig, system_overhead_frac: f64) -> OverheadReport {
    let tick_ns = measure_tick_ns(sys, 20_000);
    let budget_ns = 1e9 / sys.robot.sensor_hz;
    OverheadReport {
        tick_ns,
        tick_budget_frac: tick_ns / budget_ns,
        system_overhead_frac,
        state_bytes: state_bytes(sys),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatcher_tick_fits_sensor_budget() {
        let sys = SystemConfig::default();
        let r = run(&sys, 0.0);
        // 500 Hz budget = 2 ms; the dispatcher must use well under 5%
        assert!(
            r.tick_budget_frac < 0.05,
            "tick uses {:.3}% of budget",
            100.0 * r.tick_budget_frac
        );
        assert!(r.tick_ns > 0.0);
    }

    #[test]
    fn state_is_kilobytes() {
        let sys = SystemConfig::default();
        let b = state_bytes(&sys);
        assert!(b < 64 * 1024, "state {b} bytes");
    }
}

//! Dynamic-workload experiment: the same fleet served under different
//! open-loop arrival shapes (`[workload]` / `serve::workload`).
//!
//! The point the table makes: lockstep all-at-t0 arrivals are the *best
//! case* for cross-session batching (everyone offloads in the same
//! rounds), and the paper's latency win has to survive realistic shapes —
//! staggered joins thin the batches, Poisson jitter desynchronizes the
//! offload rounds, and bursty on-off traffic alternates between full
//! batches and drained lulls. RAPID's edge-resident routine phases make
//! it far less sensitive to the arrival shape than Cloud-Only, whose
//! per-chunk wire dependency pays for every lost co-batching opportunity.

use crate::config::{PolicyKind, SystemConfig, WorkloadConfig};
use crate::robot::TaskKind;
use crate::serve::Fleet;
use crate::util::tablefmt::{ms, pct, Table};

/// Policies compared by the arrivals table.
pub const POLICIES: [PolicyKind; 2] = [PolicyKind::Rapid, PolicyKind::CloudOnly];

/// One (shape, policy) cell of the comparison.
pub struct ArrivalRow {
    pub shape: &'static str,
    pub policy: PolicyKind,
    pub sessions: usize,
    /// Round of the last arrival (0 for lockstep shapes).
    pub last_arrival: u64,
    pub rounds: u64,
    /// Mean per-chunk total latency over every episode.
    pub mean_lat: f64,
    pub success: f64,
    pub batches: u64,
    pub mean_batch: f64,
    pub multi_session_batches: u64,
    pub max_active: usize,
    /// Every episode of every session ran to completion (no wedge).
    pub completed: bool,
}

/// The system config one arrival-shape arm runs (public so the CLI's
/// wedge path can re-run the exact failed arm with the flight recorder
/// armed).
pub fn shaped(sys: &SystemConfig, shape: &'static str) -> SystemConfig {
    let mut s = sys.clone();
    // every arm runs the SAME fleet ([fleet] knobs, default episode/family
    // draws): only the arrival shape varies, so rows are comparable even
    // when the caller's config carries its own [workload] section
    s.workload = WorkloadConfig::default();
    match shape {
        "lockstep" => s.workload.enabled = false,
        "staggered" => {
            s.workload.enabled = true;
            s.workload.arrivals = "fixed".into();
            s.workload.interarrival_rounds = 4.0;
        }
        "poisson" => {
            s.workload.enabled = true;
            s.workload.arrivals = "poisson".into();
            s.workload.interarrival_rounds = 6.0;
        }
        "bursty" => {
            s.workload.enabled = true;
            s.workload.arrivals = "bursty".into();
            s.workload.burst_len = 3;
            s.workload.idle_len = 10;
        }
        other => panic!("unknown arrival shape {other:?}"),
    }
    s
}

/// Arrival shapes compared by the table, in render order.
pub const SHAPES: [&str; 4] = ["lockstep", "staggered", "poisson", "bursty"];

/// Run the arrival-shape comparison. Fleet size and seeds come from
/// `sys.fleet` / `sys.episode`; the `[workload]` section is overridden
/// per shape (the `lockstep` arm runs with the engine disabled, so its
/// row doubles as the bit-identity anchor for the differential suite).
pub fn run(sys: &SystemConfig, task: TaskKind) -> (Table, Vec<ArrivalRow>) {
    let mut rows = Vec::new();
    for shape in SHAPES {
        let shaped_sys = shaped(sys, shape);
        for kind in POLICIES {
            let res = Fleet::local(&shaped_sys, task, kind).run();
            let summary = res.summary();
            let expect = task.seq_len();
            let completed = res
                .sessions
                .iter()
                .all(|s| s.episodes.iter().all(|m| m.steps == expect));
            rows.push(ArrivalRow {
                shape,
                policy: kind,
                sessions: res.sessions.len(),
                last_arrival: res.sessions.iter().map(|s| s.arrival_round).max().unwrap_or(0),
                rounds: res.stats.rounds,
                mean_lat: summary.fleet.total_lat_mean,
                success: summary.fleet.success_rate,
                batches: res.stats.batches,
                mean_batch: res.mean_batch,
                multi_session_batches: res.stats.multi_session_batches,
                max_active: res.stats.max_active_sessions,
                completed,
            });
        }
    }

    let mut t = Table::new(
        &format!(
            "Dynamic arrivals ({} × {} session(s), seed {})",
            task.name(),
            sys.fleet.n_sessions.max(1),
            sys.episode.seed
        ),
        &[
            "Arrivals", "Method", "Last Join", "Rounds", "Total Lat.", "Success", "Batches",
            "Mean Batch", "Multi-sess", "Peak Active",
        ],
    );
    for r in &rows {
        t.row(&[
            r.shape.to_string(),
            r.policy.name().to_string(),
            r.last_arrival.to_string(),
            r.rounds.to_string(),
            ms(r.mean_lat),
            pct(r.success),
            r.batches.to_string(),
            format!("{:.2}", r.mean_batch),
            r.multi_session_batches.to_string(),
            r.max_active.to_string(),
        ]);
    }
    t.footnote(
        "One fleet per (arrival shape, method): lockstep arrives everyone at round 0 (the \
         bit-identity anchor), staggered joins every 4 rounds, poisson draws seeded \
         exponential gaps (mean 6), bursty alternates 3 back-to-back joins with 10 idle \
         rounds. Every session completes its episodes regardless of shape (no wedge).",
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemConfig {
        let mut s = SystemConfig::default();
        s.fleet.n_sessions = 6;
        s
    }

    fn cell<'a>(rows: &'a [ArrivalRow], shape: &str, kind: PolicyKind) -> &'a ArrivalRow {
        rows.iter().find(|r| r.shape == shape && r.policy == kind).unwrap()
    }

    #[test]
    fn every_shape_completes_every_session() {
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        assert_eq!(rows.len(), SHAPES.len() * POLICIES.len());
        for r in &rows {
            assert!(r.completed, "{}/{:?} wedged", r.shape, r.policy);
            assert_eq!(r.sessions, 6);
            // at least two sessions must overlap in every shape (a poisson
            // tail can outlive an early departure, so != 6 is legal there)
            assert!(r.max_active >= 2, "{}: no overlap at all", r.shape);
            assert!(r.max_active <= 6, "{}", r.shape);
        }
        // the lockstep arm is fully co-resident by construction
        for kind in POLICIES {
            assert_eq!(cell(&rows, "lockstep", kind).max_active, 6);
        }
    }

    #[test]
    fn lockstep_row_equals_the_disabled_workload_fleet() {
        // the experiment's lockstep arm IS the plain fleet: same rounds,
        // same batches, same latency — the table-level bit-identity anchor
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        let base = Fleet::local(&sys(), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        let lock = cell(&rows, "lockstep", PolicyKind::CloudOnly);
        assert_eq!(lock.rounds, base.stats.rounds);
        assert_eq!(lock.batches, base.stats.batches);
        assert_eq!(lock.mean_lat, base.summary().fleet.total_lat_mean);
        assert_eq!(lock.last_arrival, 0);
    }

    #[test]
    fn staggered_shapes_stretch_the_run_and_thin_the_batches() {
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        let lock = cell(&rows, "lockstep", PolicyKind::CloudOnly);
        for shape in ["staggered", "poisson", "bursty"] {
            let r = cell(&rows, shape, PolicyKind::CloudOnly);
            assert!(r.last_arrival > 0, "{shape} never staggered an arrival");
            assert!(r.rounds > lock.rounds, "{shape} must outlast the lockstep run");
        }
        // lockstep is the best case for co-batching
        let stag = cell(&rows, "staggered", PolicyKind::CloudOnly);
        assert!(
            stag.mean_batch <= lock.mean_batch,
            "staggered arrivals can't beat lockstep co-batching: {} vs {}",
            stag.mean_batch,
            lock.mean_batch
        );
    }

    #[test]
    fn rows_replay_exactly_under_the_shared_seed() {
        let (_, a) = run(&sys(), TaskKind::PickPlace);
        let (_, b) = run(&sys(), TaskKind::PickPlace);
        for (ra, rb) in a.iter().zip(b.iter()) {
            assert_eq!(ra.rounds, rb.rounds, "{}/{:?}", ra.shape, ra.policy);
            assert_eq!(ra.mean_lat, rb.mean_lat);
            assert_eq!(ra.batches, rb.batches);
        }
    }

    #[test]
    fn table_renders_every_shape() {
        let (t, _) = run(&sys(), TaskKind::PickPlace);
        let rendered = t.render();
        for shape in SHAPES {
            assert!(rendered.contains(shape), "{shape} missing from table");
        }
    }
}

//! Degraded-network experiment: fleet runs of RAPID vs the static
//! Edge-Only / Cloud-Only partitionings under a deterministic fault
//! schedule (`[faults]` / `configs/chaos.toml`), side by side with the
//! same fleet under clean conditions.
//!
//! The point the table makes: Cloud-Only pays for every lost reply with a
//! full offload timeout + edge re-serve, Edge-Only is immune but slow
//! everywhere, and RAPID only exposes its (rare, critical-phase) offloads
//! to the chaos — the paper's partitioning argument extended from noisy
//! scenes to hostile networks.

use crate::config::{PolicyKind, SystemConfig};
use crate::robot::TaskKind;
use crate::serve::Fleet;
use crate::util::tablefmt::{ms, pct, Table};

/// Policies compared by the degraded-network table.
pub const POLICIES: [PolicyKind; 3] =
    [PolicyKind::Rapid, PolicyKind::EdgeOnly, PolicyKind::CloudOnly];

pub struct DegradedRow {
    pub policy: PolicyKind,
    /// Fleet-aggregate total latency under clean conditions.
    pub clean_lat: f64,
    /// The same fleet under the fault schedule.
    pub chaos_lat: f64,
    pub success: f64,
    pub cloud_events: u64,
    /// Per-episode failovers summed over the fleet (lost replies re-served
    /// from the edge slice).
    pub failovers: u64,
    /// Scheduler-level: requests degraded after exhausting every endpoint.
    pub degraded: u64,
    pub dropped_replies: u64,
    pub deferred: u64,
    /// Every episode of every session ran to completion (the no-wedge
    /// guarantee).
    pub completed: bool,
}

/// Run the comparison. `sys` carries the fault schedule in `sys.faults`
/// (the clean arm runs the identical fleet with faults disabled).
pub fn run(sys: &SystemConfig, task: TaskKind) -> (Table, Vec<DegradedRow>) {
    let mut clean_sys = sys.clone();
    clean_sys.faults.enabled = false;
    let mut rows = Vec::new();
    for kind in POLICIES {
        let clean = Fleet::local(&clean_sys, task, kind).run();
        let chaos = Fleet::local(sys, task, kind).run();
        let summary = chaos.summary();
        let failovers: u64 = chaos
            .sessions
            .iter()
            .flat_map(|s| s.episodes.iter())
            .map(|m| m.failovers)
            .sum();
        let expect = task.seq_len();
        let completed = chaos
            .sessions
            .iter()
            .all(|s| s.episodes.iter().all(|m| m.steps == expect));
        rows.push(DegradedRow {
            policy: kind,
            clean_lat: clean.summary().fleet.total_lat_mean,
            chaos_lat: summary.fleet.total_lat_mean,
            success: summary.fleet.success_rate,
            cloud_events: summary.total_cloud_events,
            failovers,
            degraded: chaos.stats.degraded_requests,
            dropped_replies: chaos.stats.dropped_replies,
            deferred: chaos.stats.deferred_offloads,
            completed,
        });
    }

    let mut t = Table::new(
        &format!(
            "Degraded-network fleet ({} × {} session(s), faults: {})",
            task.name(),
            sys.fleet.n_sessions.max(1),
            if sys.faults.enabled { "on" } else { "off" }
        ),
        &[
            "Method", "Clean Lat.", "Chaos Lat.", "Success", "Cloud Ev.", "Failovers", "Degraded",
            "Dropped", "Deferred",
        ],
    );
    for r in &rows {
        t.row(&[
            r.policy.name().to_string(),
            ms(r.clean_lat),
            ms(r.chaos_lat),
            pct(r.success),
            r.cloud_events.to_string(),
            r.failovers.to_string(),
            r.degraded.to_string(),
            r.dropped_replies.to_string(),
            r.deferred.to_string(),
        ]);
    }
    t.footnote(
        "Failovers = lost replies re-served from the edge slice after the offload timeout; \
         Degraded = requests that exhausted every endpoint; Deferred = offloads refused \
         under backpressure/outage. Every policy completes every episode (no wedged sessions).",
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FaultsConfig;

    #[test]
    fn all_policies_complete_under_total_reply_loss() {
        // the harshest schedule: single endpoint, every reply dropped, no
        // retries — Cloud-Only must fail over on every offload and still
        // finish every episode
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = 3;
        sys.faults = FaultsConfig {
            enabled: true,
            seed: 5,
            drop_prob: 1.0,
            drop_start: 0,
            drop_end: u64::MAX,
            max_retries: 0,
            ..FaultsConfig::default()
        };
        let (_, rows) = run(&sys, TaskKind::PickPlace);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.completed, "{:?} wedged", r.policy);
        }
        let by = |k: PolicyKind| rows.iter().find(|r| r.policy == k).unwrap();
        // Edge-Only never offloads: chaos cannot touch it
        assert_eq!(by(PolicyKind::EdgeOnly).failovers, 0);
        assert_eq!(by(PolicyKind::EdgeOnly).dropped_replies, 0);
        // Cloud-Only loses every reply and pays the timeout each time
        let cloud = by(PolicyKind::CloudOnly);
        assert!(cloud.failovers > 0, "failovers {}", cloud.failovers);
        assert!(cloud.degraded > 0);
        assert!(cloud.chaos_lat > cloud.clean_lat, "chaos must cost Cloud-Only latency");
        // RAPID offloads too (rarely) and records its failovers
        assert!(by(PolicyKind::Rapid).failovers > 0);
    }

    #[test]
    fn clean_arm_matches_a_faultless_run() {
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = 2;
        sys.faults = FaultsConfig::demo();
        let (_, rows) = run(&sys, TaskKind::PickPlace);
        let mut plain = sys.clone();
        plain.faults.enabled = false;
        let base = Fleet::local(&plain, TaskKind::PickPlace, PolicyKind::Rapid).run();
        let rapid = rows.iter().find(|r| r.policy == PolicyKind::Rapid).unwrap();
        assert_eq!(rapid.clean_lat, base.summary().fleet.total_lat_mean);
    }
}

//! Heterogeneous-fleet experiment: a mixed model-zoo fleet (`[models]`
//! enabled) served by RAPID vs the static Edge-Only / Cloud-Only
//! partitionings, reported **per model family**.
//!
//! The point the table makes: "compatibility-optimal" has to hold per
//! family, not on average — the AR family's expensive short-chunk cloud
//! calls, the diffusion family's heavy activations and the quantized
//! family's cheap edge slice all price the edge/cloud trade differently,
//! and RAPID (edge-resident routine phases + planner-chosen partition
//! points for its rare offloads) beats Cloud-Only's per-chunk wire cost
//! for **every** family at equal task success, while the family-keyed
//! batcher guarantees no cross-session batch ever mixes frame layouts.

use crate::config::{PolicyKind, SystemConfig};
use crate::robot::TaskKind;
use crate::serve::Fleet;
use crate::util::tablefmt::{ms, pct, Table};
use crate::vla::profile::ModelFamily;

/// Policies compared by the heterogeneous-fleet table.
pub const POLICIES: [PolicyKind; 3] =
    [PolicyKind::Rapid, PolicyKind::EdgeOnly, PolicyKind::CloudOnly];

/// One (policy, family) cell of the comparison.
pub struct HeteroRow {
    pub policy: PolicyKind,
    pub family: ModelFamily,
    pub sessions: usize,
    /// Mean per-chunk total latency over the family's episodes.
    pub mean_lat: f64,
    /// Task success rate over the family's episodes.
    pub success: f64,
    pub cloud_events: u64,
    pub batches: u64,
    /// Every episode of every session in this family completed.
    pub completed: bool,
}

/// Scheduler-level evidence per policy arm.
pub struct HeteroArm {
    pub policy: PolicyKind,
    /// Batches observed mixing model families (must be 0).
    pub mixed_family_batches: u64,
    pub family_flushes: u64,
    pub multi_session_batches: u64,
}

/// Run the mixed-fleet comparison. `sys.models` is forced on (with its
/// configured family list); fleet shape comes from `sys.fleet`.
pub fn run(sys: &SystemConfig, task: TaskKind) -> (Table, Vec<HeteroRow>, Vec<HeteroArm>) {
    let mut zoo_sys = sys.clone();
    zoo_sys.models.enabled = true;

    let mut rows = Vec::new();
    let mut arms = Vec::new();
    for kind in POLICIES {
        let res = Fleet::local(&zoo_sys, task, kind).run();
        arms.push(HeteroArm {
            policy: kind,
            mixed_family_batches: res.stats.mixed_family_batches,
            family_flushes: res.stats.family_flushes,
            multi_session_batches: res.stats.multi_session_batches,
        });
        let expect = task.seq_len();
        for t in &res.families {
            let fam_sessions: Vec<_> =
                res.sessions.iter().filter(|s| s.family == t.family).collect();
            let mut lat_sum = 0.0;
            let mut succ = 0usize;
            let mut episodes = 0usize;
            let mut completed = true;
            for s in &fam_sessions {
                for m in &s.episodes {
                    lat_sum += m.latency_columns().2;
                    succ += m.success as usize;
                    episodes += 1;
                    completed &= m.steps == expect;
                }
            }
            rows.push(HeteroRow {
                policy: kind,
                family: t.family,
                sessions: t.sessions,
                mean_lat: lat_sum / episodes.max(1) as f64,
                success: succ as f64 / episodes.max(1) as f64,
                cloud_events: t.cloud_events,
                batches: t.batches,
                completed,
            });
        }
    }

    let mut t = Table::new(
        &format!(
            "Heterogeneous model zoo ({} × {} session(s), families: {})",
            task.name(),
            sys.fleet.n_sessions.max(1),
            zoo_sys
                .models
                .family_list()
                .iter()
                .map(|f| f.name())
                .collect::<Vec<_>>()
                .join(", ")
        ),
        &["Method", "Family", "Sessions", "Total Lat.", "Success", "Cloud Ev.", "Batches"],
    );
    for r in &rows {
        t.row(&[
            r.policy.name().to_string(),
            r.family.name().to_string(),
            r.sessions.to_string(),
            ms(r.mean_lat),
            pct(r.success),
            r.cloud_events.to_string(),
            r.batches.to_string(),
        ]);
    }
    t.footnote(
        "Per-family rows of one mixed fleet per method: sessions are assigned families in \
         contiguous blocks, each session serves its family's backends at the planner-chosen \
         partition point, and cross-session cloud batches are family-keyed (zero mixed batches \
         by construction).",
    );
    (t, rows, arms)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemConfig {
        let mut s = SystemConfig::default();
        s.fleet.n_sessions = 8;
        s.fleet.max_batch = 4;
        s
    }

    fn cell<'a>(
        rows: &'a [HeteroRow],
        kind: PolicyKind,
        fam: ModelFamily,
    ) -> &'a HeteroRow {
        rows.iter().find(|r| r.policy == kind && r.family == fam).unwrap()
    }

    #[test]
    fn no_batch_ever_mixes_families() {
        let (_, rows, arms) = run(&sys(), TaskKind::PickPlace);
        assert_eq!(arms.len(), POLICIES.len());
        for a in &arms {
            assert_eq!(a.mixed_family_batches, 0, "{:?} mixed a batch", a.policy);
        }
        // the lockstep arm genuinely exercised the family seal AND
        // same-family cross-session coalescing
        let cloud = arms.iter().find(|a| a.policy == PolicyKind::CloudOnly).unwrap();
        assert!(cloud.family_flushes > 0, "family seal never fired");
        assert!(cloud.multi_session_batches > 0, "same-family blocks never coalesced");
        for r in &rows {
            assert!(r.completed, "{:?}/{:?} wedged", r.policy, r.family);
        }
    }

    #[test]
    fn rapid_beats_cloud_only_per_family_at_equal_success() {
        let (_, rows, _) = run(&sys(), TaskKind::PickPlace);
        for fam in [ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            let rapid = cell(&rows, PolicyKind::Rapid, fam);
            let cloud = cell(&rows, PolicyKind::CloudOnly, fam);
            assert!(
                rapid.mean_lat < cloud.mean_lat,
                "{fam:?}: RAPID {} !< Cloud-Only {}",
                rapid.mean_lat,
                cloud.mean_lat
            );
            assert_eq!(
                rapid.success, cloud.success,
                "{fam:?}: success must be equal ({} vs {})",
                rapid.success, cloud.success
            );
        }
    }

    #[test]
    fn family_economics_show_in_the_cells() {
        let (_, rows, _) = run(&sys(), TaskKind::PickPlace);
        // the short-chunk AR family refills more often than the
        // full-chunk diffusion family under Cloud-Only
        let ar = cell(&rows, PolicyKind::CloudOnly, ModelFamily::OpenVlaAr);
        let pi0 = cell(&rows, PolicyKind::CloudOnly, ModelFamily::Pi0Diffusion);
        let per_session = |r: &HeteroRow| r.cloud_events as f64 / r.sessions.max(1) as f64;
        assert!(
            per_session(ar) > per_session(pi0),
            "AR {} !> pi0 {}",
            per_session(ar),
            per_session(pi0)
        );
        // the quantized family's Edge-Only rows are the cheapest edge rows
        let eq = cell(&rows, PolicyKind::EdgeOnly, ModelFamily::EdgeQuant);
        let pe = cell(&rows, PolicyKind::EdgeOnly, ModelFamily::Pi0Diffusion);
        assert!(eq.mean_lat < pe.mean_lat, "quantized edge must be cheapest");
        // Edge-Only never offloads in any family
        for fam in [ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            assert_eq!(cell(&rows, PolicyKind::EdgeOnly, fam).cloud_events, 0);
            assert_eq!(cell(&rows, PolicyKind::EdgeOnly, fam).batches, 0);
        }
    }

    #[test]
    fn table_renders_every_family_cell() {
        let mut s = sys();
        s.fleet.n_sessions = 6;
        let (t, rows, _) = run(&s, TaskKind::PickPlace);
        assert_eq!(rows.len(), POLICIES.len() * 3, "3 families × 3 policies");
        let rendered = t.render();
        for fam in [ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
            assert!(rendered.contains(fam.name()), "{fam:?} missing from table");
        }
    }
}

//! Pipelined-execution experiment: the same fleet with the `[pipeline]`
//! stage off vs on, crossed with speculative edge decoding off vs on.
//!
//! The point the table makes: sequential offloads pay
//! `edge_prefix + wire + cloud` per step; overlap hides the prefix under
//! the in-flight round trip (`max` instead of the sum), and speculation
//! hides the round trip itself behind a provisional edge chunk that the
//! cloud reply confirms for free or corrects for a bounded rollback
//! penalty. With the zoo disabled there is no family plan and therefore
//! no edge prefix: the overlap column is provably bit-identical to
//! sequential and only speculation moves the numbers. A zoo fleet
//! planned under a slow link picks deep splits with real prefix
//! compute, and there overlap pays off for every policy that offloads.
//! The z-score gate shared with `[cache]` keeps anomalous phases
//! sequential, so the speculation column degrades toward the baseline
//! (never below it) under noise.

use crate::config::{PolicyKind, SystemConfig};
use crate::robot::TaskKind;
use crate::serve::Fleet;
use crate::util::tablefmt::{ms, pct, Table};

/// Policies compared by the pipeline table (the paper's contrast pair:
/// partitioned RAPID against the offload-everything baseline).
pub const POLICIES: [PolicyKind; 2] = [PolicyKind::Rapid, PolicyKind::CloudOnly];

/// Aggregate of one (policy, pipeline-arm) fleet run.
#[derive(Debug, Clone, Copy)]
pub struct ArmStats {
    /// Fleet-aggregate mean total latency per episode.
    pub lat: f64,
    /// Fleet task-success rate.
    pub success: f64,
    /// Cloud events (wire inferences).
    pub cloud_events: u64,
    /// Edge-prefix milliseconds hidden under in-flight round trips
    /// (overlap arms only; 0 elsewhere).
    pub hidden_ms: f64,
    /// Speculative dispatches / confirmed / rolled back (spec arms only).
    pub spec_dispatches: u64,
    pub spec_confirms: u64,
    pub spec_rollbacks: u64,
    /// Every episode of every session ran to its full step count.
    pub completed: bool,
}

pub struct PipelineRow {
    pub policy: PolicyKind,
    /// `[pipeline]` disabled — the PR 6 sequential scheduler.
    pub seq: ArmStats,
    /// Overlap only (`overlap = true, speculate = false`).
    pub overlap: ArmStats,
    /// Speculation only (`overlap = false, speculate = true`).
    pub spec: ArmStats,
    /// Both stages on.
    pub both: ArmStats,
}

fn arm(sys: &SystemConfig, task: TaskKind, kind: PolicyKind) -> ArmStats {
    let res = Fleet::local(sys, task, kind).run();
    let summary = res.summary();
    let expect = task.seq_len();
    let mut hidden_ms = 0.0;
    let (mut disp, mut conf, mut roll) = (0u64, 0u64, 0u64);
    let mut completed = true;
    for m in res.sessions.iter().flat_map(|s| s.episodes.iter()) {
        hidden_ms += m.overlap_hidden_ms;
        disp += m.spec_dispatches;
        conf += m.spec_confirms;
        roll += m.spec_rollbacks;
        completed &= m.steps == expect;
    }
    ArmStats {
        lat: summary.fleet.total_lat_mean,
        success: summary.fleet.success_rate,
        cloud_events: summary.total_cloud_events,
        hidden_ms,
        spec_dispatches: disp,
        spec_confirms: conf,
        spec_rollbacks: roll,
        completed,
    }
}

/// Build the four `[pipeline]` arm configs from a base system config:
/// sequential (disabled), overlap-only, speculation-only, both. The
/// sequential arm clears `enabled` so it is the PR 6 scheduler verbatim;
/// the other knobs (`spec_decode_ms`, `rollback_ms`, `accept_eps`,
/// `max_zscore`) are carried from `sys` unchanged.
pub fn arms(sys: &SystemConfig) -> [SystemConfig; 4] {
    let mk = |enabled: bool, overlap: bool, speculate: bool| {
        let mut s = sys.clone();
        s.pipeline.enabled = enabled;
        s.pipeline.overlap = overlap;
        s.pipeline.speculate = speculate;
        s
    };
    [mk(false, false, false), mk(true, true, false), mk(true, false, true), mk(true, true, true)]
}

/// Run the four-arm comparison (pipeline off/on x speculation off/on)
/// for each policy in [`POLICIES`]. All arms share the caller's seed,
/// fleet shape, and fault schedule; only the `[pipeline]` stage differs.
pub fn run(sys: &SystemConfig, task: TaskKind) -> (Table, Vec<PipelineRow>) {
    let variants = arms(sys);
    let mut rows = Vec::new();
    for kind in POLICIES {
        rows.push(PipelineRow {
            policy: kind,
            seq: arm(&variants[0], task, kind),
            overlap: arm(&variants[1], task, kind),
            spec: arm(&variants[2], task, kind),
            both: arm(&variants[3], task, kind),
        });
    }

    let mut t = Table::new(
        &format!(
            "Pipelined execution ({} × {} session(s), spec_decode {} ms, rollback {} ms, eps {})",
            task.name(),
            sys.fleet.n_sessions.max(1),
            sys.pipeline.spec_decode_ms,
            sys.pipeline.rollback_ms,
            sys.pipeline.accept_eps
        ),
        &[
            "Method",
            "Sequential",
            "+Overlap",
            "+Spec",
            "+Both",
            "Hidden",
            "Spec (conf/roll)",
            "Success (seq->both)",
        ],
    );
    for r in &rows {
        t.row(&[
            r.policy.name().to_string(),
            ms(r.seq.lat),
            ms(r.overlap.lat),
            ms(r.spec.lat),
            ms(r.both.lat),
            ms(r.overlap.hidden_ms),
            format!("{}/{}", r.both.spec_confirms, r.both.spec_rollbacks),
            format!("{} -> {}", pct(r.seq.success), pct(r.both.success)),
        ]);
    }
    t.footnote(
        "Sequential = [pipeline] disabled (bit-identical to the plain scheduler). +Overlap \
         hides the step t+1 edge prefix under the in-flight round trip; Hidden is the total \
         prefix time so absorbed. +Spec serves a provisional edge chunk immediately — conf \
         replies cost nothing, roll replies re-charge the rollback penalty and adopt the \
         cloud suffix. The [cache] z-score gate keeps anomalous phases sequential.",
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemConfig {
        let mut s = SystemConfig::default();
        s.fleet.n_sessions = 6;
        s.fleet.max_batch = 3;
        s
    }

    #[test]
    fn sequential_arm_is_the_unmodified_scheduler() {
        // arm 0 must be bit-identical to a run of the caller's config with
        // [pipeline] untouched (shipped disabled) — the differential
        // acceptance pin lives in rust/tests/pipeline_exec.rs
        let base = sys();
        let (_, rows) = run(&base, TaskKind::PickPlace);
        for kind in POLICIES {
            let plain = arm(&base, TaskKind::PickPlace, kind);
            let r = rows.iter().find(|r| r.policy == kind).unwrap();
            assert_eq!(r.seq.lat, plain.lat, "{:?}", kind);
            assert_eq!(r.seq.success, plain.success, "{:?}", kind);
            assert_eq!(r.seq.cloud_events, plain.cloud_events, "{:?}", kind);
        }
    }

    #[test]
    fn cloud_only_overlap_arm_is_bit_identical_to_sequential() {
        // zoo disabled => no family plan => no edge prefix => nothing to
        // hide => overlap is provably a no-op
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        let r = rows.iter().find(|r| r.policy == PolicyKind::CloudOnly).unwrap();
        assert_eq!(r.overlap.lat, r.seq.lat);
        assert_eq!(r.overlap.hidden_ms, 0.0);
        assert!(r.overlap.completed);
    }

    #[test]
    fn rapid_pipeline_strictly_cuts_latency_at_no_success_cost() {
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        let r = rows.iter().find(|r| r.policy == PolicyKind::Rapid).unwrap();
        assert!(r.seq.completed && r.overlap.completed && r.spec.completed && r.both.completed);
        assert!(
            r.both.lat < r.seq.lat,
            "pipeline+speculation must strictly beat sequential: {} vs {}",
            r.both.lat,
            r.seq.lat
        );
        assert!(r.spec.lat < r.seq.lat, "speculation alone hides round trips");
        assert!(r.both.spec_dispatches > 0);
        assert_eq!(
            r.both.spec_confirms + r.both.spec_rollbacks,
            r.both.spec_dispatches,
            "every speculation resolves"
        );
        // confirmed chunks are within accept_eps of the cloud answer and
        // rollbacks adopt the cloud suffix, so tracking stays inside the
        // sim's success envelope
        assert!(r.both.success >= r.seq.success);
    }

    #[test]
    fn overlap_hides_prefix_on_deep_splits() {
        // a zoo fleet planned under a slow link picks deep splits with
        // real prefix compute: the overlap arm must hide some of it and
        // get strictly cheaper without moving a single cloud event
        let mut s = sys();
        s.models.enabled = true;
        s.link.bw_mbps = 20.0;
        s.link.rtt_ms = 40.0;
        let (_, rows) = run(&s, TaskKind::PickPlace);
        for r in &rows {
            assert!(r.overlap.completed, "{:?}", r.policy);
            assert!(r.overlap.hidden_ms > 0.0, "{:?} hides no prefix", r.policy);
            assert!(
                r.overlap.lat < r.seq.lat,
                "{:?}: overlap must be cheaper: {} vs {}",
                r.policy,
                r.overlap.lat,
                r.seq.lat
            );
            // overlap restructures charges only — identical draws,
            // trajectory, and offload pattern
            assert_eq!(r.overlap.cloud_events, r.seq.cloud_events, "{:?}", r.policy);
            assert_eq!(r.overlap.success, r.seq.success, "{:?}", r.policy);
        }
    }

    #[test]
    fn table_renders_all_policies() {
        let (t, rows) = run(&sys(), TaskKind::PickPlace);
        assert_eq!(rows.len(), POLICIES.len());
        let rendered = t.render();
        for r in &rows {
            assert!(rendered.contains(r.policy.name().split(' ').next().unwrap()));
        }
    }
}

//! Hyper-parameter sweep (paper §VI-D.1): latency/load trade-off across
//! (θ_comp, θ_red) — high thresholds starve the cloud, low thresholds
//! flood the network; (0.65, 0.35) is the paper's optimum.

use super::Backends;
use crate::config::{PolicyKind, SystemConfig};
use crate::metrics::aggregate;
use crate::robot::tasks::ALL_TASKS;
use crate::serve::session::run_policy;
use crate::util::tablefmt::Table;

pub struct SweepPoint {
    pub theta_comp: f64,
    pub theta_red: f64,
    pub total_lat: f64,
    pub cloud_events_per_ep: f64,
    pub success_rate: f64,
}

pub fn run(
    sys_base: &SystemConfig,
    backends: &mut Backends,
    comps: &[f64],
    reds: &[f64],
    episodes: usize,
) -> (Table, Vec<SweepPoint>) {
    let mut points = Vec::new();
    for &tc in comps {
        for &tr in reds {
            let mut sys = sys_base.clone();
            sys.dispatcher.theta_comp = tc;
            sys.dispatcher.theta_red = tr;
            let res = run_policy(
                &sys,
                PolicyKind::Rapid,
                &ALL_TASKS,
                episodes,
                backends.edge.as_mut(),
                backends.cloud.as_mut(),
            );
            let row = aggregate(PolicyKind::Rapid, &res.episodes);
            let cloud_events =
                res.episodes.iter().map(|m| m.cloud_events as f64).sum::<f64>()
                    / res.episodes.len() as f64;
            points.push(SweepPoint {
                theta_comp: tc,
                theta_red: tr,
                total_lat: row.total_lat_mean,
                cloud_events_per_ep: cloud_events,
                success_rate: row.success_rate,
            });
        }
    }
    let mut t = Table::new(
        "Hyper-parameter sweep (theta_comp x theta_red)",
        &["theta_comp", "theta_red", "Total Lat.", "Cloud events/ep", "Success"],
    );
    for p in &points {
        t.row(&[
            format!("{:.2}", p.theta_comp),
            format!("{:.2}", p.theta_red),
            format!("{:.1}ms", p.total_lat),
            format!("{:.1}", p.cloud_events_per_ep),
            format!("{:.0}%", 100.0 * p.success_rate),
        ]);
    }
    (t, points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lower_thresholds_mean_more_offloads() {
        let sys = SystemConfig::default();
        let mut b = Backends::analytic(31);
        let (_, pts) = run(&sys, &mut b, &[0.2, 2.5], &[0.35], 1);
        // θ_comp = 0.2 must offload at least as much as θ_comp = 2.5
        assert!(
            pts[0].cloud_events_per_ep >= pts[1].cloud_events_per_ep,
            "low {} high {}",
            pts[0].cloud_events_per_ep,
            pts[1].cloud_events_per_ep
        );
    }
}

//! Device-heterogeneity (XPU) experiment: the same chaos + workload
//! scenario served by a homogeneous cloudlet fleet vs a mixed
//! lite/nx/agx zoo, for each policy in [`POLICIES`].
//!
//! Two arms share one seed, fault schedule, and arrival process; only
//! `[devices] classes` differs:
//!
//! * **uniform** — the device zoo disabled: every slot is the implicit
//!   cloudlet, bit-identical to the class-free scheduler.
//! * **mixed** — `classes = "lite,nx,agx"`: block-assigned device
//!   classes, each planning over its own (class, family, link) triple —
//!   class budget filters the split catalog, class compute scale shifts
//!   the argmin, and the lite/nx grids snap served actions.
//!
//! The point the table makes: the mixed fleet still completes (no class
//! wedges the batcher), weak silicon pays visibly higher latency, and
//! the partition matrix shows *why* — a lite robot provably picks a
//! shallower split (or degrades to edge-only) where a cloudlet offloads
//! deep. Classes change per-slot physics only, never the shared
//! schedule, so seeded replays stay exact.

use crate::config::{PolicyKind, SystemConfig};
use crate::policy::planner;
use crate::robot::TaskKind;
use crate::runtime::DeviceClass;
use crate::serve::Fleet;
use crate::util::tablefmt::{ms, pct, Table};
use crate::vla::profile::{FamilyProfile, ModelFamily};

/// Policies compared by the XPU table (the paper's contrast pair:
/// partitioned RAPID against the offload-everything baseline, which is
/// blind to edge silicon and so shows the smallest class spread).
pub const POLICIES: [PolicyKind; 2] = [PolicyKind::Rapid, PolicyKind::CloudOnly];

/// Class mix the mixed arm runs (block-assigned across the fleet).
pub const MIXED_CLASSES: &str = "lite,nx,agx";

/// Per-class slice of one mixed-fleet run.
#[derive(Debug, Clone, Copy)]
pub struct ClassLat {
    pub class: DeviceClass,
    pub sessions: usize,
    pub steps: u64,
    pub cloud_events: u64,
    /// Mean emulated episode time (edge + cloud + overhead) per episode.
    pub mean_ep_ms: f64,
}

/// Aggregate of one (policy, arm) fleet run.
#[derive(Debug, Clone)]
pub struct ArmStats {
    /// Fleet-aggregate mean total latency per episode.
    pub lat: f64,
    /// Fleet task-success rate.
    pub success: f64,
    /// Cloud events (wire inferences).
    pub cloud_events: u64,
    /// Every episode of every session ran to its full step count.
    pub completed: bool,
    /// Per-class rollup (single cloudlet row on the uniform arm).
    pub classes: Vec<ClassLat>,
}

pub struct XpuRow {
    pub policy: PolicyKind,
    /// `[devices]` disabled: the class-free scheduler verbatim.
    pub uniform: ArmStats,
    /// `classes = "lite,nx,agx"` over the same workload.
    pub mixed: ArmStats,
}

/// One (class, family) cell of the partition matrix: the split index the
/// planner picks under the nominal link, and whether the class budget
/// degraded the family to edge-only.
#[derive(Debug, Clone, Copy)]
pub struct MatrixCell {
    pub class: DeviceClass,
    pub family: ModelFamily,
    pub partition_idx: usize,
    pub edge_only: bool,
}

fn arm(sys: &SystemConfig, task: TaskKind, kind: PolicyKind) -> ArmStats {
    let res = Fleet::local(sys, task, kind).run();
    let summary = res.summary();
    let expect = task.seq_len();
    let completed =
        res.sessions.iter().flat_map(|s| s.episodes.iter()).all(|m| m.steps == expect);
    let classes = res
        .classes
        .iter()
        .map(|t| {
            let (mut busy, mut eps) = (0.0, 0u64);
            for s in res.sessions.iter().filter(|s| s.class == t.class) {
                for m in &s.episodes {
                    busy += m.edge_busy_ms + m.cloud_busy_ms + m.overhead_ms;
                    eps += 1;
                }
            }
            ClassLat {
                class: t.class,
                sessions: t.sessions,
                steps: t.steps,
                cloud_events: t.cloud_events,
                mean_ep_ms: if eps > 0 { busy / eps as f64 } else { 0.0 },
            }
        })
        .collect();
    ArmStats {
        lat: summary.fleet.total_lat_mean,
        success: summary.fleet.success_rate,
        cloud_events: res.total_cloud_events(),
        completed,
        classes,
    }
}

/// The two arms from a base system config: `[devices]` cleared (the
/// unmodified scheduler) and the [`MIXED_CLASSES`] zoo. Everything else
/// — seed, faults, workload, `[models]` — is shared verbatim.
pub fn arms(sys: &SystemConfig) -> [SystemConfig; 2] {
    let mut uniform = sys.clone();
    uniform.devices.classes.clear();
    let mut mixed = sys.clone();
    mixed.devices.classes = MIXED_CLASSES.into();
    [uniform, mixed]
}

/// The (class × family) partition choices under the nominal link: the
/// planner run once per cell with the class's catalog budget and compute
/// scale, an idle nominal endpoint, and no overrides. Pure — zero fleet
/// state — so the matrix doubles as planner documentation.
pub fn partition_matrix(sys: &SystemConfig) -> Vec<MatrixCell> {
    let (bw, rtt) = (sys.link.bw_mbps, sys.link.rtt_ms);
    let mut cells = Vec::with_capacity(DeviceClass::ALL.len() * ModelFamily::ALL.len());
    for &class in DeviceClass::ALL.iter() {
        for &family in ModelFamily::ALL.iter() {
            let prof = FamilyProfile::of(family);
            let budget = planner::DeviceBudget::for_class(class);
            let load = planner::EndpointLoad::NOMINAL;
            let plan = planner::plan_for_class(&prof, class, bw, rtt, budget, load);
            cells.push(MatrixCell {
                class,
                family,
                partition_idx: plan.partition_idx,
                edge_only: plan.is_edge_only(),
            });
        }
    }
    cells
}

/// Run the uniform-vs-mixed comparison for each policy in [`POLICIES`].
pub fn run(sys: &SystemConfig, task: TaskKind) -> (Table, Vec<XpuRow>) {
    let variants = arms(sys);
    let mut rows = Vec::new();
    for kind in POLICIES {
        rows.push(XpuRow {
            policy: kind,
            uniform: arm(&variants[0], task, kind),
            mixed: arm(&variants[1], task, kind),
        });
    }

    let mut t = Table::new(
        &format!(
            "Device-heterogeneity zoo ({} × {} session(s), mixed = {})",
            task.name(),
            sys.fleet.n_sessions.max(1),
            MIXED_CLASSES,
        ),
        &["Method", "Uniform", "Mixed", "Per-class (lite/nx/agx)", "Cloud (uni->mix)", "Success"],
    );
    for r in &rows {
        let by = |c: DeviceClass| {
            r.mixed
                .classes
                .iter()
                .find(|t| t.class == c)
                .map_or_else(|| "-".to_string(), |t| ms(t.mean_ep_ms))
        };
        t.row(&[
            r.policy.name().to_string(),
            ms(r.uniform.lat),
            ms(r.mixed.lat),
            format!("{}/{}/{}", by(DeviceClass::Lite), by(DeviceClass::Nx), by(DeviceClass::Agx)),
            format!("{} -> {}", r.uniform.cloud_events, r.mixed.cloud_events),
            format!("{} -> {}", pct(r.uniform.success), pct(r.mixed.success)),
        ]);
    }
    t.footnote(
        "Uniform runs [devices] disabled (the class-free scheduler verbatim); mixed block-assigns \
         lite/nx/agx across the same workload. Each class plans over its own (class, family, \
         link) triple: the class budget filters the split catalog, the class compute scale \
         shifts the argmin toward shallower splits on weak silicon, and nx/lite snap served \
         actions onto their NPU grids. Per-class columns are mean emulated episode time; classes \
         change per-slot physics only, so seeded replays are exact.",
    );
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemConfig {
        let mut s = SystemConfig::default();
        s.fleet.n_sessions = 6;
        s.fleet.max_batch = 4;
        s.fleet.max_inflight = 16;
        s.models.enabled = true;
        s
    }

    #[test]
    fn uniform_arm_is_the_unmodified_scheduler() {
        // arm 0 must be bit-identical to a plain run with [devices] left
        // at its shipped default — the full differential acceptance pin
        // lives in rust/tests/device_zoo.rs
        let base = sys();
        let (_, rows) = run(&base, TaskKind::PickPlace);
        for kind in POLICIES {
            let plain = arm(&base, TaskKind::PickPlace, kind);
            let r = rows.iter().find(|r| r.policy == kind).unwrap();
            assert_eq!(r.uniform.lat.to_bits(), plain.lat.to_bits(), "{kind:?}");
            assert_eq!(r.uniform.cloud_events, plain.cloud_events, "{kind:?}");
            assert_eq!(r.uniform.classes.len(), 1, "{kind:?}");
            assert_eq!(r.uniform.classes[0].class, DeviceClass::Cloudlet);
        }
    }

    #[test]
    fn mixed_arm_completes_and_pays_per_class() {
        let (_, rows) = run(&sys(), TaskKind::PickPlace);
        for r in &rows {
            assert!(r.mixed.completed, "{:?}: mixed fleet wedged", r.policy);
            assert_eq!(r.mixed.classes.len(), 3, "{:?}", r.policy);
            let steps: u64 = r.mixed.classes.iter().map(|t| t.steps).sum();
            let uniform_steps: u64 = r.uniform.classes.iter().map(|t| t.steps).sum();
            assert_eq!(steps, uniform_steps, "{:?}: same schedule of work", r.policy);
        }
        // RAPID actually exercises the edge, so weak silicon must cost
        // more than the cloudlet fleet paid
        let r = rows.iter().find(|r| r.policy == PolicyKind::Rapid).unwrap();
        assert!(r.mixed.lat > r.uniform.lat, "{} <= {}", r.mixed.lat, r.uniform.lat);
    }

    #[test]
    fn partition_matrix_degrades_with_silicon() {
        // the constrained link regime (the paper's 120 Mbps / 20 ms edge
        // uplink): deep splits pay off for strong silicon, so the class
        // axis visibly moves the argmin. On the default 1 Gbps link the
        // shallow split wins for every class and the matrix is flat.
        let mut s = sys();
        s.link.bw_mbps = 120.0;
        s.link.rtt_ms = 20.0;
        let cells = partition_matrix(&s);
        let cell = |c: DeviceClass, f: ModelFamily| {
            *cells.iter().find(|x| x.class == c && x.family == f).unwrap()
        };
        for &f in ModelFamily::ALL.iter() {
            // cloudlet is never budget-degraded to edge-only
            assert!(!cell(DeviceClass::Cloudlet, f).edge_only, "{f:?}");
        }
        // the 2 GB lite budget hosts no OpenVLA split at all
        assert!(cell(DeviceClass::Lite, ModelFamily::OpenVlaAr).edge_only);
        // and the classes pick provably different points for OpenVLA
        let cloudlet = cell(DeviceClass::Cloudlet, ModelFamily::OpenVlaAr);
        let nx = cell(DeviceClass::Nx, ModelFamily::OpenVlaAr);
        assert_ne!(cloudlet.partition_idx, nx.partition_idx);
    }

    #[test]
    fn runs_replay_exactly() {
        let base = sys();
        let (_, a) = run(&base, TaskKind::PickPlace);
        let (_, b) = run(&base, TaskKind::PickPlace);
        for (ra, rb) in a.iter().zip(b.iter()) {
            assert_eq!(ra.mixed.lat.to_bits(), rb.mixed.lat.to_bits());
            assert_eq!(ra.mixed.cloud_events, rb.mixed.cloud_events);
        }
    }

    #[test]
    fn table_renders_all_policies() {
        let (t, rows) = run(&sys(), TaskKind::PickPlace);
        assert_eq!(rows.len(), POLICIES.len());
        let rendered = t.render();
        for r in &rows {
            assert!(rendered.contains(r.policy.name().split(' ').next().unwrap()));
        }
    }
}

//! Deterministic fault injection for the edge-cloud serving stack.
//!
//! RAPID's premise is that partitioned inference must survive hostile
//! network conditions (the paper's Table I attributes communication
//! overhead surges to degraded scenes; RoboECC argues deployment must be
//! network-state-aware). This module makes those conditions *first-class
//! and reproducible*: a [`FaultPlan`] is a schedule of fault windows over
//! scheduler rounds — link outages, bandwidth/RTT collapse, endpoint
//! crash/recover, reply drops, reply delays — and a [`FaultEngine`]
//! (plan + seeded PRNG) answers the serve layer's per-round queries.
//!
//! Determinism contract: with an **empty plan the engine draws no random
//! numbers and changes no decision**, so a fault-free fleet run is
//! bit-identical to a run without the engine (pinned by
//! `rust/tests/chaos_failover.rs`). Under faults, every drop decision
//! comes from the engine's own seeded PRNG stream, so chaos runs replay
//! exactly.
//!
//! Consumers:
//! * `net::link::Link` accepts a time-varying [`net::link::LinkProfile`]
//!   override (bandwidth/RTT collapse windows) instead of a static config;
//! * `serve::fleet::Fleet` routes around crashed endpoints
//!   (`Router::pick_alive`), retries dropped replies on the least-loaded
//!   surviving endpoint, and degrades to the edge slice
//!   (`EpisodeState::fail_cloud`) when no endpoint can serve — no session
//!   ever wedges in suspend.

pub mod engine;
pub mod plan;

pub use engine::FaultEngine;
pub use plan::{FaultEvent, FaultPlan, Window};

//! Fault schedules: typed fault events over scheduler-round windows.

use crate::config::FaultsConfig;
use crate::net::link::LinkProfile;

/// Half-open window of scheduler rounds `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    pub start: u64,
    pub end: u64,
}

impl Window {
    pub fn new(start: u64, end: u64) -> Window {
        Window { start, end }
    }

    pub fn contains(&self, round: u64) -> bool {
        round >= self.start && round < self.end
    }

    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// One scheduled fault. Windows are in scheduler rounds (one control step
/// of virtual time per round).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// The uplink is down: no offload can leave the edge. Sessions that
    /// would offload degrade to their cached chunk / edge slice, and
    /// already-pending batches degrade instead of dispatching.
    LinkOutage { window: Window },
    /// Bandwidth/RTT collapse: the link runs under this profile instead of
    /// its configured nominal values.
    LinkDegrade { window: Window, bw_mbps: f64, rtt_ms: f64 },
    /// A cloud endpoint is dead during the window (recovers at `end`).
    /// Dispatches route around it via the surviving endpoints.
    EndpointCrash { endpoint: usize, window: Window },
    /// Each dispatched batch's reply is lost with probability `prob`
    /// (seeded draw in the engine). The edge times out and fails over.
    ReplyDrop { window: Window, prob: f64 },
    /// Replies arrive `extra_ms` late. A delay beyond the offload timeout
    /// is indistinguishable from a drop and is treated as one.
    ReplyDelay { window: Window, extra_ms: f64 },
}

/// A deterministic fault schedule: just an ordered list of events. Build
/// programmatically with the chainable helpers, or from the `[faults]`
/// config section via [`FaultPlan::from_config`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: injects nothing, perturbs nothing.
    pub fn none() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn outage(mut self, start: u64, end: u64) -> FaultPlan {
        self.events.push(FaultEvent::LinkOutage { window: Window::new(start, end) });
        self
    }

    pub fn degrade(mut self, start: u64, end: u64, bw_mbps: f64, rtt_ms: f64) -> FaultPlan {
        self.events.push(FaultEvent::LinkDegrade {
            window: Window::new(start, end),
            bw_mbps,
            rtt_ms,
        });
        self
    }

    pub fn crash(mut self, endpoint: usize, start: u64, end: u64) -> FaultPlan {
        self.events.push(FaultEvent::EndpointCrash { endpoint, window: Window::new(start, end) });
        self
    }

    pub fn drop_replies(mut self, start: u64, end: u64, prob: f64) -> FaultPlan {
        self.events.push(FaultEvent::ReplyDrop { window: Window::new(start, end), prob });
        self
    }

    pub fn delay_replies(mut self, start: u64, end: u64, extra_ms: f64) -> FaultPlan {
        self.events.push(FaultEvent::ReplyDelay { window: Window::new(start, end), extra_ms });
        self
    }

    /// Build the plan a `[faults]` config section describes. Disabled or
    /// empty-window entries contribute nothing, so a default config maps
    /// to the empty plan.
    pub fn from_config(f: &FaultsConfig) -> FaultPlan {
        let mut plan = FaultPlan::none();
        if !f.enabled {
            return plan;
        }
        if f.outage_end > f.outage_start {
            plan = plan.outage(f.outage_start, f.outage_end);
        }
        if f.degrade_end > f.degrade_start {
            plan =
                plan.degrade(f.degrade_start, f.degrade_end, f.degrade_bw_mbps, f.degrade_rtt_ms);
        }
        if f.crash_end > f.crash_start {
            plan = plan.crash(f.crash_endpoint, f.crash_start, f.crash_end);
        }
        if f.drop_end > f.drop_start && f.drop_prob > 0.0 {
            plan = plan.drop_replies(f.drop_start, f.drop_end, f.drop_prob);
        }
        if f.delay_end > f.delay_start && f.delay_ms > 0.0 {
            plan = plan.delay_replies(f.delay_start, f.delay_end, f.delay_ms);
        }
        plan
    }

    /// The link profile in force at `round`, if any degrade window is
    /// active (the last matching window wins, mirroring config overlays).
    pub fn link_profile(&self, round: u64) -> Option<LinkProfile> {
        let mut out = None;
        for ev in &self.events {
            if let FaultEvent::LinkDegrade { window, bw_mbps, rtt_ms } = ev {
                if window.contains(round) {
                    out = Some(LinkProfile { bw_mbps: *bw_mbps, rtt_ms: *rtt_ms });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_are_half_open() {
        let w = Window::new(5, 8);
        assert!(!w.contains(4));
        assert!(w.contains(5));
        assert!(w.contains(7));
        assert!(!w.contains(8));
        assert!(Window::new(3, 3).is_empty());
    }

    #[test]
    fn builders_accumulate_events() {
        let plan = FaultPlan::none().crash(1, 10, 20).drop_replies(0, 100, 0.5).outage(30, 40);
        assert_eq!(plan.events.len(), 3);
        assert!(!plan.is_empty());
    }

    #[test]
    fn disabled_config_is_empty_plan() {
        let f = FaultsConfig::default();
        assert!(FaultPlan::from_config(&f).is_empty());
        // enabled but with no active windows is still empty
        let mut f = FaultsConfig::default();
        f.enabled = true;
        assert!(FaultPlan::from_config(&f).is_empty());
    }

    #[test]
    fn config_windows_map_to_events() {
        let mut f = FaultsConfig::default();
        f.enabled = true;
        f.crash_start = 5;
        f.crash_end = 15;
        f.crash_endpoint = 2;
        f.drop_start = 0;
        f.drop_end = 50;
        f.drop_prob = 0.25;
        let plan = FaultPlan::from_config(&f);
        assert_eq!(plan.events.len(), 2);
        assert!(plan
            .events
            .contains(&FaultEvent::EndpointCrash { endpoint: 2, window: Window::new(5, 15) }));
    }

    #[test]
    fn last_degrade_window_wins() {
        let plan = FaultPlan::none().degrade(0, 100, 100.0, 20.0).degrade(10, 20, 10.0, 90.0);
        assert_eq!(plan.link_profile(5).unwrap().bw_mbps, 100.0);
        assert_eq!(plan.link_profile(15).unwrap().bw_mbps, 10.0);
        assert!(plan.link_profile(200).is_none());
    }
}

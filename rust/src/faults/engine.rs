//! The fault engine: a compiled [`FaultPlan`] plus its own seeded PRNG
//! stream, queried by the fleet scheduler once per round / per dispatch.
//!
//! The engine's hard contract is *zero interference when idle*: every
//! query on an empty plan (or outside every window) returns the benign
//! answer **without drawing from the PRNG**, so a fault-free run is
//! bit-identical to a run that never constructed an engine.

use super::plan::{FaultEvent, FaultPlan};
use crate::config::FaultsConfig;
use crate::net::link::LinkProfile;
use crate::util::Pcg32;

#[derive(Debug, Clone)]
pub struct FaultEngine {
    plan: FaultPlan,
    rng: Pcg32,
    /// How long the edge waits for a reply before declaring it lost (ms of
    /// virtual time, charged to the failed-over session).
    pub timeout_ms: f64,
    /// Re-dispatches attempted on surviving endpoints before a batch
    /// degrades to the edge slice.
    pub max_retries: usize,
}

impl FaultEngine {
    pub fn new(plan: FaultPlan, seed: u64, timeout_ms: f64, max_retries: usize) -> FaultEngine {
        FaultEngine { plan, rng: Pcg32::new(seed, 0xFA_017), timeout_ms, max_retries }
    }

    /// Engine described by a `[faults]` config section. `base_seed` seeds
    /// the drop stream when the section doesn't pin its own seed.
    pub fn from_config(f: &FaultsConfig, base_seed: u64) -> FaultEngine {
        let seed = if f.seed != 0 { f.seed } else { base_seed ^ 0xC4A0_5FA0 };
        FaultEngine::new(FaultPlan::from_config(f), seed, f.offload_timeout_ms, f.max_retries)
    }

    /// Disarmed engine: empty plan, default timeout/retries.
    pub fn disarmed() -> FaultEngine {
        FaultEngine::new(FaultPlan::none(), 0, FaultsConfig::default().offload_timeout_ms, 0)
    }

    pub fn is_empty(&self) -> bool {
        self.plan.is_empty()
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Link override in force at `round` (bandwidth/RTT collapse), if any.
    pub fn link_profile(&self, round: u64) -> Option<LinkProfile> {
        self.plan.link_profile(round)
    }

    /// True while an uplink outage window is active: no offload may leave
    /// the edge this round.
    pub fn link_out(&self, round: u64) -> bool {
        self.plan.events.iter().any(|ev| match ev {
            FaultEvent::LinkOutage { window } => window.contains(round),
            _ => false,
        })
    }

    /// The outage window containing `round`, as `(start, end)`, if any —
    /// the span tracer tags each outage round with its window so a
    /// Perfetto timeline shows the whole blackout, not one round at a
    /// time. Pure schedule lookup: no PRNG, no state.
    pub fn outage_window_at(&self, round: u64) -> Option<(u64, u64)> {
        self.plan.events.iter().find_map(|ev| match ev {
            FaultEvent::LinkOutage { window } if window.contains(round) => {
                Some((window.start, window.end))
            }
            _ => None,
        })
    }

    /// Is `endpoint` alive at `round`? (Dead during crash windows,
    /// recovered afterwards.)
    pub fn endpoint_up(&self, endpoint: usize, round: u64) -> bool {
        !self.plan.events.iter().any(|ev| match ev {
            FaultEvent::EndpointCrash { endpoint: e, window } => {
                *e == endpoint && window.contains(round)
            }
            _ => false,
        })
    }

    /// Decide whether this dispatch's reply is lost. Draws from the
    /// engine's PRNG only for drop windows active at `round`, so inactive
    /// schedules cost zero draws and replay exactly.
    pub fn reply_dropped(&mut self, round: u64) -> bool {
        let mut dropped = false;
        for ev in &self.plan.events {
            if let FaultEvent::ReplyDrop { window, prob } = ev {
                if window.contains(round) && *prob > 0.0 && self.rng.chance(*prob) {
                    dropped = true;
                }
            }
        }
        dropped
    }

    /// Extra reply latency in force at `round` (0.0 outside every delay
    /// window). Delays beyond `timeout_ms` are handled as drops by the
    /// caller.
    pub fn reply_delay_ms(&self, round: u64) -> f64 {
        self.plan
            .events
            .iter()
            .map(|ev| match ev {
                FaultEvent::ReplyDelay { window, extra_ms } if window.contains(round) => *extra_ms,
                _ => 0.0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_engine_is_fully_benign() {
        let mut e = FaultEngine::disarmed();
        assert!(e.is_empty());
        for round in 0..100 {
            assert!(e.link_profile(round).is_none());
            assert!(!e.link_out(round));
            assert!(e.endpoint_up(0, round));
            assert!(!e.reply_dropped(round));
            assert_eq!(e.reply_delay_ms(round), 0.0);
        }
    }

    #[test]
    fn inactive_windows_draw_nothing_from_the_rng() {
        // two engines, same seed: one queried outside its drop window many
        // times, then both enter the window — identical decisions prove
        // the inactive queries consumed no PRNG state
        let plan = FaultPlan::none().drop_replies(100, 200, 0.5);
        let mut a = FaultEngine::new(plan.clone(), 42, 250.0, 1);
        let mut b = FaultEngine::new(plan, 42, 250.0, 1);
        for round in 0..100 {
            assert!(!a.reply_dropped(round));
        }
        for round in 100..200 {
            assert_eq!(a.reply_dropped(round), b.reply_dropped(round), "round {round}");
        }
    }

    #[test]
    fn drop_decisions_replay_for_a_fixed_seed() {
        let plan = FaultPlan::none().drop_replies(0, 1000, 0.3);
        let mut a = FaultEngine::new(plan.clone(), 7, 250.0, 1);
        let mut b = FaultEngine::new(plan, 7, 250.0, 1);
        let da: Vec<bool> = (0..1000).map(|r| a.reply_dropped(r)).collect();
        let db: Vec<bool> = (0..1000).map(|r| b.reply_dropped(r)).collect();
        assert_eq!(da, db);
        assert!(da.iter().any(|&d| d), "prob 0.3 over 1000 rounds must drop something");
        assert!(da.iter().any(|&d| !d), "prob 0.3 must not drop everything");
    }

    #[test]
    fn crash_windows_kill_and_recover() {
        let e = FaultEngine::new(FaultPlan::none().crash(1, 10, 20), 1, 250.0, 1);
        assert!(e.endpoint_up(1, 9));
        assert!(!e.endpoint_up(1, 10));
        assert!(!e.endpoint_up(1, 19));
        assert!(e.endpoint_up(1, 20));
        // other endpoints unaffected
        assert!(e.endpoint_up(0, 15));
    }

    #[test]
    fn outage_and_delay_windows() {
        let e = FaultEngine::new(
            FaultPlan::none().outage(5, 8).delay_replies(6, 10, 40.0).delay_replies(7, 9, 20.0),
            1,
            250.0,
            1,
        );
        assert!(!e.link_out(4));
        assert!(e.link_out(5));
        assert!(!e.link_out(8));
        assert_eq!(e.outage_window_at(4), None);
        assert_eq!(e.outage_window_at(5), Some((5, 8)));
        assert_eq!(e.outage_window_at(7), Some((5, 8)));
        assert_eq!(e.outage_window_at(8), None);
        assert_eq!(e.reply_delay_ms(5), 0.0);
        assert_eq!(e.reply_delay_ms(6), 40.0);
        assert_eq!(e.reply_delay_ms(7), 60.0); // overlapping delays add
        assert_eq!(e.reply_delay_ms(9), 40.0);
    }

    #[test]
    fn config_seed_pins_the_stream() {
        let mut f = FaultsConfig::default();
        f.enabled = true;
        f.drop_start = 0;
        f.drop_end = 100;
        f.drop_prob = 0.5;
        f.seed = 11;
        let mut a = FaultEngine::from_config(&f, 1);
        let mut b = FaultEngine::from_config(&f, 2); // base seed ignored when pinned
        let da: Vec<bool> = (0..100).map(|r| a.reply_dropped(r)).collect();
        let db: Vec<bool> = (0..100).map(|r| b.reply_dropped(r)).collect();
        assert_eq!(da, db);
    }
}

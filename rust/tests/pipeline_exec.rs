//! Differential conformance suite for `[pipeline]` — pipelined +
//! speculative partition execution.
//!
//! Two halves:
//!
//! * **Disabled ⇒ bit-identity.** A `[pipeline]` section that is absent,
//!   disabled (whatever the other knobs say), or enabled with both
//!   `overlap` and `speculate` off must leave the scheduler *exactly*
//!   the PR 6 event loop — not just totals, but per-episode
//!   trajectories, flush causes, cache counters and fault-engine draws —
//!   across every serve path: plain fleets, the reuse cache, the
//!   chaos/failover schedule, the model zoo and dynamic arrivals.
//! * **Enabled holds the line and pays off.** With speculation on, every
//!   speculative dispatch resolves (confirm/rollback/abort — never a
//!   wedge), chaos included, with exact seeded replay; and on the
//!   shipped `configs/libero.toml`, pipeline+speculation gives RAPID a
//!   strictly lower fleet mean latency at equal task success.

use rapid::config::{FaultsConfig, PolicyKind, SystemConfig};
use rapid::robot::TaskKind;
use rapid::serve::{Fleet, FleetResult};

/// Full-strength bit-identity: scheduler counters, flush causes, router
/// spread, cache counters, speculation counters, and exact per-episode
/// trajectory columns.
fn assert_bit_identical(a: &FleetResult, b: &FleetResult, tag: &str) {
    assert_eq!(a.stats.rounds, b.stats.rounds, "{tag}: rounds");
    assert_eq!(a.stats.batches, b.stats.batches, "{tag}: batches");
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests, "{tag}: batched requests");
    assert_eq!(a.stats.multi_session_batches, b.stats.multi_session_batches, "{tag}: multi");
    assert_eq!(a.stats.full_flushes, b.stats.full_flushes, "{tag}: full flushes");
    assert_eq!(a.stats.deadline_flushes, b.stats.deadline_flushes, "{tag}: deadline flushes");
    assert_eq!(a.stats.drain_flushes, b.stats.drain_flushes, "{tag}: drain flushes");
    assert_eq!(a.stats.family_flushes, b.stats.family_flushes, "{tag}: family flushes");
    assert_eq!(a.stats.deferred_offloads, b.stats.deferred_offloads, "{tag}: deferred");
    assert_eq!(a.stats.dropped_replies, b.stats.dropped_replies, "{tag}: dropped");
    assert_eq!(a.stats.degraded_requests, b.stats.degraded_requests, "{tag}: degraded");
    assert_eq!(a.stats.failover_redispatches, b.stats.failover_redispatches, "{tag}: failover");
    assert_eq!(a.stats.outage_rounds, b.stats.outage_rounds, "{tag}: outage rounds");
    assert_eq!(a.stats.spec_requests, b.stats.spec_requests, "{tag}: spec requests");
    assert_eq!(a.endpoint_dispatches, b.endpoint_dispatches, "{tag}: router spread");
    assert_eq!(a.mean_batch, b.mean_batch, "{tag}: mean batch");
    assert_eq!(a.cache.hits, b.cache.hits, "{tag}: cache hits");
    assert_eq!(a.cache.probes, b.cache.probes, "{tag}: cache probes");
    assert_eq!(a.cache.evictions, b.cache.evictions, "{tag}: cache evictions");
    assert_eq!(a.sessions.len(), b.sessions.len(), "{tag}: session count");
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        assert_eq!(sa.family, sb.family, "{tag}: family");
        assert_eq!(sa.arrival_round, sb.arrival_round, "{tag}: arrival round");
        assert_eq!(sa.departure_round, sb.departure_round, "{tag}: departure round");
        assert_eq!(sa.episodes.len(), sb.episodes.len(), "{tag}: episode count");
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "{tag}: latency columns");
            assert_eq!(ma.cloud_events, mb.cloud_events, "{tag}: cloud events");
            assert_eq!(ma.edge_events, mb.edge_events, "{tag}: edge events");
            assert_eq!(ma.preemptions, mb.preemptions, "{tag}: preemptions");
            assert_eq!(ma.failovers, mb.failovers, "{tag}: failovers");
            assert_eq!(ma.cache_hits, mb.cache_hits, "{tag}: cache hits");
            assert_eq!(ma.overhead_ms, mb.overhead_ms, "{tag}: overhead");
            assert_eq!(ma.spec_dispatches, mb.spec_dispatches, "{tag}: spec dispatches");
            assert_eq!(ma.spec_confirms, mb.spec_confirms, "{tag}: spec confirms");
            assert_eq!(ma.spec_rollbacks, mb.spec_rollbacks, "{tag}: spec rollbacks");
            assert_eq!(ma.spec_suppressed, mb.spec_suppressed, "{tag}: spec suppressed");
            assert_eq!(ma.overlap_hidden_ms, mb.overlap_hidden_ms, "{tag}: hidden ms");
            assert_eq!(ma.rms_error, mb.rms_error, "{tag}: trajectory (rms)");
            assert_eq!(ma.success, mb.success, "{tag}: success");
        }
    }
}

/// A `[pipeline]` section that is present — with hostile knobs — but
/// disabled. Must perturb nothing.
fn disabled_pipeline(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.pipeline.enabled = false;
    s.pipeline.overlap = true;
    s.pipeline.speculate = true;
    s.pipeline.spec_decode_ms = 999.0;
    s.pipeline.rollback_ms = 777.0;
    s.pipeline.accept_eps = 0.0;
    s.pipeline.max_zscore = -1.0;
    s
}

/// The degenerate *enabled* shape: `enabled = true` with both stages
/// off — must execute bit-identically to disabled, whatever the numeric
/// knobs say.
fn degenerate_pipeline(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.pipeline.enabled = true;
    s.pipeline.overlap = false;
    s.pipeline.speculate = false;
    s.pipeline.spec_decode_ms = 999.0;
    s.pipeline.rollback_ms = 777.0;
    s.pipeline.accept_eps = 0.0;
    s.pipeline.max_zscore = -1.0;
    s
}

/// Both stages on with the shipped default economics.
fn full_pipeline(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.pipeline.enabled = true;
    s.pipeline.overlap = true;
    s.pipeline.speculate = true;
    s
}

#[test]
fn disabled_pipeline_keeps_the_fleet_bit_identical() {
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased] {
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = 4;
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&disabled_pipeline(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("{kind:?}"));
        assert_eq!(run.stats.spec_requests, 0);
    }
}

#[test]
fn degenerate_enabled_pipeline_is_bit_identical_on_the_fleet_path() {
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased] {
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = 4;
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&degenerate_pipeline(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("degenerate/{kind:?}"));
    }
}

#[test]
fn pipeline_keeps_the_reuse_cache_bit_identical() {
    // probe/admission ordering across the round: the pipelined branches
    // must not move a single store draw when disabled
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.cache.enabled = true;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(base.cache.hits > 0, "the cached fleet must actually hit");
    let off = Fleet::local(&disabled_pipeline(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly)
        .run();
    assert_bit_identical(&base, &off, "cache/disabled");
    let degen =
        Fleet::local(&degenerate_pipeline(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&base, &degen, "cache/degenerate");
}

#[test]
fn pipeline_keeps_the_chaos_path_bit_identical() {
    // the fault engine's shared PRNG stream is the strictest differential:
    // one extra (or missing) draw anywhere — e.g. the relocated cloud
    // compute-jitter sample — would shift every later drop decision
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.fleet.endpoints = 3;
    sys.faults = FaultsConfig::demo();
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let off = Fleet::local(&disabled_pipeline(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &off, &format!("chaos/disabled/{kind:?}"));
        let degen = Fleet::local(&degenerate_pipeline(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &degen, &format!("chaos/degenerate/{kind:?}"));
    }
}

#[test]
fn pipeline_keeps_the_zoo_path_bit_identical() {
    // mixed families + family-keyed batching: the speculative in-flight
    // slot accounting must vanish when the stage is off
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.models.enabled = true;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(base.stats.family_flushes > 0, "the zoo fleet must exercise the family seal");
    let off =
        Fleet::local(&disabled_pipeline(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&base, &off, "zoo/disabled");
    let degen =
        Fleet::local(&degenerate_pipeline(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&base, &degen, "zoo/degenerate");
}

#[test]
fn pipeline_keeps_dynamic_arrivals_bit_identical() {
    // open-loop Poisson arrivals layer the Arrival/Ready event classes the
    // speculative self-reschedule rides on — disabled must not perturb
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.workload.enabled = true;
    sys.workload.arrivals = "poisson".into();
    sys.workload.interarrival_rounds = 4.0;
    sys.workload.seed = 23;
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let off = Fleet::local(&disabled_pipeline(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &off, &format!("workload/disabled/{kind:?}"));
        let degen = Fleet::local(&degenerate_pipeline(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &degen, &format!("workload/degenerate/{kind:?}"));
    }
}

#[test]
fn speculation_resolves_under_the_chaos_plan_and_replays() {
    // drops, delays, outages, degrades: a speculative request whose reply
    // never lands must abort (counted as a failover) — no wedge, and the
    // whole run replays bit-identically under the shared seed
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.fleet.endpoints = 3;
    sys.faults = FaultsConfig::demo();
    let sys = full_pipeline(&sys);
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let res = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert!(res.stats.spec_requests > 0, "{kind:?}: chaos fleet never speculated");
        let (mut disp, mut conf, mut roll) = (0u64, 0u64, 0u64);
        for m in res.sessions.iter().flat_map(|s| s.episodes.iter()) {
            assert_eq!(m.steps, TaskKind::PickPlace.seq_len(), "{kind:?}: wedged under chaos");
            disp += m.spec_dispatches;
            conf += m.spec_confirms;
            roll += m.spec_rollbacks;
        }
        assert_eq!(disp, res.stats.spec_requests, "{kind:?}: dispatch accounting");
        // chaos may abort some speculations (dropped replies / exhausted
        // endpoints); the rest must resolve via a confirm or rollback
        assert!(conf + roll <= disp, "{kind:?}: over-resolved");
        assert!(conf + roll > 0, "{kind:?}: nothing ever resolved");
        let again = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert_bit_identical(&res, &again, &format!("spec-chaos replay {kind:?}"));
    }
}

#[test]
fn pipeline_acceptance_on_the_shipped_config() {
    // configs/libero.toml with [pipeline] flipped on: RAPID's fleet mean
    // latency strictly drops at equal task success, reproducibly seeded
    let src = std::fs::read_to_string("configs/libero.toml").expect("configs/libero.toml");
    let mut sys = SystemConfig::from_toml(&src).expect("parse libero.toml");
    assert!(!sys.pipeline.enabled, "libero.toml must ship [pipeline] disabled");
    sys.fleet.n_sessions = 6;

    let seq = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    let on_sys = full_pipeline(&sys);
    let on = Fleet::local(&on_sys, TaskKind::PickPlace, PolicyKind::Rapid).run();

    let expect = TaskKind::PickPlace.seq_len();
    for s in on.sessions.iter().chain(seq.sessions.iter()) {
        for m in &s.episodes {
            assert_eq!(m.steps, expect, "a session wedged");
        }
    }
    let (seq_sum, on_sum) = (seq.summary(), on.summary());
    assert!(
        on_sum.fleet.total_lat_mean < seq_sum.fleet.total_lat_mean,
        "pipeline+speculation must strictly cut RAPID mean latency: {} vs {}",
        on_sum.fleet.total_lat_mean,
        seq_sum.fleet.total_lat_mean
    );
    assert_eq!(
        on_sum.fleet.success_rate, seq_sum.fleet.success_rate,
        "latency must drop at equal task success"
    );
    assert!(on.stats.spec_requests > 0);

    // reproducibly seeded: the accepted arm replays exactly
    let again = Fleet::local(&on_sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert_bit_identical(&on, &again, "libero pipeline replay");
}

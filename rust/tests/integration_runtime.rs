//! Integration tests over the PJRT runtime: load the AOT artifacts, run
//! the real models, and verify the constructed behaviours survive the
//! python -> HLO text -> PJRT round trip. Skipped (with a notice) when
//! `make artifacts` has not been run.

use rapid::experiments::Backends;
use rapid::robot::{RobotSim, TaskKind};
use rapid::scene::{NoiseModel, Renderer};
use rapid::{CHUNK, D_PROP, D_VIS, N_JOINTS};

fn pjrt() -> Option<Backends> {
    match Backends::try_pjrt() {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn obs_with(err: f64, sal: f64, clarity: f64) -> [f32; D_VIS] {
    // renderer-equivalent synthetic observation with a persistent texture
    let mut rng = rapid::util::Pcg32::seeded(99);
    let mut o = [0f32; D_VIS];
    for j in 0..N_JOINTS {
        o[j] = err as f32;
    }
    for i in 0..CHUNK {
        o[7 + i] = sal as f32;
    }
    o[15] = sal as f32;
    for v in o.iter_mut().skip(16) {
        *v = rng.normal_ms(0.0, rapid::scene::renderer::SCENE_TEXTURE_STD) as f32;
    }
    for v in o.iter_mut() {
        *v *= clarity as f32;
    }
    o
}

#[test]
fn pjrt_outputs_have_contract_shapes_and_are_finite() {
    let Some(mut b) = pjrt() else { return };
    let out = b.cloud.infer(&obs_with(0.3, 0.5, 1.0), &[0.1; D_PROP], 1);
    assert_eq!(out.actions.len(), CHUNK);
    assert_eq!(out.logits.len(), CHUNK);
    assert_eq!(out.mass.len(), CHUNK);
    for a in &out.actions {
        assert!(a.is_finite());
        assert!(a.abs_max() <= 1.0);
    }
    assert!(out.mass.iter().all(|m| m.is_finite() && *m >= 0.0));
}

#[test]
fn pjrt_inference_is_deterministic() {
    let Some(mut b) = pjrt() else { return };
    let obs = obs_with(0.2, 0.4, 0.8);
    let a = b.cloud.infer(&obs, &[0.0; D_PROP], 2);
    let c = b.cloud.infer(&obs, &[0.0; D_PROP], 2);
    assert_eq!(a.mass, c.mass);
    assert_eq!(a.logits[0], c.logits[0]);
}

#[test]
fn pjrt_entropy_rises_with_visual_degradation() {
    let Some(mut b) = pjrt() else { return };
    for backend in [&mut b.edge, &mut b.cloud] {
        let clean = backend.infer(&obs_with(0.3, 0.1, 1.0), &[0.0; D_PROP], 1).mean_entropy();
        let noisy = backend.infer(&obs_with(0.3, 0.1, 0.25), &[0.0; D_PROP], 1).mean_entropy();
        assert!(noisy > clean + 0.4, "{}: clean {clean} noisy {noisy}", backend.name());
    }
}

#[test]
fn pjrt_mass_tracks_saliency() {
    let Some(mut b) = pjrt() else { return };
    let calm = b.cloud.infer(&obs_with(0.3, 0.05, 1.0), &[0.0; D_PROP], 1);
    let hot = b.cloud.infer(&obs_with(0.1, 0.9, 1.0), &[0.0; D_PROP], 1);
    let mean = |o: &rapid::vla::ModelOut| o.mass.iter().sum::<f64>() / CHUNK as f64;
    assert!(mean(&hot) > 3.0 * mean(&calm), "hot {} calm {}", mean(&hot), mean(&calm));
}

#[test]
fn pjrt_actions_track_joint_error_sign() {
    let Some(mut b) = pjrt() else { return };
    let pos = b.cloud.infer(&obs_with(0.5, 0.1, 1.0), &[0.0; D_PROP], 1);
    let neg = b.cloud.infer(&obs_with(-0.5, 0.1, 1.0), &[0.0; D_PROP], 1);
    let mean_j0 =
        |o: &rapid::vla::ModelOut| o.actions.iter().map(|a| a[0]).sum::<f64>() / CHUNK as f64;
    assert!(mean_j0(&pos) > 0.1);
    assert!(mean_j0(&neg) < -0.1);
}

#[test]
fn pjrt_full_episode_with_renderer_succeeds() {
    let Some(mut b) = pjrt() else { return };
    let sys = rapid::config::SystemConfig::default();
    let strategy = rapid::policy::build(rapid::config::PolicyKind::Rapid, &sys);
    let out = rapid::serve::run_episode(
        &sys,
        TaskKind::PickPlace,
        strategy,
        b.edge.as_mut(),
        b.cloud.as_mut(),
        42,
        false,
    );
    assert_eq!(out.metrics.steps, TaskKind::PickPlace.seq_len());
    assert!(out.metrics.success, "rms {}", out.metrics.rms_error);
    assert!(out.metrics.cloud_events > 0);
    assert!(out.metrics.measured_cloud_us > 0.0);
}

#[test]
fn renderer_observations_drive_pjrt_entropy_separation() {
    // end-to-end: real renderer obs (not synthetic) through the real model
    let Some(mut b) = pjrt() else { return };
    let rcfg = rapid::config::RobotConfig::default();
    let sim = RobotSim::new(TaskKind::PickPlace, &rcfg, 7);

    let mut scene_clean = rapid::config::SceneConfig::default();
    scene_clean.noise = rapid::config::NoiseLevel::Standard;
    let mut clean_r = Renderer::new(NoiseModel::new(&scene_clean, 3), 3);

    let mut scene_noisy = scene_clean.clone();
    scene_noisy.noise = rapid::config::NoiseLevel::VisualNoise;
    let mut noisy_r = Renderer::new(NoiseModel::new(&scene_noisy, 3), 3);

    let proprio = [0f32; D_PROP];
    let h_clean = b.cloud.infer(&clean_r.render(&sim), &proprio, 1).mean_entropy();
    let mut noisy_sum = 0.0;
    for _ in 0..5 {
        noisy_sum += b.cloud.infer(&noisy_r.render(&sim), &proprio, 1).mean_entropy();
    }
    let h_noisy = noisy_sum / 5.0;
    assert!(h_noisy > h_clean + 0.3, "clean {h_clean} noisy {h_noisy}");
}

//! End-to-end integration: suite-level behaviour across policies, the real
//! TCP edge-cloud path inside the episode driver, and cross-noise
//! compatibility — the system-level claims of the paper, checked in CI.

use rapid::config::{NoiseLevel, PolicyKind, SystemConfig};
use rapid::metrics::aggregate;
use rapid::net::{CloudClient, CloudServer};
use rapid::robot::tasks::ALL_TASKS;
use rapid::robot::TaskKind;
use rapid::serve::session::run_policy;
use rapid::vla::AnalyticBackend;

#[test]
fn suite_reproduces_paper_ordering_and_loads() {
    let mut sys = SystemConfig::default();
    sys.episode.seed = 33;
    let mut edge = AnalyticBackend::edge(1);
    let mut cloud = AnalyticBackend::cloud(1);
    let mut rows = Vec::new();
    for kind in
        [PolicyKind::EdgeOnly, PolicyKind::CloudOnly, PolicyKind::VisionBased, PolicyKind::Rapid]
    {
        let r = run_policy(&sys, kind, &ALL_TASKS, 3, &mut edge, &mut cloud);
        rows.push(aggregate(kind, &r.episodes));
    }
    let get = |k: PolicyKind| rows.iter().find(|r| r.policy == k).unwrap();
    // ordering: Cloud < RAPID < Vision < Edge
    assert!(get(PolicyKind::CloudOnly).total_lat_mean < get(PolicyKind::Rapid).total_lat_mean);
    assert!(get(PolicyKind::Rapid).total_lat_mean < get(PolicyKind::VisionBased).total_lat_mean);
    assert!(get(PolicyKind::VisionBased).total_lat_mean < get(PolicyKind::EdgeOnly).total_lat_mean);
    // edge-only anchored at the configured device time
    assert!((get(PolicyKind::EdgeOnly).total_lat_mean - 782.5).abs() < 40.0);
    // loads conserved everywhere
    for r in &rows {
        assert!((r.total_gb - sys.total_model_gb).abs() < 1e-6, "{:?}", r.policy);
    }
    // RAPID keeps the paper's small edge footprint
    assert!((get(PolicyKind::Rapid).edge_gb - 2.4).abs() < 1e-9);
}

#[test]
fn rapid_is_noise_compatible_where_vision_is_not() {
    let mut edge = AnalyticBackend::edge(2);
    let mut cloud = AnalyticBackend::cloud(2);
    let mut vision = Vec::new();
    let mut rapid_l = Vec::new();
    for noise in [NoiseLevel::Standard, NoiseLevel::Distraction] {
        let mut sys = SystemConfig::default();
        sys.scene.noise = noise;
        sys.episode.seed = 5;
        let v = run_policy(&sys, PolicyKind::VisionBased, &ALL_TASKS, 2, &mut edge, &mut cloud);
        vision.push(aggregate(PolicyKind::VisionBased, &v.episodes).total_lat_mean);
        let r = run_policy(&sys, PolicyKind::Rapid, &ALL_TASKS, 2, &mut edge, &mut cloud);
        rapid_l.push(aggregate(PolicyKind::Rapid, &r.episodes).total_lat_mean);
    }
    let vision_deg = (vision[1] - vision[0]) / vision[0];
    let rapid_deg = (rapid_l[1] - rapid_l[0]) / rapid_l[0];
    // vision degrades substantially; RAPID stays (relatively) flat
    assert!(vision_deg > 0.25, "vision degradation {vision_deg}");
    assert!(rapid_deg.abs() < vision_deg, "rapid {rapid_deg} vs vision {vision_deg}");
}

#[test]
fn rapid_matches_vision_accuracy_with_far_fewer_cloud_queries() {
    // the accuracy/efficiency claim: RAPID places its (few) cloud queries
    // at critical moments and keeps tracking quality comparable to the
    // vision baseline that floods the cloud under noise
    let mut sys = SystemConfig::default();
    sys.scene.noise = NoiseLevel::VisualNoise;
    sys.episode.seed = 11;
    let mut edge = AnalyticBackend::edge(3);
    let mut cloud = AnalyticBackend::cloud(3);
    let v = run_policy(&sys, PolicyKind::VisionBased, &ALL_TASKS, 3, &mut edge, &mut cloud);
    let r = run_policy(&sys, PolicyKind::Rapid, &ALL_TASKS, 3, &mut edge, &mut cloud);
    let v_row = aggregate(PolicyKind::VisionBased, &v.episodes);
    let r_row = aggregate(PolicyKind::Rapid, &r.episodes);
    let v_queries: f64 = v.episodes.iter().map(|m| m.cloud_events as f64).sum();
    let r_queries: f64 = r.episodes.iter().map(|m| m.cloud_events as f64).sum();
    assert!(
        r_row.rms_error <= v_row.rms_error + 0.15,
        "rapid rms {} vs vision {}",
        r_row.rms_error,
        v_row.rms_error
    );
    assert!(r_queries < 0.7 * v_queries, "rapid {r_queries} vs vision {v_queries} queries");
    // and RAPID's queries are better placed
    assert!(r_row.trigger_precision >= 0.5, "precision {}", r_row.trigger_precision);
}

#[test]
fn episode_driver_over_real_tcp() {
    // the driver's cloud calls leave the process over TCP (CloudClient is a
    // Backend) and hit a real server worker
    let server =
        CloudServer::start("127.0.0.1:0", 4, || Box::new(AnalyticBackend::cloud(9))).unwrap();
    let addr = server.addr.to_string();
    let mut edge = AnalyticBackend::edge(9);
    let mut client = CloudClient::connect(&addr).unwrap();

    let sys = SystemConfig::default();
    let strategy = rapid::policy::build(PolicyKind::Rapid, &sys);
    let out = rapid::serve::run_episode(
        &sys,
        TaskKind::DrawerOpen,
        strategy,
        &mut edge,
        &mut client,
        77,
        false,
    );
    assert_eq!(out.metrics.steps, TaskKind::DrawerOpen.seq_len());
    assert!(out.metrics.cloud_events > 0);
    assert_eq!(
        server.stats().requests.load(std::sync::atomic::Ordering::Relaxed),
        out.metrics.cloud_events
    );
    assert!(!client.rtts_us.is_empty());
    server.shutdown();
}

#[test]
fn cooldown_throttles_cloud_queries() {
    // paper §V-B: C prevents network flooding during sustained contact
    let mut edge = AnalyticBackend::edge(4);
    let mut cloud = AnalyticBackend::cloud(4);
    let mut count_offloads = |cooldown: u32| -> f64 {
        let mut sys = SystemConfig::default();
        sys.dispatcher.cooldown = cooldown;
        sys.episode.seed = 9;
        let r =
            run_policy(&sys, PolicyKind::Rapid, &[TaskKind::PegInsert], 3, &mut edge, &mut cloud);
        r.episodes.iter().map(|m| m.cloud_events as f64).sum::<f64>() / r.episodes.len() as f64
    };
    let no_cd = count_offloads(0);
    let with_cd = count_offloads(16);
    assert!(with_cd <= no_cd, "cooldown increased offloads: {with_cd} > {no_cd}");
}

#[test]
fn ablations_degrade_gracefully_not_catastrophically() {
    let sys = SystemConfig::default();
    let mut edge = AnalyticBackend::edge(6);
    let mut cloud = AnalyticBackend::cloud(6);
    for kind in [PolicyKind::RapidNoComp, PolicyKind::RapidNoRed, PolicyKind::RapidStaticFusion] {
        let r = run_policy(&sys, kind, &ALL_TASKS, 2, &mut edge, &mut cloud);
        let row = aggregate(kind, &r.episodes);
        assert!(row.total_lat_mean.is_finite());
        assert!(row.total_lat_mean < 782.5, "{kind:?} worse than edge-only");
    }
}

//! Chaos suite: deterministic fault injection against the fleet
//! scheduler. The load-bearing guarantees:
//!
//! * a **fault-free plan is bit-identical** to a baseline fleet run (the
//!   engine must not perturb a single PRNG draw when idle),
//! * an **endpoint crash mid-run never deadlocks** — every episode of
//!   every session completes, routed around the dead endpoint,
//! * **dropped replies degrade to the edge slice** and the failover is
//!   recorded in both per-episode metrics and scheduler stats,
//! * chaos runs **replay exactly** under a fixed seed,
//! * the **real TCP path fails over** when an endpoint dies at the RPC
//!   layer instead of panicking.

use rapid::config::{FaultsConfig, PolicyKind, SystemConfig};
use rapid::faults::{FaultEngine, FaultPlan};
use rapid::metrics::EpisodeMetrics;
use rapid::net::{CloudClient, CloudServer};
use rapid::robot::TaskKind;
use rapid::serve::{Fleet, FleetResult};
use rapid::vla::AnalyticBackend;
use std::sync::atomic::Ordering;

fn fleet_sys(n: usize, endpoints: usize) -> SystemConfig {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = n;
    sys.fleet.max_batch = 4;
    sys.fleet.max_inflight = 16;
    sys.fleet.endpoints = endpoints;
    sys
}

fn assert_metrics_eq(a: &EpisodeMetrics, b: &EpisodeMetrics, tag: &str) {
    assert_eq!(a.steps, b.steps, "{tag}: steps");
    assert_eq!(a.cloud_events, b.cloud_events, "{tag}: cloud_events");
    assert_eq!(a.edge_events, b.edge_events, "{tag}: edge_events");
    assert_eq!(a.preemptions, b.preemptions, "{tag}: preemptions");
    assert_eq!(a.retransmissions, b.retransmissions, "{tag}: retransmissions");
    assert_eq!(a.deferred_offloads, b.deferred_offloads, "{tag}: deferred_offloads");
    assert_eq!(a.failovers, b.failovers, "{tag}: failovers");
    assert_eq!(a.latency_columns(), b.latency_columns(), "{tag}: latency columns");
    assert_eq!(a.rms_error, b.rms_error, "{tag}: rms_error");
    assert_eq!(a.success, b.success, "{tag}: success");
    assert_eq!(a.edge_gb, b.edge_gb, "{tag}: edge_gb");
}

fn assert_runs_identical(a: &FleetResult, b: &FleetResult, tag: &str) {
    assert_eq!(a.stats.rounds, b.stats.rounds, "{tag}: rounds");
    assert_eq!(a.stats.batches, b.stats.batches, "{tag}: batches");
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests, "{tag}: batched_requests");
    assert_eq!(a.stats.deferred_offloads, b.stats.deferred_offloads, "{tag}: deferred");
    assert_eq!(a.stats.dropped_replies, b.stats.dropped_replies, "{tag}: dropped");
    assert_eq!(a.stats.degraded_requests, b.stats.degraded_requests, "{tag}: degraded");
    assert_eq!(
        a.stats.failover_redispatches, b.stats.failover_redispatches,
        "{tag}: redispatches"
    );
    assert_eq!(a.stats.outage_rounds, b.stats.outage_rounds, "{tag}: outage rounds");
    assert_eq!(a.endpoint_dispatches, b.endpoint_dispatches, "{tag}: endpoint spread");
    assert_eq!(a.sessions.len(), b.sessions.len(), "{tag}: session count");
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        assert_eq!(sa.episodes.len(), sb.episodes.len(), "{tag}: episode count");
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_metrics_eq(ma, mb, &format!("{tag}: session {}", sa.session));
        }
    }
}

fn assert_all_complete(res: &FleetResult, task: TaskKind, tag: &str) {
    for s in &res.sessions {
        assert!(!s.episodes.is_empty(), "{tag}: session {} completed no episodes", s.session);
        for (ep, m) in s.episodes.iter().enumerate() {
            assert_eq!(
                m.steps,
                task.seq_len(),
                "{tag}: session {} episode {ep} wedged at step {}",
                s.session,
                m.steps
            );
        }
    }
}

// ---------------------------------------------------------------- identity

#[test]
fn fault_free_plan_is_bit_identical_to_baseline() {
    let sys = fleet_sys(6, 2);
    let baseline = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();

    // an explicitly attached, empty-plan engine must change nothing
    let engine = FaultEngine::new(FaultPlan::none(), 12345, 250.0, 2);
    let empty =
        Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::Rapid, engine).run();
    assert_runs_identical(&baseline, &empty, "empty plan");

    // an enabled [faults] section whose windows never activate is equally
    // inert (this is what a chaos config with all-zero windows means)
    let mut inert = sys.clone();
    inert.faults.enabled = true;
    inert.faults.drop_prob = 0.9; // armed, but its window is empty
    let inert_run = Fleet::local(&inert, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert_runs_identical(&baseline, &inert_run, "inert config");
    assert_eq!(inert_run.stats.dropped_replies, 0);
    assert_eq!(inert_run.stats.degraded_requests, 0);
}

// ---------------------------------------------------------------- crashes

#[test]
fn endpoint_crash_mid_run_never_deadlocks() {
    // endpoints 0 and 1 crash early and never recover; everything must
    // route to survivor 2 and every episode must complete
    let sys = fleet_sys(6, 3);
    let plan = FaultPlan::none().crash(0, 2, u64::MAX).crash(1, 5, u64::MAX);
    let engine = FaultEngine::new(plan, 1, 250.0, 2);
    let res =
        Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, engine).run();

    assert_all_complete(&res, TaskKind::PickPlace, "crash");
    // no endpoint survived? no — 2 did, so nothing degraded to the edge
    assert_eq!(res.stats.degraded_requests, 0, "{:?}", res.stats);
    assert!(res.endpoint_dispatches[2] > 0, "{:?}", res.endpoint_dispatches);
    // no deferrals, no drops: every offload still becomes a cloud event
    let refill_rounds = (TaskKind::PickPlace.seq_len() + rapid::CHUNK - 1) / rapid::CHUNK;
    assert_eq!(res.total_cloud_events(), (6 * refill_rounds) as u64);
}

#[test]
fn all_endpoints_crashed_degrades_every_offload_to_the_edge() {
    let sys = fleet_sys(4, 2);
    let plan = FaultPlan::none().crash(0, 0, u64::MAX).crash(1, 0, u64::MAX);
    let engine = FaultEngine::new(plan, 1, 250.0, 2);
    let res =
        Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, engine).run();

    assert_all_complete(&res, TaskKind::PickPlace, "total crash");
    assert!(res.stats.degraded_requests > 0);
    assert_eq!(res.endpoint_dispatches, vec![0, 0], "nothing may reach a dead endpoint");
    for s in &res.sessions {
        let m = &s.episodes[0];
        assert!(m.failovers > 0, "session {} recorded no failover", s.session);
        assert_eq!(m.edge_events, m.failovers, "session {}", s.session);
    }
}

// ------------------------------------------------------------------ drops

#[test]
fn dropped_replies_degrade_to_edge_and_record_the_failover() {
    // single endpoint, every reply lost: each dispatch drops, the retry
    // finds no survivor, the batch degrades — and the books balance
    let sys = fleet_sys(4, 1);
    let plan = FaultPlan::none().drop_replies(0, u64::MAX, 1.0);
    let engine = FaultEngine::new(plan, 7, 250.0, 2);
    let res = Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::Rapid, engine).run();

    assert_all_complete(&res, TaskKind::PickPlace, "drops");
    assert!(res.stats.dropped_replies > 0);
    assert!(res.stats.degraded_requests > 0);
    assert_eq!(res.stats.degraded_requests, res.stats.batched_requests);
    let failovers: u64 =
        res.sessions.iter().flat_map(|s| s.episodes.iter()).map(|m| m.failovers).sum();
    assert_eq!(
        failovers,
        res.stats.degraded_requests,
        "per-episode metrics must record each failover"
    );
}

#[test]
fn partial_drop_window_fails_over_to_surviving_endpoint() {
    // two endpoints, drops only in a window: inside it, the retry lands on
    // the other endpoint (which draws its own drop decision); the run
    // completes either way and any lost reply is accounted
    let sys = fleet_sys(6, 2);
    let plan = FaultPlan::none().drop_replies(0, 30, 0.8);
    let engine = FaultEngine::new(plan, 3, 250.0, 2);
    let res =
        Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, engine).run();

    assert_all_complete(&res, TaskKind::PickPlace, "partial drops");
    assert!(res.stats.dropped_replies > 0, "{:?}", res.stats);
    assert!(res.stats.failover_redispatches > 0, "{:?}", res.stats);
}

// ----------------------------------------------------------------- outage

#[test]
fn outage_blocks_pending_batch_dispatch_and_degrades_it() {
    // sessions suspend at round 0 (pre-outage); the drain flush fires at
    // round 1, inside the outage window — the pending batch must NOT
    // leave the edge: it degrades, charged one offload timeout
    let mut sys = fleet_sys(4, 2);
    sys.fleet.max_batch = 8; // 4 sessions can never fill the batch
    sys.fleet.batch_deadline_us = 50_000; // 1 round: no same-round deadline flush
    let plan = FaultPlan::none().outage(1, 5);
    let engine = FaultEngine::new(plan, 1, 250.0, 2);
    let res =
        Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, engine).run();

    assert_all_complete(&res, TaskKind::PickPlace, "outage");
    // the round-0 batch (one request per session) degraded mid-outage
    assert!(res.stats.degraded_requests >= 4, "{:?}", res.stats);
    assert!(res.stats.outage_rounds >= 1, "{:?}", res.stats);
    for s in &res.sessions {
        let m = &s.episodes[0];
        assert!(m.failovers >= 1, "session {} never failed over", s.session);
        // exactly one timeout charged per degraded request — pinned
        // exactly: a CloudOnly session's only other overhead source is the
        // 40 ms/retransmission routing penalty, so double-charging (500ms
        // per failover) cannot hide in this equality
        let expect = 250.0 * m.failovers as f64 + 40.0 * m.retransmissions as f64;
        assert!(
            (m.overhead_ms - expect).abs() < 1e-6,
            "session {}: overhead {} != {expect} (failovers {}, retrans {})",
            s.session,
            m.overhead_ms,
            m.failovers,
            m.retransmissions
        );
    }
    // offloads after the outage window dispatch normally
    assert!(res.endpoint_dispatches.iter().sum::<u64>() > 0, "{:?}", res.endpoint_dispatches);
}

// ---------------------------------------------------------------- replay

#[test]
fn chaos_runs_replay_exactly_under_a_fixed_seed() {
    let mut sys = fleet_sys(6, 3);
    sys.faults = FaultsConfig::demo();
    let a = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    let b = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert_runs_identical(&a, &b, "chaos replay");
    assert_all_complete(&a, TaskKind::PickPlace, "chaos replay");
}

// ----------------------------------------------------- the shipped config

#[test]
fn chaos_toml_schedule_matches_builtin_demo() {
    // `rapid chaos` falls back to FaultsConfig::demo() (+ the same fleet
    // shape) when the file is absent — the two must not drift
    let src = std::fs::read_to_string("configs/chaos.toml").expect("configs/chaos.toml");
    let sys = SystemConfig::from_toml(&src).expect("chaos.toml parses");
    assert_eq!(sys.faults, FaultsConfig::demo(), "chaos.toml and FaultsConfig::demo() drifted");
    assert_eq!(sys.fleet.n_sessions, 6);
    assert_eq!(sys.fleet.endpoints, 3);
}

#[test]
fn chaos_toml_fleet_completes_every_episode_for_every_policy() {
    let src = std::fs::read_to_string("configs/chaos.toml").expect("configs/chaos.toml");
    let sys = SystemConfig::from_toml(&src).expect("chaos.toml parses");
    assert!(sys.faults.enabled);
    assert!(sys.faults.crash_end > sys.faults.crash_start, "chaos.toml schedules a crash");
    assert!(sys.fleet.endpoints >= 2, "chaos.toml is multi-endpoint");

    for kind in [PolicyKind::Rapid, PolicyKind::EdgeOnly, PolicyKind::CloudOnly] {
        let res = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert_all_complete(&res, TaskKind::PickPlace, &format!("chaos.toml {kind:?}"));
    }
}

// ------------------------------------------------------------- real wire

#[test]
fn crashed_remote_endpoint_fails_over_to_the_survivor() {
    // endpoint 0 is a live server; endpoint 1 is a connection whose
    // listener is gone before the run starts — its first RPC errors, the
    // scheduler circuit-breaks it and re-dispatches to the survivor
    let server =
        CloudServer::start("127.0.0.1:0", 4, || Box::new(AnalyticBackend::cloud(100))).unwrap();
    let alive = CloudClient::connect(&server.addr.to_string()).unwrap();
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let c = CloudClient::connect(&addr.to_string()).unwrap();
        drop(l); // never accepted; the connection dies with the listener
        c
    };

    let sys = fleet_sys(4, 2);
    let res =
        Fleet::remote(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, vec![alive, dead]).run();

    assert_all_complete(&res, TaskKind::PickPlace, "remote failover");
    assert!(res.stats.endpoint_errors >= 1, "{:?}", res.stats);
    assert!(res.stats.failover_redispatches >= 1, "{:?}", res.stats);
    assert_eq!(res.stats.degraded_requests, 0, "the survivor serves everything");

    let refill_rounds = (TaskKind::PickPlace.seq_len() + rapid::CHUNK - 1) / rapid::CHUNK;
    let served = server.stats().requests.load(Ordering::Relaxed);
    assert_eq!(served, (4 * refill_rounds) as u64, "every request reached the survivor");
    server.shutdown();
}

#[test]
fn remote_fleet_with_engine_crash_window_routes_around_the_endpoint() {
    // injected (engine-level) crash on a *real* endpoint: the scheduler
    // must never dispatch to it during the window
    let servers: Vec<CloudServer> = (0..2)
        .map(|i| {
            CloudServer::start("127.0.0.1:0", 4, move || {
                Box::new(AnalyticBackend::cloud(200 + i as u64))
            })
            .unwrap()
        })
        .collect();
    let clients: Vec<CloudClient> =
        servers.iter().map(|s| CloudClient::connect(&s.addr.to_string()).unwrap()).collect();

    let sys = fleet_sys(4, 2);
    let plan = FaultPlan::none().crash(1, 0, u64::MAX);
    let engine = FaultEngine::new(plan, 1, 250.0, 2);
    let res =
        Fleet::remote_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, clients, engine)
            .run();

    assert_all_complete(&res, TaskKind::PickPlace, "engine crash on wire");
    assert_eq!(res.endpoint_dispatches[1], 0, "{:?}", res.endpoint_dispatches);
    assert_eq!(servers[1].stats().requests.load(Ordering::Relaxed), 0);
    assert!(servers[0].stats().requests.load(Ordering::Relaxed) > 0);
    for s in servers {
        s.shutdown();
    }
}

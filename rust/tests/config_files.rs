//! The shipped config files must parse and produce sane systems.

use rapid::config::{NoiseLevel, SystemConfig};

fn load(path: &str) -> SystemConfig {
    let src = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path}: {e}"));
    SystemConfig::from_toml(&src).unwrap_or_else(|e| panic!("{path}: {e}"))
}

#[test]
fn libero_toml_matches_builtin_preset() {
    let cfg = load("configs/libero.toml");
    let builtin = rapid::config::presets::libero_preset();
    assert_eq!(cfg.total_model_gb, builtin.total_model_gb);
    assert_eq!(cfg.dispatcher.theta_comp, builtin.dispatcher.theta_comp);
    assert_eq!(cfg.dispatcher.theta_red, builtin.dispatcher.theta_red);
    assert_eq!(cfg.devices.edge_full_ms, builtin.devices.edge_full_ms);
    assert_eq!(cfg.scene.noise, NoiseLevel::Standard);
    // the [models] section ships disabled (zoo bit-identity) with the
    // default family list
    assert!(!cfg.models.enabled);
    assert_eq!(cfg.models.family_list(), builtin.models.family_list());
}

#[test]
fn realworld_toml_matches_builtin_preset() {
    let cfg = load("configs/realworld.toml");
    let builtin = rapid::config::presets::realworld_preset();
    assert_eq!(cfg.total_model_gb, builtin.total_model_gb);
    assert_eq!(cfg.devices.edge_full_ms, builtin.devices.edge_full_ms);
    assert_eq!(cfg.link.rtt_ms, builtin.link.rtt_ms);
}

#[test]
fn stress_toml_loads_and_runs_an_episode() {
    let cfg = load("configs/stress_noise.toml");
    assert_eq!(cfg.scene.noise, NoiseLevel::Distraction);
    assert_eq!(cfg.link.bw_mbps, 200.0);
    // the stress scenario must still complete an episode
    let strategy = rapid::policy::build(rapid::config::PolicyKind::Rapid, &cfg);
    let mut edge = rapid::vla::AnalyticBackend::edge(1);
    let mut cloud = rapid::vla::AnalyticBackend::cloud(1);
    let out = rapid::serve::run_episode(
        &cfg,
        rapid::robot::TaskKind::PickPlace,
        strategy,
        &mut edge,
        &mut cloud,
        1,
        false,
    );
    assert_eq!(out.metrics.steps, 50);
    assert!(out.metrics.identity_holds(cfg.total_model_gb));
}

#[test]
fn workload_sections_ship_disabled() {
    // every preset ships [workload] off: the disabled engine compiles the
    // lockstep plan and the scheduler stays bit-identical to PR 4
    for path in [
        "configs/libero.toml",
        "configs/realworld.toml",
        "configs/stress_noise.toml",
        "configs/chaos.toml",
    ] {
        let cfg = load(path);
        assert!(!cfg.workload.enabled, "{path}: [workload] must ship disabled");
        assert_eq!(cfg.workload.arrivals, "fixed", "{path}");
        let plan = rapid::serve::workload::plan(&cfg);
        assert!(plan.is_lockstep(), "{path}: disabled workload must compile lockstep");
    }
    // the shipped demo trace parses and is time-sorted
    let rounds = rapid::serve::workload::parse_trace("@configs/arrivals.trace");
    assert_eq!(rounds.len(), 8);
    assert!(rounds.windows(2).all(|w| w[0] <= w[1]), "{rounds:?}");
}

#[test]
fn pipeline_sections_ship_disabled() {
    // every preset ships [pipeline] off with the default knobs: the
    // disabled pipeline is bit-identical to the sequential scheduler,
    // and the knobs carried alongside match what the arms of
    // `experiments::pipeline` will run with when a user flips them on
    let defaults = rapid::config::SystemConfig::default().pipeline;
    for path in [
        "configs/libero.toml",
        "configs/realworld.toml",
        "configs/stress_noise.toml",
        "configs/chaos.toml",
    ] {
        let cfg = load(path);
        assert!(!cfg.pipeline.enabled, "{path}: [pipeline] must ship disabled");
        assert!(!cfg.pipeline.overlap_on(), "{path}");
        assert!(!cfg.pipeline.speculate_on(), "{path}");
        assert_eq!(cfg.pipeline, defaults, "{path}: shipped knobs must match the defaults");
    }
}

#[test]
fn trace_sections_ship_disabled() {
    // every preset ships [trace] off with the default knobs: a disabled
    // trace constructs no tracer/recorder and serving is bit-identical
    // to a trace-free build (pinned in rust/tests/obs_trace.rs)
    let defaults = rapid::config::SystemConfig::default().trace;
    for path in [
        "configs/libero.toml",
        "configs/realworld.toml",
        "configs/stress_noise.toml",
        "configs/chaos.toml",
    ] {
        let cfg = load(path);
        assert!(!cfg.trace.enabled, "{path}: [trace] must ship disabled");
        assert_eq!(cfg.trace, defaults, "{path}: shipped knobs must match the defaults");
    }
}

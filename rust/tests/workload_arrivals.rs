//! Differential conformance suite for the event-driven virtual-time
//! scheduler and the `[workload]` arrival engine.
//!
//! Two halves:
//!
//! * **Lockstep degeneracy ⇒ bit-identity.** The event-driven core must
//!   replay the historical lockstep round loop *exactly* — not just
//!   totals, but per-episode trajectories, flush causes and fault draws —
//!   both with `[workload]` absent/disabled (whatever the other workload
//!   knobs say) and with it **enabled** in the degenerate all-at-t0 fixed
//!   shape, across every serve path: plain fleets, the reuse cache, the
//!   chaos/failover schedule and the model zoo.
//! * **Dynamic arrivals hold the line.** Poisson/bursty/trace arrivals —
//!   including an 8-session Poisson mixed-family fleet under the full
//!   chaos demo plan — complete every episode with no wedged session and
//!   zero mixed-family batches, and replay exactly under a shared seed.

use rapid::config::{FaultsConfig, PolicyKind, SystemConfig};
use rapid::robot::TaskKind;
use rapid::serve::{Fleet, FleetResult};

/// Full-strength bit-identity: scheduler counters, flush causes, router
/// spread, cache counters, and exact per-episode trajectory columns.
fn assert_bit_identical(a: &FleetResult, b: &FleetResult, tag: &str) {
    assert_eq!(a.stats.rounds, b.stats.rounds, "{tag}: rounds");
    assert_eq!(a.stats.batches, b.stats.batches, "{tag}: batches");
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests, "{tag}: batched requests");
    assert_eq!(a.stats.multi_session_batches, b.stats.multi_session_batches, "{tag}: multi");
    assert_eq!(a.stats.full_flushes, b.stats.full_flushes, "{tag}: full flushes");
    assert_eq!(a.stats.deadline_flushes, b.stats.deadline_flushes, "{tag}: deadline flushes");
    assert_eq!(a.stats.drain_flushes, b.stats.drain_flushes, "{tag}: drain flushes");
    assert_eq!(a.stats.family_flushes, b.stats.family_flushes, "{tag}: family flushes");
    assert_eq!(a.stats.deferred_offloads, b.stats.deferred_offloads, "{tag}: deferred");
    assert_eq!(a.stats.dropped_replies, b.stats.dropped_replies, "{tag}: dropped");
    assert_eq!(a.stats.degraded_requests, b.stats.degraded_requests, "{tag}: degraded");
    assert_eq!(a.stats.failover_redispatches, b.stats.failover_redispatches, "{tag}: failover");
    assert_eq!(a.stats.outage_rounds, b.stats.outage_rounds, "{tag}: outage rounds");
    assert_eq!(a.endpoint_dispatches, b.endpoint_dispatches, "{tag}: router spread");
    assert_eq!(a.mean_batch, b.mean_batch, "{tag}: mean batch");
    assert_eq!(a.cache.hits, b.cache.hits, "{tag}: cache hits");
    assert_eq!(a.cache.probes, b.cache.probes, "{tag}: cache probes");
    assert_eq!(a.cache.evictions, b.cache.evictions, "{tag}: cache evictions");
    assert_eq!(a.sessions.len(), b.sessions.len(), "{tag}: session count");
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        assert_eq!(sa.family, sb.family, "{tag}: family");
        assert_eq!(sa.arrival_round, sb.arrival_round, "{tag}: arrival round");
        assert_eq!(sa.departure_round, sb.departure_round, "{tag}: departure round");
        assert_eq!(sa.episodes.len(), sb.episodes.len(), "{tag}: episode count");
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "{tag}: latency columns");
            assert_eq!(ma.cloud_events, mb.cloud_events, "{tag}: cloud events");
            assert_eq!(ma.edge_events, mb.edge_events, "{tag}: edge events");
            assert_eq!(ma.preemptions, mb.preemptions, "{tag}: preemptions");
            assert_eq!(ma.failovers, mb.failovers, "{tag}: failovers");
            assert_eq!(ma.cache_hits, mb.cache_hits, "{tag}: cache hits");
            assert_eq!(ma.overhead_ms, mb.overhead_ms, "{tag}: overhead");
            assert_eq!(ma.rms_error, mb.rms_error, "{tag}: trajectory (rms)");
            assert_eq!(ma.success, mb.success, "{tag}: success");
        }
    }
}

/// A `[workload]` section that is present — with hostile knobs — but
/// disabled. Must perturb nothing.
fn disabled_workload(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.workload.enabled = false;
    s.workload.arrivals = "poisson".into();
    s.workload.n_sessions = 77;
    s.workload.start_round = 500;
    s.workload.interarrival_rounds = 9.5;
    s.workload.seed = 0xDEAD_BEEF;
    s.workload.episodes_min = 4;
    s.workload.episodes_max = 9;
    s.workload.family_mix = "draw".into();
    s.workload.trace = "1,2,3".into();
    s
}

/// The degenerate *enabled* shape: everyone at t = 0, fleet episode
/// count, block families — must execute bit-identically to disabled.
fn degenerate_workload(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.workload.enabled = true;
    s.workload.arrivals = "fixed".into();
    s.workload.n_sessions = 0;
    s.workload.start_round = 0;
    s.workload.interarrival_rounds = 0.0;
    s.workload.episodes_min = 0;
    s.workload.episodes_max = 0;
    s.workload.family_mix = "blocks".into();
    s
}

#[test]
fn disabled_workload_keeps_the_fleet_bit_identical() {
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased] {
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = 4;
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&disabled_workload(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("{kind:?}"));
        assert_eq!(run.stats.arrivals, 4);
        assert_eq!(run.stats.max_active_sessions, 4);
    }
}

#[test]
fn degenerate_enabled_workload_is_bit_identical_on_the_fleet_path() {
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased] {
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = 4;
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&degenerate_workload(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("degenerate/{kind:?}"));
    }
}

#[test]
fn workload_keeps_the_reuse_cache_bit_identical() {
    // the cache path exercises probe/admission ordering across the round:
    // the event-driven core must replay the shared store's hit pattern
    // exactly, both disabled and in the degenerate enabled shape
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.cache.enabled = true;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(base.cache.hits > 0, "the cached fleet must actually hit");
    let off = Fleet::local(&disabled_workload(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly)
        .run();
    assert_bit_identical(&base, &off, "cache/disabled");
    let degen =
        Fleet::local(&degenerate_workload(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&base, &degen, "cache/degenerate");
}

#[test]
fn workload_keeps_the_chaos_path_bit_identical() {
    // the chaos path exercises the fault engine's shared PRNG stream: one
    // extra (or missing) draw anywhere in the event loop would shift every
    // later drop decision — the strictest differential there is
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.fleet.endpoints = 3;
    sys.faults = FaultsConfig::demo();
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let off = Fleet::local(&disabled_workload(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &off, &format!("chaos/disabled/{kind:?}"));
        let degen = Fleet::local(&degenerate_workload(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &degen, &format!("chaos/degenerate/{kind:?}"));
    }
}

#[test]
fn workload_keeps_the_zoo_path_bit_identical() {
    // mixed families + family-keyed batching under the event-driven core
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.models.enabled = true;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(base.stats.family_flushes > 0, "the zoo fleet must exercise the family seal");
    let off =
        Fleet::local(&disabled_workload(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&base, &off, "zoo/disabled");
    let degen =
        Fleet::local(&degenerate_workload(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&base, &degen, "zoo/degenerate");
    assert_eq!(degen.stats.mixed_family_batches, 0);
}

#[test]
fn multi_episode_rollovers_stay_bit_identical() {
    // episode rollover now routes through the arrival/departure hooks;
    // a multi-episode fleet pins that the rollover path didn't drift
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 3;
    sys.fleet.episodes_per_session = 3;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    let degen = Fleet::local(&degenerate_workload(&sys), TaskKind::PickPlace, PolicyKind::Rapid)
        .run();
    assert_bit_identical(&base, &degen, "rollover");
    for s in &degen.sessions {
        assert_eq!(s.episodes.len(), 3);
    }
}

#[test]
fn poisson_arrivals_complete_under_the_chaos_plan_and_replay() {
    // the acceptance criterion: an 8-session Poisson-arrival mixed-family
    // fleet completes the full chaos demo plan — crash, degrade, outage,
    // drops, delays — with zero mixed batches, no wedged session, and
    // exact seeded replay
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.fleet.endpoints = 3;
    sys.faults = FaultsConfig::demo();
    sys.models.enabled = true;
    sys.workload.enabled = true;
    sys.workload.arrivals = "poisson".into();
    sys.workload.interarrival_rounds = 4.0;
    sys.workload.seed = 23;
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let res = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert_eq!(res.stats.arrivals, 8, "{kind:?}");
        assert_eq!(res.stats.mixed_family_batches, 0, "{kind:?} mixed a batch under chaos");
        assert!(
            res.sessions.iter().any(|s| s.arrival_round > 0),
            "{kind:?}: the poisson plan must stagger someone"
        );
        for s in &res.sessions {
            for m in &s.episodes {
                assert_eq!(
                    m.steps,
                    TaskKind::PickPlace.seq_len(),
                    "{kind:?} session {} wedged under chaos",
                    s.session
                );
            }
            assert!(s.departure_round >= s.arrival_round);
        }
        // per-family counters still exactly partition the fleet totals
        let steps: u64 = res.families.iter().map(|t| t.steps).sum();
        let cloud: u64 = res.families.iter().map(|t| t.cloud_events).sum();
        assert_eq!(steps, res.total_steps(), "{kind:?}: family steps don't partition");
        assert_eq!(cloud, res.total_cloud_events(), "{kind:?}: family cloud events");
        // exact seeded replay: same arrivals, same faults, same metrics
        let again = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert_bit_identical(&res, &again, &format!("poisson-chaos replay {kind:?}"));
    }
}

#[test]
fn bursty_and_trace_arrivals_complete_under_chaos() {
    let mut base = SystemConfig::default();
    base.fleet.n_sessions = 6;
    base.fleet.endpoints = 3;
    base.faults = FaultsConfig::demo();
    base.workload.enabled = true;

    let mut bursty = base.clone();
    bursty.workload.arrivals = "bursty".into();
    bursty.workload.burst_len = 2;
    bursty.workload.idle_len = 7;

    let mut trace = base.clone();
    trace.workload.arrivals = "trace".into();
    trace.workload.n_sessions = 6;
    trace.workload.trace = "0, 0, 5, 11, 11, 20".into();

    for (tag, sys) in [("bursty", bursty), ("trace", trace)] {
        let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert_eq!(res.stats.arrivals, 6, "{tag}");
        assert!(res.sessions.iter().any(|s| s.arrival_round > 0), "{tag}: never staggered");
        for s in &res.sessions {
            for m in &s.episodes {
                assert_eq!(m.steps, TaskKind::PickPlace.seq_len(), "{tag}: wedged");
            }
        }
        let again = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
        assert_bit_identical(&res, &again, &format!("{tag} replay"));
    }
}

#[test]
fn trace_arrival_rounds_are_respected_exactly() {
    let mut sys = SystemConfig::default();
    sys.workload.enabled = true;
    sys.workload.arrivals = "trace".into();
    sys.workload.trace = "0, 3, 9".into();
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::EdgeOnly).run();
    assert_eq!(res.sessions.len(), 3, "the trace defines the fleet size");
    let arrivals: Vec<u64> = res.sessions.iter().map(|s| s.arrival_round).collect();
    assert_eq!(arrivals, vec![0, 3, 9]);
    // an edge-only session departs exactly seq_len rounds of stepping
    // after it joins (one step per round, no suspends)
    for s in &res.sessions {
        assert_eq!(
            s.departure_round - s.arrival_round,
            TaskKind::PickPlace.seq_len() as u64,
            "session {} didn't step once per round from arrival",
            s.session
        );
    }
    // the fleet's clock covers the straggler's whole episode
    assert!(res.stats.rounds > 9 + TaskKind::PickPlace.seq_len() as u64);
}

#[test]
fn staggered_arrivals_track_active_session_highwater() {
    // arrivals spaced wider than an episode: the fleet is never fully
    // co-resident, and the high-water mark proves sessions left before
    // later ones joined
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 3;
    sys.workload.enabled = true;
    sys.workload.arrivals = "fixed".into();
    sys.workload.interarrival_rounds = 80.0; // > one PickPlace episode
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::EdgeOnly).run();
    assert_eq!(res.stats.arrivals, 3);
    assert_eq!(res.stats.max_active_sessions, 1, "sessions must never overlap");
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
    }
}

#[test]
fn late_arrivals_still_batch_with_co_resident_sessions() {
    // two simultaneous waves of 3 CloudOnly sessions: within a wave the
    // offload rounds stay in phase and coalesce across sessions —
    // cross-session batching must survive dynamic membership
    let mut sys = SystemConfig::default();
    sys.fleet.max_batch = 3;
    sys.workload.enabled = true;
    sys.workload.arrivals = "trace".into();
    sys.workload.trace = "0,0,0,9,9,9".into();
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(
        res.stats.multi_session_batches > 0,
        "co-resident arrivals never coalesced: {:?}",
        res.stats
    );
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
    }
}

#[test]
fn workload_acceptance_on_the_shipped_config() {
    // configs/libero.toml with [workload] flipped on over the shipped
    // trace file: the full acceptance path end to end
    let src = std::fs::read_to_string("configs/libero.toml").expect("configs/libero.toml");
    let mut sys = SystemConfig::from_toml(&src).expect("parse libero.toml");
    assert!(!sys.workload.enabled, "libero.toml must ship [workload] disabled");
    sys.workload.enabled = true;
    sys.workload.arrivals = "trace".into();
    sys.workload.trace = "@configs/arrivals.trace".into();
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert_eq!(res.sessions.len(), 8, "the shipped trace carries 8 arrivals");
    assert!(res.sessions.iter().any(|s| s.arrival_round > 0));
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
    }
}
